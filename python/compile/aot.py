"""AOT compile path: lower the L2 model (with its L1 Pallas kernel) to HLO
*text* artifacts the Rust runtime loads through PJRT, plus export the
model's operator graph as a paper-format workload JSON.

Run once via ``make artifacts``; Python never runs on the request path.

HLO text (NOT ``lowered.compiler_ir("hlo")``'s proto serialization): jax
>= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: default HLO printing ELIDES large constants — the model
    # weights would silently become zeros on the Rust side. Print through
    # HloModule.to_string with print_large_constants.
    module = comp.as_hlo_module()
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax's new metadata fields (source_end_line etc.) are unknown to the
    # xla_extension-0.5.1 text parser — drop metadata entirely.
    opts.print_metadata = False
    return module.to_string(opts)


def export_stages(cfg, params, num_stages, batch, out_dir):
    """One HLO artifact per pipeline stage + a manifest."""
    manifest = {"num_stages": num_stages, "batch": batch,
                "seq": cfg.seq, "hidden": cfg.hidden, "vocab": cfg.vocab,
                "stages": []}
    for s in range(num_stages):
        fn, is_last = model.stage_fn(params, cfg, s, num_stages)
        spec = jax.ShapeDtypeStruct((batch, cfg.seq, cfg.hidden), jnp.float32)
        lowered = jax.jit(fn).lower(spec)
        text = to_hlo_text(lowered)
        name = f"stage_{s}.hlo.txt"
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        out_feat = cfg.vocab if is_last else cfg.hidden
        manifest["stages"].append({
            "path": name,
            "features_in": cfg.seq * cfg.hidden,
            "features_out": cfg.seq * out_feat,
        })
        print(f"  wrote {name} ({len(text)} chars)")
    # full model too, for single-device comparison
    full = jax.jit(lambda x: (model.forward(params, cfg, x),)).lower(
        jax.ShapeDtypeStruct((batch, cfg.seq, cfg.hidden), jnp.float32))
    with open(os.path.join(out_dir, "model_full.hlo.txt"), "w") as f:
        f.write(to_hlo_text(full))
    manifest["full"] = "model_full.hlo.txt"
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("  wrote manifest.json + model_full.hlo.txt")


def export_reference_io(cfg, params, batch, out_dir):
    """Golden input/output pair so the Rust e2e test can check numerics."""
    key = jax.random.PRNGKey(42)
    x = jax.random.normal(key, (batch, cfg.seq, cfg.hidden), dtype=jnp.float32)
    y = model.forward(params, cfg, x)
    ref = {
        "input": [float(v) for v in x.reshape(-1)],
        "output_sample": [float(v) for v in y.reshape(-1)[:64]],
        "output_mean": float(y.mean()),
        "output_shape": list(y.shape),
    }
    with open(os.path.join(out_dir, "reference_io.json"), "w") as f:
        json.dump(ref, f)
    print("  wrote reference_io.json")


def export_op_graph(cfg, params, batch, out_dir):
    """Export the jitted model's operator graph as a workload JSON (paper
    format) by parsing the lowered HLO *text* — a real operator graph,
    with naive per-op cost estimates, for the L3 partitioner to chew on."""
    import re

    spec = jax.ShapeDtypeStruct((batch, cfg.seq, cfg.hidden), jnp.float32)
    lowered = jax.jit(lambda x: (model.forward(params, cfg, x),)).lower(spec)
    text = to_hlo_text(lowered)
    nodes, edges = [], []
    name_to_id = {}
    in_entry = False
    instr_re = re.compile(r"^\s+(?:ROOT\s+)?(%?[\w.-]+)\s*=\s*\S+\s+([\w-]+)\(([^)]*)\)")
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            in_entry = True
            continue
        if in_entry and line.startswith("}"):
            break
        if not in_entry:
            continue
        mt = instr_re.match(line)
        if not mt:
            continue
        name, opcode, operands = mt.groups()
        nid = len(nodes)
        name_to_id[name] = nid
        is_dot = opcode in ("dot", "convolution", "fusion")
        nodes.append({
            "id": nid, "name": f"{opcode}_{nid}",
            "cpuLatency": 1.0 if is_dot else 0.05,
            "acceleratorLatency": 0.05 if is_dot else 0.01,
            "size": 0.1, "communicationCost": 0.02,
        })
        for ref in re.findall(r"%?[\w.-]+", operands):
            if ref in name_to_id and name_to_id[ref] != nid:
                edges.append({"sourceId": name_to_id[ref], "destId": nid})
    edges = [dict(t) for t in {tuple(sorted(e.items())) for e in edges}]
    wl = {"name": "mini-bert-hlo", "maxMemoryPerDevice": 1e9,
          "numAccelerators": 3, "numCpus": 1, "nodes": nodes, "edges": edges}
    with open(os.path.join(out_dir, "mini_bert_opgraph.json"), "w") as f:
        json.dump(wl, f)
    print(f"  wrote mini_bert_opgraph.json ({len(nodes)} ops, {len(edges)} edges)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--stages", type=int, default=3)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--hidden", type=int, default=128)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = model.Config(hidden=args.hidden, layers=args.layers)
    params = model.init_params(cfg)
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
    print(f"mini-BERT: {cfg.layers} layers, hidden {cfg.hidden}, {n_params/1e6:.2f}M params")
    export_stages(cfg, params, args.stages, args.batch, args.out_dir)
    export_reference_io(cfg, params, args.batch, args.out_dir)
    try:
        export_op_graph(cfg, params, args.batch, args.out_dir)
    except Exception as e:  # HLO-walking API varies across jax versions
        print(f"  op-graph export skipped: {e}")


if __name__ == "__main__":
    main()

"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every Pallas kernel in this package must match its reference here to
``assert_allclose`` tolerances across the shape/dtype grid exercised by
``python/tests``.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, scale=None):
    """Plain scaled-dot-product attention: softmax(q @ k.T * scale) @ v.

    Shapes: q [*, S, D], k [*, T, D], v [*, T, D] (leading dims arbitrary).
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.einsum("...sd,...td->...st", q, k) * scale
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("...st,...td->...sd", probs, v)


def transformer_block_ref(x, params, num_heads):
    """Reference transformer encoder block (pre-LN), mirroring model.py.

    x: [B, S, H]; params: dict with wq, wk, wv, wo [H, H], w1 [H, F],
    w2 [F, H], ln1_g/ln1_b/ln2_g/ln2_b [H].
    """
    b, s, h = x.shape
    d = h // num_heads

    def ln(y, g, beta):
        mu = y.mean(-1, keepdims=True)
        var = y.var(-1, keepdims=True)
        return (y - mu) / jnp.sqrt(var + 1e-5) * g + beta

    y = ln(x, params["ln1_g"], params["ln1_b"])
    q = (y @ params["wq"]).reshape(b, s, num_heads, d).transpose(0, 2, 1, 3)
    k = (y @ params["wk"]).reshape(b, s, num_heads, d).transpose(0, 2, 1, 3)
    v = (y @ params["wv"]).reshape(b, s, num_heads, d).transpose(0, 2, 1, 3)
    attn = attention_ref(q, k, v)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, s, h)
    x = x + attn @ params["wo"]
    y = ln(x, params["ln2_g"], params["ln2_b"])
    ff = jnp.maximum(y @ params["w1"], 0.0) @ params["w2"]  # ReLU MLP
    return x + ff

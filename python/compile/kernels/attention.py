"""L1 — fused scaled-dot-product attention as a Pallas kernel.

Flash-attention-style tiling rethought for TPU (DESIGN.md
§Hardware-Adaptation): the query block lives in VMEM across the whole
key/value sweep, K/V stream in block-by-block via ``BlockSpec`` (the
HBM→VMEM schedule that a CUDA implementation would express with
threadblocks + shared memory), and softmax is computed *online* (running
max/denominator) so the S = QKᵀ matrix never materializes outside VMEM.
Both matmuls per grid step are (block_q × d)·(d × block_k) — MXU-shaped.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is *estimated* from the VMEM footprint
and MXU utilization in DESIGN.md, not measured here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k, scale):
    """One (batch·head, q-block) grid step: sweep K/V blocks online."""
    q = q_ref[...]  # [block_q, d] — resident in VMEM for the whole sweep
    block_q, d = q.shape
    kv_len = k_ref.shape[0]

    def body(i, carry):
        acc, m_prev, l_prev = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...], i * block_k, block_k)
        v = jax.lax.dynamic_slice_in_dim(v_ref[...], i * block_k, block_k)
        # MXU matmul #1: [block_q, d] x [d, block_k]
        s = jnp.dot(q, k.T) * scale
        # online softmax update
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        # MXU matmul #2: [block_q, block_k] x [block_k, d]
        acc = acc * alpha[:, None] + jnp.dot(p, v)
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    m0 = jnp.full((block_q,), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, kv_len // block_k, body, (acc0, m0, l0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def attention(q, k, v, block_q=64, block_k=64):
    """Fused attention over [B, H, S, D] tensors (S divisible by blocks)."""
    b, h, s, d = q.shape
    t = k.shape[2]
    scale = 1.0 / (d**0.5)
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, "seq must divide blocks"

    q3 = q.reshape(b * h, s, d)
    k3 = k.reshape(b * h, t, d)
    v3 = v.reshape(b * h, t, d)

    grid = (b * h, s // block_q)
    out = pl.pallas_call(
        functools.partial(_attention_kernel, block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            # q: one block per grid step — stays in VMEM for the sweep
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            # k/v: the full sequence for this (batch, head); the inner loop
            # slices block_k-sized chunks (the HBM→VMEM stream)
            pl.BlockSpec((None, t, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, t, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q3, k3, v3)
    return out.reshape(b, h, s, d)


def vmem_footprint_bytes(block_q, block_k, d, dtype_bytes=4):
    """Per-grid-step VMEM residency estimate (see DESIGN.md §Perf):
    q block + one k/v block pair + probs tile + accumulator + stats."""
    return dtype_bytes * (
        block_q * d  # q
        + 2 * block_k * d  # k, v (current chunk)
        + block_q * block_k  # p tile
        + block_q * d  # acc
        + 3 * block_q  # m, l, alpha
    )

"""L2 — the mini-BERT transformer whose operator graph the Rust
coordinator partitions and whose pipeline stages it executes.

The forward pass calls the L1 Pallas attention kernel
(:mod:`compile.kernels.attention`) so the fused kernel lowers into the
same HLO as the surrounding jnp ops. ``stage_fn`` slices the model into
`num_stages` contiguous stages (embedding+early layers … late
layers+head) so ``aot.py`` can export one HLO artifact per pipeline
stage; stage composition is pytest-checked against the full model.

Default config is ~100k parameters per layer at H=128 — big enough to be
a real model on the CPU backend, small enough to iterate quickly.
"""

import jax
import jax.numpy as jnp

from .kernels.attention import attention


class Config:
    def __init__(self, hidden=128, layers=4, heads=2, ffn=512, seq=64, vocab=1000):
        self.hidden = hidden
        self.layers = layers
        self.heads = heads
        self.ffn = ffn
        self.seq = seq
        self.vocab = vocab


def init_params(cfg, seed=0):
    """Deterministic parameter pytree."""
    key = jax.random.PRNGKey(seed)
    params = {"emb": jax.random.normal(key, (cfg.vocab, cfg.hidden)) * 0.02}
    for l in range(cfg.layers):
        key, *ks = jax.random.split(key, 7)
        h, f = cfg.hidden, cfg.ffn
        params[f"l{l}"] = {
            "wq": jax.random.normal(ks[0], (h, h)) * h**-0.5,
            "wk": jax.random.normal(ks[1], (h, h)) * h**-0.5,
            "wv": jax.random.normal(ks[2], (h, h)) * h**-0.5,
            "wo": jax.random.normal(ks[3], (h, h)) * h**-0.5,
            "w1": jax.random.normal(ks[4], (h, f)) * h**-0.5,
            "w2": jax.random.normal(ks[5], (f, h)) * f**-0.5,
            "ln1_g": jnp.ones((h,)),
            "ln1_b": jnp.zeros((h,)),
            "ln2_g": jnp.ones((h,)),
            "ln2_b": jnp.zeros((h,)),
        }
    key, k2 = jax.random.split(key)
    params["head"] = jax.random.normal(k2, (cfg.hidden, cfg.vocab)) * cfg.hidden**-0.5
    return params


def _ln(y, g, b):
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    return (y - mu) / jnp.sqrt(var + 1e-5) * g + b


def block(x, p, heads):
    """Pre-LN transformer block; attention runs through the Pallas kernel."""
    b, s, h = x.shape
    d = h // heads
    y = _ln(x, p["ln1_g"], p["ln1_b"])
    q = (y @ p["wq"]).reshape(b, s, heads, d).transpose(0, 2, 1, 3)
    k = (y @ p["wk"]).reshape(b, s, heads, d).transpose(0, 2, 1, 3)
    v = (y @ p["wv"]).reshape(b, s, heads, d).transpose(0, 2, 1, 3)
    a = attention(q, k, v, block_q=min(64, s), block_k=min(64, s))
    a = a.transpose(0, 2, 1, 3).reshape(b, s, h)
    x = x + a @ p["wo"]
    y = _ln(x, p["ln2_g"], p["ln2_b"])
    return x + jnp.maximum(y @ p["w1"], 0.0) @ p["w2"]


def forward(params, cfg, x):
    """Full model: activations in [B, S, H] → logits [B, S, vocab].

    Takes pre-embedded activations (the serving path feeds f32 tensors);
    use `embed` for token ids.
    """
    for l in range(cfg.layers):
        x = block(x, params[f"l{l}"], cfg.heads)
    return x @ params["head"]


def embed(params, ids):
    return params["emb"][ids]


def stage_bounds(cfg, num_stages):
    """Split layer indices into contiguous stages (plus head in the last)."""
    assert 1 <= num_stages <= cfg.layers
    bounds = []
    per = cfg.layers / num_stages
    for s in range(num_stages):
        lo = round(s * per)
        hi = round((s + 1) * per)
        bounds.append((lo, hi))
    return bounds


def stage_fn(params, cfg, stage, num_stages):
    """The callable for one pipeline stage: activations → activations
    (logits for the last stage). Returns (fn, out_is_logits)."""
    lo, hi = stage_bounds(cfg, num_stages)[stage]
    last = stage == num_stages - 1

    def fn(x):
        for l in range(lo, hi):
            x = block(x, params[f"l{l}"], cfg.heads)
        if last:
            x = x @ params["head"]
        return (x,)

    return fn, last

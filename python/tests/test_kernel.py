"""L1 correctness: the Pallas attention kernel vs the pure-jnp oracle,
swept across shapes and dtypes (hypothesis when available, a grid
otherwise), plus invariants (softmax normalization, permutation
equivariance)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.attention import attention, vmem_footprint_bytes
from compile.kernels.ref import attention_ref

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


def check(b, h, s, d, dtype, block_q=64, block_k=64, tol=None):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(b * 1000 + s + d), 3)
    q = rand(k1, (b, h, s, d), dtype)
    k = rand(k2, (b, h, s, d), dtype)
    v = rand(k3, (b, h, s, d), dtype)
    out = attention(q, k, v, block_q=block_q, block_k=block_k)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32))
    if tol is None:
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,s,d", [
    (1, 1, 64, 32),
    (2, 2, 64, 64),
    (1, 4, 128, 32),
    (2, 1, 128, 64),
    (1, 2, 256, 16),
])
def test_matches_ref_f32(b, h, s, d):
    check(b, h, s, d, jnp.float32)


@pytest.mark.parametrize("s,d", [(64, 32), (128, 64)])
def test_matches_ref_bf16(s, d):
    check(1, 2, s, d, jnp.bfloat16)


@pytest.mark.parametrize("block_q,block_k", [(16, 16), (32, 64), (64, 32), (128, 128)])
def test_block_shape_invariance(block_q, block_k):
    # same numerics regardless of tiling
    check(1, 2, 128, 32, jnp.float32, block_q=block_q, block_k=block_k)


def test_single_block_degenerate():
    # seq == block: the online-softmax loop runs exactly once
    check(1, 1, 64, 16, jnp.float32, block_q=64, block_k=64)


def test_uniform_values_average():
    # constant v ⇒ output == v regardless of scores
    q = jnp.ones((1, 1, 64, 16))
    k = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 64, 16))
    v = jnp.full((1, 1, 64, 16), 3.25)
    out = attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), 3.25, rtol=1e-5)


def test_scale_matches_ref_explicitly():
    # the kernel folds 1/sqrt(d); a mismatch shows up as systematic error
    b, h, s, d = 1, 1, 64, 64
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    q = 10.0 * jax.random.normal(k1, (b, h, s, d))
    k = 10.0 * jax.random.normal(k2, (b, h, s, d))
    v = jax.random.normal(k3, (b, h, s, d))
    out = attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_vmem_footprint_under_budget():
    # DESIGN §Hardware-Adaptation: default tiling must fit VMEM comfortably
    assert vmem_footprint_bytes(128, 128, 64) < 1 << 20  # ≪ 16 MiB


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 2),
        h=st.integers(1, 2),
        s=st.sampled_from([64, 128]),
        d=st.sampled_from([16, 32, 64]),
    )
    def test_hypothesis_shape_sweep(b, h, s, d):
        check(b, h, s, d, jnp.float32)

"""L2 correctness: the model block vs the pure-jnp reference, stage
composition == full model, and AOT manifest sanity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels.ref import transformer_block_ref


def small_cfg():
    return model.Config(hidden=64, layers=3, heads=2, ffn=128, seq=64, vocab=100)


def test_block_matches_reference():
    cfg = small_cfg()
    params = model.init_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.seq, cfg.hidden))
    ours = model.block(x, params["l0"], cfg.heads)
    ref = transformer_block_ref(x, params["l0"], cfg.heads)
    np.testing.assert_allclose(np.asarray(ours), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_stage_composition_equals_full_model():
    cfg = small_cfg()
    params = model.init_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, cfg.seq, cfg.hidden))
    full = model.forward(params, cfg, x)
    for num_stages in (1, 2, 3):
        y = x
        for s in range(num_stages):
            fn, _ = model.stage_fn(params, cfg, s, num_stages)
            (y,) = fn(y)
        np.testing.assert_allclose(np.asarray(y), np.asarray(full),
                                   rtol=1e-5, atol=1e-5)


def test_stage_bounds_partition_layers():
    cfg = small_cfg()
    for num_stages in (1, 2, 3):
        bounds = model.stage_bounds(cfg, num_stages)
        assert bounds[0][0] == 0 and bounds[-1][1] == cfg.layers
        for (a, b), (c, _) in zip(bounds, bounds[1:]):
            assert b == c and a < b


def test_forward_is_deterministic():
    cfg = small_cfg()
    params = model.init_params(cfg)
    x = jnp.ones((1, cfg.seq, cfg.hidden))
    a = model.forward(params, cfg, x)
    b = model.forward(params, cfg, x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_embed_shape():
    cfg = small_cfg()
    params = model.init_params(cfg)
    ids = jnp.zeros((2, cfg.seq), dtype=jnp.int32)
    e = model.embed(params, ids)
    assert e.shape == (2, cfg.seq, cfg.hidden)

//! §7 scenario: single-query inference on memory-bound accelerators — the
//! model does NOT fit on one device, so a split is mandatory; minimize
//! latency with the Fig.-3 IP and compare the baselines.
//!
//! ```sh
//! cargo run --release --example latency_inference
//! ```

use dnn_partition::algos::{dp, ip_latency, objective};
use dnn_partition::baselines::{greedy, scotch_like};
use dnn_partition::workloads::{self, bert};
use std::time::Duration;

fn main() {
    let graph = bert::bert_op_graph(3, false);
    let sc = workloads::latency_scenario(&graph);
    let model_mb: f64 = graph.nodes.iter().map(|n| n.mem).sum();
    println!(
        "BERT-3 op graph, model {:.0} MB; {} accelerators x {:.0} MB (total {:.1}x model)",
        model_mb,
        sc.k,
        sc.mem_cap,
        sc.k as f64 * sc.mem_cap / model_mb
    );

    // baselines
    let g = greedy::solve(&graph, &sc);
    println!("greedy:       latency {:.2}", g.objective);
    let sco = scotch_like::solve_latency(&graph, &sc, 7);
    let viol = scotch_like::memory_violation(&graph, &sc, &sco);
    println!(
        "scotch-like:  latency {:.2}{}",
        sco.objective,
        if viol > 1.0 {
            format!("  (memory violated by {:.0}%)", (viol - 1.0) * 100.0)
        } else {
            String::new()
        }
    );
    if let Ok(ml) = dp::solve(&graph, &sc) {
        println!("max-load DP:  latency {:.2}", objective::latency(&graph, &sc, &ml));
    }

    // the latency IP
    let opts = ip_latency::LatencyIpOptions {
        time_limit: Duration::from_secs(15),
        warm_starts: vec![g],
        ..Default::default()
    };
    let r = ip_latency::solve(&graph, &sc, &opts).expect("latency IP failed");
    println!(
        "IP (latency): latency {:.2}  [status {:?}, gap {:.1}%, incumbent at {:?}]",
        r.placement.objective,
        r.status,
        r.gap * 100.0,
        r.incumbent_at
    );
    r.placement.check_memory(&graph, &sc).expect("IP split must respect memory");
}

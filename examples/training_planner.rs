//! Pipelined training planning (§5.3): BERT-24 layer graph, PipeDream and
//! GPipe schedules, with the Appendix-C extensions (replication,
//! interleaved communication, hierarchy).
//!
//! ```sh
//! cargo run --release --example training_planner
//! ```

use dnn_partition::algos::{dp, hierarchy, replication};
use dnn_partition::coordinator::placement::{CommModel, Scenario, TrainSchedule};
use dnn_partition::pipeline::sim::{self, Schedule};
use dnn_partition::workloads::bert;

fn main() {
    let graph = bert::bert24_layer_graph(true);
    println!("BERT-24 training layer graph: {} nodes", graph.n());

    // 1. PipeDream-objective optimal split on 6 devices
    let sc = Scenario::new(6, 1, 16.0 * 1024.0);
    let p = dp::solve(&graph, &sc).unwrap();
    println!("DP split, PipeDream objective max(FW+BW): TPS = {:.3}", p.objective);

    // 2. simulate both schedules on the same split (App. A: close together)
    for (sched, name) in [(Schedule::PipeDream1F1B, "1F1B"), (Schedule::GPipe, "GPipe")] {
        let r = sim::simulate(&graph, &sc, &p, sched, 24);
        println!("  simulated {name:<6} steady-state TPS = {:.3}", r.steady_tps);
    }

    // 3. App. C.1 — interleaved communication (load = max(compute, comm))
    let sc_overlap = Scenario { comm_model: CommModel::Overlap, ..sc.clone() };
    let p2 = dp::solve(&graph, &sc_overlap).unwrap();
    println!("with comm/compute overlap: TPS = {:.3}", p2.objective);

    // 4. App. C.2 — replication (hybrid model/data parallel)
    let sc_rep = Scenario { bandwidth: 1000.0, ..sc.clone() };
    let rep = replication::solve(&graph, &sc_rep, 1_000_000).unwrap();
    let replicated_stages = rep.stage_devices.iter().filter(|d| d.len() > 1).count();
    println!(
        "replication DP: TPS = {:.3} ({} stages replicated)",
        rep.objective, replicated_stages
    );

    // 5. App. C.3 — two clusters of 3 with a 4x slower inter-cluster link
    let hier = hierarchy::Hierarchy {
        num_clusters: 2,
        accs_per_cluster: 3,
        inter_factor: 4.0,
        mem_cap: 16.0 * 1024.0,
    };
    let h = hierarchy::solve(&graph, &hier, 1_000_000).unwrap();
    println!("hierarchical (2x3, 4x slower inter-cluster): TPS = {:.3}", h.objective);

    // 6. GPipe objective variant
    let sc_gpipe = Scenario { train_schedule: TrainSchedule::GPipe, ..sc };
    let pg = dp::solve(&graph, &sc_gpipe).unwrap();
    println!("GPipe objective maxFW+maxBW: TPS = {:.3}", pg.objective);
}

//! Quickstart: partition BERT-3 (operator graph) for pipelined inference
//! with the exact DP, compare against the non-contiguous IP, simulate the
//! pipeline, and render Fig.-9-style DOT splits.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dnn_partition::algos::{dp, ip_throughput};
use dnn_partition::pipeline::sim::{self, Schedule};
use dnn_partition::prelude::*;
use dnn_partition::workloads::bert;
use std::time::Duration;

fn main() {
    // 1. a workload: BERT-3 operator graph, 3 accelerators + 1 CPU (§6)
    let graph = bert::bert_op_graph(3, false);
    let scenario = Scenario::new(3, 1, 16.0 * 1024.0);
    println!("BERT-3 operator graph: {} ops, {} edges", graph.n(), graph.num_edges());

    // 2. optimal contiguous split (the paper's DP over ideals)
    let contiguous = dp::solve(&graph, &scenario).expect("DP failed");
    println!("DP (contiguous):       TPS = {:.3}", contiguous.objective);

    // 3. non-contiguous IP (may shave the bottleneck further, §5.2)
    let opts = ip_throughput::IpOptions {
        contiguous: false,
        time_limit: Duration::from_secs(10),
        ..Default::default()
    };
    let noncontig = ip_throughput::solve(&graph, &scenario, &opts).expect("IP failed");
    println!(
        "IP (non-contiguous):   TPS = {:.3}  (gain {:.1}%)",
        noncontig.placement.objective,
        100.0 * (contiguous.objective / noncontig.placement.objective - 1.0)
    );

    // 4. sanity: simulate the pipelined schedule; steady state == max-load
    let res = sim::simulate(&graph, &scenario, &contiguous, Schedule::Pipelined, 24);
    println!(
        "simulated steady-state TPS = {:.3} (predicted {:.3})",
        res.steady_tps, contiguous.objective
    );

    // 5. dump Fig.-9-style DOT renderings
    std::fs::write(
        "bert3_contiguous.dot",
        graph.to_dot(&contiguous.dense(scenario.k), "bert3-contiguous"),
    )
    .unwrap();
    std::fs::write(
        "bert3_noncontiguous.dot",
        graph.to_dot(&noncontig.placement.dense(scenario.k), "bert3-noncontiguous"),
    )
    .unwrap();
    println!("wrote bert3_contiguous.dot / bert3_noncontiguous.dot");
}

//! END-TO-END driver: all three layers compose.
//!
//! 1. `make artifacts` has AOT-lowered the mini-BERT (L2 JAX model calling
//!    the L1 Pallas attention kernel) into per-stage HLO artifacts;
//! 2. this binary (L3) loads the real operator graph exported from the
//!    same model, *plans* a placement with the paper's DP, then
//! 3. serves a stream of batched requests through the staged PJRT
//!    pipeline (one worker thread per device), checks the numerics against
//!    the JAX golden output, and reports latency/throughput vs prediction.
//!
//! ```sh
//! make artifacts && cargo run --release --example pipeline_serving
//! ```

use dnn_partition::algos::{dp, dpl};
use dnn_partition::runtime::server::{self, Request, ServerConfig};
use dnn_partition::runtime::stage::{artifacts_dir, StageSpec};
use dnn_partition::util::json::Json;
use dnn_partition::workloads::{json as wjson, Granularity, Workload};
use std::time::{Duration, Instant};

fn main() {
    let dir = artifacts_dir();
    let manifest_path = dir.join("manifest.json");
    let Ok(mtext) = std::fs::read_to_string(&manifest_path) else {
        eprintln!("no artifacts found at {} — run `make artifacts` first", dir.display());
        std::process::exit(1);
    };
    let manifest = Json::parse(&mtext).expect("bad manifest");
    let num_stages = manifest.get("num_stages").as_usize().unwrap();
    let batch = manifest.get("batch").as_usize().unwrap();
    let seq = manifest.get("seq").as_usize().unwrap();
    let hidden = manifest.get("hidden").as_usize().unwrap();
    println!("mini-BERT artifacts: {num_stages} stages, batch {batch}, seq {seq}, hidden {hidden}");

    // --- L3 planning on the REAL operator graph exported from the model ---
    if let Ok(text) = std::fs::read_to_string(dir.join("mini_bert_opgraph.json")) {
        let json = Json::parse(&text).unwrap();
        let (graph, scenario, name) = wjson::from_json(&json).unwrap();
        let w = Workload {
            name,
            graph,
            scenario,
            granularity: Granularity::Operator,
            training: false,
            expert: None,
            layer_of: None,
        };
        // exact DP if the lattice is small, DPL otherwise (§5.1.2)
        let planned = dp::solve_with_cap(&w.graph, &w.scenario, 200_000)
            .or_else(|_| dpl::solve(&w.graph, &w.scenario));
        match planned {
            Ok(p) => println!(
                "planned placement ({}) of the {}-op HLO graph over {} accelerators: predicted TPS {:.3}",
                p.algorithm,
                w.graph.n(),
                w.scenario.k,
                p.objective
            ),
            Err(e) => println!("planning note: {e}"),
        }
    }

    // --- build stage specs from the manifest ---
    let stages_json = manifest.get("stages").as_arr().unwrap();
    let specs: Vec<StageSpec> = stages_json
        .iter()
        .enumerate()
        .map(|(i, s)| StageSpec {
            name: format!("stage_{i}"),
            path: dir.join(s.get("path").as_str().unwrap()),
            tuple_arity: 1,
            sample_shape: vec![seq, hidden],
        })
        .collect();
    let _ = stages_json;

    // --- golden check: run one request through and compare with JAX ---
    let ref_io = Json::parse(
        &std::fs::read_to_string(dir.join("reference_io.json")).expect("reference_io.json"),
    )
    .unwrap();
    let input: Vec<f32> =
        ref_io.get("input").as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
    let expect: Vec<f32> = ref_io
        .get("output_sample")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    let expect_mean = ref_io.get("output_mean").as_f64().unwrap();
    {
        // sequential single-thread pass for the numerics check
        let mut x = input.clone();
        for spec in &specs {
            let stage = spec.compile().expect("stage compile");
            let shape = [batch, seq, hidden];
            let outs = stage.run_f32(&[(&x, &shape[..])]).expect("stage exec");
            x = outs.into_iter().next().unwrap();
        }
        let mean = x.iter().map(|&v| v as f64).sum::<f64>() / x.len() as f64;
        for (i, (&got, &want)) in x.iter().zip(&expect).enumerate() {
            assert!(
                (got - want).abs() < 1e-3 + 1e-3 * want.abs(),
                "logit {i} mismatch: rust {got} vs jax {want}"
            );
        }
        assert!((mean - expect_mean).abs() < 1e-4, "mean {mean} vs jax {expect_mean}");
        println!(
            "numerics: rust pipeline output matches JAX golden (mean {:.6} vs {:.6}) ✓",
            mean, expect_mean
        );
    }

    // --- serve a request stream through the threaded pipeline ---
    let num_requests = 64;
    let per_sample = seq * hidden;
    let requests: Vec<Request> = (0..num_requests)
        .map(|i| Request {
            id: i as u64,
            // batch-shaped requests: the batcher merges up to `batch`
            data: input[..per_sample].to_vec(),
            enqueued: Instant::now(),
        })
        .collect();
    // NOTE: the artifacts are compiled for a fixed batch, so the batcher
    // must emit full batches (num_requests is a multiple of `batch` and
    // the timeout is generous).
    let config = ServerConfig {
        max_batch: batch,
        batch_timeout: Duration::from_secs(5),
        input_elems: per_sample,
        queue_depth: 4,
    };
    let factories = server::stage_factories(specs.clone());
    let t0 = Instant::now();
    let metrics = server::serve(requests, factories, &config);
    let wall = t0.elapsed();
    println!(
        "served {} requests in {:?}: throughput {:.1} req/s, latency p50 {:.1} ms, p99 {:.1} ms",
        metrics.completed,
        wall,
        metrics.throughput_per_s(),
        metrics.percentile(0.5),
        metrics.percentile(0.99),
    );
    assert_eq!(metrics.completed, num_requests);
    println!("pipeline_serving OK");
}

//! END-TO-END driver: all three layers compose.
//!
//! 1. `make artifacts` has AOT-lowered the mini-BERT (L2 JAX model calling
//!    the L1 Pallas attention kernel) into per-stage HLO artifacts;
//! 2. this binary (L3) loads the real operator graph exported from the
//!    same model, *plans* a placement through the fingerprint-cached
//!    [`PlannerService`] (re-planning scenario changes at cache-hit cost),
//!    then
//! 3. serves a stream of batched requests through the staged PJRT
//!    pipeline (one worker thread per device), checks the numerics against
//!    the JAX golden output, and reports latency/throughput vs prediction.
//!
//! Without artifacts (e.g. on CI) it degrades gracefully: step 2 runs as a
//! standalone serving re-planning demo on the built-in BERT-24 layer
//! workload — cold plan, cache-hit re-plan, device loss, memory pressure —
//! and the binary exits 0.
//!
//! ```sh
//! make artifacts && cargo run --release --example pipeline_serving
//! ```

use dnn_partition::coordinator::context::SolveOpts;
use dnn_partition::coordinator::placement::{DeviceClass, Fleet, PlanRequest, Scenario};
use dnn_partition::coordinator::planner::Algorithm;
use dnn_partition::graph::OpGraph;
use dnn_partition::runtime::server::{self, Request, ServerConfig, ServingPlanner};
use dnn_partition::runtime::stage::{artifacts_dir, StageSpec};
use dnn_partition::util::json::Json;
use dnn_partition::workloads::{self, json as wjson};
use std::time::{Duration, Instant};

/// Plan through ONE serving planner, falling back to DPL when the exact
/// DP's lattice blows its cap (§5.1.2 — the paper's own recommendation).
/// The fallback runs against the same cached context, so the failed
/// enumeration is not repeated.
fn plan_or_dpl(
    planner: &mut ServingPlanner,
    g: &OpGraph,
    sc: &Scenario,
) -> Option<(String, f64, usize)> {
    let planned = planner
        .plan(g, sc)
        .or_else(|_| planner.plan_with(g, sc, Algorithm::Dpl))
        .ok()?;
    Some((
        planned.placement.algorithm.clone(),
        planned.placement.objective,
        planned.stages.len(),
    ))
}

/// The L3 serving re-planning loop on the built-in BERT-24 layer workload:
/// what a server does when deployment conditions change under it.
fn replanning_demo() {
    let w = workloads::table1_workloads()
        .into_iter()
        .find(|w| w.name == "BERT-24" && !w.training)
        .expect("BERT-24 workload");
    let mut planner = ServingPlanner::new(Algorithm::Dp, SolveOpts::default());

    let t = Instant::now();
    let cold = planner.plan(&w.graph, &w.scenario).expect("cold plan");
    let cold_t = t.elapsed();
    println!(
        "cold plan:        {} over {} devices, TPS {:.3}, {} stages in {:?}",
        cold.placement.algorithm,
        w.scenario.k,
        cold.placement.objective,
        cold.stages.len(),
        cold_t
    );

    let t = Instant::now();
    let hit = planner.plan(&w.graph, &w.scenario).expect("cache-hit plan");
    let hit_t = t.elapsed();
    assert_eq!(cold.placement.assignment, hit.placement.assignment);
    let speedup = cold_t.as_secs_f64() / hit_t.as_secs_f64().max(1e-9);
    println!("cache-hit replan: identical placement in {hit_t:?} ({speedup:.0}x faster)");

    // device loss: one accelerator drops out of the deployment
    let degraded = Scenario { k: w.scenario.k - 1, ..w.scenario.clone() };
    let t = Instant::now();
    let lost = planner.plan(&w.graph, &degraded).expect("device-loss replan");
    println!(
        "device loss:      re-planned for k={} (TPS {:.3} vs {:.3}) in {:?}",
        degraded.k,
        lost.placement.objective,
        cold.placement.objective,
        t.elapsed()
    );

    // memory pressure: caps halved (e.g. co-tenant takes half of HBM)
    let squeezed = Scenario { mem_cap: w.scenario.mem_cap / 2.0, ..w.scenario.clone() };
    let t = Instant::now();
    match planner.plan(&w.graph, &squeezed) {
        Ok(p) => println!(
            "memory pressure:  re-planned under M/2 (TPS {:.3}) in {:?}",
            p.placement.objective,
            t.elapsed()
        ),
        Err(e) => println!("memory pressure:  infeasible under M/2 ({e})"),
    }

    // heterogeneous fleet: 2 double-speed large-memory accelerators + 4
    // baseline ones + the CPU pool, then device loss as a class decrement
    let mut req = PlanRequest::new(Fleet::new(vec![
        DeviceClass::acc("fast", 2, w.scenario.mem_cap * 2.0).speed(2.0),
        DeviceClass::acc("slow", 4, w.scenario.mem_cap),
        DeviceClass::cpu("cpu", 1),
    ]));
    let t = Instant::now();
    let hetero = planner.plan_request(&w.graph, &req).expect("heterogeneous plan");
    hetero
        .placement
        .validate_req(&w.graph, &req)
        .expect("per-class memory must hold");
    println!(
        "hetero fleet:     {} over 2xfast@2 + 4xslow (TPS {:.3}, {} stages) in {:?}",
        hetero.placement.algorithm,
        hetero.placement.objective,
        hetero.stages.len(),
        t.elapsed()
    );
    assert!(req.fleet.decrement("fast"));
    let t = Instant::now();
    let lost_fast = planner.plan_request(&w.graph, &req).expect("fleet device-loss replan");
    println!(
        "fast-class loss:  re-planned for 1xfast + 4xslow (TPS {:.3}) in {:?}",
        lost_fast.placement.objective,
        t.elapsed()
    );

    let (hits, misses) = planner.cache_stats();
    println!("planner cache:    {hits} hits / {misses} misses");
    println!("pipeline_serving OK (planning-only mode)");
}

fn main() {
    let dir = artifacts_dir();
    let manifest_path = dir.join("manifest.json");
    let Ok(mtext) = std::fs::read_to_string(&manifest_path) else {
        eprintln!(
            "no artifacts found at {} — running the serving re-planning demo \
             (run `make artifacts` for the full PJRT pipeline)",
            dir.display()
        );
        replanning_demo();
        return;
    };
    let manifest = Json::parse(&mtext).expect("bad manifest");
    let num_stages = manifest.get("num_stages").as_usize().unwrap();
    let batch = manifest.get("batch").as_usize().unwrap();
    let seq = manifest.get("seq").as_usize().unwrap();
    let hidden = manifest.get("hidden").as_usize().unwrap();
    println!("mini-BERT artifacts: {num_stages} stages, batch {batch}, seq {seq}, hidden {hidden}");

    // --- L3 planning on the REAL operator graph exported from the model,
    //     through the fingerprint-cached planning service ---
    if let Ok(text) = std::fs::read_to_string(dir.join("mini_bert_opgraph.json")) {
        let json = Json::parse(&text).unwrap();
        let (graph, scenario, _name) = wjson::from_json(&json).unwrap();
        let mut planner = ServingPlanner::new(Algorithm::Dp, SolveOpts::default());
        match plan_or_dpl(&mut planner, &graph, &scenario) {
            Some((alg, tps, stages)) => {
                println!(
                    "planned placement ({alg}) of the {}-op HLO graph over {} accelerators: \
                     predicted TPS {tps:.3} ({stages} stages)",
                    graph.n(),
                    scenario.k
                );
                // re-plan for a degraded deployment (device loss) at
                // cache-hit analysis cost
                if scenario.k > 1 {
                    let degraded = Scenario { k: scenario.k - 1, ..scenario.clone() };
                    if let Some((_, tps2, _)) = plan_or_dpl(&mut planner, &graph, &degraded) {
                        println!(
                            "re-planned for device loss (k={}): predicted TPS {tps2:.3}",
                            degraded.k
                        );
                    }
                }
            }
            None => println!("planning note: no feasible plan for the exported graph"),
        }
    }

    // --- build stage specs from the manifest ---
    let stages_json = manifest.get("stages").as_arr().unwrap();
    let specs: Vec<StageSpec> = stages_json
        .iter()
        .enumerate()
        .map(|(i, s)| StageSpec {
            name: format!("stage_{i}"),
            path: dir.join(s.get("path").as_str().unwrap()),
            tuple_arity: 1,
            sample_shape: vec![seq, hidden],
        })
        .collect();

    // --- golden check: run one request through and compare with JAX ---
    let ref_io = Json::parse(
        &std::fs::read_to_string(dir.join("reference_io.json")).expect("reference_io.json"),
    )
    .unwrap();
    let input: Vec<f32> =
        ref_io.get("input").as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
    let expect: Vec<f32> = ref_io
        .get("output_sample")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    let expect_mean = ref_io.get("output_mean").as_f64().unwrap();
    {
        // sequential single-thread pass for the numerics check
        let mut x = input.clone();
        for spec in &specs {
            let stage = spec.compile().expect("stage compile");
            let shape = [batch, seq, hidden];
            let outs = stage.run_f32(&[(&x, &shape[..])]).expect("stage exec");
            x = outs.into_iter().next().unwrap();
        }
        let mean = x.iter().map(|&v| v as f64).sum::<f64>() / x.len() as f64;
        for (i, (&got, &want)) in x.iter().zip(&expect).enumerate() {
            assert!(
                (got - want).abs() < 1e-3 + 1e-3 * want.abs(),
                "logit {i} mismatch: rust {got} vs jax {want}"
            );
        }
        assert!((mean - expect_mean).abs() < 1e-4, "mean {mean} vs jax {expect_mean}");
        println!(
            "numerics: rust pipeline output matches JAX golden (mean {:.6} vs {:.6}) ✓",
            mean, expect_mean
        );
    }

    // --- serve a request stream through the threaded pipeline ---
    let num_requests = 64;
    let per_sample = seq * hidden;
    let requests: Vec<Request> = (0..num_requests)
        .map(|i| Request {
            id: i as u64,
            // batch-shaped requests: the batcher merges up to `batch`
            data: input[..per_sample].to_vec(),
            enqueued: Instant::now(),
        })
        .collect();
    // NOTE: the artifacts are compiled for a fixed batch, so the batcher
    // must emit full batches (num_requests is a multiple of `batch` and
    // the timeout is generous).
    let config = ServerConfig {
        max_batch: batch,
        batch_timeout: Duration::from_secs(5),
        input_elems: per_sample,
        queue_depth: 4,
    };
    let factories = server::stage_factories(specs.clone());
    let t0 = Instant::now();
    let metrics = server::serve(requests, factories, &config);
    let wall = t0.elapsed();
    println!(
        "served {} requests in {:?}: throughput {:.1} req/s, latency p50 {:.1} ms, p99 {:.1} ms",
        metrics.completed,
        wall,
        metrics.throughput_per_s(),
        metrics.percentile(0.5),
        metrics.percentile(0.99),
    );
    assert_eq!(metrics.completed, num_requests);
    println!("pipeline_serving OK");
}

//! Appendix C.2 — replication: hybrid model-/data-parallel splits.
//!
//! The DP transition gains a replica count `k''`: a contiguous subgraph
//! `S = I \ I'` may be replicated over `k''` accelerators, processing
//! minibatches round-robin. Its per-sample load becomes
//!
//! ```text
//! acc(S, k'') = acc(S)/k''  ⊕  sync(S, k'')
//! sync(S, k'') = (k'' − 1)·Σ_{v∈S} m_v / (k''·B)
//! ```
//!
//! (`⊕` = `+` or `max` per the App.-C.1 interleaving assumption; `B` the
//! scenario bandwidth). This costs an extra `O(k)` factor over the plain
//! DP, exactly as the paper states.
//!
//! The implementation runs on the ideal lattice like [`super::dp`] and —
//! since PR 2 — reuses the DP's incremental DFS walk
//! ([`super::dp::CarveWalker`]): subgraph costs are maintained in `O(deg
//! v)` per lattice step with undo on backtrack instead of being recomputed
//! from scratch per `(I, I')` pair, with a monotone
//! `min(cpu(S), compute(S)/k)` bound pruning useless lattice subtrees.

use super::dp::{CarveWalker, DpError, Prepared};
use crate::coordinator::context::ProblemCtx;
use crate::coordinator::placement::{CommModel, Device, Placement, PlanRequest, Scenario};
use crate::graph::ideals::{IdealId, IdealLattice};
use crate::graph::OpGraph;
use crate::util::bitset::BitSet;

/// A replicated placement: device assignment plus per-stage replica groups.
#[derive(Clone, Debug)]
pub struct ReplicatedPlacement {
    /// Stage index of every node.
    pub stage_of: Vec<usize>,
    /// For each stage: the accelerators replicating it (empty = CPU stage).
    pub stage_devices: Vec<Vec<Device>>,
    /// Per-sample time (max effective stage load).
    pub objective: f64,
}

impl ReplicatedPlacement {
    /// Flatten to a plain placement (first replica of each stage) for
    /// interoperability with validators/renderers.
    pub fn primary_placement(&self) -> Placement {
        let assignment = self
            .stage_of
            .iter()
            .map(|&s| self.stage_devices[s].first().copied().unwrap_or(Device::Cpu(0)))
            .collect();
        Placement::new(assignment, self.objective, "DP (replication)")
    }
}

/// Effective per-sample load of a subgraph replicated over `r` accelerators.
pub fn replicated_load(g: &OpGraph, sc: &Scenario, set: &BitSet, r: usize) -> f64 {
    replicated_load_parts(
        g.acc_load(set, sc.mem_cap),
        g.mem_of(set),
        sc.bandwidth,
        sc.comm_model,
        r,
    )
}

/// Effective per-sample load from precomputed set sums (the incremental
/// form of [`replicated_load`]): `base` = sequential `acc(S)`, `weights` =
/// `Σ m_v` over `S`.
fn replicated_load_parts(
    base: f64,
    weights: f64,
    bandwidth: f64,
    comm_model: CommModel,
    r: usize,
) -> f64 {
    if !base.is_finite() || r == 0 {
        return f64::INFINITY;
    }
    let sync = (r as f64 - 1.0) * weights / (r as f64 * bandwidth);
    let work = base / r as f64;
    match comm_model {
        CommModel::Sequential => work + sync,
        _ => work.max(sync),
    }
}

/// Run the replication DP (contiguous stages, each on 1..k replicas).
///
/// Deprecated thin wrapper: recomputes the preprocessing and lattice per
/// call. Prefer [`solve_ctx`] over a shared
/// [`crate::coordinator::context::ProblemCtx`].
pub fn solve(g: &OpGraph, sc: &Scenario, cap: usize) -> Result<ReplicatedPlacement, DpError> {
    let prepared = Prepared::build(g)?;
    // fold the gradient comm into node comm (PipeDream-style proxy; the
    // exact split-direction accounting lives in the plain DP)
    let mut proxy = prepared.dp_graph.clone();
    for (v, node) in proxy.nodes.iter_mut().enumerate() {
        node.comm += prepared.bw_comm[v];
    }
    let lattice = IdealLattice::enumerate(&proxy, cap).map_err(DpError::TooManyIdeals)?;
    solve_on_lattice(&proxy, &sc.to_request(), &lattice, &prepared)
}

/// [`solve`] against a shared analysis context (proxy graph, lattice and
/// preprocessing all come from the cache).
pub fn solve_ctx(ctx: &ProblemCtx) -> Result<ReplicatedPlacement, DpError> {
    solve_on_lattice(ctx.proxy()?, ctx.request(), ctx.lattice()?, ctx.prepared()?)
}

/// The replication DP over a request. Replicas of a stage are drawn from
/// the fleet interchangeably, so the fleet is viewed conservatively: the
/// *smallest* accelerator cap bounds every stage (each replica holds the
/// full stage) and the *slowest* accelerator speed scales compute — a
/// valid (never optimistic) placement for any replica→device mapping.
/// Uniform fleets reduce to the exact historical behavior.
fn solve_on_lattice(
    gg: &OpGraph,
    req: &PlanRequest,
    lattice: &IdealLattice,
    prepared: &Prepared,
) -> Result<ReplicatedPlacement, DpError> {
    let (k, l) = (req.fleet.k(), req.fleet.l());
    let mem_cap = req.fleet.min_acc_mem_cap();
    let acc_speed = req.fleet.min_acc_speed();
    // conservative: the slowest populated CPU class a stage could land on
    let min_cpu = req
        .fleet
        .classes
        .iter()
        .filter(|c| c.kind == crate::coordinator::placement::DeviceKind::Cpu && c.count > 0)
        .map(|c| c.speed)
        .fold(f64::INFINITY, f64::min);
    let cpu_speed = if min_cpu.is_finite() { min_cpu } else { 1.0 };
    let bandwidth = req.fleet.bandwidth;
    let slots = (k + 1) * (l + 1);
    let ni = lattice.len();
    let idx = |i: IdealId, k_: usize, l_: usize| i * slots + k_ * (l + 1) + l_;

    let mut dp = vec![f64::INFINITY; ni * slots];
    // choice: (sub ideal, replicas; replicas = 0 means CPU)
    let mut parent: Vec<(u32, u8)> = vec![(u32::MAX, 0); ni * slots];
    for k_ in 0..=k {
        for l_ in 0..=l {
            dp[idx(0, k_, l_)] = 0.0;
        }
    }

    // Incremental DFS over nested sub-ideals (the dp.rs walk): subgraph
    // sums are maintained in O(deg v) per lattice step; `min(cpu(S),
    // compute(S)/k)` lower-bounds every candidate from any superset of S,
    // both terms grow monotonically, so a subtree whose bound can no
    // longer improve any still-improvable cell of ideal `i` is pruned.
    // Boundary comm priced at the worst device pair (conservative, like the
    // flat DP — DESIGN.md §9); replicas are placed interchangeably, so no
    // tighter per-pair price exists here. Identity without a topology.
    let wcomm: Vec<f64> =
        gg.nodes.iter().map(|n| req.fleet.worst_pair_cost(n.comm)).collect();
    let mut walker = CarveWalker::new(ni, gg.n());
    for i in 1..ni {
        let (head, tail) = dp.split_at_mut(i * slots);
        let cells = &mut tail[..slots];
        let parents = &mut parent[i * slots..(i + 1) * slots];
        walker.walk(gg, lattice, &wcomm, i, |cur, carve| {
            if cur == i {
                // S = ∅: the dp[∅][k'][l'] = 0 seeds already cover unused
                // devices, so the empty carve relaxes nothing
                return true;
            }
            let cpu_load = carve.cpu_load() / cpu_speed;
            let acc_base = if carve.inf_acc != 0 || carve.mem > mem_cap {
                f64::INFINITY
            } else {
                carve.compute / acc_speed + carve.comm_in + carve.comm_out
            };
            {
                let eff_compute =
                    if carve.inf_acc == 0 { carve.compute } else { f64::INFINITY };
                let lb = cpu_load.min(eff_compute / acc_speed / k.max(1) as f64);
                let worst = cells[1..].iter().copied().fold(0.0, f64::max);
                if lb >= worst && worst.is_finite() {
                    return false; // prune the subtree below this sub-ideal
                }
            }
            for k_ in 0..=k {
                for l_ in 0..=l {
                    let cell = k_ * (l + 1) + l_;
                    // CPU branch
                    if l_ > 0 {
                        let cand = head[idx(cur, k_, l_ - 1)].max(cpu_load);
                        if cand < cells[cell] {
                            cells[cell] = cand;
                            parents[cell] = (cur as u32, 0);
                        }
                    }
                    // accelerator branch with r replicas
                    for r in 1..=k_ {
                        let load = replicated_load_parts(
                            acc_base,
                            carve.mem,
                            bandwidth,
                            req.comm_model,
                            r,
                        );
                        let cand = head[idx(cur, k_ - r, l_)].max(load);
                        if cand < cells[cell] {
                            cells[cell] = cand;
                            parents[cell] = (cur as u32, r as u8);
                        }
                    }
                }
            }
            true
        });
    }

    let final_cell = idx(lattice.full_id(), k, l);
    if !dp[final_cell].is_finite() {
        return Err(DpError::Infeasible);
    }

    // Reconstruct stages on the prepared graph, then expand to original.
    let mut stage_of_prepared = vec![usize::MAX; gg.n()];
    let mut stage_devices: Vec<Vec<Device>> = Vec::new();
    let (mut i, mut k_, mut l_) = (lattice.full_id(), k, l);
    let mut next_acc = 0usize;
    let mut next_cpu = 0usize;
    while i != 0 {
        let (sub, r) = parent[idx(i, k_, l_)];
        if sub == u32::MAX {
            break;
        }
        let sub = sub as usize;
        let s = lattice.difference_bitset(i, sub);
        if !s.is_empty() {
            let stage = stage_devices.len();
            let devices = if r == 0 {
                l_ -= 1;
                let d = vec![Device::Cpu(next_cpu)];
                next_cpu += 1;
                d
            } else {
                let r = r as usize;
                k_ -= r;
                let d: Vec<Device> = (0..r).map(|j| Device::Acc(next_acc + j)).collect();
                next_acc += r;
                d
            };
            stage_devices.push(devices);
            for v in s.iter() {
                stage_of_prepared[v] = stage;
            }
        } else if r == 0 {
            l_ -= 1;
        } else {
            k_ -= r as usize;
        }
        i = sub;
    }
    for s in stage_of_prepared.iter_mut() {
        if *s == usize::MAX {
            // zero-size ideal steps shouldn't leave gaps, but guard anyway
            *s = 0;
            if stage_devices.is_empty() {
                stage_devices.push(vec![Device::Cpu(0)]);
            }
        }
    }
    let stage_of: Vec<usize> = prepared.map.iter().map(|&c| stage_of_prepared[c]).collect();
    Ok(ReplicatedPlacement { stage_of, stage_devices, objective: dp[final_cell] })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn heavy_chain(n: usize, mem: f64) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(100.0).acc(10.0).mem(mem).comm(0.1));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn replication_beats_plain_dp_on_sparse_models() {
        // light weights (cheap sync) → replication halves the bottleneck
        let g = heavy_chain(2, 0.01);
        let sc = Scenario { k: 4, l: 0, bandwidth: 1.0, ..Default::default() };
        let plain = super::super::dp::solve(&g, &sc).unwrap();
        let rep = solve(&g, &sc, usize::MAX).unwrap();
        assert!(
            rep.objective < plain.objective - 1.0,
            "replicated {} vs plain {}",
            rep.objective,
            plain.objective
        );
    }

    #[test]
    fn replication_useless_when_sync_dominates() {
        // enormous weights → sync term kills replication; same as plain DP
        let g = heavy_chain(2, 1e4);
        let sc = Scenario { k: 4, l: 0, bandwidth: 1.0, ..Default::default() };
        let plain = super::super::dp::solve(&g, &sc).unwrap();
        let rep = solve(&g, &sc, usize::MAX).unwrap();
        assert!((rep.objective - plain.objective).abs() < 1e-9);
    }

    #[test]
    fn replicated_load_formula() {
        let g = heavy_chain(1, 6.0);
        let sc = Scenario { k: 2, l: 0, bandwidth: 2.0, ..Default::default() };
        let s = BitSet::from_iter(1, [0]);
        // r=1: no sync, load = acc load = 10 (no boundary edges)
        assert!((replicated_load(&g, &sc, &s, 1) - 10.0).abs() < 1e-9);
        // r=2: 10/2 + (1·6)/(2·2) = 5 + 1.5
        assert!((replicated_load(&g, &sc, &s, 2) - 6.5).abs() < 1e-9);
    }

    #[test]
    fn stage_structure_is_consistent() {
        let g = heavy_chain(4, 0.01);
        let sc = Scenario { k: 4, l: 1, bandwidth: 10.0, ..Default::default() };
        let rep = solve(&g, &sc, usize::MAX).unwrap();
        assert_eq!(rep.stage_of.len(), g.n());
        // every stage's devices are distinct and within range
        let mut used = std::collections::BTreeSet::new();
        for devices in &rep.stage_devices {
            for d in devices {
                assert!(used.insert(*d), "device {d} reused across stages");
            }
        }
        assert!(rep.objective.is_finite());
    }
}

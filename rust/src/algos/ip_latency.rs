//! Integer Programming for latency minimization (§4, Figs. 3–4).
//!
//! The latency problem couples placement with *scheduling*: an accelerator
//! holding subgraph `S` is invoked once all external inputs of `S` are in
//! RAM, transfers them in, computes, transfers results out (§3's
//! uninterrupted mode); the CPU pool runs ready nodes immediately (ℓ ≥
//! width assumption). The exact schedule semantics live in
//! [`objective::latency`], which also covers the Fig.-4 generalization
//! (multiple contiguous subgraphs per accelerator, serialized by
//! constraint (14)) by decomposing arbitrary sets into virtual pieces.
//!
//! As in §7, certifying optimality is much harder than for max-load — the
//! paper reports MIP gaps up to 93% after an hour of Gurobi. The engines:
//!
//! * [`build_model`] — the literal Fig.-3 MILP with the Lemma-4.1 big-M
//!   linearizations, solvable by the LP branch-and-bound on tiny graphs
//!   (executable specification / cross-check).
//! * [`solve`] — specialized DFS branch-and-bound: topological assignment
//!   order, per-accelerator contiguity propagation, critical-path lower
//!   bound, warm starts from caller-supplied baselines, and a single-node-
//!   move polish on the exact latency objective.

use super::{objective, PlaceError};
use crate::coordinator::context::{ProblemCtx, SolveBudget};
use crate::coordinator::placement::{Device, Placement, PlanRequest, Scenario};
use crate::graph::OpGraph;
use crate::solver::lp::{Lp, Sense};
use crate::solver::milp::{Milp, SolveStatus};
use crate::util::arena::BitMatrix;
use crate::util::bitset::BitSet;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct LatencyIpOptions {
    pub time_limit: Duration,
    pub gap_target: f64,
    /// One contiguous subgraph per accelerator (Fig. 3). With `false`,
    /// accelerators may hold arbitrary sets, executed as serialized
    /// contiguous pieces (Fig. 4 with unbounded q).
    pub contiguous: bool,
    pub polish: bool,
    /// Extra warm-start placements (e.g. from baselines).
    pub warm_starts: Vec<Placement>,
    /// Cooperative cancellation: deadline clamp on `time_limit` and/or a
    /// deterministic node cap. [`SolveBudget::UNLIMITED`] (the default) is
    /// bitwise-invisible.
    pub budget: SolveBudget,
}

impl Default for LatencyIpOptions {
    fn default() -> Self {
        LatencyIpOptions {
            time_limit: Duration::from_secs(20),
            gap_target: 0.01,
            contiguous: true,
            polish: true,
            warm_starts: Vec::new(),
            budget: SolveBudget::UNLIMITED,
        }
    }
}

#[derive(Clone, Debug)]
pub struct LatencyIpResult {
    pub placement: Placement,
    pub status: SolveStatus,
    pub bound: f64,
    pub gap: f64,
    pub nodes_explored: usize,
    pub elapsed: Duration,
    pub incumbent_at: Duration,
    /// True when the caller's [`LatencyIpOptions::budget`] cut the search
    /// short (the anytime signal).
    pub truncated: bool,
}

/// Solve latency minimization. Device model: `Cpu(0)` is the pooled CPU
/// (index 0 of Fig. 3), `Acc(0..k)` the accelerators.
///
/// Deprecated thin wrapper: builds a one-shot [`ProblemCtx`] and forwards
/// to [`solve_ctx`]. (This engine historically returned `Result<_,
/// String>`; it now speaks the crate-wide [`PlaceError`] like every other
/// solver.)
pub fn solve(
    g: &OpGraph,
    sc: &Scenario,
    opts: &LatencyIpOptions,
) -> Result<LatencyIpResult, PlaceError> {
    let ctx = ProblemCtx::new(g.clone(), sc.clone());
    solve_ctx(&ctx, opts)
}

/// [`solve`] over a heterogeneous [`PlanRequest`] fleet (one-shot context).
pub fn solve_req(
    g: &OpGraph,
    req: &PlanRequest,
    opts: &LatencyIpOptions,
) -> Result<LatencyIpResult, PlaceError> {
    let ctx = ProblemCtx::from_request(g.clone(), req.clone());
    solve_ctx(&ctx, opts)
}

/// [`solve`] against a shared analysis context: the search borrows the
/// original graph's topological order and reachability rows from `ctx`.
pub fn solve_ctx(
    ctx: &ProblemCtx,
    opts: &LatencyIpOptions,
) -> Result<LatencyIpResult, PlaceError> {
    let g = ctx.graph();
    let req = ctx.request();
    let order = ctx.orig_order()?; // also the DAG guard
    let reach = ctx.orig_reach()?;
    let co_reach = ctx.orig_co_reach()?;
    let start = Instant::now();
    let mut search = LatSearch::new(g, req, opts.clone(), start, order, reach, co_reach);

    // Warm starts: caller-provided placements (greedy, max-load DP, …).
    // Evaluated against the context's cached order/reachability — no
    // per-placement matrix rebuild (ROADMAP item (d) analogue).
    for p in &opts.warm_starts {
        if p.check_memory_req(g, req).is_ok() {
            let lat = objective::latency_in(g, req, p, order, reach);
            let dense: Vec<usize> = p.assignment.iter().map(|&d| lat_index(d)).collect();
            if lat.is_finite()
                && search.incumbent.as_ref().is_none_or(|(best, _)| lat < *best)
                && (!opts.contiguous || search.contiguous_ok_full(&dense))
            {
                search.incumbent = Some((lat, dense));
                search.incumbent_at = Duration::ZERO;
            }
        }
    }
    search.run();
    search.flush_obs();

    let truncated = search.budget_hit;
    let (obj, dense) = search.incumbent.clone().ok_or(PlaceError::NoIncumbent)?;
    let assignment: Vec<Device> = dense
        .iter()
        .map(|&d| if d == 0 { Device::Cpu(0) } else { Device::Acc(d - 1) })
        .collect();
    let mut placement = Placement::new(assignment, obj, "IP (latency)");
    placement.objective = objective::latency_in(g, req, &placement, order, reach);
    let gap = ((placement.objective - search.best_bound) / placement.objective.max(1e-12)).max(0.0);
    Ok(LatencyIpResult {
        status: search.status,
        bound: search.best_bound,
        gap,
        nodes_explored: search.nodes,
        elapsed: start.elapsed(),
        incumbent_at: search.incumbent_at,
        truncated,
        placement,
    })
}

/// Dense device index for the latency setting: 0 = CPU pool, 1..=k accs.
fn lat_index(d: Device) -> usize {
    match d {
        Device::Cpu(_) => 0,
        Device::Acc(i) => i + 1,
    }
}

struct LatSearch<'a> {
    g: &'a OpGraph,
    req: &'a PlanRequest,
    /// Total accelerator count.
    k: usize,
    /// Per accelerator: its class's memory cap.
    cap: Vec<f64>,
    /// Per accelerator: its class's relative speed.
    acc_speed: Vec<f64>,
    /// Per accelerator: class index (empty-device symmetry breaking).
    acc_class: Vec<usize>,
    /// Speed of the pooled CPU device.
    cpu_speed: f64,
    opts: LatencyIpOptions,
    order: &'a [usize],
    /// Reachability rows in one flat allocation — borrowed from the
    /// shared context.
    reach: &'a BitMatrix,
    co_reach: &'a BitMatrix,
    /// longest min-cost path from v to a sink (suffix critical path)
    tail: Vec<f64>,
    acc_mem: Vec<f64>,
    acc_set: Vec<BitSet>,
    acc_reach: Vec<BitSet>,
    /// Reused word scratch for the contiguity check / reach rebuild.
    mid_scratch: Vec<u64>,
    reach_scratch: Vec<u64>,
    assignment: Vec<usize>,
    assigned: BitSet,
    /// optimistic completion time of each assigned node (comm-free, no
    /// subgraph batching — a valid lower bound on its true completion)
    opt_done: Vec<f64>,
    incumbent: Option<(f64, Vec<usize>)>,
    incumbent_at: Duration,
    best_bound: f64,
    nodes: usize,
    status: SolveStatus,
    start: Instant,
    /// `start + time_limit` clamped by the budget's deadline.
    deadline: Instant,
    /// `start + time_limit` alone (see `ip_throughput::Search`).
    own_deadline: Instant,
    /// Deterministic node cap from the budget (`u64::MAX` = none).
    node_cap: u64,
    /// Set when the budget (deadline or node cap) stopped the search.
    budget_hit: bool,
    complete: bool,
    /// Search telemetry (see `ip_throughput::Search` — same scheme):
    /// plain hot-loop bumps, flushed to obs once per solve, never read by
    /// the search itself.
    prune_bound: usize,
    prune_memory: usize,
    prune_contiguity: usize,
    incumbent_log: Vec<(Duration, f64)>,
}

impl<'a> LatSearch<'a> {
    fn new(
        g: &'a OpGraph,
        req: &'a PlanRequest,
        opts: LatencyIpOptions,
        start: Instant,
        order: &'a [usize],
        reach: &'a BitMatrix,
        co_reach: &'a BitMatrix,
    ) -> Self {
        let stride = reach.stride();
        let fleet = &req.fleet;
        let k = fleet.k();
        // accelerator slice of the one fleet→dense-device mapping
        let dense = fleet.dense_view();
        let cap: Vec<f64> = dense[..k].iter().map(|d| d.mem_cap).collect();
        let acc_speed: Vec<f64> = dense[..k].iter().map(|d| d.speed).collect();
        let acc_class: Vec<usize> = dense[..k].iter().map(|d| d.class).collect();
        let cpu_speed = fleet.cpu_speed(0);
        // critical-path bound on the cheapest device of each kind (sound
        // for heterogeneous speeds; uniform fleets: /1.0, the old bound)
        let best_acc = fleet.best_acc_speed();
        let best_cpu = fleet.best_cpu_speed();
        let min_cost: Vec<f64> = g
            .nodes
            .iter()
            .map(|n| {
                let c = match best_cpu {
                    Some(s) => n.p_cpu / s,
                    None => n.p_cpu,
                };
                let a = match best_acc {
                    Some(s) => n.p_acc / s,
                    None => f64::INFINITY,
                };
                c.min(a)
            })
            .collect();
        let mut tail = vec![0.0; g.n()];
        for &v in order.iter().rev() {
            let best_succ = g.succs[v].iter().map(|&w| tail[w]).fold(0.0, f64::max);
            tail[v] = min_cost[v] + best_succ;
        }
        let root_bound = (0..g.n()).map(|v| tail[v]).fold(0.0, f64::max);
        LatSearch {
            g,
            req,
            k,
            cap,
            acc_speed,
            acc_class,
            cpu_speed,
            deadline: opts.budget.clamp_deadline(start, opts.time_limit),
            own_deadline: start + opts.time_limit,
            node_cap: opts.budget.node_limit.unwrap_or(u64::MAX),
            budget_hit: false,
            opts,
            reach,
            co_reach,
            tail,
            acc_mem: vec![0.0; k],
            acc_set: (0..k).map(|_| BitSet::new(g.n())).collect(),
            acc_reach: (0..k).map(|_| BitSet::new(g.n())).collect(),
            mid_scratch: vec![0; stride],
            reach_scratch: vec![0; stride],
            assignment: vec![usize::MAX; g.n()],
            assigned: BitSet::new(g.n()),
            opt_done: vec![0.0; g.n()],
            incumbent: None,
            incumbent_at: Duration::ZERO,
            best_bound: root_bound,
            nodes: 0,
            status: SolveStatus::Unknown,
            start,
            order,
            complete: true,
            prune_bound: 0,
            prune_memory: 0,
            prune_contiguity: 0,
            incumbent_log: Vec::new(),
        }
    }

    /// Flush the per-solve telemetry into the obs registry (counters
    /// always, `ip.incumbent` instants only while recording is enabled).
    fn flush_obs(&self) {
        crate::obs::counter("ip_nodes_explored_total").add(self.nodes as u64);
        crate::obs::counter("ip_prunes_total{reason=\"bound\"}").add(self.prune_bound as u64);
        crate::obs::counter("ip_prunes_total{reason=\"memory\"}").add(self.prune_memory as u64);
        crate::obs::counter("ip_prunes_total{reason=\"contiguity\"}")
            .add(self.prune_contiguity as u64);
        crate::obs::counter("ip_incumbent_updates_total").add(self.incumbent_log.len() as u64);
        if crate::obs::is_enabled() {
            let start_us = crate::obs::now_us() - self.start.elapsed().as_secs_f64() * 1e6;
            for (at, obj) in &self.incumbent_log {
                crate::obs::instant_at(
                    "ip.incumbent",
                    "ip",
                    start_us + at.as_secs_f64() * 1e6,
                    vec![
                        ("objective".to_string(), crate::util::json::Json::num(*obj)),
                        (
                            "at_ms".to_string(),
                            crate::util::json::Json::num(at.as_secs_f64() * 1e3),
                        ),
                    ],
                );
            }
        }
    }

    fn run(&mut self) {
        self.dfs(0);
        let inc = self.incumbent.as_ref().map(|(o, _)| *o);
        if self.complete {
            if let Some(obj) = inc {
                self.best_bound = obj;
                self.status = SolveStatus::Optimal;
            } else {
                self.status = SolveStatus::Infeasible;
            }
        } else {
            self.status = match inc {
                Some(obj) if (obj - self.best_bound) / obj.max(1e-12) <= self.opts.gap_target => {
                    SolveStatus::GapReached
                }
                Some(_) => SolveStatus::TimeLimit,
                None => SolveStatus::Unknown,
            };
        }
        if self.opts.polish {
            if let Some((obj, dense)) = self.incumbent.clone() {
                if let Some(better) = self.polish(obj, dense) {
                    let better_obj = better.0;
                    self.incumbent = Some(better);
                    self.incumbent_at = self.start.elapsed();
                    self.incumbent_log.push((self.incumbent_at, better_obj));
                }
            }
        }
    }

    fn dfs(&mut self, pos: usize) {
        self.nodes += 1;
        // node cap first (deterministic, one compare; never trips at the
        // u64::MAX default), then the amortized wall-clock check
        if self.nodes as u64 >= self.node_cap {
            self.complete = false;
            self.budget_hit = true;
            return;
        }
        if self.nodes % 2048 == 0 && Instant::now() > self.deadline {
            self.complete = false;
            if self.deadline < self.own_deadline {
                self.budget_hit = true;
            }
            return;
        }
        if pos == self.order.len() {
            let obj = self.eval_dense(&self.assignment.clone());
            if obj.is_finite()
                && self.incumbent.as_ref().is_none_or(|(best, _)| obj < best - 1e-12)
            {
                self.incumbent = Some((obj, self.assignment.clone()));
                self.incumbent_at = self.start.elapsed();
                self.incumbent_log.push((self.incumbent_at, obj));
            }
            return;
        }
        let v = self.order[pos];

        // candidates: CPU pool (0) + accelerators; symmetry break on empty
        // accelerators per class; cheapest optimistic completion first.
        let mut cands: Vec<(f64, usize)> = Vec::new();
        let ready = self.g.preds[v].iter().map(|&u| self.opt_done[u]).fold(0.0, f64::max);
        if self.g.nodes[v].p_cpu.is_finite() {
            cands.push((ready + self.g.nodes[v].p_cpu / self.cpu_speed, 0));
        }
        let num_classes = self.acc_class.last().map_or(0, |&c| c + 1);
        let mut seen_empty = vec![false; num_classes];
        for i in 0..self.k {
            if self.g.nodes[v].p_acc.is_infinite()
                || self.acc_mem[i] + self.g.nodes[v].mem > self.cap[i]
            {
                self.prune_memory += 1;
                continue;
            }
            if self.acc_set[i].is_empty() {
                let class = self.acc_class[i];
                if seen_empty[class] {
                    continue;
                }
                seen_empty[class] = true;
            }
            if self.opts.contiguous && !self.contiguity_ok(v, i) {
                self.prune_contiguity += 1;
                continue;
            }
            cands.push((ready + self.g.nodes[v].p_acc / self.acc_speed[i], i + 1));
        }
        cands.sort_by(|a, b| a.0.total_cmp(&b.0));

        for (done, d) in cands {
            // assign
            self.assignment[v] = d;
            self.assigned.insert(v);
            self.opt_done[v] = done;
            if d > 0 {
                let i = d - 1;
                self.acc_mem[i] += self.g.nodes[v].mem;
                self.acc_set[i].insert(v);
                self.acc_reach[i].union_with_words(self.reach.row(v));
            }
            // bound: optimistic completion + suffix critical path
            let lb = self.partial_bound(pos);
            let prune = self
                .incumbent
                .as_ref()
                .is_some_and(|(best, _)| lb >= best - 1e-12);
            if !prune {
                self.dfs(pos + 1);
            } else {
                self.prune_bound += 1;
            }
            // undo
            if d > 0 {
                let i = d - 1;
                self.acc_mem[i] -= self.g.nodes[v].mem;
                self.acc_set[i].remove(v);
                // rebuild the accelerator's reach union into the reused
                // scratch row — no allocation per node expansion
                let mut scratch = std::mem::take(&mut self.reach_scratch);
                self.reach.union_rows_of(self.acc_set[i].iter(), &mut scratch);
                self.acc_reach[i].copy_from_words(&scratch);
                self.reach_scratch = scratch;
            }
            self.assignment[v] = usize::MAX;
            self.assigned.remove(v);
            if !self.complete {
                return;
            }
        }
    }

    /// Lower bound given assignments of `order[0..=pos]`: every assigned
    /// node finishes no earlier than `opt_done` (comm-free schedule
    /// relaxation); hanging off it is at least the min-cost critical path
    /// of its unassigned descendants.
    fn partial_bound(&self, pos: usize) -> f64 {
        let mut lb: f64 = 0.0;
        for p in 0..=pos {
            let v = self.order[p];
            let hang = self.g.succs[v].iter().map(|&w| self.tail[w]).fold(0.0, f64::max);
            lb = lb.max(self.opt_done[v] + hang);
        }
        lb
    }

    /// Alloc-free assigned-prefix contiguity check (shared logic in
    /// `graph::contiguity::prefix_contiguity_ok`).
    fn contiguity_ok(&mut self, v: usize, i: usize) -> bool {
        let mut mid = std::mem::take(&mut self.mid_scratch);
        let ok = self.acc_set[i].is_empty()
            || crate::graph::contiguity::prefix_contiguity_ok(
                self.acc_reach[i].words(),
                self.co_reach.row(v),
                self.assigned.words(),
                self.acc_set[i].words(),
                v,
                &mut mid,
            );
        self.mid_scratch = mid;
        ok
    }

    fn contiguous_ok_full(&self, dense: &[usize]) -> bool {
        for i in 0..self.k {
            let set = BitSet::from_iter(
                self.g.n(),
                dense.iter().enumerate().filter(|&(_, &d)| d == i + 1).map(|(v, _)| v),
            );
            if !crate::graph::contiguity::is_contiguous_in(self.reach, &set) {
                return false;
            }
        }
        true
    }

    /// Exact-latency leaf evaluation against the context's cached order
    /// and reachability rows — the `O(V·E/64)` matrix is never rebuilt
    /// per evaluation (the former ROADMAP (a)/(d) ctx-matrix gap).
    fn eval_dense(&self, dense: &[usize]) -> f64 {
        let p = Placement::new(
            dense
                .iter()
                .map(|&d| if d == 0 { Device::Cpu(0) } else { Device::Acc(d - 1) })
                .collect(),
            0.0,
            "tmp",
        );
        if p.check_memory_req(self.g, self.req).is_err() {
            return f64::INFINITY;
        }
        objective::latency_in(self.g, self.req, &p, self.order, self.reach)
    }

    fn polish(&self, obj: f64, dense: Vec<usize>) -> Option<(f64, Vec<usize>)> {
        let mut cur = dense;
        let mut cur_obj = obj;
        let mut improved = false;
        // own 5s cap, clamped by the caller's budget deadline
        let mut polish_deadline = Instant::now() + Duration::from_secs(5);
        if let Some(d) = self.opts.budget.deadline {
            polish_deadline = polish_deadline.min(d);
        }
        'outer: loop {
            let mut best: Option<(f64, usize, usize)> = None;
            for v in 0..self.g.n() {
                if Instant::now() > polish_deadline {
                    break 'outer;
                }
                let orig = cur[v];
                for d in 0..=self.k {
                    if d == orig {
                        continue;
                    }
                    cur[v] = d;
                    if self.opts.contiguous && !self.contiguous_ok_full(&cur) {
                        cur[v] = orig;
                        continue;
                    }
                    let cand = self.eval_dense(&cur);
                    if cand < cur_obj - 1e-12 && best.as_ref().is_none_or(|&(b, _, _)| cand < b) {
                        best = Some((cand, v, d));
                    }
                    cur[v] = orig;
                }
            }
            match best {
                Some((val, v, d)) if Instant::now() < polish_deadline => {
                    cur[v] = d;
                    cur_obj = val;
                    improved = true;
                }
                _ => break,
            }
        }
        improved.then_some((cur_obj, cur))
    }
}

// ---------------------------------------------------------------------------
// Literal Fig.-3 MILP (executable specification, tiny instances)
// ---------------------------------------------------------------------------

/// Legacy scalar form of [`build_model_req`].
pub fn build_model(g: &OpGraph, sc: &Scenario, big_m: f64) -> LatencyModel {
    build_model_req(g, &sc.to_request(), big_m)
}

/// Build the Fig.-3 latency MILP (contiguous, one subgraph per
/// accelerator), with Lemma-4.1 big-M reformulations of (6) and (10) and
/// the z-variable contiguity linearization. Devices: 0 = CPU pool,
/// 1..=k accelerators. `big_m` must exceed any achievable latency.
/// Memory rows use each accelerator's class cap; processing coefficients
/// scale by the device's class speed.
pub fn build_model_req(g: &OpGraph, req: &PlanRequest, big_m: f64) -> LatencyModel {
    let n = g.n();
    let k = req.fleet.k();
    let nd = k + 1; // index 0 = CPU pool
    // layout: x[v][0..nd] | cin[v][1..=k] | cout[v][1..=k] | z[v][1..=k]
    //   | Latency[v] | Start[i] | Finish[i] | TotalLatency
    let x0 = 0;
    let cin0 = x0 + n * nd;
    let cout0 = cin0 + n * k;
    let z0 = cout0 + n * k;
    let lat0 = z0 + n * k;
    let start0 = lat0 + n;
    let fin0 = start0 + k;
    let total = fin0 + k;
    let num_vars = total + 1;

    let mut lp = Lp::new(num_vars);
    let x = |v: usize, d: usize| x0 + v * nd + d;
    let cin = |v: usize, i: usize| cin0 + v * k + i; // i in 0..k = acc i
    let cout = |v: usize, i: usize| cout0 + v * k + i;
    let z = |v: usize, i: usize| z0 + v * k + i;

    for v in 0..n {
        for d in 0..nd {
            lp.upper[x(v, d)] = 1.0;
        }
        for i in 0..k {
            lp.upper[cin(v, i)] = 1.0;
            lp.upper[cout(v, i)] = 1.0;
            lp.upper[z(v, i)] = 1.0;
        }
    }
    lp.objective[total] = 1.0;

    // (1) assignment
    for v in 0..n {
        lp.add((0..nd).map(|d| (x(v, d), 1.0)).collect(), Sense::Eq, 1.0);
    }
    // (3) memory (per accelerator class cap)
    for i in 0..k {
        lp.add(
            (0..n).map(|v| (x(v, i + 1), g.nodes[v].mem)).collect(),
            Sense::Le,
            req.fleet.acc_mem_cap(i).min(1e15),
        );
    }
    // (4)/(5) comm indicators
    for (u, v) in g.edges() {
        for i in 0..k {
            lp.add(
                vec![(cin(u, i), 1.0), (x(v, i + 1), -1.0), (x(u, i + 1), 1.0)],
                Sense::Ge,
                0.0,
            );
            lp.add(
                vec![(cout(u, i), 1.0), (x(u, i + 1), -1.0), (x(v, i + 1), 1.0)],
                Sense::Ge,
                0.0,
            );
        }
    }
    // TotalLatency ≥ Latency_v
    for v in 0..n {
        lp.add(vec![(total, 1.0), (lat0 + v, -1.0)], Sense::Ge, 0.0);
    }
    // (6) big-M: Start_i ≥ Latency_v − (1 − CommIn_vi)·H
    for v in 0..n {
        for i in 0..k {
            lp.add(
                vec![(start0 + i, 1.0), (lat0 + v, -1.0), (cin(v, i), -big_m)],
                Sense::Ge,
                -big_m,
            );
        }
    }
    // (7) Finish_i = Start_i + Σ CommIn·c + Σ x·p_acc/speed + Σ CommOut·c
    // Per-pair topology: one CommIn/Out indicator per (node, acc) can't see
    // the peer device, so crossings price at the cheapest off-diagonal pair
    // (slowdown 1 by normalization + minimum latency) — a valid relaxation,
    // exact without a topology. The specialized search scores leaves with
    // the pair-exact evaluator.
    let min_lat = req.fleet.min_comm_latency();
    for i in 0..k {
        let speed = req.fleet.acc_speed(i);
        let mut coeffs = vec![(fin0 + i, 1.0), (start0 + i, -1.0)];
        for v in 0..n {
            coeffs.push((cin(v, i), -(g.nodes[v].comm + min_lat)));
            let p = if g.nodes[v].p_acc.is_finite() { g.nodes[v].p_acc / speed } else { 1e12 };
            coeffs.push((x(v, i + 1), -p));
            coeffs.push((cout(v, i), -(g.nodes[v].comm + min_lat)));
        }
        lp.add(coeffs, Sense::Eq, 0.0);
    }
    // (8)/(9) CPU recurrences
    let cpu_speed = req.fleet.cpu_speed(0);
    for v in 0..n {
        lp.add(
            vec![(lat0 + v, 1.0), (x(v, 0), -(g.nodes[v].p_cpu / cpu_speed).min(1e12))],
            Sense::Ge,
            0.0,
        );
    }
    for (u, v) in g.edges() {
        lp.add(
            vec![
                (lat0 + v, 1.0),
                (x(v, 0), -(g.nodes[v].p_cpu / cpu_speed).min(1e12)),
                (lat0 + u, -1.0),
            ],
            Sense::Ge,
            0.0,
        );
    }
    // (10) big-M: Latency_v ≥ Finish_i − (1 − x_vi)·H
    for v in 0..n {
        for i in 0..k {
            lp.add(
                vec![(lat0 + v, 1.0), (fin0 + i, -1.0), (x(v, i + 1), -big_m)],
                Sense::Ge,
                -big_m,
            );
        }
    }
    // (2) contiguity on accelerators via Lemma 4.1
    for v in 0..n {
        for i in 0..k {
            lp.add(vec![(z(v, i), 1.0), (x(v, i + 1), -1.0)], Sense::Ge, 0.0);
        }
    }
    for (u, v) in g.edges() {
        for i in 0..k {
            lp.add(vec![(z(v, i), 1.0), (z(u, i), -1.0)], Sense::Le, 0.0);
            lp.add(
                vec![(z(v, i), 1.0), (x(v, i + 1), -1.0), (x(u, i + 1), 1.0)],
                Sense::Le,
                1.0,
            );
        }
    }

    let integers: Vec<usize> = (0..n * nd).collect();
    LatencyModel { milp: Milp { lp, integers }, n, nd }
}

pub struct LatencyModel {
    pub milp: Milp,
    n: usize,
    nd: usize,
}

impl LatencyModel {
    pub fn assignment(&self, sol: &[f64]) -> Vec<usize> {
        (0..self.n)
            .map(|v| {
                (0..self.nd)
                    .max_by(|&a, &b| sol[v * self.nd + a].total_cmp(&sol[v * self.nd + b]))
                    .unwrap()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn chain_g(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(8.0).acc(1.0).mem(1.0).comm(0.25));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn single_acc_chain_latency() {
        let g = chain_g(4);
        let sc = Scenario::new(1, 1, f64::INFINITY);
        let r = solve(&g, &sc, &LatencyIpOptions::default()).unwrap();
        // all on the accelerator: no boundary comm → latency 4
        assert_eq!(r.status, SolveStatus::Optimal);
        assert!((r.placement.objective - 4.0).abs() < 1e-9, "{}", r.placement.objective);
    }

    #[test]
    fn memory_bound_forces_multi_device() {
        let g = chain_g(4);
        let sc = Scenario::new(2, 1, 2.0);
        let r = solve(&g, &sc, &LatencyIpOptions::default()).unwrap();
        r.placement.validate(&g, &sc, true).unwrap();
        // split 2|2 across accs: 2 + c_1 out 0.25 + same c_1 in + 2 = 4.5
        assert!((r.placement.objective - 4.5).abs() < 1e-9, "{}", r.placement.objective);
    }

    #[test]
    fn parallel_branches_exploit_second_accelerator() {
        // diamond with heavy parallel branches: two accelerators must beat
        // one (branch overlap). Source/sink are cheap on CPU so the two
        // branch subgraphs can actually run concurrently.
        let mut g = OpGraph::new();
        for i in 0..4 {
            let cpu = if i == 0 || i == 3 { 0.5 } else { 50.0 };
            g.add_node(Node::new(format!("n{i}")).cpu(cpu).acc(5.0).comm(0.1).mem(1.0));
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        let sc1 = Scenario::new(1, 1, f64::INFINITY);
        let sc2 = Scenario::new(2, 1, f64::INFINITY);
        let l1 = solve(&g, &sc1, &LatencyIpOptions::default()).unwrap();
        let l2 = solve(&g, &sc2, &LatencyIpOptions::default()).unwrap();
        assert!(
            l2.placement.objective < l1.placement.objective - 1.0,
            "2 accs {} vs 1 acc {}",
            l2.placement.objective,
            l1.placement.objective
        );
    }

    #[test]
    fn matches_exhaustive_on_small_graph() {
        use crate::util::proptest::random_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x7a7);
        for case in 0..8 {
            let g = random_dag(&mut rng, 6, 0.35);
            let sc = Scenario::new(2, 1, f64::INFINITY);
            let r = solve(&g, &sc, &LatencyIpOptions { gap_target: 0.0, ..Default::default() })
                .unwrap();
            assert_eq!(r.status, SolveStatus::Optimal, "case {case}");
            // exhaustive over contiguous-per-acc assignments
            let mut best = f64::INFINITY;
            let n = g.n();
            let mut assign = vec![0usize; n];
            'outer: loop {
                let p = Placement::new(
                    assign
                        .iter()
                        .map(|&d| if d == 0 { Device::Cpu(0) } else { Device::Acc(d - 1) })
                        .collect(),
                    0.0,
                    "bf",
                );
                let contig_ok = (0..sc.k).all(|i| {
                    crate::graph::contiguity::is_contiguous(&g, &p.set_of(Device::Acc(i), n))
                });
                if contig_ok && p.check_memory(&g, &sc).is_ok() {
                    best = best.min(objective::latency(&g, &sc, &p));
                }
                let mut i = 0;
                loop {
                    if i == n {
                        break 'outer;
                    }
                    assign[i] += 1;
                    if assign[i] <= sc.k {
                        break;
                    }
                    assign[i] = 0;
                    i += 1;
                }
            }
            assert!(
                (r.placement.objective - best).abs() < 1e-6,
                "case {case}: ip={} bf={best}",
                r.placement.objective
            );
        }
    }

    #[test]
    fn noncontiguous_not_worse() {
        use crate::util::proptest::random_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x7a8);
        let g = random_dag(&mut rng, 7, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let c =
            solve(&g, &sc, &LatencyIpOptions { gap_target: 0.0, ..Default::default() }).unwrap();
        let nc = solve(
            &g,
            &sc,
            &LatencyIpOptions { gap_target: 0.0, contiguous: false, ..Default::default() },
        )
        .unwrap();
        assert!(nc.placement.objective <= c.placement.objective + 1e-9);
    }

    #[test]
    fn milp_model_builds_and_solves_tiny() {
        use crate::solver::milp::MilpOptions;
        let g = chain_g(3);
        let sc = Scenario::new(1, 1, f64::INFINITY);
        let model = build_model(&g, &sc, 1000.0);
        let r = model.milp.solve(&MilpOptions {
            gap_target: 0.0,
            time_limit: Duration::from_secs(60),
            ..Default::default()
        });
        assert_eq!(r.status, SolveStatus::Optimal);
        let s =
            solve(&g, &sc, &LatencyIpOptions { gap_target: 0.0, ..Default::default() }).unwrap();
        assert!(
            (r.objective - s.placement.objective).abs() < 1e-5,
            "milp {} vs specialized {}",
            r.objective,
            s.placement.objective
        );
    }
}

//! Dynamic Program for throughput maximization (§5.1.1) — the paper's
//! headline exact algorithm for *contiguous* splits.
//!
//! `dp[I][k'][ℓ']` = smallest achievable max-load partitioning the ideal
//! `I` over `k'` accelerators and `ℓ'` CPUs. The transition carves the
//! last device's subgraph `S = I \ I'` over all sub-ideals `I' ⊆ I`
//! (Fact 5.2 guarantees every contiguous `S` arises this way):
//!
//! ```text
//! dp[I][k'][ℓ'] = min over ideals I' ⊆ I of
//!     min( max(dp[I'][k'-1][ℓ'], acc(I \ I')),
//!          max(dp[I'][k'][ℓ'-1], cpu(I \ I')) )
//! ```
//!
//! ### Implementation notes (the paper's `O(𝓘²(V+E))` term, made fast)
//!
//! For each ideal `I` we DFS *down* the ideal lattice through precomputed
//! immediate-sub-ideal links, so each sub-ideal of `I` is visited exactly
//! once (stamped visited array — no per-`I` allocation), and the subgraph
//! cost `acc(S)`/`cpu(S)` is maintained **incrementally** along the DFS
//! tree with undo on backtrack: `O(deg v)` per lattice step instead of the
//! naive `O(V+E)` per pair. A monotone lower bound
//! `min(cpu(S), compute_acc(S))` prunes lattice subtrees that cannot
//! improve any `dp[I][·][·]` entry.

use super::objective;
use crate::coordinator::placement::{Device, Placement, Scenario};
use crate::graph::ideals::{IdealId, IdealLattice, DEFAULT_IDEAL_CAP};
use crate::graph::{contract, subdivide, NodeKind, OpGraph};

/// Error cases for the DP front end.
#[derive(Debug)]
pub enum DpError {
    /// Too many ideals — fall back to [`super::dpl`].
    TooManyIdeals(usize),
    /// No feasible split (memory/unsupported ops).
    Infeasible,
    /// Graph (after contraction) is not a DAG.
    NotADag,
}

impl std::fmt::Display for DpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DpError::TooManyIdeals(n) => write!(f, "ideal lattice exceeds cap ({n}+ ideals)"),
            DpError::Infeasible => write!(f, "no feasible contiguous split"),
            DpError::NotADag => write!(f, "graph is not a DAG after preprocessing"),
        }
    }
}

impl std::error::Error for DpError {}

/// Solve throughput maximization on `g` (inference *or* training graph)
/// with full App.-B preprocessing. Returns an optimal contiguous placement.
pub fn solve(g: &OpGraph, sc: &Scenario) -> Result<Placement, DpError> {
    solve_with_cap(g, sc, DEFAULT_IDEAL_CAP)
}

/// [`solve`] with an explicit ideal-count cap.
pub fn solve_with_cap(g: &OpGraph, sc: &Scenario, cap: usize) -> Result<Placement, DpError> {
    let prepared = Prepared::build(g)?;
    let lattice = IdealLattice::enumerate(&prepared.dp_graph, cap)
        .map_err(DpError::TooManyIdeals)?;
    let (obj, dense) =
        solve_on_lattice_with(&prepared.dp_graph, sc, &lattice, &prepared.bw_comm)?;
    Ok(prepared.expand(g, sc, obj, &dense))
}

/// Preprocessed problem: the (possibly training-merged) DAG the DP runs on,
/// plus the mapping back to original nodes.
pub struct Prepared {
    /// Graph the lattice is enumerated on: forward-shaped, colocation
    /// contracted, fw/bw merged for training graphs. Node `comm` is the
    /// FORWARD activation cost only; the backward gradient cost lives in
    /// [`Prepared::bw_comm`] so the DP can account both directions exactly
    /// (a merged node's fw boundary and bw boundary mirror each other but
    /// are billed on opposite sides).
    pub dp_graph: OpGraph,
    /// `map[orig_node] = dp_graph node`.
    pub map: Vec<usize>,
    /// Gradient transfer cost of each dp node's backward partner (0 for
    /// inference graphs).
    pub bw_comm: Vec<f64>,
}

impl Prepared {
    pub fn build(g: &OpGraph) -> Result<Prepared, DpError> {
        // 1. per-edge costs → per-node (App. B reduction)
        let sub = subdivide::reduce_edge_costs(g);
        let work = sub.graph;
        let is_training = work.nodes.iter().any(|n| n.kind == NodeKind::Backward);

        let (aug, map_aug, aug_bw_comm) = if is_training {
            // 2. artificial forward images for orphaned backward nodes
            let (aug, bw_of_fw) = contract::mirror_orphans(&work);
            // 3. merge each fw node with its bw partner: compute/mem add,
            //    comm adds (activation + gradient cross together — the
            //    PipeDream cost model, cf. App. A correlation argument).
            let mut merged = OpGraph::new();
            let mut merged_bw_comm: Vec<f64> = Vec::new();
            let mut dp_id = vec![usize::MAX; aug.n()];
            for v in 0..aug.n() {
                if aug.nodes[v].kind == NodeKind::Forward {
                    let mut node = aug.nodes[v].clone();
                    let mut bwc = 0.0;
                    if let Some(b) = bw_of_fw[v] {
                        node.p_cpu += aug.nodes[b].p_cpu;
                        node.p_acc += aug.nodes[b].p_acc;
                        node.mem += aug.nodes[b].mem;
                        bwc = aug.nodes[b].comm;
                    }
                    dp_id[v] = merged.add_node(node);
                    merged_bw_comm.push(bwc);
                }
            }
            for v in 0..aug.n() {
                if aug.nodes[v].kind == NodeKind::Backward {
                    // ride with the forward partner / image
                    let f = aug.nodes[v]
                        .fw_partner
                        .or_else(|| {
                            // artificial image added by mirror_orphans
                            (work.n()..aug.n()).find(|&img| bw_of_fw[img] == Some(v))
                        })
                        .ok_or(DpError::NotADag)?;
                    dp_id[v] = dp_id[f];
                }
            }
            // forward-part edges only (bw edges mirror them)
            let mut out = merged;
            for (u, v) in aug.edges() {
                let (du, dv) = (dp_id[u], dp_id[v]);
                if du != dv
                    && aug.nodes[u].kind == NodeKind::Forward
                    && aug.nodes[v].kind == NodeKind::Forward
                {
                    out.add_edge(du, dv);
                }
            }
            (out, dp_id, merged_bw_comm)
        } else {
            let n = work.n();
            let zeros = vec![0.0; n];
            (work, (0..n).collect(), zeros)
        };

        // 4. colocation contraction + SCC cleanup
        let con = contract::preprocess_colocation(&aug);
        if !crate::graph::topo::is_dag(&con.graph) {
            return Err(DpError::NotADag);
        }
        // bw comm through the contraction: a member's gradient leaves the
        // contracted node iff some pred of the member lies outside it
        let mut bw_comm = vec![0.0; con.graph.n()];
        for (m, &c) in con.map.iter().enumerate() {
            if aug_bw_comm[m] > 0.0
                && aug.preds[m].iter().any(|&u| con.map[u] != c)
            {
                bw_comm[c] += aug_bw_comm[m];
            }
        }
        // sources keep their grad cost attached for bw_in accounting
        for (m, &c) in con.map.iter().enumerate() {
            if aug_bw_comm[m] > 0.0 && bw_comm[c] == 0.0 && aug.preds[m].is_empty() {
                bw_comm[c] += aug_bw_comm[m];
            }
        }
        // compose: orig → subdivided (identity on originals) → aug → contracted
        let map: Vec<usize> = (0..g.n()).map(|v| con.map[map_aug[v]]).collect();
        Ok(Prepared { dp_graph: con.graph, map, bw_comm })
    }

    /// Expand a dense assignment on `dp_graph` back to the original nodes.
    pub fn expand(&self, g: &OpGraph, sc: &Scenario, obj: f64, dense: &[usize]) -> Placement {
        let assignment: Vec<Device> = self
            .map
            .iter()
            .map(|&c| Device::from_index(dense[c], sc.k))
            .collect();
        let mut p = Placement::new(assignment, obj, "DP (contiguous)");
        // Score on the *original* graph's cost model for reporting parity
        // with the other algorithms.
        let measured = objective::max_load(g, sc, &p);
        if measured.is_finite() {
            p.objective = measured;
        }
        p
    }
}

/// Run the DP on a preprocessed DAG with no backward comm (inference).
pub fn solve_on_lattice(
    g: &OpGraph,
    sc: &Scenario,
    lattice: &IdealLattice,
) -> Result<(f64, Vec<usize>), DpError> {
    let zeros = vec![0.0; g.n()];
    solve_on_lattice_with(g, sc, lattice, &zeros)
}

/// Run the DP proper. `bw_comm[v]` is the gradient transfer cost of v's
/// backward partner: billed as bw-out while any pred of v is outside the
/// carved subgraph, and as bw-in to the device holding v's preds (the
/// mirror of the forward boundary). Returns the optimal max-load and a
/// dense device assignment (`0..k` accs, `k..` CPU index `k+j`).
pub fn solve_on_lattice_with(
    g: &OpGraph,
    sc: &Scenario,
    lattice: &IdealLattice,
    bw_comm: &[f64],
) -> Result<(f64, Vec<usize>), DpError> {
    let (k, l) = (sc.k, sc.l);
    let slots = (k + 1) * (l + 1);
    let ni = lattice.len();
    let idx = |i: IdealId, k_: usize, l_: usize| i * slots + k_ * (l + 1) + l_;

    let mut dp = vec![f64::INFINITY; ni * slots];
    // parent choice: (sub-ideal id, used accelerator?) per (I, k', l')
    let mut parent: Vec<(u32, bool)> = vec![(u32::MAX, false); ni * slots];
    dp[idx(lattice.empty_id(), 0, 0)] = 0.0;
    // empty ideal partitions with any device budget at cost 0
    for k_ in 0..=k {
        for l_ in 0..=l {
            dp[idx(lattice.empty_id(), k_, l_)] = 0.0;
        }
    }

    // Reusable DFS scratch (no allocation per ideal).
    let mut visited = vec![u32::MAX; ni];
    let mut in_cnt: Vec<u32> = vec![0; g.n()]; // edges from u into S
    let mut pred_out_cnt: Vec<u32> = vec![0; g.n()]; // per S-member: preds outside S
    let mut src_cnt: Vec<u32> = vec![0; g.n()]; // per outside node: preds in S
    let n = g.n();

    for i in 1..ni {
        let stamp = i as u32;
        // cur[k_][l_] running best for this ideal
        let base = idx(i, 0, 0);
        // DFS state: (ideal id, cursor into subs, node added when entering)
        let mut stack: Vec<(IdealId, usize, usize)> = vec![(i, 0, usize::MAX)];
        visited[i] = stamp;
        // incremental S = ideals[i] \ ideals[current]
        let mut s_cpu = 0.0_f64;
        let mut s_compute = 0.0_f64;
        let mut s_mem = 0.0_f64;
        let mut s_comm_in = 0.0_f64;
        let mut s_comm_out = 0.0_f64;
        let mut s_bw_in = 0.0_f64;
        let mut s_bw_out = 0.0_f64;
        let full = &lattice.ideals[i];
        let mut st = BwState {
            bw_comm,
            pred_out_cnt: &mut pred_out_cnt,
            src_cnt: &mut src_cnt,
        };

        macro_rules! relax {
            ($sub:expr) => {{
                let sub = $sub;
                let acc_ok = s_mem <= sc.mem_cap && s_compute.is_finite();
                let acc_load = if acc_ok {
                    sc.combine(s_compute, s_comm_in + s_bw_in, s_comm_out + s_bw_out)
                } else {
                    f64::INFINITY
                };
                for k_ in 0..=k {
                    for l_ in 0..=l {
                        let cell = base + k_ * (l + 1) + l_;
                        if k_ > 0 {
                            let cand = dp[idx(sub, k_ - 1, l_)].max(acc_load);
                            if cand < dp[cell] {
                                dp[cell] = cand;
                                parent[cell] = (sub as u32, true);
                            }
                        }
                        if l_ > 0 {
                            let cand = dp[idx(sub, k_, l_ - 1)].max(s_cpu);
                            if cand < dp[cell] {
                                dp[cell] = cand;
                                parent[cell] = (sub as u32, false);
                            }
                        }
                    }
                }
            }};
        }

        while let Some(top) = stack.last_mut() {
            let (cur, cursor) = (top.0, top.1);
            if cursor < lattice.subs[cur].len() {
                top.1 += 1;
                let (sub, v) = lattice.subs[cur][cursor];
                if visited[sub] == stamp {
                    continue;
                }
                visited[sub] = stamp;
                // --- add v to S (incremental cost update) ---
                add_node(g, v, full, &mut in_cnt, &mut s_cpu, &mut s_compute, &mut s_mem, &mut s_comm_in, &mut s_comm_out);
                add_bw(g, v, full, &mut st, &mut s_bw_in, &mut s_bw_out);
                // Prune: both cpu(S) and compute(S) grow monotonically as S
                // grows, and every candidate is ≥ min of them, so once that
                // lower bound exceeds EVERY still-improvable dp cell of this
                // ideal the whole subtree is useless. Cells at (0,0) are
                // never touched by relax; INF cells are always improvable,
                // so any INF cell disables the prune.
                let lb = s_cpu.min(s_compute);
                let worst_improvable = (0..slots)
                    .filter(|&o| o != 0)
                    .map(|o| dp[base + o])
                    .fold(0.0, f64::max);
                if lb >= worst_improvable && worst_improvable.is_finite() {
                    // undo and skip subtree
                    remove_node(g, v, full, &mut in_cnt, &mut s_cpu, &mut s_compute, &mut s_mem, &mut s_comm_in, &mut s_comm_out);
                    remove_bw(g, v, full, &mut st, &mut s_bw_in, &mut s_bw_out);
                    continue;
                }
                relax!(sub);
                stack.push((sub, 0, v));
            } else {
                let added = top.2;
                stack.pop();
                if added != usize::MAX {
                    remove_node(g, added, full, &mut in_cnt, &mut s_cpu, &mut s_compute, &mut s_mem, &mut s_comm_in, &mut s_comm_out);
                    remove_bw(g, added, full, &mut st, &mut s_bw_in, &mut s_bw_out);
                }
            }
        }
        debug_assert!(in_cnt.iter().all(|&c| c == 0));
        let _ = n;

        // Monotone closure (the S = ∅ transition): a device may be left
        // empty, so dp[I][k'][ℓ'] ≤ dp[I][k'-1][ℓ'] and ≤ dp[I][k'][ℓ'-1].
        // Done after the DFS so late improvements propagate.
        for k_ in 0..=k {
            for l_ in 0..=l {
                let cell = base + k_ * (l + 1) + l_;
                if k_ > 0 {
                    let prev = base + (k_ - 1) * (l + 1) + l_;
                    if dp[prev] < dp[cell] {
                        dp[cell] = dp[prev];
                        parent[cell] = (i as u32, true);
                    }
                }
                if l_ > 0 {
                    let prev = base + k_ * (l + 1) + (l_ - 1);
                    if dp[prev] < dp[cell] {
                        dp[cell] = dp[prev];
                        parent[cell] = (i as u32, false);
                    }
                }
            }
        }
    }

    let final_cell = idx(lattice.full_id(), k, l);
    if !dp[final_cell].is_finite() {
        return Err(DpError::Infeasible);
    }

    // Reconstruct: walk parents from (full, k, l), carving device subgraphs.
    let mut dense = vec![usize::MAX; g.n()];
    let (mut i, mut k_, mut l_) = (lattice.full_id(), k, l);
    let mut next_acc = 0usize;
    let mut next_cpu = 0usize;
    while i != lattice.empty_id() {
        let (sub, used_acc) = parent[idx(i, k_, l_)];
        if sub == u32::MAX {
            break; // dp[∅][k'][l'] = 0 seeds have no parent
        }
        let sub = sub as usize;
        let s = lattice.ideals[i].difference(&lattice.ideals[sub]);
        let device = if used_acc {
            let d = next_acc;
            next_acc += 1;
            k_ -= 1;
            d
        } else {
            let d = k + next_cpu;
            next_cpu += 1;
            l_ -= 1;
            d
        };
        for v in s.iter() {
            dense[v] = device;
        }
        i = sub;
        if i == lattice.empty_id() {
            break;
        }
    }
    // Any nodes not covered (shouldn't happen) → CPU 0 fallback.
    for d in dense.iter_mut() {
        if *d == usize::MAX {
            *d = k;
        }
    }
    Ok((dp[final_cell], dense))
}

struct BwState<'a> {
    bw_comm: &'a [f64],
    pred_out_cnt: &'a mut [u32],
    src_cnt: &'a mut [u32],
}

/// Backward-direction comm bookkeeping when v joins S (§5.3 exact costs):
/// v's gradient goes OUT while any of v's preds is outside S; the gradient
/// of an outside node w with a pred in S comes IN (once per w).
#[inline]
fn add_bw(
    g: &OpGraph,
    v: usize,
    full: &crate::util::bitset::BitSet,
    st: &mut BwState<'_>,
    s_bw_in: &mut f64,
    s_bw_out: &mut f64,
) {
    // v enters S: all its preds are currently outside S
    let np = g.preds[v].len() as u32;
    st.pred_out_cnt[v] = np;
    if np > 0 {
        *s_bw_out += st.bw_comm[v];
    }
    for &w in &g.succs[v] {
        if full.contains(w) {
            // w ∈ S (succs inside the ideal are in S by maximality): one of
            // w's preds just joined S
            st.pred_out_cnt[w] -= 1;
            if st.pred_out_cnt[w] == 0 {
                *s_bw_out -= st.bw_comm[w];
            }
        } else {
            // w outside the ideal: its gradient now flows into S
            st.src_cnt[w] += 1;
            if st.src_cnt[w] == 1 {
                *s_bw_in += st.bw_comm[w];
            }
        }
    }
}

#[inline]
fn remove_bw(
    g: &OpGraph,
    v: usize,
    full: &crate::util::bitset::BitSet,
    st: &mut BwState<'_>,
    s_bw_in: &mut f64,
    s_bw_out: &mut f64,
) {
    for &w in &g.succs[v] {
        if full.contains(w) {
            if st.pred_out_cnt[w] == 0 {
                *s_bw_out += st.bw_comm[w];
            }
            st.pred_out_cnt[w] += 1;
        } else {
            st.src_cnt[w] -= 1;
            if st.src_cnt[w] == 0 {
                *s_bw_in -= st.bw_comm[w];
            }
        }
    }
    if !g.preds[v].is_empty() {
        *s_bw_out -= st.bw_comm[v];
    }
    st.pred_out_cnt[v] = 0;
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn add_node(
    g: &OpGraph,
    v: usize,
    full: &crate::util::bitset::BitSet,
    in_cnt: &mut [u32],
    s_cpu: &mut f64,
    s_compute: &mut f64,
    s_mem: &mut f64,
    s_comm_in: &mut f64,
    s_comm_out: &mut f64,
) {
    *s_cpu += g.nodes[v].p_cpu;
    *s_compute += g.nodes[v].p_acc;
    *s_mem += g.nodes[v].mem;
    // v's successors outside the enclosing ideal ⇒ out-comm (fixed per I).
    if g.succs[v].iter().any(|&w| !full.contains(w)) {
        *s_comm_out += g.nodes[v].comm;
    }
    // v stops being an external in-comm contributor.
    if in_cnt[v] > 0 {
        *s_comm_in -= g.nodes[v].comm;
    }
    // v's predecessors become/remain external contributors.
    for &u in &g.preds[v] {
        if in_cnt[u] == 0 {
            *s_comm_in += g.nodes[u].comm;
        }
        in_cnt[u] += 1;
    }
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn remove_node(
    g: &OpGraph,
    v: usize,
    full: &crate::util::bitset::BitSet,
    in_cnt: &mut [u32],
    s_cpu: &mut f64,
    s_compute: &mut f64,
    s_mem: &mut f64,
    s_comm_in: &mut f64,
    s_comm_out: &mut f64,
) {
    *s_cpu -= g.nodes[v].p_cpu;
    *s_compute -= g.nodes[v].p_acc;
    *s_mem -= g.nodes[v].mem;
    if g.succs[v].iter().any(|&w| !full.contains(w)) {
        *s_comm_out -= g.nodes[v].comm;
    }
    for &u in &g.preds[v] {
        in_cnt[u] -= 1;
        if in_cnt[u] == 0 {
            *s_comm_in -= g.nodes[u].comm;
        }
    }
    if in_cnt[v] > 0 {
        *s_comm_in += g.nodes[v].comm;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn chain_g(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(10.0).acc(1.0).mem(1.0).comm(0.1));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn single_accelerator_takes_all() {
        let g = chain_g(4);
        let sc = Scenario::new(1, 1, f64::INFINITY);
        let p = solve(&g, &sc).unwrap();
        // CPU is 10x slower: optimum is everything on the accelerator, 4.0
        assert!((p.objective - 4.0).abs() < 1e-9, "{}", p.objective);
        assert!(p.assignment.iter().all(|d| d.is_acc()));
        p.validate(&g, &sc, true).unwrap();
    }

    #[test]
    fn two_accelerators_balance() {
        let g = chain_g(4);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = solve(&g, &sc).unwrap();
        // split 2/2: load = 2 + boundary comm 0.1 = 2.1
        assert!((p.objective - 2.1).abs() < 1e-9, "{}", p.objective);
        p.validate(&g, &sc, true).unwrap();
    }

    #[test]
    fn memory_cap_forces_split() {
        let g = chain_g(4);
        let sc = Scenario::new(2, 1, 2.0);
        let p = solve(&g, &sc).unwrap();
        p.validate(&g, &sc, true).unwrap();
        assert!((p.objective - 2.1).abs() < 1e-9);
        // k=1 with cap 2 can't fit all 4 nodes on acc; 2 must go to CPU
        let sc1 = Scenario::new(1, 1, 2.0);
        let p1 = solve(&g, &sc1).unwrap();
        p1.validate(&g, &sc1, true).unwrap();
        assert!((p1.objective - 20.0).abs() < 1e-9, "{}", p1.objective);
    }

    #[test]
    fn infeasible_when_no_cpu_and_no_memory() {
        let mut g = chain_g(2);
        g.nodes[0].p_cpu = f64::INFINITY;
        g.nodes[1].p_cpu = f64::INFINITY;
        let sc = Scenario::new(1, 0, 1.0); // only 1 node fits
        assert!(matches!(solve(&g, &sc), Err(DpError::Infeasible)));
    }

    #[test]
    fn matches_brute_force_on_small_dags() {
        use crate::util::proptest::random_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xD9);
        for case in 0..30 {
            let g = random_dag(&mut rng, 6, 0.35);
            let sc = Scenario::new(2, 1, 4.0);
            let dp = solve(&g, &sc);
            let bf = brute_force_contiguous(&g, &sc);
            match (dp, bf) {
                (Ok(p), Some(best)) => {
                    assert!(
                        (p.objective - best).abs() < 1e-6,
                        "case {case}: dp={} bf={best}",
                        p.objective
                    );
                    p.validate(&g, &sc, true).unwrap();
                }
                (Err(DpError::Infeasible), None) => {}
                (dp, bf) => panic!("case {case}: dp={dp:?} bf={bf:?} disagree on feasibility"),
            }
        }
    }

    /// Brute force over the DP's exact search space: partitions whose
    /// device condensation is acyclic (pipeline-orderable ⇔ expressible as
    /// a chain of ideals; per-device contiguity follows automatically).
    fn brute_force_contiguous(g: &OpGraph, sc: &Scenario) -> Option<f64> {
        let nd = sc.k + sc.l;
        let n = g.n();
        let mut best: Option<f64> = None;
        let mut assign = vec![0usize; n];
        loop {
            let placement = Placement::new(
                assign.iter().map(|&d| Device::from_index(d, sc.k)).collect(),
                0.0,
                "bf",
            );
            let orderable =
                crate::graph::contiguity::partition_pipeline_orderable(g, &assign, nd);
            if orderable && placement.validate(g, sc, false).is_ok() {
                let obj = objective::max_load(g, sc, &placement);
                if obj.is_finite() {
                    best = Some(best.map_or(obj, |b: f64| b.min(obj)));
                }
            }
            // increment base-nd counter
            let mut i = 0;
            loop {
                if i == n {
                    return best;
                }
                assign[i] += 1;
                if assign[i] < nd {
                    break;
                }
                assign[i] = 0;
                i += 1;
            }
        }
    }

    #[test]
    fn training_graph_colocates_fw_bw() {
        use crate::util::proptest::random_training_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let g = random_training_dag(&mut rng, 6, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = solve(&g, &sc).unwrap();
        p.check_colocation(&g).unwrap();
        p.check_contiguity(&g, &sc).unwrap();
        assert!(p.objective.is_finite());
    }

    #[test]
    fn parallel_branches_use_both_accelerators() {
        // two heavy independent chains share a source/sink; two accs should
        // each take one branch
        let mut g = OpGraph::new();
        let s = g.add_node(Node::new("src").cpu(0.1).acc(0.1).comm(0.01));
        let mut last_a = s;
        let mut last_b = s;
        for i in 0..3 {
            let a = g.add_node(Node::new(format!("a{i}")).cpu(50.0).acc(5.0).comm(0.01));
            g.add_edge(last_a, a);
            last_a = a;
            let b = g.add_node(Node::new(format!("b{i}")).cpu(50.0).acc(5.0).comm(0.01));
            g.add_edge(last_b, b);
            last_b = b;
        }
        let t = g.add_node(Node::new("sink").cpu(0.1).acc(0.1).comm(0.01));
        g.add_edge(last_a, t);
        g.add_edge(last_b, t);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = solve(&g, &sc).unwrap();
        p.validate(&g, &sc, true).unwrap();
        // perfect balance would be ~15.2; one acc doing both branches ~30
        assert!(p.objective < 20.0, "objective {}", p.objective);
    }
}

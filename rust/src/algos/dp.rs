//! Dynamic Program for throughput maximization (§5.1.1) — the paper's
//! headline exact algorithm for *contiguous* splits.
//!
//! `dp[I][k'][ℓ']` = smallest achievable max-load partitioning the ideal
//! `I` over `k'` accelerators and `ℓ'` CPUs. The transition carves the
//! last device's subgraph `S = I \ I'` over all sub-ideals `I' ⊆ I`
//! (Fact 5.2 guarantees every contiguous `S` arises this way):
//!
//! ```text
//! dp[I][k'][ℓ'] = min over ideals I' ⊆ I of
//!     min( max(dp[I'][k'-1][ℓ'], acc(I \ I')),
//!          max(dp[I'][k'][ℓ'-1], cpu(I \ I')) )
//! ```
//!
//! ### Implementation notes (the paper's `O(𝓘²(V+E))` term, made fast)
//!
//! For each ideal `I` we DFS *down* the ideal lattice through precomputed
//! immediate-sub-ideal links, so each sub-ideal of `I` is visited exactly
//! once (stamped visited array — no per-`I` allocation), and the subgraph
//! cost `acc(S)`/`cpu(S)` is maintained **incrementally** along the DFS
//! tree with undo on backtrack: `O(deg v)` per lattice step instead of the
//! naive `O(V+E)` per pair. A monotone lower bound
//! `min(cpu(S), compute_acc(S))` prunes lattice subtrees that cannot
//! improve any `dp[I][·][·]` entry.
//!
//! ### Heterogeneous fleets
//!
//! The table generalizes from `(k', ℓ')` to one *remaining-count digit per
//! device class* of the request's [`crate::coordinator::placement::Fleet`]
//! (devices within a class are interchangeable, so counts stay sufficient
//! state): cell `(n_0, …, n_C)` is a mixed-radix index with class 0 most
//! significant, and the transition carves `S` onto any class with a
//! remaining device, paying that class's `speed`-scaled compute and its
//! own `mem_cap`. A one-accelerator-class + one-CPU-class fleet (what
//! [`crate::coordinator::placement::Scenario::to_request`] produces) lays
//! out exactly the historical `(k+1)·(ℓ+1)` cells in the same iteration
//! order — the legacy path is bitwise-identical (see the uniform-fleet
//! equivalence tests).
//!
//! ### Level-synchronous parallel execution
//!
//! `dp[I][·][·]` depends only on ideals of strictly smaller cardinality, so
//! the lattice's cardinality layers ([`IdealLattice::layer`]) form a
//! dependency-free schedule: all ideals of one layer are solved in
//! parallel (scoped threads, `util::par`), each worker owning a disjoint
//! chunk of the flat `dp`/`parent` tables plus its own DFS scratch. Every
//! ideal's cells are written by exactly one worker and all cross-ideal
//! reads hit finished layers, so the result is **bitwise identical for any
//! thread count** (see the determinism property test). Small layers and
//! small lattices fall back to the sequential path to avoid spawn
//! overhead; tune with [`DpOptions`].

use super::{objective, PlaceError};
use crate::coordinator::placement::{Device, DeviceKind, Placement, PlanRequest, Scenario};
use crate::graph::ideals::{IdealId, IdealLattice, IdealRef, DEFAULT_IDEAL_CAP};
use crate::graph::{contract, subdivide, NodeKind, OpGraph};
use crate::util::par;

/// Deprecated alias: the DP family's error type is now the crate-wide
/// [`PlaceError`] (variants are accessible through the alias, so existing
/// `DpError::Infeasible`-style matches keep compiling).
pub type DpError = PlaceError;

/// Execution knobs for the level-synchronous DP.
#[derive(Clone, Debug)]
pub struct DpOptions {
    /// Worker threads; 0 = use `available_parallelism`.
    pub threads: usize,
    /// Minimum ideals in a cardinality layer before that layer is solved
    /// in parallel (smaller layers run on one thread — spawn overhead
    /// dominates below this).
    pub par_threshold: usize,
}

impl Default for DpOptions {
    fn default() -> Self {
        DpOptions { threads: 0, par_threshold: 192 }
    }
}

/// Solve throughput maximization on `g` (inference *or* training graph)
/// with full App.-B preprocessing. Returns an optimal contiguous placement.
///
/// Deprecated thin wrapper: recomputes the preprocessing and lattice per
/// call. Prefer [`crate::coordinator::planner::DpSolver`] over a shared
/// [`crate::coordinator::context::ProblemCtx`], which caches both (and the
/// solution itself).
pub fn solve(g: &OpGraph, sc: &Scenario) -> Result<Placement, DpError> {
    solve_with_cap(g, sc, DEFAULT_IDEAL_CAP)
}

/// [`solve`] with an explicit ideal-count cap.
pub fn solve_with_cap(g: &OpGraph, sc: &Scenario, cap: usize) -> Result<Placement, DpError> {
    solve_req_with_cap(g, &sc.to_request(), cap)
}

/// [`solve`] over a heterogeneous [`PlanRequest`] fleet. One-shot like
/// [`solve`]; prefer a shared [`crate::coordinator::context::ProblemCtx`]
/// built via `from_request` for re-planning.
pub fn solve_req(g: &OpGraph, req: &PlanRequest) -> Result<Placement, DpError> {
    solve_req_with_cap(g, req, DEFAULT_IDEAL_CAP)
}

/// [`solve_req`] with an explicit ideal-count cap.
pub fn solve_req_with_cap(
    g: &OpGraph,
    req: &PlanRequest,
    cap: usize,
) -> Result<Placement, DpError> {
    let prepared = Prepared::build(g)?;
    let lattice = IdealLattice::enumerate(&prepared.dp_graph, cap)
        .map_err(DpError::TooManyIdeals)?;
    let (obj, dense) =
        solve_on_lattice_req(&prepared.dp_graph, req, &lattice, &prepared.bw_comm)?;
    Ok(prepared.expand_req(g, req, obj, &dense))
}

/// Preprocessed problem: the (possibly training-merged) DAG the DP runs on,
/// plus the mapping back to original nodes.
pub struct Prepared {
    /// Graph the lattice is enumerated on: forward-shaped, colocation
    /// contracted, fw/bw merged for training graphs. Node `comm` is the
    /// FORWARD activation cost only; the backward gradient cost lives in
    /// [`Prepared::bw_comm`] so the DP can account both directions exactly
    /// (a merged node's fw boundary and bw boundary mirror each other but
    /// are billed on opposite sides).
    pub dp_graph: OpGraph,
    /// `map[orig_node] = dp_graph node`.
    pub map: Vec<usize>,
    /// Gradient transfer cost of each dp node's backward partner (0 for
    /// inference graphs).
    pub bw_comm: Vec<f64>,
}

impl Prepared {
    pub fn build(g: &OpGraph) -> Result<Prepared, DpError> {
        // 1. per-edge costs → per-node (App. B reduction)
        let sub = subdivide::reduce_edge_costs(g);
        let work = sub.graph;
        let is_training = work.nodes.iter().any(|n| n.kind == NodeKind::Backward);

        let (aug, map_aug, aug_bw_comm) = if is_training {
            // 2. artificial forward images for orphaned backward nodes
            let (aug, bw_of_fw) = contract::mirror_orphans(&work);
            // 3. merge each fw node with its bw partner: compute/mem add,
            //    comm adds (activation + gradient cross together — the
            //    PipeDream cost model, cf. App. A correlation argument).
            let mut merged = OpGraph::new();
            let mut merged_bw_comm: Vec<f64> = Vec::new();
            let mut dp_id = vec![usize::MAX; aug.n()];
            for v in 0..aug.n() {
                if aug.nodes[v].kind == NodeKind::Forward {
                    let mut node = aug.nodes[v].clone();
                    let mut bwc = 0.0;
                    if let Some(b) = bw_of_fw[v] {
                        node.p_cpu += aug.nodes[b].p_cpu;
                        node.p_acc += aug.nodes[b].p_acc;
                        node.mem += aug.nodes[b].mem;
                        bwc = aug.nodes[b].comm;
                    }
                    dp_id[v] = merged.add_node(node);
                    merged_bw_comm.push(bwc);
                }
            }
            for v in 0..aug.n() {
                if aug.nodes[v].kind == NodeKind::Backward {
                    // ride with the forward partner / image
                    let f = aug.nodes[v]
                        .fw_partner
                        .or_else(|| {
                            // artificial image added by mirror_orphans
                            (work.n()..aug.n()).find(|&img| bw_of_fw[img] == Some(v))
                        })
                        .ok_or(DpError::NotADag)?;
                    dp_id[v] = dp_id[f];
                }
            }
            // forward-part edges only (bw edges mirror them)
            let mut out = merged;
            for (u, v) in aug.edges() {
                let (du, dv) = (dp_id[u], dp_id[v]);
                if du != dv
                    && aug.nodes[u].kind == NodeKind::Forward
                    && aug.nodes[v].kind == NodeKind::Forward
                {
                    out.add_edge(du, dv);
                }
            }
            (out, dp_id, merged_bw_comm)
        } else {
            let n = work.n();
            let zeros = vec![0.0; n];
            (work, (0..n).collect(), zeros)
        };

        // 4. colocation contraction + SCC cleanup
        let con = contract::preprocess_colocation(&aug);
        if !crate::graph::topo::is_dag(&con.graph) {
            return Err(DpError::NotADag);
        }
        // bw comm through the contraction: a member's gradient leaves the
        // contracted node iff some pred of the member lies outside it
        let mut bw_comm = vec![0.0; con.graph.n()];
        for (m, &c) in con.map.iter().enumerate() {
            if aug_bw_comm[m] > 0.0
                && aug.preds[m].iter().any(|&u| con.map[u] != c)
            {
                bw_comm[c] += aug_bw_comm[m];
            }
        }
        // sources keep their grad cost attached for bw_in accounting
        for (m, &c) in con.map.iter().enumerate() {
            if aug_bw_comm[m] > 0.0 && bw_comm[c] == 0.0 && aug.preds[m].is_empty() {
                bw_comm[c] += aug_bw_comm[m];
            }
        }
        // compose: orig → subdivided (identity on originals) → aug → contracted
        let map: Vec<usize> = (0..g.n()).map(|v| con.map[map_aug[v]]).collect();
        Ok(Prepared { dp_graph: con.graph, map, bw_comm })
    }

    /// Expand a dense assignment on `dp_graph` back to the original nodes.
    pub fn expand(&self, g: &OpGraph, sc: &Scenario, obj: f64, dense: &[usize]) -> Placement {
        self.expand_req(g, &sc.to_request(), obj, dense)
    }

    /// [`Prepared::expand`] against a [`PlanRequest`].
    pub fn expand_req(
        &self,
        g: &OpGraph,
        req: &PlanRequest,
        obj: f64,
        dense: &[usize],
    ) -> Placement {
        let k = req.fleet.k();
        let assignment: Vec<Device> =
            self.map.iter().map(|&c| Device::from_index(dense[c], k)).collect();
        let mut p = Placement::new(assignment, obj, "DP (contiguous)");
        // Score on the *original* graph's cost model for reporting parity
        // with the other algorithms.
        let measured = objective::max_load_req(g, req, &p);
        if measured.is_finite() {
            p.objective = measured;
        }
        p
    }
}

/// Run the DP on a preprocessed DAG with no backward comm (inference).
pub fn solve_on_lattice(
    g: &OpGraph,
    sc: &Scenario,
    lattice: &IdealLattice,
) -> Result<(f64, Vec<usize>), DpError> {
    let zeros = vec![0.0; g.n()];
    solve_on_lattice_with(g, sc, lattice, &zeros)
}

/// [`solve_on_lattice_with_opts`] with default options.
pub fn solve_on_lattice_with(
    g: &OpGraph,
    sc: &Scenario,
    lattice: &IdealLattice,
    bw_comm: &[f64],
) -> Result<(f64, Vec<usize>), DpError> {
    solve_on_lattice_with_opts(g, sc, lattice, bw_comm, &DpOptions::default())
}

/// [`solve_on_lattice_req_opts`] with default options.
pub fn solve_on_lattice_req(
    g: &OpGraph,
    req: &PlanRequest,
    lattice: &IdealLattice,
    bw_comm: &[f64],
) -> Result<(f64, Vec<usize>), DpError> {
    solve_on_lattice_req_opts(g, req, lattice, bw_comm, &DpOptions::default())
}

/// Per-class view of a request's fleet in dense-class order (accelerator
/// classes first, then CPU classes), plus the mixed-radix layout of one
/// ideal's cell block: `cell(digits) = Σ_c digits[c]·strides[c]`, class 0
/// most significant. A uniform fleet yields exactly the historical
/// `(k+1)·(ℓ+1)` layout in the same iteration order.
struct ClassTable {
    counts: Vec<usize>,
    speeds: Vec<f64>,
    mem_caps: Vec<f64>,
    is_acc: Vec<bool>,
    /// First dense device index of each class (accs from 0, CPUs from k).
    offsets: Vec<usize>,
    strides: Vec<usize>,
    slots: usize,
    k: usize,
    best_acc_speed: Option<f64>,
    best_cpu_speed: Option<f64>,
}

impl ClassTable {
    fn from_request(req: &PlanRequest) -> ClassTable {
        let fleet = &req.fleet;
        let mut counts = Vec::new();
        let mut speeds = Vec::new();
        let mut mem_caps = Vec::new();
        let mut is_acc = Vec::new();
        let mut offsets = Vec::new();
        let k = fleet.k();
        let mut acc_off = 0usize;
        let mut cpu_off = k;
        for kind in [DeviceKind::Accelerator, DeviceKind::Cpu] {
            for class in fleet.classes.iter().filter(|c| c.kind == kind) {
                counts.push(class.count);
                speeds.push(class.speed);
                mem_caps.push(class.mem_cap);
                is_acc.push(kind == DeviceKind::Accelerator);
                if kind == DeviceKind::Accelerator {
                    offsets.push(acc_off);
                    acc_off += class.count;
                } else {
                    offsets.push(cpu_off);
                    cpu_off += class.count;
                }
            }
        }
        let mut strides = vec![1usize; counts.len()];
        for c in (0..counts.len().saturating_sub(1)).rev() {
            strides[c] = strides[c + 1] * (counts[c + 1] + 1);
        }
        let slots = counts.iter().map(|&c| c + 1).product::<usize>().max(1);
        ClassTable {
            counts,
            speeds,
            mem_caps,
            is_acc,
            offsets,
            strides,
            slots,
            k,
            best_acc_speed: fleet.best_acc_speed(),
            best_cpu_speed: fleet.best_cpu_speed(),
        }
    }

    fn num_classes(&self) -> usize {
        self.counts.len()
    }
}

/// Per-worker reusable DFS state — allocated once per worker for the whole
/// solve, never per ideal.
struct DpScratch {
    /// Stamped visited array over ideal ids.
    visited: Vec<u32>,
    stamp: u32,
    /// Per graph node: edges from the node into the carved set S.
    in_cnt: Vec<u32>,
    /// Per S-member: predecessors outside S.
    pred_out_cnt: Vec<u32>,
    /// Per outside node: predecessors in S.
    src_cnt: Vec<u32>,
    /// DFS stack: (ideal id, cursor into its subs, node added on entry —
    /// `u32::MAX` for the root frame).
    stack: Vec<(u32, u32, u32)>,
    /// Per-class carved-set load of the current sub-ideal.
    loads: Vec<f64>,
    /// Mixed-radix odometer over the cell block.
    digits: Vec<usize>,
}

impl DpScratch {
    fn new(ni: usize, n: usize, num_classes: usize) -> Self {
        DpScratch {
            visited: vec![0; ni],
            stamp: 0,
            in_cnt: vec![0; n],
            pred_out_cnt: vec![0; n],
            src_cnt: vec![0; n],
            stack: Vec::with_capacity(64),
            loads: vec![0.0; num_classes],
            digits: vec![0; num_classes],
        }
    }
}

/// Relax every cell of one ideal from sub-ideal `sub`, whose carved set
/// costs `loads[c]` on a device of class `c`. Cells are walked in
/// increasing mixed-radix order and classes in dense-class order — for a
/// uniform fleet this is exactly the historical `(k', ℓ')` double loop
/// with the accelerator candidate tried before the CPU one.
#[inline]
fn relax_cells(
    ct: &ClassTable,
    sub: usize,
    done: &[f64],
    loads: &[f64],
    cells: &mut [f64],
    parents: &mut [(u32, u8)],
    digits: &mut [usize],
) {
    digits.iter_mut().for_each(|d| *d = 0);
    for cell in 0..ct.slots {
        for (c, &digit) in digits.iter().enumerate() {
            if digit > 0 {
                let cand = done[sub * ct.slots + cell - ct.strides[c]].max(loads[c]);
                if cand < cells[cell] {
                    cells[cell] = cand;
                    parents[cell] = (sub as u32, c as u8);
                }
            }
        }
        for c in (0..digits.len()).rev() {
            digits[c] += 1;
            if digits[c] <= ct.counts[c] {
                break;
            }
            digits[c] = 0;
        }
    }
}

/// Solve all device-count cells of ideal `i`: DFS down the lattice with
/// incremental subgraph costs and undo, reading only `done` (the dp cells
/// of all smaller-cardinality ideals) and writing only this ideal's
/// `cells`/`parents`.
#[allow(clippy::too_many_arguments)]
fn process_ideal(
    g: &OpGraph,
    req: &PlanRequest,
    ct: &ClassTable,
    lattice: &IdealLattice,
    comm: &[f64],
    bw_comm: &[f64],
    i: IdealId,
    done: &[f64],
    cells: &mut [f64],
    parents: &mut [(u32, u8)],
    scratch: &mut DpScratch,
) {
    let slots = ct.slots;
    debug_assert_eq!(cells.len(), slots);
    let DpScratch { visited, stamp, in_cnt, pred_out_cnt, src_cnt, stack, loads, digits } =
        scratch;
    *stamp = stamp.wrapping_add(1);
    if *stamp == 0 {
        visited.iter_mut().for_each(|v| *v = 0);
        *stamp = 1;
    }
    let stamp = *stamp;
    visited[i] = stamp;
    stack.clear();
    stack.push((i as u32, 0, u32::MAX));

    let full = lattice.ideal(i);
    // incremental S = ideals[i] \ ideals[current]. Unsupported-op costs
    // (p_acc/p_cpu = ∞) are tracked as COUNTS, not summed: `inf - inf`
    // on backtrack would turn the running sums into NaN and silently
    // corrupt every later relaxation of this ideal.
    let mut s_cpu = 0.0_f64;
    let mut s_compute = 0.0_f64;
    let mut s_mem = 0.0_f64;
    let mut s_comm_in = 0.0_f64;
    let mut s_comm_out = 0.0_f64;
    let mut s_bw_in = 0.0_f64;
    let mut s_bw_out = 0.0_f64;
    let mut inf_acc = 0u32;
    let mut inf_cpu = 0u32;

    while let Some(top) = stack.last_mut() {
        let (cur, cursor) = (top.0 as usize, top.1 as usize);
        let subs = lattice.subs(cur);
        if cursor < subs.len() {
            top.1 += 1;
            let (sub32, v32) = subs[cursor];
            let (sub, v) = (sub32 as usize, v32 as usize);
            if visited[sub] == stamp {
                continue;
            }
            visited[sub] = stamp;
            // --- add v to S (incremental cost update) ---
            add_node(
                g, v, full, comm, in_cnt, &mut s_cpu, &mut s_compute, &mut s_mem,
                &mut s_comm_in, &mut s_comm_out, &mut inf_acc, &mut inf_cpu,
            );
            add_bw(g, v, full, bw_comm, pred_out_cnt, src_cnt, &mut s_bw_in, &mut s_bw_out);
            // Prune: both cpu(S) and compute(S) grow monotonically as S
            // grows, and every candidate is ≥ min of them, so once that
            // lower bound exceeds EVERY still-improvable dp cell of this
            // ideal the whole subtree is useless. Cells at (0,0) are
            // never touched by relax; INF cells are always improvable,
            // so any INF cell disables the prune. S depends only on
            // (i, sub), so skipping sub entirely is sound.
            let eff_cpu = if inf_cpu == 0 { s_cpu } else { f64::INFINITY };
            let eff_compute = if inf_acc == 0 { s_compute } else { f64::INFINITY };
            // The lower bound divides by the FASTEST class of each kind —
            // no device can run S cheaper, so the prune stays sound for
            // heterogeneous fleets (uniform: /1.0, bitwise the old bound).
            let lb_acc = match ct.best_acc_speed {
                Some(s) => eff_compute / s,
                None => f64::INFINITY,
            };
            let lb_cpu = match ct.best_cpu_speed {
                Some(s) => eff_cpu / s,
                None => f64::INFINITY,
            };
            let lb = lb_cpu.min(lb_acc);
            let worst_improvable = (1..slots).map(|o| cells[o]).fold(0.0, f64::max);
            if lb >= worst_improvable && worst_improvable.is_finite() {
                // undo and skip subtree
                remove_node(
                    g, v, full, comm, in_cnt, &mut s_cpu, &mut s_compute, &mut s_mem,
                    &mut s_comm_in, &mut s_comm_out, &mut inf_acc, &mut inf_cpu,
                );
                remove_bw(
                    g, v, full, bw_comm, pred_out_cnt, src_cnt, &mut s_bw_in, &mut s_bw_out,
                );
                continue;
            }
            // Per-class carved-set load: class speed scales compute (not
            // comm), class cap bounds memory; CPUs pay compute only.
            for c in 0..ct.num_classes() {
                loads[c] = if ct.is_acc[c] {
                    if inf_acc == 0 && s_mem <= ct.mem_caps[c] {
                        req.combine(
                            s_compute / ct.speeds[c],
                            s_comm_in + s_bw_in,
                            s_comm_out + s_bw_out,
                        )
                    } else {
                        f64::INFINITY
                    }
                } else if inf_cpu == 0 {
                    s_cpu / ct.speeds[c]
                } else {
                    f64::INFINITY
                };
            }
            relax_cells(ct, sub, done, loads, cells, parents, digits);
            stack.push((sub32, 0, v32));
        } else {
            let added = top.2;
            stack.pop();
            if added != u32::MAX {
                let v = added as usize;
                remove_node(
                    g, v, full, comm, in_cnt, &mut s_cpu, &mut s_compute, &mut s_mem,
                    &mut s_comm_in, &mut s_comm_out, &mut inf_acc, &mut inf_cpu,
                );
                remove_bw(
                    g, v, full, bw_comm, pred_out_cnt, src_cnt, &mut s_bw_in, &mut s_bw_out,
                );
            }
        }
    }
    debug_assert!(in_cnt.iter().all(|&c| c == 0));

    // Monotone closure (the S = ∅ transition): a device of any class may
    // be left empty, so every cell is bounded by its one-fewer-device
    // neighbors. Done after the DFS so late improvements propagate.
    digits.iter_mut().for_each(|d| *d = 0);
    for cell in 0..slots {
        for (c, &digit) in digits.iter().enumerate() {
            if digit > 0 {
                let prev = cell - ct.strides[c];
                if cells[prev] < cells[cell] {
                    cells[cell] = cells[prev];
                    parents[cell] = (i as u32, c as u8);
                }
            }
        }
        for c in (0..digits.len()).rev() {
            digits[c] += 1;
            if digits[c] <= ct.counts[c] {
                break;
            }
            digits[c] = 0;
        }
    }
}

/// Legacy scalar form of [`solve_on_lattice_req_opts`] (uniform fleet).
pub fn solve_on_lattice_with_opts(
    g: &OpGraph,
    sc: &Scenario,
    lattice: &IdealLattice,
    bw_comm: &[f64],
    opts: &DpOptions,
) -> Result<(f64, Vec<usize>), DpError> {
    solve_on_lattice_req_opts(g, &sc.to_request(), lattice, bw_comm, opts)
}

/// Run the DP proper over the request's fleet. `bw_comm[v]` is the
/// gradient transfer cost of v's backward partner: billed as bw-out while
/// any pred of v is outside the carved subgraph, and as bw-in to the
/// device holding v's preds (the mirror of the forward boundary). Returns
/// the optimal max-load and a dense device assignment (`0..k` accs in
/// fleet class order, `k..` CPU index `k+j`).
pub fn solve_on_lattice_req_opts(
    g: &OpGraph,
    req: &PlanRequest,
    lattice: &IdealLattice,
    bw_comm: &[f64],
    opts: &DpOptions,
) -> Result<(f64, Vec<usize>), DpError> {
    let ct = ClassTable::from_request(req);
    let slots = ct.slots;
    let ni = lattice.len();

    // Topology-aware comm folding (DESIGN.md §9): the DP folds boundary
    // comm into per-ideal sums before any device identity is known, so a
    // per-pair price cannot be exact here. We charge the conservative
    // worst-pair bound `c · max_slowdown + max_latency` — an upper bound on
    // any realized crossing cost, so DP feasibility/pruning stays sound —
    // and `Prepared::expand_req` re-scores the reconstructed placement with
    // the exact per-pair objective. Without a topology (or with a uniform
    // one) this is `c · 1.0 + 0.0`, bitwise-identical to the raw comm.
    let wcomm: Vec<f64> =
        g.nodes.iter().map(|n| req.fleet.worst_pair_cost(n.comm)).collect();
    let wbw: Vec<f64> = bw_comm.iter().map(|&c| req.fleet.worst_pair_cost(c)).collect();

    let mut dp = vec![f64::INFINITY; ni * slots];
    // parent choice: (sub-ideal id, device class carved onto) per cell
    let mut parent: Vec<(u32, u8)> = vec![(u32::MAX, 0); ni * slots];
    // empty ideal partitions with any device budget at cost 0
    for c in dp[..slots].iter_mut() {
        *c = 0.0;
    }

    let threads = (if opts.threads == 0 { par::num_threads() } else { opts.threads }).max(1);
    // worker scratches are created lazily — a chain-shaped lattice never
    // leaves the sequential path and needs exactly one
    let mut scratches: Vec<DpScratch> = Vec::new();

    for c in 1..lattice.num_layers() {
        let layer = lattice.layer(c);
        let (start, end) = (layer.start, layer.end);
        if start == end {
            continue;
        }
        let layer_len = end - start;
        // all earlier layers are finished: split the table so workers get
        // a shared view of them plus exclusive chunks of this layer
        let (done, rest_dp) = dp.split_at_mut(start * slots);
        let active_dp = &mut rest_dp[..layer_len * slots];
        let active_par = &mut parent[start * slots..end * slots];

        // one worker (inline, no spawn) below the parallel threshold
        let workers =
            if threads == 1 || layer_len < opts.par_threshold { 1 } else { threads.min(layer_len) };
        while scratches.len() < workers {
            scratches.push(DpScratch::new(ni, g.n(), ct.num_classes()));
        }

        let dp_blocks = par::chunk_granular(active_dp, workers, slots);
        let par_blocks = par::chunk_granular(active_par, workers, slots);
        let done_ref: &[f64] = done;
        // per-worker state: (first ideal id of the block, dp chunk, parent
        // chunk, scratch); the id offset is derived from the actual chunk
        // sizes, not re-derived sizing math
        let mut states: Vec<(usize, &mut [f64], &mut [(u32, u8)], &mut DpScratch)> =
            Vec::with_capacity(workers);
        let mut row_off = 0usize;
        let mut scratch_iter = scratches.iter_mut();
        for (dp_blk, par_blk) in dp_blocks.into_iter().zip(par_blocks) {
            let lo = start + row_off;
            row_off += dp_blk.len() / slots;
            let scratch = scratch_iter.next().expect("blocks never exceed workers");
            states.push((lo, dp_blk, par_blk, scratch));
        }
        let ct_ref = &ct;
        par::run_workers(&mut states, |_, (lo, dp_blk, par_blk, scratch)| {
            for (off, (cells, parents)) in
                dp_blk.chunks_mut(slots).zip(par_blk.chunks_mut(slots)).enumerate()
            {
                process_ideal(
                    g, req, ct_ref, lattice, &wcomm, &wbw, *lo + off, done_ref, cells, parents,
                    scratch,
                );
            }
        });
    }

    // the full-budget cell has every digit at its class count: index
    // Σ counts[c]·strides[c] = slots − 1
    let final_cell = lattice.full_id() * slots + (slots - 1);
    if !dp[final_cell].is_finite() {
        return Err(DpError::Infeasible);
    }

    // Reconstruct: walk parents from the full-budget cell, carving device
    // subgraphs; devices within a class are numbered in carve order from
    // the class's dense offset.
    let mut dense = vec![usize::MAX; g.n()];
    let mut digits: Vec<usize> = ct.counts.clone();
    let mut used = vec![0usize; ct.num_classes()];
    let mut i = lattice.full_id();
    while i != lattice.empty_id() {
        let cell: usize = digits.iter().zip(&ct.strides).map(|(d, s)| d * s).sum();
        let (sub, class) = parent[i * slots + cell];
        if sub == u32::MAX {
            break; // dp[∅][·] = 0 seeds have no parent
        }
        let sub = sub as usize;
        let cls = class as usize;
        let s = lattice.difference_bitset(i, sub);
        let device = ct.offsets[cls] + used[cls];
        used[cls] += 1;
        digits[cls] -= 1;
        for v in s.iter() {
            dense[v] = device;
        }
        i = sub;
        if i == lattice.empty_id() {
            break;
        }
    }
    // Any nodes not covered (shouldn't happen) → CPU 0 fallback.
    for d in dense.iter_mut() {
        if *d == usize::MAX {
            *d = ct.k;
        }
    }
    Ok((dp[final_cell], dense))
}

/// Backward-direction comm bookkeeping when v joins S (§5.3 exact costs):
/// v's gradient goes OUT while any of v's preds is outside S; the gradient
/// of an outside node w with a pred in S comes IN (once per w).
#[inline]
#[allow(clippy::too_many_arguments)]
fn add_bw(
    g: &OpGraph,
    v: usize,
    full: IdealRef<'_>,
    bw_comm: &[f64],
    pred_out_cnt: &mut [u32],
    src_cnt: &mut [u32],
    s_bw_in: &mut f64,
    s_bw_out: &mut f64,
) {
    // v enters S: all its preds are currently outside S
    let np = g.preds[v].len() as u32;
    pred_out_cnt[v] = np;
    if np > 0 {
        *s_bw_out += bw_comm[v];
    }
    for &w in &g.succs[v] {
        if full.contains(w) {
            // w ∈ S (succs inside the ideal are in S by maximality): one of
            // w's preds just joined S
            pred_out_cnt[w] -= 1;
            if pred_out_cnt[w] == 0 {
                *s_bw_out -= bw_comm[w];
            }
        } else {
            // w outside the ideal: its gradient now flows into S
            src_cnt[w] += 1;
            if src_cnt[w] == 1 {
                *s_bw_in += bw_comm[w];
            }
        }
    }
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn remove_bw(
    g: &OpGraph,
    v: usize,
    full: IdealRef<'_>,
    bw_comm: &[f64],
    pred_out_cnt: &mut [u32],
    src_cnt: &mut [u32],
    s_bw_in: &mut f64,
    s_bw_out: &mut f64,
) {
    for &w in &g.succs[v] {
        if full.contains(w) {
            if pred_out_cnt[w] == 0 {
                *s_bw_out += bw_comm[w];
            }
            pred_out_cnt[w] += 1;
        } else {
            src_cnt[w] -= 1;
            if src_cnt[w] == 0 {
                *s_bw_in -= bw_comm[w];
            }
        }
    }
    if !g.preds[v].is_empty() {
        *s_bw_out -= bw_comm[v];
    }
    pred_out_cnt[v] = 0;
}

/// Infinite processing times (unsupported ops) are counted, not summed —
/// `∞ - ∞ = NaN` on the undo path would poison the running sums.
#[allow(clippy::too_many_arguments)]
#[inline]
fn add_node(
    g: &OpGraph,
    v: usize,
    full: IdealRef<'_>,
    comm: &[f64],
    in_cnt: &mut [u32],
    s_cpu: &mut f64,
    s_compute: &mut f64,
    s_mem: &mut f64,
    s_comm_in: &mut f64,
    s_comm_out: &mut f64,
    inf_acc: &mut u32,
    inf_cpu: &mut u32,
) {
    if g.nodes[v].p_cpu.is_finite() {
        *s_cpu += g.nodes[v].p_cpu;
    } else {
        *inf_cpu += 1;
    }
    if g.nodes[v].p_acc.is_finite() {
        *s_compute += g.nodes[v].p_acc;
    } else {
        *inf_acc += 1;
    }
    *s_mem += g.nodes[v].mem;
    // v's successors outside the enclosing ideal ⇒ out-comm (fixed per I).
    if g.succs[v].iter().any(|&w| !full.contains(w)) {
        *s_comm_out += comm[v];
    }
    // v stops being an external in-comm contributor.
    if in_cnt[v] > 0 {
        *s_comm_in -= comm[v];
    }
    // v's predecessors become/remain external contributors.
    for &u in &g.preds[v] {
        if in_cnt[u] == 0 {
            *s_comm_in += comm[u];
        }
        in_cnt[u] += 1;
    }
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn remove_node(
    g: &OpGraph,
    v: usize,
    full: IdealRef<'_>,
    comm: &[f64],
    in_cnt: &mut [u32],
    s_cpu: &mut f64,
    s_compute: &mut f64,
    s_mem: &mut f64,
    s_comm_in: &mut f64,
    s_comm_out: &mut f64,
    inf_acc: &mut u32,
    inf_cpu: &mut u32,
) {
    if g.nodes[v].p_cpu.is_finite() {
        *s_cpu -= g.nodes[v].p_cpu;
    } else {
        *inf_cpu -= 1;
    }
    if g.nodes[v].p_acc.is_finite() {
        *s_compute -= g.nodes[v].p_acc;
    } else {
        *inf_acc -= 1;
    }
    *s_mem -= g.nodes[v].mem;
    if g.succs[v].iter().any(|&w| !full.contains(w)) {
        *s_comm_out -= comm[v];
    }
    for &u in &g.preds[v] {
        in_cnt[u] -= 1;
        if in_cnt[u] == 0 {
            *s_comm_in -= comm[u];
        }
    }
    if in_cnt[v] > 0 {
        *s_comm_in += comm[v];
    }
}

// ---------------------------------------------------------------------------
// Shared incremental carve walk (used by replication.rs / hierarchy.rs)
// ---------------------------------------------------------------------------

/// Incrementally-maintained costs of the carved set `S = I \ I'` during a
/// DFS descent of the ideal lattice — the same `O(deg v)`-per-step
/// bookkeeping [`process_ideal`] uses, packaged for the Appendix-C DPs
/// (replication, hierarchy), which previously recomputed every segment's
/// costs from scratch per `(I, I')` pair. Gradient comm is expected to be
/// folded into node `comm` by those callers (their proxy graphs), so no
/// backward-direction tracking is carried here.
#[derive(Debug)]
pub(crate) struct Carve {
    /// `Σ p_cpu` over members with finite CPU cost.
    pub cpu: f64,
    /// `Σ p_acc` over members with finite accelerator cost.
    pub compute: f64,
    /// `Σ mem` over members.
    pub mem: f64,
    /// External-producer in-communication of `S` (each producer once).
    pub comm_in: f64,
    /// Out-communication of `S` (members with a successor outside `S`).
    pub comm_out: f64,
    /// Members with `p_cpu = ∞` (counted, not summed — see the NaN note in
    /// the module docs).
    pub inf_cpu: u32,
    /// Members with `p_acc = ∞`.
    pub inf_acc: u32,
    /// Members of `S` in DFS-addition order (the current descent path).
    pub members: Vec<usize>,
}

impl Carve {
    /// `cpu(S)` with unsupported-op propagation.
    pub fn cpu_load(&self) -> f64 {
        if self.inf_cpu == 0 {
            self.cpu
        } else {
            f64::INFINITY
        }
    }

    /// The §5.1.1 sequential accelerator load `acc(S)` = in-comm + compute
    /// + out-comm, `∞` when over `mem_cap` or accelerator-unsupported
    /// (matches [`OpGraph::acc_load`] on the same set).
    pub fn acc_load(&self, mem_cap: f64) -> f64 {
        if self.inf_acc != 0 || self.mem > mem_cap {
            f64::INFINITY
        } else {
            self.compute + self.comm_in + self.comm_out
        }
    }
}

/// Reusable DFS state for [`CarveWalker::walk`]; allocate once per solve.
pub(crate) struct CarveWalker {
    visited: Vec<u32>,
    stamp: u32,
    in_cnt: Vec<u32>,
    stack: Vec<(u32, u32, u32)>,
    carve: Carve,
}

impl CarveWalker {
    pub fn new(num_ideals: usize, n: usize) -> Self {
        CarveWalker {
            visited: vec![0; num_ideals],
            stamp: 0,
            in_cnt: vec![0; n],
            stack: Vec::with_capacity(64),
            carve: Carve {
                cpu: 0.0,
                compute: 0.0,
                mem: 0.0,
                comm_in: 0.0,
                comm_out: 0.0,
                inf_cpu: 0,
                inf_acc: 0,
                members: Vec::with_capacity(64),
            },
        }
    }

    /// DFS down the lattice from ideal `i`, visiting `i` itself first
    /// (`S = ∅`) and then every proper sub-ideal `I' ⊂ I` exactly once,
    /// with [`Carve`] holding the incremental costs of `S = I \ I'` at each
    /// visit. `f(sub_id, &carve)` returns `false` to prune the entire
    /// lattice subtree below that sub-ideal (sound whenever the caller's
    /// bound grows monotonically with `S`, e.g. compute or memory sums).
    ///
    /// `comm` is the per-node boundary price the walk folds into
    /// `comm_in`/`comm_out` — callers that run under a device topology pass
    /// worst-pair-scaled costs (`fleet.worst_pair_cost(node.comm)`, see
    /// DESIGN.md §9); raw `node.comm` reproduces the legacy scalar model.
    pub fn walk<F>(
        &mut self,
        g: &OpGraph,
        lattice: &IdealLattice,
        comm: &[f64],
        i: IdealId,
        mut f: F,
    ) where
        F: FnMut(IdealId, &Carve) -> bool,
    {
        let CarveWalker { visited, stamp, in_cnt, stack, carve } = self;
        // Fresh sums every walk: interleaved f64 add/undo is not exactly
        // invertible (fl(fl(a+b)-b) ≠ a in general), so the residue of one
        // walk must not become the next walk's S = ∅ baseline — over the
        // `for i in 1..ni` loops of the Appendix-C DPs that drift would
        // compound into every segment cost. (`in_cnt` is exact integer
        // bookkeeping and provably returns to zero; see the debug_assert.)
        carve.cpu = 0.0;
        carve.compute = 0.0;
        carve.mem = 0.0;
        carve.comm_in = 0.0;
        carve.comm_out = 0.0;
        carve.inf_cpu = 0;
        carve.inf_acc = 0;
        carve.members.clear();
        *stamp = stamp.wrapping_add(1);
        if *stamp == 0 {
            visited.iter_mut().for_each(|v| *v = 0);
            *stamp = 1;
        }
        let stamp = *stamp;
        visited[i] = stamp;
        if !f(i, carve) {
            return;
        }
        stack.clear();
        stack.push((i as u32, 0, u32::MAX));
        let full = lattice.ideal(i);

        while let Some(top) = stack.last_mut() {
            let (cur, cursor) = (top.0 as usize, top.1 as usize);
            let subs = lattice.subs(cur);
            if cursor < subs.len() {
                top.1 += 1;
                let (sub32, v32) = subs[cursor];
                let (sub, v) = (sub32 as usize, v32 as usize);
                if visited[sub] == stamp {
                    continue;
                }
                visited[sub] = stamp;
                add_node(
                    g,
                    v,
                    full,
                    comm,
                    in_cnt,
                    &mut carve.cpu,
                    &mut carve.compute,
                    &mut carve.mem,
                    &mut carve.comm_in,
                    &mut carve.comm_out,
                    &mut carve.inf_acc,
                    &mut carve.inf_cpu,
                );
                carve.members.push(v);
                if f(sub, carve) {
                    stack.push((sub32, 0, v32));
                } else {
                    // prune: undo v and skip the whole subtree below sub
                    remove_node(
                        g,
                        v,
                        full,
                        comm,
                        in_cnt,
                        &mut carve.cpu,
                        &mut carve.compute,
                        &mut carve.mem,
                        &mut carve.comm_in,
                        &mut carve.comm_out,
                        &mut carve.inf_acc,
                        &mut carve.inf_cpu,
                    );
                    carve.members.pop();
                }
            } else {
                let added = top.2;
                stack.pop();
                if added != u32::MAX {
                    let v = added as usize;
                    remove_node(
                        g,
                        v,
                        full,
                        comm,
                        in_cnt,
                        &mut carve.cpu,
                        &mut carve.compute,
                        &mut carve.mem,
                        &mut carve.comm_in,
                        &mut carve.comm_out,
                        &mut carve.inf_acc,
                        &mut carve.inf_cpu,
                    );
                    carve.members.pop();
                }
            }
        }
        debug_assert!(carve.members.is_empty());
        debug_assert!(in_cnt.iter().all(|&c| c == 0));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn chain_g(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(10.0).acc(1.0).mem(1.0).comm(0.1));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn single_accelerator_takes_all() {
        let g = chain_g(4);
        let sc = Scenario::new(1, 1, f64::INFINITY);
        let p = solve(&g, &sc).unwrap();
        // CPU is 10x slower: optimum is everything on the accelerator, 4.0
        assert!((p.objective - 4.0).abs() < 1e-9, "{}", p.objective);
        assert!(p.assignment.iter().all(|d| d.is_acc()));
        p.validate(&g, &sc, true).unwrap();
    }

    #[test]
    fn two_accelerators_balance() {
        let g = chain_g(4);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = solve(&g, &sc).unwrap();
        // split 2/2: load = 2 + boundary comm 0.1 = 2.1
        assert!((p.objective - 2.1).abs() < 1e-9, "{}", p.objective);
        p.validate(&g, &sc, true).unwrap();
    }

    #[test]
    fn memory_cap_forces_split() {
        let g = chain_g(4);
        let sc = Scenario::new(2, 1, 2.0);
        let p = solve(&g, &sc).unwrap();
        p.validate(&g, &sc, true).unwrap();
        assert!((p.objective - 2.1).abs() < 1e-9);
        // k=1 with cap 2 can't fit all 4 nodes on acc; 2 must go to CPU
        let sc1 = Scenario::new(1, 1, 2.0);
        let p1 = solve(&g, &sc1).unwrap();
        p1.validate(&g, &sc1, true).unwrap();
        assert!((p1.objective - 20.0).abs() < 1e-9, "{}", p1.objective);
    }

    #[test]
    fn infeasible_when_no_cpu_and_no_memory() {
        let mut g = chain_g(2);
        g.nodes[0].p_cpu = f64::INFINITY;
        g.nodes[1].p_cpu = f64::INFINITY;
        let sc = Scenario::new(1, 0, 1.0); // only 1 node fits
        assert!(matches!(solve(&g, &sc), Err(DpError::Infeasible)));
    }

    #[test]
    fn matches_brute_force_on_small_dags() {
        use crate::util::proptest::random_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xD9);
        for case in 0..30 {
            let g = random_dag(&mut rng, 6, 0.35);
            let sc = Scenario::new(2, 1, 4.0);
            let dp = solve(&g, &sc);
            let bf = brute_force_contiguous(&g, &sc);
            match (dp, bf) {
                (Ok(p), Some(best)) => {
                    assert!(
                        (p.objective - best).abs() < 1e-6,
                        "case {case}: dp={} bf={best}",
                        p.objective
                    );
                    p.validate(&g, &sc, true).unwrap();
                }
                (Err(DpError::Infeasible), None) => {}
                (dp, bf) => panic!("case {case}: dp={dp:?} bf={bf:?} disagree on feasibility"),
            }
        }
    }

    /// Brute force over the DP's exact search space: partitions whose
    /// device condensation is acyclic (pipeline-orderable ⇔ expressible as
    /// a chain of ideals; per-device contiguity follows automatically).
    fn brute_force_contiguous(g: &OpGraph, sc: &Scenario) -> Option<f64> {
        let nd = sc.k + sc.l;
        let n = g.n();
        let mut best: Option<f64> = None;
        let mut assign = vec![0usize; n];
        loop {
            let placement = Placement::new(
                assign.iter().map(|&d| Device::from_index(d, sc.k)).collect(),
                0.0,
                "bf",
            );
            let orderable =
                crate::graph::contiguity::partition_pipeline_orderable(g, &assign, nd);
            if orderable && placement.validate(g, sc, false).is_ok() {
                let obj = objective::max_load(g, sc, &placement);
                if obj.is_finite() {
                    best = Some(best.map_or(obj, |b: f64| b.min(obj)));
                }
            }
            // increment base-nd counter
            let mut i = 0;
            loop {
                if i == n {
                    return best;
                }
                assign[i] += 1;
                if assign[i] < nd {
                    break;
                }
                assign[i] = 0;
                i += 1;
            }
        }
    }

    #[test]
    fn training_graph_colocates_fw_bw() {
        use crate::util::proptest::random_training_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let g = random_training_dag(&mut rng, 6, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = solve(&g, &sc).unwrap();
        p.check_colocation(&g).unwrap();
        p.check_contiguity(&g, &sc).unwrap();
        assert!(p.objective.is_finite());
    }

    #[test]
    fn parallel_branches_use_both_accelerators() {
        // two heavy independent chains share a source/sink; two accs should
        // each take one branch
        let mut g = OpGraph::new();
        let s = g.add_node(Node::new("src").cpu(0.1).acc(0.1).comm(0.01));
        let mut last_a = s;
        let mut last_b = s;
        for i in 0..3 {
            let a = g.add_node(Node::new(format!("a{i}")).cpu(50.0).acc(5.0).comm(0.01));
            g.add_edge(last_a, a);
            last_a = a;
            let b = g.add_node(Node::new(format!("b{i}")).cpu(50.0).acc(5.0).comm(0.01));
            g.add_edge(last_b, b);
            last_b = b;
        }
        let t = g.add_node(Node::new("sink").cpu(0.1).acc(0.1).comm(0.01));
        g.add_edge(last_a, t);
        g.add_edge(last_b, t);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = solve(&g, &sc).unwrap();
        p.validate(&g, &sc, true).unwrap();
        // perfect balance would be ~15.2; one acc doing both branches ~30
        assert!(p.objective < 20.0, "objective {}", p.objective);
    }

    #[test]
    fn infinite_costs_do_not_poison_incremental_sums() {
        // Diamond 0->{1,2}->3 where node 1 is accelerator-unsupported: the
        // DFS adds node 1 (∞ acc cost) and backtracks before carving {2};
        // a naive `∞ - ∞` undo leaves NaN and loses that transition. The
        // optimum must still match brute force.
        let mut g = OpGraph::new();
        for i in 0..4 {
            g.add_node(Node::new(format!("n{i}")).cpu(4.0).acc(1.0).mem(1.0).comm(0.1));
        }
        g.nodes[1].p_acc = f64::INFINITY;
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = solve(&g, &sc).unwrap();
        p.validate(&g, &sc, true).unwrap();
        let bf = brute_force_contiguous(&g, &sc).unwrap();
        assert!((p.objective - bf).abs() < 1e-9, "dp={} bf={bf}", p.objective);
        // and the CPU-side mirror: node 1 CPU-unsupported instead
        let mut g2 = g.clone();
        g2.nodes[1].p_acc = 1.0;
        g2.nodes[1].p_cpu = f64::INFINITY;
        let p2 = solve(&g2, &sc).unwrap();
        p2.validate(&g2, &sc, true).unwrap();
        let bf2 = brute_force_contiguous(&g2, &sc).unwrap();
        assert!((p2.objective - bf2).abs() < 1e-9, "dp={} bf={bf2}", p2.objective);
    }

    #[test]
    fn heterogeneous_speed_balances_by_effective_load() {
        use crate::coordinator::placement::{DeviceClass, Fleet, PlanRequest};
        // chain of 4, zero comm: a 3x-fast accelerator should take 3 nodes
        // (load 1) while the slow one takes 1 (load 1) → objective 1.0;
        // uniform devices could do no better than 2.0.
        let mut g = OpGraph::new();
        for i in 0..4 {
            g.add_node(Node::new(format!("c{i}")).cpu(100.0).acc(1.0).mem(1.0).comm(0.0));
        }
        for i in 1..4 {
            g.add_edge(i - 1, i);
        }
        let req = PlanRequest::new(Fleet::new(vec![
            DeviceClass::acc("fast", 1, f64::INFINITY).speed(3.0),
            DeviceClass::acc("slow", 1, f64::INFINITY),
            DeviceClass::cpu("cpu", 1),
        ]));
        let p = solve_req(&g, &req).unwrap();
        assert!((p.objective - 1.0).abs() < 1e-9, "{}", p.objective);
        p.validate_req(&g, &req).unwrap();
        let uniform = solve(&g, &Scenario::new(2, 1, f64::INFINITY)).unwrap();
        assert!((uniform.objective - 2.0).abs() < 1e-9, "{}", uniform.objective);
    }

    #[test]
    fn per_class_memory_caps_respected() {
        use crate::coordinator::placement::{Device, DeviceClass, Fleet, PlanRequest};
        // chain of 4, 1 MB each, no CPU escape: big (cap 3) must take 3
        // nodes, small (cap 1) exactly one.
        let mut g = OpGraph::new();
        for i in 0..4 {
            g.add_node(Node::new(format!("c{i}")).cpu(f64::INFINITY).acc(1.0).mem(1.0).comm(0.0));
        }
        for i in 1..4 {
            g.add_edge(i - 1, i);
        }
        let req = PlanRequest::new(Fleet::new(vec![
            DeviceClass::acc("big", 1, 3.0),
            DeviceClass::acc("small", 1, 1.0),
        ]));
        let p = solve_req(&g, &req).unwrap();
        p.validate_req(&g, &req).unwrap();
        // the 3-node side must be on the big device (dense index 0)
        let on_big = p.set_of(Device::Acc(0), 4).len();
        let on_small = p.set_of(Device::Acc(1), 4).len();
        assert_eq!((on_big, on_small), (3, 1));
        // a cap that cannot hold the model at all is infeasible
        let tight = PlanRequest::new(Fleet::new(vec![
            DeviceClass::acc("a", 1, 1.0),
            DeviceClass::acc("b", 1, 1.0),
        ]));
        assert!(matches!(solve_req(&g, &tight), Err(DpError::Infeasible)));
    }

    #[test]
    fn heterogeneous_matches_brute_force_on_small_dags() {
        use crate::coordinator::placement::{DeviceClass, Fleet, PlanRequest};
        use crate::util::proptest::random_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xF1EE7);
        let req = PlanRequest::new(Fleet::new(vec![
            DeviceClass::acc("fast", 1, 4.0).speed(2.0),
            DeviceClass::acc("slow", 1, 5.0),
            DeviceClass::cpu("cpu", 1),
        ]));
        for case in 0..15 {
            let g = random_dag(&mut rng, 6, 0.35);
            let dp = solve_req(&g, &req);
            let bf = brute_force_req(&g, &req);
            match (dp, bf) {
                (Ok(p), Some(best)) => {
                    assert!(
                        (p.objective - best).abs() < 1e-6,
                        "case {case}: dp={} bf={best}",
                        p.objective
                    );
                    p.validate_req(&g, &req).unwrap();
                }
                (Err(DpError::Infeasible), None) => {}
                (dp, bf) => panic!("case {case}: dp={dp:?} bf={bf:?} disagree on feasibility"),
            }
        }
    }

    /// Heterogeneous analogue of [`brute_force_contiguous`]: exhaustive
    /// over pipeline-orderable partitions, scored by the fleet evaluator.
    fn brute_force_req(g: &OpGraph, req: &PlanRequest) -> Option<f64> {
        let k = req.fleet.k();
        let nd = req.fleet.num_devices();
        let n = g.n();
        let mut best: Option<f64> = None;
        let mut assign = vec![0usize; n];
        loop {
            let placement = Placement::new(
                assign.iter().map(|&d| Device::from_index(d, k)).collect(),
                0.0,
                "bf",
            );
            let orderable =
                crate::graph::contiguity::partition_pipeline_orderable(g, &assign, nd);
            let mut relaxed = req.clone();
            relaxed.contiguous = false;
            if orderable && placement.validate_req(g, &relaxed).is_ok() {
                let obj = objective::max_load_req(g, req, &placement);
                if obj.is_finite() {
                    best = Some(best.map_or(obj, |b: f64| b.min(obj)));
                }
            }
            let mut i = 0;
            loop {
                if i == n {
                    return best;
                }
                assign[i] += 1;
                if assign[i] < nd {
                    break;
                }
                assign[i] = 0;
                i += 1;
            }
        }
    }

    #[test]
    fn carve_walker_costs_match_direct_recompute() {
        use crate::util::proptest::random_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xCA77);
        for _ in 0..10 {
            let g = random_dag(&mut rng, 8, 0.3);
            let lattice = IdealLattice::enumerate(&g, usize::MAX).unwrap();
            let comm: Vec<f64> = g.nodes.iter().map(|n| n.comm).collect();
            let mut walker = CarveWalker::new(lattice.len(), g.n());
            for i in 0..lattice.len() {
                walker.walk(&g, &lattice, &comm, i, |sub, c| {
                    let s = lattice.difference_bitset(i, sub);
                    assert_eq!(c.members.len(), s.len(), "member count for ({i},{sub})");
                    let cpu = g.cpu_load(&s);
                    let acc = g.acc_load(&s, f64::INFINITY);
                    assert!(
                        (c.cpu_load() - cpu).abs() < 1e-9,
                        "cpu({i},{sub}): walker {} vs direct {cpu}",
                        c.cpu_load()
                    );
                    assert!(
                        (c.acc_load(f64::INFINITY) - acc).abs() < 1e-9,
                        "acc({i},{sub}): walker {} vs direct {acc}",
                        c.acc_load(f64::INFINITY)
                    );
                    assert!((c.mem - g.mem_of(&s)).abs() < 1e-9);
                    true
                });
            }
        }
    }

    #[test]
    fn forced_parallel_matches_sequential() {
        use crate::util::proptest::random_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xDA1);
        for _ in 0..5 {
            let g = random_dag(&mut rng, 9, 0.25);
            let lattice = IdealLattice::enumerate(&g, usize::MAX).unwrap();
            let sc = Scenario::new(2, 1, f64::INFINITY);
            let zeros = vec![0.0; g.n()];
            let seq = solve_on_lattice_with_opts(
                &g, &sc, &lattice, &zeros,
                &DpOptions { threads: 1, par_threshold: usize::MAX },
            );
            let park = solve_on_lattice_with_opts(
                &g, &sc, &lattice, &zeros,
                &DpOptions { threads: 4, par_threshold: 1 },
            );
            match (seq, park) {
                (Ok((a, da)), Ok((b, db))) => {
                    assert_eq!(a.to_bits(), b.to_bits(), "objective must be bitwise equal");
                    assert_eq!(da, db, "assignments must be identical");
                }
                (Err(_), Err(_)) => {}
                (a, b) => panic!("parallelism changed feasibility: {a:?} vs {b:?}"),
            }
        }
    }
}

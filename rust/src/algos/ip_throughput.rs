//! Integer Programming for throughput maximization (Fig. 6, §5.1.3/§5.2).
//!
//! Two interchangeable engines, both exact:
//!
//! * [`build_model`] emits the *literal* Fig.-6 MILP (binary `x_vi`,
//!   `CommIn/CommOut`, the Lemma-4.1 `z`-variable linearization of the
//!   contiguity constraint (16), per-device loads and the `MaxLoad`
//!   objective) for the LP-based branch-and-bound in [`crate::solver`].
//!   The dense simplex limits this path to small instances; it serves as
//!   the executable specification and cross-check.
//! * [`solve`] is a specialized combinatorial branch-and-bound over
//!   node→device assignments in topological order with incremental load
//!   bookkeeping, reachability-based contiguity propagation, device-
//!   symmetry breaking, a work/devices lower bound, DP warm start, and a
//!   node-move polish pass (the "primal heuristic") — this scales to the
//!   paper's workload sizes and natively supports the non-contiguous
//!   setting of §5.2 by dropping the contiguity check.

use super::dp::DpError;
use super::{objective, PlaceError};
use crate::coordinator::context::{ProblemCtx, SolveBudget};
use crate::coordinator::placement::{Device, Placement, PlanRequest, Scenario};
use crate::graph::OpGraph;
use crate::solver::lp::{Lp, Sense};
use crate::solver::milp::{Milp, SolveStatus};
use crate::util::arena::BitMatrix;
use crate::util::bitset::BitSet;
use std::time::{Duration, Instant};

/// Options for the specialized search.
#[derive(Clone, Debug)]
pub struct IpOptions {
    pub time_limit: Duration,
    /// Stop once the proven gap is below this (paper uses 1%).
    pub gap_target: f64,
    /// Enforce Def.-3.1 contiguity on every device (constraint (16)).
    pub contiguous: bool,
    /// Run the node-move polish on the incumbent (primal heuristic).
    pub polish: bool,
    /// Prior incumbent `(objective, dense dp_graph assignment)` to resume
    /// from — a previous [`IpResult::incumbent`] of the *same* problem and
    /// contiguity regime. Injected on top of the DP warm start, and only
    /// when strictly better than it, so seeding is monotone: the search
    /// never returns a worse objective than a cold run.
    pub warm_seed: Option<(f64, Vec<usize>)>,
    /// Cooperative cancellation: an absolute deadline that clamps
    /// `time_limit` and/or a deterministic cap on branch-and-bound nodes.
    /// [`SolveBudget::UNLIMITED`] (the default) is bitwise-invisible — the
    /// search takes exactly the pre-budget path.
    pub budget: SolveBudget,
}

impl Default for IpOptions {
    fn default() -> Self {
        IpOptions {
            time_limit: Duration::from_secs(20),
            gap_target: 0.01,
            contiguous: true,
            polish: true,
            warm_seed: None,
            budget: SolveBudget::UNLIMITED,
        }
    }
}

/// Result: a placement plus the solver's proof state.
#[derive(Clone, Debug)]
pub struct IpResult {
    pub placement: Placement,
    pub status: SolveStatus,
    /// Proven lower bound on the optimum (on the preprocessed cost model).
    pub bound: f64,
    pub gap: f64,
    pub nodes_explored: usize,
    pub elapsed: Duration,
    /// Time at which the final incumbent was found (the paper's
    /// parenthesized asterisk column).
    pub incumbent_at: Duration,
    /// The final search incumbent `(objective, dense dp_graph assignment)`
    /// in the space the branch-and-bound assigns over — resumable via
    /// [`IpOptions::warm_seed`]. (The placement's `objective` is re-scored
    /// on the original graph and may differ from this proxy value.)
    pub incumbent: (f64, Vec<usize>),
    /// True when the caller's [`IpOptions::budget`] (deadline or node
    /// limit) cut the search short — the anytime signal, distinct from the
    /// engine's own `time_limit` expiring.
    pub truncated: bool,
}

/// Solve the Fig.-6 IP with the specialized branch-and-bound.
///
/// Deprecated thin wrapper: builds a one-shot [`ProblemCtx`] (warm-start
/// lattice capped at 20k ideals, as before) and forwards to [`solve_ctx`].
/// Prefer [`solve_ctx`] over a shared context — the preprocessing,
/// reachability matrices and DP warm start are then computed once per
/// `(graph, scenario)` instead of per call.
pub fn solve(g: &OpGraph, sc: &Scenario, opts: &IpOptions) -> Result<IpResult, DpError> {
    let ctx = ProblemCtx::with_cap(g.clone(), sc.clone(), 20_000);
    solve_ctx(&ctx, opts)
}

/// [`solve`] over a heterogeneous [`PlanRequest`] fleet (one-shot context).
pub fn solve_req(
    g: &OpGraph,
    req: &PlanRequest,
    opts: &IpOptions,
) -> Result<IpResult, DpError> {
    let ctx = ProblemCtx::from_request_with_cap(g.clone(), req.clone(), 20_000);
    solve_ctx(&ctx, opts)
}

/// [`solve`] against a shared analysis context: the search reads the
/// preprocessed proxy graph, topological order, reachability rows and the
/// DP/DPL warm start from `ctx` (each computed at most once per context).
pub fn solve_ctx(ctx: &ProblemCtx, opts: &IpOptions) -> Result<IpResult, PlaceError> {
    let g = ctx.graph();
    let req = ctx.request();
    let prepared = ctx.prepared()?;
    // search cost model: dp_graph with the gradient comm folded into node
    // comm (the PipeDream-style proxy); the final incumbent is re-scored
    // on the original graph by `Prepared::expand`
    let gg = ctx.proxy()?;
    let order = ctx.dp_order()?;
    let reach = ctx.dp_reach()?;
    let co_reach = ctx.dp_co_reach()?;

    // Warm start (any optimal contiguous split is feasible for both IP
    // variants): the context's memoized cheap warm start — the cached DP
    // solution when affordable, a 20k-capped DP / DPL otherwise (see
    // `ProblemCtx::warm_solution`). Computed once per context, so IP-only
    // replanning hits the cache too.
    let warm = ctx.warm_solution().ok().cloned();

    let mut search = Search::new(gg, req, opts.clone(), order, reach, co_reach);
    if let Some((obj, dense)) = warm {
        search.incumbent = Some((obj, dense));
        search.incumbent_at = Duration::ZERO;
    }
    // Resume seed (the concurrent service's incumbent cache): a prior
    // run's final incumbent of this exact problem + regime. Strictly-
    // better-only, so a cold run's result is a floor, never a ceiling.
    if let Some((obj, dense)) = &opts.warm_seed {
        if dense.len() == gg.n()
            && search.incumbent.as_ref().is_none_or(|(best, _)| *obj < *best)
        {
            search.incumbent = Some((*obj, dense.clone()));
            search.incumbent_at = Duration::ZERO;
        }
    }
    search.run();
    search.flush_obs();

    let (obj, dense) = match search.incumbent.clone() {
        Some(inc) => inc,
        // a truncated empty search proved nothing — report the budget, not
        // a (false) infeasibility claim
        None if !search.complete => return Err(PlaceError::NoIncumbent),
        None => return Err(PlaceError::Infeasible),
    };
    let mut placement = prepared.expand_req(g, req, obj, &dense);
    placement.algorithm = if opts.contiguous {
        "IP (contiguous)".into()
    } else {
        "IP (non-contiguous)".into()
    };
    let gap = ((placement.objective - search.best_bound) / placement.objective.max(1e-12)).max(0.0);
    Ok(IpResult {
        status: search.status,
        bound: search.best_bound,
        gap,
        nodes_explored: search.nodes,
        elapsed: search.start.elapsed(),
        incumbent_at: search.incumbent_at,
        incumbent: (obj, dense),
        truncated: search.budget_hit,
        placement,
    })
}

// ---------------------------------------------------------------------------
// Specialized branch & bound
// ---------------------------------------------------------------------------

struct DeviceState {
    compute: f64,
    mem: f64,
    comm_in: f64,
    comm_out: f64,
    set: BitSet,
    /// Union of `reach[u]` over members u (for contiguity propagation).
    reach: BitSet,
    /// External producers already charged to this device's comm_in.
    in_paid: BitSet,
}

struct Search<'a> {
    g: &'a OpGraph,
    req: &'a PlanRequest,
    /// Total accelerator count (dense devices `0..k` are accelerators).
    k: usize,
    /// Per dense device: its class's memory cap (∞ for CPU devices).
    mem_cap: Vec<f64>,
    /// Per dense device: its class's relative speed.
    speed: Vec<f64>,
    /// Per dense device: class index (for empty-device symmetry breaking —
    /// only devices of the SAME class are interchangeable).
    class_of: Vec<usize>,
    opts: IpOptions,
    order: &'a [usize],
    /// Reachability rows in one flat allocation (`reach.row(u)` =
    /// descendants of u) — borrowed from the shared context.
    reach: &'a BitMatrix,
    co_reach: &'a BitMatrix,
    /// min(p_acc, p_cpu) suffix sums along `order` for the work bound.
    suffix_min_work: Vec<f64>,
    devices: Vec<DeviceState>,
    assignment: Vec<usize>,
    assigned: BitSet,
    /// Running worst-destination egress price charged per producer (0.0 =
    /// no crossing yet) — the incremental form of the evaluator's
    /// max-over-destinations egress under per-pair topology pricing.
    /// Without a topology every crossing prices at `comm`, so this
    /// degenerates to the old pay-once boolean bitwise.
    out_cost: Vec<f64>,
    /// Shared undo stacks with watermarks — no per-node-expansion `Vec`s.
    /// Entries carry the exact charged amounts so undo subtracts the same
    /// value it added (per-pair prices aren't reconstructible later).
    undo_in: Vec<(usize, f64)>,
    /// `(producer, previous out_cost, comm_out delta charged)`.
    undo_out: Vec<(usize, f64, f64)>,
    /// Reused word scratch for the contiguity check / reach rebuild.
    mid_scratch: Vec<u64>,
    reach_scratch: Vec<u64>,
    incumbent: Option<(f64, Vec<usize>)>,
    incumbent_at: Duration,
    best_bound: f64,
    nodes: usize,
    status: SolveStatus,
    start: Instant,
    /// Effective cutoff: `start + time_limit` clamped by the budget's
    /// deadline (identical to the former `start + time_limit` when no
    /// budget is set).
    deadline: Instant,
    /// `start + time_limit` alone — `deadline < own_deadline` means the
    /// caller's budget, not the engine's limit, is the binding cutoff.
    own_deadline: Instant,
    /// Deterministic node cap from the budget (`u64::MAX` = none).
    node_cap: u64,
    /// Set when the budget (deadline or node cap) stopped the search.
    budget_hit: bool,
    complete: bool,
    /// Search telemetry (plain fields bumped in the hot loop, flushed to
    /// the obs registry once per solve — DESIGN.md §10). Never read by
    /// the search itself, so recording is bitwise-invisible to results.
    prune_bound: usize,
    prune_memory: usize,
    prune_contiguity: usize,
    /// `(when, objective)` per incumbent improvement — the timeline that
    /// makes warm-start wins visible as `ip.incumbent` trace instants.
    incumbent_log: Vec<(Duration, f64)>,
}

impl<'a> Search<'a> {
    fn new(
        g: &'a OpGraph,
        req: &'a PlanRequest,
        opts: IpOptions,
        order: &'a [usize],
        reach: &'a BitMatrix,
        co_reach: &'a BitMatrix,
    ) -> Self {
        let stride = reach.stride();
        let fleet = &req.fleet;
        let k = fleet.k();
        // the one fleet→dense-device mapping (shared with the latency IP
        // and the evaluators' per-index accessors)
        let dense = fleet.dense_view();
        let nd = dense.len();
        let mem_cap: Vec<f64> = dense.iter().map(|d| d.mem_cap).collect();
        let speed: Vec<f64> = dense.iter().map(|d| d.speed).collect();
        let class_of: Vec<usize> = dense.iter().map(|d| d.class).collect();
        // work lower bound divides by the fastest class of each kind: no
        // device can run a node cheaper (uniform fleets: /1.0, the old
        // bound bitwise)
        let best_acc = fleet.best_acc_speed().unwrap_or(f64::NAN);
        let best_cpu = fleet.best_cpu_speed().unwrap_or(f64::NAN);
        let cheapest = |v: usize| -> f64 {
            let a =
                if best_acc.is_nan() { f64::INFINITY } else { g.nodes[v].p_acc / best_acc };
            let c =
                if best_cpu.is_nan() { f64::INFINITY } else { g.nodes[v].p_cpu / best_cpu };
            a.min(c)
        };
        let mut suffix = vec![0.0; order.len() + 1];
        for (pos, &v) in order.iter().enumerate().rev() {
            suffix[pos] = suffix[pos + 1] + cheapest(v);
        }
        let root_bound = if nd > 0 { suffix[0] / nd as f64 } else { f64::INFINITY };
        let start = Instant::now();
        Search {
            g,
            req,
            k,
            mem_cap,
            speed,
            class_of,
            deadline: opts.budget.clamp_deadline(start, opts.time_limit),
            own_deadline: start + opts.time_limit,
            node_cap: opts.budget.node_limit.unwrap_or(u64::MAX),
            budget_hit: false,
            opts,
            reach,
            co_reach,
            suffix_min_work: suffix,
            devices: (0..nd)
                .map(|_| DeviceState {
                    compute: 0.0,
                    mem: 0.0,
                    comm_in: 0.0,
                    comm_out: 0.0,
                    set: BitSet::new(g.n()),
                    reach: BitSet::new(g.n()),
                    in_paid: BitSet::new(g.n()),
                })
                .collect(),
            assignment: vec![usize::MAX; g.n()],
            assigned: BitSet::new(g.n()),
            out_cost: vec![0.0; g.n()],
            undo_in: Vec::with_capacity(64),
            undo_out: Vec::with_capacity(64),
            mid_scratch: vec![0; stride],
            reach_scratch: vec![0; stride],
            incumbent: None,
            incumbent_at: Duration::ZERO,
            best_bound: root_bound,
            nodes: 0,
            status: SolveStatus::Unknown,
            start,
            order,
            complete: true,
            prune_bound: 0,
            prune_memory: 0,
            prune_contiguity: 0,
            incumbent_log: Vec::new(),
        }
    }

    /// Push the per-solve telemetry into the obs registry: counters
    /// always, the incumbent timeline as trace instants only while
    /// recording is enabled. Called once after `run()` — nothing here
    /// touches the hot loop beyond the plain field bumps.
    fn flush_obs(&self) {
        crate::obs::counter("ip_nodes_explored_total").add(self.nodes as u64);
        crate::obs::counter("ip_prunes_total{reason=\"bound\"}").add(self.prune_bound as u64);
        crate::obs::counter("ip_prunes_total{reason=\"memory\"}").add(self.prune_memory as u64);
        crate::obs::counter("ip_prunes_total{reason=\"contiguity\"}")
            .add(self.prune_contiguity as u64);
        crate::obs::counter("ip_incumbent_updates_total").add(self.incumbent_log.len() as u64);
        if crate::obs::is_enabled() {
            let start_us = crate::obs::now_us() - self.start.elapsed().as_secs_f64() * 1e6;
            for (at, obj) in &self.incumbent_log {
                crate::obs::instant_at(
                    "ip.incumbent",
                    "ip",
                    start_us + at.as_secs_f64() * 1e6,
                    vec![
                        ("objective".to_string(), crate::util::json::Json::num(*obj)),
                        (
                            "at_ms".to_string(),
                            crate::util::json::Json::num(at.as_secs_f64() * 1e3),
                        ),
                    ],
                );
            }
        }
    }

    fn device_load(&self, d: usize) -> f64 {
        let ds = &self.devices[d];
        if d < self.k {
            self.req.combine(ds.compute, ds.comm_in, ds.comm_out)
        } else {
            ds.compute
        }
    }

    fn max_load(&self) -> f64 {
        (0..self.devices.len()).map(|d| self.device_load(d)).fold(0.0, f64::max)
    }

    fn run(&mut self) {
        self.dfs(0);
        let inc = self.incumbent.as_ref().map(|(o, _)| *o);
        if self.complete {
            // exhausted the tree: incumbent is optimal
            if let Some(obj) = inc {
                self.best_bound = obj;
                self.status = SolveStatus::Optimal;
            } else {
                self.status = SolveStatus::Infeasible;
            }
        } else {
            self.status = match inc {
                Some(obj) if (obj - self.best_bound) / obj.max(1e-12) <= self.opts.gap_target => {
                    SolveStatus::GapReached
                }
                Some(_) => SolveStatus::TimeLimit,
                None => SolveStatus::Unknown,
            };
        }
        // polish pass (primal heuristic): best-single-move descent
        if self.opts.polish {
            if let Some((obj, dense)) = self.incumbent.clone() {
                if let Some((better_obj, better)) = self.polish(obj, dense) {
                    self.incumbent = Some((better_obj, better));
                    self.incumbent_at = self.start.elapsed();
                    self.incumbent_log.push((self.incumbent_at, better_obj));
                }
            }
        }
    }

    fn dfs(&mut self, pos: usize) {
        self.nodes += 1;
        // node cap first (deterministic, one compare; never trips at the
        // u64::MAX default), then the amortized wall-clock check
        if self.nodes as u64 >= self.node_cap {
            self.complete = false;
            self.budget_hit = true;
            return;
        }
        if self.nodes % 4096 == 0 && Instant::now() > self.deadline {
            self.complete = false;
            if self.deadline < self.own_deadline {
                self.budget_hit = true;
            }
            return;
        }
        if pos == self.order.len() {
            let obj = self.max_load();
            if self
                .incumbent
                .as_ref()
                .is_none_or(|(best, _)| obj < best - 1e-12)
            {
                self.incumbent = Some((obj, self.assignment.clone()));
                self.incumbent_at = self.start.elapsed();
                self.incumbent_log.push((self.incumbent_at, obj));
            }
            return;
        }
        let v = self.order[pos];
        let nd = self.devices.len();

        // Candidate devices, cheapest resulting load first; symmetry break:
        // at most one *empty* device per device class considered (devices
        // are only interchangeable within their class).
        let mut cands: Vec<(f64, usize)> = Vec::with_capacity(nd);
        let mut seen_empty = vec![false; self.class_of.last().map_or(0, |&c| c + 1)];
        for d in 0..nd {
            let is_acc = d < self.k;
            let empty = self.devices[d].set.is_empty();
            if empty {
                let class = self.class_of[d];
                if seen_empty[class] {
                    continue;
                }
                seen_empty[class] = true;
            }
            if is_acc {
                if self.g.nodes[v].p_acc.is_infinite()
                    || self.devices[d].mem + self.g.nodes[v].mem > self.mem_cap[d]
                {
                    self.prune_memory += 1;
                    continue;
                }
            } else if self.g.nodes[v].p_cpu.is_infinite() {
                continue;
            }
            if self.opts.contiguous && !self.contiguity_ok(v, d) {
                self.prune_contiguity += 1;
                continue;
            }
            let p = if is_acc { self.g.nodes[v].p_acc } else { self.g.nodes[v].p_cpu };
            cands.push((self.device_load(d) + p / self.speed[d], d));
        }
        cands.sort_by(|a, b| a.0.total_cmp(&b.0));

        for (_, d) in cands {
            let undo = self.assign(v, d);
            // lower bound: current max load vs remaining-work average
            let placed: f64 = (0..nd).map(|x| self.devices[x].compute).sum();
            let lb = self
                .max_load()
                .max((placed + self.suffix_min_work[pos + 1]) / nd as f64);
            let prune = self
                .incumbent
                .as_ref()
                .is_some_and(|(best, _)| lb >= best - 1e-12);
            if !prune {
                self.dfs(pos + 1);
            } else {
                self.prune_bound += 1;
            }
            self.unassign(v, d, undo);
            if !self.complete {
                return;
            }
        }
    }

    /// Would assigning `v` to device `d` keep `set_d ∪ {v}` contiguous
    /// *given what is already assigned*? In topological order, any
    /// violating middle vertex x (u ∈ S_d ⇝ x ⇝ v, x ∉ S_d) is already
    /// assigned, so the check is exact: the violation exists iff some
    /// already-assigned non-member lies on a path from S_d to v.
    /// Runs against a reused word scratch — no clone per check.
    fn contiguity_ok(&mut self, v: usize, d: usize) -> bool {
        let mut mid = std::mem::take(&mut self.mid_scratch);
        let ds = &self.devices[d];
        let ok = ds.set.is_empty()
            || crate::graph::contiguity::prefix_contiguity_ok(
                ds.reach.words(),
                self.co_reach.row(v),
                self.assigned.words(),
                ds.set.words(),
                v,
                &mut mid,
            );
        self.mid_scratch = mid;
        ok
    }

    fn assign(&mut self, v: usize, d: usize) -> Undo {
        let is_acc = d < self.k;
        let undo = Undo { in_mark: self.undo_in.len(), out_mark: self.undo_out.len() };
        self.assignment[v] = d;
        self.assigned.insert(v);
        let speed = self.speed[d];
        let ds = &mut self.devices[d];
        ds.set.insert(v);
        ds.reach.union_with_words(self.reach.row(v));
        let p = if is_acc { self.g.nodes[v].p_acc } else { self.g.nodes[v].p_cpu };
        ds.compute += p / speed;
        ds.mem += self.g.nodes[v].mem;
        // communication: only accelerator devices pay (Fig. 6 (20) vs (21))
        for pi in 0..self.g.preds[v].len() {
            let u = self.g.preds[v][pi];
            let du = self.assignment[u];
            if du == d {
                continue;
            }
            // u → v crosses du → d, priced at that device pair (DESIGN.md
            // §9); identity (`comm·1 + 0`) without a topology
            if is_acc && !self.devices[d].in_paid.contains(u) {
                self.devices[d].in_paid.insert(u);
                let t = self.req.fleet.transfer_cost(du, d, self.g.nodes[u].comm);
                self.devices[d].comm_in += t;
                self.undo_in.push((u, t));
            }
            if du < self.k {
                // egress pays once at the WORST destination pair so far
                // (matches the evaluator's max-over-destinations egress)
                let t = self.req.fleet.transfer_cost(du, d, self.g.nodes[u].comm);
                if t > self.out_cost[u] {
                    let prev = self.out_cost[u];
                    let delta = t - prev;
                    self.devices[du].comm_out += delta;
                    self.out_cost[u] = t;
                    self.undo_out.push((u, prev, delta));
                }
            }
        }
        undo
    }

    fn unassign(&mut self, v: usize, d: usize, undo: Undo) {
        let is_acc = d < self.k;
        while self.undo_in.len() > undo.in_mark {
            let (u, t) = self.undo_in.pop().unwrap();
            self.devices[d].in_paid.remove(u);
            self.devices[d].comm_in -= t;
        }
        while self.undo_out.len() > undo.out_mark {
            let (u, prev, delta) = self.undo_out.pop().unwrap();
            let du = self.assignment[u];
            self.devices[du].comm_out -= delta;
            self.out_cost[u] = prev;
        }
        let speed = self.speed[d];
        let ds = &mut self.devices[d];
        ds.set.remove(v);
        let p = if is_acc { self.g.nodes[v].p_acc } else { self.g.nodes[v].p_cpu };
        ds.compute -= p / speed;
        ds.mem -= self.g.nodes[v].mem;
        self.assignment[v] = usize::MAX;
        self.assigned.remove(v);
        // rebuild reach for d (a union has no cheap undo) into the reused
        // scratch row — no allocation per node expansion
        let mut scratch = std::mem::take(&mut self.reach_scratch);
        self.reach.union_rows_of(self.devices[d].set.iter(), &mut scratch);
        self.devices[d].reach.copy_from_words(&scratch);
        self.reach_scratch = scratch;
    }

    /// Best-single-node-move descent on the full objective (evaluated via
    /// a scratch placement). Respects memory; respects contiguity when the
    /// options demand it.
    fn polish(&self, obj: f64, dense: Vec<usize>) -> Option<(f64, Vec<usize>)> {
        let nd = self.devices.len();
        let mut cur = dense;
        let mut cur_obj = obj;
        let mut improved_any = false;
        // own 5s cap, clamped by the caller's budget deadline (an expired
        // budget makes this pass a no-op rather than a 5s overshoot)
        let mut polish_deadline = Instant::now() + Duration::from_secs(5);
        if let Some(d) = self.opts.budget.deadline {
            polish_deadline = polish_deadline.min(d);
        }
        'outer: loop {
            let mut best: Option<(f64, usize, usize)> = None;
            for v in 0..self.g.n() {
                if Instant::now() > polish_deadline {
                    break 'outer;
                }
                let orig = cur[v];
                for d in 0..nd {
                    if d == orig {
                        continue;
                    }
                    cur[v] = d;
                    let cand = self.eval_dense(&cur);
                    if cand < cur_obj - 1e-12
                        && best.as_ref().is_none_or(|&(b, _, _)| cand < b)
                    {
                        best = Some((cand, v, d));
                    }
                }
                cur[v] = orig;
            }
            match best {
                Some((val, v, d)) if Instant::now() < polish_deadline => {
                    cur[v] = d;
                    cur_obj = val;
                    improved_any = true;
                }
                _ => break,
            }
        }
        improved_any.then_some((cur_obj, cur))
    }

    /// Evaluate a dense assignment (INF if infeasible / contiguity broken
    /// in contiguous mode).
    fn eval_dense(&self, dense: &[usize]) -> f64 {
        let p = Placement::new(
            dense.iter().map(|&d| Device::from_index(d, self.k)).collect(),
            0.0,
            "tmp",
        );
        if self.opts.contiguous {
            for d in 0..self.devices.len() {
                let set = p.set_of(Device::from_index(d, self.k), self.g.n());
                if !crate::graph::contiguity::is_contiguous_in(self.reach, &set) {
                    return f64::INFINITY;
                }
            }
        }
        objective::max_load_req(self.g, self.req, &p)
    }
}

/// Watermarks into the search's shared undo stacks (plain `Copy` — the old
/// per-expansion `Vec`s were a measurable allocation cost).
#[derive(Clone, Copy)]
struct Undo {
    in_mark: usize,
    out_mark: usize,
}

// ---------------------------------------------------------------------------
// Literal Fig.-6 MILP (executable specification, small instances)
// ---------------------------------------------------------------------------

/// Variable layout for the Fig.-6 model.
pub struct ThroughputModel {
    pub milp: Milp,
    pub num_devices: usize,
    n: usize,
}

impl ThroughputModel {
    pub fn x(&self, v: usize, i: usize) -> usize {
        v * self.num_devices + i
    }

    /// Extract a dense assignment from a MILP solution vector.
    pub fn assignment(&self, sol: &[f64]) -> Vec<usize> {
        (0..self.n)
            .map(|v| {
                (0..self.num_devices)
                    .max_by(|&a, &b| sol[self.x(v, a)].total_cmp(&sol[self.x(v, b)]))
                    .unwrap()
            })
            .collect()
    }
}

/// Legacy scalar form of [`build_model_req`].
pub fn build_model(g: &OpGraph, sc: &Scenario, contiguous: bool) -> ThroughputModel {
    build_model_req(g, &sc.to_request(), contiguous)
}

/// Build the Fig.-6 MILP. Devices `0..k` are accelerators, `k..k+ℓ` CPUs.
/// With `contiguous`, the Lemma-4.1 `z`-linearization of constraint (16) is
/// added for every device. The `CommIn/CommOut` variables exist per
/// (node, accelerator); loads and `MaxLoad` close the model. Memory
/// constraint (19) uses each accelerator's class cap; the load rows (20)/
/// (21) scale processing times by the device's class speed.
pub fn build_model_req(g: &OpGraph, req: &PlanRequest, contiguous: bool) -> ThroughputModel {
    let n = g.n();
    let k = req.fleet.k();
    let nd = k + req.fleet.l();
    // layout: x[v][i] (n*nd) | cin[v][acc i] (n*k) | cout[v][acc i] (n*k)
    //         | z[v][i] (n*nd, only if contiguous) | load[i] (nd) | maxload
    let x0 = 0;
    let cin0 = x0 + n * nd;
    let cout0 = cin0 + n * k;
    let z0 = cout0 + n * k;
    let load0 = z0 + if contiguous { n * nd } else { 0 };
    let ml = load0 + nd;
    let num_vars = ml + 1;

    let mut lp = Lp::new(num_vars);
    let x = |v: usize, i: usize| x0 + v * nd + i;
    let cin = |v: usize, i: usize| cin0 + v * k + i;
    let cout = |v: usize, i: usize| cout0 + v * k + i;
    let z = |v: usize, i: usize| z0 + v * nd + i;

    for v in 0..n {
        for i in 0..nd {
            lp.upper[x(v, i)] = 1.0;
            if contiguous {
                lp.upper[z(v, i)] = 1.0;
            }
        }
        for i in 0..k {
            lp.upper[cin(v, i)] = 1.0;
            lp.upper[cout(v, i)] = 1.0;
        }
    }
    lp.objective[ml] = 1.0;

    // (15) Σ_i x_vi = 1
    for v in 0..n {
        lp.add((0..nd).map(|i| (x(v, i), 1.0)).collect(), Sense::Eq, 1.0);
    }
    // (17)/(18) CommIn_ui ≥ x_vi − x_ui ; CommOut_ui ≥ x_ui − x_vi (accs)
    for (u, v) in g.edges() {
        for i in 0..k {
            lp.add(vec![(cin(u, i), 1.0), (x(v, i), -1.0), (x(u, i), 1.0)], Sense::Ge, 0.0);
            lp.add(vec![(cout(u, i), 1.0), (x(u, i), -1.0), (x(v, i), 1.0)], Sense::Ge, 0.0);
        }
    }
    // (19) memory per accelerator (its class's cap)
    for i in 0..k {
        lp.add(
            (0..n).map(|v| (x(v, i), g.nodes[v].mem)).collect(),
            Sense::Le,
            req.fleet.acc_mem_cap(i).min(1e15),
        );
    }
    // (20) accelerator load; (21) CPU load; MaxLoad ≥ Load_i
    for i in 0..nd {
        let mut coeffs: Vec<(usize, f64)> = vec![(load0 + i, -1.0)];
        if i < k {
            let speed = req.fleet.acc_speed(i);
            // Per-pair topology: the literal model keeps one CommIn/Out
            // indicator per (node, accelerator), so crossings are priced at
            // the cheapest off-diagonal pair (slowdown 1 by normalization,
            // plus the minimum latency) — a valid relaxation, exact without
            // a topology. The specialized search is the pair-exact engine.
            let min_lat = req.fleet.min_comm_latency();
            for v in 0..n {
                coeffs.push((x(v, i), g.nodes[v].p_acc / speed));
                coeffs.push((cin(v, i), g.nodes[v].comm + min_lat));
                coeffs.push((cout(v, i), g.nodes[v].comm + min_lat));
            }
        } else {
            let speed = req.fleet.cpu_speed(i - k);
            for v in 0..n {
                coeffs.push((x(v, i), g.nodes[v].p_cpu / speed));
            }
        }
        lp.add(coeffs, Sense::Eq, 0.0);
        lp.add(vec![(ml, 1.0), (load0 + i, -1.0)], Sense::Ge, 0.0);
    }
    // (16) contiguity via Lemma 4.1: z ≥ x ; z_v ≤ z_u ; z_v ≤ x_v − x_u + 1
    if contiguous {
        for v in 0..n {
            for i in 0..nd {
                lp.add(vec![(z(v, i), 1.0), (x(v, i), -1.0)], Sense::Ge, 0.0);
            }
        }
        for (u, v) in g.edges() {
            for i in 0..nd {
                lp.add(vec![(z(v, i), 1.0), (z(u, i), -1.0)], Sense::Le, 0.0);
                lp.add(
                    vec![(z(v, i), 1.0), (x(v, i), -1.0), (x(u, i), 1.0)],
                    Sense::Le,
                    1.0,
                );
            }
        }
    }
    // colocation (App. B): same color class ⇒ identical x rows
    let mut classes: std::collections::BTreeMap<u32, Vec<usize>> = Default::default();
    for (v, node) in g.nodes.iter().enumerate() {
        if let Some(c) = node.color_class {
            classes.entry(c).or_default().push(v);
        }
    }
    for members in classes.values() {
        for w in members.windows(2) {
            for i in 0..nd {
                lp.add(vec![(x(w[0], i), 1.0), (x(w[1], i), -1.0)], Sense::Eq, 0.0);
            }
        }
    }

    let integers: Vec<usize> = (0..n * nd).collect();
    ThroughputModel { milp: Milp { lp, integers }, num_devices: nd, n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::dp;
    use crate::solver::milp::MilpOptions;
    use crate::util::proptest::random_dag;
    use crate::util::rng::Rng;

    fn chain_g(n: usize) -> OpGraph {
        use crate::graph::Node;
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(10.0).acc(1.0).mem(1.0).comm(0.1));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn specialized_matches_dp_on_chain() {
        let g = chain_g(6);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let dp_p = dp::solve(&g, &sc).unwrap();
        let ip = solve(&g, &sc, &IpOptions::default()).unwrap();
        assert_eq!(ip.status, SolveStatus::Optimal);
        assert!((ip.placement.objective - dp_p.objective).abs() < 1e-9);
    }

    #[test]
    fn specialized_matches_brute_force_and_bounds_dp() {
        // The Fig.-6 feasible set (per-device contiguity) is a superset of
        // the DP's pipeline-orderable partitions, so IP ≤ DP; equality on
        // the paper's workloads but not on every random DAG.
        let mut rng = Rng::new(0x1790);
        for case in 0..12 {
            let g = random_dag(&mut rng, 7, 0.3);
            let sc = Scenario::new(2, 1, 5.0);
            let dp_r = dp::solve(&g, &sc);
            let ip_r = solve(&g, &sc, &IpOptions { gap_target: 0.0, ..Default::default() });
            match (dp_r, ip_r) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(b.status, SolveStatus::Optimal, "case {case}");
                    assert!(
                        b.placement.objective <= a.objective + 1e-6,
                        "case {case}: ip={} worse than dp={}",
                        b.placement.objective,
                        a.objective
                    );
                    let bf = brute_force_fig6(&g, &sc).unwrap();
                    assert!(
                        (b.placement.objective - bf).abs() < 1e-6,
                        "case {case}: ip={} bf={bf}",
                        b.placement.objective
                    );
                }
                (Err(_), Err(_)) => {}
                (a, b) => {
                    panic!("case {case}: feasibility disagreement {a:?} vs {:?}", b.map(|r| r.status))
                }
            }
        }
    }

    /// Brute force over the literal Fig.-6 feasible set: per-device
    /// contiguity (Def. 3.1) + memory, scored by the shared evaluator.
    fn brute_force_fig6(g: &OpGraph, sc: &Scenario) -> Option<f64> {
        let nd = sc.k + sc.l;
        let n = g.n();
        let mut best: Option<f64> = None;
        let mut assign = vec![0usize; n];
        loop {
            let placement = Placement::new(
                assign.iter().map(|&d| Device::from_index(d, sc.k)).collect(),
                0.0,
                "bf",
            );
            let all_contig = (0..nd).all(|d| {
                let set = placement.set_of(Device::from_index(d, sc.k), n);
                crate::graph::contiguity::is_contiguous(g, &set)
            });
            if all_contig && placement.validate(g, sc, false).is_ok() {
                let obj = objective::max_load(g, sc, &placement);
                if obj.is_finite() {
                    best = Some(best.map_or(obj, |b: f64| b.min(obj)));
                }
            }
            let mut i = 0;
            loop {
                if i == n {
                    return best;
                }
                assign[i] += 1;
                if assign[i] < nd {
                    break;
                }
                assign[i] = 0;
                i += 1;
            }
        }
    }

    #[test]
    fn non_contiguous_no_worse_than_contiguous() {
        let mut rng = Rng::new(0x1791);
        for _ in 0..8 {
            let g = random_dag(&mut rng, 7, 0.35);
            let sc = Scenario::new(2, 1, f64::INFINITY);
            let c = solve(&g, &sc, &IpOptions { gap_target: 0.0, ..Default::default() }).unwrap();
            let nc = solve(
                &g,
                &sc,
                &IpOptions { gap_target: 0.0, contiguous: false, ..Default::default() },
            )
            .unwrap();
            assert!(
                nc.placement.objective <= c.placement.objective + 1e-9,
                "non-contig {} > contig {}",
                nc.placement.objective,
                c.placement.objective
            );
        }
    }

    #[test]
    fn milp_model_agrees_with_specialized_on_tiny_graph() {
        let g = chain_g(4);
        let sc = Scenario::new(2, 0, f64::INFINITY);
        // literal Fig.-6 model through the LP-based branch & bound
        let model = build_model(&g, &sc, true);
        let r = model.milp.solve(&MilpOptions {
            gap_target: 0.0,
            time_limit: Duration::from_secs(30),
            ..Default::default()
        });
        assert_eq!(r.status, SolveStatus::Optimal);
        let ip = solve(&g, &sc, &IpOptions { gap_target: 0.0, ..Default::default() }).unwrap();
        assert!(
            (r.objective - ip.placement.objective).abs() < 1e-6,
            "milp {} vs specialized {}",
            r.objective,
            ip.placement.objective
        );
    }

    #[test]
    fn respects_memory_and_reports_feasible_split() {
        let g = chain_g(6);
        let sc = Scenario::new(3, 1, 2.0);
        let ip = solve(&g, &sc, &IpOptions::default()).unwrap();
        ip.placement.validate(&g, &sc, true).unwrap();
        assert!(ip.placement.objective.is_finite());
    }

    #[test]
    fn training_graph_supported() {
        use crate::util::proptest::random_training_dag;
        let mut rng = Rng::new(0x1793);
        let g = random_training_dag(&mut rng, 5, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let ip = solve(&g, &sc, &IpOptions::default()).unwrap();
        ip.placement.check_colocation(&g).unwrap();
        let dp_p = dp::solve(&g, &sc).unwrap();
        assert!(ip.placement.objective <= dp_p.objective + 1e-9);
    }
}

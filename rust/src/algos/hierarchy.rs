//! Appendix C.3 — accelerator hierarchies (clusters with fast intra-
//! cluster and slow inter-cluster interconnects).
//!
//! Two-level deployment: `num_clusters` clusters of `accs_per_cluster`
//! accelerators each. Data crossing a cluster boundary pays `inter_factor`×
//! the node's base transfer cost; within a cluster the base cost applies.
//!
//! Following the paper's note (PipeDream's method), the DP generalizes from
//! prefixes (ideals) to contiguous *segments*: the outer DP assigns each
//! cluster a contiguous segment `I \ I'` of the pipeline and recursively
//! splits that segment over the cluster's accelerators with the flat DP,
//! with boundary communication billed at the inter-cluster rate. This costs
//! an extra `O(𝓘)` factor — the segment table — exactly as stated in C.3.

use super::dp::{self, DpError, Prepared};
use crate::coordinator::placement::{Device, Placement, Scenario};
use crate::graph::ideals::IdealLattice;
use crate::graph::OpGraph;
use crate::util::bitset::BitSet;

/// Hierarchical deployment description.
#[derive(Clone, Debug)]
pub struct Hierarchy {
    pub num_clusters: usize,
    pub accs_per_cluster: usize,
    /// Multiplier on `c_v` for transfers crossing cluster boundaries (≥ 1).
    pub inter_factor: f64,
    /// Memory cap per accelerator.
    pub mem_cap: f64,
}

#[derive(Clone, Debug)]
pub struct HierPlacement {
    pub cluster_of: Vec<usize>,
    /// Placement within the global accelerator numbering
    /// (cluster c, slot s) → `Acc(c·accs_per_cluster + s)`.
    pub placement: Placement,
    pub objective: f64,
}

/// Solve the two-level throughput problem. The graph must be an inference
/// graph or preprocessable by [`Prepared::build`].
pub fn solve(g: &OpGraph, hier: &Hierarchy, cap: usize) -> Result<HierPlacement, DpError> {
    let prepared = Prepared::build(g)?;
    // fold gradient comm into node comm (proxy; see replication.rs)
    let mut proxy = prepared.dp_graph.clone();
    for (v, node) in proxy.nodes.iter_mut().enumerate() {
        node.comm += prepared.bw_comm[v];
    }
    let gg = &proxy;
    let lattice = IdealLattice::enumerate(gg, cap).map_err(DpError::TooManyIdeals)?;
    let ni = lattice.len();
    let nc = hier.num_clusters;

    // inner[segment(I', I)] solved lazily via the flat DP on the induced
    // subgraph with inter-cluster comm billed on the boundary.
    // outer_dp[I][c] = best max-load partitioning ideal I over c clusters.
    let mut outer = vec![f64::INFINITY; ni * (nc + 1)];
    let mut parent: Vec<u32> = vec![u32::MAX; ni * (nc + 1)];
    let idx = |i: usize, c: usize| i * (nc + 1) + c;
    for c in 0..=nc {
        outer[idx(0, c)] = 0.0;
    }

    let mut seg_cache: std::collections::HashMap<(u32, u32), f64> =
        std::collections::HashMap::new();

    let mut visited = vec![0u32; ni];
    let mut stack: Vec<usize> = Vec::new();
    for i in 1..ni {
        // enumerate sub-ideals of i (stamped visited array — no per-ideal
        // allocation)
        let stamp = i as u32;
        stack.clear();
        stack.push(i);
        visited[i] = stamp;
        while let Some(cur) = stack.pop() {
            for &(sub, _) in lattice.subs(cur) {
                let sub = sub as usize;
                if visited[sub] != stamp {
                    visited[sub] = stamp;
                    stack.push(sub);
                }
            }
            let s = lattice.difference_bitset(i, cur);
            if s.is_empty() {
                continue;
            }
            let seg_load = *seg_cache.entry((cur as u32, i as u32)).or_insert_with(|| {
                segment_load(gg, hier, &s)
            });
            for c in 1..=nc {
                let cand = outer[idx(cur, c - 1)].max(seg_load);
                let cell = idx(i, c);
                if cand < outer[cell] {
                    outer[cell] = cand;
                    parent[cell] = cur as u32;
                }
            }
        }
        // allow unused clusters
        for c in 1..=nc {
            let cell = idx(i, c);
            if outer[idx(i, c - 1)] < outer[cell] {
                outer[cell] = outer[idx(i, c - 1)];
                parent[cell] = i as u32;
            }
        }
    }

    let final_cell = idx(lattice.full_id(), nc);
    if !outer[final_cell].is_finite() {
        return Err(DpError::Infeasible);
    }

    // Reconstruct: segments per cluster, then re-run inner DP for devices.
    let mut cluster_of_prepared = vec![0usize; gg.n()];
    let mut assignment_prepared: Vec<Device> = vec![Device::Cpu(0); gg.n()];
    let (mut i, mut c) = (lattice.full_id(), nc);
    while i != 0 && c > 0 {
        let sub = parent[idx(i, c)];
        if sub == u32::MAX {
            break;
        }
        let sub = sub as usize;
        let s = lattice.difference_bitset(i, sub);
        if !s.is_empty() {
            let cluster = c - 1;
            let (_, inner_assign) = inner_split(gg, hier, &s);
            for (local, v) in s.iter().enumerate() {
                cluster_of_prepared[v] = cluster;
                let slot = inner_assign[local].min(hier.accs_per_cluster - 1);
                assignment_prepared[v] =
                    Device::Acc(cluster * hier.accs_per_cluster + slot);
            }
        }
        i = sub;
        c -= 1;
    }

    let objective = outer[final_cell];
    let assignment: Vec<Device> =
        prepared.map.iter().map(|&m| assignment_prepared[m]).collect();
    let cluster_of: Vec<usize> = prepared.map.iter().map(|&m| cluster_of_prepared[m]).collect();
    Ok(HierPlacement {
        cluster_of,
        placement: Placement::new(assignment, objective, "DP (hierarchy)"),
        objective,
    })
}

/// Load of a segment assigned to one cluster: split it over the cluster's
/// accelerators with the flat DP (intra-cluster comm at base rate), then
/// add the inter-cluster boundary transfers at the slow rate.
fn segment_load(g: &OpGraph, hier: &Hierarchy, seg: &BitSet) -> f64 {
    let (load, _) = inner_split(g, hier, seg);
    load
}

fn inner_split(g: &OpGraph, hier: &Hierarchy, seg: &BitSet) -> (f64, Vec<usize>) {
    // induced subgraph on seg (local ids in iteration order)
    let members: Vec<usize> = seg.iter().collect();
    let mut local_id = std::collections::HashMap::new();
    for (li, &v) in members.iter().enumerate() {
        local_id.insert(v, li);
    }
    let mut sub = OpGraph::new();
    for &v in &members {
        sub.add_node(g.nodes[v].clone());
    }
    for (u, v) in g.edges() {
        if let (Some(&lu), Some(&lv)) = (local_id.get(&u), local_id.get(&v)) {
            sub.add_edge(lu, lv);
        }
    }
    let sc = Scenario {
        k: hier.accs_per_cluster,
        l: 0,
        mem_cap: hier.mem_cap,
        ..Default::default()
    };
    let inner = dp::solve(&sub, &sc);
    // inter-cluster boundary comm (billed to this cluster's bottleneck
    // conservatively: added to the inner max-load)
    let mut boundary = 0.0;
    let mut paid_in = BitSet::new(g.n());
    for &v in &members {
        for &u in &g.preds[v] {
            if !seg.contains(u) && !paid_in.contains(u) {
                paid_in.insert(u);
                boundary += g.nodes[u].comm * hier.inter_factor;
            }
        }
        if g.succs[v].iter().any(|&w| !seg.contains(w)) {
            boundary += g.nodes[v].comm * hier.inter_factor;
        }
    }
    match inner {
        Ok(p) => {
            let assign: Vec<usize> = p
                .assignment
                .iter()
                .map(|d| match d {
                    Device::Acc(i) => *i,
                    Device::Cpu(_) => 0,
                })
                .collect();
            (p.objective + boundary, assign)
        }
        Err(_) => (f64::INFINITY, vec![0; members.len()]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(50.0).acc(2.0).mem(1.0).comm(0.5));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn hierarchy_solves_and_uses_clusters() {
        let g = chain(8);
        let hier = Hierarchy {
            num_clusters: 2,
            accs_per_cluster: 2,
            inter_factor: 4.0,
            mem_cap: f64::INFINITY,
        };
        let r = solve(&g, &hier, usize::MAX).unwrap();
        assert!(r.objective.is_finite());
        assert_eq!(r.cluster_of.len(), 8);
        // chain of 8 over 4 devices: objective should be ≲ 8 (2 nodes/dev
        // + comm), certainly below the single-device 16
        assert!(r.objective < 16.0, "{}", r.objective);
    }

    #[test]
    fn slow_interconnect_discourages_fine_cluster_splits() {
        let g = chain(8);
        let fast = Hierarchy {
            num_clusters: 2,
            accs_per_cluster: 2,
            inter_factor: 1.0,
            mem_cap: f64::INFINITY,
        };
        let slow = Hierarchy { inter_factor: 50.0, ..fast.clone() };
        let rf = solve(&g, &fast, usize::MAX).unwrap();
        let rs = solve(&g, &slow, usize::MAX).unwrap();
        assert!(rf.objective <= rs.objective + 1e-9);
    }

    #[test]
    fn single_cluster_matches_flat_dp() {
        let g = chain(6);
        let hier = Hierarchy {
            num_clusters: 1,
            accs_per_cluster: 3,
            inter_factor: 9.0,
            mem_cap: f64::INFINITY,
        };
        let r = solve(&g, &hier, usize::MAX).unwrap();
        let sc = Scenario::new(3, 0, f64::INFINITY);
        let flat = dp::solve(&g, &sc).unwrap();
        // one cluster holding everything has no inter-cluster boundary
        assert!((r.objective - flat.objective).abs() < 1e-9);
    }
}

//! Appendix C.3 — accelerator hierarchies (clusters with fast intra-
//! cluster and slow inter-cluster interconnects).
//!
//! Two-level deployment: `num_clusters` clusters of `accs_per_cluster`
//! accelerators each. Data crossing a cluster boundary pays `inter_factor`×
//! the node's base transfer cost; within a cluster the base cost applies.
//!
//! Following the paper's note (PipeDream's method), the DP generalizes from
//! prefixes (ideals) to contiguous *segments*: the outer DP assigns each
//! cluster a contiguous segment `I \ I'` of the pipeline and recursively
//! splits that segment over the cluster's accelerators with the flat DP,
//! with boundary communication billed at the inter-cluster rate. This costs
//! an extra `O(𝓘)` factor — the segment table — exactly as stated in C.3.

use super::dp::{self, CarveWalker, DpError, Prepared};
use crate::coordinator::context::ProblemCtx;
use crate::coordinator::placement::{Device, Placement, Scenario};
use crate::graph::ideals::IdealLattice;
use crate::graph::OpGraph;

/// Hierarchical deployment description.
#[derive(Clone, Debug)]
pub struct Hierarchy {
    pub num_clusters: usize,
    pub accs_per_cluster: usize,
    /// Multiplier on `c_v` for transfers crossing cluster boundaries (≥ 1).
    pub inter_factor: f64,
    /// Memory cap per accelerator.
    pub mem_cap: f64,
}

#[derive(Clone, Debug)]
pub struct HierPlacement {
    pub cluster_of: Vec<usize>,
    /// Placement within the global accelerator numbering
    /// (cluster c, slot s) → `Acc(c·accs_per_cluster + s)`.
    pub placement: Placement,
    pub objective: f64,
}

/// Solve the two-level throughput problem. The graph must be an inference
/// graph or preprocessable by [`Prepared::build`].
///
/// Deprecated thin wrapper: recomputes the preprocessing and lattice per
/// call. Prefer [`solve_ctx`] over a shared
/// [`crate::coordinator::context::ProblemCtx`].
pub fn solve(g: &OpGraph, hier: &Hierarchy, cap: usize) -> Result<HierPlacement, DpError> {
    let prepared = Prepared::build(g)?;
    // fold gradient comm into node comm (proxy; see replication.rs)
    let mut proxy = prepared.dp_graph.clone();
    for (v, node) in proxy.nodes.iter_mut().enumerate() {
        node.comm += prepared.bw_comm[v];
    }
    let lattice = IdealLattice::enumerate(&proxy, cap).map_err(DpError::TooManyIdeals)?;
    solve_on_lattice(&proxy, hier, &lattice, &prepared)
}

/// [`solve`] against a shared analysis context (proxy graph, lattice and
/// preprocessing all come from the cache).
pub fn solve_ctx(ctx: &ProblemCtx, hier: &Hierarchy) -> Result<HierPlacement, DpError> {
    solve_on_lattice(ctx.proxy()?, hier, ctx.lattice()?, ctx.prepared()?)
}

fn solve_on_lattice(
    gg: &OpGraph,
    hier: &Hierarchy,
    lattice: &IdealLattice,
    prepared: &Prepared,
) -> Result<HierPlacement, DpError> {
    let ni = lattice.len();
    let nc = hier.num_clusters;
    let apc = hier.accs_per_cluster.max(1);

    // inner[segment(I', I)] solved lazily via the flat DP on the induced
    // subgraph with inter-cluster comm billed on the boundary.
    // outer_dp[I][c] = best max-load partitioning ideal I over c clusters.
    let mut outer = vec![f64::INFINITY; ni * (nc + 1)];
    let mut parent: Vec<u32> = vec![u32::MAX; ni * (nc + 1)];
    let idx = |i: usize, c: usize| i * (nc + 1) + c;
    for c in 0..=nc {
        outer[idx(0, c)] = 0.0;
    }

    // Incremental DFS over nested sub-ideals (the dp.rs walk): the
    // segment's memory, compute and boundary-comm sums are maintained in
    // O(deg v) per lattice step instead of being recomputed per (I', I)
    // pair, and the expensive inner DP only runs for segments that could
    // still improve a cell — `compute(S)/apc` and `mem(S)` both grow
    // monotonically along the descent, so subtrees whose bound already
    // exceeds every improvable cell (or that can no longer fit the
    // cluster's memory) are pruned wholesale.
    // Raw per-node comm: the hierarchy model prices cross-cluster traffic
    // through its own `inter_factor` below — layering the fleet topology's
    // worst-pair bound on top would double-count the slow link.
    let comm: Vec<f64> = gg.nodes.iter().map(|n| n.comm).collect();
    let mut walker = CarveWalker::new(ni, gg.n());
    for i in 1..ni {
        let (head, tail) = outer.split_at_mut(i * (nc + 1));
        let cells = &mut tail[..nc + 1];
        let parents = &mut parent[i * (nc + 1)..(i + 1) * (nc + 1)];
        walker.walk(gg, lattice, &comm, i, |cur, carve| {
            if cur == i {
                return true; // S = ∅ handled by the unused-cluster pass
            }
            let eff_compute = if carve.inf_acc == 0 { carve.compute } else { f64::INFINITY };
            let lb = if carve.mem > apc as f64 * hier.mem_cap {
                // the segment can never fit the cluster again (mem grows)
                f64::INFINITY
            } else {
                eff_compute / apc as f64
            };
            let worst = cells[1..].iter().copied().fold(0.0, f64::max);
            if lb >= worst && worst.is_finite() {
                return false; // prune the subtree below this sub-ideal
            }
            // inter-cluster boundary at the slow rate (incremental sums),
            // inner split via the flat DP on the members; each (cur, i)
            // pair is visited exactly once per walk (stamped visited
            // array), so there is nothing to memoize across pairs
            let boundary = (carve.comm_in + carve.comm_out) * hier.inter_factor;
            let seg_load = inner_split(gg, hier, &carve.members).0 + boundary;
            for c in 1..=nc {
                let cand = head[idx(cur, c - 1)].max(seg_load);
                if cand < cells[c] {
                    cells[c] = cand;
                    parents[c] = cur as u32;
                }
            }
            true
        });
        // allow unused clusters
        for c in 1..=nc {
            if cells[c - 1] < cells[c] {
                cells[c] = cells[c - 1];
                parents[c] = i as u32;
            }
        }
    }

    let final_cell = idx(lattice.full_id(), nc);
    if !outer[final_cell].is_finite() {
        return Err(DpError::Infeasible);
    }

    // Reconstruct: segments per cluster, then re-run inner DP for devices.
    let mut cluster_of_prepared = vec![0usize; gg.n()];
    let mut assignment_prepared: Vec<Device> = vec![Device::Cpu(0); gg.n()];
    let (mut i, mut c) = (lattice.full_id(), nc);
    while i != 0 && c > 0 {
        let sub = parent[idx(i, c)];
        if sub == u32::MAX {
            break;
        }
        let sub = sub as usize;
        let s = lattice.difference_bitset(i, sub);
        if !s.is_empty() {
            let cluster = c - 1;
            let members: Vec<usize> = s.iter().collect();
            let (_, inner_assign) = inner_split(gg, hier, &members);
            for (local, &v) in members.iter().enumerate() {
                cluster_of_prepared[v] = cluster;
                let slot = inner_assign[local].min(hier.accs_per_cluster - 1);
                assignment_prepared[v] =
                    Device::Acc(cluster * hier.accs_per_cluster + slot);
            }
        }
        i = sub;
        c -= 1;
    }

    let objective = outer[final_cell];
    let assignment: Vec<Device> =
        prepared.map.iter().map(|&m| assignment_prepared[m]).collect();
    let cluster_of: Vec<usize> = prepared.map.iter().map(|&m| cluster_of_prepared[m]).collect();
    Ok(HierPlacement {
        cluster_of,
        placement: Placement::new(assignment, objective, "DP (hierarchy)"),
        objective,
    })
}

/// Split a segment over one cluster's accelerators with the flat DP
/// (intra-cluster comm at the base rate). Returns the inner max-load and a
/// per-member slot assignment (parallel to `members`); the caller bills
/// the inter-cluster boundary transfers separately (it maintains them
/// incrementally along the lattice walk).
fn inner_split(g: &OpGraph, hier: &Hierarchy, members: &[usize]) -> (f64, Vec<usize>) {
    // induced subgraph on the members (local ids in the given order)
    let mut local_id = std::collections::HashMap::new();
    for (li, &v) in members.iter().enumerate() {
        local_id.insert(v, li);
    }
    let mut sub = OpGraph::new();
    for &v in members {
        sub.add_node(g.nodes[v].clone());
    }
    for (u, v) in g.edges() {
        if let (Some(&lu), Some(&lv)) = (local_id.get(&u), local_id.get(&v)) {
            sub.add_edge(lu, lv);
        }
    }
    let sc = Scenario {
        k: hier.accs_per_cluster,
        l: 0,
        mem_cap: hier.mem_cap,
        ..Default::default()
    };
    match dp::solve(&sub, &sc) {
        Ok(p) => {
            let assign: Vec<usize> = p
                .assignment
                .iter()
                .map(|d| match d {
                    Device::Acc(i) => *i,
                    Device::Cpu(_) => 0,
                })
                .collect();
            (p.objective, assign)
        }
        Err(_) => (f64::INFINITY, vec![0; members.len()]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(50.0).acc(2.0).mem(1.0).comm(0.5));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn hierarchy_solves_and_uses_clusters() {
        let g = chain(8);
        let hier = Hierarchy {
            num_clusters: 2,
            accs_per_cluster: 2,
            inter_factor: 4.0,
            mem_cap: f64::INFINITY,
        };
        let r = solve(&g, &hier, usize::MAX).unwrap();
        assert!(r.objective.is_finite());
        assert_eq!(r.cluster_of.len(), 8);
        // chain of 8 over 4 devices: objective should be ≲ 8 (2 nodes/dev
        // + comm), certainly below the single-device 16
        assert!(r.objective < 16.0, "{}", r.objective);
    }

    #[test]
    fn slow_interconnect_discourages_fine_cluster_splits() {
        let g = chain(8);
        let fast = Hierarchy {
            num_clusters: 2,
            accs_per_cluster: 2,
            inter_factor: 1.0,
            mem_cap: f64::INFINITY,
        };
        let slow = Hierarchy { inter_factor: 50.0, ..fast.clone() };
        let rf = solve(&g, &fast, usize::MAX).unwrap();
        let rs = solve(&g, &slow, usize::MAX).unwrap();
        assert!(rf.objective <= rs.objective + 1e-9);
    }

    #[test]
    fn single_cluster_matches_flat_dp() {
        let g = chain(6);
        let hier = Hierarchy {
            num_clusters: 1,
            accs_per_cluster: 3,
            inter_factor: 9.0,
            mem_cap: f64::INFINITY,
        };
        let r = solve(&g, &hier, usize::MAX).unwrap();
        let sc = Scenario::new(3, 0, f64::INFINITY);
        let flat = dp::solve(&g, &sc).unwrap();
        // one cluster holding everything has no inter-cluster boundary
        assert!((r.objective - flat.objective).abs() < 1e-9);
    }
}

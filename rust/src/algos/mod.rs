//! The paper's optimization algorithms.
//!
//! * [`dp`] — exact DP over ideals for pipelined throughput (§5.1.1), with
//!   App.-B training preprocessing built in.
//! * [`dpl`] — the linearization heuristic (§5.1.2).
//! * [`ip_throughput`] — the Fig.-6 Integer Program (contiguous and
//!   non-contiguous, §5.1.3/§5.2), on the in-tree MILP solver.
//! * [`ip_latency`] — the Figs.-3/4 Integer Programs for single-sample
//!   latency (§4), incl. `q` subgraph slots per accelerator (§4.1).
//! * [`replication`] — App.-C.2 hybrid model/data-parallel DP.
//! * [`hierarchy`] — App.-C.3 two-level accelerator topologies.
//! * [`objective`] — the shared cost-model evaluators all of the above
//!   (and the baselines) are scored with.

pub mod dp;
pub mod dpl;
pub mod hierarchy;
pub mod ip_latency;
pub mod ip_throughput;
pub mod objective;
pub mod replication;

/// The one error vocabulary shared by every optimizer and baseline.
///
/// Historically the DP family returned its own `DpError` while the latency
/// IP returned bare `String`s, forcing the planner façade into per-arm
/// `map_err` plumbing; all solvers now speak `PlaceError` (the old
/// `dp::DpError` name survives as a type alias for source compatibility).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlaceError {
    /// The ideal lattice exceeds the enumeration cap — fall back to
    /// [`dpl`] (the count is the number of ideals seen before aborting).
    TooManyIdeals(usize),
    /// No feasible placement exists (memory caps / unsupported ops).
    Infeasible,
    /// The graph is not a DAG (possibly only after preprocessing).
    NotADag,
    /// The search produced no incumbent within its budget (it may or may
    /// not be feasible — unlike [`PlaceError::Infeasible`], nothing was
    /// proven).
    NoIncumbent,
    /// The expert baseline was requested for a workload with no expert
    /// placement rule (operator-granularity graphs, §6).
    MissingExpertRule,
    /// A solver panicked mid-solve. The planning service catches the unwind
    /// at the registry boundary so one buggy solve fails one request — the
    /// payload is the panic message, for diagnostics only (never matched
    /// on).
    SolverPanicked(String),
    /// The service's admission controller shed this request: the concurrent
    /// solve limit and its bounded wait queue were both full (or the
    /// per-tenant in-flight cap was hit). Retry later; nothing about the
    /// problem itself was proven.
    Overloaded,
    /// Anything else (kept for forward compatibility of the `Solver` trait).
    Unsupported(String),
}

impl std::fmt::Display for PlaceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlaceError::TooManyIdeals(n) => {
                write!(f, "ideal lattice exceeds cap ({n}+ ideals)")
            }
            PlaceError::Infeasible => write!(f, "no feasible placement"),
            PlaceError::NotADag => write!(f, "graph is not a DAG after preprocessing"),
            PlaceError::NoIncumbent => write!(f, "no feasible placement found within budget"),
            PlaceError::MissingExpertRule => write!(f, "no expert rule for this workload"),
            PlaceError::SolverPanicked(msg) => write!(f, "solver panicked: {msg}"),
            PlaceError::Overloaded => {
                write!(f, "planning service overloaded; request shed")
            }
            PlaceError::Unsupported(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for PlaceError {}

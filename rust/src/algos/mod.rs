//! The paper's optimization algorithms.
//!
//! * [`dp`] — exact DP over ideals for pipelined throughput (§5.1.1), with
//!   App.-B training preprocessing built in.
//! * [`dpl`] — the linearization heuristic (§5.1.2).
//! * [`ip_throughput`] — the Fig.-6 Integer Program (contiguous and
//!   non-contiguous, §5.1.3/§5.2), on the in-tree MILP solver.
//! * [`ip_latency`] — the Figs.-3/4 Integer Programs for single-sample
//!   latency (§4), incl. `q` subgraph slots per accelerator (§4.1).
//! * [`replication`] — App.-C.2 hybrid model/data-parallel DP.
//! * [`hierarchy`] — App.-C.3 two-level accelerator topologies.
//! * [`objective`] — the shared cost-model evaluators all of the above
//!   (and the baselines) are scored with.

pub mod dp;
pub mod dpl;
pub mod hierarchy;
pub mod ip_latency;
pub mod ip_throughput;
pub mod objective;
pub mod replication;

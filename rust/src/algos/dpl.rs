//! DPL — the DP with the Linearization heuristic (§5.1.2).
//!
//! For large, strongly-branching graphs the ideal lattice (and hence the
//! exact DP) blows up. DPL finds a Hamiltonian-path ordering via DFS and
//! adds it as artificial precedence edges: the constrained graph has
//! exactly `|V|+1` ideals (the prefixes of the ordering), so the DP runs in
//! `O(|V|²·(k·ℓ + deg))`. The artificial edges only restrict *which*
//! subgraphs may be carved — device loads are still computed on the
//! original edges, so reported objectives stay true to the cost model.
//! Optimality is no longer guaranteed; Table 1 shows the loss is 0 for most
//! workloads and ≤ 9% in the worst case.

use super::dp::{self, DpError, Prepared};
use crate::coordinator::placement::{Placement, Scenario};
use crate::graph::ideals::IdealLattice;
use crate::graph::topo;
use crate::graph::OpGraph;

/// Solve throughput maximization with the linearization heuristic.
///
/// Deprecated thin wrapper: recomputes the preprocessing per call. Prefer
/// [`crate::coordinator::planner::DplSolver`] over a shared
/// [`crate::coordinator::context::ProblemCtx`], which caches it.
pub fn solve(g: &OpGraph, sc: &Scenario) -> Result<Placement, DpError> {
    let prepared = Prepared::build(g)?;
    let order = topo::dfs_linearization(&prepared.dp_graph);
    // Prefix lattice along the linearization (|V|+1 ideals — what
    // enumerating the edge-augmented graph would yield, built directly);
    // costs stay on the ORIGINAL dp_graph edges.
    let lattice = IdealLattice::from_prefixes(prepared.dp_graph.n(), &order);
    debug_assert_eq!(lattice.len(), prepared.dp_graph.n() + 1);
    let (obj, dense) =
        dp::solve_on_lattice_with(&prepared.dp_graph, sc, &lattice, &prepared.bw_comm)?;
    let mut p = prepared.expand(g, sc, obj, &dense);
    p.algorithm = "DPL".into();
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::dp;
    use crate::graph::Node;
    use crate::util::proptest::random_dag;
    use crate::util::rng::Rng;

    #[test]
    fn dpl_equals_dp_on_chains() {
        // Linear graphs: linearization is exact.
        let mut g = OpGraph::new();
        for i in 0..8 {
            g.add_node(Node::new(format!("c{i}")).cpu(9.0).acc(1.0).comm(0.2));
        }
        for i in 1..8 {
            g.add_edge(i - 1, i);
        }
        let sc = Scenario::new(3, 1, f64::INFINITY);
        let a = dp::solve(&g, &sc).unwrap();
        let b = solve(&g, &sc).unwrap();
        assert!((a.objective - b.objective).abs() < 1e-9);
    }

    #[test]
    fn dpl_never_beats_dp_and_stays_feasible() {
        let mut rng = Rng::new(0xD91);
        for _ in 0..15 {
            let g = random_dag(&mut rng, 10, 0.3);
            let sc = Scenario::new(2, 1, f64::INFINITY);
            let exact = dp::solve(&g, &sc).unwrap();
            let heur = solve(&g, &sc).unwrap();
            assert!(
                heur.objective >= exact.objective - 1e-9,
                "DPL {} beat DP {}",
                heur.objective,
                exact.objective
            );
            heur.validate(&g, &sc, true).unwrap();
        }
    }

    #[test]
    fn dpl_handles_training_graphs() {
        use crate::util::proptest::random_training_dag;
        let mut rng = Rng::new(0xD92);
        let g = random_training_dag(&mut rng, 7, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = solve(&g, &sc).unwrap();
        p.check_colocation(&g).unwrap();
        assert!(p.objective.is_finite());
    }
}

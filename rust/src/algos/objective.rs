//! Ground-truth evaluators for the paper's two objectives. Every optimizer
//! and baseline is scored through these functions, so DP/IP/heuristics are
//! compared on one cost model (as in Tables 1–4):
//!
//! * [`max_load`] — throughput objective (§5): Time-Per-Sample = the
//!   maximum device load, with the training variants of §5.3 and the
//!   Appendix-C.1 communication models.
//! * [`latency`] — latency objective (§4): end-to-end makespan of the
//!   uninterrupted-subgraph schedule, evaluated for arbitrary (even
//!   non-contiguous) placements by decomposing each accelerator's set into
//!   contiguous virtual pieces and serializing them (constraint (14)).

use crate::coordinator::placement::{Device, Placement, PlanRequest, Scenario, TrainSchedule};
use crate::graph::{contiguity, topo, NodeKind, OpGraph};
use crate::util::arena::BitMatrix;
use crate::util::bitset::BitSet;

/// Load components of one device for one pass direction.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadParts {
    pub compute: f64,
    pub comm_in: f64,
    pub comm_out: f64,
}

impl LoadParts {
    pub fn total(&self, sc: &Scenario) -> f64 {
        sc.combine(self.compute, self.comm_in, self.comm_out)
    }

    pub fn total_req(&self, req: &PlanRequest) -> f64 {
        req.combine(self.compute, self.comm_in, self.comm_out)
    }
}

/// Per-device, per-direction loads of a placement.
#[derive(Clone, Debug)]
pub struct DeviceLoads {
    /// Indexed by dense device id (`0..k` accs then `k..k+ℓ` CPUs).
    pub fw: Vec<LoadParts>,
    pub bw: Vec<LoadParts>,
    pub k: usize,
}

impl DeviceLoads {
    /// Legacy scalar form of [`DeviceLoads::of_req`].
    pub fn of(g: &OpGraph, sc: &Scenario, p: &Placement) -> DeviceLoads {
        Self::of_req(g, &sc.to_request(), p)
    }

    /// Compute loads of every device. Accelerator comm follows §3 (pay
    /// `c_u` for boundary crossings, once per direction per node); CPU
    /// devices pay compute only (RAM access is free in the model).
    /// Compute times divide by the device's class `speed`; comm prices
    /// through the fleet's per-pair topology accessor (DESIGN.md §9):
    /// each in-transfer at the actual producer→consumer pair, each
    /// out-transfer once at its *worst* destination pair (one egress
    /// serialization priced at the slowest consumer link — exactly the
    /// scalar pay-once rule when the topology is uniform or absent).
    pub fn of_req(g: &OpGraph, req: &PlanRequest, p: &Placement) -> DeviceLoads {
        let (k, l) = (req.fleet.k(), req.fleet.l());
        let nd = k + l.max(1);
        let mut fw = vec![LoadParts::default(); nd];
        let mut bw = vec![LoadParts::default(); nd];

        for v in 0..g.n() {
            let d = p.assignment[v];
            let idx = d.index(k);
            let parts = match g.nodes[v].kind {
                NodeKind::Forward => &mut fw,
                NodeKind::Backward => &mut bw,
            };
            match d {
                Device::Cpu(j) => {
                    parts[idx].compute += g.nodes[v].p_cpu / req.fleet.cpu_speed(j)
                }
                Device::Acc(i) => {
                    parts[idx].compute += g.nodes[v].p_acc / req.fleet.acc_speed(i);
                    // out-comm: v's output leaves the device, priced at the
                    // worst destination pair it must reach
                    let mut out = 0.0_f64;
                    let mut crossed = false;
                    for &w in &g.succs[v] {
                        if p.assignment[w] != d {
                            crossed = true;
                            out = out.max(req.fleet.transfer_cost(
                                idx,
                                p.assignment[w].index(k),
                                g.nodes[v].comm,
                            ));
                        }
                    }
                    if crossed {
                        parts[idx].comm_out += out;
                    }
                }
            }
        }
        // in-comm: for each accelerator, each external producer u feeding it
        // is paid once (per §3 / Fig. 6 CommIn), in the direction of the
        // *consumer* side nodes, priced at the producer's pair.
        for i in 0..k {
            let d = Device::Acc(i);
            for dir in [NodeKind::Forward, NodeKind::Backward] {
                let mut paid = BitSet::new(g.n());
                for v in 0..g.n() {
                    if p.assignment[v] != d || g.nodes[v].kind != dir {
                        continue;
                    }
                    for &u in &g.preds[v] {
                        if p.assignment[u] != d && !paid.contains(u) {
                            paid.insert(u);
                            let parts =
                                if dir == NodeKind::Forward { &mut fw } else { &mut bw };
                            parts[i].comm_in += req.fleet.transfer_cost(
                                p.assignment[u].index(k),
                                i,
                                g.nodes[u].comm,
                            );
                        }
                    }
                }
            }
        }
        DeviceLoads { fw, bw, k }
    }

    /// Combined load of device `idx` under the scenario's comm model and
    /// training schedule (FW + BW for PipeDream-style accounting).
    pub fn device_total(&self, idx: usize, sc: &Scenario) -> f64 {
        self.fw[idx].total(sc) + self.bw[idx].total(sc)
    }

    pub fn device_total_req(&self, idx: usize, req: &PlanRequest) -> f64 {
        self.fw[idx].total_req(req) + self.bw[idx].total_req(req)
    }
}

/// Legacy scalar form of [`max_load_req`].
pub fn max_load(g: &OpGraph, sc: &Scenario, p: &Placement) -> f64 {
    max_load_req(g, &sc.to_request(), p)
}

/// Throughput objective: Time-Per-Sample of the pipelined schedule.
///
/// * Inference graphs: `max_i load_i` (§5.1).
/// * Training graphs, PipeDream schedule: `max_i (FW_i + BW_i)` (§5.3).
/// * Training graphs, GPipe schedule: `max_i FW_i + max_i BW_i` (App. A).
///
/// Returns `INFINITY` for memory-infeasible (per-class caps) or
/// accelerator-unsupported placements.
pub fn max_load_req(g: &OpGraph, req: &PlanRequest, p: &Placement) -> f64 {
    // memory feasibility
    if p.check_memory_req(g, req).is_err() {
        return f64::INFINITY;
    }
    for v in 0..g.n() {
        if p.assignment[v].is_acc() && g.nodes[v].p_acc.is_infinite() {
            return f64::INFINITY;
        }
    }
    let loads = DeviceLoads::of_req(g, req, p);
    let nd = req.fleet.k() + req.fleet.l().max(1);
    let is_training = g.nodes.iter().any(|n| n.kind == NodeKind::Backward);
    if !is_training || req.train_schedule == TrainSchedule::PipeDream {
        (0..nd).map(|i| loads.device_total_req(i, req)).fold(0.0, f64::max)
    } else {
        let max_fw = (0..nd).map(|i| loads.fw[i].total_req(req)).fold(0.0, f64::max);
        let max_bw = (0..nd).map(|i| loads.bw[i].total_req(req)).fold(0.0, f64::max);
        max_fw + max_bw
    }
}

/// Legacy scalar form of [`latency_req`].
pub fn latency(g: &OpGraph, sc: &Scenario, p: &Placement) -> f64 {
    latency_req(g, &sc.to_request(), p)
}

/// Latency objective (§4): makespan of the single-sample schedule where
/// each accelerator piece runs uninterrupted (in-transfer → compute →
/// out-transfer) once all its external inputs are in RAM, pieces on one
/// accelerator serialize, and CPU nodes run whenever their inputs are ready
/// (width ≤ ℓ assumed, as in the paper). Compute times divide by the
/// device's class `speed`.
///
/// Non-contiguous accelerator sets are decomposed into contiguous virtual
/// pieces first (§4.1 semantics with `q` = number of pieces).
///
/// Builds the graph's topological order and reachability matrix once per
/// call; evaluators in a loop (the latency IP's leaves) should use
/// [`latency_in`] with the shared
/// [`crate::coordinator::context::ProblemCtx`] artifacts instead.
pub fn latency_req(g: &OpGraph, req: &PlanRequest, p: &Placement) -> f64 {
    let order = topo::toposort(g).expect("latency requires a DAG");
    let reach = topo::reachability_matrix(g);
    latency_in(g, req, p, &order, &reach)
}

/// [`latency_req`] against a caller-supplied topological order and
/// reachability matrix (the `ProblemCtx::orig_order` / `orig_reach`
/// artifacts) — no per-evaluation matrix rebuild.
pub fn latency_in(
    g: &OpGraph,
    req: &PlanRequest,
    p: &Placement,
    order: &[usize],
    reach: &BitMatrix,
) -> f64 {
    latency_with_granularity(g, req, p, false, order, reach)
        .unwrap_or_else(|| {
            // Mutually-dependent pieces (two contiguous sets CAN depend on
            // each other through direct edges) make the macro graph cyclic;
            // fall back to per-node accelerator invocations (Fig. 4 with
            // q = |S|), which is always schedulable.
            latency_with_granularity(g, req, p, true, order, reach)
                .expect("singleton pieces must be schedulable")
        })
}

fn latency_with_granularity(
    g: &OpGraph,
    req: &PlanRequest,
    p: &Placement,
    singleton_pieces: bool,
    order: &[usize],
    reach: &BitMatrix,
) -> Option<f64> {
    let n = g.n();
    if n == 0 {
        return Some(0.0);
    }
    let k = req.fleet.k();
    // Build pieces: every accelerator's node set split into contiguous
    // chunks; CPU nodes are singleton "pieces" with piece id usize::MAX.
    let mut piece_of: Vec<usize> = vec![usize::MAX; n];
    let mut pieces: Vec<(usize, BitSet)> = Vec::new(); // (device, nodes)
    for i in 0..k {
        let set = p.set_of(Device::Acc(i), n);
        if set.is_empty() {
            continue;
        }
        let chunks = if singleton_pieces {
            set.iter().map(|v| BitSet::from_iter(n, [v])).collect()
        } else {
            contiguity::virtual_device_split_in(g, order, reach, &set)
        };
        for chunk in chunks {
            let id = pieces.len();
            for v in chunk.iter() {
                piece_of[v] = id;
            }
            pieces.push((i, chunk));
        }
    }

    // Build the macro-DAG: each piece is one macro node, each CPU node a
    // singleton. A piece can only start when ALL its external inputs are
    // done — which need not precede its first member in a node-level topo
    // order — so scheduling walks the macro graph in macro-topological
    // order instead. (Contiguity of the pieces guarantees the macro graph
    // is acyclic: a macro cycle through a piece would be a Def.-3.1
    // violation for that piece.)
    let num_macro = pieces.len()
        + (0..n).filter(|&v| piece_of[v] == usize::MAX).count();
    let mut macro_of: Vec<usize> = vec![usize::MAX; n];
    let mut next_macro = pieces.len();
    for v in 0..n {
        if piece_of[v] == usize::MAX {
            macro_of[v] = next_macro;
            next_macro += 1;
        } else {
            macro_of[v] = piece_of[v];
        }
    }
    let mut madj: Vec<Vec<usize>> = vec![Vec::new(); num_macro];
    let mut mindeg = vec![0usize; num_macro];
    let mut seen = std::collections::HashSet::new();
    for (u, v) in g.edges() {
        let (a, b) = (macro_of[u], macro_of[v]);
        if a != b && seen.insert((a, b)) {
            madj[a].push(b);
            mindeg[b] += 1;
        }
    }
    let mut queue: Vec<usize> = (0..num_macro).filter(|&m| mindeg[m] == 0).collect();
    let mut done_at: Vec<f64> = vec![0.0; n];
    let mut acc_free: Vec<f64> = vec![0.0; k]; // device serialization (14)
    let mut head = 0;
    let mut processed = 0;
    // map macro id back to its cpu node for singletons
    let mut cpu_node_of: Vec<usize> = vec![usize::MAX; num_macro];
    for v in 0..n {
        if piece_of[v] == usize::MAX {
            cpu_node_of[macro_of[v]] = v;
        }
    }
    while head < queue.len() {
        let m = queue[head];
        head += 1;
        processed += 1;
        if m < pieces.len() {
            let (dev, ref set) = pieces[m];
            let speed = req.fleet.acc_speed(dev);
            let mut start: f64 = acc_free[dev];
            let mut comm_in = 0.0;
            let mut paid = BitSet::new(n);
            let mut compute = 0.0;
            let mut comm_out = 0.0;
            for w in set.iter() {
                compute += g.nodes[w].p_acc / speed;
                for &u in &g.preds[w] {
                    if !set.contains(u) {
                        start = start.max(done_at[u]);
                        if !paid.contains(u) {
                            paid.insert(u);
                            // producer→piece pair pricing; same-device
                            // cross-piece transfers keep paying `c_u`
                            // (diagonal transfer_cost is exactly `s`)
                            comm_in += req.fleet.transfer_cost(
                                p.assignment[u].index(k),
                                dev,
                                g.nodes[u].comm,
                            );
                        }
                    }
                }
                // out-transfer priced at the worst external destination
                let mut out = 0.0_f64;
                let mut crossed = false;
                for &x in &g.succs[w] {
                    if !set.contains(x) {
                        crossed = true;
                        out = out.max(req.fleet.transfer_cost(
                            dev,
                            p.assignment[x].index(k),
                            g.nodes[w].comm,
                        ));
                    }
                }
                if crossed {
                    comm_out += out;
                }
            }
            let finish = start + comm_in + compute + comm_out;
            acc_free[dev] = finish;
            for w in set.iter() {
                done_at[w] = finish;
            }
        } else {
            // CPU node: longest-path recurrence (constraints (8)–(9)).
            let v = cpu_node_of[m];
            let ready = g.preds[v].iter().map(|&u| done_at[u]).fold(0.0, f64::max);
            let speed = match p.assignment[v] {
                Device::Cpu(j) => req.fleet.cpu_speed(j),
                Device::Acc(_) => 1.0, // unreachable: acc nodes are pieces
            };
            done_at[v] = ready + g.nodes[v].p_cpu / speed;
        }
        for &nxt in &madj[m] {
            mindeg[nxt] -= 1;
            if mindeg[nxt] == 0 {
                queue.push(nxt);
            }
        }
    }
    if processed != num_macro {
        return None; // macro cycle between pieces of different devices
    }
    Some(done_at.iter().copied().fold(0.0, f64::max))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn chain_g(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(4.0).acc(1.0).mem(1.0).comm(0.5));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn max_load_single_device() {
        let g = chain_g(4);
        let sc = Scenario::new(1, 1, f64::INFINITY);
        let p = Placement::new(vec![Device::Acc(0); 4], 0.0, "t");
        // all on one accelerator: no boundary comm, load = 4
        assert!((max_load(&g, &sc, &p) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn max_load_balanced_split_pays_comm() {
        let g = chain_g(4);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = Placement::new(
            vec![Device::Acc(0), Device::Acc(0), Device::Acc(1), Device::Acc(1)],
            0.0,
            "t",
        );
        // acc0: compute 2 + out c_1=0.5 → 2.5 ; acc1: in c_1 + compute 2 → 2.5
        assert!((max_load(&g, &sc, &p) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn max_load_overlap_model() {
        let g = chain_g(4);
        let mut sc = Scenario::new(2, 1, f64::INFINITY);
        sc.comm_model = crate::coordinator::placement::CommModel::Overlap;
        let p = Placement::new(
            vec![Device::Acc(0), Device::Acc(0), Device::Acc(1), Device::Acc(1)],
            0.0,
            "t",
        );
        // max(compute=2, comm=0.5) per device
        assert!((max_load(&g, &sc, &p) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn max_load_memory_infeasible() {
        let g = chain_g(4);
        let sc = Scenario::new(1, 1, 2.0);
        let p = Placement::new(vec![Device::Acc(0); 4], 0.0, "t");
        assert!(max_load(&g, &sc, &p).is_infinite());
    }

    #[test]
    fn training_schedules_differ() {
        // fw 0->1, bw 2 (partner 1) -> 3 (partner 0), heavy bw
        let mut g = OpGraph::new();
        g.add_node(Node::new("f0").acc(1.0));
        g.add_node(Node::new("f1").acc(3.0));
        let mut b1 = Node::new("b1").acc(3.0).backward();
        b1.fw_partner = Some(1);
        g.add_node(b1);
        let mut b0 = Node::new("b0").acc(1.0).backward();
        b0.fw_partner = Some(0);
        g.add_node(b0);
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g.add_edge(2, 3);
        let p = Placement::new(
            vec![Device::Acc(0), Device::Acc(1), Device::Acc(1), Device::Acc(0)],
            0.0,
            "t",
        );
        let mut sc = Scenario::new(2, 1, f64::INFINITY);
        // zero comm for clarity
        let pd = max_load(&g, &sc, &p); // max(1+1, 3+3) = 6
        assert!((pd - 6.0).abs() < 1e-9);
        sc.train_schedule = TrainSchedule::GPipe;
        let gp = max_load(&g, &sc, &p); // max FW (3) + max BW (3) = 6
        assert!((gp - 6.0).abs() < 1e-9);
    }

    #[test]
    fn heterogeneous_speed_scales_compute_not_comm() {
        use crate::coordinator::placement::{DeviceClass, Fleet, PlanRequest};
        let g = chain_g(4); // acc 1.0 each, comm 0.5
        let req = PlanRequest::new(Fleet::new(vec![
            DeviceClass::acc("fast", 1, f64::INFINITY).speed(2.0),
            DeviceClass::acc("slow", 1, f64::INFINITY),
            DeviceClass::cpu("cpu", 1),
        ]));
        let p = Placement::new(
            vec![Device::Acc(0), Device::Acc(0), Device::Acc(1), Device::Acc(1)],
            0.0,
            "t",
        );
        // fast acc0: compute 2/2 = 1 + out 0.5 = 1.5; slow acc1: in 0.5 +
        // compute 2 = 2.5 — comm is NOT scaled by speed
        assert!((max_load_req(&g, &req, &p) - 2.5).abs() < 1e-9);
        // latency too: pieces on the fast device compute at half cost
        let solo = Placement::new(vec![Device::Acc(0); 4], 0.0, "t");
        assert!((latency_req(&g, &req, &solo) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn topology_scales_cross_island_comm() {
        use crate::coordinator::placement::{Fleet, PlanRequest};
        let g = chain_g(4); // acc 1.0 each, comm 0.5
        // two 2-acc islands {0,1} / {2,3}: a 0↔2 crossing slows down 8x
        // (the intra links at bw 8 set the normalization reference)
        let fleet = Fleet::parse("4xacc,1xcpu,topo=islands:2x2@8/1").unwrap();
        let req = PlanRequest::new(fleet);
        let p = Placement::new(
            vec![Device::Acc(0), Device::Acc(0), Device::Acc(2), Device::Acc(2)],
            0.0,
            "t",
        );
        // acc0: compute 2 + out 0.5*8 = 6; acc2: in 0.5*8 + compute 2 = 6
        assert!((max_load_req(&g, &req, &p) - 6.0).abs() < 1e-9);
        // latency: piece {0,1} = 2 + out 4 = 6; piece {2,3} = 6 + in 4 + 2 = 12
        assert!((latency_req(&g, &req, &p) - 12.0).abs() < 1e-9);
    }

    #[test]
    fn latency_all_cpu_is_critical_path() {
        let g = chain_g(3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = Placement::new(vec![Device::Cpu(0); 3], 0.0, "t");
        assert!((latency(&g, &sc, &p) - 12.0).abs() < 1e-9);
    }

    #[test]
    fn latency_single_acc_subgraph() {
        let g = chain_g(3);
        let sc = Scenario::new(1, 1, f64::INFINITY);
        let p = Placement::new(vec![Device::Acc(0); 3], 0.0, "t");
        // one piece, no external inputs/outputs: latency = compute 3
        assert!((latency(&g, &sc, &p) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn latency_mixed_chain() {
        // cpu node then accelerator pair: cpu 4, then in-comm c_0 0.5 +
        // compute 2 (no out) = 6.5
        let g = chain_g(3);
        let sc = Scenario::new(1, 1, f64::INFINITY);
        let p = Placement::new(vec![Device::Cpu(0), Device::Acc(0), Device::Acc(0)], 0.0, "t");
        assert!((latency(&g, &sc, &p) - 6.5).abs() < 1e-9);
    }

    #[test]
    fn latency_noncontiguous_serializes_pieces() {
        // chain of 5; acc0 holds {0, 2, 4} (3 pieces), cpu holds {1, 3}
        let g = chain_g(5);
        let sc = Scenario::new(1, 1, f64::INFINITY);
        let p = Placement::new(
            vec![
                Device::Acc(0),
                Device::Cpu(0),
                Device::Acc(0),
                Device::Cpu(0),
                Device::Acc(0),
            ],
            0.0,
            "t",
        );
        // piece {0}: compute 1 + out 0.5 = 1.5 → node0 done 1.5
        // cpu 1: 1.5 + 4 = 5.5
        // piece {2}: start max(5.5, acc free 1.5) = 5.5 + in 0.5 + 1 + out 0.5 = 7.5
        // cpu 3: 7.5 + 4 = 11.5
        // piece {4}: 11.5 + in 0.5 + 1 = 13
        assert!((latency(&g, &sc, &p) - 13.0).abs() < 1e-9);
    }

    #[test]
    fn latency_parallel_branches_overlap() {
        // diamond with branch nodes on different accelerators runs branches
        // in parallel.
        let mut g = OpGraph::new();
        for i in 0..4 {
            g.add_node(Node::new(format!("n{i}")).cpu(1.0).acc(2.0).comm(0.0));
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = Placement::new(
            vec![Device::Cpu(0), Device::Acc(0), Device::Acc(1), Device::Cpu(0)],
            0.0,
            "t",
        );
        // cpu0: 1; branches in parallel on separate accs: +2 → 3; sink: +1 → 4
        assert!((latency(&g, &sc, &p) - 4.0).abs() < 1e-9);
    }
}

//! Inception-v3 layer graph (≈ 326 layers in the paper's input). The
//! defining property for the partitioning problem is the **wide branching**
//! of the inception modules (4 parallel towers per module), which is what
//! drives the ideal count to ~36k and makes the exact DP slow (Table 1) —
//! the generator reproduces that structure faithfully: stem, 11 inception
//! modules (A×3, B×1, C×4, D×1, E×2) with per-paper tower compositions,
//! auxiliary head, and classifier.

use super::costs::{mb_f32, CostModel};
use super::{add_op, append_backward};
use crate::graph::{NodeId, OpGraph};

const BATCH: f64 = 8.0;

struct Gen {
    g: OpGraph,
    m: CostModel,
}

impl Gen {
    fn conv(&mut self, name: &str, input: NodeId, cin: f64, cout: f64, k: f64, spatial: f64) -> NodeId {
        let out_mb = mb_f32(BATCH * cout * spatial * spatial);
        let flops = 2.0 * BATCH * spatial * spatial * cout * cin * k * k;
        let conv = add_op(&mut self.g, format!("{name}_conv"), self.m.compute_op(flops, out_mb, mb_f32(cout * cin * k * k)), &[input]);
        let bn = add_op(&mut self.g, format!("{name}_bn"), self.m.memory_op(2.0 * out_mb, out_mb), &[conv]);
        add_op(&mut self.g, format!("{name}_relu"), self.m.memory_op(2.0 * out_mb, out_mb), &[bn])
    }

    fn pool(&mut self, name: &str, input: NodeId, c: f64, spatial: f64) -> NodeId {
        let mb = mb_f32(BATCH * c * spatial * spatial);
        add_op(&mut self.g, format!("{name}_pool"), self.m.memory_op(2.0 * mb, mb), &[input])
    }

    fn concat(&mut self, name: &str, inputs: &[NodeId], c: f64, spatial: f64) -> NodeId {
        let mb = mb_f32(BATCH * c * spatial * spatial);
        add_op(&mut self.g, format!("{name}_concat"), self.m.memory_op(2.0 * mb, mb), inputs)
    }

    /// Inception-A-style module: 4 towers (1x1 | 5x5 | double 3x3 | pool).
    fn module_a(&mut self, name: &str, input: NodeId, cin: f64, spatial: f64) -> NodeId {
        let t1 = self.conv(&format!("{name}_t1"), input, cin, 64.0, 1.0, spatial);
        let t2a = self.conv(&format!("{name}_t2a"), input, cin, 48.0, 1.0, spatial);
        let t2 = self.conv(&format!("{name}_t2b"), t2a, 48.0, 64.0, 5.0, spatial);
        let t3a = self.conv(&format!("{name}_t3a"), input, cin, 64.0, 1.0, spatial);
        let t3b = self.conv(&format!("{name}_t3b"), t3a, 64.0, 96.0, 3.0, spatial);
        let t3 = self.conv(&format!("{name}_t3c"), t3b, 96.0, 96.0, 3.0, spatial);
        let p = self.pool(&format!("{name}_t4"), input, cin, spatial);
        let t4 = self.conv(&format!("{name}_t4b"), p, cin, 64.0, 1.0, spatial);
        self.concat(name, &[t1, t2, t3, t4], 288.0, spatial)
    }

    /// Factorized-7x7 module (Inception-B/C style): 4 towers with 1x7/7x1
    /// chains.
    fn module_c(&mut self, name: &str, input: NodeId, cin: f64, spatial: f64) -> NodeId {
        let c = 192.0;
        let t1 = self.conv(&format!("{name}_t1"), input, cin, c, 1.0, spatial);
        let t2a = self.conv(&format!("{name}_t2a"), input, cin, c, 1.0, spatial);
        let t2b = self.conv(&format!("{name}_t2b"), t2a, c, c, 1.7, spatial); // 1x7
        let t2 = self.conv(&format!("{name}_t2c"), t2b, c, c, 1.7, spatial); // 7x1
        let t3a = self.conv(&format!("{name}_t3a"), input, cin, c, 1.0, spatial);
        let t3b = self.conv(&format!("{name}_t3b"), t3a, c, c, 1.7, spatial);
        let t3c = self.conv(&format!("{name}_t3c"), t3b, c, c, 1.7, spatial);
        let t3d = self.conv(&format!("{name}_t3d"), t3c, c, c, 1.7, spatial);
        let t3 = self.conv(&format!("{name}_t3e"), t3d, c, c, 1.7, spatial);
        let p = self.pool(&format!("{name}_t4"), input, cin, spatial);
        let t4 = self.conv(&format!("{name}_t4b"), p, cin, c, 1.0, spatial);
        self.concat(name, &[t1, t2, t3, t4], 768.0, spatial)
    }

    /// Expanded module (Inception-E style): towers that themselves fan out.
    fn module_e(&mut self, name: &str, input: NodeId, cin: f64, spatial: f64) -> NodeId {
        let t1 = self.conv(&format!("{name}_t1"), input, cin, 320.0, 1.0, spatial);
        let t2a = self.conv(&format!("{name}_t2a"), input, cin, 384.0, 1.0, spatial);
        let t2b1 = self.conv(&format!("{name}_t2b1"), t2a, 384.0, 384.0, 1.3, spatial);
        let t2b2 = self.conv(&format!("{name}_t2b2"), t2a, 384.0, 384.0, 1.3, spatial);
        let t3a = self.conv(&format!("{name}_t3a"), input, cin, 448.0, 1.0, spatial);
        let t3b = self.conv(&format!("{name}_t3b"), t3a, 448.0, 384.0, 3.0, spatial);
        let t3c1 = self.conv(&format!("{name}_t3c1"), t3b, 384.0, 384.0, 1.3, spatial);
        let t3c2 = self.conv(&format!("{name}_t3c2"), t3b, 384.0, 384.0, 1.3, spatial);
        let p = self.pool(&format!("{name}_t4"), input, cin, spatial);
        let t4 = self.conv(&format!("{name}_t4b"), p, cin, 192.0, 1.0, spatial);
        self.concat(name, &[t1, t2b1, t2b2, t3c1, t3c2, t4], 2048.0, spatial)
    }

    /// Grid-reduction module: 2 conv towers + pool, concatenated.
    fn reduction(&mut self, name: &str, input: NodeId, cin: f64, cout: f64, spatial: f64) -> NodeId {
        let t1 = self.conv(&format!("{name}_t1"), input, cin, cout / 2.0, 3.0, spatial);
        let t2a = self.conv(&format!("{name}_t2a"), input, cin, 64.0, 1.0, spatial);
        let t2b = self.conv(&format!("{name}_t2b"), t2a, 64.0, 96.0, 3.0, spatial);
        let t2 = self.conv(&format!("{name}_t2c"), t2b, 96.0, cout / 2.0, 3.0, spatial);
        let p = self.pool(&format!("{name}_t3"), input, cin, spatial);
        self.concat(name, &[t1, t2, p], cout, spatial)
    }
}

pub fn inception_v3_layer_graph(training: bool) -> OpGraph {
    let mut gen = Gen { g: OpGraph::new(), m: CostModel::default() };
    let input = add_op(&mut gen.g, "input_0", gen.m.memory_op(mb_f32(BATCH * 3.0 * 299.0 * 299.0), mb_f32(BATCH * 3.0 * 299.0 * 299.0)), &[]);

    // stem: 5 convs + 2 pools
    let s1 = gen.conv("stem1", input, 3.0, 32.0, 3.0, 149.0);
    let s2 = gen.conv("stem2", s1, 32.0, 32.0, 3.0, 147.0);
    let s3 = gen.conv("stem3", s2, 32.0, 64.0, 3.0, 147.0);
    let p1 = gen.pool("stem4", s3, 64.0, 73.0);
    let s5 = gen.conv("stem5", p1, 64.0, 80.0, 1.0, 73.0);
    let s6 = gen.conv("stem6", s5, 80.0, 192.0, 3.0, 71.0);
    let p2 = gen.pool("stem7", s6, 192.0, 35.0);

    // 3× module A at 35×35
    let a1 = gen.module_a("mixA1", p2, 192.0, 35.0);
    let a2 = gen.module_a("mixA2", a1, 288.0, 35.0);
    let a3 = gen.module_a("mixA3", a2, 288.0, 35.0);
    // reduction to 17×17
    let r1 = gen.reduction("redB", a3, 288.0, 768.0, 17.0);
    // 4× module C at 17×17
    let c1 = gen.module_c("mixC1", r1, 768.0, 17.0);
    let c2 = gen.module_c("mixC2", c1, 768.0, 17.0);
    let c3 = gen.module_c("mixC3", c2, 768.0, 17.0);
    let c4 = gen.module_c("mixC4", c3, 768.0, 17.0);
    // auxiliary classifier branch (training-style aux head kept in graph)
    let auxp = gen.pool("aux1", c4, 768.0, 5.0);
    let auxc = gen.conv("aux2", auxp, 768.0, 128.0, 1.0, 5.0);
    let auxf = gen.conv("aux3", auxc, 128.0, 768.0, 5.0, 1.0);
    let aux_out = add_op(&mut gen.g, "aux_fc", gen.m.compute_op(2.0 * BATCH * 768.0 * 1000.0, mb_f32(BATCH * 1000.0), mb_f32(768.0 * 1000.0)), &[auxf]);
    // reduction to 8×8
    let r2 = gen.reduction("redD", c4, 768.0, 1280.0, 8.0);
    // 2× module E at 8×8
    let e1 = gen.module_e("mixE1", r2, 1280.0, 8.0);
    let e2 = gen.module_e("mixE2", e1, 2048.0, 8.0);
    // classifier
    let gap = gen.pool("gap", e2, 2048.0, 1.0);
    let fc = add_op(&mut gen.g, "fc_0", gen.m.compute_op(2.0 * BATCH * 2048.0 * 1000.0, mb_f32(BATCH * 1000.0), mb_f32(2048.0 * 1000.0)), &[gap]);
    let _join = add_op(&mut gen.g, "output_0", gen.m.memory_op(0.1, 0.1), &[fc, aux_out]);

    if training {
        append_backward(&gen.g, 2.0)
    } else {
        gen.g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ideals::IdealLattice;
    use crate::graph::topo::{is_dag, width};

    #[test]
    fn node_count_near_paper() {
        let g = inception_v3_layer_graph(false);
        let ratio = g.n() as f64 / 326.0;
        assert!((0.6..1.3).contains(&ratio), "layers {} vs paper 326", g.n());
        assert!(is_dag(&g));
    }

    #[test]
    fn strongly_branching() {
        let g = inception_v3_layer_graph(false);
        // inception towers make the antichain wide
        assert!(width(&g) >= 4, "width {}", width(&g));
        // ideal count far exceeds |V| (paper: 36596 for 326 nodes)
        let count = IdealLattice::count(&g, 200_000);
        assert!(count > 5 * g.n(), "ideals {count} vs nodes {}", g.n());
    }

    #[test]
    fn training_variant_valid() {
        let g = inception_v3_layer_graph(true);
        assert!(is_dag(&g));
        assert_eq!(g.n(), 2 * inception_v3_layer_graph(false).n());
    }
}

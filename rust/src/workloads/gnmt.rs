//! GNMT layer graph (≈ 96 layers in the paper's PipeDream input): an
//! 8-layer bidirectional-ish LSTM encoder, attention block, 8-layer LSTM
//! decoder with attention feeding every decoder layer (the cross edges are
//! what give GNMT its ~18k ideals despite only 96 nodes), embedding and
//! softmax/projection head. Node names use `lstmN_*` prefixes so the
//! expert BlockBands rule maps each LSTM layer to a device, as in
//! [SVL14, WSC+16].

use super::costs::{mb_f32, CostModel};
use super::{add_op, append_backward};
use crate::graph::{NodeId, OpGraph};

const BATCH: f64 = 64.0;
const SEQ: f64 = 50.0;
const H: f64 = 1024.0;

pub fn gnmt_layer_graph(training: bool) -> OpGraph {
    let m = CostModel::default();
    let mut g = OpGraph::new();
    let act = mb_f32(BATCH * SEQ * H);
    let lstm_flops = 2.0 * BATCH * SEQ * (8.0 * H * H); // 4 gates × 2 matmuls
    let lstm_params = mb_f32(8.0 * H * H);

    // encoder: embedding + 8 LSTM layers, each layer = 4 sub-nodes
    // (gates-matmul, recurrent, elementwise, dropout) → names lstmN_*
    let emb_e = add_op(&mut g, "encemb_0", m.compute_op(BATCH * SEQ * H, act, mb_f32(32000.0 * H)), &[]);
    let mut x = emb_e;
    let mut enc_outputs: Vec<NodeId> = Vec::new();
    for l in 0..8 {
        let p = |s: &str| format!("lstm{l}_{s}");
        let gates = add_op(&mut g, p("gates"), m.compute_op(lstm_flops * 0.5, act, lstm_params * 0.5), &[x]);
        let recur = add_op(&mut g, p("recur"), m.compute_op(lstm_flops * 0.5, act, lstm_params * 0.5), &[gates]);
        let elem = add_op(&mut g, p("elem"), m.memory_op(4.0 * act, act), &[recur]);
        let drop = add_op(&mut g, p("drop"), m.memory_op(2.0 * act, act), &[elem]);
        // residual connections from layer 2 onward (GNMT)
        if l >= 2 {
            g.add_edge(x, drop);
        }
        x = drop;
        enc_outputs.push(drop);
    }
    // attention block: scores, softmax, context (3 nodes), reads the last
    // encoder layer and feeds every decoder layer
    let att_scores = add_op(&mut g, "attn_scores", m.compute_op(2.0 * BATCH * SEQ * SEQ * H, mb_f32(BATCH * SEQ * SEQ), 0.0), &[x]);
    let att_sm = add_op(&mut g, "attn_softmax", m.memory_op(2.0 * mb_f32(BATCH * SEQ * SEQ), mb_f32(BATCH * SEQ * SEQ)), &[att_scores]);
    let att_ctx = add_op(&mut g, "attn_context", m.compute_op(2.0 * BATCH * SEQ * SEQ * H, act, 0.0), &[att_sm, x]);

    // decoder: embedding + 8 LSTM layers × 4 sub-nodes, running in
    // PARALLEL with the encoder (teacher forcing); the attention context
    // joins at the output combination. This encoder ∥ decoder structure is
    // what blows up the ideal count relative to |V| (paper: ~18k ideals
    // for 96 layers).
    let emb_d = add_op(&mut g, "decemb_0", m.compute_op(BATCH * SEQ * H, act, mb_f32(32000.0 * H)), &[]);
    let mut y = emb_d;
    for l in 8..16 {
        let p = |s: &str| format!("lstm{l}_{s}");
        let gates = add_op(&mut g, p("gates"), m.compute_op(lstm_flops, 2.0 * act, lstm_params), &[y]);
        let recur = add_op(&mut g, p("recur"), m.compute_op(lstm_flops * 0.5, act, lstm_params * 0.5), &[gates]);
        let elem = add_op(&mut g, p("elem"), m.memory_op(4.0 * act, act), &[recur]);
        let drop = add_op(&mut g, p("drop"), m.memory_op(2.0 * act, act), &[elem]);
        if l >= 10 {
            g.add_edge(y, drop);
        }
        y = drop;
    }
    // head: attention context + decoder state combine, then projection
    let combine = add_op(&mut g, "attncomb_0", m.memory_op(3.0 * act, act), &[y, att_ctx]);
    let proj = add_op(&mut g, "proj_0", m.compute_op(2.0 * BATCH * SEQ * H * 32000.0, mb_f32(BATCH * SEQ * 320.0), mb_f32(H * 32000.0)), &[combine]);
    let sm = add_op(&mut g, "outsm_0", m.memory_op(2.0 * mb_f32(BATCH * SEQ * 320.0), mb_f32(BATCH * SEQ * 320.0)), &[proj]);
    let _out = add_op(&mut g, "output_0", m.memory_op(0.1, 0.1), &[sm]);

    if training {
        append_backward(&g, 2.0)
    } else {
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ideals::IdealLattice;
    use crate::graph::topo::is_dag;

    #[test]
    fn node_count_near_paper() {
        let g = gnmt_layer_graph(false);
        let ratio = g.n() as f64 / 96.0;
        assert!((0.6..1.4).contains(&ratio), "layers {} vs paper 96", g.n());
        assert!(is_dag(&g));
        assert_eq!(gnmt_layer_graph(true).n(), 2 * g.n());
    }

    #[test]
    fn attention_cross_edges_inflate_ideals() {
        // the decoder/encoder parallel structure gives many ideals relative
        // to the node count (paper: 17914 for 96 nodes)
        let g = gnmt_layer_graph(false);
        let count = IdealLattice::count(&g, 500_000);
        assert!(count > 10 * g.n(), "ideals {count} nodes {}", g.n());
    }

    #[test]
    fn lstm_blocks_are_named_for_expert_banding() {
        let g = gnmt_layer_graph(false);
        let lstm_nodes = g.nodes.iter().filter(|n| n.name.starts_with("lstm")).count();
        assert!(lstm_nodes >= 64);
    }
}

//! BERT workload generators (operator and layer granularity).
//!
//! Operator graphs follow the ONNX-style decomposition of a transformer
//! encoder: per layer, fused-attention sub-ops (Q/K/V projections with
//! reshape/transpose plumbing, scaled QKᵀ, mask-add, softmax, context
//! matmul, output projection), the residual/LayerNorm pairs and the GELU
//! MLP — ~70 ops per layer plus embedding and pooler blocks, matching the
//! paper's node counts (BERT-3: 235 ops) within a few percent.
//!
//! Dimensions: hidden 768, heads 12, seq 128, batch 4, FFN 3072 (BERT
//! base).

use super::costs::{mb_f32, CostModel};
use super::{add_op, append_backward};
use crate::graph::{NodeId, OpGraph};

const H: f64 = 768.0;
const S: f64 = 128.0;
const B: f64 = 4.0;
const FFN: f64 = 3072.0;
const HEADS: f64 = 12.0;

/// BERT operator graph with `layers` encoder layers; `training` appends
/// the mirrored backward pass (colocated, reversed edges).
pub fn bert_op_graph(layers: usize, training: bool) -> OpGraph {
    let m = CostModel::default();
    let mut g = OpGraph::new();
    let act = mb_f32(B * S * H);

    // --- embedding block (≈ 22 ops) ---
    let ids = add_op(&mut g, "emb_ids", m.memory_op(0.01, 0.01), &[]);
    let tok = add_op(
        &mut g,
        "emb_tok_gather",
        m.compute_op(B * S * H, act, mb_f32(30522.0 * H)),
        &[ids],
    );
    let pos = add_op(&mut g, "emb_pos_gather", m.compute_op(B * S * H, act, mb_f32(512.0 * H)), &[ids]);
    let seg = add_op(&mut g, "emb_seg_gather", m.compute_op(B * S * H, act, mb_f32(2.0 * H)), &[ids]);
    let sum1 = add_op(&mut g, "emb_add1", m.memory_op(2.0 * act, act), &[tok, pos]);
    let sum2 = add_op(&mut g, "emb_add2", m.memory_op(2.0 * act, act), &[sum1, seg]);
    let mut x = layer_norm(&mut g, &m, "emb_ln", sum2, act);

    // --- encoder layers ---
    for l in 0..layers {
        x = encoder_layer(&mut g, &m, l, x, act);
    }

    // --- pooler + classifier head (≈ 8 ops) ---
    let pool_slice = add_op(&mut g, "pool_slice", m.memory_op(act, act / S), &[x]);
    let pool_mm = add_op(
        &mut g,
        "pool_dense",
        m.compute_op(2.0 * B * H * H, mb_f32(B * H), mb_f32(H * H)),
        &[pool_slice],
    );
    let pool_tanh = add_op(&mut g, "pool_tanh", m.memory_op(mb_f32(B * H) * 2.0, mb_f32(B * H)), &[pool_mm]);
    let logits = add_op(
        &mut g,
        "cls_dense",
        m.compute_op(2.0 * B * H * 2.0, mb_f32(B * 2.0), mb_f32(H * 2.0)),
        &[pool_tanh],
    );
    let _sm = add_op(&mut g, "cls_softmax", m.memory_op(mb_f32(B * 4.0), mb_f32(B * 2.0)), &[logits]);

    if training {
        append_backward(&g, 2.0)
    } else {
        g
    }
}

/// One encoder layer: ~70 ops. Returns the output node.
fn encoder_layer(g: &mut OpGraph, m: &CostModel, l: usize, input: NodeId, act: f64) -> NodeId {
    let p = |s: &str| format!("l{l}_{s}");
    let head_act = act; // B*S*H split into heads, same bytes
    let qk_flops = 2.0 * B * HEADS * S * S * (H / HEADS);
    let proj_flops = 2.0 * B * S * H * H;
    let proj_w = mb_f32(H * H);
    let attn_scores = mb_f32(B * HEADS * S * S);

    // attention-mask plumbing chained off the input (3 ops)
    let mask_sl = add_op(g, p("mask_slice"), m.memory_op(act / H, act / H), &[input]);
    let mask_cast = add_op(g, p("mask_cast"), m.memory_op(act / H, act / H), &[mask_sl]);
    let mask_mul = add_op(g, p("mask_scale"), m.memory_op(act / H, act / H), &[mask_cast]);
    // Q/K/V: dense + bias + reshape + transpose + cast (5 ops each = 15)
    let mut qkv = Vec::new();
    for name in ["q", "k", "v"] {
        let mm = add_op(g, p(&format!("{name}_mm")), m.compute_op(proj_flops, act, proj_w), &[input]);
        let bias = add_op(g, p(&format!("{name}_bias")), m.memory_op(2.0 * act, act), &[mm]);
        let rs = add_op(g, p(&format!("{name}_reshape")), m.memory_op(act, act), &[bias]);
        let tr = add_op(g, p(&format!("{name}_transpose")), m.memory_op(2.0 * act, head_act), &[rs]);
        let cast = add_op(g, p(&format!("{name}_cast")), m.memory_op(head_act, head_act), &[tr]);
        qkv.push(cast);
    }
    // scores = QKᵀ / sqrt(d) + mask; softmax (6 ops)
    let qk = add_op(g, p("qk_matmul"), m.compute_op(qk_flops, attn_scores, 0.0), &[qkv[0], qkv[1]]);
    let scale = add_op(g, p("qk_scale"), m.memory_op(2.0 * attn_scores, attn_scores), &[qk]);
    let mask = add_op(g, p("mask_add"), m.memory_op(2.0 * attn_scores, attn_scores), &[scale, mask_mul]);
    let sm_max = add_op(g, p("sm_max"), m.memory_op(attn_scores, attn_scores / S), &[mask]);
    let sm_sub = add_op(g, p("sm_sub_exp"), m.memory_op(2.0 * attn_scores, attn_scores), &[mask, sm_max]);
    let sm_sum = add_op(g, p("sm_sum"), m.memory_op(attn_scores, attn_scores / S), &[sm_sub]);
    let sm_div = add_op(g, p("sm_div"), m.memory_op(2.0 * attn_scores, attn_scores), &[sm_sub, sm_sum]);
    // attention dropout (mask gen + mul, chained)
    let dr_m = add_op(g, p("attn_dropmask"), m.memory_op(attn_scores, attn_scores), &[sm_div]);
    let dr = add_op(g, p("attn_dropout"), m.memory_op(2.0 * attn_scores, attn_scores), &[sm_div, dr_m]);
    // context = scores·V, reshape back, output proj + bias (5 ops)
    let ctx = add_op(g, p("ctx_matmul"), m.compute_op(qk_flops, head_act, 0.0), &[dr, qkv[2]]);
    let ctx_tr = add_op(g, p("ctx_transpose"), m.memory_op(2.0 * head_act, act), &[ctx]);
    let ctx_rs = add_op(g, p("ctx_reshape"), m.memory_op(act, act), &[ctx_tr]);
    let out_mm = add_op(g, p("out_mm"), m.compute_op(proj_flops, act, proj_w), &[ctx_rs]);
    let out_bias = add_op(g, p("out_bias"), m.memory_op(2.0 * act, act), &[out_mm]);
    let out_dm = add_op(g, p("out_dropmask"), m.memory_op(act, act), &[out_bias]);
    let out_dr = add_op(g, p("out_dropout"), m.memory_op(2.0 * act, act), &[out_bias, out_dm]);
    // residual + LN (1 + 8 ops)
    let res1 = add_op(g, p("res1_add"), m.memory_op(2.0 * act, act), &[input, out_dr]);
    let ln1 = layer_norm(g, m, &p("ln1"), res1, act);
    // MLP: dense(4H) + bias + gelu(4 ops) + dense(H) + bias (8 ops)
    let ffn_act = mb_f32(B * S * FFN);
    let fc1 = add_op(g, p("fc1_mm"), m.compute_op(2.0 * B * S * H * FFN, ffn_act, mb_f32(H * FFN)), &[ln1]);
    let fc1_b = add_op(g, p("fc1_bias"), m.memory_op(2.0 * ffn_act, ffn_act), &[fc1]);
    let g1 = add_op(g, p("gelu_pow"), m.memory_op(2.0 * ffn_act, ffn_act), &[fc1_b]);
    let g2 = add_op(g, p("gelu_tanh"), m.memory_op(2.0 * ffn_act, ffn_act), &[g1]);
    let g3 = add_op(g, p("gelu_mul"), m.memory_op(2.0 * ffn_act, ffn_act), &[fc1_b, g2]);
    let fc2 = add_op(g, p("fc2_mm"), m.compute_op(2.0 * B * S * FFN * H, act, mb_f32(FFN * H)), &[g3]);
    let fc2_b = add_op(g, p("fc2_bias"), m.memory_op(2.0 * act, act), &[fc2]);
    let fc2_dm = add_op(g, p("fc2_dropmask"), m.memory_op(act, act), &[fc2_b]);
    let fc2_dr = add_op(g, p("fc2_dropout"), m.memory_op(2.0 * act, act), &[fc2_b, fc2_dm]);
    // residual + LN
    let res2 = add_op(g, p("res2_add"), m.memory_op(2.0 * act, act), &[ln1, fc2_dr]);
    layer_norm(g, m, &p("ln2"), res2, act)
}

/// LayerNorm decomposed ONNX-style into 8 ops.
fn layer_norm(g: &mut OpGraph, m: &CostModel, prefix: &str, input: NodeId, act: f64) -> NodeId {
    let p = |s: &str| format!("{prefix}_{s}");
    let mean = add_op(g, p("mean"), m.memory_op(act, act / H), &[input]);
    let sub = add_op(g, p("sub"), m.memory_op(2.0 * act, act), &[input, mean]);
    let sq = add_op(g, p("sq"), m.memory_op(2.0 * act, act), &[sub]);
    let var = add_op(g, p("var"), m.memory_op(act, act / H), &[sq]);
    let eps = add_op(g, p("add_eps"), m.memory_op(act / H, act / H), &[var]);
    let rsqrt = add_op(g, p("rsqrt"), m.memory_op(act / H, act / H), &[eps]);
    let norm = add_op(g, p("norm_mul"), m.memory_op(2.0 * act, act), &[sub, rsqrt]);
    add_op(g, p("scale_shift"), m.memory_op(2.0 * act, act), &[norm])
}

/// Layer id of each op (for the Table-3 operator→layer contraction):
/// derived from the `l<k>_` name prefix; embedding ops are layer 0, head
/// ops the last layer, backward ops mirror their forward partner.
pub fn bert_op_layer_of(g: &OpGraph) -> Vec<usize> {
    let mut out = vec![0usize; g.n()];
    let mut max_layer = 0usize;
    for (v, node) in g.nodes.iter().enumerate() {
        let name = node.name.strip_prefix("bw_").unwrap_or(&node.name);
        if let Some(rest) = name.strip_prefix('l') {
            if let Some((num, _)) = rest.split_once('_') {
                if let Ok(l) = num.parse::<usize>() {
                    out[v] = l + 1;
                    max_layer = max_layer.max(l + 1);
                }
            }
        }
    }
    for (v, node) in g.nodes.iter().enumerate() {
        let name = node.name.strip_prefix("bw_").unwrap_or(&node.name);
        if name.starts_with("pool") || name.starts_with("cls") {
            out[v] = max_layer + 1;
        }
    }
    out
}

/// BERT-24 layer-granularity graph (32 layers, as in the paper): embedding,
/// 24 transformer blocks (each one node, named `layer<i>_block` so the
/// expert banding groups them), pooler-side nodes and the head.
pub fn bert24_layer_graph(training: bool) -> OpGraph {
    let m = CostModel::default();
    let mut g = OpGraph::new();
    let act = mb_f32(B * S * H);
    let layer_flops = 2.0 * B * S * H * (4.0 * H + 2.0 * FFN) + 2.0 * B * HEADS * S * S * (H / HEADS) * 2.0;
    let layer_params = mb_f32(4.0 * H * H + 2.0 * H * FFN);

    let emb = add_op(&mut g, "embedding_0", m.compute_op(B * S * H, act, mb_f32(30522.0 * H)), &[]);
    let emb_ln = add_op(&mut g, "embln_0", m.memory_op(4.0 * act, act), &[emb]);
    let mut x = emb_ln;
    for l in 0..24 {
        x = add_op(
            &mut g,
            format!("layer{l}_block"),
            m.compute_op(layer_flops, act, layer_params),
            &[x],
        );
    }
    // pooler branch + final head (6 nodes → total 32)
    let pool = add_op(&mut g, "pooler_0", m.compute_op(2.0 * B * H * H, mb_f32(B * H), mb_f32(H * H)), &[x]);
    let tanh = add_op(&mut g, "pooltanh_0", m.memory_op(mb_f32(B * H) * 2.0, mb_f32(B * H)), &[pool]);
    let seq_out = add_op(&mut g, "seqout_0", m.memory_op(act, act), &[x]);
    let cls = add_op(&mut g, "cls_0", m.compute_op(2.0 * B * H * 2.0, 0.01, mb_f32(2.0 * H)), &[tanh]);
    let mask_head = add_op(&mut g, "mlmhead_0", m.compute_op(2.0 * B * S * H * 100.0, 0.1, mb_f32(100.0 * H)), &[seq_out]);
    let _join = add_op(&mut g, "loss_0", m.memory_op(0.2, 0.1), &[cls, mask_head]);

    if training {
        append_backward(&g, 2.0)
    } else {
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topo::is_dag;

    #[test]
    fn op_graph_node_counts_near_paper() {
        // paper: BERT-3 235, BERT-6 418, BERT-12 783 (inference ops)
        let sizes: Vec<usize> =
            [3, 6, 12].iter().map(|&l| bert_op_graph(l, false).n()).collect();
        for (ours, paper) in sizes.iter().zip([235.0, 418.0, 783.0]) {
            let ratio = *ours as f64 / paper;
            assert!((0.8..1.2).contains(&ratio), "count {ours} vs paper {paper}");
        }
    }

    #[test]
    fn training_graphs_are_bigger_and_valid() {
        let inf = bert_op_graph(3, false);
        let tr = bert_op_graph(3, true);
        assert!(tr.n() > 2 * inf.n() - 5);
        assert!(is_dag(&tr));
    }

    #[test]
    fn bert24_has_32_layers() {
        let g = bert24_layer_graph(false);
        assert_eq!(g.n(), 32);
        assert!(is_dag(&g));
        assert_eq!(bert24_layer_graph(true).n(), 64);
    }

    #[test]
    fn layer_of_is_monotone_in_depth() {
        let g = bert_op_graph(3, false);
        let lo = bert_op_layer_of(&g);
        assert_eq!(lo.len(), g.n());
        // embedding ops are layer 0; at least 4 distinct layers (emb, 3 enc)
        let distinct: std::collections::BTreeSet<usize> = lo.iter().copied().collect();
        assert!(distinct.len() >= 4, "{distinct:?}");
    }

    #[test]
    fn compute_ops_dominate_cost() {
        let g = bert_op_graph(3, false);
        let total_acc: f64 = g.nodes.iter().map(|n| n.p_acc).sum();
        let mm: f64 = g
            .nodes
            .iter()
            .filter(|n| n.name.contains("mm") || n.name.contains("matmul"))
            .map(|n| n.p_acc)
            .sum();
        assert!(mm > total_acc * 0.4, "matmuls {mm} of {total_acc}");
    }
}

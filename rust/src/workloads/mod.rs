//! Workload substrate: structural generators for the paper's seven DNNs
//! (BERT-{3,6,12,24}, ResNet-50, Inception-v3, GNMT) at operator and layer
//! granularity, inference and training, plus the JSON interchange format.
//!
//! The original inputs (msr-fiddle/dnn-partitioning) carry profiled V100 /
//! estimated-accelerator costs; these generators regenerate topologically
//! faithful graphs with FLOP-derived costs (see [`costs`]) — the
//! substitution documented in DESIGN.md §3.

pub mod bert;
pub mod costs;
pub mod gnmt;
pub mod inception;
pub mod json;
pub mod resnet;

use crate::baselines::expert::ExpertStyle;
use crate::coordinator::placement::{Fleet, PlanRequest, Scenario};
use crate::graph::{Node, NodeId, OpGraph};
use crate::simx::event::EventScript;
use costs::OpCost;

/// Granularity of a workload graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    Operator,
    Layer,
}

/// A named workload: graph + its Table-1 deployment scenario, optionally
/// overridden by a heterogeneous device fleet (CLI `--fleet` / the JSON
/// `fleet` section).
pub struct Workload {
    pub name: String,
    pub graph: OpGraph,
    pub scenario: Scenario,
    /// When set, planning runs against this fleet instead of the
    /// scenario's uniform `(k, ℓ, M)` shape (the scenario's comm model,
    /// schedule and objective semantics still apply).
    pub fleet: Option<Fleet>,
    /// Default event script for simulation replays (JSON `events` string;
    /// the CLI `--events` flag overrides it).
    pub events: Option<EventScript>,
    pub granularity: Granularity,
    pub training: bool,
    /// Expert rule applicable to this workload (layer graphs only).
    pub expert: Option<ExpertStyle>,
    /// Layer id per node, for the Table-3 operator→layer contraction.
    pub layer_of: Option<Vec<usize>>,
}

impl Workload {
    /// The paper's §6 deployment: 6 accelerators (3 for BERT-3/6), 16 GB
    /// each, 1 CPU device.
    pub fn paper_scenario(k: usize) -> Scenario {
        Scenario::new(k, 1, 16.0 * 1024.0)
    }

    /// The [`PlanRequest`] this workload plans under: its fleet when one
    /// is set, otherwise the scenario's uniform fleet. The scenario keeps
    /// contributing the comm model and train schedule; the fleet replaces
    /// the device AND interconnect description wholesale — including
    /// `bandwidth` (set it via the JSON `fleet.bandwidth` field or the
    /// CLI `bw=X` entry; it defaults to 1.0 like `Scenario`'s).
    pub fn request(&self) -> PlanRequest {
        let mut req = self.scenario.to_request();
        if let Some(fleet) = &self.fleet {
            req.fleet = fleet.clone();
        }
        req
    }
}

/// Helper used by the generators: add a node with an [`OpCost`].
pub(crate) fn add_op(
    g: &mut OpGraph,
    name: impl Into<String>,
    cost: OpCost,
    preds: &[NodeId],
) -> NodeId {
    let node = Node::new(name)
        .cpu(cost.p_cpu)
        .acc(cost.p_acc)
        .mem(cost.mem)
        .comm(cost.comm);
    let id = g.add_node(node);
    for &p in preds {
        g.add_edge(p, id);
    }
    id
}

/// Append a mirrored backward pass to a forward graph: every forward node
/// gets a backward partner (costs scaled by `bw_factor`, colocated via a
/// fresh color class), edges reversed, and the loss node bridges the two.
/// Returns the augmented graph (used by all training-workload generators).
pub(crate) fn append_backward(fw: &OpGraph, bw_factor: f64) -> OpGraph {
    let mut g = fw.clone();
    let n = fw.n();
    // color classes pair fw/bw
    let base_color = g
        .nodes
        .iter()
        .filter_map(|x| x.color_class)
        .max()
        .map_or(0, |m| m + 1);
    let mut bw_id = vec![usize::MAX; n];
    for v in (0..n).rev() {
        let f = &fw.nodes[v];
        // the gradient bw(v) emits (toward bw(preds)) is shaped like v's
        // INPUT, i.e. the preds' outputs — price it accordingly so the
        // training DP's merged fw/bw comm proxy matches the exact
        // evaluator on chain segments
        let grad_comm = if fw.preds[v].is_empty() {
            f.comm
        } else {
            fw.preds[v].iter().map(|&u| fw.nodes[u].comm).sum::<f64>()
                / fw.preds[v].len() as f64
        };
        let mut node = Node::new(format!("bw_{}", f.name))
            .cpu(f.p_cpu * bw_factor)
            .acc(f.p_acc * bw_factor)
            .mem(f.mem * 0.5)
            .comm(grad_comm)
            .backward();
        node.fw_partner = Some(v);
        node.color_class = Some(base_color + v as u32);
        g.nodes[v].color_class = Some(base_color + v as u32);
        bw_id[v] = g.add_node(node);
    }
    for (u, v) in fw.edges() {
        g.add_edge(bw_id[v], bw_id[u]);
    }
    // bridge: forward sinks feed the loss-side backward sources
    let sinks: Vec<usize> = (0..n).filter(|&v| fw.succs[v].is_empty()).collect();
    for &s in &sinks {
        g.add_edge(s, bw_id[s]);
    }
    g
}

/// The 16 Table-1 rows, in paper order.
pub fn table1_workloads() -> Vec<Workload> {
    let mut out = Vec::new();
    // operator-granularity, inference + training
    for training in [false, true] {
        for layers in [3usize, 6, 12] {
            let g = bert::bert_op_graph(layers, training);
            let k = if layers <= 6 { 3 } else { 6 };
            let layer_of = Some(bert::bert_op_layer_of(&g));
            out.push(Workload {
                name: format!("BERT-{layers}"),
                graph: g,
                scenario: Workload::paper_scenario(k),
                fleet: None,
                events: None,
                granularity: Granularity::Operator,
                training,
                expert: None,
                layer_of,
            });
        }
        let g = resnet::resnet50_op_graph(training);
        let layer_of = Some(resnet::resnet50_op_layer_of(&g));
        out.push(Workload {
            name: "ResNet50".into(),
            graph: g,
            scenario: Workload::paper_scenario(6),
            fleet: None,
            events: None,
            granularity: Granularity::Operator,
            training,
            expert: None,
            layer_of,
        });
    }
    // layer-granularity, inference + training
    for training in [false, true] {
        out.push(Workload {
            name: "BERT-24".into(),
            graph: bert::bert24_layer_graph(training),
            scenario: Workload::paper_scenario(6),
            fleet: None,
            events: None,
            granularity: Granularity::Layer,
            training,
            expert: Some(ExpertStyle::BlockBands),
            layer_of: None,
        });
        out.push(Workload {
            name: "ResNet50".into(),
            graph: resnet::resnet50_layer_graph(training),
            scenario: Workload::paper_scenario(6),
            fleet: None,
            events: None,
            granularity: Granularity::Layer,
            training,
            expert: Some(ExpertStyle::EqualStripes),
            layer_of: None,
        });
        out.push(Workload {
            name: "InceptionV3".into(),
            graph: inception::inception_v3_layer_graph(training),
            scenario: Workload::paper_scenario(6),
            fleet: None,
            events: None,
            granularity: Granularity::Layer,
            training,
            expert: Some(ExpertStyle::EqualStripes),
            layer_of: None,
        });
        out.push(Workload {
            name: "GNMT".into(),
            graph: gnmt::gnmt_layer_graph(training),
            scenario: Workload::paper_scenario(6),
            fleet: None,
            events: None,
            granularity: Granularity::Layer,
            training,
            expert: Some(ExpertStyle::BlockBands),
            layer_of: None,
        });
    }
    // Paper order: op-inference, op-training, layer-inference, layer-training.
    // The loops above produce op-inf, op-train, then layer-inf, layer-train —
    // already the Table-1 section order.
    out
}

/// The §7 latency scenarios: memory-bound accelerator counts such that
/// total accelerator memory is ~1.4–1.8× the model size (so no single
/// accelerator fits the model). The paper uses 600 MB / 2 GB caps for its
/// GB-scale inputs; for smaller generated models the cap scales down so
/// the memory pressure ratio is preserved.
pub fn latency_scenario(g: &OpGraph) -> Scenario {
    let model_mb: f64 = g.nodes.iter().map(|n| n.mem).sum();
    let cap = if model_mb > 9.0 * 1024.0 {
        2048.0
    } else if model_mb > 1100.0 {
        600.0
    } else {
        (model_mb * 0.55).max(16.0)
    };
    let k = ((model_mb * 1.6 / cap).round() as usize).max(2);
    Scenario { k, l: 1, mem_cap: cap, ..Default::default() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topo::is_dag;
    use crate::graph::NodeKind;

    #[test]
    fn table1_has_16_rows_in_order() {
        let w = table1_workloads();
        assert_eq!(w.len(), 16);
        assert!(w[..4].iter().all(|x| x.granularity == Granularity::Operator && !x.training));
        assert!(w[4..8].iter().all(|x| x.granularity == Granularity::Operator && x.training));
        assert!(w[8..12].iter().all(|x| x.granularity == Granularity::Layer && !x.training));
        assert!(w[12..].iter().all(|x| x.granularity == Granularity::Layer && x.training));
        for wl in &w {
            assert!(is_dag(&wl.graph), "{} not a DAG", wl.name);
            assert!(wl.graph.n() > 10);
        }
    }

    #[test]
    fn training_workloads_have_backward_nodes() {
        for wl in table1_workloads() {
            let has_bw = wl.graph.nodes.iter().any(|n| n.kind == NodeKind::Backward);
            assert_eq!(has_bw, wl.training, "{}", wl.name);
        }
    }

    #[test]
    fn append_backward_doubles_and_colocates() {
        let fw = bert::bert24_layer_graph(false);
        let tr = append_backward(&fw, 2.0);
        assert_eq!(tr.n(), 2 * fw.n());
        assert!(is_dag(&tr));
        for v in 0..fw.n() {
            let b = tr.nodes[fw.n() + v].fw_partner;
            assert!(b.is_some());
        }
    }

    #[test]
    fn latency_scenario_is_memory_bound() {
        let g = bert::bert_op_graph(3, false);
        let sc = latency_scenario(&g);
        let model: f64 = g.nodes.iter().map(|n| n.mem).sum();
        assert!(sc.k as f64 * sc.mem_cap < 2.0 * model);
        assert!(sc.k as f64 * sc.mem_cap > 1.2 * model);
    }
}

//! ResNet-50 generators. The operator graph decomposes every convolution
//! ONNX-style (Pad → Conv → BN-scale → BN-shift → ReLU plus the residual
//! Adds), landing near the paper's 604 inference ops; the layer graph
//! keeps Conv/BN/ReLU as separate layers (177 nodes in the paper's
//! PipeDream-profiled input).
//!
//! Batch 8, input 224×224×3.

use super::costs::{mb_f32, CostModel};
use super::{add_op, append_backward};
use crate::graph::{NodeId, OpGraph};

const BATCH: f64 = 8.0;

/// Stage spec: (blocks, channels_out, spatial).
const STAGES: [(usize, f64, f64); 4] =
    [(3, 256.0, 56.0), (4, 512.0, 28.0), (6, 1024.0, 14.0), (8, 2048.0, 7.0)];
// note: real ResNet-50 has (3,4,6,3); we keep 3+4+6+3=16 bottlenecks but the
// paper's ONNX export at 604 ops implies extra plumbing; we use (3,4,6,8)?
// — no: keep the architecture faithful and add plumbing ops instead.

/// Conv op bundle at operator granularity. Returns the output node.
#[allow(clippy::too_many_arguments)]
fn conv_ops(
    g: &mut OpGraph,
    m: &CostModel,
    name: &str,
    input: NodeId,
    cin: f64,
    cout: f64,
    k: f64,
    spatial: f64,
    relu: bool,
) -> NodeId {
    let out_mb = mb_f32(BATCH * cout * spatial * spatial);
    let flops = 2.0 * BATCH * spatial * spatial * cout * cin * k * k;
    let w = mb_f32(cout * cin * k * k);
    let shape = add_op(g, format!("{name}_shape"), m.memory_op(0.001, 0.001), &[input]);
    let pad = add_op(g, format!("{name}_pad"), m.memory_op(out_mb, out_mb), &[shape]);
    let conv = add_op(g, format!("{name}_conv"), m.compute_op(flops, out_mb, w), &[pad]);
    let bias = add_op(g, format!("{name}_bias"), m.memory_op(2.0 * out_mb, out_mb), &[conv]);
    let bn_mean = add_op(g, format!("{name}_bnmean"), m.memory_op(out_mb, 0.01), &[bias]);
    let bn_var = add_op(g, format!("{name}_bnvar"), m.memory_op(out_mb, 0.01), &[bn_mean]);
    let bn_scale = add_op(g, format!("{name}_bnscale"), m.memory_op(2.0 * out_mb, out_mb), &[bn_var]);
    let bn_shift = add_op(g, format!("{name}_bnshift"), m.memory_op(2.0 * out_mb, out_mb), &[bn_scale]);
    if relu {
        add_op(g, format!("{name}_relu"), m.memory_op(2.0 * out_mb, out_mb), &[bn_shift])
    } else {
        bn_shift
    }
}

/// ResNet-50 operator graph (≈ 600 ops inference).
pub fn resnet50_op_graph(training: bool) -> OpGraph {
    let m = CostModel::default();
    let mut g = OpGraph::new();
    let stem_out = mb_f32(BATCH * 64.0 * 112.0 * 112.0);

    let input = add_op(&mut g, "input", m.memory_op(mb_f32(BATCH * 3.0 * 224.0 * 224.0), mb_f32(BATCH * 3.0 * 224.0 * 224.0)), &[]);
    let stem = conv_ops(&mut g, &m, "stem", input, 3.0, 64.0, 7.0, 112.0, true);
    let pool = add_op(&mut g, "stem_maxpool", m.memory_op(stem_out, stem_out / 4.0), &[stem]);

    let mut x = pool;
    let mut cin = 64.0;
    let real_stages: [(usize, f64, f64); 4] =
        [(3, 256.0, 56.0), (4, 512.0, 28.0), (6, 1024.0, 14.0), (3, 2048.0, 7.0)];
    for (si, &(blocks, cout, spatial)) in real_stages.iter().enumerate() {
        for b in 0..blocks {
            let name = format!("s{si}b{b}");
            let mid = cout / 4.0;
            let c1 = conv_ops(&mut g, &m, &format!("{name}_c1"), x, cin, mid, 1.0, spatial, true);
            let c2 = conv_ops(&mut g, &m, &format!("{name}_c2"), c1, mid, mid, 3.0, spatial, true);
            let c3 = conv_ops(&mut g, &m, &format!("{name}_c3"), c2, mid, cout, 1.0, spatial, false);
            let shortcut = if b == 0 {
                conv_ops(&mut g, &m, &format!("{name}_down"), x, cin, cout, 1.0, spatial, false)
            } else {
                x
            };
            let out_mb = mb_f32(BATCH * cout * spatial * spatial);
            let add = add_op(&mut g, format!("{name}_add"), m.memory_op(2.0 * out_mb, out_mb), &[c3, shortcut]);
            x = add_op(&mut g, format!("{name}_relu"), m.memory_op(2.0 * out_mb, out_mb), &[add]);
            cin = cout;
        }
    }
    let feat = mb_f32(BATCH * 2048.0);
    let gap = add_op(&mut g, "gap", m.memory_op(mb_f32(BATCH * 2048.0 * 49.0), feat), &[x]);
    let flat = add_op(&mut g, "flatten", m.memory_op(feat, feat), &[gap]);
    let fc = add_op(&mut g, "fc", m.compute_op(2.0 * BATCH * 2048.0 * 1000.0, mb_f32(BATCH * 1000.0), mb_f32(2048.0 * 1000.0)), &[flat]);
    let _sm = add_op(&mut g, "softmax", m.memory_op(2.0 * mb_f32(BATCH * 1000.0), mb_f32(BATCH * 1000.0)), &[fc]);

    if training {
        append_backward(&g, 2.0)
    } else {
        g
    }
}

/// Layer id per op for the Table-3 contraction: ops sharing the conv-bundle
/// name prefix (`s2b1_c3`, `stem`, …) form one layer.
pub fn resnet50_op_layer_of(g: &OpGraph) -> Vec<usize> {
    let mut layer_names: std::collections::BTreeMap<String, usize> = Default::default();
    g.nodes
        .iter()
        .map(|node| {
            let name = node.name.strip_prefix("bw_").unwrap_or(&node.name);
            // strip the op suffix: everything before the last '_'
            let prefix = name.rsplit_once('_').map(|(p, _)| p).unwrap_or(name);
            let next = layer_names.len();
            *layer_names.entry(prefix.to_string()).or_insert(next)
        })
        .collect()
}

/// ResNet-50 layer graph (Conv/BN/ReLU as separate layers ≈ 177 nodes).
pub fn resnet50_layer_graph(training: bool) -> OpGraph {
    let m = CostModel::default();
    let mut g = OpGraph::new();

    let conv_layer = |g: &mut OpGraph, name: &str, input: NodeId, cin: f64, cout: f64, k: f64, spatial: f64| -> NodeId {
        let out_mb = mb_f32(BATCH * cout * spatial * spatial);
        let flops = 2.0 * BATCH * spatial * spatial * cout * cin * k * k;
        let conv = add_op(g, format!("{name}_conv"), m.compute_op(flops, out_mb, mb_f32(cout * cin * k * k)), &[input]);
        let bn = add_op(g, format!("{name}_bn"), m.memory_op(2.0 * out_mb, out_mb), &[conv]);
        add_op(g, format!("{name}_relu"), m.memory_op(2.0 * out_mb, out_mb), &[bn])
    };

    let input = add_op(&mut g, "input_0", m.memory_op(mb_f32(BATCH * 3.0 * 224.0 * 224.0), mb_f32(BATCH * 3.0 * 224.0 * 224.0)), &[]);
    let stem = conv_layer(&mut g, "stem", input, 3.0, 64.0, 7.0, 112.0);
    let pool = add_op(&mut g, "maxpool_0", m.memory_op(mb_f32(BATCH * 64.0 * 112.0 * 112.0), mb_f32(BATCH * 64.0 * 56.0 * 56.0)), &[stem]);

    let mut x = pool;
    let mut cin = 64.0;
    for (si, &(blocks, cout, spatial)) in STAGES.iter().enumerate().take(4) {
        let blocks = if si == 3 { 3 } else { blocks };
        for b in 0..blocks {
            let name = format!("s{si}b{b}");
            let mid = cout / 4.0;
            let c1 = conv_layer(&mut g, &format!("{name}c1"), x, cin, mid, 1.0, spatial);
            let c2 = conv_layer(&mut g, &format!("{name}c2"), c1, mid, mid, 3.0, spatial);
            // final conv of the block has no relu before the add
            let out_mb = mb_f32(BATCH * cout * spatial * spatial);
            let c3conv = add_op(&mut g, format!("{name}c3_conv"), m.compute_op(2.0 * BATCH * spatial * spatial * cout * mid, out_mb, mb_f32(cout * mid)), &[c2]);
            let c3bn = add_op(&mut g, format!("{name}c3_bn"), m.memory_op(2.0 * out_mb, out_mb), &[c3conv]);
            let shortcut = if b == 0 {
                let dconv = add_op(&mut g, format!("{name}d_conv"), m.compute_op(2.0 * BATCH * spatial * spatial * cout * cin, out_mb, mb_f32(cout * cin)), &[x]);
                add_op(&mut g, format!("{name}d_bn"), m.memory_op(2.0 * out_mb, out_mb), &[dconv])
            } else {
                x
            };
            let add = add_op(&mut g, format!("{name}_add"), m.memory_op(2.0 * out_mb, out_mb), &[c3bn, shortcut]);
            x = add_op(&mut g, format!("{name}_relu"), m.memory_op(2.0 * out_mb, out_mb), &[add]);
            cin = cout;
        }
    }
    let feat = mb_f32(BATCH * 2048.0);
    let gap = add_op(&mut g, "avgpool_0", m.memory_op(mb_f32(BATCH * 2048.0 * 49.0), feat), &[x]);
    let _fc = add_op(&mut g, "fc_0", m.compute_op(2.0 * BATCH * 2048.0 * 1000.0, mb_f32(BATCH * 1000.0), mb_f32(2048.0 * 1000.0)), &[gap]);

    if training {
        append_backward(&g, 2.0)
    } else {
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topo::is_dag;

    #[test]
    fn op_graph_near_paper_count() {
        let g = resnet50_op_graph(false);
        let ratio = g.n() as f64 / 604.0;
        assert!((0.75..1.25).contains(&ratio), "ops {} vs paper 604", g.n());
        assert!(is_dag(&g));
    }

    #[test]
    fn layer_graph_near_paper_count() {
        let g = resnet50_layer_graph(false);
        let ratio = g.n() as f64 / 177.0;
        assert!((0.75..1.25).contains(&ratio), "layers {} vs paper 177", g.n());
        assert!(is_dag(&g));
        assert!(is_dag(&resnet50_layer_graph(true)));
    }

    #[test]
    fn residual_structure_has_branching() {
        let g = resnet50_layer_graph(false);
        // residual adds have 2 predecessors
        let adds = g
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.name.ends_with("_add"))
            .count();
        assert!(adds >= 16);
        for (v, n) in g.nodes.iter().enumerate() {
            if n.name.ends_with("_add") {
                assert_eq!(g.preds[v].len(), 2, "{}", n.name);
            }
        }
    }

    #[test]
    fn layer_of_groups_conv_bundles() {
        let g = resnet50_op_graph(false);
        let lo = resnet50_op_layer_of(&g);
        // pad/conv/bnscale/bnshift/relu of one conv share a layer id
        let mut by_name = std::collections::HashMap::new();
        for (v, n) in g.nodes.iter().enumerate() {
            by_name.insert(n.name.clone(), v);
        }
        let a = by_name["stem_pad"];
        let b = by_name["stem_conv"];
        assert_eq!(lo[a], lo[b]);
    }
}

//! Paper-format JSON interchange (msr-fiddle/dnn-partitioning style).
//!
//! Schema:
//! ```json
//! {
//!   "name": "BERT-3",
//!   "maxMemoryPerDevice": 16384.0,
//!   "numAccelerators": 3,
//!   "numCpus": 1,
//!   "nodes": [{"id": 0, "name": "emb", "cpuLatency": 1.0,
//!               "acceleratorLatency": 0.1, "size": 2.0,
//!               "communicationCost": 0.3, "colorClass": 4,
//!               "isBackward": false}],
//!   "edges": [{"sourceId": 0, "destId": 1, "cost": 0.25}]
//! }
//! ```
//! `colorClass` and per-edge `cost` are optional, exactly as in App. B.
//!
//! An optional `fleet` section describes a heterogeneous device fleet
//! (superseding the scalar `numAccelerators`/`maxMemoryPerDevice` shape,
//! which is still emitted for backward compatibility):
//! ```json
//! "fleet": {
//!   "bandwidth": 1.0,
//!   "classes": [
//!     {"name": "a100", "count": 2, "memCap": 40960.0, "speed": 4.0,
//!      "kind": "accelerator"},
//!     {"name": "cpu", "count": 1, "kind": "cpu"}
//!   ]
//! }
//! ```
//! `memCap` defaults to unlimited, `speed` to 1.0, `kind` to
//! `"accelerator"` unless the name starts with `cpu`.
//!
//! An optional `events` string carries a default simulation event script
//! in the [`crate::simx::event::EventScript`] grammar (the CLI `--events`
//! flag overrides it):
//! ```json
//! "events": "fail:acc0@t=5,slow:acc1*0.5@t=9,spike:+8@t=12"
//! ```

use super::Workload;
use crate::coordinator::placement::{DeviceClass, DeviceKind, Fleet, Scenario};
use crate::graph::{Node, NodeKind, OpGraph};
use crate::simx::event::EventScript;
use crate::util::json::Json;

/// Serialize a workload.
pub fn to_json(w: &Workload) -> Json {
    let g = &w.graph;
    let nodes: Vec<Json> = g
        .nodes
        .iter()
        .enumerate()
        .map(|(id, n)| {
            let mut fields = vec![
                ("id", Json::num(id as f64)),
                ("name", Json::str(n.name.clone())),
                ("cpuLatency", json_latency(n.p_cpu)),
                ("acceleratorLatency", json_latency(n.p_acc)),
                ("size", Json::num(n.mem)),
                ("communicationCost", Json::num(n.comm)),
                ("isBackward", Json::Bool(n.kind == NodeKind::Backward)),
            ];
            if let Some(c) = n.color_class {
                fields.push(("colorClass", Json::num(c as f64)));
            }
            if let Some(f) = n.fw_partner {
                fields.push(("forwardPartnerId", Json::num(f as f64)));
            }
            Json::obj(fields)
        })
        .collect();
    let edges: Vec<Json> = g
        .edges()
        .map(|(u, v)| {
            let mut fields =
                vec![("sourceId", Json::num(u as f64)), ("destId", Json::num(v as f64))];
            if let Some(&c) = g.edge_costs.get(&(u, v)) {
                fields.push(("cost", Json::num(c)));
            }
            Json::obj(fields)
        })
        .collect();
    let mut fields = vec![
        ("name", Json::str(w.name.clone())),
        ("maxMemoryPerDevice", Json::num(w.scenario.mem_cap)),
        ("numAccelerators", Json::num(w.scenario.k as f64)),
        ("numCpus", Json::num(w.scenario.l as f64)),
    ];
    if let Some(fleet) = &w.fleet {
        fields.push(("fleet", fleet_to_json(fleet)));
    }
    if let Some(events) = &w.events {
        fields.push(("events", Json::str(events.to_string())));
    }
    fields.push(("nodes", Json::Arr(nodes)));
    fields.push(("edges", Json::Arr(edges)));
    Json::obj(fields)
}

/// Serialize a [`Fleet`] into the `fleet` section.
pub fn fleet_to_json(fleet: &Fleet) -> Json {
    let classes: Vec<Json> = fleet
        .classes
        .iter()
        .map(|c| {
            let mut fields = vec![
                ("name", Json::str(c.name.clone())),
                ("count", Json::num(c.count as f64)),
            ];
            if c.mem_cap.is_finite() {
                fields.push(("memCap", Json::num(c.mem_cap)));
            }
            fields.push(("speed", Json::num(c.speed)));
            fields.push((
                "kind",
                Json::str(match c.kind {
                    DeviceKind::Accelerator => "accelerator",
                    DeviceKind::Cpu => "cpu",
                }),
            ));
            Json::obj(fields)
        })
        .collect();
    let mut fields = vec![
        ("bandwidth", Json::num(fleet.bandwidth)),
        ("classes", Json::Arr(classes)),
    ];
    if let Some(t) = &fleet.topology {
        // the spec string is the canonical serialized form — it re-
        // materializes against the fleet's own device counts on parse
        fields.push(("topology", Json::obj(vec![("spec", Json::str(t.spec().to_string()))])));
    }
    Json::obj(fields)
}

/// Parse a `fleet` section.
pub fn fleet_from_json(j: &Json) -> Result<Fleet, String> {
    let classes_json = j.get("classes").as_arr().ok_or("fleet missing 'classes' array")?;
    let mut classes = Vec::new();
    for cj in classes_json {
        let name = cj.get("name").as_str().ok_or("fleet class missing 'name'")?.to_string();
        let count = cj.get("count").as_usize().ok_or("fleet class missing 'count'")?;
        let mem_cap = cj.get("memCap").as_f64().unwrap_or(f64::INFINITY);
        let speed = cj.get("speed").as_f64().unwrap_or(1.0);
        if !(speed.is_finite() && speed > 0.0) {
            return Err(format!("fleet class '{name}' has non-positive speed"));
        }
        let kind = match cj.get("kind").as_str() {
            Some("cpu") => DeviceKind::Cpu,
            Some("accelerator") | Some("acc") => DeviceKind::Accelerator,
            Some(other) => return Err(format!("unknown device kind '{other}'")),
            None => DeviceKind::infer(&name),
        };
        classes.push(DeviceClass { name, count, mem_cap, speed, kind });
    }
    if classes.is_empty() {
        return Err("fleet declares no device classes".into());
    }
    let bandwidth = j.get("bandwidth").as_f64().unwrap_or(1.0);
    if !(bandwidth.is_finite() && bandwidth > 0.0) {
        return Err("fleet bandwidth must be positive".into());
    }
    let mut fleet = Fleet { classes, bandwidth, topology: None };
    // `topology` is either `{"spec": "islands:2x4@900/64"}` or the bare
    // spec string; absence keeps the scalar-bandwidth path
    let tj = j.get("topology");
    let spec_str = tj.get("spec").as_str().or_else(|| tj.as_str());
    if let Some(s) = spec_str {
        let spec = crate::topo::TopoSpec::parse(s)
            .map_err(|e| format!("fleet topology: {e}"))?;
        let topo = crate::topo::Topology::from_spec(&spec, fleet.k(), fleet.l())
            .map_err(|e| format!("fleet topology: {e}"))?;
        fleet.topology = Some(topo);
    }
    Ok(fleet)
}

fn json_latency(v: f64) -> Json {
    if v.is_finite() {
        Json::num(v)
    } else {
        Json::Null // unsupported op
    }
}

/// Parse a workload file. Unknown fields are ignored; missing optional
/// fields default per §3.
pub fn from_json(j: &Json) -> Result<(OpGraph, Scenario, String), String> {
    let name = j.get("name").as_str().unwrap_or("unnamed").to_string();
    let nodes = j.get("nodes").as_arr().ok_or("missing 'nodes' array")?;
    let mut g = OpGraph::new();
    // ids may be sparse: map id → dense index
    let mut id_map = std::collections::BTreeMap::new();
    for nj in nodes {
        let id = nj.get("id").as_usize().ok_or("node missing 'id'")?;
        let mut node = Node::new(nj.get("name").as_str().unwrap_or("op"));
        node.p_cpu = nj.get("cpuLatency").as_f64().unwrap_or(f64::INFINITY);
        node.p_acc = nj.get("acceleratorLatency").as_f64().unwrap_or(f64::INFINITY);
        node.mem = nj.get("size").as_f64().unwrap_or(0.0);
        node.comm = nj.get("communicationCost").as_f64().unwrap_or(0.0);
        node.color_class = nj.get("colorClass").as_usize().map(|c| c as u32);
        if nj.get("isBackward").as_bool() == Some(true) {
            node.kind = NodeKind::Backward;
        }
        let dense = g.add_node(node);
        if id_map.insert(id, dense).is_some() {
            return Err(format!("duplicate node id {id}"));
        }
    }
    // forward partners need the id map
    for (pos, nj) in nodes.iter().enumerate() {
        if let Some(f) = nj.get("forwardPartnerId").as_usize() {
            let fp = *id_map.get(&f).ok_or(format!("bad forwardPartnerId {f}"))?;
            g.nodes[pos].fw_partner = Some(fp);
        }
    }
    for ej in j.get("edges").as_arr().ok_or("missing 'edges' array")? {
        let s = ej.get("sourceId").as_usize().ok_or("edge missing sourceId")?;
        let d = ej.get("destId").as_usize().ok_or("edge missing destId")?;
        let (&su, &dv) = (
            id_map.get(&s).ok_or(format!("unknown sourceId {s}"))?,
            id_map.get(&d).ok_or(format!("unknown destId {d}"))?,
        );
        match ej.get("cost").as_f64() {
            Some(c) => g.add_edge_cost(su, dv, c),
            None => g.add_edge(su, dv),
        }
    }
    let scenario = Scenario {
        k: j.get("numAccelerators").as_usize().unwrap_or(6),
        l: j.get("numCpus").as_usize().unwrap_or(1),
        mem_cap: j.get("maxMemoryPerDevice").as_f64().unwrap_or(f64::INFINITY),
        ..Default::default()
    };
    Ok((g, scenario, name))
}

/// Parse a workload file into a full [`Workload`], including the optional
/// `fleet` section (absent → `fleet: None`, the scalar scenario applies)
/// and the optional `events` script string.
pub fn from_json_workload(j: &Json) -> Result<Workload, String> {
    let (graph, scenario, name) = from_json(j)?;
    let fleet = match j.get("fleet") {
        Json::Null => None,
        section => Some(fleet_from_json(section)?),
    };
    let events = match j.get("events") {
        Json::Null => None,
        section => {
            let spec = section.as_str().ok_or("'events' must be a script string")?;
            let script = EventScript::parse(spec)?;
            if script.is_empty() {
                None
            } else {
                Some(script)
            }
        }
    };
    // training-ness is derivable from the nodes (isBackward), and the
    // simulate CLI keys its default schedule off it
    let training = graph.nodes.iter().any(|n| n.kind == NodeKind::Backward);
    Ok(Workload {
        name,
        graph,
        scenario,
        fleet,
        events,
        granularity: super::Granularity::Operator,
        training,
        expert: None,
        layer_of: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{table1_workloads, Granularity};

    #[test]
    fn roundtrip_preserves_structure_and_costs() {
        let w = &table1_workloads()[0]; // BERT-3 op inference
        let j = to_json(w);
        let (g, sc, name) = from_json(&j).unwrap();
        assert_eq!(name, "BERT-3");
        assert_eq!(g.n(), w.graph.n());
        assert_eq!(g.num_edges(), w.graph.num_edges());
        assert_eq!(sc.k, w.scenario.k);
        for v in 0..g.n() {
            assert!((g.nodes[v].p_acc - w.graph.nodes[v].p_acc).abs() < 1e-12);
            assert!((g.nodes[v].comm - w.graph.nodes[v].comm).abs() < 1e-12);
            assert_eq!(g.nodes[v].color_class, w.graph.nodes[v].color_class);
        }
    }

    #[test]
    fn roundtrip_training_graph_with_colocation() {
        let w = table1_workloads().into_iter().find(|w| w.training).unwrap();
        let j = to_json(&w);
        let (g, _, _) = from_json(&j).unwrap();
        let bw = g.nodes.iter().filter(|n| n.kind == NodeKind::Backward).count();
        assert!(bw > 0);
        // fw partners survive
        let partnered = g.nodes.iter().filter(|n| n.fw_partner.is_some()).count();
        assert_eq!(partnered, bw);
    }

    #[test]
    fn per_edge_costs_roundtrip() {
        let mut g = OpGraph::new();
        g.add_node(Node::new("a"));
        g.add_node(Node::new("b"));
        g.add_edge_cost(0, 1, 2.5);
        let w = Workload {
            name: "t".into(),
            graph: g,
            scenario: Scenario::new(1, 1, 10.0),
            fleet: None,
            events: None,
            granularity: Granularity::Operator,
            training: false,
            expert: None,
            layer_of: None,
        };
        let (g2, _, _) = from_json(&to_json(&w)).unwrap();
        assert_eq!(g2.edge_costs.get(&(0, 1)), Some(&2.5));
    }

    #[test]
    fn unsupported_ops_roundtrip_as_null() {
        let mut g = OpGraph::new();
        let mut n = Node::new("gpuonly");
        n.p_acc = f64::INFINITY;
        g.add_node(n);
        let w = Workload {
            name: "t".into(),
            graph: g,
            scenario: Scenario::new(1, 1, 10.0),
            fleet: None,
            events: None,
            granularity: Granularity::Operator,
            training: false,
            expert: None,
            layer_of: None,
        };
        let (g2, _, _) = from_json(&to_json(&w)).unwrap();
        assert!(g2.nodes[0].p_acc.is_infinite());
    }

    #[test]
    fn fleet_section_roundtrips() {
        let mut g = OpGraph::new();
        g.add_node(Node::new("a").mem(2.0));
        g.add_node(Node::new("b").mem(2.0));
        g.add_edge(0, 1);
        let fleet = Fleet::new(vec![
            DeviceClass::acc("a100", 2, 40.0).speed(4.0),
            DeviceClass::acc("t4", 4, 16.0),
            DeviceClass::cpu("cpu", 1),
        ])
        .bandwidth(2.5);
        let w = Workload {
            name: "hetero".into(),
            graph: g,
            scenario: Scenario::new(6, 1, 40.0),
            fleet: Some(fleet.clone()),
            events: None,
            granularity: Granularity::Operator,
            training: false,
            expert: None,
            layer_of: None,
        };
        // in-memory roundtrip
        let j = to_json(&w);
        let back = from_json_workload(&j).unwrap();
        assert_eq!(back.fleet.as_ref(), Some(&fleet));
        // through the textual form too (serialize → parse → compare)
        let reparsed = crate::util::json::Json::parse(&j.to_string_pretty()).unwrap();
        let back2 = from_json_workload(&reparsed).unwrap();
        assert_eq!(back2.fleet.as_ref(), Some(&fleet));
        assert_eq!(back2.scenario.k, w.scenario.k);
    }

    #[test]
    fn events_section_roundtrips() {
        let mut g = OpGraph::new();
        g.add_node(Node::new("a"));
        g.add_node(Node::new("b"));
        g.add_edge(0, 1);
        let script = EventScript::parse("fail:acc0@t=5,slow:acc1*0.5@t=9,spike:+8@t=12").unwrap();
        let w = Workload {
            name: "scripted".into(),
            graph: g,
            scenario: Scenario::new(2, 1, f64::INFINITY),
            fleet: None,
            events: Some(script.clone()),
            granularity: Granularity::Operator,
            training: false,
            expert: None,
            layer_of: None,
        };
        let j = to_json(&w);
        let back = from_json_workload(&j).unwrap();
        assert_eq!(back.events.as_ref(), Some(&script));
        // textual roundtrip too
        let reparsed = crate::util::json::Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(from_json_workload(&reparsed).unwrap().events, Some(script));
        // malformed script strings are rejected, not ignored
        let bad = crate::util::json::Json::parse(
            r#"{"name": "x", "nodes": [], "edges": [], "events": "melt:acc0@t=1"}"#,
        )
        .unwrap();
        assert!(from_json_workload(&bad).is_err());
    }

    #[test]
    fn fleetless_files_parse_with_no_fleet() {
        let w = &table1_workloads()[0];
        let back = from_json_workload(&to_json(w)).unwrap();
        assert!(back.fleet.is_none());
        assert_eq!(back.graph.n(), w.graph.n());
    }

    #[test]
    fn fleet_kind_inference_and_errors() {
        let j = crate::util::json::Json::parse(
            r#"{"bandwidth": 1.0, "classes": [
                {"name": "cpu_pool", "count": 2},
                {"name": "gpu", "count": 1, "memCap": 8.0}
            ]}"#,
        )
        .unwrap();
        let fleet = fleet_from_json(&j).unwrap();
        assert_eq!(fleet.classes[0].kind, DeviceKind::Cpu);
        assert_eq!(fleet.classes[1].kind, DeviceKind::Accelerator);
        assert_eq!(fleet.l(), 2);
        assert_eq!(fleet.k(), 1);
        let bad = crate::util::json::Json::parse(r#"{"classes": []}"#).unwrap();
        assert!(fleet_from_json(&bad).is_err());
        let bad_kind = crate::util::json::Json::parse(
            r#"{"classes": [{"name": "x", "count": 1, "kind": "tpu-pod"}]}"#,
        )
        .unwrap();
        assert!(fleet_from_json(&bad_kind).is_err());
    }

    #[test]
    fn errors_on_malformed() {
        assert!(from_json(&Json::parse(r#"{"nodes": "x"}"#).unwrap()).is_err());
        assert!(from_json(
            &Json::parse(r#"{"nodes": [], "edges": [{"sourceId": 0, "destId": 1}]}"#).unwrap()
        )
        .is_err());
    }
}

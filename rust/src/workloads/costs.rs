//! Cost model turning op shapes into the §3 node weights.
//!
//! The paper profiles layer graphs on a GPU and *estimates* operator-graph
//! costs for a non-GPU accelerator; our substitute derives costs
//! analytically from FLOPs and bytes with device constants chosen so that
//! magnitudes land in the paper's range (TPS in tens-to-hundreds of ms).
//! Units: time = ms, memory/data = MB.

/// Device/interconnect constants.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Accelerator matmul throughput (FLOPs per ms).
    pub acc_flops_per_ms: f64,
    /// Accelerator memory bandwidth for elementwise ops (MB per ms).
    pub acc_mb_per_ms: f64,
    /// CPU throughput (FLOPs per ms).
    pub cpu_flops_per_ms: f64,
    /// CPU memory bandwidth (MB per ms).
    pub cpu_mb_per_ms: f64,
    /// Host↔accelerator interconnect (MB per ms) — PCIe 3.0 x16 ≈ 12.
    pub pcie_mb_per_ms: f64,
    /// Fixed accelerator kernel-launch overhead (ms).
    pub acc_overhead_ms: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            acc_flops_per_ms: 1.0e10, // 10 TFLOP/s effective
            acc_mb_per_ms: 600.0,     // ~600 GB/s HBM
            cpu_flops_per_ms: 2.0e8,  // 0.2 TFLOP/s
            cpu_mb_per_ms: 40.0,
            pcie_mb_per_ms: 12.0,
            acc_overhead_ms: 0.002,
        }
    }
}

/// Cost triple of an op: (p_cpu, p_acc, comm), plus the memory footprint.
#[derive(Clone, Copy, Debug, Default)]
pub struct OpCost {
    pub p_cpu: f64,
    pub p_acc: f64,
    pub comm: f64,
    pub mem: f64,
}

impl CostModel {
    /// Compute-bound op (matmul/conv): `flops` of math producing
    /// `out_mb` of output, with `param_mb` resident parameters.
    pub fn compute_op(&self, flops: f64, out_mb: f64, param_mb: f64) -> OpCost {
        OpCost {
            p_cpu: flops / self.cpu_flops_per_ms,
            p_acc: flops / self.acc_flops_per_ms + self.acc_overhead_ms,
            comm: out_mb / self.pcie_mb_per_ms,
            mem: param_mb + out_mb,
        }
    }

    /// Memory-bound op (elementwise / norm / softmax): touches
    /// `touched_mb`, produces `out_mb`.
    pub fn memory_op(&self, touched_mb: f64, out_mb: f64) -> OpCost {
        OpCost {
            p_cpu: touched_mb / self.cpu_mb_per_ms,
            p_acc: touched_mb / self.acc_mb_per_ms + self.acc_overhead_ms,
            comm: out_mb / self.pcie_mb_per_ms,
            mem: out_mb,
        }
    }
}

/// MB of a f32 tensor with the given element count.
pub fn mb_f32(elements: f64) -> f64 {
    elements * 4.0 / 1.0e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_op_scales_with_flops() {
        let m = CostModel::default();
        let a = m.compute_op(1e9, 1.0, 10.0);
        let b = m.compute_op(2e9, 1.0, 10.0);
        assert!(b.p_acc > a.p_acc);
        assert!((b.p_cpu / a.p_cpu - 2.0).abs() < 1e-9);
        assert!(a.p_cpu > a.p_acc, "CPU must be slower on compute ops");
        assert!((a.mem - 11.0).abs() < 1e-12);
    }

    #[test]
    fn memory_op_bandwidth_bound() {
        let m = CostModel::default();
        let c = m.memory_op(4.0, 2.0);
        assert!((c.p_cpu - 0.1).abs() < 1e-9);
        assert!(c.p_acc < c.p_cpu);
        assert!((c.comm - 2.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn mb_conversion() {
        assert!((mb_f32(1_000_000.0) - 4.0).abs() < 1e-12);
    }
}

//! In-tree substrates that replace unavailable third-party crates in the
//! offline build: bitsets, a deterministic PRNG, a JSON parser/writer, a
//! property-testing harness, and a micro-benchmark timer.

pub mod arena;
pub mod bench;
pub mod bitset;
pub mod counters;
pub mod json;
pub mod par;
pub mod proptest;
pub mod rng;

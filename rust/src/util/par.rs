//! Minimal data-parallel helpers on `std::thread::scope` — the offline
//! stand-in for `rayon` (this crate builds with no external dependencies;
//! see `Cargo.toml`). The level-synchronous DP in `algos::dp` hands each
//! worker a disjoint mutable chunk of the table plus its own scratch, so
//! plain scoped threads are all the structure we need.

/// Number of worker threads to use: `available_parallelism`, or 1 when the
/// platform won't say.
pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(worker_index, &mut state)` once per element of `states`, each on
/// its own thread. Blocks until all workers finish. With a single state the
/// call runs inline — no thread spawn.
pub fn run_workers<S, F>(states: &mut [S], f: F)
where
    S: Send,
    F: Fn(usize, &mut S) + Sync,
{
    if states.len() == 1 {
        f(0, &mut states[0]);
        return;
    }
    let f = &f;
    std::thread::scope(|scope| {
        for (t, state) in states.iter_mut().enumerate() {
            scope.spawn(move || f(t, state));
        }
    });
}

/// Split `slice` into `parts` near-even contiguous chunks whose lengths are
/// multiples of `granule` (except possibly the last). Returns fewer chunks
/// when the slice is short. Used to hand each DP worker whole-row blocks.
pub fn chunk_granular<'a, T>(
    mut slice: &'a mut [T],
    parts: usize,
    granule: usize,
) -> Vec<&'a mut [T]> {
    // a partial tail counts as a row, and per >= 1, so every iteration
    // consumes at least one element — no spin on short slices
    let granule = granule.max(1);
    let rows = slice.len().div_ceil(granule);
    let per = rows.div_ceil(parts.max(1)).max(1);
    let mut out = Vec::with_capacity(parts);
    while !slice.is_empty() {
        let take = (per * granule).min(slice.len());
        let (head, rest) = slice.split_at_mut(take);
        out.push(head);
        slice = rest;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workers_cover_all_states() {
        let mut states: Vec<(usize, u64)> = (0..4).map(|i| (i, 0u64)).collect();
        run_workers(&mut states, |t, s| {
            assert_eq!(t, s.0);
            s.1 = (s.0 as u64 + 1) * 10;
        });
        assert_eq!(states.iter().map(|s| s.1).collect::<Vec<_>>(), vec![10, 20, 30, 40]);
    }

    #[test]
    fn single_state_runs_inline() {
        let mut states = [0usize];
        run_workers(&mut states, |_, s| *s = 7);
        assert_eq!(states[0], 7);
    }

    #[test]
    fn chunking_respects_granule() {
        let mut data = vec![0u8; 35];
        let chunks = chunk_granular(&mut data, 4, 5);
        assert!(chunks.iter().all(|c| c.len() % 5 == 0));
        assert_eq!(chunks.iter().map(|c| c.len()).sum::<usize>(), 35);
        // 7 rows over 4 parts → per = 2 rows = 10 elems
        assert_eq!(chunks[0].len(), 10);
    }

    #[test]
    fn chunking_short_and_degenerate_inputs_terminate() {
        // slice shorter than one granule: a single chunk with everything
        let mut short = vec![0u8; 3];
        let chunks = chunk_granular(&mut short, 4, 5);
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].len(), 3);
        // zero granule behaves as granule 1
        let mut tiny = vec![0u8; 2];
        let chunks = chunk_granular(&mut tiny, 2, 0);
        assert_eq!(chunks.iter().map(|c| c.len()).sum::<usize>(), 2);
        // empty slice: no chunks
        let mut empty: Vec<u8> = Vec::new();
        assert!(chunk_granular(&mut empty, 3, 4).is_empty());
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }
}

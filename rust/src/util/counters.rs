//! Compatibility re-export: the instrumentation counters moved to
//! [`crate::obs::counters`] when the unified observability layer landed
//! (PR 9, DESIGN.md §10). The `bump_*` / `*_calls` / [`ctx_builds`]
//! names are unchanged, so every call site and test assertion written
//! against `util::counters` keeps working; the obs module additionally
//! mirrors each bump into a registered process-wide
//! [`crate::obs::Counter`] for the `stats` CLI and Prometheus export.

pub use crate::obs::counters::{
    bump_co_reachability, bump_ctx_build, bump_enumerate, bump_reachability,
    co_reachability_calls, ctx_builds, enumerate_calls, reachability_calls,
};

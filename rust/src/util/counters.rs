//! Thread-local instrumentation counters for the expensive shared analysis
//! passes (ideal-lattice enumeration, reachability matrices), plus one
//! process-wide counter for context construction.
//!
//! The [`crate::coordinator::context::ProblemCtx`] cache exists so that
//! planning every algorithm of a scenario computes each of these artifacts
//! at most once; these counters let tests assert that property directly on
//! the real entry points instead of trusting the cache plumbing. They are
//! thread-local (not global atomics) so concurrently running tests cannot
//! pollute each other's deltas; the counted functions all run on the
//! calling thread (the DP's layer workers never re-enter them).
//!
//! [`ctx_builds`] is the one exception: the single-flight dedup of
//! [`crate::coordinator::concurrent::ConcurrentService`] promises at most
//! one `ProblemCtx` construction per fingerprint *across* threads, which a
//! thread-local counter cannot observe. It is a process-wide atomic;
//! tests that assert on its delta serialize themselves (see
//! `rust/tests/concurrent_service.rs`).

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

thread_local! {
    static ENUMERATE_CALLS: Cell<u64> = const { Cell::new(0) };
    static REACHABILITY_CALLS: Cell<u64> = const { Cell::new(0) };
    static CO_REACHABILITY_CALLS: Cell<u64> = const { Cell::new(0) };
}

/// Record one `IdealLattice::enumerate` invocation (called by `graph::ideals`).
pub fn bump_enumerate() {
    ENUMERATE_CALLS.with(|c| c.set(c.get() + 1));
}

/// Record one `topo::reachability_matrix` invocation.
pub fn bump_reachability() {
    REACHABILITY_CALLS.with(|c| c.set(c.get() + 1));
}

/// Record one `topo::co_reachability_matrix` invocation.
pub fn bump_co_reachability() {
    CO_REACHABILITY_CALLS.with(|c| c.set(c.get() + 1));
}

/// Lattice enumerations performed by this thread so far.
pub fn enumerate_calls() -> u64 {
    ENUMERATE_CALLS.with(Cell::get)
}

/// Reachability-matrix builds performed by this thread so far.
pub fn reachability_calls() -> u64 {
    REACHABILITY_CALLS.with(Cell::get)
}

/// Co-reachability-matrix builds performed by this thread so far.
pub fn co_reachability_calls() -> u64 {
    CO_REACHABILITY_CALLS.with(Cell::get)
}

static CTX_BUILDS: AtomicU64 = AtomicU64::new(0);

/// Record one `ProblemCtx` construction (called by
/// `ProblemCtx::from_request_with_cap` — every constructor funnels there).
pub fn bump_ctx_build() {
    CTX_BUILDS.fetch_add(1, Ordering::Relaxed);
}

/// `ProblemCtx` constructions performed process-wide so far.
pub fn ctx_builds() -> u64 {
    CTX_BUILDS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_increment_monotonically() {
        let a = enumerate_calls();
        bump_enumerate();
        bump_enumerate();
        assert_eq!(enumerate_calls(), a + 2);
        let r = reachability_calls();
        bump_reachability();
        assert_eq!(reachability_calls(), r + 1);
        let c = co_reachability_calls();
        bump_co_reachability();
        assert_eq!(co_reachability_calls(), c + 1);
        let b = ctx_builds();
        bump_ctx_build();
        // ≥: other tests may build contexts concurrently (global atomic)
        assert!(ctx_builds() >= b + 1);
    }
}

//! Thread-local instrumentation counters for the expensive shared analysis
//! passes (ideal-lattice enumeration, reachability matrices).
//!
//! The [`crate::coordinator::context::ProblemCtx`] cache exists so that
//! planning every algorithm of a scenario computes each of these artifacts
//! at most once; these counters let tests assert that property directly on
//! the real entry points instead of trusting the cache plumbing. They are
//! thread-local (not global atomics) so concurrently running tests cannot
//! pollute each other's deltas; the counted functions all run on the
//! calling thread (the DP's layer workers never re-enter them).

use std::cell::Cell;

thread_local! {
    static ENUMERATE_CALLS: Cell<u64> = const { Cell::new(0) };
    static REACHABILITY_CALLS: Cell<u64> = const { Cell::new(0) };
    static CO_REACHABILITY_CALLS: Cell<u64> = const { Cell::new(0) };
}

/// Record one `IdealLattice::enumerate` invocation (called by `graph::ideals`).
pub fn bump_enumerate() {
    ENUMERATE_CALLS.with(|c| c.set(c.get() + 1));
}

/// Record one `topo::reachability_matrix` invocation.
pub fn bump_reachability() {
    REACHABILITY_CALLS.with(|c| c.set(c.get() + 1));
}

/// Record one `topo::co_reachability_matrix` invocation.
pub fn bump_co_reachability() {
    CO_REACHABILITY_CALLS.with(|c| c.set(c.get() + 1));
}

/// Lattice enumerations performed by this thread so far.
pub fn enumerate_calls() -> u64 {
    ENUMERATE_CALLS.with(Cell::get)
}

/// Reachability-matrix builds performed by this thread so far.
pub fn reachability_calls() -> u64 {
    REACHABILITY_CALLS.with(Cell::get)
}

/// Co-reachability-matrix builds performed by this thread so far.
pub fn co_reachability_calls() -> u64 {
    CO_REACHABILITY_CALLS.with(Cell::get)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_increment_monotonically() {
        let a = enumerate_calls();
        bump_enumerate();
        bump_enumerate();
        assert_eq!(enumerate_calls(), a + 2);
        let r = reachability_calls();
        bump_reachability();
        assert_eq!(reachability_calls(), r + 1);
        let c = co_reachability_calls();
        bump_co_reachability();
        assert_eq!(co_reachability_calls(), c + 1);
    }
}

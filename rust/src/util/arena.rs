//! Flat word arenas for the set-heavy hot paths.
//!
//! The ideal lattice (§5.1.1) stores up to millions of node sets; keeping
//! each as its own heap `Vec<u64>` (the old `BitSet`-per-ideal layout)
//! costs one allocation, one pointer chase and one cache miss per set. A
//! [`SetArena`] instead packs every set into a single `Vec<u64>` at a fixed
//! word stride, so sets are addressed as slices, iteration is cache-linear,
//! and creating a set is an `extend_from_within` — zero per-set allocations
//! once the arena's backing vector has grown to size.
//!
//! [`InternTable`] deduplicates arena rows (open addressing on precomputed
//! 64-bit hashes with slice-equality fallback), replacing the old
//! `HashMap<BitSet, IdealId>` that re-hashed and cloned whole bitsets.
//!
//! [`BitMatrix`] is the same idea for n×n relations (reachability rows in
//! `graph::topo` / `graph::contiguity` and the branch-and-bound searches).

/// Number of 64-bit words needed for `bits` bits.
#[inline]
pub fn words_for(bits: usize) -> usize {
    bits.div_ceil(64)
}

/// FNV-style hash of a word slice. `BitSet::fast_hash` delegates here, so
/// arena rows and `BitSet`s always hash compatibly.
#[inline]
pub fn hash_words(words: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &w in words {
        h = (h ^ w).wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Number of set bits in a word slice.
#[inline]
pub fn popcount(words: &[u64]) -> usize {
    words.iter().map(|w| w.count_ones() as usize).sum()
}

/// Is bit `i` set?
#[inline]
pub fn word_contains(words: &[u64], i: usize) -> bool {
    words[i / 64] >> (i % 64) & 1 == 1
}

/// Set bit `i`.
#[inline]
pub fn word_set(words: &mut [u64], i: usize) {
    words[i / 64] |= 1u64 << (i % 64);
}

/// Clear bit `i`.
#[inline]
pub fn word_clear(words: &mut [u64], i: usize) {
    words[i / 64] &= !(1u64 << (i % 64));
}

/// `dst &= src`.
#[inline]
pub fn and_into(dst: &mut [u64], src: &[u64]) {
    for (a, b) in dst.iter_mut().zip(src) {
        *a &= b;
    }
}

/// `dst |= src`.
#[inline]
pub fn or_into(dst: &mut [u64], src: &[u64]) {
    for (a, b) in dst.iter_mut().zip(src) {
        *a |= b;
    }
}

/// `dst &= !src`.
#[inline]
pub fn andnot_into(dst: &mut [u64], src: &[u64]) {
    for (a, b) in dst.iter_mut().zip(src) {
        *a &= !b;
    }
}

/// Any bit set?
#[inline]
pub fn any(words: &[u64]) -> bool {
    words.iter().any(|&w| w != 0)
}

/// `a ∩ b ≠ ∅`.
#[inline]
pub fn intersects(a: &[u64], b: &[u64]) -> bool {
    a.iter().zip(b).any(|(x, y)| x & y != 0)
}

/// Iterate the set bits of a word slice in increasing order.
pub fn bits(words: &[u64]) -> WordBits<'_> {
    WordBits { words, word_idx: 0, current: words.first().copied().unwrap_or(0) }
}

pub struct WordBits<'a> {
    words: &'a [u64],
    word_idx: usize,
    current: u64,
}

impl Iterator for WordBits<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        loop {
            if self.current != 0 {
                let bit = self.current.trailing_zeros() as usize;
                self.current &= self.current - 1;
                return Some(self.word_idx * 64 + bit);
            }
            self.word_idx += 1;
            if self.word_idx >= self.words.len() {
                return None;
            }
            self.current = self.words[self.word_idx];
        }
    }
}

/// A growable collection of equal-capacity bitsets stored back-to-back in
/// one `Vec<u64>`. Rows are addressed by dense index; the last row can be
/// popped, which makes "stage a candidate, dedup, keep or discard" loops
/// allocation-free.
#[derive(Clone, Debug)]
pub struct SetArena {
    words: Vec<u64>,
    stride: usize,
    capacity: usize,
    rows: usize,
}

impl SetArena {
    /// Arena of sets over `0..capacity`.
    pub fn new(capacity: usize) -> Self {
        SetArena { words: Vec::new(), stride: words_for(capacity), capacity, rows: 0 }
    }

    /// Pre-reserve space for `rows` rows.
    pub fn with_row_capacity(capacity: usize, rows: usize) -> Self {
        let mut a = Self::new(capacity);
        a.words.reserve(rows * a.stride);
        a
    }

    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Addressable bits per row.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Words per row.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Append an all-zero row; returns its index.
    pub fn push_empty(&mut self) -> usize {
        self.words.resize(self.words.len() + self.stride, 0);
        self.rows += 1;
        self.rows - 1
    }

    /// Append a copy of row `src`; returns the new row's index.
    pub fn push_copy(&mut self, src: usize) -> usize {
        debug_assert!(src < self.rows);
        let a = src * self.stride;
        self.words.extend_from_within(a..a + self.stride);
        self.rows += 1;
        self.rows - 1
    }

    /// Drop the last row (the staged-candidate discard path).
    pub fn pop_last(&mut self) {
        debug_assert!(self.rows > 0);
        self.words.truncate(self.words.len() - self.stride);
        self.rows -= 1;
    }

    /// Drop the first `k` rows, shifting the rest down (queue-style reuse:
    /// callers rebase their row indices by `k`). Amortized O(live rows).
    pub fn discard_front(&mut self, k: usize) {
        debug_assert!(k <= self.rows);
        let off = k * self.stride;
        self.words.copy_within(off.., 0);
        self.words.truncate(self.words.len() - off);
        self.rows -= k;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[u64] {
        &self.words[i * self.stride..(i + 1) * self.stride]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [u64] {
        &mut self.words[i * self.stride..(i + 1) * self.stride]
    }

    #[inline]
    pub fn set_bit(&mut self, row: usize, bit: usize) {
        debug_assert!(bit < self.capacity);
        word_set(self.row_mut(row), bit);
    }

    #[inline]
    pub fn clear_bit(&mut self, row: usize, bit: usize) {
        debug_assert!(bit < self.capacity);
        word_clear(self.row_mut(row), bit);
    }

    #[inline]
    pub fn contains(&self, row: usize, bit: usize) -> bool {
        debug_assert!(bit < self.capacity);
        word_contains(self.row(row), bit)
    }
}

const EMPTY_SLOT: u32 = u32::MAX;

/// Open-addressing hash table interning [`SetArena`] rows: maps row content
/// to the row index of its first occurrence. Keys are precomputed 64-bit
/// hashes ([`hash_words`]) with slice equality on collision — no re-hashing
/// of whole sets through SipHash, no owned keys.
#[derive(Clone, Debug, Default)]
pub struct InternTable {
    slots: Vec<u32>,
    hashes: Vec<u64>,
    mask: usize,
    items: usize,
}

impl InternTable {
    pub fn with_capacity(cap: usize) -> Self {
        let size = (cap.max(8) * 2).next_power_of_two();
        InternTable {
            slots: vec![EMPTY_SLOT; size],
            hashes: vec![0; size],
            mask: size - 1,
            items: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.items
    }

    pub fn is_empty(&self) -> bool {
        self.items == 0
    }

    fn grow(&mut self) {
        let new_size = (self.slots.len() * 2).max(16);
        let old_slots = std::mem::replace(&mut self.slots, vec![EMPTY_SLOT; new_size]);
        let old_hashes = std::mem::replace(&mut self.hashes, vec![0; new_size]);
        self.mask = new_size - 1;
        for (slot, h) in old_slots.into_iter().zip(old_hashes) {
            if slot != EMPTY_SLOT {
                let mut i = (h as usize) & self.mask;
                while self.slots[i] != EMPTY_SLOT {
                    i = (i + 1) & self.mask;
                }
                self.slots[i] = slot;
                self.hashes[i] = h;
            }
        }
    }

    /// Look up a set (given as words) without inserting.
    pub fn find(&self, hash: u64, words: &[u64], arena: &SetArena) -> Option<u32> {
        if self.slots.is_empty() {
            return None;
        }
        let mut i = (hash as usize) & self.mask;
        loop {
            let s = self.slots[i];
            if s == EMPTY_SLOT {
                return None;
            }
            if self.hashes[i] == hash && arena.row(s as usize) == words {
                return Some(s);
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Intern the arena's LAST row: if an equal row already exists, pop the
    /// staged row and return `(existing_id, false)`; otherwise keep it and
    /// return `(staged_id, true)`. This is the zero-allocation dedup step of
    /// the lattice BFS.
    pub fn intern_last(&mut self, arena: &mut SetArena) -> (u32, bool) {
        if (self.items + 1) * 4 > self.slots.len() * 3 {
            self.grow();
        }
        let staged = (arena.len() - 1) as u32;
        let hash = hash_words(arena.row(staged as usize));
        let mut i = (hash as usize) & self.mask;
        loop {
            let s = self.slots[i];
            if s == EMPTY_SLOT {
                self.slots[i] = staged;
                self.hashes[i] = hash;
                self.items += 1;
                return (staged, true);
            }
            if self.hashes[i] == hash
                && arena.row(s as usize) == arena.row(staged as usize)
            {
                arena.pop_last();
                return (s, false);
            }
            i = (i + 1) & self.mask;
        }
    }
}

/// Dense n×n bit matrix in a single allocation — reachability rows and
/// similar per-node relations, replacing `Vec<BitSet>`.
#[derive(Clone, Debug)]
pub struct BitMatrix {
    words: Vec<u64>,
    stride: usize,
    n: usize,
}

impl BitMatrix {
    pub fn new(n: usize) -> Self {
        let stride = words_for(n);
        BitMatrix { words: vec![0; stride * n], stride, n }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[u64] {
        &self.words[i * self.stride..(i + 1) * self.stride]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [u64] {
        &mut self.words[i * self.stride..(i + 1) * self.stride]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize) {
        debug_assert!(i < self.n && j < self.n);
        word_set(self.row_mut(i), j);
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        debug_assert!(i < self.n && j < self.n);
        word_contains(self.row(i), j)
    }

    /// OR the rows of `members` into `out` (cleared first) — the
    /// "rebuild a device's reach union" loop of the B&B searches.
    pub fn union_rows_of(&self, members: impl Iterator<Item = usize>, out: &mut [u64]) {
        out.fill(0);
        for u in members {
            or_into(out, self.row(u));
        }
    }

    /// `row(dst) |= row(src)` without allocating.
    pub fn union_rows(&mut self, dst: usize, src: usize) {
        if dst == src {
            return;
        }
        let s = self.stride;
        let (d_slice, s_slice) = if dst < src {
            let (a, b) = self.words.split_at_mut(src * s);
            (&mut a[dst * s..dst * s + s], &b[..s])
        } else {
            let (a, b) = self.words.split_at_mut(dst * s);
            (&mut b[..s], &a[src * s..src * s + s])
        };
        for (x, y) in d_slice.iter_mut().zip(s_slice) {
            *x |= *y;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_push_copy_pop() {
        let mut a = SetArena::new(130);
        let r0 = a.push_empty();
        a.set_bit(r0, 0);
        a.set_bit(r0, 129);
        let r1 = a.push_copy(r0);
        a.set_bit(r1, 64);
        assert!(a.contains(r1, 0) && a.contains(r1, 64) && a.contains(r1, 129));
        assert!(!a.contains(r0, 64));
        assert_eq!(popcount(a.row(r1)), 3);
        assert_eq!(bits(a.row(r1)).collect::<Vec<_>>(), vec![0, 64, 129]);
        a.pop_last();
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn discard_front_shifts_rows() {
        let mut a = SetArena::new(70);
        for i in 0..5 {
            let r = a.push_empty();
            a.set_bit(r, i);
            a.set_bit(r, 64 + (i % 6));
        }
        a.discard_front(2);
        assert_eq!(a.len(), 3);
        // former rows 2..5 are now rows 0..3
        for (new, old) in (0..3).zip(2..5) {
            assert!(a.contains(new, old), "row {new} should hold bit {old}");
            assert_eq!(popcount(a.row(new)), 2);
        }
        a.discard_front(0); // no-op
        assert_eq!(a.len(), 3);
        a.discard_front(3);
        assert!(a.is_empty());
    }

    #[test]
    fn intern_dedups() {
        let mut a = SetArena::new(100);
        let mut t = InternTable::with_capacity(4);
        let r0 = a.push_empty();
        a.set_bit(r0, 5);
        assert_eq!(t.intern_last(&mut a), (0, true));
        // identical content → deduped, staged row popped
        let r1 = a.push_empty();
        a.set_bit(r1, 5);
        assert_eq!(t.intern_last(&mut a), (0, false));
        assert_eq!(a.len(), 1);
        // different content → kept
        let r2 = a.push_empty();
        a.set_bit(r2, 6);
        assert_eq!(t.intern_last(&mut a), (1, true));
        assert_eq!(a.len(), 2);
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn intern_grows_past_load_factor() {
        let mut a = SetArena::new(512);
        let mut t = InternTable::with_capacity(4);
        for i in 0..200 {
            let r = a.push_empty();
            a.set_bit(r, i);
            let (id, fresh) = t.intern_last(&mut a);
            assert!(fresh);
            assert_eq!(id as usize, i);
        }
        // all still findable after growth
        let mut scratch = vec![0u64; a.stride()];
        for i in 0..200 {
            scratch.iter_mut().for_each(|w| *w = 0);
            word_set(&mut scratch, i);
            let h = hash_words(&scratch);
            assert_eq!(t.find(h, &scratch, &a), Some(i as u32));
        }
    }

    #[test]
    fn word_ops() {
        let mut a = vec![0u64; 2];
        word_set(&mut a, 3);
        word_set(&mut a, 70);
        let mut b = vec![0u64; 2];
        word_set(&mut b, 70);
        assert!(intersects(&a, &b));
        andnot_into(&mut a, &b);
        assert!(!intersects(&a, &b));
        assert!(word_contains(&a, 3));
        or_into(&mut a, &b);
        assert!(word_contains(&a, 70));
        and_into(&mut a, &b);
        assert_eq!(bits(&a).collect::<Vec<_>>(), vec![70]);
        word_clear(&mut a, 70);
        assert!(!any(&a));
    }

    #[test]
    fn bitmatrix_union_rows_both_directions() {
        let mut m = BitMatrix::new(200);
        m.set(0, 7);
        m.set(3, 150);
        m.union_rows(0, 3);
        assert!(m.get(0, 7) && m.get(0, 150));
        assert!(!m.get(3, 7));
        m.union_rows(3, 0);
        assert!(m.get(3, 7));
        m.union_rows(2, 2); // no-op, must not panic
        assert!(!m.get(2, 7));
    }

    #[test]
    fn hash_matches_bitset_fast_hash() {
        use crate::util::bitset::BitSet;
        let s = BitSet::from_iter(100, [1, 64, 99]);
        assert_eq!(hash_words(s.words()), s.fast_hash());
    }
}

//! Minimal JSON parser/serializer. The offline environment has no
//! `serde_json`, and the paper's workload files (msr-fiddle/dnn-partitioning
//! format) are JSON, so the crate carries its own implementation: a
//! recursive-descent parser producing a `Json` tree and a compact writer.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Numbers are stored as `f64`, which is exact for
//! the integer ids and millisecond costs the workload files contain.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for artifact diffing and golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`, or `Json::Null` when missing — convenient for optional
    /// fields in workload files.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- construction helpers -------------------------------------------

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // -- serialization ----------------------------------------------------

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Nesting bound for the recursive-descent parser. Each `[`/`{` level
/// costs a few stack frames, so unbounded input like `[[[[…` would
/// overflow the thread stack (an abort, not an `Err`) — fed to us by any
/// malformed or hostile workload file. Far above any real workload's
/// nesting, far below stack exhaustion.
const MAX_DEPTH: usize = 512;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.nested(Parser::object),
            Some(b'[') => self.nested(Parser::array),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", lit)))
        }
    }

    /// Run a container parse one nesting level down, restoring the level
    /// on the way out; errors (not aborts) past [`MAX_DEPTH`].
    fn nested(
        &mut self,
        f: fn(&mut Parser<'a>) -> Result<Json, JsonError>,
    ) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.depth += 1;
        let r = f(self);
        self.depth -= 1;
        r
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in workload files;
                            // map lone surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes verbatim.
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid UTF-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"nodes":[{"id":0,"cost":1.5},{"id":1}],"k":6}"#).unwrap();
        assert_eq!(j.get("k").as_usize(), Some(6));
        let nodes = j.get("nodes").as_arr().unwrap();
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0].get("cost").as_f64(), Some(1.5));
        assert_eq!(nodes[1].get("cost"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":{"c":null,"d":false},"e":[]}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
        // pretty also roundtrips
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        // one past the cap must error; an abort here is the bug
        let deep = "[".repeat(MAX_DEPTH + 1);
        assert!(Json::parse(&deep).is_err());
        let deep_obj = "{\"a\":".repeat(MAX_DEPTH + 1);
        assert!(Json::parse(&deep_obj).is_err());
        // within the cap still parses
        let ok = format!("{}{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}

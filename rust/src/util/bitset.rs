//! Fixed-capacity bitset used for node sets (ideals, reachability rows,
//! subgraphs). The dynamic-programming search space of this crate is a
//! lattice of *ideals* of a DAG, each represented as one `BitSet`; the DP
//! hot loop hashes, compares and walks these sets, so the representation is
//! a flat `Vec<u64>` with no indirection beyond the one allocation.

use crate::util::arena;
use std::fmt;

/// A set of `usize` elements in `0..capacity`, stored as 64-bit words.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct BitSet {
    words: Vec<u64>,
    /// Number of addressable bits (not the number of set bits).
    capacity: usize,
}

impl BitSet {
    /// Empty set able to hold elements `0..capacity`.
    pub fn new(capacity: usize) -> Self {
        BitSet { words: vec![0; capacity.div_ceil(64)], capacity }
    }

    /// Set containing every element in `0..capacity`: whole words filled at
    /// once, with the partial tail word masked.
    pub fn full(capacity: usize) -> Self {
        let mut words = vec![!0u64; capacity.div_ceil(64)];
        let tail = capacity % 64;
        if tail != 0 {
            if let Some(last) = words.last_mut() {
                *last = (1u64 << tail) - 1;
            }
        }
        BitSet { words, capacity }
    }

    /// Build from raw words (low word first; bits ≥ `capacity` must be 0).
    pub fn from_words(capacity: usize, words: &[u64]) -> Self {
        debug_assert_eq!(words.len(), capacity.div_ceil(64));
        BitSet { words: words.to_vec(), capacity }
    }

    /// The backing words (low word first) — arena/word-slice interop.
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Overwrite content from a word slice of the same stride.
    pub fn copy_from_words(&mut self, words: &[u64]) {
        debug_assert_eq!(words.len(), self.words.len());
        self.words.copy_from_slice(words);
    }

    /// In-place union with a raw word slice of the same stride.
    pub fn union_with_words(&mut self, words: &[u64]) {
        arena::or_into(&mut self.words, words);
    }

    /// Build from an iterator of elements.
    pub fn from_iter<I: IntoIterator<Item = usize>>(capacity: usize, iter: I) -> Self {
        let mut s = Self::new(capacity);
        for i in iter {
            s.insert(i);
        }
        s
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    #[inline]
    pub fn insert(&mut self, i: usize) {
        debug_assert!(i < self.capacity);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    pub fn remove(&mut self, i: usize) {
        debug_assert!(i < self.capacity);
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        debug_assert!(i < self.capacity);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    /// Number of elements in the set.
    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// `self ⊆ other`.
    pub fn is_subset(&self, other: &BitSet) -> bool {
        self.words.iter().zip(&other.words).all(|(a, b)| a & !b == 0)
    }

    /// In-place union. (The word loops delegate to `util::arena` so any
    /// future upgrade there — e.g. explicit SIMD — applies everywhere.)
    pub fn union_with(&mut self, other: &BitSet) {
        arena::or_into(&mut self.words, &other.words);
    }

    /// In-place intersection.
    pub fn intersect_with(&mut self, other: &BitSet) {
        arena::and_into(&mut self.words, &other.words);
    }

    /// In-place difference (`self \ other`).
    pub fn difference_with(&mut self, other: &BitSet) {
        arena::andnot_into(&mut self.words, &other.words);
    }

    /// `self ∩ other ≠ ∅` without allocating.
    pub fn intersects(&self, other: &BitSet) -> bool {
        arena::intersects(&self.words, &other.words)
    }

    /// New set `self \ other`.
    pub fn difference(&self, other: &BitSet) -> BitSet {
        let mut out = self.clone();
        out.difference_with(other);
        out
    }

    /// Iterate set elements in increasing order.
    pub fn iter(&self) -> BitSetIter<'_> {
        BitSetIter(arena::bits(&self.words))
    }

    /// Stable 64-bit hash used to key DP tables without re-hashing the
    /// whole `Vec` through `std`'s SipHash. Delegates to
    /// [`crate::util::arena::hash_words`] so arena rows and `BitSet`s hash
    /// identically (the intern-table lookups in `graph::ideals` rely on
    /// this).
    pub fn fast_hash(&self) -> u64 {
        crate::util::arena::hash_words(&self.words)
    }
}

impl fmt::Debug for BitSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_set().entries(self.iter()).finish()
    }
}

/// Thin wrapper over the shared word-slice iterator in `util::arena`.
pub struct BitSetIter<'a>(arena::WordBits<'a>);

impl Iterator for BitSetIter<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        self.0.next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_remove() {
        let mut s = BitSet::new(200);
        assert!(!s.contains(63));
        s.insert(63);
        s.insert(64);
        s.insert(199);
        assert!(s.contains(63) && s.contains(64) && s.contains(199));
        assert_eq!(s.len(), 3);
        s.remove(64);
        assert!(!s.contains(64));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn iter_order() {
        let s = BitSet::from_iter(300, [5, 0, 299, 64, 128]);
        let v: Vec<usize> = s.iter().collect();
        assert_eq!(v, vec![0, 5, 64, 128, 299]);
    }

    #[test]
    fn subset_and_ops() {
        let a = BitSet::from_iter(100, [1, 2, 3]);
        let b = BitSet::from_iter(100, [1, 2, 3, 50]);
        assert!(a.is_subset(&b));
        assert!(!b.is_subset(&a));
        assert!(a.is_subset(&a));
        let d = b.difference(&a);
        assert_eq!(d.iter().collect::<Vec<_>>(), vec![50]);
        assert!(b.intersects(&a));
        assert!(!d.intersects(&a));
    }

    #[test]
    fn full_and_empty() {
        let f = BitSet::full(65);
        assert_eq!(f.len(), 65);
        assert!(!f.is_empty());
        assert!(BitSet::new(65).is_empty());
        // tail word masked: no phantom bits beyond capacity
        for cap in [1, 63, 64, 65, 127, 128, 200] {
            let f = BitSet::full(cap);
            assert_eq!(f.len(), cap, "cap {cap}");
            assert_eq!(f.iter().collect::<Vec<_>>(), (0..cap).collect::<Vec<_>>());
            assert_eq!(f, BitSet::from_iter(cap, 0..cap));
        }
        assert!(BitSet::full(0).is_empty());
    }

    #[test]
    fn words_roundtrip() {
        let s = BitSet::from_iter(130, [0, 64, 129]);
        let t = BitSet::from_words(130, s.words());
        assert_eq!(s, t);
        let mut u = BitSet::new(130);
        u.union_with_words(s.words());
        assert_eq!(u, s);
        let mut v = BitSet::full(130);
        v.copy_from_words(s.words());
        assert_eq!(v, s);
    }

    #[test]
    fn union_intersect() {
        let mut a = BitSet::from_iter(100, [1, 2]);
        let b = BitSet::from_iter(100, [2, 3]);
        a.union_with(&b);
        assert_eq!(a.iter().collect::<Vec<_>>(), vec![1, 2, 3]);
        a.intersect_with(&b);
        assert_eq!(a.iter().collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn hash_differs() {
        let a = BitSet::from_iter(100, [1]);
        let b = BitSet::from_iter(100, [2]);
        assert_ne!(a.fast_hash(), b.fast_hash());
        assert_eq!(a.fast_hash(), a.clone().fast_hash());
    }
}

//! Fixed-capacity bitset used for node sets (ideals, reachability rows,
//! subgraphs). The dynamic-programming search space of this crate is a
//! lattice of *ideals* of a DAG, each represented as one `BitSet`; the DP
//! hot loop hashes, compares and walks these sets, so the representation is
//! a flat `Vec<u64>` with no indirection beyond the one allocation.

use std::fmt;

/// A set of `usize` elements in `0..capacity`, stored as 64-bit words.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct BitSet {
    words: Vec<u64>,
    /// Number of addressable bits (not the number of set bits).
    capacity: usize,
}

impl BitSet {
    /// Empty set able to hold elements `0..capacity`.
    pub fn new(capacity: usize) -> Self {
        BitSet { words: vec![0; capacity.div_ceil(64)], capacity }
    }

    /// Set containing every element in `0..capacity`.
    pub fn full(capacity: usize) -> Self {
        let mut s = Self::new(capacity);
        for i in 0..capacity {
            s.insert(i);
        }
        s
    }

    /// Build from an iterator of elements.
    pub fn from_iter<I: IntoIterator<Item = usize>>(capacity: usize, iter: I) -> Self {
        let mut s = Self::new(capacity);
        for i in iter {
            s.insert(i);
        }
        s
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    #[inline]
    pub fn insert(&mut self, i: usize) {
        debug_assert!(i < self.capacity);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    pub fn remove(&mut self, i: usize) {
        debug_assert!(i < self.capacity);
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        debug_assert!(i < self.capacity);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    /// Number of elements in the set.
    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// `self ⊆ other`.
    pub fn is_subset(&self, other: &BitSet) -> bool {
        self.words.iter().zip(&other.words).all(|(a, b)| a & !b == 0)
    }

    /// In-place union.
    pub fn union_with(&mut self, other: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// In-place intersection.
    pub fn intersect_with(&mut self, other: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// In-place difference (`self \ other`).
    pub fn difference_with(&mut self, other: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
        }
    }

    /// `self ∩ other ≠ ∅` without allocating.
    pub fn intersects(&self, other: &BitSet) -> bool {
        self.words.iter().zip(&other.words).any(|(a, b)| a & b != 0)
    }

    /// New set `self \ other`.
    pub fn difference(&self, other: &BitSet) -> BitSet {
        let mut out = self.clone();
        out.difference_with(other);
        out
    }

    /// Iterate set elements in increasing order.
    pub fn iter(&self) -> BitSetIter<'_> {
        BitSetIter { set: self, word_idx: 0, current: self.words.first().copied().unwrap_or(0) }
    }

    /// Stable 64-bit hash (FxHash-style) used to key DP tables without
    /// re-hashing the whole `Vec` through `std`'s SipHash.
    pub fn fast_hash(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &w in &self.words {
            h = (h ^ w).wrapping_mul(0x1000_0000_01b3);
        }
        h
    }
}

impl fmt::Debug for BitSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_set().entries(self.iter()).finish()
    }
}

pub struct BitSetIter<'a> {
    set: &'a BitSet,
    word_idx: usize,
    current: u64,
}

impl Iterator for BitSetIter<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        loop {
            if self.current != 0 {
                let bit = self.current.trailing_zeros() as usize;
                self.current &= self.current - 1;
                return Some(self.word_idx * 64 + bit);
            }
            self.word_idx += 1;
            if self.word_idx >= self.set.words.len() {
                return None;
            }
            self.current = self.set.words[self.word_idx];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_remove() {
        let mut s = BitSet::new(200);
        assert!(!s.contains(63));
        s.insert(63);
        s.insert(64);
        s.insert(199);
        assert!(s.contains(63) && s.contains(64) && s.contains(199));
        assert_eq!(s.len(), 3);
        s.remove(64);
        assert!(!s.contains(64));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn iter_order() {
        let s = BitSet::from_iter(300, [5, 0, 299, 64, 128]);
        let v: Vec<usize> = s.iter().collect();
        assert_eq!(v, vec![0, 5, 64, 128, 299]);
    }

    #[test]
    fn subset_and_ops() {
        let a = BitSet::from_iter(100, [1, 2, 3]);
        let b = BitSet::from_iter(100, [1, 2, 3, 50]);
        assert!(a.is_subset(&b));
        assert!(!b.is_subset(&a));
        assert!(a.is_subset(&a));
        let d = b.difference(&a);
        assert_eq!(d.iter().collect::<Vec<_>>(), vec![50]);
        assert!(b.intersects(&a));
        assert!(!d.intersects(&a));
    }

    #[test]
    fn full_and_empty() {
        let f = BitSet::full(65);
        assert_eq!(f.len(), 65);
        assert!(!f.is_empty());
        assert!(BitSet::new(65).is_empty());
    }

    #[test]
    fn union_intersect() {
        let mut a = BitSet::from_iter(100, [1, 2]);
        let b = BitSet::from_iter(100, [2, 3]);
        a.union_with(&b);
        assert_eq!(a.iter().collect::<Vec<_>>(), vec![1, 2, 3]);
        a.intersect_with(&b);
        assert_eq!(a.iter().collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn hash_differs() {
        let a = BitSet::from_iter(100, [1]);
        let b = BitSet::from_iter(100, [2]);
        assert_ne!(a.fast_hash(), b.fast_hash());
        assert_eq!(a.fast_hash(), a.clone().fast_hash());
    }
}

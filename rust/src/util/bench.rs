//! Minimal benchmarking harness (no `criterion` offline). Runs a closure
//! repeatedly with warmup, reports median / mean / p90 wall times, and
//! prints rows in a stable machine-grepable format consumed by
//! EXPERIMENTS.md tables.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub p90: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "bench {name:<44} iters={iters:<4} median={median:>12?} mean={mean:>12?} p90={p90:>12?} min={min:>12?}",
            name = self.name,
            iters = self.iters,
            median = self.median,
            mean = self.mean,
            p90 = self.p90,
            min = self.min,
        )
    }
}

/// Time `f`, choosing an iteration count so total time ≈ `budget`, with at
/// least `min_iters` samples. The closure's return value is black-boxed to
/// keep the optimizer honest.
pub fn bench<T>(name: &str, budget: Duration, min_iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    // Warmup + calibration run.
    let t0 = Instant::now();
    black_box(f());
    let one = t0.elapsed().max(Duration::from_nanos(50));
    let iters = ((budget.as_secs_f64() / one.as_secs_f64()).ceil() as usize)
        .clamp(min_iters, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        black_box(f());
        samples.push(t.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        median: samples[samples.len() / 2],
        mean,
        p90: samples[(samples.len() * 9 / 10).min(samples.len() - 1)],
        min: samples[0],
    };
    println!("{}", stats.report());
    stats
}

/// Opaque value sink (stable `std::hint::black_box`).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Time a single run of `f`, returning (result, elapsed).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

/// Format a Duration like the paper's runtime column ("0s", "5s", "1m",
/// "32m") for Table-1-style output.
pub fn paper_runtime(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 0.5 {
        "0s".into()
    } else if s < 99.5 {
        format!("{}s", s.round() as u64)
    } else {
        format!("{}m", (s / 60.0).round() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let stats = bench("noop", Duration::from_millis(5), 3, || 1 + 1);
        assert!(stats.iters >= 3);
        assert!(stats.median <= stats.p90);
        assert!(stats.min <= stats.median);
    }

    #[test]
    fn paper_runtime_format() {
        assert_eq!(paper_runtime(Duration::from_millis(100)), "0s");
        assert_eq!(paper_runtime(Duration::from_secs(5)), "5s");
        assert_eq!(paper_runtime(Duration::from_secs(119)), "2m");
        assert_eq!(paper_runtime(Duration::from_secs(32 * 60)), "32m");
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}

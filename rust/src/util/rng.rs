//! Deterministic PRNG (xoshiro256**). The offline build has no `rand`
//! crate; all stochastic components of the crate (local-search restarts,
//! random DAG generation for tests, workload jitter) draw from this
//! generator so every experiment is reproducible from a seed.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that even small/sequential seeds produce
    /// well-distributed internal state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (unbiased via rejection).
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Bernoulli(p).
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range(i + 1);
            v.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.gen_range(v.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.gen_range(13);
            assert!(x < 13);
        }
        for _ in 0..1000 {
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn range_covers_all_values() {
        let mut r = Rng::new(3);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[r.gen_range(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

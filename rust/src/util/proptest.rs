//! Property-testing harness (no `proptest` crate offline). Provides random
//! DAG/workload generators and a `check` runner that, on failure, replays a
//! seed so failures are reproducible, and *shrinks* DAG cases by deleting
//! nodes while the property still fails.

use crate::graph::{Node, NodeKind, OpGraph};
use crate::util::rng::Rng;

/// Run `prop` on `cases` random inputs produced by `gen`. On failure,
/// panics with the failing seed. Generators must be deterministic in the
/// provided `Rng`.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5eed_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property '{name}' failed on seed {seed:#x} (case {case}): {msg}");
        }
    }
}

/// Like [`check`] but for DAG-valued properties: shrinks a failing graph by
/// repeatedly removing single nodes while the property keeps failing, then
/// reports the minimal graph.
pub fn check_dag<P>(name: &str, cases: usize, max_nodes: usize, mut prop: P)
where
    P: FnMut(&OpGraph) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xda60_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let n = 2 + rng.gen_range(max_nodes.max(3) - 2);
        let g = random_dag(&mut rng, n, 0.3);
        if let Err(first_msg) = prop(&g) {
            // shrink: drop nodes one at a time while still failing
            let mut current = g;
            let mut msg = first_msg;
            'shrink: loop {
                for drop in 0..current.n() {
                    let smaller = remove_node(&current, drop);
                    if smaller.n() < 2 {
                        continue;
                    }
                    if let Err(m) = prop(&smaller) {
                        current = smaller;
                        msg = m;
                        continue 'shrink;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed on seed {seed:#x} (case {case}); shrunk to {} nodes / {} edges: {msg}\n{:?}",
                current.n(),
                current.num_edges(),
                current.edges().collect::<Vec<_>>()
            );
        }
    }
}

/// Random DAG: nodes 0..n with edges only forward in index order (so it is
/// a DAG by construction), each forward pair present with probability `p`.
/// Costs are positive and varied; some nodes get comm-heavy outputs.
pub fn random_dag(rng: &mut Rng, n: usize, p: f64) -> OpGraph {
    let mut g = OpGraph::new();
    for i in 0..n {
        let node = Node::new(format!("r{i}"))
            .cpu(rng.gen_f64_range(0.5, 8.0))
            .acc(rng.gen_f64_range(0.1, 4.0))
            .mem(rng.gen_f64_range(0.1, 2.0))
            .comm(rng.gen_f64_range(0.0, 1.5));
        g.add_node(node);
    }
    for u in 0..n {
        for v in (u + 1)..n {
            if rng.gen_bool(p) {
                g.add_edge(u, v);
            }
        }
    }
    g
}

/// Random *training-shaped* DAG: a forward random DAG plus a mirrored
/// backward part with colocation color classes linking partners.
/// Deterministic training chain: a forward chain of `n` nodes built from
/// the `fw` cost template, mirrored colocated backward partners from the
/// `bw` template (reversed edges), and the loss bridge at the sink — the
/// deterministic cousin of [`random_training_dag`], shared by the simx
/// engine/equivalence/validation suites.
pub fn training_chain(n: usize, fw: &Node, bw: &Node) -> OpGraph {
    let mut g = OpGraph::new();
    for i in 0..n {
        let mut node = fw.clone();
        node.name = format!("f{i}");
        g.add_node(node);
    }
    for i in 1..n {
        g.add_edge(i - 1, i);
    }
    for i in (0..n).rev() {
        let mut node = bw.clone();
        node.name = format!("b{i}");
        node.kind = NodeKind::Backward;
        node.fw_partner = Some(i);
        node.color_class = Some(i as u32);
        let id = g.add_node(node);
        g.nodes[i].color_class = Some(i as u32);
        if i + 1 < n {
            g.add_edge(id - 1, id); // bw chain reversed: b(i+1) -> b(i)
        } else {
            g.add_edge(i, id); // loss bridge: fw sink -> bw source
        }
    }
    g
}

pub fn random_training_dag(rng: &mut Rng, n_fw: usize, p: f64) -> OpGraph {
    let mut g = random_dag(rng, n_fw, p);
    let n = g.n();
    // backward part: mirror nodes (some orphaned with probability 0.1)
    let mut bw_id = vec![None; n];
    for v in (0..n).rev() {
        if rng.gen_bool(0.9) {
            let mut node = Node::new(format!("bw{v}"))
                .cpu(g.nodes[v].p_cpu * 2.0)
                .acc(g.nodes[v].p_acc * 2.0)
                .mem(g.nodes[v].mem)
                .comm(g.nodes[v].comm)
                .backward();
            node.fw_partner = Some(v);
            node.color_class = Some(v as u32);
            g.nodes[v].color_class = Some(v as u32);
            bw_id[v] = Some(g.add_node(node));
        }
    }
    // connect last forward node to first backward node; mirror edges
    let fw_edges: Vec<(usize, usize)> =
        g.edges().filter(|&(u, v)| u < n && v < n).collect();
    for (u, v) in fw_edges {
        if let (Some(bu), Some(bv)) = (bw_id[u], bw_id[v]) {
            g.add_edge(bv, bu); // reversed
        }
    }
    // bridge fw → bw so the whole thing is connected (loss node)
    if let Some(first_bw) = (0..n).rev().filter_map(|v| bw_id[v]).next() {
        // attach to some forward sink
        let sinks: Vec<usize> = (0..n).filter(|&v| g.succs[v].iter().all(|&w| w >= n)).collect();
        if let Some(&s) = sinks.first() {
            g.add_edge(s, first_bw);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topo::is_dag;
    use crate::graph::NodeKind;

    #[test]
    fn random_dag_is_dag() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let g = random_dag(&mut rng, 12, 0.3);
            assert!(is_dag(&g));
            assert_eq!(g.n(), 12);
        }
    }

    #[test]
    fn random_training_dag_is_dag_with_backward() {
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            let g = random_training_dag(&mut rng, 8, 0.3);
            assert!(is_dag(&g));
            assert!(g.nodes.iter().any(|n| n.kind == NodeKind::Backward));
        }
    }

    #[test]
    fn check_passes_trivially() {
        check("trivial", 10, |r| r.gen_range(10), |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 5, |r| r.gen_range(10), |&x| {
            if x < 100 {
                Err(format!("x={x}"))
            } else {
                Ok(())
            }
        });
    }
}

/// Remove node `v` (reconnecting nothing — shrinking keeps it simple).
fn remove_node(g: &OpGraph, v: usize) -> OpGraph {
    let mut out = OpGraph::new();
    let mut map = vec![usize::MAX; g.n()];
    for (i, node) in g.nodes.iter().enumerate() {
        if i != v {
            map[i] = out.add_node(node.clone());
        }
    }
    for (a, b) in g.edges() {
        if a != v && b != v {
            out.add_edge(map[a], map[b]);
        }
    }
    out
}

//! Device-interconnect topology: per-device-pair bandwidth/latency.
//!
//! The paper charges every cut edge one scalar bandwidth; real fleets are
//! NVLink islands over PCIe hosts over a datacenter network (Moirai,
//! QuickP's `DeviceGraph`). [`Topology`] holds a dense per-ordered-pair
//! matrix over the fleet's device slots — accelerators first, in class
//! order, then CPUs, the same dense index space as `Fleet::dense_view` —
//! and prices a transfer of `s` reference-seconds across the pair
//! `(a, b)` as
//!
//! ```text
//! transfer_cost(a, b, s) = s * slowdown(a, b) + latency(a, b)
//! ```
//!
//! `slowdown(a, b) = ref_bw / bw(a, b)` is normalized against the
//! *fastest* off-diagonal link (`ref_bw = max bw`), so `slowdown >= 1.0`
//! everywhere and equals exactly `1.0` on every pair of a uniform
//! topology. Node `comm` costs stay what they always were — transfer
//! time at reference bandwidth — and the topology only stretches them.
//! The diagonal is pinned to `slowdown = 1.0`, `latency = 0.0`, which
//! makes the uniform case bitwise-identical to the scalar path:
//! `s * 1.0 + 0.0 == s` in IEEE-754 for every finite non-negative `s`.
//! [`Topology::pair_cost`] additionally zeroes same-device transfers.
//!
//! Hierarchical constructors mirror real cluster shapes:
//! [`Topology::uniform`] (the `bw=` special case), [`Topology::islands`]
//! (NVLink islands bridged by a slow interconnect),
//! [`Topology::tiered`] (NVLink within an island, PCIe within a host,
//! network across hosts) and [`Topology::from_matrix`] (explicit).
//! Island/tier specs describe the *accelerators*; CPU slots attach to
//! everything over the slowest tier (inter-island / network), which is
//! where host RAM actually sits.
//!
//! [`TopoSpec`] is the parse/Display surface — the `topo=` clause of the
//! `--fleet` grammar and the JSON `topology` section both round-trip
//! through it:
//!
//! ```text
//! topo=uniform:900                    every pair at 900 (≡ scalar path)
//! topo=islands:2x4@900/64             2 islands of 4, 900 intra / 64 inter
//! topo=islands:0.2|1.3@900/64        explicit groups: {0,2} and {1,3}
//! topo=tiered:2x2x2@900/64/12         2 hosts × 2 islands × 2 devices
//! topo=matrix:0;64/64;0+0;0.5/0.5;0   explicit bw rows (+ optional latency)
//! ```

use std::fmt;

/// Parseable, display-able description of a topology. Kept alongside the
/// materialized matrices so `Fleet::parse` / `Display` round-trip the
/// exact clause the user wrote.
#[derive(Clone, Debug, PartialEq)]
pub enum TopoSpec {
    /// Every off-diagonal pair at the same bandwidth (scalar special case).
    Uniform { bw: f64 },
    /// Accelerator islands: fast links within a group, slow across groups
    /// and to CPUs. `groups` partitions the accelerator dense indices.
    Islands { groups: Vec<Vec<usize>>, intra_bw: f64, inter_bw: f64 },
    /// Three-tier cluster: `hosts` hosts × `islands_per_host` islands ×
    /// `size` accelerators; NVLink within an island, PCIe within a host,
    /// network across hosts (and to CPUs).
    Tiered {
        hosts: usize,
        islands_per_host: usize,
        size: usize,
        nvlink: f64,
        pcie: f64,
        net: f64,
    },
    /// Explicit per-pair bandwidth (and optional latency) matrices over
    /// *all* device slots. Diagonal entries are ignored.
    Matrix { bw: Vec<Vec<f64>>, lat: Vec<Vec<f64>> },
}

/// Slot-count sanity bound for topology shapes and materialization. A
/// materialized [`Topology`] holds n×n matrices, so fuzzed or
/// fat-fingered counts (`islands:999999999x999999999@…`,
/// `999999xacc,topo=uniform:900`) must be rejected instead of allocated
/// (or usize-overflowed) on. Far above any deployment the simulator can
/// drive; at the bound the matrices are ~33 MB each.
pub const MAX_SLOTS: usize = 2048;

fn parse_rate(s: &str, what: &str) -> Result<f64, String> {
    let v: f64 =
        s.parse().map_err(|_| format!("topology: bad {what} '{s}' (expected a number)"))?;
    if !(v.is_finite() && v > 0.0) {
        return Err(format!("topology: {what} must be positive and finite, got '{s}'"));
    }
    Ok(v)
}

fn parse_groups(shape: &str) -> Result<Vec<Vec<usize>>, String> {
    let mut groups = Vec::new();
    for gs in shape.split('|') {
        let mut g = Vec::new();
        for ms in gs.split('.') {
            let m: usize = ms.parse().map_err(|_| {
                format!("topology: bad island member '{ms}' in '{shape}' (expected device index)")
            })?;
            g.push(m);
        }
        if g.is_empty() {
            return Err(format!("topology: empty island group in '{shape}'"));
        }
        groups.push(g);
    }
    Ok(groups)
}

fn parse_matrix(part: &str, what: &str) -> Result<Vec<Vec<f64>>, String> {
    let mut rows = Vec::new();
    for rs in part.split('/') {
        let mut row = Vec::new();
        for es in rs.split(';') {
            let v: f64 = es
                .parse()
                .map_err(|_| format!("topology: bad {what} matrix entry '{es}'"))?;
            row.push(v);
        }
        rows.push(row);
    }
    Ok(rows)
}

fn fmt_matrix(m: &[Vec<f64>]) -> String {
    m.iter()
        .map(|row| row.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(";"))
        .collect::<Vec<_>>()
        .join("/")
}

impl TopoSpec {
    /// Parse the value of a `topo=` clause (grammar in the module docs).
    pub fn parse(s: &str) -> Result<TopoSpec, String> {
        let (kind, rest) = s.split_once(':').ok_or_else(|| {
            format!("topology spec '{s}' missing ':' (expected e.g. 'islands:2x4@900/64')")
        })?;
        match kind {
            "uniform" => Ok(TopoSpec::Uniform { bw: parse_rate(rest, "bandwidth")? }),
            "islands" => {
                let (shape, rates) = rest.split_once('@').ok_or_else(|| {
                    format!("islands spec '{s}' missing '@INTRA/INTER' rates")
                })?;
                let (intra, inter) = rates.split_once('/').ok_or_else(|| {
                    format!("islands spec '{s}' rates must be 'INTRA/INTER'")
                })?;
                let intra_bw = parse_rate(intra, "intra-island bandwidth")?;
                let inter_bw = parse_rate(inter, "inter-island bandwidth")?;
                // `GxS` = G consecutive blocks of S; anything else is the
                // explicit `0.2|1.3` group form.
                let block = shape.split_once('x').and_then(|(g, sz)| {
                    match (g.parse::<usize>(), sz.parse::<usize>()) {
                        (Ok(g), Ok(sz)) if g > 0 && sz > 0 => Some((g, sz)),
                        _ => None,
                    }
                });
                let groups = match block {
                    Some((g, sz)) => {
                        if g.checked_mul(sz).map_or(true, |t| t > MAX_SLOTS) {
                            return Err(format!(
                                "islands spec '{s}' covers more than {MAX_SLOTS} slots"
                            ));
                        }
                        (0..g).map(|i| (i * sz..(i + 1) * sz).collect()).collect()
                    }
                    None => parse_groups(shape)?,
                };
                Ok(TopoSpec::Islands { groups, intra_bw, inter_bw })
            }
            "tiered" => {
                let (shape, rates) = rest.split_once('@').ok_or_else(|| {
                    format!("tiered spec '{s}' missing '@NV/PCIE/NET' rates")
                })?;
                let dims: Vec<&str> = shape.split('x').collect();
                let rs: Vec<&str> = rates.split('/').collect();
                if dims.len() != 3 || rs.len() != 3 {
                    return Err(format!(
                        "tiered spec '{s}' must be 'tiered:HxGxS@NV/PCIE/NET'"
                    ));
                }
                let dim = |i: usize, what: &str| -> Result<usize, String> {
                    match dims[i].parse::<usize>() {
                        Ok(v) if v > 0 => Ok(v),
                        _ => Err(format!("tiered spec: bad {what} '{}'", dims[i])),
                    }
                };
                let hosts = dim(0, "host count")?;
                let islands_per_host = dim(1, "islands-per-host")?;
                let size = dim(2, "island size")?;
                if hosts
                    .checked_mul(islands_per_host)
                    .and_then(|t| t.checked_mul(size))
                    .map_or(true, |t| t > MAX_SLOTS)
                {
                    return Err(format!(
                        "tiered spec '{s}' covers more than {MAX_SLOTS} slots"
                    ));
                }
                Ok(TopoSpec::Tiered {
                    hosts,
                    islands_per_host,
                    size,
                    nvlink: parse_rate(rs[0], "nvlink bandwidth")?,
                    pcie: parse_rate(rs[1], "pcie bandwidth")?,
                    net: parse_rate(rs[2], "network bandwidth")?,
                })
            }
            "matrix" => {
                let (bw_part, lat_part) = match rest.split_once('+') {
                    Some((b, l)) => (b, Some(l)),
                    None => (rest, None),
                };
                let bw = parse_matrix(bw_part, "bandwidth")?;
                let lat = match lat_part {
                    Some(l) => parse_matrix(l, "latency")?,
                    None => bw.iter().map(|r| vec![0.0; r.len()]).collect(),
                };
                Ok(TopoSpec::Matrix { bw, lat })
            }
            other => Err(format!(
                "unknown topology kind '{other}' (expected uniform|islands|tiered|matrix)"
            )),
        }
    }

    /// Number of accelerator slots the spec pins down, if any (`Matrix`
    /// pins the *total* slot count instead and returns `None` here).
    fn acc_slots(&self) -> Option<usize> {
        match self {
            TopoSpec::Uniform { .. } | TopoSpec::Matrix { .. } => None,
            TopoSpec::Islands { groups, .. } => Some(groups.iter().map(Vec::len).sum()),
            TopoSpec::Tiered { hosts, islands_per_host, size, .. } => {
                Some(hosts * islands_per_host * size)
            }
        }
    }
}

impl fmt::Display for TopoSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TopoSpec::Uniform { bw } => write!(f, "uniform:{bw}"),
            TopoSpec::Islands { groups, intra_bw, inter_bw } => {
                // Prefer the compact GxS form when the groups are the
                // consecutive equal-size blocks it denotes.
                let sz = groups.first().map_or(0, Vec::len);
                let block = sz > 0
                    && groups.iter().enumerate().all(|(i, g)| {
                        g.len() == sz && g.iter().enumerate().all(|(j, &m)| m == i * sz + j)
                    });
                if block {
                    write!(f, "islands:{}x{}@{}/{}", groups.len(), sz, intra_bw, inter_bw)
                } else {
                    let shape = groups
                        .iter()
                        .map(|g| {
                            g.iter().map(|m| m.to_string()).collect::<Vec<_>>().join(".")
                        })
                        .collect::<Vec<_>>()
                        .join("|");
                    write!(f, "islands:{shape}@{intra_bw}/{inter_bw}")
                }
            }
            TopoSpec::Tiered { hosts, islands_per_host, size, nvlink, pcie, net } => {
                write!(f, "tiered:{hosts}x{islands_per_host}x{size}@{nvlink}/{pcie}/{net}")
            }
            TopoSpec::Matrix { bw, lat } => {
                write!(f, "matrix:{}", fmt_matrix(bw))?;
                if lat.iter().any(|r| r.iter().any(|&v| v != 0.0)) {
                    write!(f, "+{}", fmt_matrix(lat))?;
                }
                Ok(())
            }
        }
    }
}

/// Materialized per-pair cost model over `n` dense device slots.
///
/// Row-major `n × n` matrices; `slow` is the normalized slowdown
/// (diagonal exactly `1.0`), `lat` the per-pair latency (diagonal `0.0`).
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    spec: TopoSpec,
    n: usize,
    /// Raw off-diagonal bandwidths (diagonal unused; kept so slot
    /// add/remove can rebuild without losing the user's units).
    bw: Vec<f64>,
    slow: Vec<f64>,
    lat: Vec<f64>,
    max_slow: f64,
    max_lat: f64,
    min_lat: f64,
}

impl Topology {
    /// Build from raw matrices. `bw`/`lat` are row-major `n × n`;
    /// diagonal entries are ignored (pinned to slowdown 1, latency 0).
    fn build(spec: TopoSpec, n: usize, bw: Vec<f64>, lat: Vec<f64>) -> Result<Topology, String> {
        debug_assert_eq!(bw.len(), n * n);
        debug_assert_eq!(lat.len(), n * n);
        if n == 0 {
            return Err("topology: fleet has no devices".into());
        }
        let mut reference = 0.0_f64;
        for a in 0..n {
            for b in 0..n {
                if a == b {
                    continue;
                }
                let w = bw[a * n + b];
                if !(w.is_finite() && w > 0.0) {
                    return Err(format!(
                        "topology: bandwidth for pair ({a},{b}) must be positive, got {w}"
                    ));
                }
                let l = lat[a * n + b];
                if !(l.is_finite() && l >= 0.0) {
                    return Err(format!(
                        "topology: latency for pair ({a},{b}) must be non-negative, got {l}"
                    ));
                }
                reference = reference.max(w);
            }
        }
        let mut slow = vec![1.0; n * n];
        let mut lat_m = vec![0.0; n * n];
        let (mut max_slow, mut max_lat, mut min_lat) = (1.0_f64, 0.0_f64, f64::INFINITY);
        for a in 0..n {
            for b in 0..n {
                if a == b {
                    continue;
                }
                let s = reference / bw[a * n + b];
                let l = lat[a * n + b];
                slow[a * n + b] = s;
                lat_m[a * n + b] = l;
                max_slow = max_slow.max(s);
                max_lat = max_lat.max(l);
                min_lat = min_lat.min(l);
            }
        }
        if !min_lat.is_finite() {
            min_lat = 0.0; // n == 1: no off-diagonal pairs
        }
        Ok(Topology { spec, n, bw, slow, lat: lat_m, max_slow, max_lat, min_lat })
    }

    /// Materialize a spec for a fleet with `k` accelerator and `l` CPU
    /// slots (dense order: accelerators `0..k`, CPUs `k..k+l`).
    pub fn from_spec(spec: &TopoSpec, k: usize, l: usize) -> Result<Topology, String> {
        let n = k + l;
        if n > MAX_SLOTS {
            return Err(format!(
                "topology: fleet has {n} slots, more than the {MAX_SLOTS} a \
                 per-pair topology can cover"
            ));
        }
        if let Some(acc) = spec.acc_slots() {
            if acc != k {
                return Err(format!(
                    "topology spec '{spec}' covers {acc} accelerators but the fleet has {k}"
                ));
            }
        }
        match spec {
            TopoSpec::Uniform { bw } => {
                let m = vec![*bw; n * n];
                Topology::build(spec.clone(), n, m, vec![0.0; n * n])
            }
            TopoSpec::Islands { groups, intra_bw, inter_bw } => {
                let mut island = vec![usize::MAX; n];
                for (gi, g) in groups.iter().enumerate() {
                    for &m in g {
                        if m >= k {
                            return Err(format!(
                                "topology: island member {m} is not an accelerator (k = {k})"
                            ));
                        }
                        if island[m] != usize::MAX {
                            return Err(format!(
                                "topology: accelerator {m} appears in two islands"
                            ));
                        }
                        island[m] = gi;
                    }
                }
                let mut bw = vec![*inter_bw; n * n];
                for a in 0..k {
                    for b in 0..k {
                        if island[a] == island[b] {
                            bw[a * n + b] = *intra_bw;
                        }
                    }
                }
                Topology::build(spec.clone(), n, bw, vec![0.0; n * n])
            }
            TopoSpec::Tiered { islands_per_host, size, nvlink, pcie, net, .. } => {
                let mut bw = vec![*net; n * n];
                for a in 0..k {
                    for b in 0..k {
                        if a / size == b / size {
                            bw[a * n + b] = *nvlink;
                        } else if a / (size * islands_per_host) == b / (size * islands_per_host)
                        {
                            bw[a * n + b] = *pcie;
                        }
                    }
                }
                Topology::build(spec.clone(), n, bw, vec![0.0; n * n])
            }
            TopoSpec::Matrix { bw, lat } => {
                let dim_ok = |m: &Vec<Vec<f64>>| {
                    m.len() == n && m.iter().all(|r| r.len() == n)
                };
                if !dim_ok(bw) || !dim_ok(lat) {
                    return Err(format!(
                        "topology: matrix must be {n}x{n} for this fleet (got {}x{})",
                        bw.len(),
                        bw.first().map_or(0, Vec::len)
                    ));
                }
                let flat =
                    |m: &Vec<Vec<f64>>| m.iter().flat_map(|r| r.iter().copied()).collect();
                // The validator skips the diagonal, so placeholder 0s there
                // are fine.
                Topology::build(spec.clone(), n, flat(bw), flat(lat))
            }
        }
    }

    /// All `n` slots at one bandwidth — the scalar `bw=` special case.
    pub fn uniform(n: usize, bw: f64) -> Result<Topology, String> {
        Topology::from_spec(&TopoSpec::Uniform { bw }, n, 0)
    }

    /// Accelerator islands over a slow interconnect. `groups` must
    /// partition `0..total` where `total` is the number of members.
    pub fn islands(
        groups: Vec<Vec<usize>>,
        intra_bw: f64,
        inter_bw: f64,
    ) -> Result<Topology, String> {
        let k = groups.iter().map(Vec::len).sum();
        Topology::from_spec(&TopoSpec::Islands { groups, intra_bw, inter_bw }, k, 0)
    }

    /// Three-tier cluster of `hosts × islands_per_host × size` devices.
    pub fn tiered(
        hosts: usize,
        islands_per_host: usize,
        size: usize,
        nvlink: f64,
        pcie: f64,
        net: f64,
    ) -> Result<Topology, String> {
        let spec = TopoSpec::Tiered { hosts, islands_per_host, size, nvlink, pcie, net };
        Topology::from_spec(&spec, hosts * islands_per_host * size, 0)
    }

    /// Explicit per-pair matrices (diagonal entries ignored).
    pub fn from_matrix(bw: Vec<Vec<f64>>, lat: Vec<Vec<f64>>) -> Result<Topology, String> {
        let n = bw.len();
        Topology::from_spec(&TopoSpec::Matrix { bw, lat }, n, 0)
    }

    /// Number of device slots covered.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The parse/Display spec this topology was materialized from.
    pub fn spec(&self) -> &TopoSpec {
        &self.spec
    }

    /// Dense pair index; out-of-range slots clamp to the last one. (The
    /// solvers model a phantom CPU slot when the fleet declares `l = 0`;
    /// clamping prices its links like the last real device's.)
    #[inline]
    fn at(&self, a: usize, b: usize) -> usize {
        a.min(self.n - 1) * self.n + b.min(self.n - 1)
    }

    /// Normalized slowdown for `a → b`; `1.0` on the diagonal and on
    /// every pair of a uniform topology.
    #[inline]
    pub fn slowdown(&self, a: usize, b: usize) -> f64 {
        self.slow[self.at(a, b)]
    }

    /// Per-pair latency for `a → b`; `0.0` on the diagonal.
    #[inline]
    pub fn latency(&self, a: usize, b: usize) -> f64 {
        self.lat[self.at(a, b)]
    }

    /// Cost of moving `s` reference-seconds of data `a → b`:
    /// `s * slowdown + latency`. Diagonal cost is exactly `s`.
    #[inline]
    pub fn transfer_cost(&self, a: usize, b: usize, s: f64) -> f64 {
        let i = self.at(a, b);
        s * self.slow[i] + self.lat[i]
    }

    /// Like [`Self::transfer_cost`] but free on the same device — the
    /// canonical accessor for cut-edge pricing.
    #[inline]
    pub fn pair_cost(&self, a: usize, b: usize, s: f64) -> f64 {
        if a.min(self.n - 1) == b.min(self.n - 1) {
            0.0
        } else {
            self.transfer_cost(a, b, s)
        }
    }

    /// Largest off-diagonal slowdown (`1.0` for uniform / single-slot).
    pub fn max_slowdown(&self) -> f64 {
        self.max_slow
    }

    /// Largest off-diagonal latency (`0.0` for uniform / single-slot).
    pub fn max_latency(&self) -> f64 {
        self.max_lat
    }

    /// Smallest off-diagonal latency (`0.0` when there are no pairs).
    /// The smallest off-diagonal *slowdown* is `1.0` by normalization.
    pub fn min_offdiag_latency(&self) -> f64 {
        self.min_lat
    }

    /// Conservative worst-pair bound: `s * max_slowdown + max_latency`.
    /// Bitwise-identity (`s * 1.0 + 0.0`) on uniform topologies.
    #[inline]
    pub fn worst_pair_cost(&self, s: f64) -> f64 {
        s * self.max_slow + self.max_lat
    }

    /// Topology with slot `i` removed (for `Fleet::decrement`). Uniform
    /// specs stay uniform; every other spec degrades to an explicit
    /// matrix over the surviving slots.
    pub fn without_slot(&self, i: usize) -> Result<Topology, String> {
        let n = self.n;
        if n <= 1 {
            return Err("topology: cannot remove the last device slot".into());
        }
        let i = i.min(n - 1);
        if let TopoSpec::Uniform { bw } = &self.spec {
            return Topology::uniform(n - 1, *bw);
        }
        let keep: Vec<usize> = (0..n).filter(|&s| s != i).collect();
        let pick = |m: &[f64]| -> Vec<Vec<f64>> {
            keep.iter()
                .map(|&a| keep.iter().map(|&b| if a == b { 0.0 } else { m[a * n + b] }).collect())
                .collect()
        };
        Topology::from_matrix(pick(&self.bw), pick(&self.lat))
    }

    /// Topology with a copy of slot `i` inserted at `i + 1` (for
    /// `Fleet::increment`): the clone inherits slot `i`'s rows/columns
    /// and connects to `i` itself over `i`'s fastest link — "the new
    /// device joins its twin's island". Uniform specs stay uniform.
    pub fn with_cloned_slot(&self, i: usize) -> Result<Topology, String> {
        let n = self.n;
        let i = i.min(n - 1);
        if let TopoSpec::Uniform { bw } = &self.spec {
            return Topology::uniform(n + 1, *bw);
        }
        // Fastest link out of `i` prices the twin pair; a single-slot
        // topology has no links, so fall back to the reference rate 1.
        let mut best = (1.0_f64, 0.0_f64);
        let mut seen = false;
        for b in 0..n {
            if b != i && (!seen || self.bw[i * n + b] > best.0) {
                best = (self.bw[i * n + b], self.lat[i * n + b]);
                seen = true;
            }
        }
        let idx = |s: usize| if s <= i { s } else { s - 1 }; // new-slot index → old
        let m = n + 1;
        let grid = |src: &[f64], twin: f64| -> Vec<Vec<f64>> {
            (0..m)
                .map(|a| {
                    (0..m)
                        .map(|b| {
                            if a == b {
                                0.0
                            } else if (a == i || a == i + 1) && (b == i || b == i + 1) {
                                twin
                            } else {
                                src[idx(a) * n + idx(b)]
                            }
                        })
                        .collect()
                })
                .collect()
        };
        Topology::from_matrix(grid(&self.bw, best.0), grid(&self.lat, best.1))
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.spec.fmt(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_is_bitwise_identity() {
        let t = Topology::uniform(4, 900.0).unwrap();
        for a in 0..4 {
            for b in 0..4 {
                assert_eq!(t.slowdown(a, b).to_bits(), 1.0_f64.to_bits());
                assert_eq!(t.latency(a, b).to_bits(), 0.0_f64.to_bits());
                for &s in &[0.0, 0.3, 7.25, 1e9] {
                    assert_eq!(t.transfer_cost(a, b, s).to_bits(), s.to_bits());
                }
            }
        }
        assert_eq!(t.max_slowdown().to_bits(), 1.0_f64.to_bits());
        assert_eq!(t.max_latency().to_bits(), 0.0_f64.to_bits());
        assert_eq!(t.worst_pair_cost(2.5).to_bits(), 2.5_f64.to_bits());
    }

    #[test]
    fn islands_price_cross_island_pairs() {
        // 2 islands of 2 accelerators + 1 CPU slot.
        let spec = TopoSpec::parse("islands:2x2@800/100").unwrap();
        let t = Topology::from_spec(&spec, 4, 1).unwrap();
        assert_eq!(t.n(), 5);
        assert_eq!(t.slowdown(0, 1), 1.0); // intra = fastest link
        assert_eq!(t.slowdown(0, 2), 8.0); // 800 / 100
        assert_eq!(t.slowdown(0, 4), 8.0); // CPU over the slow tier
        assert_eq!(t.pair_cost(0, 0, 3.0), 0.0);
        assert_eq!(t.pair_cost(0, 2, 3.0), 24.0);
        assert_eq!(t.max_slowdown(), 8.0);
    }

    #[test]
    fn tiered_has_three_rates() {
        let t = Topology::tiered(2, 2, 2, 900.0, 90.0, 9.0).unwrap();
        assert_eq!(t.n(), 8);
        assert_eq!(t.slowdown(0, 1), 1.0); // same island
        assert_eq!(t.slowdown(0, 2), 10.0); // same host, PCIe
        assert_eq!(t.slowdown(0, 4), 100.0); // cross-host network
    }

    #[test]
    fn matrix_latency_and_asymmetry() {
        let t = Topology::from_matrix(
            vec![vec![0.0, 4.0], vec![2.0, 0.0]],
            vec![vec![0.0, 0.5], vec![0.25, 0.0]],
        )
        .unwrap();
        assert_eq!(t.slowdown(0, 1), 1.0); // 4 is the reference
        assert_eq!(t.slowdown(1, 0), 2.0);
        assert_eq!(t.transfer_cost(0, 1, 2.0), 2.5);
        assert_eq!(t.transfer_cost(1, 0, 2.0), 4.25);
        assert_eq!(t.min_offdiag_latency(), 0.25);
    }

    #[test]
    fn spec_display_parse_roundtrip() {
        for s in [
            "uniform:900",
            "islands:2x4@900/64",
            "islands:0.2|1.3@900/64",
            "tiered:2x2x2@900/64/12",
            "matrix:0;64/64;0",
            "matrix:0;64/64;0+0;0.5/0.5;0",
        ] {
            let spec = TopoSpec::parse(s).unwrap();
            assert_eq!(spec.to_string(), s, "display drifted for {s}");
            assert_eq!(TopoSpec::parse(&spec.to_string()).unwrap(), spec);
        }
        // Block-structured explicit groups collapse to the GxS form.
        let spec = TopoSpec::parse("islands:0.1|2.3@900/64").unwrap();
        assert_eq!(spec.to_string(), "islands:2x2@900/64");
    }

    #[test]
    fn bad_specs_are_loud() {
        for s in [
            "islands:2x4",            // no rates
            "islands:2x4@900",        // one rate
            "ring:4@10",              // unknown kind
            "uniform:-1",             // non-positive
            "uniform:abc",            // not a number
            "matrix:0;1",             // not square (1x2)
            "islands:0.0|1@10/1",     // duplicate member
        ] {
            let err = TopoSpec::parse(s)
                .and_then(|spec| Topology::from_spec(&spec, 2, 0).map(|_| ()));
            assert!(err.is_err(), "expected '{s}' to be rejected");
        }
        // Spec / fleet size mismatch.
        let spec = TopoSpec::parse("islands:2x4@900/64").unwrap();
        assert!(Topology::from_spec(&spec, 6, 1).is_err());
    }

    #[test]
    fn slot_removal_and_cloning() {
        let t = Topology::islands(vec![vec![0, 1], vec![2, 3]], 800.0, 100.0).unwrap();
        let smaller = t.without_slot(3).unwrap();
        assert_eq!(smaller.n(), 3);
        assert_eq!(smaller.slowdown(0, 1), 1.0);
        assert_eq!(smaller.slowdown(0, 2), 8.0);
        let bigger = t.with_cloned_slot(1).unwrap();
        assert_eq!(bigger.n(), 5);
        assert_eq!(bigger.slowdown(1, 2), 1.0); // twin joins slot 1's island
        assert_eq!(bigger.slowdown(0, 2), 1.0); // clone of old pair (0,1)
        assert_eq!(bigger.slowdown(2, 4), 8.0); // still slow to island 2
        // Uniform stays uniform (and stays an identity).
        let u = Topology::uniform(3, 50.0).unwrap();
        assert_eq!(u.without_slot(0).unwrap().spec(), &TopoSpec::Uniform { bw: 50.0 });
        assert_eq!(u.with_cloned_slot(2).unwrap().n(), 4);
    }

    #[test]
    fn clamping_covers_phantom_cpu_slot() {
        let t = Topology::uniform(3, 10.0).unwrap();
        // Index 7 is out of range; it clamps to the last slot.
        assert_eq!(t.slowdown(0, 7), 1.0);
        assert_eq!(t.pair_cost(7, 9, 5.0), 0.0); // both clamp to slot 2
    }
}

//! The crate's cache-instrumentation counters, folded into obs (PR 9 —
//! previously `util::counters`, which now re-exports this module so every
//! `bump_*`/`ctx_builds` call site and test assertion is untouched).
//!
//! The [`crate::coordinator::context::ProblemCtx`] cache exists so that
//! planning every algorithm of a scenario computes each expensive shared
//! artifact at most once; these counters let tests assert that property
//! directly on the real entry points instead of trusting the cache
//! plumbing. They are thread-local (not global atomics) so concurrently
//! running tests cannot pollute each other's deltas; the counted
//! functions all run on the calling thread (the DP's layer workers never
//! re-enter them).
//!
//! [`ctx_builds`] is the one exception: the single-flight dedup of
//! [`crate::coordinator::concurrent::ConcurrentService`] promises at most
//! one `ProblemCtx` construction per fingerprint *across* threads, which a
//! thread-local counter cannot observe. It lives on the process-wide obs
//! registry; tests that assert on its delta serialize themselves (see
//! `rust/tests/concurrent_service.rs`).
//!
//! Every bump is mirrored into a registered [`crate::obs::Counter`]
//! (`lattice_enumerations_total`, `reachability_builds_total`,
//! `co_reachability_builds_total`, `ctx_builds_total`) so the `stats` CLI
//! and the Prometheus exporter see process-wide totals, while the
//! thread-local cells keep their exact per-thread semantics for tests.

use std::cell::Cell;
use std::sync::{Arc, OnceLock};

use crate::obs::recorder::{counter, Counter};

thread_local! {
    static ENUMERATE_CALLS: Cell<u64> = const { Cell::new(0) };
    static REACHABILITY_CALLS: Cell<u64> = const { Cell::new(0) };
    static CO_REACHABILITY_CALLS: Cell<u64> = const { Cell::new(0) };
}

fn enumerate_total() -> &'static Arc<Counter> {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| counter("lattice_enumerations_total"))
}

fn reachability_total() -> &'static Arc<Counter> {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| counter("reachability_builds_total"))
}

fn co_reachability_total() -> &'static Arc<Counter> {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| counter("co_reachability_builds_total"))
}

fn ctx_builds_total() -> &'static Arc<Counter> {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| counter("ctx_builds_total"))
}

/// Record one `IdealLattice::enumerate` invocation (called by `graph::ideals`).
pub fn bump_enumerate() {
    ENUMERATE_CALLS.with(|c| c.set(c.get() + 1));
    enumerate_total().inc();
}

/// Record one `topo::reachability_matrix` invocation.
pub fn bump_reachability() {
    REACHABILITY_CALLS.with(|c| c.set(c.get() + 1));
    reachability_total().inc();
}

/// Record one `topo::co_reachability_matrix` invocation.
pub fn bump_co_reachability() {
    CO_REACHABILITY_CALLS.with(|c| c.set(c.get() + 1));
    co_reachability_total().inc();
}

/// Lattice enumerations performed by this thread so far.
pub fn enumerate_calls() -> u64 {
    ENUMERATE_CALLS.with(Cell::get)
}

/// Reachability-matrix builds performed by this thread so far.
pub fn reachability_calls() -> u64 {
    REACHABILITY_CALLS.with(Cell::get)
}

/// Co-reachability-matrix builds performed by this thread so far.
pub fn co_reachability_calls() -> u64 {
    CO_REACHABILITY_CALLS.with(Cell::get)
}

/// Record one `ProblemCtx` construction (called by
/// `ProblemCtx::from_request_with_cap` — every constructor funnels there).
pub fn bump_ctx_build() {
    ctx_builds_total().inc();
}

/// `ProblemCtx` constructions performed process-wide so far.
pub fn ctx_builds() -> u64 {
    ctx_builds_total().get()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_increment_monotonically() {
        let a = enumerate_calls();
        bump_enumerate();
        bump_enumerate();
        assert_eq!(enumerate_calls(), a + 2);
        let r = reachability_calls();
        bump_reachability();
        assert_eq!(reachability_calls(), r + 1);
        let c = co_reachability_calls();
        bump_co_reachability();
        assert_eq!(co_reachability_calls(), c + 1);
        let b = ctx_builds();
        bump_ctx_build();
        // ≥: other tests may build contexts concurrently (global counter)
        assert!(ctx_builds() >= b + 1);
    }

    #[test]
    fn bumps_mirror_into_registered_totals() {
        let before = crate::obs::counter("lattice_enumerations_total").get();
        bump_enumerate();
        assert!(crate::obs::counter("lattice_enumerations_total").get() >= before + 1);
    }
}

//! The three read-side surfaces of the recorder (DESIGN.md §10):
//!
//! 1. **Chrome `trace_event` JSON** — `{"traceEvents": [...]}` loadable in
//!    Perfetto / `chrome://tracing`. Wall-clock spans become `"X"`
//!    (complete) events nested by time on per-thread lanes; instants
//!    become `"i"`; lane naming uses `"M"` metadata events. The same
//!    [`TraceEvent`] vocabulary carries simx's *virtual-time* Gantt lanes
//!    on a separate `pid`, so one file shows solver wall time next to the
//!    simulated pipeline.
//! 2. **Prometheus text exposition** — counters and histograms in the
//!    standard `# TYPE` / `name{labels} value` format. A series name may
//!    embed labels verbatim (`plan_shard_hits_total{shard="3"}`);
//!    histogram buckets are sparse (only non-empty `le` bounds plus
//!    `+Inf`), which scrapers accept and humans can read.
//! 3. **Structured JSON snapshot** — the whole [`Snapshot`] as one `Json`
//!    tree for programmatic diffing.

use crate::obs::hist::Histogram;
use crate::obs::recorder::Snapshot;
use crate::util::json::Json;

/// One Chrome `trace_event`. `ph` is the phase: `'X'` complete span,
/// `'i'` instant, `'M'` metadata, `'C'` counter track.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: String,
    pub cat: String,
    pub ph: char,
    /// Microseconds. Wall lanes use recorder time; simx lanes use
    /// simulated time (1 cost unit = 1 ms = 1000 µs).
    pub ts_us: f64,
    /// Only meaningful for `'X'` events.
    pub dur_us: f64,
    pub pid: u32,
    pub tid: u32,
    pub args: Vec<(String, Json)>,
}

impl TraceEvent {
    pub fn complete(
        name: impl Into<String>,
        cat: impl Into<String>,
        ts_us: f64,
        dur_us: f64,
        pid: u32,
        tid: u32,
    ) -> TraceEvent {
        TraceEvent {
            name: name.into(),
            cat: cat.into(),
            ph: 'X',
            ts_us,
            dur_us,
            pid,
            tid,
            args: Vec::new(),
        }
    }

    pub fn instant(
        name: impl Into<String>,
        cat: impl Into<String>,
        ts_us: f64,
        pid: u32,
        tid: u32,
    ) -> TraceEvent {
        TraceEvent {
            name: name.into(),
            cat: cat.into(),
            ph: 'i',
            ts_us,
            dur_us: f64::NAN,
            pid,
            tid,
            args: Vec::new(),
        }
    }

    /// `"M"` metadata event; `kind` is `"thread_name"` / `"process_name"`.
    pub fn meta(kind: &str, value: &str, pid: u32, tid: u32) -> TraceEvent {
        TraceEvent {
            name: kind.to_string(),
            cat: "__metadata".to_string(),
            ph: 'M',
            ts_us: 0.0,
            dur_us: f64::NAN,
            pid,
            tid,
            args: vec![("name".to_string(), Json::str(value))],
        }
    }

    pub fn arg(mut self, key: &str, val: Json) -> TraceEvent {
        self.args.push((key.to_string(), val));
        self
    }

    fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("name", Json::str(self.name.clone())),
            ("cat", Json::str(self.cat.clone())),
            ("ph", Json::str(self.ph.to_string())),
            ("ts", Json::num(self.ts_us)),
            ("pid", Json::num(self.pid as f64)),
            ("tid", Json::num(self.tid as f64)),
        ];
        if self.ph == 'X' {
            fields.push(("dur", Json::num(if self.dur_us.is_nan() { 0.0 } else { self.dur_us })));
        }
        if self.ph == 'i' {
            // scope "t": the instant belongs to its thread lane
            fields.push(("s", Json::str("t")));
        }
        if !self.args.is_empty() {
            fields.push((
                "args",
                Json::Obj(self.args.iter().map(|(k, v)| (k.clone(), v.clone())).collect()),
            ));
        }
        Json::obj(fields)
    }
}

/// Assemble the standard envelope: `{"traceEvents": [...],
/// "displayTimeUnit": "ms", <extra...>}`. `extra` carries run metadata
/// (workload, algorithm, steady TPS, …) that viewers ignore.
pub fn chrome_trace(events: &[TraceEvent], extra: Vec<(&str, Json)>) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("traceEvents", Json::Arr(events.iter().map(TraceEvent::to_json).collect())),
        ("displayTimeUnit", Json::str("ms")),
    ];
    fields.extend(extra);
    Json::obj(fields)
}

/// Convert the recorder's wall-clock spans/instants into trace events on
/// `pid`, one lane per recording thread.
pub fn span_events(snap: &Snapshot, pid: u32) -> Vec<TraceEvent> {
    let mut out = Vec::with_capacity(snap.spans.len() + snap.threads.len() + 1);
    out.push(TraceEvent::meta("process_name", "planner (wall time)", pid, 0));
    for (tid, name) in &snap.threads {
        out.push(TraceEvent::meta("thread_name", name, pid, *tid));
    }
    for rec in &snap.spans {
        let mut ev = if rec.is_instant() {
            TraceEvent::instant(rec.name.clone(), rec.cat, rec.ts_us, pid, rec.tid)
        } else {
            TraceEvent::complete(rec.name.clone(), rec.cat, rec.ts_us, rec.dur_us, pid, rec.tid)
        };
        ev.args = rec.args.clone();
        out.push(ev);
    }
    out
}

/// `name` or `name{labels}` → `(sanitized_base, Some(labels))`.
fn split_labels(name: &str) -> (String, Option<&str>) {
    let (base, labels) = match name.find('{') {
        Some(i) => (&name[..i], name[i..].strip_prefix('{').and_then(|r| r.strip_suffix('}'))),
        None => (name, None),
    };
    let base: String = base
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    (base, labels)
}

fn prom_line(out: &mut String, base: &str, suffix: &str, labels: &[String], value: &str) {
    out.push_str(base);
    out.push_str(suffix);
    if !labels.is_empty() {
        out.push('{');
        out.push_str(&labels.join(","));
        out.push('}');
    }
    out.push(' ');
    out.push_str(value);
    out.push('\n');
}

fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Render the snapshot in Prometheus text exposition format.
pub fn prometheus(snap: &Snapshot) -> String {
    let mut out = String::new();
    let mut last_type: Option<String> = None;
    let mut type_line = |out: &mut String, base: &str, kind: &str| {
        if last_type.as_deref() != Some(base) {
            out.push_str(&format!("# TYPE {base} {kind}\n"));
            last_type = Some(base.to_string());
        }
    };
    for (name, val) in &snap.counters {
        let (base, labels) = split_labels(name);
        type_line(&mut out, &base, "counter");
        let labels: Vec<String> = labels.iter().map(|l| l.to_string()).collect();
        prom_line(&mut out, &base, "", &labels, &val.to_string());
    }
    for (name, h) in &snap.hists {
        let (base, labels) = split_labels(name);
        type_line(&mut out, &base, "histogram");
        let series: Vec<String> = labels.iter().map(|l| l.to_string()).collect();
        for (le, cum) in h.cumulative() {
            let mut with_le = series.clone();
            with_le.push(format!("le=\"{}\"", fmt_f64(le)));
            prom_line(&mut out, &base, "_bucket", &with_le, &cum.to_string());
        }
        let mut inf = series.clone();
        inf.push("le=\"+Inf\"".to_string());
        prom_line(&mut out, &base, "_bucket", &inf, &h.count().to_string());
        prom_line(&mut out, &base, "_sum", &series, &fmt_f64(h.sum()));
        prom_line(&mut out, &base, "_count", &series, &h.count().to_string());
    }
    out
}

fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::num(v)
    } else {
        Json::Null
    }
}

fn hist_json(h: &Histogram) -> Json {
    Json::obj(vec![
        ("count", Json::num(h.count() as f64)),
        ("sum", num_or_null(h.sum())),
        ("min", num_or_null(h.min())),
        ("max", num_or_null(h.max())),
        ("mean", num_or_null(h.mean())),
        ("p50", num_or_null(h.p(50.0))),
        ("p90", num_or_null(h.p(90.0))),
        ("p99", num_or_null(h.p(99.0))),
        (
            "buckets",
            Json::Arr(
                h.cumulative()
                    .into_iter()
                    .map(|(le, cum)| Json::Arr(vec![num_or_null(le), Json::num(cum as f64)]))
                    .collect(),
            ),
        ),
    ])
}

/// The whole snapshot as one JSON tree (counters, histogram summaries,
/// span log, thread-lane names).
pub fn snapshot_json(snap: &Snapshot) -> Json {
    let counters =
        Json::Obj(snap.counters.iter().map(|(n, v)| (n.clone(), Json::num(*v as f64))).collect());
    let hists = Json::Obj(snap.hists.iter().map(|(n, h)| (n.clone(), hist_json(h))).collect());
    let spans = Json::Arr(
        snap.spans
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::str(r.name.clone())),
                    ("cat", Json::str(r.cat)),
                    ("tid", Json::num(r.tid as f64)),
                    ("depth", Json::num(r.depth as f64)),
                    ("ts_us", Json::num(r.ts_us)),
                    ("dur_us", if r.is_instant() { Json::Null } else { Json::num(r.dur_us) }),
                    (
                        "args",
                        Json::Obj(r.args.iter().map(|(k, v)| (k.clone(), v.clone())).collect()),
                    ),
                ])
            })
            .collect(),
    );
    let threads = Json::Obj(
        snap.threads.iter().map(|(tid, name)| (tid.to_string(), Json::str(name.clone()))).collect(),
    );
    Json::obj(vec![
        ("counters", counters),
        ("histograms", hists),
        ("spans", spans),
        ("threads", threads),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::SpanRecord;

    fn sample_snapshot() -> Snapshot {
        let mut h = Histogram::new();
        for v in [1.0, 2.0, 300.0] {
            h.record(v);
        }
        Snapshot {
            counters: vec![
                ("ctx_builds_total".to_string(), 3),
                ("plan_shard_hits_total{shard=\"0\"}".to_string(), 7),
            ],
            hists: vec![("plan_latency_ms".to_string(), h)],
            spans: vec![SpanRecord {
                name: "ctx.lattice".to_string(),
                cat: "ctx",
                tid: 0,
                depth: 1,
                ts_us: 10.0,
                dur_us: 25.0,
                args: vec![("ideals".to_string(), Json::num(12.0))],
            }],
            threads: vec![(0, "main".to_string())],
        }
    }

    #[test]
    fn prometheus_exposition_shape() {
        let text = prometheus(&sample_snapshot());
        assert!(text.contains("# TYPE ctx_builds_total counter"));
        assert!(text.contains("ctx_builds_total 3"));
        assert!(text.contains("plan_shard_hits_total{shard=\"0\"} 7"));
        assert!(text.contains("# TYPE plan_latency_ms histogram"));
        assert!(text.contains("plan_latency_ms_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("plan_latency_ms_count 3"));
        assert!(text.contains("plan_latency_ms_sum 303"));
    }

    #[test]
    fn chrome_trace_is_valid_json_with_required_fields() {
        let snap = sample_snapshot();
        let events = span_events(&snap, 1);
        let json = chrome_trace(&events, vec![("workload", Json::str("unit-test"))]);
        let text = json.to_string_pretty();
        let parsed = Json::parse(&text).expect("trace must be valid JSON");
        let evs = parsed.get("traceEvents").as_arr().unwrap();
        assert!(evs.len() >= 3, "process meta + thread meta + span");
        let span = evs.iter().find(|e| e.get("ph").as_str() == Some("X")).unwrap();
        assert_eq!(span.get("name").as_str(), Some("ctx.lattice"));
        assert_eq!(span.get("dur").as_f64(), Some(25.0));
        assert_eq!(span.get("args").get("ideals").as_f64(), Some(12.0));
    }

    #[test]
    fn snapshot_json_has_no_nan_tokens() {
        // an empty histogram has ±inf min/max and NaN quantiles — the JSON
        // exporter must map them all to null, or the output won't parse
        let snap = Snapshot {
            counters: vec![],
            hists: vec![("empty_ms".to_string(), Histogram::new())],
            spans: vec![],
            threads: vec![],
        };
        let text = snapshot_json(&snap).to_string_pretty();
        let parsed = Json::parse(&text).expect("snapshot must be valid JSON");
        assert_eq!(parsed.get("histograms").get("empty_ms").get("p50"), &Json::Null);
    }
}

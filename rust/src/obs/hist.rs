//! Fixed-bucket log2 histograms: bounded memory, mergeable, quantiles
//! without retaining samples.
//!
//! The bucket scheme is shared by the plain [`Histogram`] (single-owner
//! aggregation, e.g. inside a mutexed metrics struct) and the lock-free
//! [`AtomicHistogram`] (the recorder registry's concurrent form): 64
//! buckets laid out by the value's binary exponent.
//!
//! * bucket `0` — underflow: `v ≤ 0`, NaN, subnormals, and anything below
//!   `2^MIN_EXP`;
//! * bucket `i` (`1 ≤ i ≤ 62`) — `2^(MIN_EXP+i-1) ≤ v < 2^(MIN_EXP+i)`;
//! * bucket `63` — overflow: everything at or above `2^(MIN_EXP+62)`,
//!   including `+∞`.
//!
//! With `MIN_EXP = -20` the covered range is ≈ `9.5e-7 .. 4.4e12`, which
//! spans sub-microsecond spans, multi-hour latencies in milliseconds, and
//! terabyte transfer counts in one shape. A quantile estimate is the
//! bucket's upper bound clamped to the observed `[min, max]`, so the
//! relative error is at most one bucket (2×) and exact when all samples
//! share a bucket. Merging adds per-bucket counts — histograms recorded on
//! different threads or machines combine losslessly.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets (one underflow + 62 log2 + one overflow).
pub const BUCKETS: usize = 64;

/// Exponent of the first finite bucket's lower bound: bucket 1 starts at
/// `2^MIN_EXP`.
pub const MIN_EXP: i32 = -20;

/// Bucket index for a sample (see the module docs for the layout).
pub fn bucket_of(v: f64) -> usize {
    if v.is_nan() || v <= 0.0 {
        return 0;
    }
    let raw_exp = ((v.to_bits() >> 52) & 0x7ff) as i32;
    if raw_exp == 0 {
        return 0; // subnormal: far below 2^MIN_EXP
    }
    if raw_exp == 0x7ff {
        return BUCKETS - 1; // +inf
    }
    let idx = (raw_exp - 1023) - MIN_EXP + 1;
    idx.clamp(0, (BUCKETS - 1) as i32) as usize
}

/// Inclusive lower bound of bucket `i` (0 for the underflow bucket).
pub fn bucket_lower(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        (2.0f64).powi(MIN_EXP + i as i32 - 1)
    }
}

/// Exclusive upper bound of bucket `i` (`+∞` for the overflow bucket).
pub fn bucket_upper(i: usize) -> f64 {
    if i + 1 == BUCKETS {
        f64::INFINITY
    } else {
        (2.0f64).powi(MIN_EXP + i as i32)
    }
}

/// A mergeable fixed-memory log2 histogram. ~600 bytes regardless of how
/// many samples it has absorbed — the bound that lets long serving runs
/// keep per-stage latency distributions forever (DESIGN.md §10).
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: [0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Absorb one sample.
    pub fn record(&mut self, v: f64) {
        self.counts[bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Add another histogram's contents into this one. Bucket counts add,
    /// so merging is commutative and (with exactly-representable sums)
    /// associative — the property the obs tests pin.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest sample seen (`+∞` when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample seen (`-∞` when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Raw count of bucket `i`.
    pub fn bucket_count(&self, i: usize) -> u64 {
        self.counts[i]
    }

    /// Quantile estimate for `q ∈ [0, 1]`: the upper bound of the bucket
    /// holding the `⌈q·count⌉`-th sample, clamped to the observed
    /// `[min, max]`. NaN when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for i in 0..BUCKETS {
            seen += self.counts[i];
            if seen >= target {
                let rep = if i + 1 == BUCKETS { self.max } else { bucket_upper(i) };
                return rep.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Percentile convenience: `p(99.0)` is `quantile(0.99)`.
    pub fn p(&self, pct: f64) -> f64 {
        self.quantile(pct / 100.0)
    }

    /// `(upper_bound, cumulative_count)` for every non-empty bucket, the
    /// shape both text exporters consume.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for i in 0..BUCKETS {
            if self.counts[i] > 0 {
                cum += self.counts[i];
                out.push((bucket_upper(i), cum));
            }
        }
        out
    }
}

/// The registry's concurrent histogram: identical buckets, all-atomic
/// fields, `observe` from any thread without a lock. `sum`/`min`/`max`
/// are f64 bit-patterns updated by CAS loops.
pub struct AtomicHistogram {
    counts: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> AtomicHistogram {
        AtomicHistogram::new()
    }
}

impl AtomicHistogram {
    pub fn new() -> AtomicHistogram {
        AtomicHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }

    /// Absorb one sample (lock-free; relaxed ordering — totals are read
    /// only at snapshot time, never used for synchronization).
    pub fn observe(&self, v: f64) {
        self.counts[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        fold_f64(&self.sum_bits, v, |acc, v| acc + v);
        fold_f64(&self.min_bits, v, f64::min);
        fold_f64(&self.max_bits, v, f64::max);
    }

    /// Copy the current totals into a plain mergeable [`Histogram`].
    /// Concurrent `observe`s may land between field reads; each snapshot
    /// field is individually consistent, which is all the exporters need.
    pub fn snapshot(&self) -> Histogram {
        let mut h = Histogram::new();
        for (i, c) in self.counts.iter().enumerate() {
            h.counts[i] = c.load(Ordering::Relaxed);
        }
        h.count = self.count.load(Ordering::Relaxed);
        h.sum = f64::from_bits(self.sum_bits.load(Ordering::Relaxed));
        h.min = f64::from_bits(self.min_bits.load(Ordering::Relaxed));
        h.max = f64::from_bits(self.max_bits.load(Ordering::Relaxed));
        h
    }

    /// Zero every field (used by `obs::reset` between CLI phases/tests).
    pub fn clear(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_bits.store(0.0f64.to_bits(), Ordering::Relaxed);
        self.min_bits.store(f64::INFINITY.to_bits(), Ordering::Relaxed);
        self.max_bits.store(f64::NEG_INFINITY.to_bits(), Ordering::Relaxed);
    }
}

/// CAS-loop update of an f64 stored as bits: `bits ← op(bits, v)`.
fn fold_f64(bits: &AtomicU64, v: f64, op: impl Fn(f64, f64) -> f64) {
    let mut cur = bits.load(Ordering::Relaxed);
    loop {
        let next = op(f64::from_bits(cur), v).to_bits();
        match bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_exhaustive_and_ordered() {
        assert_eq!(bucket_of(f64::NAN), 0);
        assert_eq!(bucket_of(-1.0), 0);
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(f64::INFINITY), BUCKETS - 1);
        // every value lands in the bucket whose bounds contain it
        for i in 1..BUCKETS - 1 {
            let lo = bucket_lower(i);
            assert_eq!(bucket_of(lo), i, "lower bound of bucket {i}");
            assert_eq!(bucket_of(lo * 1.5), i, "interior of bucket {i}");
            assert_eq!(bucket_of(bucket_upper(i)), i + 1, "upper bound is exclusive");
        }
    }

    #[test]
    fn atomic_matches_plain() {
        let a = AtomicHistogram::new();
        let mut h = Histogram::new();
        for v in [0.5, 3.0, 3.0, 120.0, 1e9] {
            a.observe(v);
            h.record(v);
        }
        assert_eq!(a.snapshot(), h);
        a.clear();
        assert_eq!(a.snapshot().count(), 0);
    }

    #[test]
    fn quantiles_are_clamped_to_observed_range() {
        let mut h = Histogram::new();
        for _ in 0..100 {
            h.record(7.0);
        }
        // all samples equal ⇒ every quantile is exact
        assert_eq!(h.quantile(0.5), 7.0);
        assert_eq!(h.quantile(0.99), 7.0);
        assert_eq!(h.p(50.0), 7.0);
        assert!(Histogram::new().quantile(0.5).is_nan());
    }
}

//! The process-wide recorder: RAII [`Span`]s buffered per thread,
//! registered [`Counter`]s and [`AtomicHistogram`]s, and the coherent
//! [`Snapshot`] the exporters read.
//!
//! ## Cost contract (DESIGN.md §10)
//!
//! * **Counters and histograms are always live.** They are plain relaxed
//!   atomics with no allocation on the hot path; callers cache the `Arc`
//!   handle once (`obs::counter(name)`) and bump it forever. The `stats`
//!   CLI can therefore report cache/search/link totals without anyone
//!   having opted into tracing.
//! * **Spans and instants only exist while recording is enabled.** A
//!   disabled recorder makes [`span`] return an inert guard — one relaxed
//!   load, no clock read, no allocation — so instrumented hot paths cost
//!   nothing in production solves (the obs test suite pins bitwise-equal
//!   solver results with recording on vs off).
//! * **Flush contract.** Finished spans accumulate in a thread-local
//!   buffer and migrate to the global event log under one mutex lock when
//!   the thread's outermost span closes, when the buffer hits
//!   [`FLUSH_AT`] records, or when the thread exits (the thread-local's
//!   `Drop` — this is what makes spans from `util::par`'s scoped workers
//!   visible after `run_workers` returns). [`snapshot`] flushes the
//!   calling thread, so a thread sees its own history; other threads'
//!   *open* buffers become visible at their next flush point.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::obs::hist::{AtomicHistogram, Histogram};
use crate::util::json::Json;

/// Thread-local buffer size that forces an early flush.
pub const FLUSH_AT: usize = 256;

/// One finished span or instant, in recorder time (µs since the recorder
/// was first touched). `dur_us` is NaN for instants.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub name: String,
    /// Coarse category for trace viewers ("ctx", "solver", "ip", …).
    pub cat: &'static str,
    /// Dense per-thread lane id (assigned on a thread's first record).
    pub tid: u32,
    /// Number of enclosing spans open on the same thread at entry.
    pub depth: u32,
    pub ts_us: f64,
    pub dur_us: f64,
    pub args: Vec<(String, Json)>,
}

impl SpanRecord {
    pub fn is_instant(&self) -> bool {
        self.dur_us.is_nan()
    }
}

/// A monotonically increasing named total. Always live (see module docs);
/// `get` is exact for asserting deltas in tests.
pub struct Counter {
    val: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.val.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.val.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.val.load(Ordering::Relaxed)
    }

    fn clear(&self) {
        self.val.store(0, Ordering::Relaxed);
    }
}

struct Recorder {
    enabled: AtomicBool,
    epoch: Instant,
    events: Mutex<Vec<SpanRecord>>,
    /// `(tid, thread name)` pairs, one per thread that ever recorded.
    threads: Mutex<Vec<(u32, String)>>,
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    hists: Mutex<BTreeMap<String, Arc<AtomicHistogram>>>,
}

fn global() -> &'static Recorder {
    static R: OnceLock<Recorder> = OnceLock::new();
    R.get_or_init(|| Recorder {
        enabled: AtomicBool::new(false),
        epoch: Instant::now(),
        events: Mutex::new(Vec::new()),
        threads: Mutex::new(Vec::new()),
        counters: Mutex::new(BTreeMap::new()),
        hists: Mutex::new(BTreeMap::new()),
    })
}

static NEXT_TID: AtomicU32 = AtomicU32::new(0);

struct ThreadBuf {
    tid: u32,
    depth: u32,
    buf: Vec<SpanRecord>,
}

impl ThreadBuf {
    fn new() -> ThreadBuf {
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let name = std::thread::current()
            .name()
            .map(str::to_string)
            .unwrap_or_else(|| format!("thread-{tid}"));
        global().threads.lock().unwrap().push((tid, name));
        ThreadBuf { tid, depth: 0, buf: Vec::new() }
    }

    fn flush(&mut self) {
        if !self.buf.is_empty() {
            global().events.lock().unwrap().append(&mut self.buf);
        }
    }
}

impl Drop for ThreadBuf {
    // Thread exit is a flush point: scoped `util::par` workers hand their
    // spans over before `run_workers` returns.
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static TLS: RefCell<ThreadBuf> = RefCell::new(ThreadBuf::new());
}

/// Turn span/instant collection on or off (counters/histograms are always
/// live). The CLI's `--profile` flips this on for the run.
pub fn set_enabled(on: bool) {
    global().enabled.store(on, Ordering::Relaxed);
}

pub fn is_enabled() -> bool {
    global().enabled.load(Ordering::Relaxed)
}

struct SpanLive {
    name: String,
    cat: &'static str,
    ts_us: f64,
    begin: Instant,
    depth: u32,
    args: Vec<(String, Json)>,
}

/// RAII scoped timer: records a [`SpanRecord`] on drop. Inert (no clock
/// read, no allocation) when recording is disabled at entry.
#[must_use = "a Span records its duration on drop; bind it: let _span = obs::span(..)"]
pub struct Span(Option<SpanLive>);

impl Span {
    /// Attach a key/value shown under the event's `args` in trace viewers.
    pub fn arg(mut self, key: &str, val: Json) -> Span {
        if let Some(live) = self.0.as_mut() {
            live.args.push((key.to_string(), val));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(live) = self.0.take() else { return };
        let dur_us = live.begin.elapsed().as_secs_f64() * 1e6;
        // try_with: a span dropped during thread teardown (after the
        // thread-local was destroyed) silently discards its record.
        let _ = TLS.try_with(|tls| {
            let mut tls = tls.borrow_mut();
            tls.depth = tls.depth.saturating_sub(1);
            let rec = SpanRecord {
                name: live.name,
                cat: live.cat,
                tid: tls.tid,
                depth: live.depth,
                ts_us: live.ts_us,
                dur_us,
                args: live.args,
            };
            tls.buf.push(rec);
            if tls.depth == 0 || tls.buf.len() >= FLUSH_AT {
                tls.flush();
            }
        });
    }
}

/// Open a span in the default category. See [`span_cat`].
pub fn span(name: &str) -> Span {
    span_cat(name, "span")
}

/// Open a span: times `name` from now until the guard drops, nested under
/// whatever spans the calling thread already has open.
pub fn span_cat(name: &str, cat: &'static str) -> Span {
    if !is_enabled() {
        return Span(None);
    }
    let begin = Instant::now();
    let ts_us = begin.duration_since(global().epoch).as_secs_f64() * 1e6;
    let depth = TLS
        .try_with(|tls| {
            let mut tls = tls.borrow_mut();
            let d = tls.depth;
            tls.depth += 1;
            d
        })
        .unwrap_or(0);
    Span(Some(SpanLive { name: name.to_string(), cat, ts_us, begin, depth, args: Vec::new() }))
}

/// Microseconds since the recorder epoch — the timestamp base every span
/// and instant uses. Lets callers that buffered their own event times
/// (e.g. the IP incumbent log) convert to recorder time for
/// [`instant_at`].
pub fn now_us() -> f64 {
    global().epoch.elapsed().as_secs_f64() * 1e6
}

/// Record a zero-duration instant event (e.g. an IP incumbent update or a
/// controller decision). No-op while recording is disabled.
pub fn instant(name: &str, cat: &'static str, args: Vec<(String, Json)>) {
    instant_at(name, cat, now_us(), args);
}

/// [`instant`] with an explicit recorder-time timestamp (µs since epoch),
/// for events whose true time predates their emission.
pub fn instant_at(name: &str, cat: &'static str, ts_us: f64, args: Vec<(String, Json)>) {
    if !is_enabled() {
        return;
    }
    let _ = TLS.try_with(|tls| {
        let mut tls = tls.borrow_mut();
        let rec = SpanRecord {
            name: name.to_string(),
            cat,
            tid: tls.tid,
            depth: tls.depth,
            ts_us,
            dur_us: f64::NAN,
            args,
        };
        tls.buf.push(rec);
        if tls.depth == 0 || tls.buf.len() >= FLUSH_AT {
            tls.flush();
        }
    });
}

/// Get-or-create the named counter. Cache the handle — the lookup takes
/// the registry lock, the handle itself is a lock-free atomic.
pub fn counter(name: &str) -> Arc<Counter> {
    let mut m = global().counters.lock().unwrap();
    m.entry(name.to_string())
        .or_insert_with(|| Arc::new(Counter { val: AtomicU64::new(0) }))
        .clone()
}

/// Get-or-create the named histogram (same caching advice as [`counter`]).
pub fn histogram(name: &str) -> Arc<AtomicHistogram> {
    let mut m = global().hists.lock().unwrap();
    m.entry(name.to_string()).or_insert_with(|| Arc::new(AtomicHistogram::new())).clone()
}

/// Flush the calling thread's span buffer to the global log (spans from
/// other live threads surface at *their* next flush point).
pub fn flush_thread() {
    let _ = TLS.try_with(|tls| tls.borrow_mut().flush());
}

/// A coherent copy of everything the recorder holds.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub hists: Vec<(String, Histogram)>,
    pub spans: Vec<SpanRecord>,
    /// `(tid, thread name)` for every thread that ever recorded a span.
    pub threads: Vec<(u32, String)>,
}

impl Snapshot {
    /// Value of a counter by exact name (0 when absent).
    pub fn counter_value(&self, name: &str) -> u64 {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap_or(0)
    }

    /// Histogram by exact name.
    pub fn hist(&self, name: &str) -> Option<&Histogram> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }
}

/// Snapshot counters, histograms, and the event log (flushing the calling
/// thread first).
pub fn snapshot() -> Snapshot {
    flush_thread();
    let r = global();
    let counters =
        r.counters.lock().unwrap().iter().map(|(n, c)| (n.clone(), c.get())).collect();
    let hists =
        r.hists.lock().unwrap().iter().map(|(n, h)| (n.clone(), h.snapshot())).collect();
    let spans = r.events.lock().unwrap().clone();
    let threads = r.threads.lock().unwrap().clone();
    Snapshot { counters, hists, spans, threads }
}

/// Drop all buffered span/instant events (counters/histograms keep their
/// totals). Used between CLI phases that want separate trace files.
pub fn reset_events() {
    flush_thread();
    global().events.lock().unwrap().clear();
}

/// Zero every counter and histogram and drop all events. Registered
/// handles stay valid — they simply read 0 again.
pub fn reset() {
    reset_events();
    let r = global();
    for c in r.counters.lock().unwrap().values() {
        c.clear();
    }
    for h in r.hists.lock().unwrap().values() {
        h.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_register_once_and_accumulate() {
        let a = counter("obs_recorder_test_total");
        let b = counter("obs_recorder_test_total");
        let before = a.get();
        b.inc();
        a.add(2);
        assert_eq!(a.get(), before + 3, "both handles must hit the same cell");
        assert!(snapshot().counter_value("obs_recorder_test_total") >= before + 3);
    }

    #[test]
    fn histogram_handles_share_state() {
        let h = histogram("obs_recorder_test_ms");
        let before = h.snapshot().count();
        histogram("obs_recorder_test_ms").observe(4.0);
        assert_eq!(h.snapshot().count(), before + 1);
    }
}

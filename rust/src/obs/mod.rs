//! # obs — the unified observability layer (DESIGN.md §10)
//!
//! A zero-dependency telemetry core every subsystem emits into and every
//! surface (CLI `stats`, `--profile` trace files, CI smoke checks, benches)
//! reads back out of:
//!
//! * [`Span`] — RAII scoped timers with parent nesting, buffered
//!   thread-locally and flushed to the process-wide recorder
//!   ([`recorder`] documents the flush contract);
//! * [`Counter`] — registered, always-live relaxed atomics;
//! * [`Histogram`] / [`AtomicHistogram`] — fixed-bucket log2 histograms:
//!   bounded memory, mergeable, p50/p90/p99 without retaining samples
//!   ([`hist`] documents the bucket scheme);
//! * three exporters ([`export`]): Chrome `trace_event` JSON (Perfetto —
//!   solver phases as nested wall-time spans, simx compute/transfer tasks
//!   as per-device virtual-time Gantt lanes), Prometheus text exposition,
//!   and a structured JSON snapshot.
//!
//! What emits what:
//!
//! * `coordinator::context` — artifact-build spans (`ctx.prepared`,
//!   `ctx.lattice`, `ctx.reach`, `ctx.dp`, …) and `ctx_builds_total`;
//! * `algos::ip_throughput` / `ip_latency` — search telemetry: nodes
//!   explored, prunes by reason, incumbent-update instants
//!   (`ip.incumbent`) that make warm-start wins visible;
//! * `coordinator::concurrent` — per-shard hit/miss/dedup counters and
//!   plan-latency histograms;
//! * `simx` — per-device busy/utilization and per-directed-pair link
//!   transfer totals, plus virtual-time Gantt trace events; the
//!   controller's re-plan decisions become trace instants;
//! * `runtime::server` — per-stage service-time histograms (bounded,
//!   replacing the unbounded sample vectors).
//!
//! Everything is cheap when idle: counters/histograms are single relaxed
//! atomic ops, and span collection is off until [`set_enabled`]`(true)` —
//! a disabled recorder's spans are inert guards. Recording is
//! bitwise-invisible to solver results (pinned by `rust/tests/obs.rs`).

pub mod counters;
pub mod export;
pub mod hist;
pub mod recorder;

pub use export::{chrome_trace, prometheus, snapshot_json, span_events, TraceEvent};
pub use hist::{AtomicHistogram, Histogram};
pub use recorder::{
    counter, flush_thread, histogram, instant, instant_at, is_enabled, now_us, reset,
    reset_events, set_enabled, snapshot, span, span_cat, Counter, Snapshot, Span, SpanRecord,
};

//! Device health monitoring: observed-vs-predicted drift detection.
//!
//! The cost model predicts what every stage *should* cost
//! ([`crate::algos::objective::DeviceLoads`] per-device loads, piece costs
//! in the `simx` engine); the serving loop observes what stages *actually*
//! cost (task service times in a [`crate::simx::engine::SimxResult`]
//! trace, per-stage service samples in
//! [`crate::runtime::server::Metrics`]). The [`HealthMonitor`] consumes
//! both, maintains a per-device EWMA of the **drift ratio**
//! `observed / predicted`, and drives a per-device state machine:
//!
//! ```text
//!            drift ≥ suspect_ratio            probe ok, drift high
//! Healthy ─────────────────────────► Suspect ─────────────────────► Degraded
//!    ▲     (or silence_timeout with     │                              │
//!    │      work outstanding)           │ probe timeout × max_probes   │ drift ≤
//!    │                                  ▼   (exponential backoff)      │ clear_ratio
//!    │◄────────────────────────────── Dead ◄──────────────────────────┘
//!         probe answered / task completed (re-admission)
//! ```
//!
//! The asymmetry is deliberate: a **straggler must not be treated as a
//! loss**. A slow device still completes tasks and still answers probes,
//! so it settles in `Degraded` (the re-planning controller re-costs it);
//! only a device that stays silent through the full probe ladder —
//! `max_probe_attempts` probes, each waiting `probe_timeout · backoffⁱ` —
//! is declared `Dead` (the controller decrements it from the fleet). A
//! dead device keeps being re-probed at a capped interval so recovered
//! capacity is re-admitted ([`crate::coordinator::placement::Fleet::increment`]).
//!
//! The monitor is pure state + f64 timestamps: the simulation controller
//! ([`crate::simx::controller`]) drives it with virtual time and answers
//! probes from the scripted ground truth; a live server drives it with
//! wall-clock seconds and real RPCs. Neither the engine nor PJRT is
//! referenced here.

/// Health states, ordered by severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceHealth {
    /// Observed service times agree with the cost model.
    Healthy,
    /// Drift or silence detected; probes in flight to distinguish a
    /// straggler from a loss.
    Suspect,
    /// Alive but drifted: completes work and answers probes slowly. The
    /// controller's re-cost rung reacts to this state.
    Degraded,
    /// The full probe ladder timed out. The controller's decrement rung
    /// reacts to this state; re-admission probes continue.
    Dead,
}

impl std::fmt::Display for DeviceHealth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            DeviceHealth::Healthy => "healthy",
            DeviceHealth::Suspect => "suspect",
            DeviceHealth::Degraded => "degraded",
            DeviceHealth::Dead => "dead",
        })
    }
}

/// Monitor thresholds. All time fields share the caller's time unit
/// (virtual simulation time for the controller, seconds for a live
/// server); [`HealthConfig::scaled`] rescales them in one call.
#[derive(Clone, Copy, Debug)]
pub struct HealthConfig {
    /// EWMA smoothing factor for the drift ratio (weight of the newest
    /// observation).
    pub ewma_alpha: f64,
    /// Drift EWMA at or above this marks a device `Suspect` (and, once a
    /// probe confirms it is alive, `Degraded`).
    pub suspect_ratio: f64,
    /// Drift EWMA at or below this clears `Degraded` back to `Healthy`
    /// (strictly below [`HealthConfig::suspect_ratio`]: the gap is the
    /// anti-flap band).
    pub clear_ratio: f64,
    /// Observations before drift alone may trigger (single-sample noise
    /// guard).
    pub min_obs: u32,
    /// No completion for this long while work is outstanding marks the
    /// device `Suspect`.
    pub silence_timeout: f64,
    /// Base probe response timeout; attempt `i` waits
    /// `probe_timeout · probe_backoff^i`.
    pub probe_timeout: f64,
    /// Exponential backoff factor between probe attempts.
    pub probe_backoff: f64,
    /// Unanswered probes before `Suspect` becomes `Dead`.
    pub max_probe_attempts: u32,
    /// Re-admission probe interval for `Dead` devices (capped — no
    /// unbounded backoff once dead).
    pub reprobe_dead_every: f64,
}

impl Default for HealthConfig {
    fn default() -> HealthConfig {
        HealthConfig {
            ewma_alpha: 0.5,
            suspect_ratio: 1.5,
            clear_ratio: 1.2,
            min_obs: 2,
            silence_timeout: 8.0,
            probe_timeout: 2.0,
            probe_backoff: 2.0,
            max_probe_attempts: 3,
            reprobe_dead_every: 8.0,
        }
    }
}

impl HealthConfig {
    /// Multiply every time-dimensioned field by `unit` (ratios and counts
    /// are dimensionless and stay put). The controller scales by the
    /// plan's predicted time-per-sample so the defaults mean "a handful
    /// of pipeline beats" on any workload.
    pub fn scaled(mut self, unit: f64) -> HealthConfig {
        self.silence_timeout *= unit;
        self.probe_timeout *= unit;
        self.reprobe_dead_every *= unit;
        self
    }

    /// Worst-case time from silence onset to a `Dead` declaration: the
    /// silence window plus the full probe ladder. The controller uses
    /// this to bound its detection scan.
    pub fn detection_bound(&self) -> f64 {
        let mut ladder = 0.0;
        for i in 0..self.max_probe_attempts {
            ladder += self.probe_timeout * self.probe_backoff.powi(i as i32);
        }
        self.silence_timeout + ladder
    }
}

/// One recorded state-machine transition (the decision trace's raw
/// material).
#[derive(Clone, Debug)]
pub struct HealthTransition {
    pub t: f64,
    /// Dense device index at the time of the transition.
    pub dev: usize,
    pub from: DeviceHealth,
    pub to: DeviceHealth,
    /// Human-readable cause, e.g. `"drift 2.10x"` or `"3 probes timed out"`.
    pub why: String,
}

impl HealthTransition {
    /// Transitions the re-planning controller reacts to: a confirmed
    /// degradation, a declared death, or a recovery (re-admission).
    pub fn actionable(&self) -> bool {
        matches!(self.to, DeviceHealth::Dead | DeviceHealth::Degraded)
            || (matches!(self.from, DeviceHealth::Dead | DeviceHealth::Degraded)
                && self.to == DeviceHealth::Healthy)
    }
}

/// What the monitor waits for on a device.
#[derive(Clone, Copy, Debug)]
enum Waiting {
    /// Next silence check (`Healthy`/`Degraded` with work outstanding).
    Silence,
    /// A probe response (attempt index, for the backoff ladder).
    ProbeResponse { attempt: u32 },
    /// Next re-admission probe of a `Dead` device.
    Reprobe,
}

#[derive(Clone, Debug)]
struct DevHealth {
    state: DeviceHealth,
    /// EWMA of `observed / predicted` service time; 1.0 = on-model.
    ewma: f64,
    obs: u32,
    last_heard: f64,
    busy: bool,
    busy_since: f64,
    deadline: Option<(f64, Waiting)>,
}

impl DevHealth {
    fn fresh() -> DevHealth {
        DevHealth {
            state: DeviceHealth::Healthy,
            ewma: 1.0,
            obs: 0,
            last_heard: 0.0,
            busy: false,
            busy_since: 0.0,
            deadline: None,
        }
    }
}

/// Probes the monitor wants sent now, plus the transitions the advance
/// caused.
#[derive(Debug, Default)]
pub struct AdvanceResult {
    /// Dense device indices to probe at the advanced-to time. The caller
    /// answers an alive device with [`HealthMonitor::probe_ok`];
    /// non-answers time out via the next [`HealthMonitor::advance`].
    pub probes: Vec<usize>,
    pub transitions: Vec<HealthTransition>,
}

/// Per-device drift/health tracking over a dense device index space (the
/// same `acc 0..k, cpu k..k+ℓ` layout the engine and the evaluators use).
#[derive(Clone, Debug)]
pub struct HealthMonitor {
    cfg: HealthConfig,
    devs: Vec<DevHealth>,
    log: Vec<HealthTransition>,
}

impl HealthMonitor {
    pub fn new(num_devices: usize, cfg: HealthConfig) -> HealthMonitor {
        assert!(
            cfg.clear_ratio < cfg.suspect_ratio,
            "clear_ratio must sit below suspect_ratio (anti-flap band)"
        );
        HealthMonitor { cfg, devs: vec![DevHealth::fresh(); num_devices], log: Vec::new() }
    }

    pub fn num_devices(&self) -> usize {
        self.devs.len()
    }

    pub fn state(&self, dev: usize) -> DeviceHealth {
        self.devs[dev].state
    }

    /// Current drift EWMA (`observed / predicted`; 1.0 = on-model).
    pub fn drift(&self, dev: usize) -> f64 {
        self.devs[dev].ewma
    }

    /// All `Degraded` devices with their drift — the re-cost rung's input.
    pub fn degraded(&self) -> Vec<(usize, f64)> {
        self.devs
            .iter()
            .enumerate()
            .filter(|(_, d)| d.state == DeviceHealth::Degraded)
            .map(|(i, d)| (i, d.ewma))
            .collect()
    }

    /// Every transition recorded so far (the decision trace feeds on
    /// this).
    pub fn transitions(&self) -> &[HealthTransition] {
        &self.log
    }

    /// Drop a device's slot (fleet decrement): later indices shift down
    /// by one, mirroring the dense-index remap of
    /// [`crate::coordinator::placement::Fleet::decrement`].
    pub fn remove_device(&mut self, dev: usize) {
        self.devs.remove(dev);
    }

    /// Insert a fresh `Healthy` slot at `dev` (fleet re-increment on
    /// recovery): later indices shift up by one.
    pub fn insert_device(&mut self, dev: usize) {
        self.devs.insert(dev, DevHealth::fresh());
    }

    /// The device has outstanding work from `t` on — silence detection
    /// arms against `max(busy_since, last completion)`.
    pub fn note_busy(&mut self, dev: usize, t: f64) {
        let d = &mut self.devs[dev];
        if !d.busy {
            d.busy = true;
            d.busy_since = t;
        }
        if d.deadline.is_none()
            && matches!(d.state, DeviceHealth::Healthy | DeviceHealth::Degraded)
        {
            d.deadline =
                Some((d.last_heard.max(d.busy_since) + self.cfg.silence_timeout, Waiting::Silence));
        }
    }

    /// No more outstanding work anywhere (end of a drained run): disarm
    /// silence checks so an idle device is not probed forever. Probe
    /// ladders in flight keep running.
    pub fn clear_busy_all(&mut self) {
        for d in &mut self.devs {
            d.busy = false;
            if matches!(d.deadline, Some((_, Waiting::Silence))) {
                d.deadline = None;
            }
        }
    }

    fn transition(
        log: &mut Vec<HealthTransition>,
        dev: usize,
        d: &mut DevHealth,
        t: f64,
        to: DeviceHealth,
        why: String,
    ) -> HealthTransition {
        let tr = HealthTransition { t, dev, from: d.state, to, why };
        d.state = to;
        log.push(tr.clone());
        tr
    }

    /// One observed service time against its prediction. Returns the
    /// transition it caused, if any. A completion is also liveness
    /// evidence: it clears probe ladders and re-arms silence detection.
    pub fn observe(
        &mut self,
        dev: usize,
        t: f64,
        observed: f64,
        predicted: f64,
    ) -> Option<HealthTransition> {
        if !(predicted > 1e-12 && observed.is_finite() && observed >= 0.0) {
            return None;
        }
        let cfg = self.cfg;
        let d = &mut self.devs[dev];
        let ratio = observed / predicted;
        d.ewma = cfg.ewma_alpha * ratio + (1.0 - cfg.ewma_alpha) * d.ewma;
        d.obs += 1;
        d.last_heard = t;
        let mut out = None;
        match d.state {
            DeviceHealth::Healthy => {
                if d.obs >= cfg.min_obs && d.ewma >= cfg.suspect_ratio {
                    // the completion itself proves liveness, so the probe
                    // round-trip is already answered: straight to Degraded
                    out = Some(Self::transition(
                        &mut self.log,
                        dev,
                        d,
                        t,
                        DeviceHealth::Degraded,
                        format!("drift {:.2}x", d.ewma),
                    ));
                }
            }
            DeviceHealth::Suspect => {
                // completing work is the evidence the probes were after
                let (to, why) = if d.ewma >= cfg.suspect_ratio {
                    (DeviceHealth::Degraded, format!("completed while drifted {:.2}x", d.ewma))
                } else {
                    (DeviceHealth::Healthy, "completed on-model".to_string())
                };
                d.deadline = None;
                out = Some(Self::transition(&mut self.log, dev, d, t, to, why));
            }
            DeviceHealth::Degraded => {
                if d.ewma <= cfg.clear_ratio {
                    out = Some(Self::transition(
                        &mut self.log,
                        dev,
                        d,
                        t,
                        DeviceHealth::Healthy,
                        format!("drift cleared to {:.2}x", d.ewma),
                    ));
                }
            }
            DeviceHealth::Dead => {
                // a completion from a declared-dead device: it recovered
                d.deadline = None;
                d.ewma = ratio;
                out = Some(Self::transition(
                    &mut self.log,
                    dev,
                    d,
                    t,
                    DeviceHealth::Healthy,
                    "completed after being declared dead".to_string(),
                ));
            }
        }
        // re-arm silence detection against the fresh completion
        if d.busy
            && matches!(d.state, DeviceHealth::Healthy | DeviceHealth::Degraded)
            && !matches!(d.deadline, Some((_, Waiting::ProbeResponse { .. })))
        {
            d.deadline = Some((t + cfg.silence_timeout, Waiting::Silence));
        }
        out
    }

    /// The earliest pending deadline (silence check, probe timeout or
    /// re-admission probe) across all devices.
    pub fn next_deadline(&self) -> Option<f64> {
        self.devs
            .iter()
            .filter_map(|d| d.deadline.map(|(t, _)| t))
            .min_by(f64::total_cmp)
    }

    /// Advance the monitor's clock to `t`, firing every deadline at or
    /// before it: silence checks escalate to `Suspect` + a probe, probe
    /// timeouts retry with exponential backoff and eventually declare
    /// `Dead`, and dead devices get periodic re-admission probes.
    pub fn advance(&mut self, t: f64) -> AdvanceResult {
        let cfg = self.cfg;
        let mut res = AdvanceResult::default();
        // deadlines can cascade (a probe timing out arms the next); loop
        // until none is due
        loop {
            let mut fired = false;
            for dev in 0..self.devs.len() {
                let Some((due, waiting)) = self.devs[dev].deadline else { continue };
                if due > t + 1e-12 {
                    continue;
                }
                fired = true;
                let d = &mut self.devs[dev];
                match waiting {
                    Waiting::Silence => {
                        let quiet_since = d.last_heard.max(d.busy_since);
                        if d.busy && due - quiet_since >= cfg.silence_timeout - 1e-9 {
                            let why = format!(
                                "silent for {:.2} with work outstanding",
                                due - quiet_since
                            );
                            res.transitions.push(Self::transition(
                                &mut self.log,
                                dev,
                                d,
                                due,
                                DeviceHealth::Suspect,
                                why,
                            ));
                            d.deadline = Some((
                                due + cfg.probe_timeout,
                                Waiting::ProbeResponse { attempt: 0 },
                            ));
                            res.probes.push(dev);
                        } else if d.busy {
                            // heard from since the deadline was armed
                            d.deadline =
                                Some((quiet_since + cfg.silence_timeout, Waiting::Silence));
                        } else {
                            d.deadline = None;
                        }
                    }
                    Waiting::ProbeResponse { attempt } => {
                        if attempt + 1 >= cfg.max_probe_attempts {
                            let why = format!(
                                "{} probes timed out (backoff {}x)",
                                cfg.max_probe_attempts, cfg.probe_backoff
                            );
                            res.transitions.push(Self::transition(
                                &mut self.log,
                                dev,
                                d,
                                due,
                                DeviceHealth::Dead,
                                why,
                            ));
                            d.deadline = Some((due + cfg.reprobe_dead_every, Waiting::Reprobe));
                        } else {
                            let next = attempt + 1;
                            d.deadline = Some((
                                due + cfg.probe_timeout * cfg.probe_backoff.powi(next as i32),
                                Waiting::ProbeResponse { attempt: next },
                            ));
                            res.probes.push(dev);
                        }
                    }
                    Waiting::Reprobe => {
                        d.deadline = Some((due + cfg.reprobe_dead_every, Waiting::Reprobe));
                        res.probes.push(dev);
                    }
                }
            }
            if !fired {
                break;
            }
        }
        res
    }

    /// A probe of `dev` was answered at `t` (the device is alive). From
    /// `Suspect` this resolves the straggler-vs-loss question; from
    /// `Dead` it re-admits the device.
    pub fn probe_ok(&mut self, dev: usize, t: f64) -> Option<HealthTransition> {
        let cfg = self.cfg;
        let d = &mut self.devs[dev];
        d.last_heard = t;
        let out = match d.state {
            DeviceHealth::Suspect => {
                let (to, why) = if d.obs >= cfg.min_obs && d.ewma >= cfg.suspect_ratio {
                    (DeviceHealth::Degraded, format!("probe ok, drift {:.2}x", d.ewma))
                } else {
                    (DeviceHealth::Healthy, "probe ok".to_string())
                };
                Some(Self::transition(&mut self.log, dev, d, t, to, why))
            }
            DeviceHealth::Dead => {
                d.ewma = 1.0;
                d.obs = 0;
                Some(Self::transition(
                    &mut self.log,
                    dev,
                    d,
                    t,
                    DeviceHealth::Healthy,
                    "re-admission probe answered".to_string(),
                ))
            }
            _ => None,
        };
        let d = &mut self.devs[dev];
        d.deadline = if d.busy
            && matches!(d.state, DeviceHealth::Healthy | DeviceHealth::Degraded)
        {
            Some((t + cfg.silence_timeout, Waiting::Silence))
        } else {
            None
        };
        out
    }

    /// Feed per-stage service-time samples from the serving loop's
    /// [`crate::runtime::server::Metrics::recent_stage_samples`] window:
    /// `stage_dev[s]` is stage `s`'s
    /// dense device index and `predicted_ms[s]` its cost-model service
    /// time. Samples are replayed in order at timestamp `t` (wall
    /// spacing within one metrics scrape is below the monitor's time
    /// resolution). Returns the transitions caused.
    pub fn ingest_stage_samples(
        &mut self,
        stage_dev: &[usize],
        stage_service_ms: &[Vec<f64>],
        predicted_ms: &[f64],
        t: f64,
    ) -> Vec<HealthTransition> {
        let mut out = Vec::new();
        for (s, samples) in stage_service_ms.iter().enumerate() {
            let (Some(&dev), Some(&pred)) = (stage_dev.get(s), predicted_ms.get(s)) else {
                continue;
            };
            for &ms in samples {
                if let Some(tr) = self.observe(dev, t, ms, pred) {
                    out.push(tr);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HealthConfig {
        HealthConfig::default()
    }

    #[test]
    fn on_model_observations_stay_healthy() {
        let mut m = HealthMonitor::new(2, cfg());
        for i in 0..10 {
            assert!(m.observe(0, i as f64, 1.0, 1.0).is_none());
        }
        assert_eq!(m.state(0), DeviceHealth::Healthy);
        assert!((m.drift(0) - 1.0).abs() < 1e-12);
        assert!(m.transitions().is_empty());
    }

    #[test]
    fn sustained_drift_degrades_but_never_kills() {
        let mut m = HealthMonitor::new(1, cfg());
        // 2x drift: first observation is guarded by min_obs, the second
        // pushes the EWMA over the suspect ratio
        assert!(m.observe(0, 0.0, 2.0, 1.0).is_none());
        let tr = m.observe(0, 1.0, 2.0, 1.0).expect("transition");
        assert_eq!(tr.to, DeviceHealth::Degraded);
        assert_eq!(m.state(0), DeviceHealth::Degraded);
        // a straggler keeps completing: state stays Degraded, never Dead
        for i in 2..20 {
            m.observe(0, i as f64, 2.0, 1.0);
        }
        assert_eq!(m.state(0), DeviceHealth::Degraded);
    }

    #[test]
    fn drift_clears_back_to_healthy_with_hysteresis_band() {
        let mut m = HealthMonitor::new(1, cfg());
        m.observe(0, 0.0, 2.0, 1.0);
        m.observe(0, 1.0, 2.0, 1.0);
        assert_eq!(m.state(0), DeviceHealth::Degraded);
        // recovery: ratios back to 1.0 decay the EWMA below clear_ratio
        let mut t = 2.0;
        while m.state(0) == DeviceHealth::Degraded {
            m.observe(0, t, 1.0, 1.0);
            t += 1.0;
            assert!(t < 32.0, "EWMA must decay below clear_ratio");
        }
        assert_eq!(m.state(0), DeviceHealth::Healthy);
        let last = m.transitions().last().unwrap();
        assert_eq!(last.from, DeviceHealth::Degraded);
        assert_eq!(last.to, DeviceHealth::Healthy);
    }

    #[test]
    fn silence_probes_then_declares_dead_with_backoff() {
        let c = cfg();
        let mut m = HealthMonitor::new(1, c);
        m.observe(0, 0.0, 1.0, 1.0);
        m.note_busy(0, 0.0);
        // silence deadline at last_heard + silence_timeout
        let t_sil = 0.0 + c.silence_timeout;
        assert_eq!(m.next_deadline(), Some(t_sil));
        let r = m.advance(t_sil);
        assert_eq!(r.probes, vec![0]);
        assert_eq!(m.state(0), DeviceHealth::Suspect);
        // never answer: the ladder is timeout·(1 + backoff + backoff²)
        let ladder: f64 = (0..c.max_probe_attempts)
            .map(|i| c.probe_timeout * c.probe_backoff.powi(i as i32))
            .sum();
        let r = m.advance(t_sil + ladder + 1e-9);
        assert_eq!(m.state(0), DeviceHealth::Dead);
        assert!(r.transitions.iter().any(|tr| tr.to == DeviceHealth::Dead));
        // detection_bound covers silence + ladder
        assert!(c.detection_bound() >= c.silence_timeout + ladder - 1e-9);
        // dead devices keep getting re-admission probes
        let r = m.advance(t_sil + ladder + c.reprobe_dead_every + 1e-6);
        assert_eq!(r.probes, vec![0]);
    }

    #[test]
    fn straggler_answers_probe_and_lands_degraded_not_dead() {
        let c = cfg();
        let mut m = HealthMonitor::new(1, c);
        // drifted history, then silence (a very slow task in flight)
        m.observe(0, 0.0, 2.0, 1.0);
        m.observe(0, 1.0, 2.0, 1.0);
        assert_eq!(m.state(0), DeviceHealth::Degraded);
        m.note_busy(0, 1.0);
        let t_sil = 1.0 + c.silence_timeout;
        let r = m.advance(t_sil);
        assert_eq!(r.probes, vec![0]);
        assert_eq!(m.state(0), DeviceHealth::Suspect);
        // the device answers: straggler, not loss
        let tr = m.probe_ok(0, t_sil + 0.5).expect("transition");
        assert_eq!(tr.to, DeviceHealth::Degraded);
        assert!(tr.actionable());
    }

    #[test]
    fn dead_device_readmitted_on_probe_answer() {
        let c = cfg();
        let mut m = HealthMonitor::new(1, c);
        m.note_busy(0, 0.0);
        m.advance(c.silence_timeout + c.detection_bound());
        assert_eq!(m.state(0), DeviceHealth::Dead);
        let tr = m.probe_ok(0, 100.0).expect("transition");
        assert_eq!(tr.from, DeviceHealth::Dead);
        assert_eq!(tr.to, DeviceHealth::Healthy);
        assert!(tr.actionable());
        assert!((m.drift(0) - 1.0).abs() < 1e-12, "drift resets on re-admission");
    }

    #[test]
    fn remove_and_insert_shift_slots() {
        let mut m = HealthMonitor::new(3, cfg());
        m.observe(1, 0.0, 2.0, 1.0);
        m.observe(1, 1.0, 2.0, 1.0);
        assert_eq!(m.state(1), DeviceHealth::Degraded);
        m.remove_device(0);
        assert_eq!(m.num_devices(), 2);
        assert_eq!(m.state(0), DeviceHealth::Degraded, "slot 1 shifted down to 0");
        m.insert_device(0);
        assert_eq!(m.state(0), DeviceHealth::Healthy, "fresh slot");
        assert_eq!(m.state(1), DeviceHealth::Degraded, "shifted back up");
    }

    #[test]
    fn clear_busy_disarms_silence_but_not_probe_ladders() {
        let c = cfg();
        let mut m = HealthMonitor::new(2, c);
        m.note_busy(0, 0.0);
        m.note_busy(1, 0.0);
        // device 1 already escalated to a probe ladder
        m.advance(c.silence_timeout);
        assert_eq!(m.state(1), DeviceHealth::Suspect);
        m.clear_busy_all();
        // device 0 was also suspect (same silence deadline) — both keep
        // their probe ladders; no *new* silence deadlines exist
        let next = m.next_deadline().expect("probe timeouts pending");
        assert!(next > c.silence_timeout);
        // the ladders still run to completion
        m.advance(c.silence_timeout + c.detection_bound());
        assert_eq!(m.state(0), DeviceHealth::Dead);
        assert_eq!(m.state(1), DeviceHealth::Dead);
    }

    #[test]
    fn ingest_stage_samples_maps_stages_to_devices() {
        let mut m = HealthMonitor::new(2, cfg());
        let stage_dev = vec![0, 1];
        let samples = vec![vec![1.0, 1.0, 1.0], vec![2.1, 2.0, 2.2]];
        let predicted = vec![1.0, 1.0];
        let trs = m.ingest_stage_samples(&stage_dev, &samples, &predicted, 5.0);
        assert_eq!(m.state(0), DeviceHealth::Healthy);
        assert_eq!(m.state(1), DeviceHealth::Degraded);
        assert!(trs.iter().any(|t| t.dev == 1 && t.to == DeviceHealth::Degraded));
    }
}

//! Execution runtime: loads AOT-compiled HLO artifacts (produced once by
//! `python/compile/aot.py` — L2 JAX model + L1 Pallas kernel) and serves
//! them from Rust through the PJRT C API. Python never runs on the request
//! path.
//!
//! * [`stage`] — one compiled pipeline stage: HLO text → PJRT executable.
//! * [`server`] — the pipelined serving loop: per-stage worker threads
//!   connected by channels, a dynamic batcher, and latency/throughput
//!   metrics. (The offline build has no tokio; OS threads + mpsc channels
//!   implement the same dataflow.)
//! * [`health`] — observed-vs-predicted drift detection: per-device EWMA
//!   drift ratios and the `Healthy → Suspect → Degraded → Dead` state
//!   machine (probe retry + backoff) the re-planning controller
//!   ([`crate::simx::controller`]) reacts to.

pub mod health;
pub mod pjrt_stub;
pub mod server;
pub mod stage;

pub use health::{DeviceHealth, HealthConfig, HealthMonitor, HealthTransition};
pub use stage::{Stage, StageError};

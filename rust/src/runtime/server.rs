//! Pipelined serving loop: the L3 hot path.
//!
//! Requests enter a queue; a **dynamic batcher** groups them (up to
//! `max_batch`, or after `batch_timeout`); batches flow through the
//! pipeline stages, each owned by a dedicated worker thread (one per real
//! device), connected by bounded channels (backpressure). Stage workers
//! execute their PJRT executable; the tail thread records per-request
//! latency and the server reports throughput/latency percentiles — the
//! numbers the end-to-end example compares against the simulator's
//! prediction.

use crate::obs::Histogram;
use std::collections::VecDeque;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A request: an input vector (flattened f32) with an id.
pub struct Request {
    pub id: u64,
    pub data: Vec<f32>,
    pub enqueued: Instant,
}

/// What flows between stages.
struct Batch {
    ids: Vec<u64>,
    enqueued: Vec<Instant>,
    /// activation tensor, flattened
    data: Vec<f32>,
    batch: usize,
}

/// How many per-stage service-time samples the recent-window ring keeps
/// (the drift detector's input; the full distribution lives in the
/// bounded histogram).
pub const RECENT_STAGE_SAMPLES: usize = 64;

/// Latency/throughput metrics collected at the pipeline tail.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub completed: usize,
    pub latencies_ms: Vec<f64>,
    /// Per-stage service-time distributions (wall ms per batch
    /// execution) as bounded [`Histogram`]s — a serving loop can run
    /// forever without metrics memory growing (DESIGN.md §10).
    pub stage_service: Vec<Histogram>,
    /// Ring of the most recent service-time samples per stage (capped at
    /// [`RECENT_STAGE_SAMPLES`]) — the observed side of the drift
    /// detection
    /// [`crate::runtime::health::HealthMonitor::ingest_stage_samples`]
    /// runs against the cost model's predictions.
    pub stage_recent_ms: Vec<VecDeque<f64>>,
    pub started: Option<Instant>,
    pub finished: Option<Instant>,
}

impl Metrics {
    /// Absorb one stage service-time sample: histogram + recent ring.
    pub fn record_stage(&mut self, stage: usize, ms: f64) {
        self.stage_service[stage].record(ms);
        let ring = &mut self.stage_recent_ms[stage];
        if ring.len() == RECENT_STAGE_SAMPLES {
            ring.pop_front();
        }
        ring.push_back(ms);
    }

    /// The recent-window samples per stage, in arrival order — the shape
    /// [`crate::runtime::health::HealthMonitor::ingest_stage_samples`]
    /// consumes.
    pub fn recent_stage_samples(&self) -> Vec<Vec<f64>> {
        self.stage_recent_ms.iter().map(|r| r.iter().copied().collect()).collect()
    }

    /// The `p`-quantile of the request latencies (`0.0 ≤ p ≤ 1.0`;
    /// anything else — including NaN — returns `NaN` rather than
    /// clamping to a silently wrong answer). O(n) selection, no sort.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.latencies_ms.is_empty() || !(0.0..=1.0).contains(&p) {
            return f64::NAN;
        }
        let mut v = self.latencies_ms.clone();
        let i = ((v.len() as f64 - 1.0) * p).round() as usize;
        let (_, x, _) = v.select_nth_unstable_by(i, f64::total_cmp);
        *x
    }

    /// Several quantiles in one pass: sorts the latency vector once
    /// instead of selecting per call. Out-of-range entries map to `NaN`
    /// like [`Metrics::percentile`].
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.latencies_ms.is_empty() {
            return vec![f64::NAN; ps.len()];
        }
        let mut v = self.latencies_ms.clone();
        v.sort_by(f64::total_cmp);
        ps.iter()
            .map(|&p| {
                if !(0.0..=1.0).contains(&p) {
                    return f64::NAN;
                }
                v[((v.len() as f64 - 1.0) * p).round() as usize]
            })
            .collect()
    }

    pub fn throughput_per_s(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => self.completed as f64 / (b - a).as_secs_f64(),
            _ => f64::NAN,
        }
    }
}

/// Pipeline server configuration.
pub struct ServerConfig {
    pub max_batch: usize,
    pub batch_timeout: Duration,
    /// per-sample input element count (stage 0's expected row width)
    pub input_elems: usize,
    /// channel capacity between stages (backpressure depth)
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            batch_timeout: Duration::from_millis(2),
            input_elems: 1,
            queue_depth: 4,
        }
    }
}

/// Run a request stream through the staged pipeline and return metrics.
///
/// `stage_factories`: one factory per stage, invoked **inside** the
/// stage's worker thread to build the (batch_size, input) → output
/// closure. PJRT executables are not `Send`, so in production the factory
/// compiles the stage on its own thread (one client per device); tests
/// inject pure functions.
pub fn serve<G, F>(
    requests: Vec<Request>,
    stage_factories: Vec<G>,
    config: &ServerConfig,
) -> Metrics
where
    G: FnOnce() -> F + Send + 'static,
    F: FnMut(usize, Vec<f32>) -> Vec<f32>,
{
    let metrics = Arc::new(Mutex::new(Metrics::default()));
    let num_stages = stage_factories.len();
    {
        let mut m = metrics.lock().unwrap();
        m.stage_service = vec![Histogram::new(); num_stages];
        m.stage_recent_ms = vec![VecDeque::new(); num_stages];
    }

    // channels: batcher → s0 → s1 → … → tail
    let mut senders: Vec<SyncSender<Batch>> = Vec::new();
    let mut receivers: Vec<Receiver<Batch>> = Vec::new();
    for _ in 0..=num_stages {
        let (tx, rx) = sync_channel::<Batch>(config.queue_depth);
        senders.push(tx);
        receivers.push(rx);
    }

    // stage workers. A warm-up barrier keeps request latency honest: every
    // worker finishes building its stage (for PJRT stages: compiling the
    // HLO) before the batcher starts the clock — compilation is a
    // deployment cost, not a per-request one.
    let warmup = Arc::new(std::sync::Barrier::new(num_stages + 1));
    let mut handles = Vec::new();
    let mut receivers_iter = receivers.into_iter();
    let first_rx = receivers_iter.next().unwrap();
    let mut rx_cursor = Some(first_rx);
    for (si, factory) in stage_factories.into_iter().enumerate() {
        let rx = rx_cursor.take().unwrap();
        let tx = senders[si + 1].clone();
        rx_cursor = receivers_iter.next();
        let ready = Arc::clone(&warmup);
        let stage_metrics = Arc::clone(&metrics);
        handles.push(std::thread::spawn(move || {
            let mut f = factory();
            ready.wait();
            // service times buffer locally; one metrics lock per batch
            // (after the execute, off the blocking path of upstream sends)
            while let Ok(batch) = rx.recv() {
                let started = Instant::now();
                let out = f(batch.batch, batch.data);
                let service_ms = started.elapsed().as_secs_f64() * 1e3;
                stage_metrics.lock().unwrap().record_stage(si, service_ms);
                crate::obs::histogram("serve_stage_service_ms").observe(service_ms);
                let fwd = Batch {
                    ids: batch.ids,
                    enqueued: batch.enqueued,
                    data: out,
                    batch: batch.batch,
                };
                if tx.send(fwd).is_err() {
                    break;
                }
            }
        }));
    }
    // tail: metrics
    let tail_rx = rx_cursor.take().unwrap();
    let m2 = Arc::clone(&metrics);
    let tail = std::thread::spawn(move || {
        while let Ok(batch) = tail_rx.recv() {
            let now = Instant::now();
            let mut m = m2.lock().unwrap();
            for t in &batch.enqueued {
                m.latencies_ms.push((now - *t).as_secs_f64() * 1e3);
            }
            m.completed += batch.ids.len();
            m.finished = Some(now);
            crate::obs::counter("serve_requests_total").add(batch.ids.len() as u64);
        }
    });

    // batcher (runs inline): dynamic batching with timeout
    {
        warmup.wait(); // all stages compiled
        let tx0 = senders[0].clone();
        let mut queue: VecDeque<Request> = requests.into();
        let t0 = Instant::now();
        // requests enqueued before warm-up completed are re-stamped so
        // latency measures serving, not compilation
        for r in queue.iter_mut() {
            if r.enqueued < t0 {
                r.enqueued = t0;
            }
        }
        metrics.lock().unwrap().started = Some(t0);
        while !queue.is_empty() {
            let mut ids = Vec::new();
            let mut enq = Vec::new();
            let mut data = Vec::new();
            let deadline = Instant::now() + config.batch_timeout;
            while ids.len() < config.max_batch {
                match queue.pop_front() {
                    Some(r) => {
                        assert_eq!(r.data.len(), config.input_elems, "ragged request");
                        ids.push(r.id);
                        enq.push(r.enqueued);
                        data.extend_from_slice(&r.data);
                    }
                    None => break,
                }
                if Instant::now() > deadline {
                    break;
                }
            }
            let b = ids.len();
            let _ = tx0.send(Batch { ids, enqueued: enq, data, batch: b });
        }
    }
    // closing senders shuts the pipeline down in order
    drop(senders);
    for h in handles {
        let _ = h.join();
    }
    let _ = tail.join();

    Arc::try_unwrap(metrics).map(|m| m.into_inner().unwrap()).unwrap_or_default()
}

/// Wrap [`StageSpec`]s into the factories [`serve`] expects: each factory
/// compiles its stage inside the worker thread (one PJRT client per
/// device). Activations are shaped `[batch, features_in]`.
#[allow(clippy::type_complexity)]
pub fn stage_factories(
    specs: Vec<crate::runtime::stage::StageSpec>,
) -> Vec<impl FnOnce() -> Box<dyn FnMut(usize, Vec<f32>) -> Vec<f32>> + Send + 'static> {
    specs
        .into_iter()
        .map(|spec| {
            move || -> Box<dyn FnMut(usize, Vec<f32>) -> Vec<f32>> {
                let stage = spec
                    .compile()
                    .unwrap_or_else(|e| panic!("compiling stage {} failed: {e}", spec.name));
                let sample_shape = spec.sample_shape.clone();
                Box::new(move |batch: usize, data: Vec<f32>| -> Vec<f32> {
                    let mut shape = vec![batch];
                    shape.extend_from_slice(&sample_shape);
                    let outs = stage
                        .run_f32(&[(&data, &shape[..])])
                        .unwrap_or_else(|e| panic!("stage {} failed: {e}", stage.name));
                    outs.into_iter().next().unwrap_or_default()
                })
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Serving-time re-planning
// ---------------------------------------------------------------------------

use crate::algos::PlaceError;
use crate::coordinator::concurrent::ConcurrentService;
use crate::coordinator::context::{SolveBudget, SolveOpts};
use crate::coordinator::placement::{Device, Placement, PlanRequest, Scenario};
use crate::coordinator::planner::Algorithm;
use crate::graph::{topo, OpGraph};

/// Re-planning front end for a live pipeline server: rides a (possibly
/// shared) [`ConcurrentService`] so scenario changes (device loss, a new
/// memory cap, a different `k`) re-plan at cache-hit cost, and turns
/// placements into the per-device stage node lists [`serve`] pipelines
/// over. [`ServingPlanner::new`] gives the planner a private engine;
/// [`ServingPlanner::with_service`] joins it to an existing multi-tenant
/// one, so every serving front end of a deployment shares one context and
/// incumbent cache.
pub struct ServingPlanner {
    service: Arc<ConcurrentService>,
    alg: Algorithm,
    opts: SolveOpts,
    /// Per-solve re-plan deadline: when set, every plan call runs under a
    /// fresh `SolveBudget::deadline_in(d)` — a live re-plan (device loss,
    /// drift) degrades to an anytime answer instead of stalling the
    /// serving loop (DESIGN.md §11).
    replan_deadline: Option<Duration>,
}

/// A planned pipeline: the placement plus its stages in pipeline order.
pub struct PlannedStages {
    pub placement: Placement,
    /// `(device, nodes)` per non-empty device, nodes in topological order,
    /// stages ordered by their first topological position.
    pub stages: Vec<(Device, Vec<usize>)>,
}

impl ServingPlanner {
    pub fn new(alg: Algorithm, opts: SolveOpts) -> ServingPlanner {
        Self::with_service(Arc::new(ConcurrentService::default()), alg, opts)
    }

    /// A serving planner over a shared engine: N front ends (or tenants)
    /// holding clones of the same `Arc` pool their context cache,
    /// single-flight builds, and IP incumbents.
    pub fn with_service(
        service: Arc<ConcurrentService>,
        alg: Algorithm,
        opts: SolveOpts,
    ) -> ServingPlanner {
        ServingPlanner { service, alg, opts, replan_deadline: None }
    }

    /// Give every subsequent plan call `d` of wall clock: past it the
    /// solve degrades through the planner's fallback ladder (anytime IP →
    /// exact DP → greedy) instead of blocking the serving loop. The
    /// deadline is stamped per call, so each re-plan gets the full `d`.
    pub fn with_deadline(mut self, d: Duration) -> ServingPlanner {
        self.replan_deadline = Some(d);
        self
    }

    /// The options for one solve: the planner's base options, with a
    /// fresh deadline stamped if one is configured.
    fn solve_opts(&self) -> SolveOpts {
        let mut opts = self.opts.clone();
        if let Some(d) = self.replan_deadline {
            opts.budget = SolveBudget::deadline_in(d);
        }
        opts
    }

    /// Plan (or re-plan) `g` under `sc` with the planner's default
    /// algorithm. Repeating a known `(graph, scenario)` reuses the cached
    /// analysis context — and for the deterministic DP/DPL solvers the
    /// cached solution itself.
    pub fn plan(&mut self, g: &OpGraph, sc: &Scenario) -> Result<PlannedStages, PlaceError> {
        self.plan_with(g, sc, self.alg)
    }

    /// [`ServingPlanner::plan`] with an explicit algorithm, against the
    /// SAME cached context — e.g. a DPL fallback after the exact DP blew
    /// its lattice cap pays no second analysis pass.
    pub fn plan_with(
        &mut self,
        g: &OpGraph,
        sc: &Scenario,
        alg: Algorithm,
    ) -> Result<PlannedStages, PlaceError> {
        let r = self.service.plan(g, sc, alg, &self.solve_opts())?;
        let stages = stages_of(g, &r.placement);
        Ok(PlannedStages { placement: r.placement, stages })
    }

    /// Plan a [`PlanRequest`] — the fleet-level serving path. Live fleet
    /// mutations are expressed on the request itself (device loss =
    /// [`crate::coordinator::placement::Fleet::decrement`] on a class,
    /// memory pressure = a class-cap edit) instead of hand-rebuilding
    /// scenarios; re-plans of known fleets run at cache-hit cost, and the
    /// request's algorithm selection (`Auto` included) applies.
    pub fn plan_request(
        &mut self,
        g: &OpGraph,
        req: &PlanRequest,
    ) -> Result<PlannedStages, PlaceError> {
        let r = self.service.plan_request(g, req, &self.solve_opts())?;
        let stages = stages_of(g, &r.placement);
        Ok(PlannedStages { placement: r.placement, stages })
    }

    /// The serving loop's device-loss reaction, in one call: resolve the
    /// lost device's class, `Fleet::decrement` it on a copy of the
    /// request, and re-plan against the shrunk fleet (cache-hit cost for
    /// fleets this planner has seen). Returns the mutated request
    /// alongside the new stages so the caller can keep serving — and keep
    /// simulating — against the post-loss fleet. The `simx` re-planning
    /// loop ([`crate::simx::loop_`]) measures whether the swap pays.
    pub fn plan_after_device_loss(
        &mut self,
        g: &OpGraph,
        req: &PlanRequest,
        lost: Device,
    ) -> Result<(PlanRequest, PlannedStages), PlaceError> {
        // the class accessors deliberately clamp out-of-range indices to
        // the last class ("callers validate ranges"), so validate here: a
        // phantom device must not decrement a real class
        let in_range = match lost {
            Device::Acc(i) => i < req.fleet.k(),
            Device::Cpu(j) => j < req.fleet.l(),
        };
        if !in_range {
            return Err(PlaceError::Unsupported(format!(
                "device {lost} is outside the fleet"
            )));
        }
        let class = req
            .fleet
            .class_of(lost)
            .map(|c| c.name.clone())
            .ok_or_else(|| {
                PlaceError::Unsupported(format!("device {lost} has no class in the fleet"))
            })?;
        let mut degraded = req.clone();
        if !degraded.fleet.decrement(&class) {
            return Err(PlaceError::Unsupported(format!(
                "class {class} has no device left to lose"
            )));
        }
        let stages = self.plan_request(g, &degraded)?;
        Ok((degraded, stages))
    }

    /// `(hits, misses)` of the underlying context cache.
    pub fn cache_stats(&self) -> (usize, usize) {
        (self.service.hits(), self.service.misses())
    }
}

/// Group a placement into pipeline stages: one stage per non-empty device,
/// ordered by the first topological position of its nodes.
pub fn stages_of(g: &OpGraph, p: &Placement) -> Vec<(Device, Vec<usize>)> {
    let order = topo::toposort(g).unwrap_or_else(|| (0..g.n()).collect());
    let mut pos = vec![0usize; g.n()];
    for (i, &v) in order.iter().enumerate() {
        pos[v] = i;
    }
    let mut by_device: std::collections::BTreeMap<Device, Vec<usize>> = Default::default();
    for &v in &order {
        by_device.entry(p.assignment[v]).or_default().push(v);
    }
    let mut stages: Vec<(Device, Vec<usize>)> = by_device.into_iter().collect();
    stages.sort_by_key(|(_, nodes)| pos[nodes[0]]);
    stages
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs(n: usize, elems: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                id: i as u64,
                data: vec![i as f32; elems],
                enqueued: Instant::now(),
            })
            .collect()
    }

    type DynStage = Box<dyn FnMut(usize, Vec<f32>) -> Vec<f32>>;
    type DynFactory = Box<dyn FnOnce() -> DynStage + Send>;

    #[test]
    fn all_requests_complete_through_identity_stages() {
        let stages: Vec<DynFactory> = vec![
            Box::new(|| Box::new(|_b, d| d) as DynStage),
            Box::new(|| Box::new(|_b, d| d) as DynStage),
            Box::new(|| Box::new(|_b, d| d) as DynStage),
        ];
        let m = serve(reqs(37, 4), stages, &ServerConfig { input_elems: 4, ..Default::default() });
        assert_eq!(m.completed, 37);
        assert_eq!(m.latencies_ms.len(), 37);
        assert!(m.throughput_per_s() > 0.0);
    }

    #[test]
    fn batcher_respects_max_batch() {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let s2 = Arc::clone(&seen);
        let stages: Vec<DynFactory> = vec![Box::new(move || {
            Box::new(move |b, d| {
                s2.lock().unwrap().push(b);
                d
            }) as DynStage
        })];
        let cfg = ServerConfig { max_batch: 4, input_elems: 2, ..Default::default() };
        let m = serve(reqs(10, 2), stages, &cfg);
        assert_eq!(m.completed, 10);
        let batches = seen.lock().unwrap();
        assert!(batches.iter().all(|&b| b <= 4));
        assert_eq!(batches.iter().sum::<usize>(), 10);
    }

    #[test]
    fn stages_transform_data_in_order() {
        let stages: Vec<DynFactory> = vec![
            Box::new(|| Box::new(|_b, d: Vec<f32>| d.iter().map(|x| x + 1.0).collect()) as DynStage),
            Box::new(|| Box::new(|_b, d: Vec<f32>| d.iter().map(|x| x * 2.0).collect()) as DynStage),
        ];
        // capture output via a third checking stage
        let ok = Arc::new(Mutex::new(true));
        let ok2 = Arc::clone(&ok);
        let mut all: Vec<DynFactory> = stages;
        all.push(Box::new(move || {
            Box::new(move |_b, d: Vec<f32>| {
                // input i → (i+1)*2, always even
                for &x in d.iter() {
                    if x % 2.0 != 0.0 {
                        *ok2.lock().unwrap() = false;
                    }
                }
                d
            }) as DynStage
        }));
        let m = serve(reqs(8, 1), all, &ServerConfig { input_elems: 1, ..Default::default() });
        assert_eq!(m.completed, 8);
        assert!(*ok.lock().unwrap());
    }

    fn chain_graph(n: usize) -> OpGraph {
        use crate::graph::Node;
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(9.0).acc(1.0).mem(1.0).comm(0.1));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn serving_planner_replans_scenarios_at_cache_hit_cost() {
        let g = chain_graph(8);
        let mut planner = ServingPlanner::new(Algorithm::Dp, SolveOpts::default());
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let a = planner.plan(&g, &sc).unwrap();
        assert!(!a.stages.is_empty());
        // stages cover all nodes exactly once, in topological order
        let mut seen = vec![false; g.n()];
        for (_, nodes) in &a.stages {
            for &v in nodes {
                assert!(!seen[v], "node {v} in two stages");
                seen[v] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // same scenario again: a cache hit with an identical plan
        let b = planner.plan(&g, &sc).unwrap();
        assert_eq!(a.placement.assignment, b.placement.assignment);
        let (hits, misses) = planner.cache_stats();
        assert_eq!((hits, misses), (1, 1));
        // device loss: k = 1 still plans, against a second cached context
        let degraded = Scenario::new(1, 1, f64::INFINITY);
        let c = planner.plan(&g, &degraded).unwrap();
        c.placement.validate(&g, &degraded, true).unwrap();
        assert_eq!(planner.cache_stats(), (1, 2));
    }

    #[test]
    fn serving_planner_replans_fleet_mutations() {
        use crate::coordinator::placement::{AlgoChoice, DeviceClass, Fleet, PlanRequest};
        let g = chain_graph(8);
        let mut planner = ServingPlanner::new(Algorithm::Dp, SolveOpts::default());
        let mut req = PlanRequest::new(Fleet::new(vec![
            DeviceClass::acc("fast", 1, f64::INFINITY).speed(2.0),
            DeviceClass::acc("slow", 2, f64::INFINITY),
            DeviceClass::cpu("cpu", 1),
        ]))
        .algorithm(AlgoChoice::Fixed(Algorithm::Dp));
        let full = planner.plan_request(&g, &req).unwrap();
        full.placement.validate_req(&g, &req).unwrap();
        // same fleet again: cache hit, identical plan
        let again = planner.plan_request(&g, &req).unwrap();
        assert_eq!(full.placement.assignment, again.placement.assignment);
        assert_eq!(planner.cache_stats(), (1, 1));
        // device loss IS a class decrement — no scenario rebuilt by hand
        assert!(req.fleet.decrement("slow"));
        let degraded = planner.plan_request(&g, &req).unwrap();
        degraded.placement.validate_req(&g, &req).unwrap();
        assert_eq!(planner.cache_stats(), (1, 2), "mutated fleet is a new context");
        // losing a device can't improve the bottleneck
        assert!(degraded.placement.objective >= full.placement.objective - 1e-9);
    }

    #[test]
    fn plan_after_device_loss_decrements_and_replans() {
        use crate::coordinator::placement::{AlgoChoice, DeviceClass, Fleet, PlanRequest};
        let g = chain_graph(8);
        let mut planner = ServingPlanner::new(Algorithm::Dp, SolveOpts::default());
        let req = PlanRequest::new(Fleet::new(vec![
            DeviceClass::acc("fast", 1, f64::INFINITY).speed(2.0),
            DeviceClass::acc("slow", 2, f64::INFINITY),
            DeviceClass::cpu("cpu", 1),
        ]))
        .algorithm(AlgoChoice::Fixed(Algorithm::Dp));
        let full = planner.plan_request(&g, &req).unwrap();
        // losing dense acc1 (class "slow") shrinks the fleet by one
        let (degraded_req, degraded) =
            planner.plan_after_device_loss(&g, &req, Device::Acc(1)).unwrap();
        assert_eq!(degraded_req.fleet.k(), req.fleet.k() - 1);
        degraded.placement.validate_req(&g, &degraded_req).unwrap();
        assert!(degraded.placement.objective >= full.placement.objective - 1e-9);
        // draining the class twice more exhausts it
        let (mut r2, _) =
            planner.plan_after_device_loss(&g, &degraded_req, Device::Acc(1)).unwrap();
        assert_eq!(r2.fleet.class_named_mut("slow").unwrap().count, 0);
        assert!(planner.plan_after_device_loss(&g, &r2, Device::Acc(1)).is_err());
    }

    #[test]
    fn percentiles_monotone() {
        let m = Metrics {
            completed: 4,
            latencies_ms: vec![1.0, 5.0, 2.0, 10.0],
            ..Default::default()
        };
        assert!(m.percentile(0.5) <= m.percentile(0.99));
        assert_eq!(m.percentile(1.0), 10.0);
        assert_eq!(m.percentile(0.0), 1.0);
    }

    #[test]
    fn percentile_rejects_out_of_range() {
        let m = Metrics {
            completed: 3,
            latencies_ms: vec![3.0, 1.0, 2.0],
            ..Default::default()
        };
        assert!(m.percentile(-0.1).is_nan());
        assert!(m.percentile(1.1).is_nan());
        assert!(m.percentile(f64::NAN).is_nan());
        assert!(Metrics::default().percentile(0.5).is_nan());
    }

    #[test]
    fn batch_percentiles_match_single_calls() {
        let m = Metrics {
            completed: 5,
            latencies_ms: vec![7.0, 1.0, 9.0, 3.0, 5.0],
            ..Default::default()
        };
        let ps = [0.0, 0.5, 0.9, 1.0];
        let batch = m.percentiles(&ps);
        for (i, &p) in ps.iter().enumerate() {
            assert_eq!(batch[i], m.percentile(p), "p = {p}");
        }
        assert!(m.percentiles(&[2.0])[0].is_nan());
        assert!(Metrics::default().percentiles(&[0.5])[0].is_nan());
    }

    #[test]
    fn serve_records_per_stage_service_times() {
        let stages: Vec<DynFactory> = vec![
            Box::new(|| Box::new(|_b, d| d) as DynStage),
            Box::new(|| {
                Box::new(|_b, d| {
                    std::thread::sleep(Duration::from_millis(2));
                    d
                }) as DynStage
            }),
        ];
        let cfg = ServerConfig { max_batch: 4, input_elems: 1, ..Default::default() };
        let m = serve(reqs(8, 1), stages, &cfg);
        assert_eq!(m.completed, 8);
        assert_eq!(m.stage_service.len(), 2, "one histogram per stage");
        assert_eq!(m.stage_recent_ms.len(), 2, "one recent ring per stage");
        for (s, h) in m.stage_service.iter().enumerate() {
            assert!(h.count() > 0, "stage {s} recorded no batches");
            assert!(h.min() >= 0.0);
        }
        // both stages saw the same batch count; the ring mirrors it while
        // under the cap
        assert_eq!(m.stage_service[0].count(), m.stage_service[1].count());
        let recent = m.recent_stage_samples();
        assert_eq!(recent[0].len() as u64, m.stage_service[0].count());
        // the sleeping stage is measurably slower than the identity stage
        let sum = [m.stage_service[0].sum(), m.stage_service[1].sum()];
        assert!(sum[1] > sum[0], "slow stage must dominate: {sum:?}");
    }

    #[test]
    fn recent_stage_ring_is_bounded() {
        let mut m = Metrics {
            stage_service: vec![Histogram::new()],
            stage_recent_ms: vec![VecDeque::new()],
            ..Default::default()
        };
        for i in 0..(RECENT_STAGE_SAMPLES + 10) {
            m.record_stage(0, i as f64);
        }
        assert_eq!(m.stage_service[0].count() as usize, RECENT_STAGE_SAMPLES + 10);
        let recent = m.recent_stage_samples();
        assert_eq!(recent[0].len(), RECENT_STAGE_SAMPLES, "ring must stay capped");
        // the ring keeps the newest samples
        assert_eq!(recent[0][0], 10.0);
        assert_eq!(*recent[0].last().unwrap(), (RECENT_STAGE_SAMPLES + 9) as f64);
    }
}

//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The execution runtime was written against the real `xla_extension`
//! bindings, but this repository builds **offline and dependency-free**
//! (see Cargo.toml): no registry, no PJRT shared library. This module
//! mirrors exactly the slice of the `xla` API that
//! [`crate::runtime::stage`] consumes, with every entry point that would
//! touch PJRT
//! returning a clear "unavailable in the offline build" error. The
//! artifact-gated callers (`tests/runtime_e2e.rs`, the pipeline_serving
//! example) skip before ever reaching these paths on a fresh checkout, so
//! the stub keeps `cargo build`/`cargo test` green while preserving the
//! real API shape for environments that relink the genuine crate
//! (swap the `use … as xla;` alias in stage.rs back).

/// Error type mirroring `xla::Error` (stringly, like the binding's).
#[derive(Debug, Clone)]
pub struct Error(String);

fn unavailable() -> Error {
    Error("PJRT is unavailable in the offline build (xla crate stubbed; see \
           runtime::pjrt_stub)"
        .into())
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Host literal (`xla::Literal`).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

/// Device buffer (`xla::PjRtBuffer`): what `execute` returns.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_pjrt_path_errors_clearly() {
        let e = PjRtClient::cpu().err().expect("cpu client must be unavailable");
        assert!(e.to_string().contains("offline build"), "{e}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_err());
    }
}

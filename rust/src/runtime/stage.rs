//! One pipeline stage: an AOT-lowered HLO module compiled onto the PJRT
//! CPU client and executed with `f32` tensors.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The offline build has no `xla` crate; [`crate::runtime::pjrt_stub`]
//! mirrors the consumed API slice and errors on every PJRT touchpoint, so
//! stage loading fails gracefully (callers skip when artifacts are
//! absent). Environments with the real bindings swap the alias below.

use crate::runtime::pjrt_stub as xla;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum StageError {
    Io(std::io::Error),
    Xla(String),
}

impl std::fmt::Display for StageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StageError::Io(e) => write!(f, "stage I/O error: {e}"),
            StageError::Xla(e) => write!(f, "XLA error: {e}"),
        }
    }
}

impl std::error::Error for StageError {}

impl From<xla::Error> for StageError {
    fn from(e: xla::Error) -> Self {
        StageError::Xla(e.to_string())
    }
}

/// A compiled stage. The xla crate's executables are not `Send` (they hold
/// `Rc` internals), so a `Stage` must live on the thread that created it —
/// the serving loop therefore compiles one per worker thread from a
/// [`StageSpec`].
pub struct Stage {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Number of outputs the stage returns (jax lowers with
    /// `return_tuple=True`, so the result is always a tuple).
    pub tuple_arity: usize,
}

/// Thread-portable description of a stage: everything needed to compile it
/// inside a worker thread.
#[derive(Clone, Debug)]
pub struct StageSpec {
    pub name: String,
    pub path: PathBuf,
    pub tuple_arity: usize,
    /// per-sample input shape (the compiled parameter is
    /// `[batch, ..sample_shape]`)
    pub sample_shape: Vec<usize>,
}

impl StageSpec {
    /// Flattened per-sample element count.
    pub fn features_in(&self) -> usize {
        self.sample_shape.iter().product()
    }
}

impl StageSpec {
    /// Compile on a fresh CPU client (call from the owning thread).
    pub fn compile(&self) -> Result<Stage, StageError> {
        let client = xla::PjRtClient::cpu()?;
        Stage::load(&client, self.name.clone(), &self.path, self.tuple_arity)
    }
}

impl Stage {
    /// Load an HLO text artifact and compile it on the given client.
    pub fn load(
        client: &xla::PjRtClient,
        name: impl Into<String>,
        path: &Path,
        tuple_arity: usize,
    ) -> Result<Stage, StageError> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| StageError::Xla("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Stage { name: name.into(), exe, tuple_arity })
    }

    /// Execute on f32 buffers: each input is (data, shape). Returns the
    /// flattened f32 outputs.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>, StageError> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims)?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // jax lowers with return_tuple=True → unpack
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(self.tuple_arity.max(parts.len()));
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// Convenience: the artifacts directory (env `DNN_PARTITION_ARTIFACTS`
/// overrides; defaults to `artifacts/` relative to the crate root).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("DNN_PARTITION_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_env_override() {
        // don't mutate process env in-parallel tests; just check default
        let d = artifacts_dir();
        assert!(d.ends_with("artifacts") || std::env::var("DNN_PARTITION_ARTIFACTS").is_ok());
    }

    // Stage loading/execution against real artifacts is covered by the
    // `runtime_e2e` integration test (skips when artifacts are absent).
}

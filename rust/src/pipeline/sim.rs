//! The legacy pipeline-simulator API — now a thin adapter over the
//! [`crate::simx`] discrete-event engine.
//!
//! A placement is compiled into *virtual devices*: each real device's node
//! set is decomposed into contiguous pieces (§5.2), topologically ordered;
//! each (sample, piece, direction) becomes a task whose cost is the
//! piece's load share. Tasks run under device exclusivity (virtual devices
//! of one real device never overlap — Fig. 5b) and dependency order, with
//! the schedule policy deciding priority among ready tasks:
//!
//! * [`Schedule::SingleStream`] — one sample at a time (Figs. 2a/2b).
//! * [`Schedule::Pipelined`] — inference pipelining (Fig. 5a).
//! * [`Schedule::PipeDream1F1B`] — backward-priority training (Fig. 7b).
//! * [`Schedule::GPipe`] — all forwards, then all backwards (Fig. 7a).
//!
//! [`simulate`] keeps its historical signature (uniform scalar
//! [`Scenario`]) and delegates to [`crate::simx::engine::simulate_req`]
//! with the engine's legacy-exact configuration (instantaneous macro
//! hand-offs, no activation gating). [`simulate_reference`] preserves the
//! original PR-0 greedy list-scheduling loop verbatim as the equivalence
//! oracle: `tests/simx_equivalence.rs` pins the adapter to it within ε.
//! Fleet-aware runs (per-class speeds, link bandwidth, event scripts)
//! should call the `simx` engine directly.

use crate::coordinator::placement::{Device, Placement, Scenario};
use crate::graph::OpGraph;
use crate::simx::engine::{self, SimConfig};

// The schedule policies and the virtual-device decomposition live with the
// engine now; re-exported so every legacy import path keeps resolving.
pub use crate::simx::engine::{Piece, Schedule};

/// Simulation result (legacy shape; the engine's richer
/// [`crate::simx::engine::SimxResult`] adds transfers, memory peaks and
/// stall reasons).
#[derive(Clone, Debug)]
pub struct SimResult {
    /// completion time of each sample (backward included for training)
    pub sample_done: Vec<f64>,
    /// measured steady-state time-per-sample (slope of the last half)
    pub steady_tps: f64,
    /// makespan
    pub total: f64,
    /// per-(sample, piece, direction) start/finish for timeline rendering:
    /// (sample, piece, is_backward, start, finish)
    pub trace: Vec<(usize, usize, bool, f64, f64)>,
    pub pieces: Vec<Piece>,
}

/// Decompose a placement into virtual devices with per-piece costs (legacy
/// scalar form of [`crate::simx::engine::build_pieces_req`]).
pub fn build_pieces(g: &OpGraph, sc: &Scenario, p: &Placement) -> Vec<Piece> {
    engine::build_pieces_req(g, &sc.to_request(), p)
}

/// Run the simulation for `num_samples` samples on the scenario's uniform
/// fleet — the legacy entry point, now a delegation to the `simx` engine
/// in its §3-exact configuration.
pub fn simulate(
    g: &OpGraph,
    sc: &Scenario,
    p: &Placement,
    schedule: Schedule,
    num_samples: usize,
) -> SimResult {
    let req = sc.to_request();
    let r = engine::simulate_req(g, &req, p, schedule, num_samples, &SimConfig::default());
    SimResult {
        sample_done: r.sample_done,
        steady_tps: r.steady_tps,
        total: r.total,
        trace: r.trace,
        pieces: r.pieces,
    }
}

/// The **frozen PR-0 implementation**: the original greedy
/// min-feasible-start list scheduler, kept verbatim as the oracle for the
/// engine-equivalence suite (`tests/simx_equivalence.rs`). Use
/// [`simulate`] everywhere else.
pub fn simulate_reference(
    g: &OpGraph,
    sc: &Scenario,
    p: &Placement,
    schedule: Schedule,
    num_samples: usize,
) -> SimResult {
    let pieces = build_pieces(g, sc, p);
    let np = pieces.len();
    let is_training = pieces.iter().any(|x| x.bw_cost > 0.0);

    // Task = (sample, piece). Cost = fw or bw cost of the piece.
    // remaining dep count per (sample, piece)
    let mut remaining: Vec<Vec<usize>> = (0..num_samples)
        .map(|_| pieces.iter().map(|x| x.deps.len()).collect())
        .collect();
    // pipeline discipline: sample s on piece j also waits for sample s-1 on
    // piece j (in-order processing per piece)
    let mut piece_free = vec![0.0_f64; np];
    let mut device_free: std::collections::BTreeMap<Device, f64> = Default::default();
    let mut done_time: Vec<Vec<f64>> = vec![vec![f64::NAN; np]; num_samples];
    let mut sample_done = vec![0.0_f64; num_samples];
    let mut trace = Vec::new();

    // ready set of (sample, piece)
    let mut ready: Vec<(usize, usize)> = Vec::new();
    for s in 0..num_samples {
        for j in 0..np {
            if remaining[s][j] == 0 {
                ready.push((s, j));
            }
        }
    }

    let mut completed = 0usize;
    let mut sample_tasks_done = vec![0usize; num_samples];
    let total_tasks = num_samples * np;
    while completed < total_tasks {
        // pick the ready task per schedule policy with the earliest
        // feasible start; tie-break by policy priority
        let mut best: Option<(f64, i64, usize)> = None; // (start, -priority, ready idx)
        for (ri, &(s, j)) in ready.iter().enumerate() {
            let piece = &pieces[j];
            // single-stream: sample s may not start until s-1 is FULLY done
            if schedule == Schedule::SingleStream && s > 0 && sample_tasks_done[s - 1] < np {
                continue;
            }
            let dev = piece.real_device;
            let dep_ready = piece
                .deps
                .iter()
                .map(|&d| done_time[s][d])
                .fold(0.0_f64, f64::max);
            let in_order = if s > 0 { done_time[s - 1][j].max(0.0) } else { 0.0 };
            let dev_free = *device_free.get(&dev).unwrap_or(&0.0);
            let start = dep_ready.max(in_order).max(dev_free).max(piece_free[j]);
            let start = if schedule == Schedule::SingleStream && s > 0 {
                start.max(sample_done[s - 1])
            } else {
                start
            };
            // GPipe: backwards wait for ALL forwards of the batch
            let is_bw = piece.bw_cost > 0.0;
            let start = if schedule == Schedule::GPipe && is_bw {
                let all_fw_done = (0..num_samples)
                    .map(|s2| {
                        (0..np)
                            .filter(|&j2| pieces[j2].fw_cost > 0.0)
                            .map(|j2| done_time[s2][j2])
                            .fold(0.0_f64, f64::max)
                    })
                    .fold(0.0_f64, f64::max);
                if (0..num_samples).any(|s2| {
                    (0..np).any(|j2| pieces[j2].fw_cost > 0.0 && done_time[s2][j2].is_nan())
                }) {
                    f64::INFINITY // not yet schedulable
                } else {
                    start.max(all_fw_done)
                }
            } else {
                start
            };
            if start.is_infinite() {
                continue;
            }
            // priority: PipeDream favors backward, then lower sample id
            let prio: i64 = match schedule {
                Schedule::PipeDream1F1B => (if is_bw { 1_000_000 } else { 0 }) - s as i64,
                _ => -(s as i64) - if is_bw { 0 } else { 1 },
            };
            if best.is_none_or(|(bs, bp, _)| start < bs - 1e-12 || (start < bs + 1e-12 && -prio < bp))
            {
                best = Some((start, -prio, ri));
            }
        }
        let (start, _, ri) = best.expect("deadlock: no schedulable ready task");
        let (s, j) = ready.swap_remove(ri);
        let cost = pieces[j].fw_cost + pieces[j].bw_cost;
        let finish = start + cost;
        let is_bw = pieces[j].bw_cost > 0.0;
        done_time[s][j] = finish;
        piece_free[j] = finish;
        device_free.insert(pieces[j].real_device, finish);
        sample_done[s] = sample_done[s].max(finish);
        trace.push((s, j, is_bw, start, finish));
        completed += 1;
        sample_tasks_done[s] += 1;
        // unlock dependents
        for j2 in 0..np {
            if pieces[j2].deps.contains(&j) {
                remaining[s][j2] -= 1;
                if remaining[s][j2] == 0 {
                    ready.push((s, j2));
                }
            }
        }
    }
    // training: a sample is done when its backward is done; recompute
    if is_training {
        for s in 0..num_samples {
            sample_done[s] = (0..np).map(|j| done_time[s][j]).fold(0.0, f64::max);
        }
    }

    let total = sample_done.iter().copied().fold(0.0, f64::max);
    // steady-state slope over the middle-to-end samples (GPipe's phase
    // structure makes per-sample completion bursty; the average still
    // converges). Sort completions to get the k-th finished sample.
    let mut finish_sorted = sample_done.clone();
    finish_sorted.sort_by(f64::total_cmp);
    let steady_tps = if num_samples >= 4 {
        let a = num_samples / 2;
        let b = num_samples - 1;
        (finish_sorted[b] - finish_sorted[a]) / (b - a) as f64
    } else {
        total / num_samples as f64
    };

    SimResult { sample_done, steady_tps, total, trace, pieces }
}

/// Render an ASCII timeline (Figs. 2/5/7 style): one row per real device,
/// one column per time quantum; cells hold the sample id being processed
/// (uppercase = backward). Shares the engine's renderer.
pub fn render_timeline(res: &SimResult, width: usize) -> String {
    engine::render_trace_timeline(&res.trace, &res.pieces, res.total, width)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{dp, objective};
    use crate::graph::Node;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(10.0).acc(1.0).mem(1.0).comm(0.1));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn pipelined_steady_state_equals_max_load() {
        let g = chain(6);
        let sc = Scenario::new(3, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let res = simulate(&g, &sc, &p, Schedule::Pipelined, 40);
        let predicted = objective::max_load(&g, &sc, &p);
        assert!(
            (res.steady_tps - predicted).abs() / predicted < 0.05,
            "steady {} vs predicted {}",
            res.steady_tps,
            predicted
        );
    }

    #[test]
    fn single_stream_is_serial() {
        let g = chain(4);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let res = simulate(&g, &sc, &p, Schedule::SingleStream, 5);
        // no overlap: total = 5 × single-sample time
        let per = res.sample_done[0];
        assert!((res.total - 5.0 * per).abs() < 1e-6, "total {} per {}", res.total, per);
    }

    #[test]
    fn noncontiguous_split_matches_max_load_via_virtual_devices() {
        // Fig. 5b: device holding {0, 2} and device holding {1, 3}
        let g = chain(4);
        let sc = Scenario::new(2, 0, f64::INFINITY);
        let p = Placement::new(
            vec![Device::Acc(0), Device::Acc(1), Device::Acc(0), Device::Acc(1)],
            0.0,
            "manual",
        );
        let predicted = objective::max_load(&g, &sc, &p);
        let res = simulate(&g, &sc, &p, Schedule::Pipelined, 60);
        assert_eq!(res.pieces.iter().filter(|x| x.real_device == Device::Acc(0)).count(), 2);
        assert!(
            (res.steady_tps - predicted).abs() / predicted < 0.08,
            "steady {} vs predicted {}",
            res.steady_tps,
            predicted
        );
    }

    #[test]
    fn training_1f1b_matches_fw_plus_bw_load() {
        use crate::util::proptest::random_training_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x51);
        let g = random_training_dag(&mut rng, 6, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let predicted = objective::max_load(&g, &sc, &p);
        let res = simulate(&g, &sc, &p, Schedule::PipeDream1F1B, 40);
        assert!(
            (res.steady_tps - predicted).abs() / predicted < 0.1,
            "steady {} vs predicted {}",
            res.steady_tps,
            predicted
        );
    }

    #[test]
    fn gpipe_no_faster_than_1f1b_and_both_finish() {
        use crate::util::proptest::random_training_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x52);
        let g = random_training_dag(&mut rng, 5, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let a = simulate(&g, &sc, &p, Schedule::PipeDream1F1B, 16);
        let b = simulate(&g, &sc, &p, Schedule::GPipe, 16);
        assert!(a.total > 0.0 && b.total > 0.0);
        // GPipe's phase barrier can only delay completion
        assert!(b.total >= a.total - 1e-9);
    }

    #[test]
    fn timeline_renders_all_devices() {
        let g = chain(4);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let res = simulate(&g, &sc, &p, Schedule::Pipelined, 6);
        let t = render_timeline(&res, 60);
        assert!(t.contains("acc0"));
        assert!(t.lines().count() >= 1);
    }
}

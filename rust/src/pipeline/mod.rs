//! Legacy façade over the discrete-event simulation of the paper's
//! pipeline schedules (Figs. 2, 5, 7): single-stream execution, pipelined
//! inference, PipeDream 1F1B and GPipe training, including non-contiguous
//! splits via virtual devices (§5.2). The simulator validates the cost
//! model: after ramp-up, the measured steady-state time-per-sample equals
//! the max-load objective.
//!
//! Since the `simx` subsystem landed, [`sim`] is a thin adapter over
//! [`crate::simx::engine`] (uniform scalar scenarios only, pinned to the
//! frozen reference implementation by `tests/simx_equivalence.rs`);
//! fleet-aware simulation, event scripts and the re-planning loop live in
//! [`crate::simx`].

pub mod sim;

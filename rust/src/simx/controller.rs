//! Hysteresis re-planning controller: the serving loop that survives.
//!
//! [`crate::simx::loop_`] reacts to exactly one scripted fault with one
//! un-rate-limited re-plan. This module closes the loop the way a
//! production controller must: a [`HealthMonitor`] watches
//! observed-vs-predicted task times from the engine trace, and every
//! *actionable* health transition is answered through a
//! **graceful-degradation ladder** under a **hysteresis contract**
//! (DESIGN.md §7):
//!
//! 1. **Re-cost + re-plan in place** — a `Degraded` (straggling but
//!    alive) device is carved into its own single-device class with its
//!    observed slow-factor folded into the class speed; the planner
//!    re-plans against that drift-adjusted fleet, and the new plan is
//!    swapped in only if it beats the current one by
//!    [`ControllerConfig::min_improvement`].
//! 2. **`Fleet::decrement` re-plan** — a `Dead` device is removed from
//!    the fleet ([`ServingPlanner::plan_after_device_loss`]) and the
//!    shrunk fleet re-planned. Never skipped for improvement (the
//!    current plan cannot finish), but *deferred* to the end of the
//!    cooldown window rather than dropped.
//! 3. **CPU failover** — when the shrunk fleet has no plan, the dead
//!    device's nodes hot-failover to the CPU pool
//!    ([`crate::simx::loop_::fallback_after_loss`]); skipped when an op
//!    has no CPU cost (that is a [`PlaceError`], not an ∞ placement).
//! 4. **Admission control** — when nothing can place the work (or the
//!    injection backlog exceeds [`ControllerConfig::backlog_cap`]),
//!    load is shed with a classified [`ShedCause`] instead of
//!    deadlocking.
//!
//! The hysteresis contract: at most [`ControllerConfig::max_swaps`] plan
//! swaps per run, consecutive swaps at least
//! [`ControllerConfig::cooldown`] apart, and improvement-gated swaps
//! only above the `min_improvement` threshold — an oscillating
//! slow/recover script cannot thrash the planner.
//!
//! Execution is an **epoch-segmented replay**: the run simulates under
//! the current plan until the first accepted swap at time `T`, the epoch
//! is cut at `T` (completions at or before `T` count; in-flight work
//! replays from scratch next epoch — the re-injection approximation),
//! and a new epoch starts on the new plan with the not-yet-completed
//! backlog. Scripted ground truth answers the monitor's probes, keeps
//! per-device fail/slow/recover state across fleet mutations, and
//! schedules re-admission of recovered capacity
//! ([`crate::coordinator::placement::Fleet::increment`]).
//!
//! All time-dimensioned config fields are expressed in **beats** — units
//! of the initial plan's predicted time-per-sample — and scaled once at
//! run start, so the same defaults behave identically on fast and slow
//! workloads.

use crate::algos::{objective, PlaceError};
use crate::coordinator::placement::{
    Device, DeviceKind, Placement, PlanRequest,
};
use crate::graph::OpGraph;
use crate::runtime::health::{DeviceHealth, HealthConfig, HealthMonitor, HealthTransition};
use crate::runtime::server::ServingPlanner;
use crate::simx::engine::{self, Schedule, SimConfig, Stall};
use crate::simx::event::{EventScript, ScriptAction, ScriptedEvent};
use crate::simx::loop_::fallback_after_loss;

/// Controller thresholds. Time fields are in beats (initial predicted
/// time-per-sample); [`run_monitored`] scales them once at start.
#[derive(Clone, Debug)]
pub struct ControllerConfig {
    /// Health-monitor thresholds (its time fields are beats too).
    pub health: HealthConfig,
    /// Minimum time between consecutive plan swaps. Improvement-gated
    /// swaps inside the window are rejected; dead-device swaps are
    /// deferred to the window's end (never dropped).
    pub cooldown: f64,
    /// Minimum fractional predicted improvement (`old/new - 1`) before
    /// an improvement-gated swap is accepted.
    pub min_improvement: f64,
    /// Hard cap on plan swaps per run (the hysteresis bound the chaos
    /// campaign asserts).
    pub max_swaps: usize,
    /// Injection-backlog bound: epochs starting with more outstanding
    /// samples shed the excess (admission control).
    pub backlog_cap: usize,
    /// Epoch budget; exhausting it sheds with [`ShedCause::Unresolved`]
    /// (a backstop — accepted swaps are already bounded by `max_swaps`).
    pub max_epochs: usize,
}

impl Default for ControllerConfig {
    fn default() -> ControllerConfig {
        ControllerConfig {
            health: HealthConfig::default(),
            cooldown: 12.0,
            min_improvement: 0.05,
            max_swaps: 5,
            backlog_cap: 512,
            max_epochs: 24,
        }
    }
}

impl ControllerConfig {
    /// Multiply every time-dimensioned field by `unit` (beats → absolute
    /// simulation time).
    pub fn scaled(mut self, unit: f64) -> ControllerConfig {
        self.cooldown *= unit;
        self.health = self.health.scaled(unit);
        self
    }
}

/// Why a run shed load instead of completing (the classified `Stall`
/// analogue at the controller level).
#[derive(Clone, Debug, PartialEq)]
pub enum ShedCause {
    /// Every ladder rung errored: no placement can finish the work.
    NoFeasiblePlacement,
    /// A dead device needed a swap but the hysteresis budget was spent.
    SwapBudgetExhausted,
    /// The engine reported a memory deadlock (schedule infeasible).
    MemoryDeadlock,
    /// The epoch/scan budget ran out before the run settled.
    Unresolved,
}

impl std::fmt::Display for ShedCause {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ShedCause::NoFeasiblePlacement => "no-feasible-placement",
            ShedCause::SwapBudgetExhausted => "swap-budget-exhausted",
            ShedCause::MemoryDeadlock => "memory-deadlock",
            ShedCause::Unresolved => "unresolved",
        })
    }
}

/// How a monitored run ended. In both cases
/// `completed + shed == injected` — nothing is silently dropped.
#[derive(Clone, Debug, PartialEq)]
pub enum Verdict {
    /// Every non-shed sample completed.
    Completed,
    /// Remaining load was shed for the classified cause.
    Shed(ShedCause),
}

/// One controller decision (accepted or rejected), the JSON decision
/// trace's unit.
#[derive(Clone, Debug)]
pub struct Decision {
    /// Absolute simulation time of the decision.
    pub t: f64,
    /// What fired, e.g. `"dead:acc1"`, `"degraded:acc0*2.1"`,
    /// `"readmit:fast"`, `"backlog"`.
    pub trigger: String,
    /// The ladder rung taken, e.g. `"decrement-replan:fast"`,
    /// `"replan-in-place"`, `"cpu-failover"`, `"shed:12"`.
    pub action: String,
    pub accepted: bool,
    /// Why (cooldown, improvement below threshold, plan error, …).
    pub reason: String,
    /// Predicted time-per-sample before / after (NaN when not computed).
    pub predicted_before: f64,
    pub predicted_after: f64,
    pub swaps_so_far: usize,
}

/// Outcome of a monitored run.
#[derive(Clone, Debug)]
pub struct MonitorOutcome {
    pub verdict: Verdict,
    /// Base samples + every scripted spike.
    pub injected: usize,
    pub completed: usize,
    pub shed: usize,
    /// Absolute time the run ended (completion or shed).
    pub makespan: f64,
    /// Steady-state time-per-sample of the final epoch (NaN when shed).
    pub final_steady_tps: f64,
    pub plan_swaps: usize,
    /// Absolute times of the accepted swaps (consecutive gaps honor the
    /// cooldown — asserted by the chaos campaign).
    pub swap_times: Vec<f64>,
    pub decisions: Vec<Decision>,
    /// Every health transition the monitor recorded.
    pub transitions: Vec<HealthTransition>,
    pub final_placement: Placement,
    pub final_request: PlanRequest,
    pub epochs: usize,
    /// The beat length the config was scaled by (initial predicted
    /// time-per-sample).
    pub time_unit: f64,
    /// The scaled cooldown actually enforced.
    pub cooldown: f64,
}

// ---------------------------------------------------------------------------
// Scripted ground truth
// ---------------------------------------------------------------------------

/// The original script as a queryable oracle: per-device alive/slow state
/// at any absolute time (stable order among equal times, matching the
/// engine's FIFO event heap), in the **original** dense device space.
struct ScriptTruth {
    events: Vec<ScriptedEvent>,
}

impl ScriptTruth {
    fn new(script: &EventScript) -> ScriptTruth {
        let mut events = script.events.clone();
        events.sort_by(|a, b| a.at.total_cmp(&b.at));
        ScriptTruth { events }
    }

    /// `(alive, slow_scale)` of `dev` after every event with `at ≤ t`.
    fn state_of(&self, dev: Device, t: f64) -> (bool, f64) {
        let mut alive = true;
        let mut scale = 1.0;
        for e in &self.events {
            if e.at > t + 1e-12 {
                break;
            }
            match e.action {
                ScriptAction::Fail { device } if device == dev => alive = false,
                ScriptAction::Slow { device, factor } if device == dev => scale *= factor,
                ScriptAction::Recover { device } if device == dev => {
                    alive = true;
                    scale = 1.0;
                }
                _ => {}
            }
        }
        (alive, scale)
    }

    fn alive(&self, dev: Device, t: f64) -> bool {
        self.state_of(dev, t).0
    }

    fn first_recover_after(&self, dev: Device, t: f64) -> Option<f64> {
        self.events
            .iter()
            .find(|e| {
                e.at > t
                    && matches!(e.action, ScriptAction::Recover { device } if device == dev)
            })
            .map(|e| e.at)
    }

    /// Spike samples arriving in `(epoch_start, cut]` — with the one
    /// boundary exception that the very first epoch also owns spikes at
    /// exactly `t = 0`.
    fn spikes_fired(&self, epoch_start: f64, cut: f64) -> usize {
        self.events
            .iter()
            .filter(|e| {
                (e.at > epoch_start + 1e-12 || (epoch_start == 0.0 && e.at == 0.0))
                    && e.at <= cut + 1e-12
            })
            .map(|e| match e.action {
                ScriptAction::Spike { count } => count,
                _ => 0,
            })
            .sum()
    }

    fn total_spikes(&self) -> usize {
        self.spikes_fired(0.0, f64::INFINITY)
    }
}

// ---------------------------------------------------------------------------
// Dense-space bookkeeping helpers
// ---------------------------------------------------------------------------

/// Apply a permutation over accelerator slots to a placement (CPU
/// assignments untouched).
fn apply_acc_perm(p: &Placement, pi: &[usize]) -> Placement {
    let assignment = p
        .assignment
        .iter()
        .map(|&d| match d {
            Device::Acc(s) => Device::Acc(pi[s]),
            cpu => cpu,
        })
        .collect();
    Placement::new(assignment, p.objective, p.algorithm.clone())
}

fn invert_perm(pi: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; pi.len()];
    for (i, &x) in pi.iter().enumerate() {
        inv[x] = i;
    }
    inv
}

/// Shift a placement's same-kind slots at or above `ins` up by one (a
/// device was re-admitted at dense slot `ins` of that kind).
fn shift_plan_for_insert(p: &Placement, ins: usize, kind: DeviceKind) -> Placement {
    let assignment = p
        .assignment
        .iter()
        .map(|&d| match (d, kind) {
            (Device::Acc(s), DeviceKind::Accelerator) if s >= ins => Device::Acc(s + 1),
            (Device::Cpu(j), DeviceKind::Cpu) if j >= ins => Device::Cpu(j + 1),
            (other, _) => other,
        })
        .collect();
    Placement::new(assignment, p.objective, p.algorithm.clone())
}

/// Carve every degraded accelerator slot into its own single-device
/// class with the observed slow-factor folded into the class speed
/// (`speed / drift`). Returns the adjusted request plus the permutation
/// `pi[old_slot] = new_slot` over accelerator slots: within each class
/// the non-degraded devices keep their order at the front, the degraded
/// ones move to the class range's tail (within a class devices are
/// interchangeable, so this is a relabeling, not a migration).
fn drift_adjusted_request(
    req: &PlanRequest,
    degraded: &[(usize, f64)],
) -> (PlanRequest, Vec<usize>) {
    let k = req.fleet.k();
    let mut pi: Vec<usize> = (0..k).collect();
    let mut classes = Vec::new();
    let mut base = 0usize;
    for c in &req.fleet.classes {
        if c.kind != DeviceKind::Accelerator {
            classes.push(c.clone());
            continue;
        }
        let n = c.count;
        let deg: Vec<(usize, f64)> = degraded
            .iter()
            .copied()
            .filter(|&(s, _)| s >= base && s < base + n)
            .collect();
        if deg.is_empty() {
            classes.push(c.clone());
        } else {
            let keep = n - deg.len();
            if keep > 0 {
                let mut kept = c.clone();
                kept.count = keep;
                classes.push(kept);
            }
            let mut next_keep = base;
            let mut next_deg = base + keep;
            for s in base..base + n {
                if let Some(&(_, drift)) = deg.iter().find(|&&(d, _)| d == s) {
                    pi[s] = next_deg;
                    next_deg += 1;
                    let mut solo = c.clone();
                    solo.name = format!("{}~s{s}", c.name);
                    solo.count = 1;
                    solo.speed = c.speed / drift.max(1.0);
                    classes.push(solo);
                } else {
                    pi[s] = next_keep;
                    next_keep += 1;
                }
            }
        }
        base += n;
    }
    let mut adj = req.clone();
    adj.fleet.classes = classes;
    (adj, pi)
}

/// Dense slot (within its kind) a re-admitted device of `class` lands
/// on: the tail of the class's range, classes walked in declaration
/// order.
fn class_tail_slot(req: &PlanRequest, class: &str, kind: DeviceKind) -> usize {
    let mut seen = 0usize;
    for c in req.fleet.classes.iter().filter(|c| c.kind == kind) {
        seen += c.count;
        if c.name == class {
            return seen - 1;
        }
    }
    seen.saturating_sub(1)
}

// ---------------------------------------------------------------------------
// The monitored run
// ---------------------------------------------------------------------------

/// A staged plan swap, applied after the epoch is cut.
enum SwapKind {
    /// Rung 2: dead device decremented, fleet re-planned.
    Decrement { dense: usize, orig: usize, kind: DeviceKind, req: PlanRequest, plan: Placement },
    /// Rung 3: dead device's nodes moved to the CPU pool, fleet kept.
    Failover { plan: Placement },
    /// Rung 1: drift-adjusted re-plan on the unchanged fleet.
    Replan { plan: Placement },
    /// Recovered capacity re-admitted (`Fleet::increment`) + re-plan.
    Readmit { ins: usize, orig: usize, kind: DeviceKind, req: PlanRequest, plan: Placement },
}

enum ScanEnd {
    /// Accepted swap at the absolute cut time.
    Swap(f64, SwapKind),
    /// Epoch ran to completion with no accepted swap.
    Clean,
    /// Terminal shed at the absolute time.
    Shed(f64, ShedCause),
}

/// Run `script` against a monitored, self-healing serving loop (see the
/// module docs) and report what happened. `cfg` is in beats and scaled
/// internally by the initial plan's predicted time-per-sample.
pub fn run_monitored(
    g: &OpGraph,
    req: &PlanRequest,
    script: &EventScript,
    schedule: Schedule,
    samples: usize,
    planner: &mut ServingPlanner,
    cfg: &ControllerConfig,
) -> Result<MonitorOutcome, PlaceError> {
    let healthy = planner.plan_request(g, req)?;
    let unit = objective::max_load_req(g, req, &healthy.placement).max(1e-9);
    let cfg = cfg.clone().scaled(unit);
    let truth = ScriptTruth::new(script);

    let mut cur_req = req.clone();
    let mut plan = healthy.placement;
    let phantom_cpu = cur_req.fleet.l() == 0;
    let mut orig_acc: Vec<usize> = (0..cur_req.fleet.k()).collect();
    let mut orig_cpu: Vec<usize> = (0..cur_req.fleet.l()).collect();
    let mut monitor = HealthMonitor::new(
        cur_req.fleet.k() + cur_req.fleet.l().max(1),
        cfg.health,
    );

    let injected_total = samples + truth.total_spikes();
    let mut pending = samples;
    let mut completed_total = 0usize;
    let mut shed_total = 0usize;
    let mut swaps = 0usize;
    let mut swap_times: Vec<f64> = Vec::new();
    let mut last_swap = f64::NEG_INFINITY;
    let mut decisions: Vec<Decision> = Vec::new();
    // (detection time, class, orig slot, kind) of removed devices whose
    // scripted recovery is pending re-admission
    let mut readmits: Vec<(f64, String, usize, DeviceKind)> = Vec::new();
    let mut t0 = 0.0f64;
    let mut epochs = 0usize;
    let mut verdict: Option<(Verdict, f64, f64)> = None; // (verdict, makespan, steady)

    'epochs: while verdict.is_none() {
        epochs += 1;
        if epochs > cfg.max_epochs {
            shed_total = injected_total.saturating_sub(completed_total);
            verdict = Some((Verdict::Shed(ShedCause::Unresolved), t0, f64::NAN));
            break;
        }
        // --- admission control: bound the injection backlog -------------
        if pending > cfg.backlog_cap {
            let drop = pending - cfg.backlog_cap;
            shed_total += drop;
            pending = cfg.backlog_cap;
            decisions.push(Decision {
                t: t0,
                trigger: "backlog".into(),
                action: format!("shed:{drop}"),
                accepted: true,
                reason: format!("backlog {} over cap {}", pending + drop, cfg.backlog_cap),
                predicted_before: f64::NAN,
                predicted_after: f64::NAN,
                swaps_so_far: swaps,
            });
        }

        // --- effective script for this epoch -----------------------------
        let k = cur_req.fleet.k();
        let l_dense = cur_req.fleet.l().max(1);
        let cur_dev = |slot: usize| -> Device {
            if slot < k {
                Device::Acc(orig_acc[slot])
            } else {
                let j = slot - k;
                Device::Cpu(orig_cpu.get(j).copied().unwrap_or(j))
            }
        };
        let mut eff: Vec<ScriptedEvent> = Vec::new();
        for slot in 0..k + l_dense {
            let here = if slot < k { Device::Acc(slot) } else { Device::Cpu(slot - k) };
            let (alive, scale) = truth.state_of(cur_dev(slot), t0);
            if !alive {
                eff.push(ScriptedEvent { at: 0.0, action: ScriptAction::Fail { device: here } });
            } else if (scale - 1.0).abs() > 1e-12 {
                eff.push(ScriptedEvent {
                    at: 0.0,
                    action: ScriptAction::Slow { device: here, factor: scale },
                });
            }
        }
        let remap = |d: Device| -> Option<Device> {
            match d {
                Device::Acc(o) => orig_acc.iter().position(|&x| x == o).map(Device::Acc),
                Device::Cpu(o) if phantom_cpu => Some(Device::Cpu(o)),
                Device::Cpu(o) => orig_cpu.iter().position(|&x| x == o).map(Device::Cpu),
            }
        };
        for e in &truth.events {
            let future = e.at > t0 + 1e-12;
            let spike_at_zero =
                t0 == 0.0 && e.at == 0.0 && matches!(e.action, ScriptAction::Spike { .. });
            if !(future || spike_at_zero) {
                continue;
            }
            let at = (e.at - t0).max(0.0);
            let action = match e.action {
                ScriptAction::Fail { device } => match remap(device) {
                    Some(d) => ScriptAction::Fail { device: d },
                    None => continue,
                },
                ScriptAction::Slow { device, factor } => match remap(device) {
                    Some(d) => ScriptAction::Slow { device: d, factor },
                    None => continue,
                },
                ScriptAction::Recover { device } => match remap(device) {
                    Some(d) => ScriptAction::Recover { device: d },
                    None => continue,
                },
                spike @ ScriptAction::Spike { .. } => spike,
            };
            eff.push(ScriptedEvent { at, action });
        }
        let eff = EventScript { events: eff };

        // --- simulate the epoch ------------------------------------------
        let sim_cfg = SimConfig::for_request(&cur_req);
        let res =
            engine::simulate_with_events(g, &cur_req, &plan, schedule, pending, &eff, &sim_cfg);

        // observations: (abs finish, dense dev, observed, predicted)
        let mut obs: Vec<(f64, usize, f64, f64)> = res
            .trace
            .iter()
            .map(|&(_, j, is_bw, start, finish)| {
                let p = &res.pieces[j];
                let predicted = if is_bw { p.bw_cost } else { p.fw_cost };
                (t0 + finish, p.real_device.index(k), finish - start, predicted)
            })
            .collect();
        obs.sort_by(|a, b| a.0.total_cmp(&b.0));

        // silence detection arms only against devices that own work
        monitor.clear_busy_all();
        if pending > 0 || res.injected > 0 {
            let mut owns = vec![false; k + l_dense];
            for p in &res.pieces {
                owns[p.real_device.index(k)] = true;
            }
            for (d, &o) in owns.iter().enumerate() {
                if o {
                    monitor.note_busy(d, t0);
                }
            }
        }

        let hard_deadline =
            t0 + res.total + 2.0 * cfg.health.detection_bound() + cfg.cooldown + unit;
        let mut oi = 0usize;
        let mut guard = 0usize;
        let end: ScanEnd = 'scan: loop {
            guard += 1;
            if guard > 100_000 {
                break 'scan ScanEnd::Shed(t0 + res.total, ShedCause::Unresolved);
            }
            let t_obs = obs.get(oi).map_or(f64::INFINITY, |o| o.0);
            let t_dl = monitor.next_deadline().unwrap_or(f64::INFINITY);
            let t_rm = readmits
                .iter()
                .map(|r| r.0)
                .fold(f64::INFINITY, f64::min)
                .max(t0);
            if oi >= obs.len() {
                // nothing left to observe: classify the epoch's end
                match res.stall {
                    None => break 'scan ScanEnd::Clean,
                    Some(Stall::MemoryDeadlock { .. }) => {
                        break 'scan ScanEnd::Shed(
                            t0 + res.total,
                            ShedCause::MemoryDeadlock,
                        );
                    }
                    Some(Stall::DeviceLost { .. }) => {
                        // keep driving monitor deadlines / readmits until
                        // the probe ladder declares the device dead
                        if t_dl.min(t_rm) > hard_deadline {
                            break 'scan ScanEnd::Shed(
                                hard_deadline,
                                ShedCause::Unresolved,
                            );
                        }
                    }
                }
            }
            // fresh transitions to classify this iteration
            let mut fresh: Vec<HealthTransition> = Vec::new();
            let now;
            if t_obs <= t_dl && t_obs <= t_rm {
                let (t, dev, observed, predicted) = obs[oi];
                oi += 1;
                now = t;
                if let Some(tr) = monitor.observe(dev, t, observed, predicted) {
                    fresh.push(tr);
                }
            } else if t_rm < t_dl {
                // a removed device's scripted recovery was detected
                now = t_rm;
                let idx = readmits
                    .iter()
                    .position(|r| r.0 <= now)
                    .expect("a readmit is due");
                let (_, class, o, kind) = readmits.remove(idx);
                if swaps >= cfg.max_swaps {
                    decisions.push(Decision {
                        t: now,
                        trigger: format!("readmit:{class}"),
                        action: "none".into(),
                        accepted: false,
                        reason: "swap budget exhausted".into(),
                        predicted_before: f64::NAN,
                        predicted_after: f64::NAN,
                        swaps_so_far: swaps,
                    });
                    continue;
                }
                if now < last_swap + cfg.cooldown {
                    // defer, never drop: re-admission re-fires after the
                    // cooldown window closes
                    readmits.push((last_swap + cfg.cooldown, class, o, kind));
                    continue;
                }
                let mut cand_req = cur_req.clone();
                if !cand_req.fleet.increment(&class) {
                    continue; // class vanished; nothing to re-admit
                }
                let ins = class_tail_slot(&cand_req, &class, kind);
                let shifted = shift_plan_for_insert(&plan, ins, kind);
                let before = objective::max_load_req(g, &cand_req, &shifted);
                match planner.plan_request(g, &cand_req) {
                    Ok(cand) => {
                        let after = objective::max_load_req(g, &cand_req, &cand.placement);
                        let ok = before / after >= 1.0 + cfg.min_improvement;
                        decisions.push(Decision {
                            t: now,
                            trigger: format!("readmit:{class}"),
                            action: format!("readmit-replan:{class}"),
                            accepted: ok,
                            reason: if ok {
                                format!("predicted {before:.4} -> {after:.4}")
                            } else {
                                format!(
                                    "improvement {:.3} below threshold {:.3}",
                                    before / after - 1.0,
                                    cfg.min_improvement
                                )
                            },
                            predicted_before: before,
                            predicted_after: after,
                            swaps_so_far: swaps,
                        });
                        if ok {
                            break 'scan ScanEnd::Swap(
                                now,
                                SwapKind::Readmit {
                                    ins,
                                    orig: o,
                                    kind,
                                    req: cand_req,
                                    plan: cand.placement,
                                },
                            );
                        }
                        // rejected for improvement: dropped (documented)
                    }
                    Err(e) => decisions.push(Decision {
                        t: now,
                        trigger: format!("readmit:{class}"),
                        action: format!("readmit-replan:{class}"),
                        accepted: false,
                        reason: format!("re-plan failed: {e}"),
                        predicted_before: before,
                        predicted_after: f64::NAN,
                        swaps_so_far: swaps,
                    }),
                }
                continue;
            } else {
                // a monitor deadline (silence check / probe timeout /
                // re-admission probe of an in-fleet dead device)
                if t_dl > hard_deadline {
                    break 'scan ScanEnd::Shed(hard_deadline, ShedCause::Unresolved);
                }
                now = t_dl;
                let adv = monitor.advance(now);
                fresh.extend(adv.transitions);
                for dev in adv.probes {
                    if truth.alive(cur_dev(dev), now) {
                        if let Some(tr) = monitor.probe_ok(dev, now) {
                            fresh.push(tr);
                        }
                    }
                }
            }

            // --- classify fresh transitions into ladder decisions --------
            for tr in fresh {
                if !tr.actionable() {
                    continue;
                }
                let dev = tr.dev;
                let here =
                    if dev < k { Device::Acc(dev) } else { Device::Cpu(dev - k) };
                if tr.to == DeviceHealth::Dead {
                    // rung 2 (decrement re-plan), deferred by cooldown —
                    // never improvement-gated: the current plan cannot
                    // finish with this device dead
                    if swaps >= cfg.max_swaps {
                        decisions.push(Decision {
                            t: now,
                            trigger: format!("dead:{here}"),
                            action: "shed".into(),
                            accepted: true,
                            reason: "swap budget exhausted".into(),
                            predicted_before: plan.objective,
                            predicted_after: f64::NAN,
                            swaps_so_far: swaps,
                        });
                        break 'scan ScanEnd::Shed(now, ShedCause::SwapBudgetExhausted);
                    }
                    let swap_at = now.max(last_swap + cfg.cooldown);
                    let cpu_pool_dead = here == Device::Cpu(0);
                    match planner.plan_after_device_loss(g, &cur_req, here) {
                        Ok((new_req, stages)) => {
                            let class = cur_req
                                .fleet
                                .class_of(here)
                                .map(|c| c.name.clone())
                                .unwrap_or_default();
                            decisions.push(Decision {
                                t: now,
                                trigger: format!("dead:{here}"),
                                action: format!("decrement-replan:{class}"),
                                accepted: true,
                                reason: if swap_at > now {
                                    format!("deferred to t={swap_at:.3} (cooldown)")
                                } else {
                                    "device lost".into()
                                },
                                predicted_before: plan.objective,
                                predicted_after: stages.placement.objective,
                                swaps_so_far: swaps,
                            });
                            let (orig, kind) = if dev < k {
                                (orig_acc[dev], DeviceKind::Accelerator)
                            } else {
                                (
                                    orig_cpu.get(dev - k).copied().unwrap_or(dev - k),
                                    DeviceKind::Cpu,
                                )
                            };
                            break 'scan ScanEnd::Swap(
                                swap_at,
                                SwapKind::Decrement {
                                    dense: dev,
                                    orig,
                                    kind,
                                    req: new_req,
                                    plan: stages.placement,
                                },
                            );
                        }
                        Err(decrement_err) => {
                            // rung 3: CPU failover (meaningless when the
                            // CPU pool head itself is the dead device)
                            let fb = if cpu_pool_dead {
                                Err(PlaceError::Unsupported(
                                    "CPU pool head died; failover target is itself".into(),
                                ))
                            } else {
                                fallback_after_loss(g, &cur_req, &plan, here)
                            };
                            match fb {
                                Ok(fb_plan) => {
                                    decisions.push(Decision {
                                        t: now,
                                        trigger: format!("dead:{here}"),
                                        action: "cpu-failover".into(),
                                        accepted: true,
                                        reason: format!(
                                            "decrement re-plan failed ({decrement_err})"
                                        ),
                                        predicted_before: plan.objective,
                                        predicted_after: fb_plan.objective,
                                        swaps_so_far: swaps,
                                    });
                                    break 'scan ScanEnd::Swap(
                                        swap_at,
                                        SwapKind::Failover { plan: fb_plan },
                                    );
                                }
                                Err(fb_err) => {
                                    // rung 4: shed, classified
                                    decisions.push(Decision {
                                        t: now,
                                        trigger: format!("dead:{here}"),
                                        action: "shed".into(),
                                        accepted: true,
                                        reason: format!(
                                            "no rung can place the work \
                                             (decrement: {decrement_err}; \
                                              failover: {fb_err})"
                                        ),
                                        predicted_before: plan.objective,
                                        predicted_after: f64::NAN,
                                        swaps_so_far: swaps,
                                    });
                                    break 'scan ScanEnd::Shed(
                                        now,
                                        ShedCause::NoFeasiblePlacement,
                                    );
                                }
                            }
                        }
                    }
                } else {
                    // rung 1: drift-adjusted re-plan in place, gated on
                    // cooldown + improvement. Fires on ->Degraded,
                    // Degraded->Healthy (drift cleared) and
                    // Dead->Healthy (in-fleet recovery).
                    let trigger = match (tr.from, tr.to) {
                        (_, DeviceHealth::Degraded) => {
                            format!("degraded:{here}*{:.2}", monitor.drift(dev))
                        }
                        (DeviceHealth::Dead, _) => format!("recovered:{here}"),
                        _ => format!("cleared:{here}"),
                    };
                    if swaps >= cfg.max_swaps {
                        decisions.push(Decision {
                            t: now,
                            trigger,
                            action: "replan-in-place".into(),
                            accepted: false,
                            reason: "swap budget exhausted".into(),
                            predicted_before: f64::NAN,
                            predicted_after: f64::NAN,
                            swaps_so_far: swaps,
                        });
                        continue;
                    }
                    if now < last_swap + cfg.cooldown {
                        decisions.push(Decision {
                            t: now,
                            trigger,
                            action: "replan-in-place".into(),
                            accepted: false,
                            reason: format!(
                                "cooldown until t={:.3}",
                                last_swap + cfg.cooldown
                            ),
                            predicted_before: f64::NAN,
                            predicted_after: f64::NAN,
                            swaps_so_far: swaps,
                        });
                        continue;
                    }
                    let degraded: Vec<(usize, f64)> = monitor
                        .degraded()
                        .into_iter()
                        .filter(|&(s, _)| s < k)
                        .collect();
                    let (adj_req, pi) = drift_adjusted_request(&cur_req, &degraded);
                    let mapped_old = apply_acc_perm(&plan, &pi);
                    let before = objective::max_load_req(g, &adj_req, &mapped_old);
                    match planner.plan_request(g, &adj_req) {
                        Ok(cand) => {
                            let after =
                                objective::max_load_req(g, &adj_req, &cand.placement);
                            let ok = before / after >= 1.0 + cfg.min_improvement;
                            decisions.push(Decision {
                                t: now,
                                trigger,
                                action: "replan-in-place".into(),
                                accepted: ok,
                                reason: if ok {
                                    format!("predicted {before:.4} -> {after:.4}")
                                } else {
                                    format!(
                                        "improvement {:.3} below threshold {:.3}",
                                        before / after - 1.0,
                                        cfg.min_improvement
                                    )
                                },
                                predicted_before: before,
                                predicted_after: after,
                                swaps_so_far: swaps,
                            });
                            if ok {
                                let inv = invert_perm(&pi);
                                let mut new_plan = apply_acc_perm(&cand.placement, &inv);
                                new_plan.objective =
                                    objective::max_load_req(g, &cur_req, &new_plan);
                                break 'scan ScanEnd::Swap(
                                    now,
                                    SwapKind::Replan { plan: new_plan },
                                );
                            }
                        }
                        Err(e) => decisions.push(Decision {
                            t: now,
                            trigger,
                            action: "replan-in-place".into(),
                            accepted: false,
                            reason: format!("re-plan failed: {e}"),
                            predicted_before: before,
                            predicted_after: f64::NAN,
                            swaps_so_far: swaps,
                        }),
                    }
                }
            }
        };

        // --- cut the epoch and apply the staged outcome -------------------
        match end {
            ScanEnd::Clean => {
                completed_total += res.completed;
                pending = 0;
                verdict = Some((Verdict::Completed, t0 + res.total, res.steady_tps));
            }
            ScanEnd::Shed(t, cause) => {
                // count what completed before the shed, shed the rest
                let rel = t - t0;
                let done_now = res
                    .sample_done
                    .iter()
                    .filter(|d| d.is_finite() && **d <= rel + 1e-9)
                    .count();
                completed_total += done_now;
                shed_total = injected_total.saturating_sub(completed_total);
                verdict = Some((Verdict::Shed(cause), t, f64::NAN));
            }
            ScanEnd::Swap(t, kind) => {
                let rel = t - t0;
                let done_now = res
                    .sample_done
                    .iter()
                    .filter(|d| d.is_finite() && **d <= rel + 1e-9)
                    .count();
                completed_total += done_now;
                let fired = truth.spikes_fired(t0, t);
                pending = (pending + fired).saturating_sub(done_now);
                swaps += 1;
                swap_times.push(t);
                last_swap = t;
                match kind {
                    SwapKind::Decrement { dense, orig, kind, req, plan: p } => {
                        // schedule re-admission if the script later
                        // recovers this device (one reprobe interval of
                        // detection lag)
                        let k_old = cur_req.fleet.k();
                        let dev_now = match kind {
                            DeviceKind::Accelerator => Device::Acc(dense),
                            DeviceKind::Cpu => Device::Cpu(dense - k_old),
                        };
                        let od = match kind {
                            DeviceKind::Accelerator => Device::Acc(orig),
                            DeviceKind::Cpu => Device::Cpu(orig),
                        };
                        let class = cur_req
                            .fleet
                            .class_of(dev_now)
                            .map(|c| c.name.clone())
                            .unwrap_or_default();
                        if let Some(tr_at) = truth.first_recover_after(od, t) {
                            readmits.push((
                                tr_at + cfg.health.reprobe_dead_every,
                                class,
                                orig,
                                kind,
                            ));
                        }
                        match kind {
                            DeviceKind::Accelerator => {
                                orig_acc.remove(dense);
                                monitor.remove_device(dense);
                            }
                            DeviceKind::Cpu => {
                                orig_cpu.remove(dense - k_old);
                                // the last CPU's slot stays behind as the
                                // engine's phantom CPU slot
                                if req.fleet.l() > 0 {
                                    monitor.remove_device(dense);
                                }
                            }
                        }
                        cur_req = req;
                        plan = p;
                    }
                    SwapKind::Failover { plan: p } | SwapKind::Replan { plan: p } => {
                        plan = p;
                    }
                    SwapKind::Readmit { ins, orig, kind, req, plan: p } => {
                        match kind {
                            DeviceKind::Accelerator => {
                                orig_acc.insert(ins, orig);
                                monitor.insert_device(ins);
                            }
                            DeviceKind::Cpu => {
                                // a 0-CPU fleet kept a phantom slot; the
                                // re-admitted device takes it over
                                if cur_req.fleet.l() == 0 {
                                    monitor.remove_device(cur_req.fleet.k());
                                }
                                orig_cpu.insert(ins, orig);
                                monitor.insert_device(req.fleet.k() + ins);
                            }
                        }
                        cur_req = req;
                        plan = p;
                    }
                }
                t0 = t;
                continue 'epochs;
            }
        }
    }

    let (verdict, makespan, steady) = verdict.expect("loop sets a verdict");
    Ok(MonitorOutcome {
        verdict,
        injected: injected_total,
        completed: completed_total,
        shed: shed_total,
        makespan,
        final_steady_tps: steady,
        plan_swaps: swaps,
        swap_times,
        decisions,
        transitions: monitor.transitions().to_vec(),
        final_placement: plan,
        final_request: cur_req,
        epochs,
        time_unit: unit,
        cooldown: cfg.cooldown,
    })
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::SolveOpts;
    use crate::coordinator::placement::Scenario;
    use crate::coordinator::planner::Algorithm;
    use crate::graph::Node;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(10.0).acc(1.0).mem(1.0).comm(0.1));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    fn planner() -> ServingPlanner {
        ServingPlanner::new(Algorithm::Dp, SolveOpts::default())
    }

    fn run(
        g: &OpGraph,
        req: &PlanRequest,
        spec: &str,
        samples: usize,
        cfg: &ControllerConfig,
    ) -> MonitorOutcome {
        let script = EventScript::parse(spec).unwrap();
        let mut pl = planner();
        run_monitored(g, req, &script, engine::Schedule::Pipelined, samples, &mut pl, cfg)
            .unwrap()
    }

    /// The loop's conservation law, checked after every test run.
    fn check_invariants(out: &MonitorOutcome) {
        assert_eq!(
            out.completed + out.shed,
            out.injected,
            "conservation: completed {} + shed {} != injected {}",
            out.completed,
            out.shed,
            out.injected
        );
        assert_eq!(out.plan_swaps, out.swap_times.len());
        for w in out.swap_times.windows(2) {
            assert!(
                w[1] - w[0] >= out.cooldown - 1e-9,
                "swaps at {} and {} violate cooldown {}",
                w[0],
                w[1],
                out.cooldown
            );
        }
    }

    #[test]
    fn no_event_run_matches_plain_simulation() {
        // the acceptance bar: with no scripted events the monitored loop
        // is a bitwise replay of the plain engine run
        let g = chain(6);
        let req = Scenario::new(3, 1, f64::INFINITY).to_request();
        let mut pl = planner();
        let stages = pl.plan_request(&g, &req).unwrap();
        let base = engine::simulate_req(
            &g,
            &req,
            &stages.placement,
            engine::Schedule::Pipelined,
            24,
            &SimConfig::for_request(&req),
        );
        let out = run(&g, &req, "", 24, &ControllerConfig::default());
        check_invariants(&out);
        assert_eq!(out.verdict, Verdict::Completed);
        assert_eq!(out.plan_swaps, 0);
        assert_eq!(out.epochs, 1);
        assert_eq!(out.completed, 24);
        assert_eq!(out.final_steady_tps.to_bits(), base.steady_tps.to_bits());
        assert_eq!(out.makespan.to_bits(), base.total.to_bits());
        assert!(out.decisions.is_empty());
    }

    #[test]
    fn single_fail_is_detected_and_replanned_around() {
        // a permanent accelerator loss: silence -> probes -> Dead ->
        // decrement re-plan; the run then finishes on the shrunk fleet
        let g = chain(6);
        let req = Scenario::new(3, 1, f64::INFINITY).to_request();
        let out = run(&g, &req, "fail:acc1@t=3", 20, &ControllerConfig::default());
        check_invariants(&out);
        assert_eq!(out.verdict, Verdict::Completed, "decisions: {:#?}", out.decisions);
        assert_eq!(out.completed, 20);
        assert_eq!(out.plan_swaps, 1);
        assert_eq!(out.final_request.fleet.k(), 2, "fleet must shrink by the dead device");
        assert!(
            out.decisions
                .iter()
                .any(|d| d.accepted && d.action.starts_with("decrement-replan")),
            "decisions: {:#?}",
            out.decisions
        );
        // the monitor, not the script, timed the detection
        let dead_at = out
            .transitions
            .iter()
            .find(|tr| tr.to == DeviceHealth::Dead)
            .map(|tr| tr.t)
            .expect("a Dead transition");
        assert!(dead_at > 3.0, "death declared only after the probe ladder ran");
    }

    #[test]
    fn quick_recover_needs_no_swap_at_all() {
        // outage shorter than the detection bound: in-flight work resumes
        // on recovery before the probe ladder condemns the device
        let g = chain(6);
        let req = Scenario::new(3, 1, f64::INFINITY).to_request();
        let out = run(
            &g,
            &req,
            "fail:acc1@t=3,recover:acc1@t=6",
            20,
            &ControllerConfig::default(),
        );
        check_invariants(&out);
        assert_eq!(out.verdict, Verdict::Completed, "decisions: {:#?}", out.decisions);
        assert_eq!(out.completed, 20);
        assert_eq!(out.plan_swaps, 0, "decisions: {:#?}", out.decisions);
        assert_eq!(out.final_request.fleet.k(), 3);
    }

    #[test]
    fn sustained_straggler_triggers_inplace_replan() {
        // a 4x straggler never dies (completions keep arriving) but the
        // drift EWMA crosses the threshold and rung 1 rebalances around it
        let g = chain(6);
        let req = Scenario::new(3, 1, f64::INFINITY).to_request();
        let out = run(&g, &req, "slow:acc1*0.25@t=0", 24, &ControllerConfig::default());
        check_invariants(&out);
        assert_eq!(out.verdict, Verdict::Completed, "decisions: {:#?}", out.decisions);
        assert_eq!(out.completed, 24);
        assert_eq!(out.final_request.fleet.k(), 3, "straggler must stay in the fleet");
        assert!(
            out.decisions
                .iter()
                .any(|d| d.accepted && d.action == "replan-in-place"),
            "decisions: {:#?}",
            out.decisions
        );
        assert!(out.plan_swaps >= 1);
    }

    #[test]
    fn backlog_over_cap_sheds_instead_of_deadlocking() {
        let g = chain(4);
        let req = Scenario::new(2, 1, f64::INFINITY).to_request();
        let cfg = ControllerConfig { backlog_cap: 8, ..ControllerConfig::default() };
        let out = run(&g, &req, "", 20, &cfg);
        check_invariants(&out);
        assert_eq!(out.verdict, Verdict::Completed);
        assert_eq!(out.completed, 8);
        assert_eq!(out.shed, 12);
        assert!(
            out.decisions.iter().any(|d| d.trigger == "backlog" && d.action == "shed:12"),
            "decisions: {:#?}",
            out.decisions
        );
    }

    #[test]
    fn oscillating_straggler_respects_hysteresis() {
        // slow/recover flapping: however noisy the script, accepted swaps
        // stay under the budget and at least a cooldown apart (asserted
        // by check_invariants)
        let g = chain(6);
        let req = Scenario::new(3, 1, f64::INFINITY).to_request();
        let cfg = ControllerConfig { max_swaps: 3, ..ControllerConfig::default() };
        let out = run(
            &g,
            &req,
            "slow:acc1*0.25@t=0,recover:acc1@t=30,slow:acc1*0.25@t=60,recover:acc1@t=90",
            48,
            &cfg,
        );
        check_invariants(&out);
        assert!(out.plan_swaps <= 3, "decisions: {:#?}", out.decisions);
        assert_eq!(out.verdict, Verdict::Completed, "decisions: {:#?}", out.decisions);
    }

    #[test]
    fn fail_then_recover_readmits_the_device() {
        // device dies long enough to be swapped out, then recovers: the
        // controller schedules a re-admission probe and grows the fleet
        // back when the re-plan pays for itself
        let g = chain(6);
        let req = Scenario::new(3, 1, f64::INFINITY).to_request();
        // generous sample count so the run is still going when the
        // re-admission probe fires (recovery + reprobe interval)
        let out = run(
            &g,
            &req,
            "fail:acc1@t=3,recover:acc1@t=80",
            160,
            &ControllerConfig::default(),
        );
        check_invariants(&out);
        assert_eq!(out.verdict, Verdict::Completed, "decisions: {:#?}", out.decisions);
        if out
            .decisions
            .iter()
            .any(|d| d.accepted && d.action.starts_with("readmit-replan"))
        {
            assert_eq!(out.final_request.fleet.k(), 3, "re-admission must restore k");
        } else {
            // run may have drained before the probe fired; the swap-out
            // alone must still have happened
            assert_eq!(out.final_request.fleet.k(), 2);
        }
    }
}

//! `simx` — the fleet-aware discrete-event simulation subsystem.
//!
//! The paper's claims are throughput claims: Figs. 5/7 and Table 1 state
//! what a placement *does when executed* under a pipelined schedule. This
//! subsystem is the executable half of that statement for heterogeneous
//! fleets, replacing the scalar-scenario greedy loop the repository grew
//! up with:
//!
//! * [`engine`] — a binary-heap event queue over typed events
//!   (`ComputeDone`, `TransferDone`, `DeviceFail`, `DeviceSlow`,
//!   `SampleInject`) driving per-device resources (class-speed-scaled
//!   compute, live weight/activation memory occupancy against per-class
//!   caps) and per-link resources (bandwidth-delayed cross-device tensor
//!   transfers), under the four [`engine::Schedule`] policies.
//! * [`event`] — scripted fault / straggler / load-spike injection and
//!   its CLI grammar (`fail:acc0@t=5,slow:acc1*0.5@t=9,spike:+8@t=12`).
//! * [`validate`] — cross-checks every registry solver's predicted
//!   objective against simulated steady-state TPS on heterogeneous
//!   fleets (the simulation analogue of `tests/fleet_equivalence.rs`).
//! * [`loop_`] — the drift-driven re-planning loop: a scripted fault
//!   triggers `Fleet::decrement` → `ServingPlanner::plan_request` → plan
//!   swap, with before/after TPS measured *in simulation*.
//! * [`controller`] — the closed-loop version of [`loop_`]: a
//!   [`crate::runtime::health::HealthMonitor`] consumes the engine trace,
//!   and its transitions drive a hysteresis re-plan controller
//!   (cooldown + improvement threshold + swap budget) down a
//!   graceful-degradation ladder — re-plan in place, decrement re-plan,
//!   CPU failover, admission-controlled shed.
//! * [`chaos`] — seeded chaos campaigns: randomized fail/slow/recover/
//!   spike scripts fuzzed through [`controller::run_monitored`], with
//!   liveness, hysteresis and near-oracle-throughput invariants checked
//!   on every run.
//! * [`trace`] — observability glue (DESIGN.md §10): engine runs become
//!   Chrome-trace Gantt lanes in virtual time plus utilization/link
//!   counters in the obs registry; controller decisions become trace
//!   instants.
//!
//! The legacy [`crate::pipeline::sim`] API survives as a thin adapter
//! over this engine (uniform-fleet results within ε of the frozen
//! reference implementation, enforced by `tests/simx_equivalence.rs`).
//! See DESIGN.md §6 for the event/resource model and the tolerance
//! contract.

pub mod chaos;
pub mod controller;
pub mod engine;
pub mod event;
pub mod loop_;
pub mod trace;
pub mod validate;

pub use chaos::{ChaosCampaign, ChaosConfig, ChaosReport, RunReport};
pub use controller::{
    run_monitored, ControllerConfig, Decision, MonitorOutcome, ShedCause, Verdict,
};
pub use engine::{
    build_pieces_req, simulate_req, simulate_with_events, Piece, Schedule, SimConfig,
    SimxResult, Stall,
};
pub use event::{EventScript, ScriptAction, ScriptedEvent};

//! Prediction-vs-simulation cross-validation — the simulation analogue of
//! `tests/fleet_equivalence.rs`.
//!
//! For each registry solver, the produced placement's **predicted**
//! throughput objective (`objective::max_load_req` — what the planner
//! claims the plan will do) is replayed through the [`crate::simx`]
//! engine on the *same heterogeneous fleet*, and the measured steady-state
//! time-per-sample must agree within a documented tolerance
//! ([`DEFAULT_TOLERANCE`], 10%: the ramp-up window plus slope estimation
//! noise; DESIGN.md §6).
//!
//! Two deliberate scope notes:
//!
//! * The latency IP and the replication/hierarchy DPs optimize objectives
//!   that are not a pipelined TPS, so their rows compare the *max-load
//!   evaluation of their placement* against its simulation — the claim
//!   being validated is always "this placement pipelines at the predicted
//!   max-load", uniformly across solvers.
//! * Memory-oblivious baselines (Scotch, expert) can emit placements that
//!   are infeasible under per-class caps; their predicted objective is
//!   `∞` and nothing can be simulated — such rows are reported in
//!   [`ValidationReport::skipped`] rather than silently dropped.

use crate::algos::{objective, PlaceError};
use crate::coordinator::context::SolveOpts;
use crate::coordinator::placement::{AlgoChoice, PlanRequest, TrainSchedule};
use crate::coordinator::planner::Algorithm;
use crate::coordinator::service::PlannerService;
use crate::graph::{NodeKind, OpGraph};
use crate::simx::engine::{self, Schedule, SimConfig};

/// Documented prediction-vs-simulation agreement bound (relative).
pub const DEFAULT_TOLERANCE: f64 = 0.10;

/// One solver's prediction-vs-simulation comparison.
#[derive(Clone, Debug)]
pub struct ValidationRow {
    pub algorithm: Algorithm,
    /// `objective::max_load_req` of the produced placement.
    pub predicted: f64,
    /// Simulated steady-state time-per-sample of the same placement.
    pub simulated: f64,
    /// `|simulated - predicted| / predicted`.
    pub rel_err: f64,
}

/// All rows of one `(graph, fleet)` validation sweep.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    pub rows: Vec<ValidationRow>,
    /// Solvers with nothing to simulate on this fleet: the placement was
    /// memory-infeasible (predicted `∞`) or the solver itself errored.
    pub skipped: Vec<Algorithm>,
    pub tolerance: f64,
}

impl ValidationReport {
    pub fn max_rel_err(&self) -> f64 {
        self.rows.iter().map(|r| r.rel_err).fold(0.0, f64::max)
    }

    /// Every simulated row within the tolerance.
    pub fn all_within(&self) -> bool {
        self.rows.iter().all(|r| r.rel_err <= self.tolerance)
    }

    /// The worst row, for error messages.
    pub fn worst(&self) -> Option<&ValidationRow> {
        self.rows
            .iter()
            .max_by(|a, b| a.rel_err.total_cmp(&b.rel_err))
    }
}

/// The schedule the validation replays: the request's training schedule
/// for training graphs, pipelined inference otherwise.
pub fn replay_schedule(g: &OpGraph, req: &PlanRequest) -> Schedule {
    let training = g.nodes.iter().any(|n| n.kind == NodeKind::Backward);
    if !training {
        Schedule::Pipelined
    } else {
        match req.train_schedule {
            TrainSchedule::PipeDream => Schedule::PipeDream1F1B,
            TrainSchedule::GPipe => Schedule::GPipe,
        }
    }
}

/// Cross-check `algorithms` on `(g, req)`: plan each through a shared
/// [`PlannerService`] context, simulate the placement for `samples`
/// samples with [`SimConfig::for_request`] (bandwidth-delayed links at the
/// fleet's `bw`), and report prediction-vs-simulation agreement.
///
/// GPipe's phase barrier makes per-sample completions bursty, so its
/// measured cost is the amortized `total / samples` instead of the
/// order-statistic slope (both converge to the objective as `samples`
/// grows).
pub fn validate_request(
    g: &OpGraph,
    req: &PlanRequest,
    algorithms: &[Algorithm],
    opts: &SolveOpts,
    samples: usize,
    tolerance: f64,
) -> Result<ValidationReport, PlaceError> {
    let mut svc = PlannerService::new(2);
    let schedule = replay_schedule(g, req);
    let cfg = SimConfig::for_request(req);
    let mut rows = Vec::new();
    let mut skipped = Vec::new();
    for &alg in algorithms {
        let fixed = req.clone().algorithm(AlgoChoice::Fixed(alg));
        // a solver that errors on this fleet joins the skipped rows like
        // the memory-infeasible ones — one bad entry must not abort the
        // other solvers' validation
        let Ok(r) = svc.plan_request(g, &fixed, opts) else {
            skipped.push(alg);
            continue;
        };
        let predicted = objective::max_load_req(g, req, &r.placement);
        if !predicted.is_finite() {
            skipped.push(alg);
            continue;
        }
        let sim = engine::simulate_req(g, req, &r.placement, schedule, samples, &cfg);
        let simulated = if schedule == Schedule::GPipe && sim.completed > 0 {
            sim.total / sim.completed as f64
        } else {
            sim.steady_tps
        };
        let rel_err = (simulated - predicted).abs() / predicted;
        rows.push(ValidationRow { algorithm: alg, predicted, simulated, rel_err });
    }
    Ok(ValidationReport { rows, skipped, tolerance })
}

/// [`validate_request`] over the full 12-entry registry with the default
/// tolerance.
pub fn validate_registry(
    g: &OpGraph,
    req: &PlanRequest,
    opts: &SolveOpts,
    samples: usize,
) -> Result<ValidationReport, PlaceError> {
    validate_request(g, req, &Algorithm::ALL, opts, samples, DEFAULT_TOLERANCE)
}

//! Seeded chaos campaigns for the monitored serving loop.
//!
//! A campaign fuzzes hundreds of randomized fail / slow / recover /
//! spike scripts (deterministic per seed, [`crate::util::rng::Rng`])
//! through [`run_monitored`] on a fixed workload × fleet, and checks the
//! resilience invariants on **every** run:
//!
//! 1. **Liveness** — the controller returns: every injected sample is
//!    either completed or shed with a classified
//!    [`crate::simx::controller::ShedCause`]
//!    (`completed + shed == injected`), never silently lost and never
//!    deadlocked.
//! 2. **Hysteresis** — accepted plan swaps number at most
//!    [`ControllerConfig::max_swaps`] and consecutive swaps are at least
//!    the (scaled) cooldown apart.
//! 3. **Near-oracle throughput** — for clean single-permanent-fail runs,
//!    the final steady time-per-sample is within
//!    [`ChaosConfig::oracle_factor`] of the *oracle* that re-plans at
//!    the instant of the fault with perfect knowledge
//!    ([`ServingPlanner::plan_after_device_loss`] + a plain engine run).
//!
//! Violations are collected (not panicked) into
//! [`ChaosReport::violations`] so a campaign reports every failure at
//! once; `tests/chaos_campaign.rs` and the `chaos` CLI subcommand assert
//! the list is empty. Script generation never emits a fail for the last
//! remaining accelerator class member unless a CPU pool exists, and caps
//! concurrent permanent fails at `k - 1` — total fleet loss is a
//! different (trivially shed) regime than the degradation ladder under
//! test.

use crate::coordinator::placement::{Device, PlanRequest};
use crate::graph::OpGraph;
use crate::runtime::server::ServingPlanner;
use crate::simx::controller::{run_monitored, ControllerConfig, MonitorOutcome, Verdict};
use crate::simx::engine::{self, Schedule, SimConfig};
use crate::simx::event::{EventScript, ScriptAction, ScriptedEvent};
use crate::util::rng::Rng;

/// Campaign shape. `runs` scripts are generated from `seed` (run `i`
/// uses seed `seed + i`, so any single run reproduces in isolation).
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    pub seed: u64,
    pub runs: usize,
    /// Base samples per run, drawn uniformly from this inclusive range.
    pub samples_min: usize,
    pub samples_max: usize,
    /// Mean number of fault events per script (0–2 fails, 0–2 slows,
    /// 0–1 spikes are drawn independently; see `gen_script`).
    pub max_fails: usize,
    /// Probability that a generated fail is followed by a recover.
    pub p_recover: f64,
    /// Straggler slow-down factors are drawn from `[slow_min, slow_max]`
    /// (a factor < 1 multiplies device speed down).
    pub slow_min: f64,
    pub slow_max: f64,
    /// Max extra samples a single spike injects.
    pub spike_max: usize,
    /// Script horizon in beats (event times are drawn in `[0, horizon)`
    /// and scaled by the run's measured beat).
    pub horizon_beats: f64,
    /// Allowed ratio of monitored steady tps over the oracle's for
    /// single-permanent-fail runs (invariant 3; DESIGN.md §7).
    pub oracle_factor: f64,
    pub controller: ControllerConfig,
    pub schedule: Schedule,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            seed: 0xC1A05,
            runs: 50,
            samples_min: 12,
            samples_max: 16,
            max_fails: 2,
            p_recover: 0.5,
            slow_min: 0.2,
            slow_max: 0.9,
            spike_max: 6,
            horizon_beats: 10.0,
            oracle_factor: 2.0,
            controller: ControllerConfig::default(),
            schedule: Schedule::Pipelined,
        }
    }
}

/// Outcome of one fuzzed run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub seed: u64,
    /// The generated script, in the CLI grammar (reproducible input).
    pub script: String,
    pub samples: usize,
    pub verdict: Verdict,
    pub injected: usize,
    pub completed: usize,
    pub shed: usize,
    pub plan_swaps: usize,
    pub makespan: f64,
    pub final_steady_tps: f64,
    /// `Some(monitored / oracle)` when invariant 3 applied to this run.
    pub oracle_ratio: Option<f64>,
}

/// Aggregate campaign result.
#[derive(Clone, Debug, Default)]
pub struct ChaosReport {
    pub runs: Vec<RunReport>,
    pub completed_runs: usize,
    pub shed_runs: usize,
    /// Shed runs by cause (`Display` name → count), for the CLI summary.
    pub shed_by_cause: Vec<(String, usize)>,
    /// Every invariant violation across the campaign, human-readable.
    pub violations: Vec<String>,
}

impl ChaosReport {
    /// `Err(first violation)` when any invariant failed.
    pub fn ok(&self) -> Result<(), String> {
        match self.violations.first() {
            None => Ok(()),
            Some(v) => Err(format!("{} violation(s), first: {v}", self.violations.len())),
        }
    }
}

/// A seeded chaos campaign over one workload × fleet.
pub struct ChaosCampaign<'a> {
    pub g: &'a OpGraph,
    pub req: &'a PlanRequest,
    pub cfg: ChaosConfig,
}

impl<'a> ChaosCampaign<'a> {
    pub fn new(g: &'a OpGraph, req: &'a PlanRequest, cfg: ChaosConfig) -> ChaosCampaign<'a> {
        ChaosCampaign { g, req, cfg }
    }

    /// Generate one script from `rng`. Times are in absolute simulation
    /// units (`beat` = predicted time-per-sample of the healthy plan).
    fn gen_script(&self, rng: &mut Rng, beat: f64) -> EventScript {
        let cfg = &self.cfg;
        let k = self.req.fleet.k();
        let horizon = cfg.horizon_beats * beat;
        let mut events: Vec<ScriptedEvent> = Vec::new();
        let mut at = |rng: &mut Rng| (rng.gen_f64() * horizon * 1e3).round() / 1e3;

        // permanent/transient fails: never more than k - 1 accelerators
        // down at once (total loss is out of scope; see module docs)
        let fail_budget = cfg.max_fails.min(k.saturating_sub(1));
        let n_fails = if fail_budget == 0 { 0 } else { rng.gen_range(fail_budget + 1) };
        let mut devs: Vec<usize> = (0..k).collect();
        rng.shuffle(&mut devs);
        for &d in devs.iter().take(n_fails) {
            let t_fail = at(rng);
            events.push(ScriptedEvent {
                at: t_fail,
                action: ScriptAction::Fail { device: Device::Acc(d) },
            });
            if rng.gen_bool(cfg.p_recover) {
                let dt = rng.gen_f64_range(0.5 * beat, horizon);
                events.push(ScriptedEvent {
                    at: ((t_fail + dt) * 1e3).round() / 1e3,
                    action: ScriptAction::Recover { device: Device::Acc(d) },
                });
            }
        }
        // stragglers (any accelerator, including failed ones — recover
        // resets the scale, so the interleavings are the interesting part)
        for _ in 0..rng.gen_range(3) {
            let d = rng.gen_range(k.max(1));
            let factor =
                (rng.gen_f64_range(cfg.slow_min, cfg.slow_max) * 1e3).round() / 1e3;
            events.push(ScriptedEvent {
                at: at(rng),
                action: ScriptAction::Slow { device: Device::Acc(d), factor },
            });
        }
        // load spikes
        if cfg.spike_max > 0 && rng.gen_bool(0.5) {
            events.push(ScriptedEvent {
                at: at(rng),
                action: ScriptAction::Spike { count: 1 + rng.gen_range(cfg.spike_max) },
            });
        }
        events.sort_by(|a, b| a.at.total_cmp(&b.at));
        EventScript { events }
    }

    /// Run the campaign. Deterministic for a given `(cfg.seed, g, req)`.
    pub fn run(&self, planner: &mut ServingPlanner) -> ChaosReport {
        let mut report = ChaosReport::default();
        // one healthy plan up front: beat for time scaling + oracle base
        let beat = match planner.plan_request(self.g, self.req) {
            Ok(s) => crate::algos::objective::max_load_req(self.g, self.req, &s.placement)
                .max(1e-9),
            Err(e) => {
                report
                    .violations
                    .push(format!("workload/fleet has no healthy plan: {e}"));
                return report;
            }
        };
        for i in 0..self.cfg.runs {
            let seed = self.cfg.seed.wrapping_add(i as u64);
            let mut rng = Rng::new(seed);
            let script = self.gen_script(&mut rng, beat);
            let samples = self.cfg.samples_min
                + rng.gen_range(self.cfg.samples_max - self.cfg.samples_min + 1);
            match run_monitored(
                self.g,
                self.req,
                &script,
                self.cfg.schedule,
                samples,
                planner,
                &self.cfg.controller,
            ) {
                Ok(out) => self.check_run(seed, &script, samples, out, planner, &mut report),
                Err(e) => report.violations.push(format!(
                    "seed {seed} script '{script}': run_monitored errored: {e}"
                )),
            }
        }
        report.completed_runs =
            report.runs.iter().filter(|r| r.verdict == Verdict::Completed).count();
        report.shed_runs = report.runs.len() - report.completed_runs;
        for r in &report.runs {
            if let Verdict::Shed(cause) = &r.verdict {
                let name = cause.to_string();
                match report.shed_by_cause.iter_mut().find(|(n, _)| *n == name) {
                    Some((_, c)) => *c += 1,
                    None => report.shed_by_cause.push((name, 1)),
                }
            }
        }
        report
    }

    fn check_run(
        &self,
        seed: u64,
        script: &EventScript,
        samples: usize,
        out: MonitorOutcome,
        planner: &mut ServingPlanner,
        report: &mut ChaosReport,
    ) {
        let tag = format!("seed {seed} script '{script}'");
        // invariant 1: conservation (liveness is the return itself)
        if out.completed + out.shed != out.injected {
            report.violations.push(format!(
                "{tag}: completed {} + shed {} != injected {}",
                out.completed, out.shed, out.injected
            ));
        }
        // invariant 2: hysteresis
        if out.plan_swaps > self.cfg.controller.max_swaps {
            report.violations.push(format!(
                "{tag}: {} swaps over budget {}",
                out.plan_swaps, self.cfg.controller.max_swaps
            ));
        }
        for w in out.swap_times.windows(2) {
            if w[1] - w[0] < out.cooldown - 1e-9 {
                report.violations.push(format!(
                    "{tag}: swaps at {:.3} and {:.3} inside cooldown {:.3}",
                    w[0], w[1], out.cooldown
                ));
            }
        }
        // invariant 3: near-oracle steady tps on clean
        // single-permanent-acc-fail runs
        let oracle_ratio = self.oracle_ratio(script, samples, &out, planner);
        if let Some(ratio) = oracle_ratio {
            if ratio > self.cfg.oracle_factor {
                report.violations.push(format!(
                    "{tag}: steady tps {:.4} is {ratio:.2}x the oracle (allowed {:.2}x)",
                    out.final_steady_tps, self.cfg.oracle_factor
                ));
            }
        }
        report.runs.push(RunReport {
            seed,
            script: script.to_string(),
            samples,
            verdict: out.verdict,
            injected: out.injected,
            completed: out.completed,
            shed: out.shed,
            plan_swaps: out.plan_swaps,
            makespan: out.makespan,
            final_steady_tps: out.final_steady_tps,
            oracle_ratio,
        });
    }

    /// `Some(monitored_tps / oracle_tps)` when the run qualifies for
    /// invariant 3: exactly one accelerator fail, never recovered, no
    /// stragglers left active at the end, verdict Completed with at
    /// least one swap.
    fn oracle_ratio(
        &self,
        script: &EventScript,
        samples: usize,
        out: &MonitorOutcome,
        planner: &mut ServingPlanner,
    ) -> Option<f64> {
        if out.verdict != Verdict::Completed || out.plan_swaps == 0 {
            return None;
        }
        if !out.final_steady_tps.is_finite() {
            return None;
        }
        let fails: Vec<Device> = script
            .events
            .iter()
            .filter_map(|e| match e.action {
                ScriptAction::Fail { device } => Some(device),
                _ => None,
            })
            .collect();
        if fails.len() != 1 {
            return None;
        }
        let failed = fails[0];
        if !matches!(failed, Device::Acc(_)) {
            return None;
        }
        let recovered = script.events.iter().any(
            |e| matches!(e.action, ScriptAction::Recover { device } if device == failed),
        );
        // any slow event muddies the comparison — the oracle runs nominal
        let slowed = script
            .events
            .iter()
            .any(|e| matches!(e.action, ScriptAction::Slow { .. }));
        if recovered || slowed {
            return None;
        }
        let (oracle_req, oracle_stages) =
            planner.plan_after_device_loss(self.g, self.req, failed).ok()?;
        let oracle = engine::simulate_req(
            self.g,
            &oracle_req,
            &oracle_stages.placement,
            self.cfg.schedule,
            samples.max(8),
            &SimConfig::for_request(&oracle_req),
        );
        if !oracle.steady_tps.is_finite() || oracle.steady_tps <= 0.0 {
            return None;
        }
        Some(out.final_steady_tps / oracle.steady_tps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::SolveOpts;
    use crate::coordinator::placement::Scenario;
    use crate::coordinator::planner::Algorithm;
    use crate::graph::Node;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(10.0).acc(1.0).mem(1.0).comm(0.1));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn scripts_are_deterministic_per_seed() {
        let g = chain(6);
        let req = Scenario::new(3, 1, f64::INFINITY).to_request();
        let camp = ChaosCampaign::new(&g, &req, ChaosConfig::default());
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let s1 = camp.gen_script(&mut a, 2.0);
        let s2 = camp.gen_script(&mut b, 2.0);
        assert_eq!(s1, s2);
        // and the grammar roundtrips, so every script is reproducible
        // from its printed form
        if !s1.is_empty() {
            assert_eq!(EventScript::parse(&s1.to_string()).unwrap(), s1);
        }
    }

    #[test]
    fn fail_count_never_reaches_fleet_size() {
        let g = chain(6);
        let req = Scenario::new(2, 1, f64::INFINITY).to_request();
        let camp = ChaosCampaign::new(&g, &req, ChaosConfig::default());
        for seed in 0..40 {
            let mut rng = Rng::new(seed);
            let s = camp.gen_script(&mut rng, 2.0);
            let fails = s
                .events
                .iter()
                .filter(|e| matches!(e.action, ScriptAction::Fail { .. }))
                .count();
            assert!(fails < 2, "k=2 fleet must keep one accelerator: {s}");
        }
    }

    #[test]
    fn small_campaign_holds_all_invariants() {
        // a fast in-tree smoke (the full 200-run campaign lives in
        // tests/chaos_campaign.rs)
        let g = chain(6);
        let req = Scenario::new(3, 1, f64::INFINITY).to_request();
        let cfg = ChaosConfig { runs: 8, seed: 42, ..ChaosConfig::default() };
        let camp = ChaosCampaign::new(&g, &req, cfg);
        let mut planner = ServingPlanner::new(Algorithm::Dp, SolveOpts::default());
        let report = camp.run(&mut planner);
        assert_eq!(report.runs.len(), 8);
        assert!(report.ok().is_ok(), "violations: {:#?}", report.violations);
    }
}

//! The drift-driven re-planning loop: simulation closing the serving loop.
//!
//! `runtime::server::ServingPlanner` can re-plan a mutated fleet at
//! cache-hit cost, but until this module nothing could *evaluate* whether
//! the new plan is actually better. [`run_device_loss_demo`] wires the
//! engine to the planner end to end:
//!
//! 1. plan the request and measure its healthy steady-state TPS in
//!    simulation;
//! 2. replay the event script against the healthy plan — the scripted
//!    `fail:` strands every sample still needing the dead device
//!    ([`Stall::DeviceLost`]), which is the drift signal;
//! 3. the *no-replan* fallback ([`fallback_after_loss`]): the dead
//!    device's nodes hot-failover to the CPU pool — valid, degraded;
//! 4. the re-planned path:
//!    [`ServingPlanner::plan_after_device_loss`] = `Fleet::decrement` →
//!    `plan_request` over the mutated fleet (cache-hit cost for known
//!    fleets) — then both plans are simulated and compared.
//!
//! The contract (DESIGN.md §6, asserted by the CI smoke job and
//! `tests/simx_validate.rs`): post-replan time-per-sample is strictly
//! better (lower) than the degraded fallback's whenever the fallback
//! actually degraded the pipeline.

use crate::algos::{objective, PlaceError};
use crate::coordinator::placement::{Device, Placement, PlanRequest};
use crate::graph::OpGraph;
use crate::runtime::server::ServingPlanner;
use crate::simx::engine::{self, Schedule, SimConfig, Stall};
use crate::simx::event::{EventScript, ScriptAction, ScriptedEvent};

/// Outcome of one scripted device-loss → re-plan cycle. All `*_tps`
/// fields are steady-state **time-per-sample** — lower is better.
#[derive(Clone, Debug)]
pub struct ReplanDemo {
    pub failed_device: Device,
    pub failed_class: String,
    pub fail_time: f64,
    /// Steady-state TPS of the original plan on the intact, undisturbed
    /// fleet (the pre-fault baseline).
    pub healthy_tps: f64,
    /// Steady-state TPS of the CPU-failover fallback (no re-planning),
    /// under the script's residual stragglers/spikes.
    pub degraded_tps: f64,
    /// Steady-state TPS of the re-planned placement on the shrunk fleet,
    /// under the same residual disturbances (device-remapped).
    pub replanned_tps: f64,
    /// The fallback placement (dead device's nodes on the CPU pool).
    pub degraded: Placement,
    pub replanned: Placement,
    /// The request after `Fleet::decrement` (what the replan ran on).
    pub degraded_request: PlanRequest,
    /// Samples the *healthy plan* completed under the fault script before
    /// stalling — the drift signal as the engine saw it.
    pub disrupted_completed: usize,
    pub disrupted_injected: usize,
    pub disrupted_stall: Option<Stall>,
}

impl ReplanDemo {
    /// `degraded / replanned` time-per-sample ratio (> 1 ⇔ re-planning
    /// pays).
    pub fn improvement(&self) -> f64 {
        self.degraded_tps / self.replanned_tps
    }
}

/// The script minus its `fail:` events — the residual disturbances
/// (stragglers, load spikes) that keep applying after the loss is reacted
/// to.
fn residual_script(script: &EventScript) -> EventScript {
    EventScript {
        events: script
            .events
            .iter()
            .copied()
            .filter(|e| !matches!(e.action, ScriptAction::Fail { .. }))
            .collect(),
    }
}

/// Re-address device-scoped events for the post-`decrement` fleet. The
/// *failed device's own* dense slot disappears (its events die with it —
/// within a class devices are interchangeable, so the survivors occupy
/// the class's remaining slots in order) and every accelerator index
/// above it shifts down by one, including later classes. CPU indices and
/// spikes are unaffected.
fn remap_after_loss(script: &EventScript, failed: Device) -> EventScript {
    let lost_slot = match failed {
        Device::Acc(i) => i,
        Device::Cpu(_) => return script.clone(),
    };
    let remap = |d: Device| -> Option<Device> {
        match d {
            Device::Acc(i) if i == lost_slot => None,
            Device::Acc(i) if i > lost_slot => Some(Device::Acc(i - 1)),
            other => Some(other),
        }
    };
    EventScript {
        events: script
            .events
            .iter()
            .filter_map(|e| {
                let action = match e.action {
                    ScriptAction::Fail { device } => {
                        ScriptAction::Fail { device: remap(device)? }
                    }
                    ScriptAction::Slow { device, factor } => {
                        ScriptAction::Slow { device: remap(device)?, factor }
                    }
                    ScriptAction::Recover { device } => {
                        ScriptAction::Recover { device: remap(device)? }
                    }
                    spike @ ScriptAction::Spike { .. } => spike,
                };
                Some(ScriptedEvent { at: e.at, action })
            })
            .collect(),
    }
}

/// The no-replan fallback after losing `failed`: its nodes hot-failover to
/// the CPU pool (`Cpu(0)`), everything else stays put. Usually a badly
/// degraded placement — that is the point of comparison — but only a
/// *valid* one when every re-homed op actually runs on a CPU: an op with
/// no finite `p_cpu` (accelerator-only kernels) has nowhere to fail over
/// to, and this errors instead of silently returning an
/// infinite-objective placement (the re-planning controller skips this
/// ladder rung on that error).
pub fn fallback_after_loss(
    g: &OpGraph,
    req: &PlanRequest,
    p: &Placement,
    failed: Device,
) -> Result<Placement, PlaceError> {
    for (v, &d) in p.assignment.iter().enumerate() {
        if d == failed && !g.nodes[v].p_cpu.is_finite() {
            return Err(PlaceError::Unsupported(format!(
                "op '{}' on lost device {failed} has no finite CPU cost — CPU failover \
                 cannot place it",
                g.nodes[v].name
            )));
        }
    }
    let assignment = p
        .assignment
        .iter()
        .map(|&d| if d == failed { Device::Cpu(0) } else { d })
        .collect();
    let mut out = Placement::new(assignment, 0.0, format!("{} + CPU failover", p.algorithm));
    out.objective = objective::max_load_req(g, req, &out);
    Ok(out)
}

/// Run the full loss → drift → re-plan cycle (see the module docs).
/// `script` must contain a `fail:` event naming an accelerator of the
/// request's fleet; `samples` base samples are replayed per simulation.
/// Plans the healthy placement and replays the disruption itself; callers
/// that already hold both (the CLI `simulate` path) should use
/// [`run_device_loss_demo_with`] instead of paying them twice.
pub fn run_device_loss_demo(
    g: &OpGraph,
    req: &PlanRequest,
    script: &EventScript,
    schedule: Schedule,
    samples: usize,
    planner: &mut ServingPlanner,
) -> Result<ReplanDemo, PlaceError> {
    let healthy = planner.plan_request(g, req)?;
    let cfg = SimConfig::for_request(req);
    let disrupted = engine::simulate_with_events(
        g,
        req,
        &healthy.placement,
        schedule,
        samples,
        script,
        &cfg,
    );
    run_device_loss_demo_with(
        g,
        req,
        script,
        schedule,
        samples,
        planner,
        &healthy.placement,
        &disrupted,
    )
}

/// [`run_device_loss_demo`] against an already-planned healthy placement
/// and its already-simulated disrupted run (no re-planning, no repeated
/// fault replay).
#[allow(clippy::too_many_arguments)]
pub fn run_device_loss_demo_with(
    g: &OpGraph,
    req: &PlanRequest,
    script: &EventScript,
    schedule: Schedule,
    samples: usize,
    planner: &mut ServingPlanner,
    healthy: &Placement,
    disrupted: &engine::SimxResult,
) -> Result<ReplanDemo, PlaceError> {
    // react to the earliest *accelerator* fail — a CPU fault in the same
    // script simulates fine but has no failover/decrement story
    let (fail_time, failed_device) = script.first_acc_fail().ok_or_else(|| {
        PlaceError::Unsupported(
            "event script has no accelerator fail: event to react to".into(),
        )
    })?;
    // re-plan first: ServingPlanner::plan_after_device_loss is the one
    // authoritative range/class validation (out-of-fleet devices error
    // here, before any simulation runs)
    let (degraded_request, replanned_stages) =
        planner.plan_after_device_loss(g, req, failed_device)?;
    let replanned = replanned_stages.placement;
    let failed_class = req
        .fleet
        .class_of(failed_device)
        .map(|c| c.name.clone())
        .unwrap_or_default();

    // the comparison replays keep the script's *residual* disturbances
    // (stragglers, load spikes) — only the reacted-to faults drop out —
    // so degraded-vs-replanned is measured under the scripted scenario,
    // not a healthy-fleet idealization
    let residual = residual_script(script);
    let residual_remapped = remap_after_loss(&residual, failed_device);

    let cfg = SimConfig::for_request(req);
    let healthy_sim = engine::simulate_req(g, req, healthy, schedule, samples, &cfg);

    let degraded = fallback_after_loss(g, req, healthy, failed_device)?;
    let degraded_sim =
        engine::simulate_with_events(g, req, &degraded, schedule, samples, &residual, &cfg);

    let replanned_sim = engine::simulate_with_events(
        g,
        &degraded_request,
        &replanned,
        schedule,
        samples,
        &residual_remapped,
        &cfg,
    );

    Ok(ReplanDemo {
        failed_device,
        failed_class,
        fail_time,
        healthy_tps: healthy_sim.steady_tps,
        degraded_tps: degraded_sim.steady_tps,
        replanned_tps: replanned_sim.steady_tps,
        degraded,
        replanned,
        degraded_request,
        disrupted_completed: disrupted.completed,
        disrupted_injected: disrupted.injected,
        disrupted_stall: disrupted.stall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::placement::{DeviceClass, Fleet};
    use crate::graph::Node;

    fn ev(spec: &str) -> EventScript {
        EventScript::parse(spec).unwrap()
    }

    #[test]
    fn remap_drops_lost_slot_and_shifts_higher_accs() {
        // two fails in one script: reacting to acc1 drops acc1's own
        // events and shifts acc2 → acc1; acc0 and CPUs stay put
        let s = ev("fail:acc1@t=3,fail:acc2@t=7,slow:acc0*0.5@t=4,slow:cpu0*0.9@t=5");
        let r = remap_after_loss(&s, Device::Acc(1));
        assert_eq!(r, ev("fail:acc1@t=7,slow:acc0*0.5@t=4,slow:cpu0*0.9@t=5"));
    }

    #[test]
    fn remap_drops_all_events_of_the_lost_device() {
        // fail + slow + recover on the same device all die with it
        let s = ev("fail:acc0@t=2,slow:acc0*0.5@t=1,recover:acc0@t=9,spike:+3@t=4");
        let r = remap_after_loss(&s, Device::Acc(0));
        assert_eq!(r, ev("spike:+3@t=4"));
    }

    #[test]
    fn remap_of_highest_dense_index_shifts_nothing() {
        // losing the highest accelerator slot: no survivor shifts
        let s = ev("fail:acc2@t=5,slow:acc1*0.5@t=6,recover:acc2@t=11");
        let r = remap_after_loss(&s, Device::Acc(2));
        assert_eq!(r, ev("slow:acc1*0.5@t=6"));
    }

    #[test]
    fn remap_of_cpu_loss_is_identity() {
        let s = ev("fail:cpu0@t=5,slow:acc0*0.5@t=6");
        assert_eq!(remap_after_loss(&s, Device::Cpu(0)), s);
    }

    #[test]
    fn residual_drops_only_fail_events() {
        // multi-fault script: both fails drop; slow/spike/recover survive
        let s = ev("fail:acc0@t=2,fail:acc1@t=3,slow:acc1*0.5@t=4,spike:+2@t=5,recover:acc0@t=8");
        let r = residual_script(&s);
        assert_eq!(r, ev("slow:acc1*0.5@t=4,spike:+2@t=5,recover:acc0@t=8"));
        assert!(residual_script(&ev("fail:acc0@t=1")).is_empty());
    }

    #[test]
    fn fallback_errors_on_accelerator_only_ops() {
        // op 1 has no finite CPU cost: failing its device over to the CPU
        // pool must be a PlaceError, not an infinite-objective placement
        let mut g = OpGraph::new();
        g.add_node(Node::new("a").cpu(10.0).acc(1.0).mem(1.0));
        g.add_node(Node::new("kernel").cpu(f64::INFINITY).acc(1.0).mem(1.0));
        g.add_node(Node::new("c").cpu(10.0).acc(1.0).mem(1.0));
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        let fleet = Fleet::new(vec![
            DeviceClass::acc("a", 2, f64::INFINITY),
            DeviceClass::cpu("cpu", 1),
        ]);
        let req = PlanRequest::new(fleet);
        let p = Placement::new(
            vec![Device::Acc(0), Device::Acc(1), Device::Acc(1)],
            0.0,
            "test",
        );
        assert!(fallback_after_loss(&g, &req, &p, Device::Acc(1)).is_err());
        // losing acc0 is fine: only finite-p_cpu ops fail over
        let ok = fallback_after_loss(&g, &req, &p, Device::Acc(0)).unwrap();
        assert_eq!(ok.assignment[0], Device::Cpu(0));
        assert!(ok.objective.is_finite());
    }
}

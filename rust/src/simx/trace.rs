//! Observability glue for the simulation engine (DESIGN.md §10): turn a
//! [`SimxResult`] into Chrome trace-event Gantt lanes and registry
//! counters, and a [`MonitorOutcome`]'s re-plan decisions into trace
//! instants.
//!
//! Simulated time is mapped as 1 cost unit = 1 ms = 1000 µs, on its own
//! trace `pid` so virtual-time lanes sit next to (not interleaved with)
//! the planner's wall-clock spans. Lanes are one per real device, then
//! one per directed device pair that actually carried a transfer.

use crate::coordinator::placement::Device;
use crate::obs::TraceEvent;
use crate::simx::controller::MonitorOutcome;
use crate::simx::engine::{SimxResult, Stall};
use crate::util::json::Json;

/// Simulated cost units → trace microseconds (1 unit = 1 ms).
const UNIT_US: f64 = 1000.0;

/// Real devices with at least one piece, in dense order (lane order).
fn lane_devices(res: &SimxResult) -> Vec<Device> {
    let mut devices: Vec<Device> = res.pieces.iter().map(|p| p.real_device).collect();
    devices.sort();
    devices.dedup();
    devices
}

/// Directed device pairs that carried at least one transfer, sorted.
fn lane_links(res: &SimxResult) -> Vec<(Device, Device)> {
    let mut links: Vec<(Device, Device)> = res
        .transfers
        .iter()
        .map(|&(_, a, b, _, _, _)| (res.pieces[a].real_device, res.pieces[b].real_device))
        .collect();
    links.sort();
    links.dedup();
    links
}

/// Convert a simulation run into per-device Gantt lanes (`'X'` events in
/// virtual time) plus per-directed-pair link lanes, all on `pid`.
/// Task/transfer detail (sample, piece, bytes) rides in event `args`.
pub fn trace_events(res: &SimxResult, pid: u32) -> Vec<TraceEvent> {
    let devices = lane_devices(res);
    let links = lane_links(res);
    let lane_of = |d: Device| devices.iter().position(|&x| x == d).unwrap_or(0) as u32;
    let link_lane_of = |a: Device, b: Device| {
        (devices.len() + links.iter().position(|&x| x == (a, b)).unwrap_or(0)) as u32
    };

    let mut out = Vec::with_capacity(res.trace.len() + res.transfers.len() + devices.len() + 2);
    out.push(TraceEvent::meta("process_name", "simx (virtual time)", pid, 0));
    for &d in &devices {
        out.push(TraceEvent::meta("thread_name", &d.to_string(), pid, lane_of(d)));
    }
    for &(a, b) in &links {
        out.push(TraceEvent::meta(
            "thread_name",
            &format!("link {a}->{b}"),
            pid,
            link_lane_of(a, b),
        ));
    }
    for &(s, j, is_bw, start, finish) in &res.trace {
        let d = res.pieces[j].real_device;
        let name = format!("s{s} {}", if is_bw { "bw" } else { "fw" });
        out.push(
            TraceEvent::complete(
                name,
                if is_bw { "simx.bw" } else { "simx.fw" },
                start * UNIT_US,
                (finish - start) * UNIT_US,
                pid,
                lane_of(d),
            )
            .arg("sample", Json::num(s as f64))
            .arg("piece", Json::num(j as f64))
            .arg("device", Json::str(d.to_string()))
            .arg("backward", Json::Bool(is_bw)),
        );
    }
    for &(s, a, b, bytes, start, finish) in &res.transfers {
        let (da, db) = (res.pieces[a].real_device, res.pieces[b].real_device);
        out.push(
            TraceEvent::complete(
                format!("s{s} {da}->{db}"),
                "simx.xfer",
                start * UNIT_US,
                (finish - start) * UNIT_US,
                pid,
                link_lane_of(da, db),
            )
            .arg("sample", Json::num(s as f64))
            .arg("fromPiece", Json::num(a as f64))
            .arg("toPiece", Json::num(b as f64))
            .arg("bytes", Json::num(bytes)),
        );
    }
    out
}

/// Record a run's utilization and link statistics into the obs registry:
/// per-device busy/idle totals (µs of virtual time) and a utilization
/// histogram, per-directed-pair transfer counts / bytes / busy time,
/// sample and event totals, and a stall counter by kind.
pub fn record_obs(res: &SimxResult) {
    let devices = lane_devices(res);
    let makespan = res.total.max(0.0);
    for &d in &devices {
        let busy: f64 = res
            .trace
            .iter()
            .filter(|&&(_, j, _, _, _)| res.pieces[j].real_device == d)
            .map(|&(_, _, _, start, finish)| finish - start)
            .sum();
        let idle = (makespan - busy).max(0.0);
        crate::obs::counter(&format!("simx_device_busy_us_total{{device=\"{d}\"}}"))
            .add((busy * UNIT_US) as u64);
        crate::obs::counter(&format!("simx_device_idle_us_total{{device=\"{d}\"}}"))
            .add((idle * UNIT_US) as u64);
        if makespan > 0.0 {
            crate::obs::histogram("simx_device_utilization").observe(busy / makespan);
        }
    }
    for &(a, b) in &lane_links(res) {
        let (mut n, mut bytes, mut busy) = (0u64, 0.0_f64, 0.0_f64);
        for &(_, fp, tp, sz, start, finish) in &res.transfers {
            if res.pieces[fp].real_device == a && res.pieces[tp].real_device == b {
                n += 1;
                bytes += sz;
                busy += finish - start;
            }
        }
        crate::obs::counter(&format!("simx_link_transfers_total{{link=\"{a}->{b}\"}}")).add(n);
        crate::obs::counter(&format!("simx_link_bytes_total{{link=\"{a}->{b}\"}}"))
            .add(bytes as u64);
        crate::obs::counter(&format!("simx_link_busy_us_total{{link=\"{a}->{b}\"}}"))
            .add((busy * UNIT_US) as u64);
    }
    crate::obs::counter("simx_samples_injected_total").add(res.injected as u64);
    crate::obs::counter("simx_samples_completed_total").add(res.completed as u64);
    crate::obs::counter("simx_events_processed_total").add(res.events_processed as u64);
    if let Some(stall) = res.stall {
        let kind = match stall {
            Stall::DeviceLost { .. } => "device_lost",
            Stall::MemoryDeadlock { .. } => "memory_deadlock",
        };
        crate::obs::counter(&format!("simx_stalls_total{{kind=\"{kind}\"}}")).inc();
    }
}

/// Convert a monitored run's controller decisions into `'i'` instants on
/// a dedicated lane of `pid` (decision times are in the trace's virtual
/// unit, same mapping as [`trace_events`]).
pub fn decision_events(out: &MonitorOutcome, pid: u32, tid: u32) -> Vec<TraceEvent> {
    let mut evs = Vec::with_capacity(out.decisions.len() + 1);
    evs.push(TraceEvent::meta("thread_name", "controller", pid, tid));
    for d in &out.decisions {
        let name = if d.accepted {
            format!("replan: {}", d.action)
        } else {
            format!("rejected: {}", d.action)
        };
        crate::obs::counter(&format!(
            "controller_decisions_total{{accepted=\"{}\"}}",
            d.accepted
        ))
        .inc();
        evs.push(
            TraceEvent::instant(name, "controller", d.t * UNIT_US, pid, tid)
                .arg("trigger", Json::str(d.trigger.clone()))
                .arg("action", Json::str(d.action.clone()))
                .arg("accepted", Json::Bool(d.accepted))
                .arg("reason", Json::str(d.reason.clone()))
                .arg(
                    "predictedBefore",
                    if d.predicted_before.is_finite() {
                        Json::num(d.predicted_before)
                    } else {
                        Json::Null
                    },
                )
                .arg(
                    "predictedAfter",
                    if d.predicted_after.is_finite() {
                        Json::num(d.predicted_after)
                    } else {
                        Json::Null
                    },
                )
                .arg("swapsSoFar", Json::num(d.swaps_so_far as f64)),
        );
    }
    evs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::dp;
    use crate::coordinator::placement::Scenario;
    use crate::graph::{Node, OpGraph};
    use crate::simx::engine::{simulate_req, Schedule, SimConfig};

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(10.0).acc(1.0).mem(1.0).comm(0.5));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn trace_events_cover_tasks_and_transfers() {
        let g = chain(6);
        let sc = Scenario::new(3, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let req = sc.to_request();
        let cfg = SimConfig { link_bandwidth: Some(1.0), ..SimConfig::default() };
        let res = simulate_req(&g, &req, &p, Schedule::Pipelined, 8, &cfg);
        assert!(!res.transfers.is_empty());
        let evs = trace_events(&res, 2);
        let tasks =
            evs.iter().filter(|e| e.cat == "simx.fw" || e.cat == "simx.bw").count();
        let xfers = evs.iter().filter(|e| e.cat == "simx.xfer").count();
        assert_eq!(tasks, res.trace.len());
        assert_eq!(xfers, res.transfers.len());
        // transfers carry their byte size in args
        let xfer = evs.iter().find(|e| e.cat == "simx.xfer").unwrap();
        assert!(xfer.args.iter().any(|(k, _)| k == "bytes"));
        // every event sits on a named lane
        let lanes: std::collections::BTreeSet<u32> = evs
            .iter()
            .filter(|e| e.ph == 'M' && e.name == "thread_name")
            .map(|e| e.tid)
            .collect();
        assert!(evs.iter().filter(|e| e.ph != 'M').all(|e| lanes.contains(&e.tid)));
    }

    #[test]
    fn record_obs_accumulates_device_and_link_series() {
        let g = chain(6);
        let sc = Scenario::new(3, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let req = sc.to_request();
        let cfg = SimConfig { link_bandwidth: Some(1.0), ..SimConfig::default() };
        let res = simulate_req(&g, &req, &p, Schedule::Pipelined, 8, &cfg);
        let busy_before =
            crate::obs::counter("simx_device_busy_us_total{device=\"acc0\"}").get();
        let injected_before = crate::obs::counter("simx_samples_injected_total").get();
        record_obs(&res);
        assert!(
            crate::obs::counter("simx_device_busy_us_total{device=\"acc0\"}").get()
                > busy_before
        );
        assert_eq!(
            crate::obs::counter("simx_samples_injected_total").get(),
            injected_before + res.injected as u64
        );
        let links = lane_links(&res);
        assert!(!links.is_empty());
        let (a, b) = links[0];
        assert!(
            crate::obs::counter(&format!("simx_link_transfers_total{{link=\"{a}->{b}\"}}"))
                .get()
                > 0
        );
    }
}

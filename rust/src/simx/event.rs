//! Scripted simulation events and their CLI grammar.
//!
//! A fleet simulation can be perturbed mid-stream by a script of typed
//! events — the scenarios the serving north-star has to survive:
//!
//! * `fail:acc0@t=5` — device loss: `acc0` finishes its running task and
//!   then stops accepting work (graceful drain; samples that still need
//!   the device stall, which is how the re-planning loop detects the hit).
//! * `slow:acc1*0.5@t=9` — straggler onset: from `t=9` every task
//!   *starting* on `acc1` runs at 0.5× its previous speed (factors
//!   compound multiplicatively across repeated `slow` events).
//! * `spike:+8@t=12` — load spike: 8 extra samples are injected at
//!   `t=12` on top of the base request stream.
//! * `recover:acc0@t=20` — the device comes back at nominal speed: a
//!   failed `acc0` accepts work again and any accumulated `slow` factors
//!   reset to 1.0 (transient faults; failure is no longer permanent).
//!
//! The grammar is `KIND:BODY@t=TIME`, comma-separated; `Display` re-emits
//! it and `parse ∘ Display` is the identity (mirroring
//! [`crate::coordinator::placement::Fleet::parse`]). Scripts ride on the
//! CLI (`simulate … --events "…"`) and on the optional `events` string of
//! the workload JSON schema ([`crate::workloads::json`]).

use crate::coordinator::placement::Device;

/// What a scripted event does when it fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScriptAction {
    /// Graceful device loss: running task completes, no new starts.
    Fail { device: Device },
    /// Straggler onset: the device's speed is multiplied by `factor`
    /// (`0 < factor`, usually `< 1`) for tasks starting after the event.
    Slow { device: Device, factor: f64 },
    /// Load spike: `count` extra samples enter the stream.
    Spike { count: usize },
    /// Recovery to nominal: a failed device accepts work again and its
    /// accumulated `slow` factors reset to 1.0. A no-op on a device that
    /// is already healthy and at full speed.
    Recover { device: Device },
}

/// One scripted event: an action at an absolute simulation time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScriptedEvent {
    pub at: f64,
    pub action: ScriptAction,
}

/// An ordered script of events (kept in declaration order; the engine's
/// event queue orders them by time).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EventScript {
    pub events: Vec<ScriptedEvent>,
}

impl EventScript {
    /// The empty script (a plain, undisturbed run).
    pub fn empty() -> EventScript {
        EventScript::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The earliest `fail:` event, if any.
    pub fn first_fail(&self) -> Option<(f64, Device)> {
        self.events
            .iter()
            .filter_map(|e| match e.action {
                ScriptAction::Fail { device } => Some((e.at, device)),
                _ => None,
            })
            .min_by(|a, b| a.0.total_cmp(&b.0))
    }

    /// The earliest `fail:` event naming an *accelerator* — the fault the
    /// re-planning loop ([`crate::simx::loop_`]) reacts to (CPU faults
    /// simulate fine but have no failover/decrement story).
    pub fn first_acc_fail(&self) -> Option<(f64, Device)> {
        self.events
            .iter()
            .filter_map(|e| match e.action {
                ScriptAction::Fail { device: d @ Device::Acc(_) } => Some((e.at, d)),
                _ => None,
            })
            .min_by(|a, b| a.0.total_cmp(&b.0))
    }

    /// Parse the comma-separated `KIND:BODY@t=TIME` grammar (see the
    /// module docs). Empty entries are skipped, so a trailing comma is
    /// harmless; an all-empty spec yields the empty script.
    pub fn parse(spec: &str) -> Result<EventScript, String> {
        let mut events = Vec::new();
        for raw in spec.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            let (head, time) = entry
                .rsplit_once("@t=")
                .ok_or_else(|| format!("missing '@t=TIME' in '{entry}'"))?;
            let at = time
                .parse::<f64>()
                .map_err(|_| format!("bad time in '{entry}'"))?;
            if !(at.is_finite() && at >= 0.0) {
                return Err(format!("time must be finite and >= 0 in '{entry}'"));
            }
            let (kind, body) = head
                .split_once(':')
                .ok_or_else(|| format!("missing 'KIND:' in '{entry}'"))?;
            let action = match kind {
                "fail" => ScriptAction::Fail { device: Device::parse(body)? },
                "slow" => {
                    let (dev, factor) = body
                        .split_once('*')
                        .ok_or_else(|| format!("slow needs 'DEVICE*FACTOR' in '{entry}'"))?;
                    let factor = factor
                        .parse::<f64>()
                        .map_err(|_| format!("bad slow factor in '{entry}'"))?;
                    if !(factor.is_finite() && factor > 0.0) {
                        return Err(format!("slow factor must be positive in '{entry}'"));
                    }
                    ScriptAction::Slow { device: Device::parse(dev)?, factor }
                }
                "spike" => {
                    let count = body
                        .strip_prefix('+')
                        .ok_or_else(|| format!("spike needs '+COUNT' in '{entry}'"))?;
                    let count = count
                        .parse::<usize>()
                        .map_err(|_| format!("bad spike count in '{entry}'"))?;
                    if count == 0 {
                        return Err(format!("spike count must be >= 1 in '{entry}'"));
                    }
                    ScriptAction::Spike { count }
                }
                "recover" => ScriptAction::Recover { device: Device::parse(body)? },
                other => return Err(format!("unknown event kind '{other}' in '{entry}'")),
            };
            events.push(ScriptedEvent { at, action });
        }
        Ok(EventScript { events })
    }
}

impl std::fmt::Display for EventScript {
    /// Emits the [`EventScript::parse`] grammar; `Display → parse`
    /// round-trips exactly.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            match e.action {
                ScriptAction::Fail { device } => write!(f, "fail:{device}")?,
                ScriptAction::Slow { device, factor } => write!(f, "slow:{device}*{factor}")?,
                ScriptAction::Spike { count } => write!(f, "spike:+{count}")?,
                ScriptAction::Recover { device } => write!(f, "recover:{device}")?,
            }
            write!(f, "@t={}", e.at)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_covers_all_kinds() {
        let s = EventScript::parse("fail:acc0@t=5,slow:acc1*0.5@t=9,spike:+8@t=12").unwrap();
        assert_eq!(s.events.len(), 3);
        assert_eq!(
            s.events[0],
            ScriptedEvent { at: 5.0, action: ScriptAction::Fail { device: Device::Acc(0) } }
        );
        assert_eq!(
            s.events[1],
            ScriptedEvent {
                at: 9.0,
                action: ScriptAction::Slow { device: Device::Acc(1), factor: 0.5 },
            }
        );
        assert_eq!(
            s.events[2],
            ScriptedEvent { at: 12.0, action: ScriptAction::Spike { count: 8 } }
        );
        assert_eq!(s.first_fail(), Some((5.0, Device::Acc(0))));
    }

    #[test]
    fn parse_recover_and_roundtrip() {
        let s = EventScript::parse("fail:acc1@t=4,recover:acc1@t=11").unwrap();
        assert_eq!(s.events.len(), 2);
        assert_eq!(
            s.events[1],
            ScriptedEvent { at: 11.0, action: ScriptAction::Recover { device: Device::Acc(1) } }
        );
        // recover events are not fails: the re-planning helpers ignore them
        assert_eq!(s.first_fail(), Some((4.0, Device::Acc(1))));
        let round = EventScript::parse(&s.to_string()).unwrap();
        assert_eq!(s, round, "display was: {s}");
        assert!(EventScript::parse("recover:gpu0@t=5").is_err());
        assert!(EventScript::parse("recover:acc0").is_err());
    }

    #[test]
    fn display_reparses() {
        for spec in [
            "fail:acc0@t=5,slow:acc1*0.5@t=9,spike:+8@t=12",
            "slow:cpu0*0.25@t=1.5",
            "fail:acc3@t=0",
            "recover:acc0@t=7.5,recover:cpu1@t=8",
            "fail:acc0@t=2,slow:acc0*0.5@t=3,recover:acc0@t=9,spike:+2@t=10",
            "",
        ] {
            let s = EventScript::parse(spec).unwrap();
            let round = EventScript::parse(&s.to_string()).unwrap();
            assert_eq!(s, round, "display was: {s}");
        }
    }

    #[test]
    fn first_fail_picks_earliest() {
        let s = EventScript::parse("fail:acc1@t=9,fail:acc0@t=5").unwrap();
        assert_eq!(s.first_fail(), Some((5.0, Device::Acc(0))));
        assert_eq!(EventScript::parse("spike:+2@t=1").unwrap().first_fail(), None);
        assert!(EventScript::empty().is_empty());
        // the accelerator filter skips earlier CPU faults
        let mixed = EventScript::parse("fail:cpu0@t=1,fail:acc2@t=7").unwrap();
        assert_eq!(mixed.first_fail(), Some((1.0, Device::Cpu(0))));
        assert_eq!(mixed.first_acc_fail(), Some((7.0, Device::Acc(2))));
        assert_eq!(EventScript::parse("fail:cpu0@t=1").unwrap().first_acc_fail(), None);
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        for bad in [
            "fail:acc0",            // no time
            "fail:gpu0@t=5",        // unknown device
            "slow:acc0@t=5",        // missing factor
            "slow:acc0*0@t=5",      // non-positive factor
            "slow:acc0*x@t=5",      // bad factor
            "spike:8@t=5",          // missing '+'
            "spike:+0@t=5",         // zero count
            "melt:acc0@t=5",        // unknown kind
            "fail:acc0@t=-1",       // negative time
            "fail:acc0@t=oops",     // bad time
        ] {
            assert!(EventScript::parse(bad).is_err(), "'{bad}' should not parse");
        }
        // trailing comma and whitespace are fine
        let ok = EventScript::parse(" fail:acc0@t=2 , ").unwrap();
        assert_eq!(ok.events.len(), 1);
    }
}

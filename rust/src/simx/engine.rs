//! The discrete-event engine: a binary-heap queue of typed events driving
//! fleet device and link resources.
//!
//! A placement is compiled into *virtual devices* ([`Piece`]s, §5.2): each
//! real device's node set is decomposed into contiguous chunks whose costs
//! split the device's fleet-aware load
//! ([`crate::algos::objective::DeviceLoads::of_req`] — per-class speeds
//! scale compute, the request's comm model folds boundary transfer time
//! into the owning device's busy time, exactly what the max-load
//! objective predicts). Each
//! `(sample, piece)` is a task; tasks run under device exclusivity and
//! dependency order, with the [`Schedule`] policy picking among ready
//! tasks. Ready tasks wait in per-device forward/backward priority queues
//! ([`ReadyQueues`]): each start inspects only the admissible queue tops,
//! so dispatch costs `O(log)` per task instead of a full ready-set scan.
//!
//! The engine advances a clock through a binary heap of typed events:
//!
//! * `ComputeDone` — a task finished; frees its device, unblocks
//!   dependents (directly, or through a link transfer), releases
//!   activation memory when the sample's last task on the device is done.
//! * `TransferDone` — a cross-device tensor arrived; with
//!   [`SimConfig::link_bandwidth`] set, macro-dependency hand-offs are
//!   delayed by `size / bw` and serialize per directed device pair
//!   (replacing the legacy zero-cost hand-off).
//! * `DeviceFail` / `DeviceSlow` / `DeviceRecover` — scripted fault /
//!   straggler / recovery injection ([`crate::simx::event::EventScript`]).
//! * `SampleInject` — request arrivals: the base stream at `t = 0` plus
//!   scripted load spikes.
//!
//! Memory is accounted live per device: a `(1 - act_frac)` share of the
//! placed nodes' memory is static weights, and each *in-flight* sample
//! (admitted when its first task on the device starts, released when its
//! last one finishes — for training, that is the backward) holds an
//! `act_frac` share of activations. With [`SimConfig::enforce_memory`]
//! set, task admission blocks on the per-class cap, which makes the
//! GPipe-vs-1F1B memory gap observable and lets the engine *reject* an
//! infeasible schedule: a blocked-forever run drains the queue with
//! samples outstanding and reports [`Stall::MemoryDeadlock`].

use crate::algos::objective::DeviceLoads;
use crate::coordinator::placement::{Device, Placement, PlanRequest};
use crate::graph::{contiguity, NodeKind, OpGraph};
use crate::simx::event::{EventScript, ScriptAction};
use crate::util::bitset::BitSet;
use std::collections::{BTreeMap, BinaryHeap};

/// Pipeline schedule policy (Figs. 2, 5, 7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// One sample at a time (Figs. 2a/2b).
    SingleStream,
    /// Inference pipelining (Fig. 5a).
    Pipelined,
    /// Backward-priority training (Fig. 7b).
    PipeDream1F1B,
    /// All forwards, then all backwards (Fig. 7a).
    GPipe,
}

impl Schedule {
    pub const ALL: [Schedule; 4] = [
        Schedule::SingleStream,
        Schedule::Pipelined,
        Schedule::PipeDream1F1B,
        Schedule::GPipe,
    ];

    /// Canonical CLI name (round-trips through [`Schedule::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Schedule::SingleStream => "single-stream",
            Schedule::Pipelined => "pipelined",
            Schedule::PipeDream1F1B => "1f1b",
            Schedule::GPipe => "gpipe",
        }
    }

    pub fn parse(s: &str) -> Option<Schedule> {
        let s = s.to_ascii_lowercase();
        Some(match s.as_str() {
            "ss" => Schedule::SingleStream,
            "pipedream" => Schedule::PipeDream1F1B,
            _ => return Schedule::ALL.into_iter().find(|x| x.name() == s),
        })
    }

    /// The schedule the CLI replays by default: 1F1B for training graphs,
    /// pipelined inference otherwise.
    pub fn default_for(training: bool) -> Schedule {
        if training {
            Schedule::PipeDream1F1B
        } else {
            Schedule::Pipelined
        }
    }
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One virtual device: a contiguous piece of a real device's set.
#[derive(Clone, Debug)]
pub struct Piece {
    pub real_device: Device,
    pub nodes: BitSet,
    /// forward-pass share of the piece's per-sample load
    pub fw_cost: f64,
    /// backward-pass share (0 for inference graphs)
    pub bw_cost: f64,
    /// pieces that must process a sample before this one (macro deps)
    pub deps: Vec<usize>,
}

/// Engine configuration. The default replays the §3 cost model exactly —
/// instantaneous macro hand-offs, no activation accounting — which is the
/// regime the max-load objective predicts (and the legacy
/// `pipeline::sim` adapter's contract).
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// `None` = instantaneous macro-dependency hand-off (the §3 model:
    /// boundary transfer time is already inside the device loads).
    /// `Some(bw)` = cross-device tensors additionally traverse an
    /// exclusive per-directed-device-pair link at `size / bw` — the
    /// fleet's interconnect as a contended resource.
    pub link_bandwidth: Option<f64>,
    /// Fraction of each node's `mem` that is per-sample activation state
    /// (the rest is static weights). 0.0 disables activation accounting.
    pub act_frac: f64,
    /// Gate task admission on per-class memory caps (weights + live
    /// activations); a run blocked forever reports
    /// [`Stall::MemoryDeadlock`].
    pub enforce_memory: bool,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig { link_bandwidth: None, act_frac: 0.0, enforce_memory: false }
    }
}

impl SimConfig {
    /// The fleet-replay configuration: bandwidth-delayed link transfers at
    /// the request's interconnect bandwidth, no activation gating.
    pub fn for_request(req: &PlanRequest) -> SimConfig {
        SimConfig { link_bandwidth: Some(req.fleet.bandwidth), ..SimConfig::default() }
    }

    /// Activation-accounting configuration: `act_frac` of node memory is
    /// per-sample state and admission is gated on the per-class caps.
    pub fn with_memory_model(act_frac: f64) -> SimConfig {
        SimConfig { act_frac, enforce_memory: true, ..SimConfig::default() }
    }
}

/// Why a run failed to complete every injected sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Stall {
    /// A failed device still owned work; samples behind it can never
    /// finish (the signal the re-planning loop reacts to).
    DeviceLost { device: Device, pending_samples: usize },
    /// Memory admission blocked every remaining task — the schedule is
    /// infeasible under the per-class caps (e.g. GPipe holding all
    /// minibatch activations at once).
    MemoryDeadlock { device: Device, pending_samples: usize },
}

impl std::fmt::Display for Stall {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Stall::DeviceLost { device, pending_samples } => {
                write!(f, "{device} lost with {pending_samples} samples outstanding")
            }
            Stall::MemoryDeadlock { device, pending_samples } => write!(
                f,
                "memory deadlock on {device} with {pending_samples} samples outstanding"
            ),
        }
    }
}

/// Simulation result.
#[derive(Clone, Debug)]
pub struct SimxResult {
    /// Completion time per injected sample (`NAN` if it never finished).
    pub sample_done: Vec<f64>,
    /// Measured steady-state time-per-sample (slope of the last half of
    /// the *completed* samples, sorted by finish).
    pub steady_tps: f64,
    /// Makespan (last task finish).
    pub total: f64,
    /// Per-task `(sample, piece, is_backward, start, finish)`.
    pub trace: Vec<(usize, usize, bool, f64, f64)>,
    /// Per-transfer `(sample, from_piece, to_piece, bytes, start, finish)`
    /// (empty without [`SimConfig::link_bandwidth`]).
    pub transfers: Vec<(usize, usize, usize, f64, f64, f64)>,
    pub pieces: Vec<Piece>,
    /// Samples injected (base stream + spikes).
    pub injected: usize,
    /// Samples fully completed.
    pub completed: usize,
    /// Peak memory occupancy per dense device (weights + activations).
    pub mem_peak: Vec<f64>,
    /// Heap events processed (the engine-throughput denominator).
    pub events_processed: usize,
    /// `Some` when not every injected sample completed.
    pub stall: Option<Stall>,
}

impl SimxResult {
    /// `Err` when the run stalled (device loss / memory deadlock).
    pub fn ok(&self) -> Result<(), Stall> {
        match self.stall {
            Some(s) => Err(s),
            None => Ok(()),
        }
    }

    /// ASCII timeline (Figs. 2/5/7 style): one row per real device, cells
    /// hold the sample id being processed (uppercase = backward).
    pub fn render_timeline(&self, width: usize) -> String {
        render_trace_timeline(&self.trace, &self.pieces, self.total, width)
    }
}

/// The one timeline renderer behind [`SimxResult::render_timeline`] and
/// the legacy `pipeline::sim::render_timeline`.
pub fn render_trace_timeline(
    trace: &[(usize, usize, bool, f64, f64)],
    pieces: &[Piece],
    total: f64,
    width: usize,
) -> String {
    if width == 0 {
        return String::new();
    }
    let mut devices: Vec<Device> = pieces.iter().map(|p| p.real_device).collect();
    devices.sort();
    devices.dedup();
    let total = total.max(1e-9);
    let mut out = String::new();
    for &d in &devices {
        let mut row = vec![' '; width];
        for &(s, j, is_bw, start, finish) in trace {
            if pieces[j].real_device != d {
                continue;
            }
            // a ≤ width-1 keeps the a+1 ≤ width clamp bound valid even for
            // zero-cost tasks landing exactly at `total`
            let a = (((start / total) * width as f64) as usize).min(width - 1);
            let b = (((finish / total) * width as f64) as usize).clamp(a + 1, width);
            let c = if is_bw {
                (b'A' + (s % 26) as u8) as char
            } else {
                char::from_digit((s % 10) as u32, 10).unwrap()
            };
            for cell in row.iter_mut().take(b).skip(a) {
                *cell = c;
            }
        }
        out.push_str(&format!("{d:>6} |{}|\n", row.iter().collect::<String>()));
    }
    out
}

/// Decompose a placement into virtual devices with fleet-aware per-piece
/// costs: the piece costs split the device's `DeviceLoads::of_req` load
/// (per-class speed-scaled compute, comm per the request's model)
/// proportionally to compute, so the total per-device cost equals the
/// objective's device load (footnote 5). On a uniform fleet this is
/// bitwise the legacy `pipeline::sim::build_pieces` decomposition.
pub fn build_pieces_req(g: &OpGraph, req: &PlanRequest, p: &Placement) -> Vec<Piece> {
    let n = g.n();
    let loads = DeviceLoads::of_req(g, req, p);
    let (k, l) = (req.fleet.k(), req.fleet.l());
    let mut pieces: Vec<Piece> = Vec::new();
    let mut piece_of = vec![usize::MAX; n];

    let mut devices: Vec<Device> = (0..k).map(Device::Acc).collect();
    devices.extend((0..l.max(1)).map(Device::Cpu));
    for d in devices {
        let all = p.set_of(d, n);
        if all.is_empty() {
            continue;
        }
        let idx = d.index(k);
        for dir in [NodeKind::Forward, NodeKind::Backward] {
            let set = BitSet::from_iter(n, all.iter().filter(|&v| g.nodes[v].kind == dir));
            if set.is_empty() {
                continue;
            }
            let dir_load = match dir {
                NodeKind::Forward => loads.fw[idx].total_req(req),
                NodeKind::Backward => loads.bw[idx].total_req(req),
            };
            let dir_compute: f64 = set
                .iter()
                .map(|v| if d.is_acc() { g.nodes[v].p_acc } else { g.nodes[v].p_cpu })
                .sum();
            let chunks = contiguity::virtual_device_split(g, &set);
            let num_chunks = chunks.len();
            for chunk in chunks {
                let chunk_compute: f64 = chunk
                    .iter()
                    .map(|v| if d.is_acc() { g.nodes[v].p_acc } else { g.nodes[v].p_cpu })
                    .sum();
                // proportional share of the device-direction load
                let share = if dir_compute > 0.0 {
                    dir_load * chunk_compute / dir_compute
                } else {
                    dir_load / num_chunks as f64
                };
                let id = pieces.len();
                for v in chunk.iter() {
                    piece_of[v] = id;
                }
                pieces.push(Piece {
                    real_device: d,
                    nodes: chunk,
                    fw_cost: if dir == NodeKind::Forward { share } else { 0.0 },
                    bw_cost: if dir == NodeKind::Backward { share } else { 0.0 },
                    deps: Vec::new(),
                });
            }
        }
    }
    // macro dependencies
    let mut seen = std::collections::BTreeSet::new();
    for (u, v) in g.edges() {
        let (a, b) = (piece_of[u], piece_of[v]);
        if a != b && a != usize::MAX && b != usize::MAX && seen.insert((a, b)) {
            pieces[b].deps.push(a);
        }
    }
    pieces
}

// ---------------------------------------------------------------------------
// The event queue
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
enum Ev {
    SampleInject { count: usize },
    ComputeDone { sample: usize, piece: usize },
    TransferDone { sample: usize, to_piece: usize },
    DeviceFail { dev: usize },
    DeviceSlow { dev: usize, factor: f64 },
    DeviceRecover { dev: usize },
}

/// Heap entry ordered so `BinaryHeap` (a max-heap) pops the *earliest*
/// time first, FIFO among equal times (by push sequence).
struct QEvent {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for QEvent {
    fn eq(&self, other: &Self) -> bool {
        self.t.total_cmp(&other.t).is_eq() && self.seq == other.seq
    }
}

impl Eq for QEvent {}

impl PartialOrd for QEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: smallest (t, seq) is the heap maximum
        other.t.total_cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct DevState {
    alive: bool,
    busy_until: f64,
    /// Multiplicative straggler scale (1.0 = nominal; `slow` events
    /// compound onto it; applies to tasks *starting* after the event).
    slow_scale: f64,
    cap: f64,
    /// Static weight occupancy: `(1 - act_frac) · Σ mem` of placed nodes.
    weights: f64,
    /// Activation occupancy per in-flight sample: `act_frac · Σ mem`.
    act: f64,
    resident: usize,
    mem_peak: f64,
}

struct SampleState {
    rem_deps: Vec<usize>,
    done_t: Vec<f64>,
    tasks_left: usize,
    /// Injection wave (0 = base stream, 1.. = spikes, in firing order).
    /// GPipe's barrier is per wave: a wave's backwards wait for the
    /// forwards of its own and all earlier waves, never for later spikes.
    wave: usize,
    /// Unfinished tasks per dense device (activation release bookkeeping).
    rem_on_dev: Vec<usize>,
    resident_on: Vec<bool>,
}

/// A ready-to-run task, prioritized at push time: the schedule priority
/// depends only on the sample index and the piece's forward/backward kind,
/// neither of which changes while the task waits.
#[derive(Clone, Copy, PartialEq, Eq)]
struct ReadyTask {
    prio: i64,
    s: usize,
    j: usize,
}

impl PartialOrd for ReadyTask {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ReadyTask {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // heap maximum = highest priority, ties to the smallest (s, j) —
        // the dispatcher's historical global tie-break
        self.prio
            .cmp(&other.prio)
            .then_with(|| other.s.cmp(&self.s))
            .then_with(|| other.j.cmp(&self.j))
    }
}

/// Per-device ready queues: each dense device keeps its forward and
/// backward candidates in separate max-heaps ordered like [`ReadyTask`].
///
/// This replaces the historical flat ready `Vec` the dispatcher re-scanned
/// wholly for every task start (`O(events · samples)` overall): a start
/// now examines only the admissible *tops* of `2 · nd` heaps and pays one
/// `O(log)` pop, and a busy or dead device is skipped in `O(1)` instead of
/// once per queued task. Splitting forwards from backwards is what makes
/// top-inspection sound: within each half, heap order is nonincreasing in
/// priority and — for every schedule formula — nondecreasing in sample
/// index, so the schedule-level blocks (SingleStream's in-order admission,
/// GPipe's per-wave barrier) are monotone along the heap and a blocked top
/// proves the whole half blocked. The per-sample memory-admission check is
/// the one non-monotone rule; the dispatcher handles it by deferring
/// blocked tops aside and restoring them after each pick.
struct ReadyQueues {
    /// `[device][0 = forward, 1 = backward]`.
    queues: Vec<[BinaryHeap<ReadyTask>; 2]>,
    schedule: Schedule,
}

impl ReadyQueues {
    fn new(nd: usize, schedule: Schedule) -> ReadyQueues {
        ReadyQueues {
            queues: (0..nd).map(|_| [BinaryHeap::new(), BinaryHeap::new()]).collect(),
            schedule,
        }
    }

    fn push(&mut self, s: usize, j: usize, dev: usize, is_bw: bool) {
        let prio: i64 = match self.schedule {
            Schedule::PipeDream1F1B => (if is_bw { 1_000_000 } else { 0 }) - s as i64,
            _ => -(s as i64) - if is_bw { 0 } else { 1 },
        };
        self.queues[dev][is_bw as usize].push(ReadyTask { prio, s, j });
    }

    /// Every queued `(sample, piece)`, devices in index order (stall
    /// diagnostics only — order within a device's heap is unspecified).
    fn iter(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.queues
            .iter()
            .flat_map(|q| q[0].iter().chain(q[1].iter()))
            .map(|t| (t.s, t.j))
    }

    /// Lowest-indexed device with queued work, if any.
    fn first_device(&self) -> Option<usize> {
        self.queues.iter().position(|q| !q[0].is_empty() || !q[1].is_empty())
    }
}

/// Run the engine with no scripted events (see [`simulate_with_events`]).
pub fn simulate_req(
    g: &OpGraph,
    req: &PlanRequest,
    p: &Placement,
    schedule: Schedule,
    num_samples: usize,
    cfg: &SimConfig,
) -> SimxResult {
    simulate_with_events(g, req, p, schedule, num_samples, &EventScript::empty(), cfg)
}

/// Run `num_samples` base samples (injected at `t = 0`) plus the script's
/// spikes through the placement's pipeline under `schedule`, perturbed by
/// the script's faults and stragglers. Script events naming devices
/// outside the fleet are ignored (callers validate ranges up front).
pub fn simulate_with_events(
    g: &OpGraph,
    req: &PlanRequest,
    p: &Placement,
    schedule: Schedule,
    num_samples: usize,
    script: &EventScript,
    cfg: &SimConfig,
) -> SimxResult {
    let pieces = build_pieces_req(g, req, p);
    let np = pieces.len();
    let k = req.fleet.k();
    let nd = k + req.fleet.l().max(1);
    let dense = req.fleet.dense_view();

    // per-device static memory from the placement
    let mut mem_total = vec![0.0_f64; nd];
    for v in 0..g.n() {
        mem_total[p.assignment[v].index(k)] += g.nodes[v].mem;
    }
    let mut devs: Vec<DevState> = (0..nd)
        .map(|d| DevState {
            alive: true,
            busy_until: 0.0,
            slow_scale: 1.0,
            // the phantom CPU slot of an ℓ = 0 fleet is uncapped
            cap: dense.get(d).map_or(f64::INFINITY, |x| x.mem_cap),
            weights: (1.0 - cfg.act_frac) * mem_total[d],
            act: cfg.act_frac * mem_total[d],
            resident: 0,
            mem_peak: (1.0 - cfg.act_frac) * mem_total[d],
        })
        .collect();

    let piece_dev: Vec<usize> = pieces.iter().map(|x| x.real_device.index(k)).collect();
    let mut pieces_on_dev = vec![0usize; nd];
    for &d in &piece_dev {
        pieces_on_dev[d] += 1;
    }
    // dependents[j] = pieces depending on j; transfer sizes per macro edge
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); np];
    let mut xfer_size: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    for (b, piece) in pieces.iter().enumerate() {
        for &a in &piece.deps {
            dependents[a].push(b);
        }
    }
    if cfg.link_bandwidth.is_some() {
        // node -> piece map (one O(n) pass over the decomposition)
        let mut piece_of = vec![usize::MAX; g.n()];
        for (j, piece) in pieces.iter().enumerate() {
            for v in piece.nodes.iter() {
                piece_of[v] = j;
            }
        }
        // tensor size per macro edge: each producer u ships once per
        // *consumer device* (the objective's CommIn dedup — a second
        // piece on the same device reads the already-arrived tensor), so
        // u's comm lands on the first macro edge toward that device in
        // deterministic edge order
        let mut shipped: std::collections::BTreeSet<(usize, usize)> = Default::default();
        for (u, v) in g.edges() {
            let (a, b) = (piece_of[u], piece_of[v]);
            if a == usize::MAX || b == usize::MAX || a == b || piece_dev[a] == piece_dev[b]
            {
                continue;
            }
            if shipped.insert((u, piece_dev[b])) {
                *xfer_size.entry((a, b)).or_insert(0.0) += g.nodes[u].comm;
            }
        }
    }

    // --- event queue -------------------------------------------------------
    let mut heap: BinaryHeap<QEvent> = BinaryHeap::new();
    let mut seq = 0u64;
    let push = |heap: &mut BinaryHeap<QEvent>, seq: &mut u64, t: f64, ev: Ev| {
        heap.push(QEvent { t, seq: *seq, ev });
        *seq += 1;
    };
    if num_samples > 0 {
        push(&mut heap, &mut seq, 0.0, Ev::SampleInject { count: num_samples });
    }
    // a device is addressable iff its dense slot exists for its own kind
    // (an out-of-range accelerator must NOT alias onto a CPU slot)
    let dense_of = |device: Device| -> Option<usize> {
        match device {
            Device::Acc(i) if i < k => Some(i),
            Device::Cpu(j) if k + j < nd => Some(k + j),
            _ => None,
        }
    };
    for e in &script.events {
        let ev = match e.action {
            ScriptAction::Fail { device } => match dense_of(device) {
                Some(d) => Ev::DeviceFail { dev: d },
                None => continue,
            },
            ScriptAction::Slow { device, factor } => match dense_of(device) {
                Some(d) => Ev::DeviceSlow { dev: d, factor },
                None => continue,
            },
            ScriptAction::Spike { count } => Ev::SampleInject { count },
            ScriptAction::Recover { device } => match dense_of(device) {
                Some(d) => Ev::DeviceRecover { dev: d },
                None => continue,
            },
        };
        push(&mut heap, &mut seq, e.at, ev);
    }

    // --- simulation state --------------------------------------------------
    let mut samples: Vec<SampleState> = Vec::new();
    let mut sample_done: Vec<f64> = Vec::new();
    let mut ready = ReadyQueues::new(nd, schedule);
    let mut trace: Vec<(usize, usize, bool, f64, f64)> = Vec::new();
    let mut transfers: Vec<(usize, usize, usize, f64, f64, f64)> = Vec::new();
    let mut link_free: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    // unfinished forward tasks per injection wave (GPipe barrier state)
    let mut fw_left_per_wave: Vec<usize> = Vec::new();
    let fw_pieces = pieces.iter().filter(|x| x.fw_cost > 0.0).count();
    let piece_is_bw: Vec<bool> = pieces.iter().map(|x| x.bw_cost > 0.0).collect();
    let mut completed = 0usize;
    let mut events_processed = 0usize;

    let inject = |count: usize,
                  samples: &mut Vec<SampleState>,
                  sample_done: &mut Vec<f64>,
                  ready: &mut ReadyQueues,
                  fw_left_per_wave: &mut Vec<usize>| {
        let wave = fw_left_per_wave.len();
        fw_left_per_wave.push(count * fw_pieces);
        for _ in 0..count {
            let s = samples.len();
            samples.push(SampleState {
                rem_deps: pieces.iter().map(|x| x.deps.len()).collect(),
                done_t: vec![f64::NAN; np],
                tasks_left: np,
                wave,
                rem_on_dev: pieces_on_dev.clone(),
                resident_on: vec![false; nd],
            });
            sample_done.push(f64::NAN);
            for (j, piece) in pieces.iter().enumerate() {
                if piece.deps.is_empty() {
                    ready.push(s, j, piece_dev[j], piece_is_bw[j]);
                }
            }
        }
    };

    while let Some(first) = heap.pop() {
        let t = first.t;
        let mut batch = vec![first];
        while heap.peek().is_some_and(|e| e.t.total_cmp(&t).is_eq()) {
            batch.push(heap.pop().expect("peeked"));
        }
        for qe in batch {
            events_processed += 1;
            match qe.ev {
                Ev::SampleInject { count } => {
                    inject(
                        count,
                        &mut samples,
                        &mut sample_done,
                        &mut ready,
                        &mut fw_left_per_wave,
                    );
                }
                Ev::DeviceFail { dev } => devs[dev].alive = false,
                Ev::DeviceSlow { dev, factor } => devs[dev].slow_scale *= factor,
                // recovery to nominal: accept work again, straggler scale
                // resets (all script events sit in the heap from the
                // start, so a recover wakes the loop even after every
                // in-flight task drained on a dead fleet)
                Ev::DeviceRecover { dev } => {
                    devs[dev].alive = true;
                    devs[dev].slow_scale = 1.0;
                }
                Ev::TransferDone { sample, to_piece } => {
                    let st = &mut samples[sample];
                    st.rem_deps[to_piece] -= 1;
                    if st.rem_deps[to_piece] == 0 {
                        ready.push(
                            sample,
                            to_piece,
                            piece_dev[to_piece],
                            piece_is_bw[to_piece],
                        );
                    }
                }
                Ev::ComputeDone { sample, piece } => {
                    let d = piece_dev[piece];
                    let is_fw = pieces[piece].fw_cost > 0.0;
                    {
                        let st = &mut samples[sample];
                        st.done_t[piece] = t;
                        st.tasks_left -= 1;
                        st.rem_on_dev[d] -= 1;
                        if st.rem_on_dev[d] == 0 && st.resident_on[d] {
                            st.resident_on[d] = false;
                            devs[d].resident -= 1;
                        }
                        if st.tasks_left == 0 {
                            sample_done[sample] = t;
                            completed += 1;
                        }
                    }
                    if is_fw {
                        fw_left_per_wave[samples[sample].wave] -= 1;
                    }
                    for &b in &dependents[piece] {
                        let same_dev = piece_dev[b] == d;
                        match cfg.link_bandwidth {
                            Some(bw) if !same_dev => {
                                let size =
                                    xfer_size.get(&(piece, b)).copied().unwrap_or(0.0);
                                let key = (d, piece_dev[b]);
                                let free = link_free.get(&key).copied().unwrap_or(0.0);
                                let start = free.max(t);
                                // directed-pair link: the topology scales
                                // this pair's effective rate and adds its
                                // latency (identity without a topology:
                                // `+ 0.0 + size·1.0/bw`, bitwise the old
                                // `size/bw` for non-negative sizes)
                                let finish = start
                                    + req.fleet.pair_latency(d, piece_dev[b])
                                    + size * req.fleet.pair_slowdown(d, piece_dev[b]) / bw;
                                link_free.insert(key, finish);
                                transfers.push((sample, piece, b, size, start, finish));
                                push(
                                    &mut heap,
                                    &mut seq,
                                    finish,
                                    Ev::TransferDone { sample, to_piece: b },
                                );
                            }
                            _ => {
                                let st = &mut samples[sample];
                                st.rem_deps[b] -= 1;
                                if st.rem_deps[b] == 0 {
                                    ready.push(sample, b, piece_dev[b], piece_is_bw[b]);
                                }
                            }
                        }
                    }
                }
            }
        }

        // --- dispatcher: start every task admissible at time t ------------
        loop {
            let mut best: Option<(i64, usize, usize, usize, usize)> = None; // (prio, s, j, d, half)
            // memory-blocked tops set aside this round; restored after the
            // pick (a start changes residency, so they are re-judged)
            let mut deferred: Vec<(usize, usize, ReadyTask)> = Vec::new();
            for (d, dev) in devs.iter().enumerate() {
                if !dev.alive || dev.busy_until > t {
                    continue; // one check retires the whole device
                }
                for half in 0..2 {
                    let top = loop {
                        let Some(&top) = ready.queues[d][half].peek() else {
                            break None;
                        };
                        // SingleStream admits samples strictly in order, so
                        // samples complete as a prefix: a top whose
                        // predecessor is unfinished proves every larger-s
                        // entry behind it blocked too
                        if schedule == Schedule::SingleStream
                            && top.s > 0
                            && samples[top.s - 1].tasks_left > 0
                        {
                            break None;
                        }
                        // GPipe barrier, per injection wave: a backward
                        // waits for every forward of its own and all
                        // earlier waves; a later spike's forwards never
                        // retro-block it. Waves are nondecreasing in s and
                        // a blocked wave blocks all later ones, so a
                        // blocked top proves the whole backward half
                        // blocked.
                        if half == 1
                            && schedule == Schedule::GPipe
                            && fw_left_per_wave[..=samples[top.s].wave]
                                .iter()
                                .any(|&x| x > 0)
                        {
                            break None;
                        }
                        // residency is per-sample, so this check is not
                        // monotone along the heap: defer the blocked top
                        // and look at the next entry
                        if cfg.enforce_memory && !samples[top.s].resident_on[d] {
                            let need = dev.weights + (dev.resident + 1) as f64 * dev.act;
                            if need > dev.cap * (1.0 + 1e-9) {
                                let task =
                                    ready.queues[d][half].pop().expect("peeked above");
                                deferred.push((d, half, task));
                                continue;
                            }
                        }
                        break Some(top);
                    };
                    if let Some(top) = top {
                        let better = match best {
                            None => true,
                            Some((bp, bs, bj, _, _)) => {
                                top.prio > bp || (top.prio == bp && (top.s, top.j) < (bs, bj))
                            }
                        };
                        if better {
                            best = Some((top.prio, top.s, top.j, d, half));
                        }
                    }
                }
            }
            let Some((_, s, j, bd, bh)) = best else {
                for (d, half, task) in deferred {
                    ready.queues[d][half].push(task);
                }
                break;
            };
            // the winner is its half's top (its deferred entries are still
            // set aside): pop it, then restore the deferred tasks
            let won = ready.queues[bd][bh].pop().expect("winner peeked above");
            debug_assert_eq!((won.s, won.j), (s, j));
            for (d, half, task) in deferred {
                ready.queues[d][half].push(task);
            }
            let d = piece_dev[j];
            if !samples[s].resident_on[d] {
                samples[s].resident_on[d] = true;
                devs[d].resident += 1;
                let occ = devs[d].weights + devs[d].resident as f64 * devs[d].act;
                if occ > devs[d].mem_peak {
                    devs[d].mem_peak = occ;
                }
            }
            let cost = pieces[j].fw_cost + pieces[j].bw_cost;
            let finish = t + cost / devs[d].slow_scale;
            devs[d].busy_until = finish;
            let is_bw = pieces[j].bw_cost > 0.0;
            trace.push((s, j, is_bw, t, finish));
            push(&mut heap, &mut seq, finish, Ev::ComputeDone { sample: s, piece: j });
        }
    }

    // --- wrap-up -----------------------------------------------------------
    let injected = samples.len();
    let total = trace
        .iter()
        .map(|&(_, _, _, _, f)| f)
        .fold(0.0_f64, f64::max);
    let stall = if completed < injected {
        let pending_samples = injected - completed;
        // pending work on a dead device → device loss is the root cause
        let dead_with_work = (0..nd).find(|&d| {
            !devs[d].alive
                && samples.iter().any(|st| {
                    st.tasks_left > 0
                        && (0..np).any(|j| piece_dev[j] == d && st.done_t[j].is_nan())
                })
        });
        match dead_with_work {
            Some(d) => Some(Stall::DeviceLost {
                device: Device::from_index(d, k),
                pending_samples,
            }),
            None => {
                // name a device whose memory admission actually blocks a
                // ready task (barrier-blocked entries are symptoms, not
                // the cause); fall back to any ready entry's device
                let mem_blocked = ready.iter().find_map(|(s, j)| {
                    let d = piece_dev[j];
                    let dev = &devs[d];
                    let over = dev.weights + (dev.resident + 1) as f64 * dev.act
                        > dev.cap * (1.0 + 1e-9);
                    (cfg.enforce_memory && !samples[s].resident_on[d] && over).then_some(d)
                });
                let blocked = mem_blocked.or_else(|| ready.first_device()).unwrap_or(0);
                Some(Stall::MemoryDeadlock {
                    device: Device::from_index(blocked, k),
                    pending_samples,
                })
            }
        }
    } else {
        None
    };

    let mut finish_sorted: Vec<f64> =
        sample_done.iter().copied().filter(|x| x.is_finite()).collect();
    finish_sorted.sort_by(f64::total_cmp);
    let m = finish_sorted.len();
    let steady_tps = if m >= 4 {
        let a = m / 2;
        let b = m - 1;
        (finish_sorted[b] - finish_sorted[a]) / (b - a) as f64
    } else if m > 0 {
        total / m as f64
    } else {
        f64::INFINITY
    };

    SimxResult {
        sample_done,
        steady_tps,
        total,
        trace,
        transfers,
        pieces,
        injected,
        completed,
        mem_peak: devs.iter().map(|d| d.mem_peak).collect(),
        events_processed,
        stall,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::dp;
    use crate::coordinator::placement::Scenario;
    use crate::graph::Node;
    use crate::simx::event::EventScript;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(10.0).acc(1.0).mem(1.0).comm(0.1));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn schedule_parse_roundtrip() {
        for s in Schedule::ALL {
            assert_eq!(Schedule::parse(s.name()), Some(s), "roundtrip of {s:?}");
            assert_eq!(Schedule::parse(&s.to_string()), Some(s));
        }
        assert_eq!(Schedule::parse("SS"), Some(Schedule::SingleStream));
        assert_eq!(Schedule::parse("pipedream"), Some(Schedule::PipeDream1F1B));
        assert_eq!(Schedule::parse("nope"), None);
        assert_eq!(Schedule::default_for(true), Schedule::PipeDream1F1B);
        assert_eq!(Schedule::default_for(false), Schedule::Pipelined);
    }

    #[test]
    fn pipelined_steady_state_matches_max_load() {
        let g = chain(6);
        let sc = Scenario::new(3, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let req = sc.to_request();
        let res = simulate_req(&g, &req, &p, Schedule::Pipelined, 40, &SimConfig::default());
        assert!(res.ok().is_ok());
        assert_eq!(res.completed, 40);
        let predicted = crate::algos::objective::max_load_req(&g, &req, &p);
        assert!(
            (res.steady_tps - predicted).abs() / predicted < 0.05,
            "steady {} vs predicted {}",
            res.steady_tps,
            predicted
        );
    }

    #[test]
    fn straggler_slows_the_pipeline() {
        let g = chain(6);
        let sc = Scenario::new(3, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let req = sc.to_request();
        let base = simulate_req(&g, &req, &p, Schedule::Pipelined, 30, &SimConfig::default());
        let script = EventScript::parse("slow:acc1*0.5@t=0").unwrap();
        let slowed = simulate_with_events(
            &g,
            &req,
            &p,
            Schedule::Pipelined,
            30,
            &script,
            &SimConfig::default(),
        );
        assert_eq!(slowed.completed, 30);
        assert!(
            slowed.steady_tps > base.steady_tps * 1.4,
            "straggler must slow steady state: {} vs {}",
            slowed.steady_tps,
            base.steady_tps
        );
    }

    #[test]
    fn spike_injects_extra_samples() {
        let g = chain(4);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let req = sc.to_request();
        let script = EventScript::parse("spike:+4@t=2").unwrap();
        let res = simulate_with_events(
            &g,
            &req,
            &p,
            Schedule::Pipelined,
            6,
            &script,
            &SimConfig::default(),
        );
        assert_eq!(res.injected, 10);
        assert_eq!(res.completed, 10);
        assert!(res.ok().is_ok());
        // spiked samples cannot start before the spike fires
        let first_spike_start = res
            .trace
            .iter()
            .filter(|&&(s, _, _, _, _)| s >= 6)
            .map(|&(_, _, _, start, _)| start)
            .fold(f64::INFINITY, f64::min);
        assert!(first_spike_start >= 2.0 - 1e-12, "spike ran at {first_spike_start}");
    }

    #[test]
    fn device_loss_stalls_downstream_samples() {
        let g = chain(6);
        let sc = Scenario::new(3, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let req = sc.to_request();
        let script = EventScript::parse("fail:acc1@t=3").unwrap();
        let res = simulate_with_events(
            &g,
            &req,
            &p,
            Schedule::Pipelined,
            24,
            &script,
            &SimConfig::default(),
        );
        assert!(res.completed < res.injected, "device loss must strand samples");
        match res.stall {
            Some(Stall::DeviceLost { device, pending_samples }) => {
                assert_eq!(device, Device::Acc(1));
                assert_eq!(pending_samples, res.injected - res.completed);
            }
            other => panic!("expected DeviceLost, got {other:?}"),
        }
        assert!(res.ok().is_err());
    }

    #[test]
    fn recover_after_fail_completes_every_sample() {
        let g = chain(6);
        let sc = Scenario::new(3, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let req = sc.to_request();
        // same fault as device_loss_stalls_downstream_samples, but the
        // device comes back — no sample may stay stranded, even though
        // the pipeline fully drained while acc1 was down
        let script = EventScript::parse("fail:acc1@t=3,recover:acc1@t=40").unwrap();
        let res = simulate_with_events(
            &g,
            &req,
            &p,
            Schedule::Pipelined,
            24,
            &script,
            &SimConfig::default(),
        );
        assert_eq!(res.completed, res.injected, "recovery must unstall the run");
        assert!(res.stall.is_none());
        assert!(res.ok().is_ok());
        // the outage is visible in the makespan: work restarted at t=40
        assert!(res.total >= 40.0, "makespan {} must cover the outage", res.total);
    }

    #[test]
    fn recover_resets_straggler_scale() {
        let g = chain(6);
        let sc = Scenario::new(3, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let req = sc.to_request();
        let base = simulate_req(&g, &req, &p, Schedule::Pipelined, 30, &SimConfig::default());
        // heavy straggler, then recovery to nominal early in the run:
        // steady state (tail window) must match the undisturbed run
        let script = EventScript::parse("slow:acc1*0.1@t=0,recover:acc1@t=6").unwrap();
        let rec = simulate_with_events(
            &g,
            &req,
            &p,
            Schedule::Pipelined,
            30,
            &script,
            &SimConfig::default(),
        );
        assert_eq!(rec.completed, 30);
        assert!(
            rec.steady_tps < base.steady_tps * 1.3,
            "post-recovery steady state must be near-nominal: {} vs {}",
            rec.steady_tps,
            base.steady_tps
        );
    }

    #[test]
    fn link_bandwidth_delays_but_preserves_bottleneck_throughput() {
        let g = chain(6);
        let sc = Scenario::new(3, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let req = sc.to_request();
        let instant =
            simulate_req(&g, &req, &p, Schedule::Pipelined, 40, &SimConfig::default());
        let cfg = SimConfig { link_bandwidth: Some(1.0), ..SimConfig::default() };
        let linked = simulate_req(&g, &req, &p, Schedule::Pipelined, 40, &cfg);
        assert_eq!(linked.completed, 40);
        assert!(!linked.transfers.is_empty(), "cross-device hand-offs must use links");
        // wire delay adds ramp latency, never removes work
        assert!(linked.total >= instant.total - 1e-9);
        // tiny tensors over unit bandwidth: steady state still the bottleneck
        assert!(
            (linked.steady_tps - instant.steady_tps).abs() / instant.steady_tps < 0.05,
            "linked {} vs instant {}",
            linked.steady_tps,
            instant.steady_tps
        );
        // a starved link must throttle steady state below the compute bound
        let tight = SimConfig { link_bandwidth: Some(0.01), ..SimConfig::default() };
        let throttled = simulate_req(&g, &req, &p, Schedule::Pipelined, 40, &tight);
        assert!(
            throttled.steady_tps > instant.steady_tps * 1.5,
            "bw 0.01 should throttle: {} vs {}",
            throttled.steady_tps,
            instant.steady_tps
        );
    }

    /// Training chain with unit-mem forwards and mem-free backwards (the
    /// memory tests size caps against the forward activations alone).
    fn training_chain(n: usize) -> OpGraph {
        crate::util::proptest::training_chain(
            n,
            &Node::new("f").cpu(10.0).acc(1.0).mem(1.0).comm(0.1),
            &Node::new("b").cpu(10.0).acc(1.0).mem(0.0).comm(0.1),
        )
    }

    #[test]
    fn gpipe_holds_more_activation_memory_than_1f1b() {
        let g = training_chain(4);
        // fw/bw colocated 2+2 across two accelerators
        let assign = vec![
            Device::Acc(0),
            Device::Acc(0),
            Device::Acc(1),
            Device::Acc(1),
            Device::Acc(1),
            Device::Acc(1),
            Device::Acc(0),
            Device::Acc(0),
        ];
        let p = Placement::new(assign, 0.0, "manual");
        let sc = Scenario::new(2, 0, f64::INFINITY);
        let req = sc.to_request();
        let cfg = SimConfig { act_frac: 0.5, ..SimConfig::default() };
        let a = simulate_req(&g, &req, &p, Schedule::PipeDream1F1B, 12, &cfg);
        let b = simulate_req(&g, &req, &p, Schedule::GPipe, 12, &cfg);
        assert_eq!(a.completed, 12);
        assert_eq!(b.completed, 12);
        let peak = |r: &SimxResult| r.mem_peak.iter().copied().fold(0.0_f64, f64::max);
        assert!(
            peak(&b) > peak(&a) + 0.5,
            "GPipe must hold more live activations: {} vs {}",
            peak(&b),
            peak(&a)
        );
    }

    #[test]
    fn memory_enforcement_rejects_gpipe_but_admits_1f1b() {
        let g = training_chain(4);
        let assign = vec![
            Device::Acc(0),
            Device::Acc(0),
            Device::Acc(1),
            Device::Acc(1),
            Device::Acc(1),
            Device::Acc(1),
            Device::Acc(0),
            Device::Acc(0),
        ];
        let p = Placement::new(assign, 0.0, "manual");
        // cap 5: weights 1 + 4 in-flight activations fit, 12 do not
        let sc = Scenario::new(2, 0, 5.0);
        let req = sc.to_request();
        let cfg = SimConfig::with_memory_model(0.5);
        let a = simulate_req(&g, &req, &p, Schedule::PipeDream1F1B, 12, &cfg);
        assert_eq!(a.completed, 12, "1F1B must complete under the cap: {:?}", a.stall);
        for (d, &peak) in a.mem_peak.iter().enumerate() {
            assert!(peak <= 5.0 * (1.0 + 1e-9), "device {d} peak {peak} over cap");
        }
        let b = simulate_req(&g, &req, &p, Schedule::GPipe, 12, &cfg);
        assert!(
            matches!(b.stall, Some(Stall::MemoryDeadlock { .. })),
            "GPipe must be rejected: {:?}",
            b.stall
        );
        assert!(b.completed < b.injected);
    }

    #[test]
    fn timeline_renders_all_devices() {
        let g = chain(4);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = dp::solve(&g, &sc).unwrap();
        let req = sc.to_request();
        let res = simulate_req(&g, &req, &p, Schedule::Pipelined, 6, &SimConfig::default());
        let t = res.render_timeline(60);
        assert!(t.contains("acc0"));
        assert!(t.lines().count() >= 1);
    }
}

//! The in-tree optimization engine that stands in for Gurobi: a dense
//! two-phase simplex ([`lp`]) and a branch-and-bound MILP driver
//! ([`milp`]) with incumbent warm-starts, time-limit control and
//! optimality-gap reporting — the same operational surface the paper uses
//! ("run until within 1% of optimum, but no longer than 20 minutes").

pub mod lp;
pub mod milp;

//! Branch-and-bound MILP driver on top of the dense simplex ([`super::lp`]).
//!
//! Supports binary/integer variables, warm-start incumbents, a wall-clock
//! time limit and a relative-gap stopping rule — mirroring how the paper
//! drives Gurobi ("within 1% of the optimum, but no longer than 20
//! minutes"), and reporting the proven gap when the limit is hit (Table 4's
//! "MIP Gap" column).
//!
//! Node selection is best-first (smallest LP bound); branching picks the
//! integer variable with the most fractional LP value. The specialized
//! combinatorial searches in `algos::ip_throughput` / `algos::ip_latency`
//! use the same [`SolveStatus`]/gap conventions so results are comparable.

use super::lp::{Lp, LpOutcome, Sense};
use std::collections::BinaryHeap;
use std::time::{Duration, Instant};

/// A mixed-integer program: an [`Lp`] plus integrality marks.
#[derive(Clone, Debug, Default)]
pub struct Milp {
    pub lp: Lp,
    /// Indices of integer-constrained variables.
    pub integers: Vec<usize>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveStatus {
    /// Proven optimal (within tolerance).
    Optimal,
    /// Stopped at the target gap.
    GapReached,
    /// Hit the time limit with an incumbent.
    TimeLimit,
    /// Proven infeasible.
    Infeasible,
    /// Time limit with no incumbent found.
    Unknown,
}

#[derive(Clone, Debug)]
pub struct MilpResult {
    pub status: SolveStatus,
    /// Best feasible solution found (empty if none).
    pub solution: Vec<f64>,
    /// Objective of the incumbent (`INFINITY` if none).
    pub objective: f64,
    /// Best proven lower bound.
    pub bound: f64,
    /// Relative gap `(obj - bound) / max(|obj|, ε)`.
    pub gap: f64,
    pub nodes_explored: usize,
    pub elapsed: Duration,
}

/// Solver options.
#[derive(Clone, Debug)]
pub struct MilpOptions {
    pub time_limit: Duration,
    /// Stop when `(incumbent - bound)/|incumbent| ≤ gap_target`.
    pub gap_target: f64,
    /// Optional warm-start incumbent (must be integer-feasible; checked).
    pub warm_start: Option<Vec<f64>>,
    pub max_nodes: usize,
}

impl Default for MilpOptions {
    fn default() -> Self {
        MilpOptions {
            time_limit: Duration::from_secs(60),
            gap_target: 0.01,
            warm_start: None,
            max_nodes: 1_000_000,
        }
    }
}

struct Node {
    bound: f64,
    /// (var, fixed_value) decisions along this branch.
    fixes: Vec<(usize, f64)>,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; we want smallest bound first.
        other.bound.total_cmp(&self.bound)
    }
}

impl Milp {
    /// Check that `x` satisfies all constraints and integrality.
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        if x.len() != self.lp.num_vars {
            return false;
        }
        for (j, &v) in x.iter().enumerate() {
            if v < -tol || v > self.lp.upper[j] + tol {
                return false;
            }
        }
        for &j in &self.integers {
            if (x[j] - x[j].round()).abs() > tol {
                return false;
            }
        }
        for c in &self.lp.constraints {
            let lhs: f64 = c.coeffs.iter().map(|&(j, a)| a * x[j]).sum();
            let ok = match c.sense {
                Sense::Le => lhs <= c.rhs + tol,
                Sense::Ge => lhs >= c.rhs - tol,
                Sense::Eq => (lhs - c.rhs).abs() <= tol,
            };
            if !ok {
                return false;
            }
        }
        true
    }

    pub fn objective_of(&self, x: &[f64]) -> f64 {
        self.lp.objective.iter().zip(x).map(|(c, v)| c * v).sum()
    }

    /// Solve by LP-based branch and bound.
    pub fn solve(&self, opts: &MilpOptions) -> MilpResult {
        let start = Instant::now();
        let mut incumbent: Option<(f64, Vec<f64>)> = None;
        if let Some(ws) = &opts.warm_start {
            if self.is_feasible(ws, 1e-6) {
                incumbent = Some((self.objective_of(ws), ws.clone()));
            }
        }

        let root_lp = self.lp_with_fixes(&[]);
        let root = match root_lp.solve() {
            LpOutcome::Optimal { objective, .. } => objective,
            LpOutcome::Infeasible => {
                return MilpResult {
                    status: if incumbent.is_some() {
                        // warm start says feasible but LP says no: numeric
                        // trouble; report the incumbent without a bound
                        SolveStatus::TimeLimit
                    } else {
                        SolveStatus::Infeasible
                    },
                    solution: incumbent.clone().map(|i| i.1).unwrap_or_default(),
                    objective: incumbent.map_or(f64::INFINITY, |i| i.0),
                    bound: f64::NEG_INFINITY,
                    gap: f64::INFINITY,
                    nodes_explored: 1,
                    elapsed: start.elapsed(),
                };
            }
            LpOutcome::Unbounded => f64::NEG_INFINITY,
        };

        let mut heap = BinaryHeap::new();
        heap.push(Node { bound: root, fixes: Vec::new() });
        let mut nodes = 0usize;
        let mut best_bound = root;

        while let Some(node) = heap.pop() {
            nodes += 1;
            best_bound = node.bound;
            // prune / stop conditions
            if let Some((inc_obj, _)) = &incumbent {
                let gap = rel_gap(*inc_obj, node.bound);
                if node.bound >= *inc_obj - 1e-9 || gap <= opts.gap_target {
                    // best-first ⇒ bound is global; we are done
                    return self.finish(
                        if gap <= 1e-9 { SolveStatus::Optimal } else { SolveStatus::GapReached },
                        incumbent,
                        node.bound,
                        nodes,
                        start,
                    );
                }
            }
            if start.elapsed() > opts.time_limit || nodes > opts.max_nodes {
                return self.finish(
                    if incumbent.is_some() { SolveStatus::TimeLimit } else { SolveStatus::Unknown },
                    incumbent,
                    node.bound,
                    nodes,
                    start,
                );
            }

            // Re-solve LP at this node to get the fractional solution.
            let lp = self.lp_with_fixes(&node.fixes);
            let (obj, x) = match lp.solve() {
                LpOutcome::Optimal { objective, solution } => (objective, solution),
                _ => continue, // infeasible/unbounded subtree
            };
            if let Some((inc_obj, _)) = &incumbent {
                if obj >= *inc_obj - 1e-9 {
                    continue;
                }
            }

            // Find branching variable.
            let frac_var = self
                .integers
                .iter()
                .copied()
                .map(|j| (j, (x[j] - x[j].round()).abs()))
                .filter(|&(_, f)| f > 1e-6)
                .max_by(|a, b| a.1.total_cmp(&b.1));

            match frac_var {
                None => {
                    // integral: new incumbent
                    if incumbent.as_ref().is_none_or(|(o, _)| obj < *o - 1e-12) {
                        incumbent = Some((obj, x));
                    }
                }
                Some((j, _)) => {
                    for dir in [x[j].floor(), x[j].ceil()] {
                        let mut fixes = node.fixes.clone();
                        fixes.push((j, dir));
                        heap.push(Node { bound: obj, fixes });
                    }
                }
            }
        }

        // heap exhausted: incumbent (if any) is optimal
        let bound = incumbent.as_ref().map_or(best_bound, |(o, _)| *o);
        self.finish(
            if incumbent.is_some() { SolveStatus::Optimal } else { SolveStatus::Infeasible },
            incumbent,
            bound,
            nodes,
            start,
        )
    }

    fn finish(
        &self,
        status: SolveStatus,
        incumbent: Option<(f64, Vec<f64>)>,
        bound: f64,
        nodes: usize,
        start: Instant,
    ) -> MilpResult {
        let (objective, solution) = incumbent.map_or((f64::INFINITY, Vec::new()), |(o, s)| (o, s));
        MilpResult {
            status,
            gap: rel_gap(objective, bound),
            solution,
            objective,
            bound,
            nodes_explored: nodes,
            elapsed: start.elapsed(),
        }
    }

    /// Clone the LP with branching fixes applied: `x_j = v` becomes
    /// `upper[j] = v` plus a `≥ v` constraint when `v > 0`.
    fn lp_with_fixes(&self, fixes: &[(usize, f64)]) -> Lp {
        let mut lp = self.lp.clone();
        for &(j, v) in fixes {
            lp.upper[j] = lp.upper[j].min(v);
            if v > 0.0 {
                lp.add(vec![(j, 1.0)], Sense::Ge, v);
            }
        }
        lp
    }
}

fn rel_gap(obj: f64, bound: f64) -> f64 {
    if !obj.is_finite() {
        return f64::INFINITY;
    }
    ((obj - bound) / obj.abs().max(1e-9)).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 0/1 knapsack as a MILP (minimize negative value).
    fn knapsack(values: &[f64], weights: &[f64], cap: f64) -> Milp {
        let n = values.len();
        let mut lp = Lp::new(n);
        lp.objective = values.iter().map(|v| -v).collect();
        lp.upper = vec![1.0; n];
        lp.add(weights.iter().copied().enumerate().collect(), Sense::Le, cap);
        Milp { lp, integers: (0..n).collect() }
    }

    #[test]
    fn knapsack_exact() {
        // values [60,100,120], weights [10,20,30], cap 50 → take {1,2} = 220
        let m = knapsack(&[60.0, 100.0, 120.0], &[10.0, 20.0, 30.0], 50.0);
        let r = m.solve(&MilpOptions { gap_target: 0.0, ..Default::default() });
        assert_eq!(r.status, SolveStatus::Optimal);
        assert!((r.objective + 220.0).abs() < 1e-6, "{}", r.objective);
        assert!(r.solution[0] < 0.5 && r.solution[1] > 0.5 && r.solution[2] > 0.5);
    }

    #[test]
    fn knapsack_10_items_matches_dp() {
        let values = [12.0, 7.0, 20.0, 15.0, 5.0, 11.0, 17.0, 3.0, 9.0, 14.0];
        let weights = [4.0, 3.0, 9.0, 7.0, 2.0, 5.0, 8.0, 1.0, 4.0, 6.0];
        let cap = 20.0;
        // reference via exhaustive enumeration
        let mut best = 0.0_f64;
        for mask in 0u32..(1 << 10) {
            let (mut v, mut w) = (0.0, 0.0);
            for i in 0..10 {
                if mask >> i & 1 == 1 {
                    v += values[i];
                    w += weights[i];
                }
            }
            if w <= cap {
                best = best.max(v);
            }
        }
        let m = knapsack(&values, &weights, cap);
        let r = m.solve(&MilpOptions { gap_target: 0.0, ..Default::default() });
        assert_eq!(r.status, SolveStatus::Optimal);
        assert!((r.objective + best).abs() < 1e-6, "milp {} vs dp {best}", -r.objective);
    }

    #[test]
    fn warm_start_accepted_and_improved() {
        let m = knapsack(&[60.0, 100.0, 120.0], &[10.0, 20.0, 30.0], 50.0);
        let opts = MilpOptions {
            gap_target: 0.0,
            warm_start: Some(vec![1.0, 1.0, 0.0]), // value 160, feasible
            ..Default::default()
        };
        let r = m.solve(&opts);
        assert_eq!(r.status, SolveStatus::Optimal);
        assert!((r.objective + 220.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_milp() {
        let mut lp = Lp::new(1);
        lp.upper = vec![1.0];
        lp.add(vec![(0, 1.0)], Sense::Ge, 2.0);
        let m = Milp { lp, integers: vec![0] };
        let r = m.solve(&MilpOptions::default());
        assert_eq!(r.status, SolveStatus::Infeasible);
    }

    #[test]
    fn integer_equality_assignment() {
        // assignment problem 2x2: minimize 3x00 + x01 + 2x10 + 4x11 with row
        // and column sums = 1 → x01 + x10 = 3.
        let mut lp = Lp::new(4);
        lp.objective = vec![3.0, 1.0, 2.0, 4.0];
        lp.upper = vec![1.0; 4];
        lp.add(vec![(0, 1.0), (1, 1.0)], Sense::Eq, 1.0);
        lp.add(vec![(2, 1.0), (3, 1.0)], Sense::Eq, 1.0);
        lp.add(vec![(0, 1.0), (2, 1.0)], Sense::Eq, 1.0);
        lp.add(vec![(1, 1.0), (3, 1.0)], Sense::Eq, 1.0);
        let m = Milp { lp, integers: (0..4).collect() };
        let r = m.solve(&MilpOptions { gap_target: 0.0, ..Default::default() });
        assert_eq!(r.status, SolveStatus::Optimal);
        assert!((r.objective - 3.0).abs() < 1e-6);
    }

    #[test]
    fn gap_reporting_sane() {
        let m = knapsack(&[10.0, 10.0], &[1.0, 1.0], 2.0);
        let r = m.solve(&MilpOptions { gap_target: 0.0, ..Default::default() });
        assert!(r.gap < 1e-6);
        assert!(r.bound <= r.objective + 1e-9);
    }
}

//! Dense two-phase primal simplex — the LP core of the in-tree MILP solver
//! (the offline environment has no Gurobi; §6 "Algorithm execution setup"
//! used Gurobi 8.1, which this module + `milp.rs` replace).
//!
//! Scope: minimize `c·x` subject to `A x ⋈ b` (⋈ ∈ {≤, ≥, =}), `0 ≤ x ≤ u`.
//! Finite upper bounds are handled as explicit rows for simplicity; the
//! tableau is dense, so this engine is intended for models up to a few
//! hundred columns — exactly the sizes the branch-and-bound layer feeds it
//! (larger IPs use combinatorial bounds instead; see `milp.rs`).
//! Degeneracy is handled with Bland's rule after a stall is detected.

/// Constraint sense.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sense {
    Le,
    Ge,
    Eq,
}

/// A linear constraint `Σ coeffs[j]·x[j] ⋈ rhs` in sparse form.
#[derive(Clone, Debug)]
pub struct Constraint {
    pub coeffs: Vec<(usize, f64)>,
    pub sense: Sense,
    pub rhs: f64,
}

/// An LP: minimize `objective · x` subject to `constraints`, `0 ≤ x ≤ upper`.
#[derive(Clone, Debug, Default)]
pub struct Lp {
    pub num_vars: usize,
    pub objective: Vec<f64>,
    pub constraints: Vec<Constraint>,
    /// Per-variable upper bound (`f64::INFINITY` = unbounded above).
    pub upper: Vec<f64>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum LpOutcome {
    Optimal { objective: f64, solution: Vec<f64> },
    Infeasible,
    Unbounded,
}

impl Lp {
    pub fn new(num_vars: usize) -> Self {
        Lp {
            num_vars,
            objective: vec![0.0; num_vars],
            constraints: Vec::new(),
            upper: vec![f64::INFINITY; num_vars],
        }
    }

    pub fn add(&mut self, coeffs: Vec<(usize, f64)>, sense: Sense, rhs: f64) {
        debug_assert!(coeffs.iter().all(|&(j, _)| j < self.num_vars));
        self.constraints.push(Constraint { coeffs, sense, rhs });
    }

    /// Solve with the two-phase simplex. `max_iters` bounds pivots
    /// (guards against numerical cycling on pathological inputs).
    pub fn solve(&self) -> LpOutcome {
        self.solve_with_limit(200_000)
    }

    pub fn solve_with_limit(&self, max_iters: usize) -> LpOutcome {
        // Assemble rows: constraints + finite upper bounds.
        let mut rows: Vec<(Vec<(usize, f64)>, Sense, f64)> = Vec::new();
        for c in &self.constraints {
            rows.push((c.coeffs.clone(), c.sense, c.rhs));
        }
        for (j, &u) in self.upper.iter().enumerate() {
            if u.is_finite() {
                rows.push((vec![(j, 1.0)], Sense::Le, u));
            }
        }
        let m = rows.len();
        let n = self.num_vars;

        // Normalize to b ≥ 0.
        for row in rows.iter_mut() {
            if row.2 < 0.0 {
                for e in row.0.iter_mut() {
                    e.1 = -e.1;
                }
                row.2 = -row.2;
                row.1 = match row.1 {
                    Sense::Le => Sense::Ge,
                    Sense::Ge => Sense::Le,
                    Sense::Eq => Sense::Eq,
                };
            }
        }

        // Column layout: [x (n)] [slack/surplus (≤/≥ rows)] [artificials].
        let mut num_slack = 0;
        for row in &rows {
            if row.1 != Sense::Eq {
                num_slack += 1;
            }
        }
        // artificials: for ≥ and = rows
        let mut num_art = 0;
        for row in &rows {
            if row.1 != Sense::Le {
                num_art += 1;
            }
        }
        let total = n + num_slack + num_art;

        // Dense tableau: m rows × (total + 1), last col = rhs.
        let mut t = vec![vec![0.0_f64; total + 1]; m];
        let mut basis = vec![usize::MAX; m];
        let mut slack_idx = n;
        let mut art_idx = n + num_slack;
        let mut artificial_cols: Vec<usize> = Vec::new();
        for (i, row) in rows.iter().enumerate() {
            for &(j, a) in &row.0 {
                t[i][j] += a;
            }
            t[i][total] = row.2;
            match row.1 {
                Sense::Le => {
                    t[i][slack_idx] = 1.0;
                    basis[i] = slack_idx;
                    slack_idx += 1;
                }
                Sense::Ge => {
                    t[i][slack_idx] = -1.0;
                    slack_idx += 1;
                    t[i][art_idx] = 1.0;
                    basis[i] = art_idx;
                    artificial_cols.push(art_idx);
                    art_idx += 1;
                }
                Sense::Eq => {
                    t[i][art_idx] = 1.0;
                    basis[i] = art_idx;
                    artificial_cols.push(art_idx);
                    art_idx += 1;
                }
            }
        }

        // --- Phase 1: minimize sum of artificials ---
        if !artificial_cols.is_empty() {
            let mut cost1 = vec![0.0; total];
            for &a in &artificial_cols {
                cost1[a] = 1.0;
            }
            match simplex_core(&mut t, &mut basis, &cost1, total, max_iters) {
                CoreOutcome::Optimal(obj) => {
                    if obj > 1e-7 {
                        return LpOutcome::Infeasible;
                    }
                }
                CoreOutcome::Unbounded => unreachable!("phase-1 objective is bounded below"),
                CoreOutcome::IterLimit => return LpOutcome::Infeasible,
            }
            // Drive artificials out of the basis where possible.
            for i in 0..m {
                if basis[i] >= n + num_slack {
                    // pivot on any eligible non-artificial column
                    if let Some(j) = (0..n + num_slack).find(|&j| t[i][j].abs() > 1e-9) {
                        pivot(&mut t, &mut basis, i, j);
                    }
                }
            }
        }

        // --- Phase 2 ---
        let mut cost2 = vec![0.0; total];
        cost2[..n].copy_from_slice(&self.objective);
        // artificial columns are banned from entering (allowed = n+num_slack);
        // any artificial stuck basic at value 0 after phase 1 contributes 0.
        match simplex_core(&mut t, &mut basis, &cost2, n + num_slack, max_iters) {
            CoreOutcome::Optimal(_) | CoreOutcome::IterLimit => {
                let mut x = vec![0.0; n];
                for (i, &b) in basis.iter().enumerate() {
                    if b < n {
                        x[b] = t[i][total];
                    }
                }
                let obj = self
                    .objective
                    .iter()
                    .zip(&x)
                    .map(|(c, v)| c * v)
                    .sum();
                LpOutcome::Optimal { objective: obj, solution: x }
            }
            CoreOutcome::Unbounded => LpOutcome::Unbounded,
        }
    }
}

enum CoreOutcome {
    Optimal(f64),
    Unbounded,
    IterLimit,
}

/// Run primal simplex iterations on the tableau for the given cost vector.
/// The reduced-cost row is computed once (`O(m·n)`) and maintained through
/// pivots, so each iteration is `O(m·n)` total. Dantzig pricing, switching
/// to Bland's rule after a stall streak to escape degeneracy.
fn simplex_core(
    t: &mut [Vec<f64>],
    basis: &mut [usize],
    cost: &[f64],
    allowed: usize,
    max_iters: usize,
) -> CoreOutcome {
    let m = t.len();
    if m == 0 {
        return CoreOutcome::Optimal(0.0);
    }
    let total = t[0].len() - 1;
    // rc[j] = cost[j] - Σ_i cost[basis[i]]·t[i][j]; rc[total] = -objective.
    let mut rc = vec![0.0_f64; total + 1];
    rc[..total].copy_from_slice(&cost[..total]);
    for i in 0..m {
        let cb = cost[basis[i]];
        if cb != 0.0 {
            for j in 0..=total {
                rc[j] -= cb * t[i][j];
            }
        }
    }

    let mut stall = 0usize;
    let mut last_obj = f64::INFINITY;
    for _iter in 0..max_iters {
        let bland = stall > 2 * m + 20;
        let mut entering = None;
        let mut best = -1e-9;
        for j in 0..allowed {
            if rc[j] < -1e-9 {
                if bland {
                    entering = Some(j);
                    break;
                }
                if rc[j] < best {
                    best = rc[j];
                    entering = Some(j);
                }
            }
        }
        let Some(e) = entering else {
            return CoreOutcome::Optimal(-rc[total]);
        };
        // ratio test
        let mut leave: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            if t[i][e] > 1e-9 {
                let ratio = t[i][total] / t[i][e];
                if ratio < best_ratio - 1e-12
                    || (bland
                        && (ratio - best_ratio).abs() <= 1e-12
                        && leave.is_some_and(|l| basis[i] < basis[l]))
                {
                    best_ratio = ratio;
                    leave = Some(i);
                }
            }
        }
        let Some(r) = leave else {
            return CoreOutcome::Unbounded;
        };
        pivot(t, basis, r, e);
        // maintain reduced costs: rc -= rc[e] * (pivot row, normalized)
        let f = rc[e];
        if f.abs() > 1e-12 {
            for j in 0..=total {
                rc[j] -= f * t[r][j];
            }
        }
        rc[e] = 0.0;
        let obj = -rc[total];
        if (obj - last_obj).abs() < 1e-12 {
            stall += 1;
        } else {
            stall = 0;
            last_obj = obj;
        }
    }
    CoreOutcome::IterLimit
}

fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], r: usize, e: usize) {
    let total = t[0].len() - 1;
    let piv = t[r][e];
    for j in 0..=total {
        t[r][j] /= piv;
    }
    for i in 0..t.len() {
        if i != r && t[i][e].abs() > 1e-12 {
            let f = t[i][e];
            for j in 0..=total {
                t[i][j] -= f * t[r][j];
            }
        }
    }
    basis[r] = e;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(lp: &Lp, expect_obj: f64, tol: f64) -> Vec<f64> {
        match lp.solve() {
            LpOutcome::Optimal { objective, solution } => {
                assert!(
                    (objective - expect_obj).abs() < tol,
                    "objective {objective} != {expect_obj}"
                );
                solution
            }
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn textbook_max_problem() {
        // max 3x + 5y s.t. x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18 → (2, 6), obj 36
        let mut lp = Lp::new(2);
        lp.objective = vec![-3.0, -5.0]; // minimize negative
        lp.add(vec![(0, 1.0)], Sense::Le, 4.0);
        lp.add(vec![(1, 2.0)], Sense::Le, 12.0);
        lp.add(vec![(0, 3.0), (1, 2.0)], Sense::Le, 18.0);
        let x = assert_opt(&lp, -36.0, 1e-7);
        assert!((x[0] - 2.0).abs() < 1e-7 && (x[1] - 6.0).abs() < 1e-7);
    }

    #[test]
    fn equality_and_ge_constraints() {
        // min x + 2y s.t. x + y = 10, x ≥ 3 → (10-y)... optimal x=10,y=0? x≥3:
        // min at y=0, x=10 → 10.
        let mut lp = Lp::new(2);
        lp.objective = vec![1.0, 2.0];
        lp.add(vec![(0, 1.0), (1, 1.0)], Sense::Eq, 10.0);
        lp.add(vec![(0, 1.0)], Sense::Ge, 3.0);
        let x = assert_opt(&lp, 10.0, 1e-7);
        assert!((x[0] - 10.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_detected() {
        let mut lp = Lp::new(1);
        lp.objective = vec![1.0];
        lp.add(vec![(0, 1.0)], Sense::Ge, 5.0);
        lp.add(vec![(0, 1.0)], Sense::Le, 3.0);
        assert_eq!(lp.solve(), LpOutcome::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = Lp::new(1);
        lp.objective = vec![-1.0]; // max x, no bound
        lp.add(vec![(0, 1.0)], Sense::Ge, 0.0);
        assert_eq!(lp.solve(), LpOutcome::Unbounded);
    }

    #[test]
    fn upper_bounds_respected() {
        let mut lp = Lp::new(2);
        lp.objective = vec![-1.0, -1.0];
        lp.upper = vec![1.0, 0.5];
        lp.add(vec![(0, 1.0), (1, 1.0)], Sense::Le, 10.0);
        let x = assert_opt(&lp, -1.5, 1e-7);
        assert!((x[0] - 1.0).abs() < 1e-7 && (x[1] - 0.5).abs() < 1e-7);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // Classic degenerate corner; must not cycle.
        let mut lp = Lp::new(3);
        lp.objective = vec![-0.75, 150.0, -0.02];
        lp.add(vec![(0, 0.25), (1, -60.0), (2, -0.04)], Sense::Le, 0.0);
        lp.add(vec![(0, 0.5), (1, -90.0), (2, -0.02)], Sense::Le, 0.0);
        lp.add(vec![(2, 1.0)], Sense::Le, 1.0);
        match lp.solve() {
            LpOutcome::Optimal { .. } | LpOutcome::Unbounded => {}
            other => panic!("degenerate LP failed: {other:?}"),
        }
    }

    #[test]
    fn transportation_lp() {
        // 2 sources (supply 20, 30), 3 sinks (demand 10, 25, 15); costs:
        // [[2,4,5],[3,1,7]]. Optimum 125: x00=5, x02=15 (s0 full), x10=5,
        // x11=25 (s1 full) → 10 + 75 + 15 + 25 = 125.
        let mut lp = Lp::new(6);
        lp.objective = vec![2.0, 4.0, 5.0, 3.0, 1.0, 7.0];
        lp.add(vec![(0, 1.0), (1, 1.0), (2, 1.0)], Sense::Le, 20.0);
        lp.add(vec![(3, 1.0), (4, 1.0), (5, 1.0)], Sense::Le, 30.0);
        lp.add(vec![(0, 1.0), (3, 1.0)], Sense::Eq, 10.0);
        lp.add(vec![(1, 1.0), (4, 1.0)], Sense::Eq, 25.0);
        lp.add(vec![(2, 1.0), (5, 1.0)], Sense::Eq, 15.0);
        assert_opt(&lp, 125.0, 1e-6);
    }
}

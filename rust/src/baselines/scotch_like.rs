//! Scotch-style baseline [Pel09] (§6/§7): a multilevel graph partitioner
//! that balances *computation weight* across devices while minimizing the
//! *communication cut*, oblivious to the max-load pipeline objective and to
//! accelerator memory limits — reproducing both of the failure modes the
//! paper reports for Scotch (mediocre TPS, memory violations up to 34%).
//!
//! Pipeline: (1) coarsen by heavy-edge matching until ≤ `coarse_target`
//! vertices; (2) greedy balanced seed partition of the coarse graph;
//! (3) uncoarsen with Kernighan–Lin/Fiduccia–Mattheyses-style single-move
//! refinement at every level, optimizing `α·imbalance + cut`.

use crate::algos::objective;
use crate::coordinator::placement::{Device, Placement, PlanRequest, Scenario};
use crate::graph::OpGraph;

/// Undirected weighted graph used internally by the partitioner.
struct WGraph {
    /// vertex weights (computation)
    vw: Vec<f64>,
    /// adjacency: (neighbor, edge weight = comm cost)
    adj: Vec<Vec<(usize, f64)>>,
    /// mapping to the previous (finer) level's vertices
    map_up: Vec<Vec<usize>>,
}

impl WGraph {
    fn n(&self) -> usize {
        self.vw.len()
    }
}

/// Partition `g` into `parts` balanced parts, Scotch-style. Returns the
/// part index per node. Edge weights are the producers' raw transfer
/// costs; [`partition_comm`] takes explicit (e.g. topology-scaled) costs.
pub fn partition(g: &OpGraph, parts: usize, seed: u64) -> Vec<usize> {
    let comm: Vec<f64> = g.nodes.iter().map(|n| n.comm).collect();
    partition_comm(g, &comm, parts, seed)
}

/// [`partition`] with an explicit per-producer edge cost, so the cut
/// objective can reflect a device topology's worst-pair comm price.
pub fn partition_comm(g: &OpGraph, comm: &[f64], parts: usize, seed: u64) -> Vec<usize> {
    // Build the undirected working graph: vertex weight = accelerator
    // processing time (the dominant execution cost), edge weight = the
    // producer's transfer cost.
    let mut adj: Vec<Vec<(usize, f64)>> = vec![Vec::new(); g.n()];
    for (u, v) in g.edges() {
        let w = comm[u].max(1e-6);
        adj[u].push((v, w));
        adj[v].push((u, w));
    }
    let mut level = WGraph {
        vw: g.nodes.iter().map(|n| if n.p_acc.is_finite() { n.p_acc } else { n.p_cpu }).collect(),
        adj,
        map_up: (0..g.n()).map(|v| vec![v]).collect(),
    };

    let mut rng = crate::util::rng::Rng::new(seed);
    let mut levels: Vec<WGraph> = Vec::new();
    // --- coarsening ---
    let coarse_target = (parts * 8).max(24);
    while level.n() > coarse_target {
        let coarser = coarsen(&level, &mut rng);
        if coarser.n() as f64 > level.n() as f64 * 0.95 {
            levels.push(level);
            level = coarser;
            break; // diminishing returns
        }
        levels.push(level);
        level = coarser;
    }

    // --- initial partition on the coarsest level: greedy weight balancing
    let mut part = greedy_balance(&level, parts, &mut rng);
    refine(&level, &mut part, parts);

    // --- uncoarsen + refine ---
    while let Some(finer) = levels.pop() {
        // project: coarse vertex c covers finer.map-up... level.map_up[c]
        // lists vertices of `finer`
        let mut fine_part = vec![0usize; finer.n()];
        for (c, members) in level.map_up.iter().enumerate() {
            for &m in members {
                fine_part[m] = part[c];
            }
        }
        part = fine_part;
        refine(&finer, &mut part, parts);
        level = finer;
    }
    part
}

fn coarsen(g: &WGraph, rng: &mut crate::util::rng::Rng) -> WGraph {
    let n = g.n();
    let mut matched = vec![usize::MAX; n];
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    // heavy-edge matching
    for &v in &order {
        if matched[v] != usize::MAX {
            continue;
        }
        let mut best: Option<(f64, usize)> = None;
        for &(u, w) in &g.adj[v] {
            if matched[u] == usize::MAX && u != v {
                if best.as_ref().is_none_or(|&(bw, _)| w > bw) {
                    best = Some((w, u));
                }
            }
        }
        match best {
            Some((_, u)) => {
                matched[v] = u;
                matched[u] = v;
            }
            None => matched[v] = v,
        }
    }
    // build coarse graph
    let mut coarse_id = vec![usize::MAX; n];
    let mut next = 0;
    for v in 0..n {
        if coarse_id[v] == usize::MAX {
            coarse_id[v] = next;
            let m = matched[v];
            if m != v && m != usize::MAX {
                coarse_id[m] = next;
            }
            next += 1;
        }
    }
    let mut vw = vec![0.0; next];
    let mut map_up: Vec<Vec<usize>> = vec![Vec::new(); next];
    for v in 0..n {
        vw[coarse_id[v]] += g.vw[v];
        map_up[coarse_id[v]].push(v);
    }
    let mut edge_acc: std::collections::HashMap<(usize, usize), f64> = Default::default();
    for v in 0..n {
        for &(u, w) in &g.adj[v] {
            let (a, b) = (coarse_id[v], coarse_id[u]);
            if a < b {
                *edge_acc.entry((a, b)).or_insert(0.0) += w;
            }
        }
    }
    let mut adj: Vec<Vec<(usize, f64)>> = vec![Vec::new(); next];
    for (&(a, b), &w) in &edge_acc {
        adj[a].push((b, w));
        adj[b].push((a, w));
    }
    WGraph { vw, adj, map_up }
}

fn greedy_balance(g: &WGraph, parts: usize, rng: &mut crate::util::rng::Rng) -> Vec<usize> {
    let mut order: Vec<usize> = (0..g.n()).collect();
    order.sort_by(|&a, &b| g.vw[b].total_cmp(&g.vw[a]));
    // small random tiebreak for restart diversity
    if g.n() > 2 && rng.gen_bool(0.5) {
        order.swap(0, 1);
    }
    let mut load = vec![0.0_f64; parts];
    let mut part = vec![0usize; g.n()];
    for &v in &order {
        let p = (0..parts).min_by(|&a, &b| load[a].total_cmp(&load[b])).unwrap();
        part[v] = p;
        load[p] += g.vw[v];
    }
    part
}

/// KL/FM-style refinement: best single-vertex move under the objective
/// `α·(max part weight) + cut`, until a local optimum.
fn refine(g: &WGraph, part: &mut [usize], parts: usize) {
    let total: f64 = g.vw.iter().sum();
    let alpha = if total > 0.0 {
        // weight imbalance and cut on comparable scales
        let avg_edge: f64 = 1.0;
        parts as f64 * avg_edge
    } else {
        1.0
    };
    let mut load = vec![0.0_f64; parts];
    for v in 0..g.n() {
        load[part[v]] += g.vw[v];
    }
    let score = |load: &[f64], cut: f64| {
        alpha * load.iter().copied().fold(0.0, f64::max) + cut
    };
    let mut cut = cut_of(g, part);
    let mut cur = score(&load, cut);
    for _round in 0..8 {
        let mut improved = false;
        for v in 0..g.n() {
            let from = part[v];
            // gain of moving v to p: recompute local cut delta
            let mut to_weight = vec![0.0_f64; parts];
            for &(u, w) in &g.adj[v] {
                to_weight[part[u]] += w;
            }
            for p in 0..parts {
                if p == from {
                    continue;
                }
                let new_cut = cut + to_weight[from] - to_weight[p];
                load[from] -= g.vw[v];
                load[p] += g.vw[v];
                let cand = score(&load, new_cut);
                if cand < cur - 1e-12 {
                    part[v] = p;
                    cut = new_cut;
                    cur = cand;
                    improved = true;
                    break;
                }
                load[from] += g.vw[v];
                load[p] -= g.vw[v];
            }
        }
        if !improved {
            break;
        }
    }
}

fn cut_of(g: &WGraph, part: &[usize]) -> f64 {
    let mut cut = 0.0;
    for v in 0..g.n() {
        for &(u, w) in &g.adj[v] {
            if v < u && part[v] != part[u] {
                cut += w;
            }
        }
    }
    cut
}

/// Legacy scalar form of [`solve_req`].
pub fn solve(g: &OpGraph, sc: &Scenario, seed: u64) -> Placement {
    solve_req(g, &sc.to_request(), seed)
}

/// Scotch baseline for the throughput tables: partition over all fleet
/// devices (k accelerators + ℓ CPUs), ignoring memory limits — like the
/// real Scotch run in the paper. Loads are still speed-scaled per class.
pub fn solve_req(g: &OpGraph, req: &PlanRequest, seed: u64) -> Placement {
    let k = req.fleet.k();
    let nd = k + req.fleet.l().max(1);
    // cut weights at the topology's worst-pair price (identity without one)
    let wcomm: Vec<f64> = g.nodes.iter().map(|n| req.fleet.worst_pair_cost(n.comm)).collect();
    let part = partition_comm(g, &wcomm, nd, seed);
    let assignment: Vec<Device> = part.iter().map(|&p| Device::from_index(p, k)).collect();
    let mut placement = Placement::new(assignment, 0.0, "Scotch");
    // Score WITHOUT the memory check (Scotch violates it; Table 4 flags
    // this with daggers) — compute raw loads.
    let mut relaxed = req.clone();
    relaxed.fleet = req.fleet.with_unbounded_memory();
    placement.objective = objective::max_load_req(g, &relaxed, &placement);
    placement
}

/// Scotch for the latency tables: partition over accelerators only.
pub fn solve_latency(g: &OpGraph, sc: &Scenario, seed: u64) -> Placement {
    solve_latency_req(g, &sc.to_request(), seed)
}

/// [`solve_latency`] over a fleet.
pub fn solve_latency_req(g: &OpGraph, req: &PlanRequest, seed: u64) -> Placement {
    let wcomm: Vec<f64> = g.nodes.iter().map(|n| req.fleet.worst_pair_cost(n.comm)).collect();
    let part = partition_comm(g, &wcomm, req.fleet.k().max(1), seed);
    let assignment: Vec<Device> = part.iter().map(|&p| Device::Acc(p)).collect();
    let mut placement = Placement::new(assignment, 0.0, "Scotch");
    let mut relaxed = req.clone();
    relaxed.fleet = req.fleet.with_unbounded_memory();
    placement.objective = objective::latency_req(g, &relaxed, &placement);
    placement
}

/// Memory-violation factor of a placement: max over accelerators of
/// used/capacity (Table 4's dagger column).
pub fn memory_violation(g: &OpGraph, sc: &Scenario, p: &Placement) -> f64 {
    memory_violation_req(g, &sc.to_request(), p)
}

/// [`memory_violation`] against per-class caps.
pub fn memory_violation_req(g: &OpGraph, req: &PlanRequest, p: &Placement) -> f64 {
    (0..req.fleet.k())
        .map(|i| {
            g.mem_of(&p.set_of(Device::Acc(i), g.n())) / req.fleet.acc_mem_cap(i)
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;
    use crate::util::proptest::random_dag;
    use crate::util::rng::Rng;

    #[test]
    fn partition_covers_all_parts_roughly_balanced() {
        let mut rng = Rng::new(3);
        let g = random_dag(&mut rng, 60, 0.1);
        let part = partition(&g, 4, 1);
        assert_eq!(part.len(), 60);
        let mut loads = [0.0f64; 4];
        for (v, &p) in part.iter().enumerate() {
            assert!(p < 4);
            loads[p] += g.nodes[v].p_acc;
        }
        let max = loads.iter().fold(0.0f64, |a, &b| a.max(b));
        let min = loads.iter().fold(f64::INFINITY, |a, &b| a.min(b));
        assert!(max < min * 3.0 + 1.0, "imbalanced: {loads:?}");
    }

    #[test]
    fn never_beats_noncontiguous_optimum() {
        let mut rng = Rng::new(4);
        for _ in 0..4 {
            let g = random_dag(&mut rng, 8, 0.3);
            let sc = Scenario::new(2, 1, f64::INFINITY);
            let opt = crate::algos::ip_throughput::solve(
                &g,
                &sc,
                &crate::algos::ip_throughput::IpOptions {
                    contiguous: false,
                    gap_target: 0.0,
                    ..Default::default()
                },
            )
            .unwrap();
            let s = solve(&g, &sc, 11);
            assert!(s.objective >= opt.placement.objective - 1e-6);
        }
    }

    #[test]
    fn memory_violation_detected() {
        let mut g = OpGraph::new();
        for i in 0..4 {
            g.add_node(Node::new(format!("n{i}")).mem(10.0).acc(1.0).cpu(1.0));
        }
        let sc = Scenario::new(2, 0, 5.0);
        let p = Placement::new(vec![Device::Acc(0); 4], 0.0, "t");
        assert!(memory_violation(&g, &sc, &p) > 3.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut rng = Rng::new(8);
        let g = random_dag(&mut rng, 30, 0.15);
        assert_eq!(partition(&g, 3, 5), partition(&g, 3, 5));
    }

    #[test]
    fn coarsening_preserves_total_weight() {
        let mut rng = Rng::new(9);
        let g = random_dag(&mut rng, 50, 0.1);
        let mut adj: Vec<Vec<(usize, f64)>> = vec![Vec::new(); g.n()];
        for (u, v) in g.edges() {
            adj[u].push((v, 1.0));
            adj[v].push((u, 1.0));
        }
        let w = WGraph {
            vw: g.nodes.iter().map(|n| n.p_acc).collect(),
            adj,
            map_up: (0..g.n()).map(|v| vec![v]).collect(),
        };
        let total: f64 = w.vw.iter().sum();
        let c = coarsen(&w, &mut Rng::new(1));
        let ctotal: f64 = c.vw.iter().sum();
        assert!((total - ctotal).abs() < 1e-9);
        assert!(c.n() <= w.n());
    }
}

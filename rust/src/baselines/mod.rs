//! The comparison baselines of §6/§7: greedy topological bin-filling,
//! a Scotch-style multilevel partitioner, random-restart local search,
//! PipeDream's linear-chain DP, and rule-based human-expert placements.

pub mod expert;
pub mod greedy;
pub mod local_search;
pub mod pipedream;
pub mod scotch_like;

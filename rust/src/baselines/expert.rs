//! Human-expert placements (§6): rule-based splits for *layer* graphs only
//! (the paper: operator graphs are "infeasible to split manually").
//!
//! Rules follow the paper's description:
//! * GNMT / BERT-24: place each repeated block (LSTM / transformer layer)
//!   on its own device, then balance blocks across the `k` devices in
//!   round-robin bands — "in line with prior work [SVL14, WSC+16]".
//! * ResNet-50 / Inception-v3: stripe the conv/bn/relu layers equally
//!   (by count) across all devices in topological order.
//!
//! Expert splits ignore the memory cap (Table 4 reports OOM for two of
//! them), so no feasibility repair is attempted.

use crate::algos::objective;
use crate::coordinator::placement::{Device, Placement, PlanRequest, Scenario};
use crate::graph::{topo, NodeKind, OpGraph};

/// Expert style per workload family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpertStyle {
    /// Band the repeated blocks (GNMT, BERT-24): contiguous bands of equal
    /// *block* count per device, blocks identified by a name prefix like
    /// "layerN" / "lstmN".
    BlockBands,
    /// Equal-count striping of layers across devices in topo order
    /// (ResNet, Inception).
    EqualStripes,
}

/// Legacy scalar form of [`solve_req`].
pub fn solve(g: &OpGraph, sc: &Scenario, style: ExpertStyle) -> Placement {
    solve_req(g, &sc.to_request(), style)
}

/// Produce the expert placement. `style` chooses the rule; blocks are
/// derived from node names of the form `<block>_<rest>` (the workload
/// generators emit these). The expert stripes over the fleet's `k`
/// accelerators by count, class-oblivious — humans don't rebalance for
/// device speed either, which is exactly the baseline's point.
pub fn solve_req(g: &OpGraph, req: &PlanRequest, style: ExpertStyle) -> Placement {
    let order = topo::toposort(g).expect("expert split requires a DAG");
    let nd = req.fleet.k().max(1);
    // the expert stripes/bands FORWARD work; backward nodes follow their
    // forward partner (humans keep a layer's weights on one device)
    let fw_order: Vec<usize> = order
        .iter()
        .copied()
        .filter(|&v| g.nodes[v].kind == NodeKind::Forward)
        .collect();
    let mut dense: Vec<usize> = vec![usize::MAX; g.n()];
    match style {
        ExpertStyle::EqualStripes => {
            let n = fw_order.len().max(1);
            for (pos, &v) in fw_order.iter().enumerate() {
                dense[v] = (pos * nd / n).min(nd - 1);
            }
        }
        ExpertStyle::BlockBands => {
            // identify blocks by name prefix before the last '_' (bw nodes
            // share the block of their forward counterpart)
            let mut block_of = vec![0usize; g.n()];
            let mut blocks: std::collections::BTreeMap<String, usize> = Default::default();
            for &v in &fw_order {
                let name = g.nodes[v].name.strip_prefix("bw_").unwrap_or(&g.nodes[v].name);
                let prefix = name.rsplit_once('_').map(|(p, _)| p).unwrap_or(name);
                let next = blocks.len();
                let b = *blocks.entry(prefix.to_string()).or_insert(next);
                block_of[v] = b;
            }
            let nb = blocks.len().max(1);
            for &v in &fw_order {
                dense[v] = (block_of[v] * nd / nb).min(nd - 1);
            }
        }
    }
    // backward nodes inherit the partner's device; orphans follow topo pos
    for v in 0..g.n() {
        if dense[v] == usize::MAX {
            dense[v] = match g.nodes[v].fw_partner {
                Some(f) if dense[f] != usize::MAX => dense[f],
                _ => nd - 1,
            };
        }
    }
    let dense: Vec<usize> = dense;
    let assignment: Vec<Device> = dense.iter().map(|&d| Device::Acc(d)).collect();
    let mut p = Placement::new(assignment, 0.0, "Expert");
    // score without the memory constraint; callers report violations
    let mut relaxed = req.clone();
    relaxed.fleet = req.fleet.with_unbounded_memory();
    p.objective = objective::max_load_req(g, &relaxed, &p);
    p
}

/// Latency variant of the expert scoring.
pub fn solve_latency(g: &OpGraph, sc: &Scenario, style: ExpertStyle) -> Placement {
    solve_latency_req(g, &sc.to_request(), style)
}

/// [`solve_latency`] over a fleet.
pub fn solve_latency_req(g: &OpGraph, req: &PlanRequest, style: ExpertStyle) -> Placement {
    let mut p = solve_req(g, req, style);
    let mut relaxed = req.clone();
    relaxed.fleet = req.fleet.with_unbounded_memory();
    p.objective = objective::latency_req(g, &relaxed, &p);
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn blocky_chain() -> OpGraph {
        // 4 blocks of 2 layers: block0_a block0_b block1_a ...
        let mut g = OpGraph::new();
        for b in 0..4 {
            for part in ["a", "b"] {
                g.add_node(Node::new(format!("block{b}_{part}")).cpu(4.0).acc(1.0).comm(0.1));
            }
        }
        for i in 1..8 {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn block_bands_keep_blocks_whole() {
        let g = blocky_chain();
        let sc = Scenario::new(2, 0, f64::INFINITY);
        let p = solve(&g, &sc, ExpertStyle::BlockBands);
        // nodes of the same block land on the same device
        for b in 0..4 {
            assert_eq!(p.assignment[2 * b], p.assignment[2 * b + 1], "block {b} split");
        }
        // both devices used
        assert!(p.assignment.iter().any(|&d| d == Device::Acc(0)));
        assert!(p.assignment.iter().any(|&d| d == Device::Acc(1)));
    }

    #[test]
    fn equal_stripes_balance_counts() {
        let g = blocky_chain();
        let sc = Scenario::new(4, 0, f64::INFINITY);
        let p = solve(&g, &sc, ExpertStyle::EqualStripes);
        for d in 0..4 {
            assert_eq!(p.set_of(Device::Acc(d), 8).len(), 2);
        }
    }

    #[test]
    fn expert_never_beats_dp() {
        let g = blocky_chain();
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let dp = crate::algos::dp::solve(&g, &sc).unwrap();
        for style in [ExpertStyle::BlockBands, ExpertStyle::EqualStripes] {
            let e = solve(&g, &sc, style);
            assert!(e.objective >= dp.objective - 1e-9);
        }
    }

    #[test]
    fn latency_variant_scores_latency() {
        let g = blocky_chain();
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = solve_latency(&g, &sc, ExpertStyle::EqualStripes);
        assert!(p.objective.is_finite());
    }
}

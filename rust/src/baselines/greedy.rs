//! Greedy latency baseline (§7): contract colocated nodes and SCCs, fix a
//! topological ordering, fill each accelerator in turn with as many nodes
//! as fit, park the remainder on the CPU. Feasible by construction,
//! oblivious to processing times and communication costs — the paper's
//! sanity floor for Table 4.

use crate::coordinator::placement::{Device, Placement, PlanRequest, Scenario};
use crate::graph::{contract, topo, OpGraph};

/// Legacy scalar form of [`solve_req`].
pub fn solve(g: &OpGraph, sc: &Scenario) -> Placement {
    solve_req(g, &sc.to_request())
}

/// Greedy bin-fill over the fleet's accelerators in dense order, each
/// filled to its *own class's* cap; remainder on the CPU pool.
pub fn solve_req(g: &OpGraph, req: &PlanRequest) -> Placement {
    let k = req.fleet.k();
    let con = contract::preprocess_colocation(g);
    let order = topo::toposort(&con.graph).expect("greedy requires a DAG after contraction");

    let mut dense = vec![usize::MAX; con.graph.n()];
    let mut acc = 0usize;
    let mut used = 0.0_f64;
    for &v in &order {
        let m = con.graph.nodes[v].mem;
        while acc < k
            && (used + m > req.fleet.acc_mem_cap(acc)
                || con.graph.nodes[v].p_acc.is_infinite())
        {
            if con.graph.nodes[v].p_acc.is_infinite() {
                break;
            }
            acc += 1;
            used = 0.0;
        }
        if acc < k
            && used + m <= req.fleet.acc_mem_cap(acc)
            && con.graph.nodes[v].p_acc.is_finite()
        {
            dense[v] = acc;
            used += m;
        } else {
            dense[v] = k; // CPU pool
        }
    }

    let assignment: Vec<Device> = con
        .map
        .iter()
        .map(|&c| {
            if dense[c] < k {
                Device::Acc(dense[c])
            } else {
                Device::Cpu(0)
            }
        })
        .collect();
    let mut p = Placement::new(assignment, 0.0, "Greedy");
    p.objective = crate::algos::objective::latency_req(g, req, &p);
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(9.0).acc(1.0).mem(1.0).comm(0.2));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn fills_accelerators_in_topo_order() {
        let g = chain(6);
        let sc = Scenario::new(2, 1, 2.0);
        let p = solve(&g, &sc);
        p.validate(&g, &sc, true).unwrap();
        // 2 per accelerator, remaining 2 on CPU
        assert_eq!(p.set_of(Device::Acc(0), 6).len(), 2);
        assert_eq!(p.set_of(Device::Acc(1), 6).len(), 2);
        assert_eq!(p.set_of(Device::Cpu(0), 6).len(), 2);
        assert!(p.objective.is_finite());
    }

    #[test]
    fn all_fit_no_cpu_needed() {
        let g = chain(4);
        let sc = Scenario::new(2, 1, 2.0);
        let p = solve(&g, &sc);
        assert!(p.set_of(Device::Cpu(0), 4).is_empty());
    }

    #[test]
    fn respects_colocation() {
        let mut g = chain(4);
        g.nodes[0].color_class = Some(1);
        g.nodes[3].color_class = Some(1);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = solve(&g, &sc);
        p.check_colocation(&g).unwrap();
    }

    #[test]
    fn acc_unsupported_ops_go_to_cpu() {
        let mut g = chain(3);
        g.nodes[1].p_acc = f64::INFINITY;
        let sc = Scenario::new(1, 1, f64::INFINITY);
        let p = solve(&g, &sc);
        assert_eq!(p.assignment[1], Device::Cpu(0));
    }
}

//! PipeDream's optimizer [NHP+19] as a baseline (§6): a DP restricted to
//! *linear* layer graphs. Branchings are contracted to single nodes first
//! (the paper: "it requires the input to be a linear path, thus it
//! contracts all branchings to single nodes"), then the optimal split of
//! the resulting path into `k + ℓ` consecutive segments minimizes max-load.
//!
//! Only meaningful for layer-granularity graphs; on heavily branching
//! operator graphs the contraction collapses most of the network and the
//! result degrades — exactly the effect Table 1 shows.

use crate::algos::objective;
use crate::coordinator::placement::{Device, Placement, PlanRequest, Scenario};
use crate::graph::{contract, topo, OpGraph};

/// Contract every "branching region" so the remaining graph is a path:
/// walk in topological order; whenever more than one node is ready at once
/// (parallel branches), merge everything until the graph re-converges.
/// Returns `group_of[v]`.
pub fn linearize_by_contraction(g: &OpGraph) -> Vec<usize> {
    let order = topo::toposort(g).expect("pipedream baseline requires a DAG");
    let n = g.n();
    // longest-path level of each node
    let mut level = vec![0usize; n];
    for &v in &order {
        for &u in &g.preds[v] {
            level[v] = level[v].max(level[u] + 1);
        }
    }
    // a node is a "cut" if it is the ONLY node at its level and every
    // earlier node precedes it (path graph of cut nodes); between cuts,
    // contract everything into one group.
    let mut by_level: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
    for v in 0..n {
        by_level.entry(level[v]).or_default().push(v);
    }
    // Only reachability TO the cut candidates (the sole node of a level)
    // is ever queried, so build an n × |candidates| table instead of the
    // full n × n matrix: one reverse-topological pass of word ORs.
    let cands: Vec<usize> =
        by_level.values().filter(|ns| ns.len() == 1).map(|ns| ns[0]).collect();
    let mut cand_idx = vec![usize::MAX; n];
    for (ci, &c) in cands.iter().enumerate() {
        cand_idx[c] = ci;
    }
    let stride = crate::util::arena::words_for(cands.len().max(1));
    let mut rc = vec![0u64; n * stride];
    for &u in order.iter().rev() {
        for &v in &g.succs[u] {
            for w in 0..stride {
                let x = rc[v * stride + w];
                rc[u * stride + w] |= x;
            }
        }
        if cand_idx[u] != usize::MAX {
            rc[u * stride + cand_idx[u] / 64] |= 1u64 << (cand_idx[u] % 64);
        }
    }
    let reaches = |u: usize, ci: usize| rc[u * stride + ci / 64] >> (ci % 64) & 1 == 1;
    let mut group_of = vec![usize::MAX; n];
    let mut next_group = 0usize;
    let mut open: Vec<usize> = Vec::new(); // nodes in the current region
    for (_lvl, nodes) in by_level.iter() {
        let is_cut = nodes.len() == 1 && {
            let c = nodes[0];
            // all open nodes must reach c (so the region converges here)
            open.iter().all(|&u| reaches(u, cand_idx[c]))
        };
        if is_cut && !open.is_empty() {
            // close the region (open nodes form one group), cut starts new
            for &u in &open {
                group_of[u] = next_group;
            }
            next_group += 1;
            open.clear();
        }
        open.extend(nodes.iter().copied());
        if is_cut && open.len() == 1 {
            group_of[open[0]] = next_group;
            next_group += 1;
            open.clear();
        }
    }
    if !open.is_empty() {
        for &u in &open {
            group_of[u] = next_group;
        }
    }
    group_of
}

/// Legacy scalar form of [`solve_req`].
pub fn solve(g: &OpGraph, sc: &Scenario) -> Placement {
    solve_req(g, &sc.to_request())
}

/// PipeDream baseline: contract to a path, then optimal consecutive
/// segmentation over the devices by DP. Devices keep their fleet dense
/// order (accelerator classes first), so each segment is costed against
/// its device's own class speed and memory cap.
pub fn solve_req(g: &OpGraph, req: &PlanRequest) -> Placement {
    // PipeDream treats a layer's forward and backward work as ONE unit
    // (its path nodes carry combined fw+bw costs), so colocation classes
    // are merged across BOTH directions here — unlike the DP's App.-B
    // preprocessing, which keeps the directions as separate (colocated)
    // contiguous subgraphs.
    let mut class_group: std::collections::BTreeMap<u32, usize> = Default::default();
    let mut group_of = vec![usize::MAX; g.n()];
    let mut next = 0usize;
    for (v, node) in g.nodes.iter().enumerate() {
        group_of[v] = match node.color_class {
            Some(c) => *class_group.entry(c).or_insert_with(|| {
                let id = next;
                next += 1;
                id
            }),
            None => {
                let id = next;
                next += 1;
                id
            }
        };
    }
    let c1 = contract::contract_groups(g, &group_of);
    let scc = contract::sccs(&c1.graph);
    let c2 = contract::contract_groups(&c1.graph, &scc);
    let map: Vec<usize> = c1.map.iter().map(|&m| c2.map[m]).collect();
    let con = contract::Contraction {
        graph: c2.graph,
        groups: {
            let mut groups = vec![Vec::new(); map.iter().max().map_or(0, |m| m + 1)];
            for (v, &m) in map.iter().enumerate() {
                groups[m].push(v);
            }
            groups
        },
        map,
    };
    let group_of = linearize_by_contraction(&con.graph);
    let mut path = contract::contract_groups(&con.graph, &group_of);
    // The path DP can't see device pairs, so segment-boundary comm is
    // priced at the topology's worst pair (identity without one); the
    // final objective below is re-scored pair-exactly on the original graph.
    for node in path.graph.nodes.iter_mut() {
        node.comm = req.fleet.worst_pair_cost(node.comm);
    }
    let order = topo::toposort(&path.graph).expect("path contraction broke acyclicity");
    let m = order.len();
    let k = req.fleet.k();
    let nd = k + req.fleet.l().max(1);

    // dp[i][d] = best max-load splitting the first i path nodes over d
    // devices (consecutive segments). Device type chosen greedily per
    // segment: accelerators first (they are faster on these workloads),
    // falling back to CPU when out of accelerators.
    // We model devices as an ordered multiset: first k segments on accs.
    let big = f64::INFINITY;
    let mut dp = vec![vec![big; nd + 1]; m + 1];
    let mut choice = vec![vec![0usize; nd + 1]; m + 1];
    dp[0][0] = 0.0;
    // prefix sums of acc/cpu costs along the path
    for i in 1..=m {
        for d in 1..=nd {
            for j in 0..i {
                // segment j..i on device index d-1 (accs are 0..k)
                let seg: Vec<usize> = order[j..i].to_vec();
                let set = crate::util::bitset::BitSet::from_iter(path.graph.n(), seg);
                let load = if d - 1 < k {
                    path.graph.acc_load_scaled(
                        &set,
                        req.fleet.acc_mem_cap(d - 1),
                        req.fleet.acc_speed(d - 1),
                    )
                } else {
                    path.graph.cpu_load_scaled(&set, req.fleet.cpu_speed(d - 1 - k))
                };
                let cand = dp[j][d - 1].max(load);
                if cand < dp[i][d] {
                    dp[i][d] = cand;
                    choice[i][d] = j;
                }
            }
        }
    }
    let (mut best_d, mut best) = (nd, dp[m][nd]);
    for d in 1..=nd {
        if dp[m][d] < best {
            best = dp[m][d];
            best_d = d;
        }
    }

    // reconstruct segment boundaries
    let mut dense_path = vec![0usize; path.graph.n()];
    let (mut i, mut d) = (m, best_d);
    while d > 0 && i > 0 {
        let j = choice[i][d];
        for &v in &order[j..i] {
            dense_path[v] = d - 1;
        }
        i = j;
        d -= 1;
    }

    // expand: original node → colocation group → path group → device
    let assignment: Vec<Device> = (0..g.n())
        .map(|v| {
            let pg = path.map[con.map[v]];
            Device::from_index(dense_path[pg], k)
        })
        .collect();
    let mut placement = Placement::new(assignment, 0.0, "PipeDream");
    placement.objective = objective::max_load_req(g, req, &placement);
    placement
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(9.0).acc(1.0).mem(1.0).comm(0.1));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn linear_graph_matches_dp_exactly() {
        // On a true path, PipeDream's optimizer IS optimal.
        let g = chain(8);
        let sc = Scenario::new(3, 1, f64::INFINITY);
        let pd = solve(&g, &sc);
        let dp = crate::algos::dp::solve(&g, &sc).unwrap();
        assert!(
            (pd.objective - dp.objective).abs() < 1e-9,
            "pipedream {} vs dp {}",
            pd.objective,
            dp.objective
        );
    }

    #[test]
    fn branching_contracted_to_single_node() {
        // diamond: branches contracted → path src, {branches}, sink
        let mut g = OpGraph::new();
        for i in 0..4 {
            g.add_node(Node::new(format!("n{i}")));
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        let groups = linearize_by_contraction(&g);
        assert_eq!(groups[1], groups[2], "parallel branches must merge");
        assert_ne!(groups[0], groups[1]);
        assert_ne!(groups[1], groups[3]);
    }

    #[test]
    fn branchy_graph_no_better_than_dp() {
        use crate::util::proptest::random_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x9d);
        for _ in 0..6 {
            let g = random_dag(&mut rng, 10, 0.25);
            let sc = Scenario::new(2, 1, f64::INFINITY);
            let pd = solve(&g, &sc);
            let dp = crate::algos::dp::solve(&g, &sc).unwrap();
            assert!(
                pd.objective >= dp.objective - 1e-9,
                "pipedream {} beat dp {}",
                pd.objective,
                dp.objective
            );
        }
    }

    #[test]
    fn produces_valid_placement() {
        let g = chain(6);
        let sc = Scenario::new(2, 1, 3.0);
        let p = solve(&g, &sc);
        p.validate(&g, &sc, false).unwrap();
        assert!(p.objective.is_finite());
    }
}

//! Local search baseline [MKA07] (§6): start from a random assignment,
//! repeatedly apply the best single-node reassignment until no move
//! improves the max-load objective; restart `restarts` times and keep the
//! best. Produces (almost always) non-contiguous splits. As the paper
//! observes, it fares badly on these instances — the optimization landscape
//! is non-local.

use crate::algos::objective;
use crate::coordinator::placement::{Device, Placement, PlanRequest, Scenario};
use crate::graph::OpGraph;
use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// Wall-clock budget per restart: the paper's local search runs to a local
/// optimum; on 1k+-node operator graphs a full best-improvement sweep is
/// O(V·devices) objective evaluations per move, so we cap each descent —
/// the truncation only makes the baseline *weaker*, consistent with its
/// role.
const RESTART_BUDGET: Duration = Duration::from_secs(3);

/// Legacy scalar form of [`solve_req`].
pub fn solve(g: &OpGraph, sc: &Scenario, restarts: usize, seed: u64) -> Placement {
    solve_req(g, &sc.to_request(), restarts, seed)
}

/// Random-restart local search over the fleet's dense devices; moves are
/// scored by the per-class-aware evaluator, so overfilling a small-memory
/// class reads as infeasible (∞) exactly like the scalar path did.
pub fn solve_req(g: &OpGraph, req: &PlanRequest, restarts: usize, seed: u64) -> Placement {
    let mut rng = Rng::new(seed);
    let (k, l) = (req.fleet.k(), req.fleet.l());
    let nd = k + l.max(1);
    let mut best: Option<(f64, Vec<usize>)> = None;

    for _ in 0..restarts.max(1) {
        // random colocation-respecting start
        let mut dense: Vec<usize> = vec![0; g.n()];
        let mut class_dev: std::collections::BTreeMap<u32, usize> = Default::default();
        for v in 0..g.n() {
            dense[v] = match g.nodes[v].color_class {
                Some(c) => *class_dev.entry(c).or_insert_with(|| rng.gen_range(nd)),
                None => rng.gen_range(nd),
            };
        }
        let mut cur = eval(g, req, &dense);
        let deadline = Instant::now() + RESTART_BUDGET;
        // best-improvement hill climbing over single-node moves (moving a
        // whole color class together)
        'descent: loop {
            let mut improved: Option<(f64, usize, usize)> = None;
            for v in 0..g.n() {
                if Instant::now() > deadline {
                    break 'descent;
                }
                // only the representative of a color class moves
                if let Some(c) = g.nodes[v].color_class {
                    let rep = (0..g.n())
                        .find(|&u| g.nodes[u].color_class == Some(c))
                        .unwrap();
                    if rep != v {
                        continue;
                    }
                }
                let orig = dense[v];
                for d in 0..nd {
                    if d == orig {
                        continue;
                    }
                    set_class(g, &mut dense, v, d);
                    let cand = eval(g, req, &dense);
                    if cand < cur - 1e-12
                        && improved.as_ref().is_none_or(|&(b, _, _)| cand < b)
                    {
                        improved = Some((cand, v, d));
                    }
                    set_class(g, &mut dense, v, orig);
                }
            }
            match improved {
                Some((val, v, d)) => {
                    set_class(g, &mut dense, v, d);
                    cur = val;
                }
                None => break,
            }
        }
        if cur.is_finite() && best.as_ref().is_none_or(|(b, _)| cur < *b) {
            best = Some((cur, dense));
        }
    }

    match best {
        Some((obj, dense)) => {
            let assignment = dense.iter().map(|&d| Device::from_index(d, k)).collect();
            Placement::new(assignment, obj, "Local search")
        }
        None => {
            // no feasible local optimum found: park everything on CPU
            let p = Placement::new(vec![Device::Cpu(0); g.n()], 0.0, "Local search");
            let obj = objective::max_load_req(g, req, &p);
            Placement { objective: obj, ..p }
        }
    }
}

fn set_class(g: &OpGraph, dense: &mut [usize], v: usize, d: usize) {
    match g.nodes[v].color_class {
        Some(c) => {
            for u in 0..g.n() {
                if g.nodes[u].color_class == Some(c) {
                    dense[u] = d;
                }
            }
        }
        None => dense[v] = d,
    }
}

fn eval(g: &OpGraph, req: &PlanRequest, dense: &[usize]) -> f64 {
    let p = Placement::new(
        dense.iter().map(|&d| Device::from_index(d, req.fleet.k())).collect(),
        0.0,
        "tmp",
    );
    objective::max_load_req(g, req, &p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(9.0).acc(1.0).mem(1.0).comm(0.2));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn deterministic_for_seed_and_feasible() {
        let g = chain(8);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let a = solve(&g, &sc, 5, 42);
        let b = solve(&g, &sc, 5, 42);
        assert_eq!(a.assignment, b.assignment);
        a.validate(&g, &sc, false).unwrap();
        assert!(a.objective.is_finite());
    }

    #[test]
    fn never_better_than_optimum() {
        use crate::util::proptest::random_dag;
        let mut rng = Rng::new(0x15);
        for _ in 0..5 {
            let g = random_dag(&mut rng, 8, 0.3);
            let sc = Scenario::new(2, 1, f64::INFINITY);
            let opt = crate::algos::ip_throughput::solve(
                &g,
                &sc,
                &crate::algos::ip_throughput::IpOptions {
                    contiguous: false,
                    gap_target: 0.0,
                    ..Default::default()
                },
            )
            .unwrap();
            let ls = solve(&g, &sc, 10, 7);
            assert!(ls.objective >= opt.placement.objective - 1e-6);
        }
    }

    #[test]
    fn respects_colocation_classes() {
        let mut g = chain(6);
        g.nodes[1].color_class = Some(3);
        g.nodes[4].color_class = Some(3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = solve(&g, &sc, 5, 1);
        p.check_colocation(&g).unwrap();
    }

    #[test]
    fn restarts_help_or_equal() {
        let g = chain(10);
        let sc = Scenario::new(3, 1, f64::INFINITY);
        let one = solve(&g, &sc, 1, 9);
        let many = solve(&g, &sc, 10, 9);
        assert!(many.objective <= one.objective + 1e-12);
    }
}

//! `dnn-partition` CLI — the leader entrypoint.
//!
//! ```text
//! dnn-partition list                       # show the built-in workloads
//! dnn-partition partition <wl> <alg>       # plan a pipelined split
//! dnn-partition latency <wl>               # §7 latency planning
//! dnn-partition simulate <wl|file.json> <alg> [n]   # fleet simulation + timeline
//!     [--events "SCRIPT"] [--schedule POLICY] [--trace FILE] [--assert-improves]
//!     [--monitor]
//! dnn-partition chaos <wl|file.json> <alg>  # seeded chaos campaign
//!     [--runs N] [--seed N] [--samples N] [--fleet "SPEC"]
//! dnn-partition export <wl> <out.json>     # dump paper-format JSON
//! dnn-partition partition-file <in.json> <alg>   # plan an external workload
//! dnn-partition bench-traffic [--smoke]    # concurrent planning traffic bench
//! dnn-partition stats                      # exercise the planner, print Prometheus metrics
//! ```
//!
//! `partition`, `simulate` and `bench-traffic` accept
//! `--profile FILE.trace.json`: record the run's solver/context spans
//! (plus, for `simulate`, the virtual-time device/link Gantt lanes) and
//! write a Chrome `trace_event` file loadable in Perfetto or
//! `chrome://tracing` (DESIGN.md §10).
//!
//! `partition`, `bench-traffic` and `chaos` accept `--deadline-ms N`: each
//! solve runs under a cooperative-cancellation budget and degrades through
//! the anytime ladder (IP incumbent → exact DP → DPL → greedy) instead of
//! overrunning — `partition` reports the answer's quality tag (`exact` vs
//! `anytime(rung)`). `partition` additionally accepts `--node-limit N` to
//! cap the search's explored nodes (DESIGN.md §11).
//!
//! Workload names: `bert3op`, `bert6op`, `bert12op`, `resnet50op`,
//! `bert24`, `resnet50`, `inceptionv3`, `gnmt` — suffix `-train` for the
//! training variant (e.g. `bert24-train`).
//!
//! Algorithms: `dp`, `dpl`, `ip`/`ip-contiguous`, `ipnc`/`ip-noncontiguous`,
//! `ip-latency`, `replication`, `hierarchy`, `expert`, `ls`/`local-search`,
//! `pipedream`, `scotch`, `greedy`.
//!
//! ## Heterogeneous fleets (`--fleet`)
//!
//! `partition`, `simulate`, `latency` and `partition-file` accept
//! `--fleet "SPEC"` to replace the workload's uniform `(k, ℓ, M)` scenario
//! with a typed device fleet. SPEC is comma-separated
//! `COUNTxNAME[@SPEED][:MEM]` entries; a name starting with `cpu` declares
//! a CPU class. Example:
//!
//! ```text
//! dnn-partition partition bert24 dp --fleet "2xfast@2:32768,4xslow:16384,1xcpu"
//! ```
//!
//! plans BERT-24 over 2 double-speed 32 GB accelerators, 4 baseline 16 GB
//! accelerators and one CPU — per-class memory caps and speeds are honored
//! by every planning algorithm (JSON files can declare the same under a
//! `fleet` key; see `workloads::json`). An optional `bw=X` entry sets the
//! interconnect bandwidth, `+acc`/`+cpu` suffixes force a class kind.
//!
//! An optional `topo=SPEC` entry declares a hierarchical interconnect
//! topology with per-device-pair comm costs (DESIGN.md §9):
//!
//! ```text
//! topo=uniform:900                   # all pairs at one rate (= scalar path)
//! topo=islands:2x4@900/64            # 2 islands of 4 accs; intra 900, inter 64
//! topo=islands:0.2|1.3@900/64        # explicit island membership by slot
//! topo=tiered:2x2x2@900/64/8         # hosts x islands x accs; nvlink/pcie/net
//! topo=matrix:0;5/5;0                # explicit per-pair bandwidth rows
//! dnn-partition partition bert24 dp --fleet "8xacc:32768,1xcpu,topo=islands:2x4@900/64"
//! ```
//!
//! CPU slots ride the slowest tier. Cross-island boundaries are priced
//! per device pair by every solver, the objective evaluators, and the
//! simulate replay; without `topo=` (or with `uniform:`) the legacy
//! scalar cost model applies bit-for-bit.
//!
//! ## Fleet simulation (`simulate`)
//!
//! `simulate` replays the plan through the `simx` discrete-event engine —
//! per-class speeds, per-class memory and bandwidth-delayed cross-device
//! transfers included — and accepts:
//!
//! * `--events "fail:acc0@t=5,slow:acc1*0.5@t=9,spike:+8@t=12"` — a
//!   scripted fault / straggler / load-spike scenario (the workload
//!   JSON's `events` string is the default). A `fail:` event also runs
//!   the re-planning loop: `Fleet::decrement` → re-plan → before/after
//!   steady-state TPS, demonstrating whether re-planning pays.
//! * `--schedule single-stream|pipelined|1f1b|gpipe` — override the
//!   default policy (1F1B for training workloads, pipelined otherwise).
//! * `--trace out.json` — dump the per-task/per-transfer trace (Chrome
//!   `trace_event` format: per-device and per-link lanes, memory peaks
//!   and stall diagnosis in the envelope metadata).
//! * `--assert-improves` — exit non-zero unless the re-planned
//!   time-per-sample strictly beats the degraded no-replan fallback
//!   (the CI smoke contract).
//! * `--monitor` — run the script through the closed serving loop
//!   instead of the open replay: a health monitor watches the trace and
//!   a hysteresis controller walks the degradation ladder (re-plan in
//!   place → decrement re-plan → CPU failover → shed). Prints the
//!   verdict plus a JSON decision trace (`--trace FILE` redirects the
//!   JSON to a file). Mutually exclusive with `--assert-improves`.
//!
//! ## Chaos campaigns (`chaos`)
//!
//! `chaos <wl> <alg>` fuzzes seeded fail/slow/recover/spike scripts
//! through the monitored loop (`--runs`, `--seed`, `--samples` control
//! the campaign; `--fleet` overrides the deployment) and checks the
//! resilience invariants of DESIGN.md §7 on every run — liveness with
//! classified shed causes, the hysteresis swap bound, near-oracle
//! steady-state throughput. Exits non-zero on any violation.

use dnn_partition::coordinator::context::{SolveBudget, SolveOpts};
use dnn_partition::coordinator::placement::{AlgoChoice, Device, Fleet};
use dnn_partition::coordinator::planner::{self, Algorithm};
use dnn_partition::obs;
use dnn_partition::simx::trace as simx_trace;
use dnn_partition::pipeline::sim::Schedule;
use dnn_partition::runtime::server::ServingPlanner;
use dnn_partition::simx::chaos::{ChaosCampaign, ChaosConfig};
use dnn_partition::simx::controller::{self, ControllerConfig, MonitorOutcome, Verdict};
use dnn_partition::simx::engine::{self as simx_engine, SimConfig, SimxResult};
use dnn_partition::simx::event::{EventScript, ScriptAction};
use dnn_partition::simx::loop_;
use dnn_partition::util::json::Json;
use dnn_partition::workloads::{self, json as wjson, Workload};
use std::time::Duration;

fn find_workload(name: &str) -> Option<Workload> {
    let (base, training) = match name.strip_suffix("-train") {
        Some(b) => (b, true),
        None => (name, false),
    };
    let all = workloads::table1_workloads();
    all.into_iter().find(|w| {
        let key = match (w.name.as_str(), w.granularity) {
            ("BERT-3", workloads::Granularity::Operator) => "bert3op",
            ("BERT-6", workloads::Granularity::Operator) => "bert6op",
            ("BERT-12", workloads::Granularity::Operator) => "bert12op",
            ("ResNet50", workloads::Granularity::Operator) => "resnet50op",
            ("BERT-24", _) => "bert24",
            ("ResNet50", _) => "resnet50",
            ("InceptionV3", _) => "inceptionv3",
            ("GNMT", _) => "gnmt",
            _ => "",
        };
        key == base && w.training == training
    })
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&args);
    std::process::exit(code);
}

/// Flags shared by the subcommands: `--fleet` everywhere, the simulation
/// flags on `simulate`.
#[derive(Default)]
struct CliFlags {
    fleet: Option<Fleet>,
    events: Option<EventScript>,
    schedule: Option<Schedule>,
    trace: Option<String>,
    assert_improves: bool,
    monitor: bool,
    runs: Option<usize>,
    seed: Option<u64>,
    samples: Option<usize>,
    smoke: bool,
    profile: Option<String>,
    deadline_ms: Option<u64>,
    node_limit: Option<u64>,
}

/// Strip `--NAME VALUE` / `--NAME=VALUE` flags out of the argument list,
/// returning the remaining positional args and the parsed flags.
fn extract_flags(args: &[String]) -> Result<(Vec<String>, CliFlags), String> {
    let mut rest = Vec::with_capacity(args.len());
    let mut flags = CliFlags::default();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        // `--NAME=VALUE` or `--NAME VALUE`
        let valued = |name: &str, i: &mut usize| -> Result<Option<String>, String> {
            if let Some(v) = a.strip_prefix(&format!("--{name}=")) {
                return Ok(Some(v.to_string()));
            }
            if a == &format!("--{name}") {
                let v = args
                    .get(*i + 1)
                    .ok_or(format!("--{name} requires an argument"))?;
                *i += 1;
                return Ok(Some(v.clone()));
            }
            Ok(None)
        };
        if let Some(spec) = valued("fleet", &mut i)? {
            flags.fleet = Some(Fleet::parse(&spec).map_err(|e| format!("bad --fleet: {e}"))?);
        } else if let Some(spec) = valued("events", &mut i)? {
            flags.events =
                Some(EventScript::parse(&spec).map_err(|e| format!("bad --events: {e}"))?);
        } else if let Some(name) = valued("schedule", &mut i)? {
            flags.schedule = Some(
                Schedule::parse(&name)
                    .ok_or(format!("bad --schedule: unknown policy '{name}'"))?,
            );
        } else if let Some(path) = valued("trace", &mut i)? {
            flags.trace = Some(path);
        } else if let Some(path) = valued("profile", &mut i)? {
            flags.profile = Some(path);
        } else if let Some(v) = valued("runs", &mut i)? {
            flags.runs =
                Some(v.parse().map_err(|_| format!("bad --runs: '{v}' is not a count"))?);
        } else if let Some(v) = valued("seed", &mut i)? {
            flags.seed =
                Some(v.parse().map_err(|_| format!("bad --seed: '{v}' is not a u64"))?);
        } else if let Some(v) = valued("samples", &mut i)? {
            flags.samples = Some(
                v.parse().map_err(|_| format!("bad --samples: '{v}' is not a count"))?,
            );
        } else if let Some(v) = valued("deadline-ms", &mut i)? {
            flags.deadline_ms = Some(
                v.parse()
                    .map_err(|_| format!("bad --deadline-ms: '{v}' is not a millisecond count"))?,
            );
        } else if let Some(v) = valued("node-limit", &mut i)? {
            flags.node_limit = Some(
                v.parse()
                    .map_err(|_| format!("bad --node-limit: '{v}' is not a node count"))?,
            );
        } else if a == "--assert-improves" {
            flags.assert_improves = true;
        } else if a == "--monitor" {
            flags.monitor = true;
        } else if a == "--smoke" {
            flags.smoke = true;
        } else if a.starts_with("--") {
            // a misspelled flag must not silently become a positional
            return Err(format!("unknown flag {a}"));
        } else {
            rest.push(a.clone());
        }
        i += 1;
    }
    Ok((rest, flags))
}

fn run(raw_args: &[String]) -> i32 {
    let (args, flags) = match extract_flags(raw_args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let fleet = flags.fleet.clone();
    let args = &args[..];
    // flags a subcommand would silently drop are rejected loudly instead
    let cmd = args.first().map(String::as_str);
    if cmd != Some("simulate")
        && (flags.events.is_some()
            || flags.schedule.is_some()
            || flags.trace.is_some()
            || flags.assert_improves
            || flags.monitor)
    {
        eprintln!(
            "--events/--schedule/--trace/--assert-improves/--monitor are only valid \
             with `simulate`"
        );
        return 2;
    }
    if flags.monitor && flags.assert_improves {
        // --assert-improves contracts the open-loop replan demo, which
        // the closed loop replaces wholesale
        eprintln!("--monitor and --assert-improves are mutually exclusive");
        return 2;
    }
    if cmd != Some("chaos")
        && (flags.runs.is_some() || flags.seed.is_some() || flags.samples.is_some())
    {
        eprintln!("--runs/--seed/--samples are only valid with `chaos`");
        return 2;
    }
    if flags.smoke && cmd != Some("bench-traffic") {
        eprintln!("--smoke is only valid with `bench-traffic`");
        return 2;
    }
    // deadline budgets only reach subcommands that honor them — anywhere
    // else the flag would silently plan without the deadline
    if flags.deadline_ms.is_some()
        && !matches!(cmd, Some("partition" | "bench-traffic" | "chaos"))
    {
        eprintln!("--deadline-ms is only valid with partition/bench-traffic/chaos");
        return 2;
    }
    if flags.node_limit.is_some() && cmd != Some("partition") {
        // without a deadline there is no ladder under it: a blown node
        // cap surfaces as an error, acceptable only where errors are loud
        eprintln!("--node-limit is only valid with `partition`");
        return 2;
    }
    if flags.profile.is_some()
        && !matches!(cmd, Some("partition" | "simulate" | "bench-traffic"))
    {
        eprintln!("--profile is only valid with partition/simulate/bench-traffic");
        return 2;
    }
    if flags.fleet.is_some()
        && !matches!(
            cmd,
            Some("partition" | "simulate" | "latency" | "partition-file" | "chaos")
        )
    {
        eprintln!(
            "--fleet is only valid with partition/simulate/latency/partition-file/chaos"
        );
        return 2;
    }
    // Profiling turns on span collection before the command runs; the
    // trace file is assembled afterwards from the recorder's wall-time
    // spans (pid 1) plus any virtual-time simx lanes the command
    // collected (pid 2).
    if flags.profile.is_some() {
        obs::set_enabled(true);
    }
    let mut sim_events: Vec<obs::TraceEvent> = Vec::new();
    let code = (|sim_events: &mut Vec<obs::TraceEvent>| -> i32 {
    match args.first().map(String::as_str) {
        Some("list") => {
            println!("{:<14} {:>6} {:>7} {:>3}  granularity  task", "workload", "nodes", "edges", "k");
            for w in workloads::table1_workloads() {
                println!(
                    "{:<14} {:>6} {:>7} {:>3}  {:<11}  {}",
                    format!(
                        "{}{}",
                        cli_key(&w),
                        if w.training { "-train" } else { "" }
                    ),
                    w.graph.n(),
                    w.graph.num_edges(),
                    w.scenario.k,
                    format!("{:?}", w.granularity),
                    if w.training { "training" } else { "inference" },
                );
            }
            println!(
                "\nk above is the paper's uniform deployment; override with\n\
                 --fleet \"COUNTxNAME[@SPEED][:MEM],…\" on partition/simulate/\n\
                 latency/partition-file, e.g. --fleet \"2xfast@2:32768,4xslow:16384,1xcpu\";\n\
                 add topo=islands:2x4@900/64 (or tiered:/matrix:/uniform:) for\n\
                 per-device-pair interconnect costs"
            );
            0
        }
        Some("partition") if args.len() >= 3 => {
            let Some(mut w) = find_workload(&args[1]) else {
                eprintln!("unknown workload {}", args[1]);
                return 2;
            };
            w.fleet = fleet.clone().or(w.fleet);
            let Some(alg) = Algorithm::parse(&args[2]) else {
                eprintln!("unknown algorithm {}", args[2]);
                return 2;
            };
            let budget = Duration::from_secs(
                args.get(3).and_then(|s| s.parse().ok()).unwrap_or(20),
            );
            let opts = SolveOpts {
                ip_budget: budget,
                expert: w.expert,
                budget: solve_budget(&flags),
                ..SolveOpts::default()
            };
            match planner::plan_opts(&w, alg, &opts) {
                Ok(r) => {
                    println!(
                        "{} {:?}: TPS {:.2}  runtime {:?}  quality {}{}",
                        w.name,
                        alg,
                        r.placement.objective,
                        r.runtime,
                        r.quality,
                        r.gap.map(|g| format!("  gap {:.1}%", g * 100.0)).unwrap_or_default()
                    );
                    print_split(&w, &r.placement);
                    0
                }
                Err(e) => {
                    eprintln!("planning failed: {e}");
                    1
                }
            }
        }
        Some("latency") if args.len() >= 2 => {
            let Some(mut w) = find_workload(&args[1]) else {
                eprintln!("unknown workload {}", args[1]);
                return 2;
            };
            w.scenario = workloads::latency_scenario(&w.graph);
            w.fleet = fleet.clone().or(w.fleet);
            let budget =
                Duration::from_secs(args.get(2).and_then(|s| s.parse().ok()).unwrap_or(20));
            match planner::plan(&w, Algorithm::IpLatency, budget) {
                Ok(r) => {
                    let deployed = match &w.fleet {
                        Some(f) => format!("fleet {f}"),
                        None => {
                            format!("k={}, M={:.0}", w.scenario.k, w.scenario.mem_cap)
                        }
                    };
                    println!(
                        "{}: latency {:.2} ({deployed})  runtime {:?}{}",
                        w.name,
                        r.placement.objective,
                        r.runtime,
                        r.gap.map(|g| format!("  gap {:.1}%", g * 100.0)).unwrap_or_default()
                    );
                    0
                }
                Err(e) => {
                    eprintln!("planning failed: {e}");
                    1
                }
            }
        }
        Some("simulate") if args.len() >= 3 => {
            // built-in name, or a workload JSON file (whose optional
            // `fleet`/`events` sections then apply)
            let mut w = match find_workload(&args[1]) {
                Some(w) => w,
                None => match load_workload_file(&args[1]) {
                    Ok(Some(w)) => w,
                    Ok(None) => {
                        eprintln!("unknown workload {}", args[1]);
                        return 2;
                    }
                    Err(e) => {
                        eprintln!("bad workload file {}: {e}", args[1]);
                        return 2;
                    }
                },
            };
            w.fleet = fleet.clone().or(w.fleet);
            let Some(alg) = Algorithm::parse(&args[2]) else {
                eprintln!("unknown algorithm {}", args[2]);
                return 2;
            };
            let n = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(12);
            let r = match planner::plan(&w, alg, Duration::from_secs(10)) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("planning failed: {e}");
                    return 1;
                }
            };
            // the simx engine replays the plan on the fleet itself:
            // per-class speeds and caps, bandwidth-delayed link transfers
            let req = w.request();
            let schedule = flags.schedule.unwrap_or(Schedule::default_for(w.training));
            let script = flags.events.clone().or(w.events.clone()).unwrap_or_default();
            for e in &script.events {
                let dev = match e.action {
                    ScriptAction::Fail { device }
                    | ScriptAction::Slow { device, .. }
                    | ScriptAction::Recover { device } => device,
                    ScriptAction::Spike { .. } => continue,
                };
                let in_range = match dev {
                    Device::Acc(i) => i < req.fleet.k(),
                    Device::Cpu(j) => j < req.fleet.l().max(1),
                };
                if !in_range {
                    eprintln!("bad --events: {dev} is outside the deployment");
                    return 2;
                }
            }
            if flags.monitor {
                // closed loop: health monitor + hysteresis controller
                // instead of the open replay + one-shot replan demo
                let opts = SolveOpts {
                    ip_budget: Duration::from_secs(10),
                    expert: w.expert,
                    ..SolveOpts::default()
                };
                let mut serving = ServingPlanner::new(alg, opts);
                let loop_req = req.clone().algorithm(AlgoChoice::Fixed(alg));
                let out = match controller::run_monitored(
                    &w.graph,
                    &loop_req,
                    &script,
                    schedule,
                    n,
                    &mut serving,
                    &ControllerConfig::default(),
                ) {
                    Ok(out) => out,
                    Err(e) => {
                        eprintln!("monitored run failed: {e}");
                        return 1;
                    }
                };
                let verdict = match &out.verdict {
                    Verdict::Completed => "completed".to_string(),
                    Verdict::Shed(cause) => format!("shed ({cause})"),
                };
                println!(
                    "{} {:?} [{schedule}] monitored: {verdict}; {}/{} samples \
                     completed, {} shed; {} plan swap(s) over {} epoch(s); \
                     final steady time-per-sample {:.2}",
                    w.name,
                    alg,
                    out.completed,
                    out.injected,
                    out.shed,
                    out.plan_swaps,
                    out.epochs,
                    out.final_steady_tps
                );
                for d in &out.decisions {
                    println!(
                        "  t={:<8.2} {} -> {} [{}] {}",
                        d.t,
                        d.trigger,
                        d.action,
                        if d.accepted { "accepted" } else { "rejected" },
                        d.reason
                    );
                }
                if flags.profile.is_some() {
                    sim_events.extend(simx_trace::decision_events(&out, 2, 0));
                }
                let json = monitor_to_json(&w, alg, schedule, &out);
                match &flags.trace {
                    Some(path) => {
                        if std::fs::write(path, json.to_string_pretty()).is_err() {
                            eprintln!("cannot write {path}");
                            return 1;
                        }
                        println!("decision trace written to {path}");
                    }
                    None => println!("{}", json.to_string_pretty()),
                }
                return i32::from(out.verdict != Verdict::Completed);
            }
            // fleet runs model the interconnect as a link resource; the
            // plain scalar path keeps the §3-exact regime the printed
            // prediction is computed under (instant hand-offs)
            let cfg = if w.fleet.is_some() {
                SimConfig::for_request(&req)
            } else {
                SimConfig::default()
            };
            let res = simx_engine::simulate_with_events(
                &w.graph,
                &req,
                &r.placement,
                schedule,
                n,
                &script,
                &cfg,
            );
            simx_trace::record_obs(&res);
            if flags.profile.is_some() {
                sim_events.extend(simx_trace::trace_events(&res, 2));
            }
            println!(
                "{} {:?} [{schedule}]: predicted TPS {:.2}, simulated steady-state {:.2} \
                 over {}/{} samples",
                w.name, alg, r.placement.objective, res.steady_tps, res.completed, res.injected
            );
            if let Some(stall) = res.stall {
                println!("stalled: {stall}");
            }
            println!("{}", res.render_timeline(100));
            if let Some(path) = &flags.trace {
                let json = trace_to_json(&w, alg, schedule, &req, &res);
                if std::fs::write(path, json.to_string_pretty()).is_err() {
                    eprintln!("cannot write {path}");
                    return 1;
                }
                println!("trace written to {path}");
            }
            // the replan demo reacts to accelerator loss only (CPU faults
            // simulate fine above but have no class to decrement)
            if script.first_acc_fail().is_some() {
                // drift-driven re-planning loop: decrement the lost
                // device's class, re-plan, compare in simulation
                let opts = SolveOpts { ip_budget: Duration::from_secs(10), expert: w.expert,
                    ..SolveOpts::default() };
                let mut serving = ServingPlanner::new(alg, opts);
                let loop_req = req.clone().algorithm(AlgoChoice::Fixed(alg));
                // the healthy plan and the disrupted replay were already
                // computed above — hand them over instead of paying twice
                match loop_::run_device_loss_demo_with(
                    &w.graph,
                    &loop_req,
                    &script,
                    schedule,
                    n,
                    &mut serving,
                    &r.placement,
                    &res,
                ) {
                    Ok(demo) => {
                        println!(
                            "replan: {} ({}) lost at t={}; disrupted run completed {}/{}; \
                             time-per-sample healthy {:.2} | degraded (cpu failover) {:.2} | \
                             re-planned {:.2}  (replan gain {:.2}x)",
                            demo.failed_device,
                            demo.failed_class,
                            demo.fail_time,
                            demo.disrupted_completed,
                            demo.disrupted_injected,
                            demo.healthy_tps,
                            demo.degraded_tps,
                            demo.replanned_tps,
                            demo.improvement()
                        );
                        if flags.assert_improves && demo.replanned_tps >= demo.degraded_tps {
                            eprintln!(
                                "re-planned TPS {:.3} does not beat degraded {:.3}",
                                demo.replanned_tps, demo.degraded_tps
                            );
                            return 1;
                        }
                    }
                    Err(e) => {
                        eprintln!("replan demo failed: {e}");
                        return 1;
                    }
                }
            } else if flags.assert_improves {
                eprintln!("--assert-improves requires an accelerator fail: event in --events");
                return 2;
            }
            0
        }
        Some("chaos") if args.len() >= 3 => {
            let mut w = match find_workload(&args[1]) {
                Some(w) => w,
                None => match load_workload_file(&args[1]) {
                    Ok(Some(w)) => w,
                    Ok(None) => {
                        eprintln!("unknown workload {}", args[1]);
                        return 2;
                    }
                    Err(e) => {
                        eprintln!("bad workload file {}: {e}", args[1]);
                        return 2;
                    }
                },
            };
            w.fleet = fleet.clone().or(w.fleet);
            let Some(alg) = Algorithm::parse(&args[2]) else {
                eprintln!("unknown algorithm {}", args[2]);
                return 2;
            };
            let req = w.request().algorithm(AlgoChoice::Fixed(alg));
            let mut cfg = ChaosConfig::default();
            if let Some(runs) = flags.runs {
                cfg.runs = runs;
            }
            if let Some(seed) = flags.seed {
                cfg.seed = seed;
            }
            if let Some(samples) = flags.samples {
                cfg.samples_min = samples;
                cfg.samples_max = samples;
            }
            let opts = SolveOpts {
                ip_budget: Duration::from_secs(10),
                expert: w.expert,
                ..SolveOpts::default()
            };
            let mut serving = ServingPlanner::new(alg, opts);
            if let Some(ms) = flags.deadline_ms {
                // tight-deadline variant: every re-plan inside the
                // monitored loop runs under this budget and degrades
                // through the ladder instead of blowing the campaign
                serving = serving.with_deadline(Duration::from_millis(ms));
            }
            let camp = ChaosCampaign::new(&w.graph, &req, cfg);
            let report = camp.run(&mut serving);
            println!(
                "{} {:?} chaos: {} run(s) from seed {:#x} — {} completed, {} shed",
                w.name,
                alg,
                report.runs.len(),
                camp.cfg.seed,
                report.completed_runs,
                report.shed_runs
            );
            for (cause, count) in &report.shed_by_cause {
                println!("  shed by {cause}: {count}");
            }
            let swaps: usize = report.runs.iter().map(|r| r.plan_swaps).sum();
            let checked = report.runs.iter().filter(|r| r.oracle_ratio.is_some()).count();
            println!(
                "  {} plan swap(s) total; oracle invariant checked on {} run(s)",
                swaps, checked
            );
            match report.ok() {
                Ok(()) => {
                    println!("all invariants held");
                    0
                }
                Err(e) => {
                    eprintln!("chaos invariants violated: {e}");
                    for v in &report.violations {
                        eprintln!("  {v}");
                    }
                    1
                }
            }
        }
        Some("export") if args.len() >= 3 => {
            let Some(w) = find_workload(&args[1]) else {
                eprintln!("unknown workload {}", args[1]);
                return 2;
            };
            let json = wjson::to_json(&w).to_string_pretty();
            if std::fs::write(&args[2], json).is_err() {
                eprintln!("cannot write {}", args[2]);
                return 1;
            }
            println!("wrote {}", args[2]);
            0
        }
        Some("partition-file") if args.len() >= 3 => {
            let Ok(text) = std::fs::read_to_string(&args[1]) else {
                eprintln!("cannot read {}", args[1]);
                return 1;
            };
            let json = match Json::parse(&text) {
                Ok(j) => j,
                Err(e) => {
                    eprintln!("bad JSON: {e}");
                    return 1;
                }
            };
            let mut w = match wjson::from_json_workload(&json) {
                Ok(x) => x,
                Err(e) => {
                    eprintln!("bad workload: {e}");
                    return 1;
                }
            };
            let Some(alg) = Algorithm::parse(&args[2]) else {
                eprintln!("unknown algorithm {}", args[2]);
                return 2;
            };
            // CLI --fleet wins over the file's own fleet section
            w.fleet = fleet.clone().or(w.fleet);
            match planner::plan(&w, alg, Duration::from_secs(20)) {
                Ok(r) => {
                    println!("{} {:?}: TPS {:.2} in {:?}", w.name, alg, r.placement.objective, r.runtime);
                    0
                }
                Err(e) => {
                    eprintln!("planning failed: {e}");
                    1
                }
            }
        }
        Some("bench-traffic") => run_bench_traffic(flags.smoke, flags.deadline_ms),
        Some("stats") => run_stats(),
        _ => {
            eprintln!(
                "usage: dnn-partition <list|partition|latency|simulate|chaos|export|\
                 partition-file|bench-traffic|stats> …\n\
                 see `cargo doc` or README.md for details"
            );
            2
        }
    }
    })(&mut sim_events);
    if let Some(path) = &flags.profile {
        match write_profile(path, &sim_events) {
            Ok(()) => println!("profile written to {path}"),
            Err(e) => {
                eprintln!("{e}");
                if code == 0 {
                    return 1;
                }
            }
        }
    }
    code
}

/// The cooperative-cancellation budget from `--deadline-ms`/`--node-limit`
/// (unlimited when neither flag is given — bitwise the pre-budget CLI).
/// Deadlines are relative to *now*, so call this right before the solve it
/// budgets.
fn solve_budget(flags: &CliFlags) -> SolveBudget {
    let mut b = match flags.deadline_ms {
        Some(ms) => SolveBudget::deadline_in(Duration::from_millis(ms)),
        None => SolveBudget::UNLIMITED,
    };
    b.node_limit = flags.node_limit;
    b
}

/// Assemble and write the `--profile` Chrome trace: recorder spans as
/// wall-time lanes on pid 1, simx virtual-time lanes (if the command
/// produced any) on pid 2.
fn write_profile(path: &str, sim_events: &[obs::TraceEvent]) -> Result<(), String> {
    obs::flush_thread();
    let snap = obs::snapshot();
    let mut events = obs::span_events(&snap, 1);
    events.extend_from_slice(sim_events);
    let json = obs::chrome_trace(&events, Vec::new());
    std::fs::write(path, json.to_string_pretty())
        .map_err(|e| format!("cannot write {path}: {e}"))
}

/// `stats`: run a representative planning/simulation exercise (context
/// builds, cache hits and dedup, an IP search, a linked simulation) and
/// print the obs registry in Prometheus text exposition format.
fn run_stats() -> i32 {
    use dnn_partition::coordinator::concurrent::ConcurrentService;
    use dnn_partition::coordinator::placement::Scenario;
    use dnn_partition::graph::{Node, OpGraph};

    // a chain that provably splits across the three accelerators (the
    // simx engine tests pin this shape), so every metric family below has
    // non-trivial traffic: ctx builds, shard hit/miss, IP search, device
    // utilization and cross-device link bytes
    let mut g = OpGraph::new();
    for i in 0..6 {
        g.add_node(Node::new(format!("c{i}")).cpu(10.0).acc(1.0).mem(1.0).comm(0.5));
    }
    for i in 1..6 {
        g.add_edge(i - 1, i);
    }
    let sc = Scenario::new(3, 1, f64::INFINITY);
    let opts = SolveOpts { ip_budget: Duration::from_secs(2), ..SolveOpts::default() };
    let svc = ConcurrentService::default();

    // one miss, one hit (per-shard counters + plan latency histograms)
    for _ in 0..2 {
        if let Err(e) = svc.plan(&g, &sc, Algorithm::Dp, &opts) {
            eprintln!("stats exercise failed: {e}");
            return 1;
        }
    }
    // an IP search on the same cached context (nodes, prunes, incumbents)
    let ip = match svc.plan(&g, &sc, Algorithm::IpContiguous, &opts) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("stats exercise failed: {e}");
            return 1;
        }
    };
    // a zero-budget Auto plan drives the degradation ladder, so the
    // deadline/fallback counter families (plan_deadline_hits_total,
    // plan_fallback_total{rung=…}) show up in the dump below — counters
    // live per process, so the exercise must produce its own traffic
    let tight =
        SolveOpts { budget: SolveBudget::deadline_in(Duration::ZERO), ..opts.clone() };
    if let Err(e) = svc.plan_request(&g, &sc.to_request(), &tight) {
        eprintln!("stats exercise failed: {e}");
        return 1;
    }
    // a linked simulation (device utilization, per-pair link bytes)
    let req = sc.to_request();
    let cfg = SimConfig { link_bandwidth: Some(1.0), ..SimConfig::default() };
    let res =
        simx_engine::simulate_req(&g, &req, &ip.placement, Schedule::Pipelined, 8, &cfg);
    simx_trace::record_obs(&res);

    print!("{}", obs::prometheus(&obs::snapshot()));
    0
}

/// `bench-traffic [--smoke]`: hammer one shared
/// [`ConcurrentService`](dnn_partition::coordinator::concurrent::ConcurrentService)
/// with a seeded synthetic request stream from worker threads and report
/// p50/p99 plan latency, cache hit/dedup rates, and scaling vs the
/// single-threaded drain. `--smoke` is the CI configuration: small stream,
/// tiny IP budgets, and hard assertions on the concurrency invariants
/// (every request planned; hits + misses + dedup waits account for all of
/// them; misses never exceed the distinct fingerprints — the single-flight
/// bound). `--deadline-ms` puts every request under a per-solve
/// [`SolveBudget`] deadline: requests then answer through the anytime
/// search or the degradation ladder, and the same invariants must still
/// hold — a deadline may degrade an answer, never lose one.
fn run_bench_traffic(smoke: bool, deadline_ms: Option<u64>) -> i32 {
    use dnn_partition::coordinator::concurrent::ConcurrentService;
    use dnn_partition::coordinator::context::fingerprint_req;
    use dnn_partition::coordinator::placement::{DeviceClass, Objective, PlanRequest};
    use dnn_partition::util::proptest::random_dag;
    use dnn_partition::util::rng::Rng;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Instant;

    let (n_requests, graph_nodes) = if smoke { (48, 8) } else { (400, 12) };
    let mut rng = Rng::new(0x7AFF1C);
    let graphs: Vec<_> = (0..3).map(|i| random_dag(&mut rng, graph_nodes + i, 0.3)).collect();
    let fleets = vec![
        Fleet::uniform(2, 1, f64::INFINITY),
        Fleet::uniform(3, 1, f64::INFINITY),
        Fleet::new(vec![
            DeviceClass::acc("fast", 1, f64::INFINITY).speed(2.0),
            DeviceClass::acc("slow", 2, f64::INFINITY),
            DeviceClass::cpu("cpu", 1),
        ]),
    ];
    let stream: Vec<(usize, PlanRequest)> = (0..n_requests)
        .map(|_| {
            let req = PlanRequest::new(fleets[rng.gen_range(fleets.len())].clone());
            let req = match rng.gen_range(4) {
                0 => req
                    .objective(Objective::Throughput)
                    .algorithm(AlgoChoice::Fixed(Algorithm::IpContiguous)),
                1 => req.objective(Objective::Throughput).contiguous(false),
                2 => req.objective(Objective::Latency).contiguous(rng.gen_bool(0.5)),
                _ => req
                    .objective(Objective::Throughput)
                    .algorithm(AlgoChoice::Fixed(Algorithm::Dp)),
            };
            (rng.gen_range(graphs.len()), req)
        })
        .collect();
    let mut fps: Vec<u64> =
        stream.iter().map(|(g, r)| fingerprint_req(&graphs[*g], r)).collect();
    fps.sort_unstable();
    fps.dedup();
    let distinct = fps.len();
    let opts = SolveOpts {
        ip_budget: Duration::from_millis(if smoke { 15 } else { 50 }),
        ..SolveOpts::default()
    };

    let drain = |svc: &ConcurrentService, m: usize| -> (Duration, Vec<f64>) {
        let next = AtomicUsize::new(0);
        let t0 = Instant::now();
        let mut lat_ms: Vec<f64> = Vec::with_capacity(stream.len());
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..m)
                .map(|_| {
                    let next = &next;
                    let stream = &stream;
                    let graphs = &graphs;
                    let opts = &opts;
                    scope.spawn(move || {
                        let mut mine = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            let Some((g, req)) = stream.get(i) else { break };
                            let t = Instant::now();
                            // per-request budget: the deadline clock starts
                            // when the request is picked up, not when the
                            // stream was built
                            let mut o = opts.clone();
                            if let Some(ms) = deadline_ms {
                                o.budget = SolveBudget::deadline_in(Duration::from_millis(ms));
                            }
                            svc.plan_request(&graphs[*g], req, &o)
                                .expect("traffic request must plan");
                            mine.push(t.elapsed().as_secs_f64() * 1e3);
                        }
                        mine
                    })
                })
                .collect();
            for h in handles {
                lat_ms.extend(h.join().expect("worker panicked"));
            }
        });
        (t0.elapsed(), lat_ms)
    };
    let pctl = |sorted: &[f64], p: f64| -> f64 {
        sorted[((sorted.len() as f64 - 1.0) * p).round() as usize]
    };

    println!(
        "bench-traffic{}{}: {n_requests} requests over {} graphs × {} fleets \
         ({distinct} distinct problems)",
        if smoke { " --smoke" } else { "" },
        deadline_ms.map(|ms| format!(" --deadline-ms {ms}")).unwrap_or_default(),
        graphs.len(),
        fleets.len(),
    );
    let base_svc = ConcurrentService::new(8, 64);
    let (base_wall, mut base_lat) = drain(&base_svc, 1);
    base_lat.sort_by(f64::total_cmp);
    for m in [1usize, 4] {
        let (hits, wall, lat) = if m == 1 {
            (base_svc.hits(), base_wall, base_lat.clone()) // reuse the baseline drain
        } else {
            let svc = ConcurrentService::new(8, 64);
            let (wall, mut lat) = drain(&svc, m);
            lat.sort_by(f64::total_cmp);
            if lat.len() != n_requests
                || svc.hits() + svc.misses() + svc.dedup_waits() != n_requests
                || svc.misses() > distinct
            {
                eprintln!(
                    "traffic invariants violated: {} planned, {} hits + {} misses + \
                     {} dedup waits, {distinct} distinct",
                    lat.len(),
                    svc.hits(),
                    svc.misses(),
                    svc.dedup_waits(),
                );
                return 1;
            }
            (svc.hits(), wall, lat)
        };
        println!(
            "  m={m}: wall {:8.1} ms  p50 {:6.2} ms  p99 {:6.2} ms  hits {hits}  scaling {:.2}x",
            wall.as_secs_f64() * 1e3,
            pctl(&lat, 0.50),
            pctl(&lat, 0.99),
            base_wall.as_secs_f64() / wall.as_secs_f64(),
        );
    }
    println!("bench-traffic OK");
    0
}

/// Load a workload JSON file as a simulate target (its optional `fleet`
/// and `events` sections apply). `Ok(None)` = not a readable file (fall
/// back to the unknown-workload message); `Err` = the file exists but is
/// malformed (a distinct, precise diagnostic).
fn load_workload_file(path: &str) -> Result<Option<Workload>, String> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        // absent file = the arg was a (bad) workload name, not a path
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.to_string()),
    };
    Json::parse(&text)
        .map_err(|e| e.to_string())
        .and_then(|j| wjson::from_json_workload(&j))
        .map(Some)
}

fn cli_key(w: &Workload) -> String {
    match (w.name.as_str(), w.granularity) {
        ("BERT-3", workloads::Granularity::Operator) => "bert3op".into(),
        ("BERT-6", workloads::Granularity::Operator) => "bert6op".into(),
        ("BERT-12", workloads::Granularity::Operator) => "bert12op".into(),
        ("ResNet50", workloads::Granularity::Operator) => "resnet50op".into(),
        ("BERT-24", _) => "bert24".into(),
        ("ResNet50", _) => "resnet50".into(),
        ("InceptionV3", _) => "inceptionv3".into(),
        ("GNMT", _) => "gnmt".into(),
        _ => w.name.to_lowercase(),
    }
}

/// The `simulate --monitor` JSON decision trace: verdict, counters, and
/// every controller decision / health transition with timestamps.
fn monitor_to_json(
    w: &Workload,
    alg: Algorithm,
    schedule: Schedule,
    out: &MonitorOutcome,
) -> Json {
    let decisions: Vec<Json> = out
        .decisions
        .iter()
        .map(|d| {
            Json::obj(vec![
                ("t", Json::num(d.t)),
                ("trigger", Json::str(d.trigger.clone())),
                ("action", Json::str(d.action.clone())),
                ("accepted", Json::Bool(d.accepted)),
                ("reason", Json::str(d.reason.clone())),
                ("predictedBefore", Json::num(d.predicted_before)),
                ("predictedAfter", Json::num(d.predicted_after)),
                ("swapsSoFar", Json::num(d.swaps_so_far as f64)),
            ])
        })
        .collect();
    let transitions: Vec<Json> = out
        .transitions
        .iter()
        .map(|tr| {
            Json::obj(vec![
                ("t", Json::num(tr.t)),
                ("device", Json::num(tr.dev as f64)),
                ("from", Json::str(tr.from.to_string())),
                ("to", Json::str(tr.to.to_string())),
                ("why", Json::str(tr.why.clone())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("workload", Json::str(w.name.clone())),
        ("algorithm", Json::str(alg.name())),
        ("schedule", Json::str(schedule.name())),
        ("fleet", Json::str(out.final_request.fleet.to_string())),
        (
            "verdict",
            Json::str(match &out.verdict {
                Verdict::Completed => "completed".to_string(),
                Verdict::Shed(cause) => format!("shed:{cause}"),
            }),
        ),
        ("injected", Json::num(out.injected as f64)),
        ("completed", Json::num(out.completed as f64)),
        ("shed", Json::num(out.shed as f64)),
        ("makespan", Json::num(out.makespan)),
        ("finalSteadyTps", Json::num(out.final_steady_tps)),
        ("planSwaps", Json::num(out.plan_swaps as f64)),
        ("swapTimes", Json::Arr(out.swap_times.iter().map(|&t| Json::num(t)).collect())),
        ("epochs", Json::num(out.epochs as f64)),
        ("timeUnit", Json::num(out.time_unit)),
        ("cooldown", Json::num(out.cooldown)),
        ("decisions", Json::Arr(decisions)),
        ("transitions", Json::Arr(transitions)),
    ])
}

/// Serialize a simulation run for `simulate --trace FILE` in Chrome
/// `trace_event` format (one trace format across the CLI): tasks and
/// transfers become per-device / per-link lanes with sample/piece/bytes
/// detail in event `args`; run summary, memory peaks and stall diagnosis
/// ride in the envelope metadata keys viewers ignore.
fn trace_to_json(
    w: &Workload,
    alg: Algorithm,
    schedule: Schedule,
    req: &dnn_partition::prelude::PlanRequest,
    res: &SimxResult,
) -> Json {
    let num_or_null =
        |v: f64| if v.is_finite() { Json::num(v) } else { Json::Null };
    let events = simx_trace::trace_events(res, 2);
    obs::chrome_trace(
        &events,
        vec![
            ("workload", Json::str(w.name.clone())),
            ("algorithm", Json::str(alg.name())),
            ("schedule", Json::str(schedule.name())),
            ("fleet", Json::str(req.fleet.to_string())),
            ("steadyTps", num_or_null(res.steady_tps)),
            ("total", num_or_null(res.total)),
            ("completed", Json::num(res.completed as f64)),
            ("injected", Json::num(res.injected as f64)),
            ("eventsProcessed", Json::num(res.events_processed as f64)),
            (
                "stall",
                match res.stall {
                    Some(s) => Json::str(s.to_string()),
                    None => Json::Null,
                },
            ),
            ("memPeak", Json::Arr(res.mem_peak.iter().map(|&m| Json::num(m)).collect())),
        ],
    )
}

fn print_split(w: &Workload, p: &dnn_partition::prelude::Placement) {
    let n = w.graph.n();
    let req = w.request();
    for i in 0..req.fleet.k() {
        let set = p.set_of(Device::Acc(i), n);
        let class = req.fleet.class_of(Device::Acc(i));
        let (name, cap) = class.map_or(("acc", f64::INFINITY), |c| (c.name.as_str(), c.mem_cap));
        let cap_str = if cap.is_finite() { format!("/{cap:.0}") } else { String::new() };
        println!(
            "  acc{i} ({name}): {} nodes, {:.1}{cap_str} MB",
            set.len(),
            w.graph.mem_of(&set)
        );
    }
    for j in 0..req.fleet.l().max(1) {
        let set = p.set_of(Device::Cpu(j), n);
        if !set.is_empty() {
            println!("  cpu{j}: {} nodes", set.len());
        }
    }
}

//! `dnn-partition` CLI — the leader entrypoint.
//!
//! ```text
//! dnn-partition list                       # show the built-in workloads
//! dnn-partition partition <wl> <alg>       # plan a pipelined split
//! dnn-partition latency <wl>               # §7 latency planning
//! dnn-partition simulate <wl> <alg> [n]    # pipeline simulation + timeline
//! dnn-partition export <wl> <out.json>     # dump paper-format JSON
//! dnn-partition partition-file <in.json> <alg>   # plan an external workload
//! ```
//!
//! Workload names: `bert3op`, `bert6op`, `bert12op`, `resnet50op`,
//! `bert24`, `resnet50`, `inceptionv3`, `gnmt` — suffix `-train` for the
//! training variant (e.g. `bert24-train`).
//!
//! Algorithms: `dp`, `dpl`, `ip`/`ip-contiguous`, `ipnc`/`ip-noncontiguous`,
//! `ip-latency`, `replication`, `hierarchy`, `expert`, `ls`/`local-search`,
//! `pipedream`, `scotch`, `greedy`.
//!
//! ## Heterogeneous fleets (`--fleet`)
//!
//! `partition`, `simulate`, `latency` and `partition-file` accept
//! `--fleet "SPEC"` to replace the workload's uniform `(k, ℓ, M)` scenario
//! with a typed device fleet. SPEC is comma-separated
//! `COUNTxNAME[@SPEED][:MEM]` entries; a name starting with `cpu` declares
//! a CPU class. Example:
//!
//! ```text
//! dnn-partition partition bert24 dp --fleet "2xfast@2:32768,4xslow:16384,1xcpu"
//! ```
//!
//! plans BERT-24 over 2 double-speed 32 GB accelerators, 4 baseline 16 GB
//! accelerators and one CPU — per-class memory caps and speeds are honored
//! by every planning algorithm (JSON files can declare the same under a
//! `fleet` key; see `workloads::json`). An optional `bw=X` entry sets the
//! interconnect bandwidth, `+acc`/`+cpu` suffixes force a class kind. The
//! `simulate` command plans fleet-aware but replays the schedule on the
//! scalar uniform view (the discrete-event simulator is not yet
//! fleet-aware; it prints a note when a fleet is active).

use dnn_partition::coordinator::placement::Fleet;
use dnn_partition::coordinator::planner::{self, Algorithm};
use dnn_partition::pipeline::sim::{self, Schedule};
use dnn_partition::util::json::Json;
use dnn_partition::workloads::{self, json as wjson, Workload};
use std::time::Duration;

fn find_workload(name: &str) -> Option<Workload> {
    let (base, training) = match name.strip_suffix("-train") {
        Some(b) => (b, true),
        None => (name, false),
    };
    let all = workloads::table1_workloads();
    all.into_iter().find(|w| {
        let key = match (w.name.as_str(), w.granularity) {
            ("BERT-3", workloads::Granularity::Operator) => "bert3op",
            ("BERT-6", workloads::Granularity::Operator) => "bert6op",
            ("BERT-12", workloads::Granularity::Operator) => "bert12op",
            ("ResNet50", workloads::Granularity::Operator) => "resnet50op",
            ("BERT-24", _) => "bert24",
            ("ResNet50", _) => "resnet50",
            ("InceptionV3", _) => "inceptionv3",
            ("GNMT", _) => "gnmt",
            _ => "",
        };
        key == base && w.training == training
    })
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&args);
    std::process::exit(code);
}

/// Strip `--fleet SPEC` / `--fleet=SPEC` out of the argument list,
/// returning the remaining positional args and the parsed fleet (if any).
fn extract_fleet(args: &[String]) -> Result<(Vec<String>, Option<Fleet>), String> {
    let mut rest = Vec::with_capacity(args.len());
    let mut fleet = None;
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(spec) = a.strip_prefix("--fleet=") {
            fleet = Some(Fleet::parse(spec)?);
        } else if a == "--fleet" {
            let spec = args.get(i + 1).ok_or("--fleet requires a spec argument")?;
            fleet = Some(Fleet::parse(spec)?);
            i += 1;
        } else {
            rest.push(a.clone());
        }
        i += 1;
    }
    Ok((rest, fleet))
}

fn run(raw_args: &[String]) -> i32 {
    let (args, fleet) = match extract_fleet(raw_args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("bad --fleet: {e}");
            return 2;
        }
    };
    let args = &args[..];
    match args.first().map(String::as_str) {
        Some("list") => {
            println!("{:<14} {:>6} {:>7} {:>3}  granularity  task", "workload", "nodes", "edges", "k");
            for w in workloads::table1_workloads() {
                println!(
                    "{:<14} {:>6} {:>7} {:>3}  {:<11}  {}",
                    format!(
                        "{}{}",
                        cli_key(&w),
                        if w.training { "-train" } else { "" }
                    ),
                    w.graph.n(),
                    w.graph.num_edges(),
                    w.scenario.k,
                    format!("{:?}", w.granularity),
                    if w.training { "training" } else { "inference" },
                );
            }
            println!(
                "\nk above is the paper's uniform deployment; override with\n\
                 --fleet \"COUNTxNAME[@SPEED][:MEM],…\" on partition/simulate/\n\
                 latency/partition-file, e.g. --fleet \"2xfast@2:32768,4xslow:16384,1xcpu\""
            );
            0
        }
        Some("partition") if args.len() >= 3 => {
            let Some(mut w) = find_workload(&args[1]) else {
                eprintln!("unknown workload {}", args[1]);
                return 2;
            };
            w.fleet = fleet.clone().or(w.fleet);
            let Some(alg) = Algorithm::parse(&args[2]) else {
                eprintln!("unknown algorithm {}", args[2]);
                return 2;
            };
            let budget = Duration::from_secs(
                args.get(3).and_then(|s| s.parse().ok()).unwrap_or(20),
            );
            match planner::plan(&w, alg, budget) {
                Ok(r) => {
                    println!(
                        "{} {:?}: TPS {:.2}  runtime {:?}{}",
                        w.name,
                        alg,
                        r.placement.objective,
                        r.runtime,
                        r.gap.map(|g| format!("  gap {:.1}%", g * 100.0)).unwrap_or_default()
                    );
                    print_split(&w, &r.placement);
                    0
                }
                Err(e) => {
                    eprintln!("planning failed: {e}");
                    1
                }
            }
        }
        Some("latency") if args.len() >= 2 => {
            let Some(mut w) = find_workload(&args[1]) else {
                eprintln!("unknown workload {}", args[1]);
                return 2;
            };
            w.scenario = workloads::latency_scenario(&w.graph);
            w.fleet = fleet.clone().or(w.fleet);
            let budget =
                Duration::from_secs(args.get(2).and_then(|s| s.parse().ok()).unwrap_or(20));
            match planner::plan(&w, Algorithm::IpLatency, budget) {
                Ok(r) => {
                    let deployed = match &w.fleet {
                        Some(f) => format!("fleet {f}"),
                        None => {
                            format!("k={}, M={:.0}", w.scenario.k, w.scenario.mem_cap)
                        }
                    };
                    println!(
                        "{}: latency {:.2} ({deployed})  runtime {:?}{}",
                        w.name,
                        r.placement.objective,
                        r.runtime,
                        r.gap.map(|g| format!("  gap {:.1}%", g * 100.0)).unwrap_or_default()
                    );
                    0
                }
                Err(e) => {
                    eprintln!("planning failed: {e}");
                    1
                }
            }
        }
        Some("simulate") if args.len() >= 3 => {
            let Some(mut w) = find_workload(&args[1]) else {
                eprintln!("unknown workload {}", args[1]);
                return 2;
            };
            w.fleet = fleet.clone().or(w.fleet);
            let Some(alg) = Algorithm::parse(&args[2]) else {
                eprintln!("unknown algorithm {}", args[2]);
                return 2;
            };
            let n = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(12);
            let r = match planner::plan(&w, alg, Duration::from_secs(10)) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("planning failed: {e}");
                    return 1;
                }
            };
            let schedule = if w.training { Schedule::PipeDream1F1B } else { Schedule::Pipelined };
            // the simulator still speaks the scalar scenario; a fleet run
            // simulates against its conservative uniform view
            let sim_sc = w.request().legacy_scenario();
            if w.fleet.is_some() {
                println!(
                    "note: plan is fleet-aware, but the simulator replays it on the \
                     uniform view (per-class speeds not simulated)"
                );
            }
            let res = sim::simulate(&w.graph, &sim_sc, &r.placement, schedule, n);
            println!(
                "{} {:?}: predicted TPS {:.2}, simulated steady-state {:.2} over {n} samples",
                w.name, alg, r.placement.objective, res.steady_tps
            );
            println!("{}", sim::render_timeline(&res, 100));
            0
        }
        Some("export") if args.len() >= 3 => {
            let Some(w) = find_workload(&args[1]) else {
                eprintln!("unknown workload {}", args[1]);
                return 2;
            };
            let json = wjson::to_json(&w).to_string_pretty();
            if std::fs::write(&args[2], json).is_err() {
                eprintln!("cannot write {}", args[2]);
                return 1;
            }
            println!("wrote {}", args[2]);
            0
        }
        Some("partition-file") if args.len() >= 3 => {
            let Ok(text) = std::fs::read_to_string(&args[1]) else {
                eprintln!("cannot read {}", args[1]);
                return 1;
            };
            let json = match Json::parse(&text) {
                Ok(j) => j,
                Err(e) => {
                    eprintln!("bad JSON: {e}");
                    return 1;
                }
            };
            let mut w = match wjson::from_json_workload(&json) {
                Ok(x) => x,
                Err(e) => {
                    eprintln!("bad workload: {e}");
                    return 1;
                }
            };
            let Some(alg) = Algorithm::parse(&args[2]) else {
                eprintln!("unknown algorithm {}", args[2]);
                return 2;
            };
            // CLI --fleet wins over the file's own fleet section
            w.fleet = fleet.clone().or(w.fleet);
            match planner::plan(&w, alg, Duration::from_secs(20)) {
                Ok(r) => {
                    println!("{} {:?}: TPS {:.2} in {:?}", w.name, alg, r.placement.objective, r.runtime);
                    0
                }
                Err(e) => {
                    eprintln!("planning failed: {e}");
                    1
                }
            }
        }
        _ => {
            eprintln!(
                "usage: dnn-partition <list|partition|latency|simulate|export|partition-file> …\n\
                 see `cargo doc` or README.md for details"
            );
            2
        }
    }
}

fn cli_key(w: &Workload) -> String {
    match (w.name.as_str(), w.granularity) {
        ("BERT-3", workloads::Granularity::Operator) => "bert3op".into(),
        ("BERT-6", workloads::Granularity::Operator) => "bert6op".into(),
        ("BERT-12", workloads::Granularity::Operator) => "bert12op".into(),
        ("ResNet50", workloads::Granularity::Operator) => "resnet50op".into(),
        ("BERT-24", _) => "bert24".into(),
        ("ResNet50", _) => "resnet50".into(),
        ("InceptionV3", _) => "inceptionv3".into(),
        ("GNMT", _) => "gnmt".into(),
        _ => w.name.to_lowercase(),
    }
}

fn print_split(w: &Workload, p: &dnn_partition::prelude::Placement) {
    use dnn_partition::coordinator::placement::Device;
    let n = w.graph.n();
    let req = w.request();
    for i in 0..req.fleet.k() {
        let set = p.set_of(Device::Acc(i), n);
        let class = req.fleet.class_of(Device::Acc(i));
        let (name, cap) = class.map_or(("acc", f64::INFINITY), |c| (c.name.as_str(), c.mem_cap));
        let cap_str = if cap.is_finite() { format!("/{cap:.0}") } else { String::new() };
        println!(
            "  acc{i} ({name}): {} nodes, {:.1}{cap_str} MB",
            set.len(),
            w.graph.mem_of(&set)
        );
    }
    for j in 0..req.fleet.l().max(1) {
        let set = p.set_of(Device::Cpu(j), n);
        if !set.is_empty() {
            println!("  cpu{j}: {} nodes", set.len());
        }
    }
}

//! Fingerprint-cached planning service — the serving-time re-planning
//! loop's front door.
//!
//! The ROADMAP's serving north-star plans *many scenarios over one model*
//! (device loss, tighter memory caps, different `k`, comm-model what-ifs):
//! the expensive part of each plan is the shared analysis
//! ([`ProblemCtx`]), not the solver. [`PlannerService`] keys contexts by
//! the [`fingerprint_req`] of `(graph, scenario)` and keeps a bounded LRU,
//! so repeated plans of a known problem run at cache-hit cost and a
//! scenario change only pays for the artifacts it actually invalidates (a
//! new scenario over the same graph is a new context — invalidation is
//! whole-context by construction, which is what makes the cache trivially
//! correct: every artifact depends on the full key).
//!
//! Since the concurrent rework this type is a thin single-owner façade
//! over a one-shard [`ConcurrentService`] — same caching contract, same
//! counters, plus the engine's budget-keyed incumbent cache on
//! [`PlannerService::plan_request`]. Multi-tenant callers should hold the
//! [`ConcurrentService`] directly (it plans through `&self`).

use crate::algos::PlaceError;
use crate::coordinator::concurrent::ConcurrentService;
use crate::coordinator::context::{PlanResult, ProblemCtx, SolveOpts};
use crate::coordinator::placement::{PlanRequest, Scenario};
use crate::coordinator::planner::Algorithm;
use crate::graph::OpGraph;
use crate::workloads::Workload;
use std::sync::Arc;

#[allow(unused_imports)] // doc links
use crate::coordinator::context::fingerprint_req;

/// Bounded LRU of [`ProblemCtx`]s keyed by content fingerprint.
pub struct PlannerService {
    inner: ConcurrentService,
}

impl PlannerService {
    /// Service caching up to `capacity` contexts (≥ 1), with the default
    /// lattice cap ([`crate::graph::ideals::DEFAULT_IDEAL_CAP`]).
    pub fn new(capacity: usize) -> PlannerService {
        Self::with_ideal_cap(capacity, crate::graph::ideals::DEFAULT_IDEAL_CAP)
    }

    /// [`PlannerService::new`] with an explicit lattice cap for the
    /// contexts it creates. The cap bounds what the exact DP (and hence
    /// the IP warm starts that share its cached solution) will pay before
    /// falling back to DPL — lower it when serving IP-only plans over
    /// graphs whose lattices are huge.
    pub fn with_ideal_cap(capacity: usize, ideal_cap: usize) -> PlannerService {
        // one shard keeps the LRU order (and thus eviction behavior)
        // exactly what the pre-concurrent service promised
        PlannerService { inner: ConcurrentService::with_ideal_cap(1, capacity, ideal_cap) }
    }

    /// The shared engine, for callers graduating a single-owner service
    /// into multi-tenant use.
    pub fn engine(&self) -> &ConcurrentService {
        &self.inner
    }

    /// The context for `(graph, scenario)`: cached if its fingerprint is
    /// known, freshly created (and cached) otherwise. A scenario shares
    /// its cache entry with the equivalent uniform-fleet request. Fails
    /// with [`PlaceError::SolverPanicked`] only if the build itself
    /// panicked (the engine's unwind envelope, DESIGN.md §11).
    pub fn context(
        &mut self,
        g: &OpGraph,
        sc: &Scenario,
    ) -> Result<Arc<ProblemCtx>, PlaceError> {
        self.inner.context(g, sc)
    }

    /// The context for `(graph, request)` — the fleet-level entry point.
    /// Keyed by [`fingerprint_req`], so requests differing only in solver
    /// selectors (objective / contiguity / algorithm) share one context.
    pub fn context_request(
        &mut self,
        g: &OpGraph,
        req: &PlanRequest,
    ) -> Result<Arc<ProblemCtx>, PlaceError> {
        self.inner.context_request(g, req)
    }

    /// Plan `(graph, scenario)` with `alg`, reusing every cached artifact.
    pub fn plan(
        &mut self,
        g: &OpGraph,
        sc: &Scenario,
        alg: Algorithm,
        opts: &SolveOpts,
    ) -> Result<PlanResult, PlaceError> {
        self.inner.plan(g, sc, alg, opts)
    }

    /// Plan a [`PlanRequest`] (fleet + objective + algorithm selection,
    /// `Auto` included), reusing every cached artifact. Serving-time
    /// fleet mutations — device loss via
    /// [`crate::coordinator::placement::Fleet::decrement`], cap changes —
    /// re-plan here at cache-hit cost for known fleets; IP-backed requests
    /// additionally resume from the engine's cached incumbent of the same
    /// `(problem, regime)`.
    pub fn plan_request(
        &mut self,
        g: &OpGraph,
        req: &PlanRequest,
        opts: &SolveOpts,
    ) -> Result<PlanResult, PlaceError> {
        self.inner.plan_request(g, req, opts)
    }

    /// [`PlannerService::plan`] for a [`Workload`], filling the expert rule
    /// from the workload when the caller didn't set one.
    pub fn plan_workload(
        &mut self,
        w: &Workload,
        alg: Algorithm,
        opts: &SolveOpts,
    ) -> Result<PlanResult, PlaceError> {
        self.inner.plan_workload(w, alg, opts)
    }

    /// Cache hits so far.
    pub fn hits(&self) -> usize {
        self.inner.hits()
    }

    /// Cache misses so far (= contexts created).
    pub fn misses(&self) -> usize {
        self.inner.misses()
    }

    /// Cached contexts currently held.
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Drop every cached context and incumbent seed (e.g. after an
    /// external cost-model update that a caller knows invalidates
    /// everything).
    pub fn clear(&mut self) {
        self.inner.clear()
    }
}

impl Default for PlannerService {
    /// Eight cached contexts — enough for a model × a handful of live
    /// scenarios.
    fn default() -> PlannerService {
        PlannerService::new(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(9.0).acc(1.0).mem(1.0).comm(0.2));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn same_problem_hits_cache() {
        let g = chain(6);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let mut svc = PlannerService::new(4);
        let a = svc.context(&g, &sc).unwrap();
        let b = svc.context(&g, &sc).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(svc.hits(), 1);
        assert_eq!(svc.misses(), 1);
    }

    #[test]
    fn scenario_change_is_a_new_context_and_lru_evicts() {
        let g = chain(6);
        let mut svc = PlannerService::new(2);
        let a = svc.context(&g, &Scenario::new(2, 1, f64::INFINITY)).unwrap();
        let _b = svc.context(&g, &Scenario::new(1, 1, f64::INFINITY)).unwrap();
        let _c = svc.context(&g, &Scenario::new(3, 1, f64::INFINITY)).unwrap();
        assert_eq!(svc.len(), 2, "capacity bound");
        // `a`'s problem was evicted: planning it again is a miss
        let a2 = svc.context(&g, &Scenario::new(2, 1, f64::INFINITY)).unwrap();
        assert!(!Arc::ptr_eq(&a, &a2));
        assert_eq!(svc.misses(), 4);
    }

    #[test]
    fn plan_through_service_matches_free_planner() {
        let g = chain(8);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let mut svc = PlannerService::default();
        let opts = SolveOpts::default();
        let cold = svc.plan(&g, &sc, Algorithm::Dp, &opts).unwrap();
        let hit = svc.plan(&g, &sc, Algorithm::Dp, &opts).unwrap();
        assert_eq!(
            cold.placement.objective.to_bits(),
            hit.placement.objective.to_bits(),
            "cache hit must be bitwise identical"
        );
        assert_eq!(cold.placement.assignment, hit.placement.assignment);
        assert!(svc.hits() >= 1);
    }
}

//! Deployment scenarios and device placements — the input/output
//! specification of §3, shared by every optimizer, baseline, simulator and
//! the serving runtime.

use crate::graph::{NodeKind, OpGraph};
use crate::util::bitset::BitSet;

/// A device in the deployment: accelerator `i ∈ 0..k` or CPU `j ∈ 0..ℓ`.
/// In the latency setting all CPU cores act as one pool, `Cpu(0)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Device {
    Acc(usize),
    Cpu(usize),
}

impl Device {
    pub fn is_acc(self) -> bool {
        matches!(self, Device::Acc(_))
    }

    /// Dense index: accelerators first (`0..k`), then CPUs (`k..k+ℓ`).
    pub fn index(self, k: usize) -> usize {
        match self {
            Device::Acc(i) => i,
            Device::Cpu(j) => k + j,
        }
    }

    pub fn from_index(idx: usize, k: usize) -> Device {
        if idx < k {
            Device::Acc(idx)
        } else {
            Device::Cpu(idx - k)
        }
    }
}

impl std::fmt::Display for Device {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Device::Acc(i) => write!(f, "acc{i}"),
            Device::Cpu(j) => write!(f, "cpu{j}"),
        }
    }
}

/// How communication overlaps computation when computing a device's load
/// (Appendix C.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum CommModel {
    /// §3 default: transfers serialize with compute → load = comm + compute.
    #[default]
    Sequential,
    /// C.1: transfers overlap compute (one channel) → load = max(comm, compute).
    Overlap,
    /// C.1 full-duplex: separate in/out channels → max(in, compute, out).
    FullDuplex,
}

/// Pipelined-training schedule flavor (§5.3, Fig. 7). Affects the training
/// objective: PipeDream (1F1B) uses `max_i (FW_i + BW_i)`; GPipe uses
/// `max_i FW_i + max_i BW_i`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TrainSchedule {
    #[default]
    PipeDream,
    GPipe,
}

/// A deployment scenario: the non-graph half of the paper's input.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Number of accelerators (`k`).
    pub k: usize,
    /// Number of CPUs (`ℓ`). Throughput algorithms treat these as separate
    /// pipeline devices; the latency IP pools them.
    pub l: usize,
    /// Accelerator memory capacity `M` (same unit as node `mem`).
    pub mem_cap: f64,
    pub comm_model: CommModel,
    pub train_schedule: TrainSchedule,
    /// Interconnect bandwidth used by the App.-C.2 replication DP's
    /// AllReduce weight-sync term (size units per time unit).
    pub bandwidth: f64,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            k: 6,
            l: 1,
            mem_cap: f64::INFINITY,
            comm_model: CommModel::Sequential,
            train_schedule: TrainSchedule::PipeDream,
            bandwidth: 1.0,
        }
    }
}

impl Scenario {
    pub fn new(k: usize, l: usize, mem_cap: f64) -> Self {
        Scenario { k, l, mem_cap, ..Default::default() }
    }

    pub fn num_devices(&self) -> usize {
        self.k + self.l
    }

    /// Combine a device's computation and communication loads per the
    /// scenario's comm model.
    pub fn combine(&self, compute: f64, comm_in: f64, comm_out: f64) -> f64 {
        match self.comm_model {
            CommModel::Sequential => compute + comm_in + comm_out,
            CommModel::Overlap => compute.max(comm_in + comm_out),
            CommModel::FullDuplex => compute.max(comm_in).max(comm_out),
        }
    }
}

/// A device placement: every node assigned to exactly one device.
#[derive(Clone, Debug)]
pub struct Placement {
    pub assignment: Vec<Device>,
    /// Objective value claimed by the producing algorithm (TPS for
    /// throughput = max-load; end-to-end latency for the latency IP).
    pub objective: f64,
    /// Human-readable producer tag ("DP", "IP (non-contiguous)", …).
    pub algorithm: String,
}

impl Placement {
    pub fn new(assignment: Vec<Device>, objective: f64, algorithm: impl Into<String>) -> Self {
        Placement { assignment, objective, algorithm: algorithm.into() }
    }

    /// Node set on a given device.
    pub fn set_of(&self, device: Device, n: usize) -> BitSet {
        BitSet::from_iter(
            n,
            self.assignment
                .iter()
                .enumerate()
                .filter(|(_, &d)| d == device)
                .map(|(v, _)| v),
        )
    }

    /// All nodes on accelerators.
    pub fn acc_nodes(&self) -> BitSet {
        BitSet::from_iter(
            self.assignment.len(),
            self.assignment
                .iter()
                .enumerate()
                .filter(|(_, d)| d.is_acc())
                .map(|(v, _)| v),
        )
    }

    /// Dense device indices (`0..k` accs, `k..k+ℓ` CPUs) for rendering.
    pub fn dense(&self, k: usize) -> Vec<usize> {
        self.assignment.iter().map(|d| d.index(k)).collect()
    }

    /// Memory-feasibility check (constraint (3)): accelerator memory only.
    pub fn check_memory(&self, g: &OpGraph, sc: &Scenario) -> Result<(), String> {
        for i in 0..sc.k {
            let set = self.set_of(Device::Acc(i), g.n());
            let used = g.mem_of(&set);
            if used > sc.mem_cap * (1.0 + 1e-9) {
                return Err(format!(
                    "accelerator {i} over capacity: {used:.3} > {:.3}",
                    sc.mem_cap
                ));
            }
        }
        Ok(())
    }

    /// Colocation check (App. B): same color class ⇒ same device.
    pub fn check_colocation(&self, g: &OpGraph) -> Result<(), String> {
        use std::collections::BTreeMap;
        let mut seen: BTreeMap<u32, Device> = BTreeMap::new();
        for (v, node) in g.nodes.iter().enumerate() {
            if let Some(c) = node.color_class {
                match seen.get(&c) {
                    None => {
                        seen.insert(c, self.assignment[v]);
                    }
                    Some(&d) if d != self.assignment[v] => {
                        return Err(format!(
                            "color class {c} split across {d} and {}",
                            self.assignment[v]
                        ));
                    }
                    _ => {}
                }
            }
        }
        Ok(())
    }

    /// Contiguity check (Def. 3.1) per accelerator; for training graphs the
    /// forward and backward parts are checked separately (§5.3). CPUs are
    /// never contiguity-constrained (§4 treats the CPU pool specially, and
    /// §5 pipelines may assign CPUs arbitrary sets).
    pub fn check_contiguity(&self, g: &OpGraph, sc: &Scenario) -> Result<(), String> {
        let has_bw = g.nodes.iter().any(|n| n.kind == NodeKind::Backward);
        for i in 0..sc.k {
            let set = self.set_of(Device::Acc(i), g.n());
            if !has_bw {
                if !crate::graph::contiguity::is_contiguous(g, &set) {
                    return Err(format!("accelerator {i} holds a non-contiguous set"));
                }
            } else {
                for kind in [NodeKind::Forward, NodeKind::Backward] {
                    let part = BitSet::from_iter(
                        g.n(),
                        set.iter().filter(|&v| g.nodes[v].kind == kind),
                    );
                    if !crate::graph::contiguity::is_contiguous(g, &part) {
                        return Err(format!(
                            "accelerator {i} holds a non-contiguous {kind:?} set"
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Validate everything an optimizer output must satisfy; `contiguous`
    /// toggles the Def.-3.1 check (non-contiguous optimizers skip it).
    pub fn validate(&self, g: &OpGraph, sc: &Scenario, contiguous: bool) -> Result<(), String> {
        if self.assignment.len() != g.n() {
            return Err("assignment length mismatch".into());
        }
        for &d in &self.assignment {
            match d {
                Device::Acc(i) if i >= sc.k => return Err(format!("device {d} out of range")),
                Device::Cpu(j) if j >= sc.l.max(1) => {
                    return Err(format!("device {d} out of range"))
                }
                _ => {}
            }
        }
        self.check_memory(g, sc)?;
        self.check_colocation(g)?;
        if contiguous {
            self.check_contiguity(g, sc)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn g4() -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..4 {
            g.add_node(Node::new(format!("n{i}")).mem(1.0));
        }
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g.add_edge(2, 3);
        g
    }

    #[test]
    fn device_index_roundtrip() {
        let k = 3;
        for idx in 0..6 {
            assert_eq!(Device::from_index(idx, k).index(k), idx);
        }
        assert_eq!(Device::Acc(2).index(3), 2);
        assert_eq!(Device::Cpu(0).index(3), 3);
    }

    #[test]
    fn memory_validation() {
        let g = g4();
        let sc = Scenario::new(2, 1, 1.5);
        let p = Placement::new(
            vec![Device::Acc(0), Device::Acc(0), Device::Acc(1), Device::Cpu(0)],
            0.0,
            "t",
        );
        assert!(p.check_memory(&g, &sc).is_err()); // acc0 holds 2 > 1.5
        let sc_ok = Scenario::new(2, 1, 2.0);
        assert!(p.check_memory(&g, &sc_ok).is_ok());
    }

    #[test]
    fn contiguity_validation() {
        let g = g4();
        let sc = Scenario::new(1, 1, f64::INFINITY);
        let bad = Placement::new(
            vec![Device::Acc(0), Device::Cpu(0), Device::Acc(0), Device::Cpu(0)],
            0.0,
            "t",
        );
        assert!(bad.check_contiguity(&g, &sc).is_err());
        assert!(bad.validate(&g, &sc, false).is_ok()); // ok if non-contiguous allowed
        let good = Placement::new(
            vec![Device::Acc(0), Device::Acc(0), Device::Cpu(0), Device::Cpu(0)],
            0.0,
            "t",
        );
        assert!(good.validate(&g, &sc, true).is_ok());
    }

    #[test]
    fn colocation_validation() {
        let mut g = g4();
        g.nodes[0].color_class = Some(1);
        g.nodes[3].color_class = Some(1);
        let split = Placement::new(
            vec![Device::Acc(0), Device::Acc(0), Device::Acc(0), Device::Cpu(0)],
            0.0,
            "t",
        );
        assert!(split.check_colocation(&g).is_err());
        let together = Placement::new(vec![Device::Acc(0); 4], 0.0, "t");
        assert!(together.check_colocation(&g).is_ok());
    }

    #[test]
    fn comm_models_combine() {
        let sc = |m| Scenario { comm_model: m, ..Default::default() };
        assert_eq!(sc(CommModel::Sequential).combine(5.0, 2.0, 1.0), 8.0);
        assert_eq!(sc(CommModel::Overlap).combine(5.0, 2.0, 1.0), 5.0);
        assert_eq!(sc(CommModel::Overlap).combine(2.0, 4.0, 1.0), 5.0);
        assert_eq!(sc(CommModel::FullDuplex).combine(2.0, 4.0, 1.0), 4.0);
    }

    #[test]
    fn set_of_and_dense() {
        let p = Placement::new(
            vec![Device::Acc(1), Device::Cpu(0), Device::Acc(1), Device::Acc(0)],
            0.0,
            "t",
        );
        let s = p.set_of(Device::Acc(1), 4);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(p.dense(2), vec![1, 2, 1, 0]);
    }
}

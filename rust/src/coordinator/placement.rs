//! Deployment scenarios and device placements — the input/output
//! specification of §3, shared by every optimizer, baseline, simulator and
//! the serving runtime.

use crate::graph::{NodeKind, OpGraph};
use crate::util::bitset::BitSet;

/// A device in the deployment: accelerator `i ∈ 0..k` or CPU `j ∈ 0..ℓ`.
/// In the latency setting all CPU cores act as one pool, `Cpu(0)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Device {
    Acc(usize),
    Cpu(usize),
}

impl Device {
    pub fn is_acc(self) -> bool {
        matches!(self, Device::Acc(_))
    }

    /// Dense index: accelerators first (`0..k`), then CPUs (`k..k+ℓ`).
    pub fn index(self, k: usize) -> usize {
        match self {
            Device::Acc(i) => i,
            Device::Cpu(j) => k + j,
        }
    }

    pub fn from_index(idx: usize, k: usize) -> Device {
        if idx < k {
            Device::Acc(idx)
        } else {
            Device::Cpu(idx - k)
        }
    }

    /// The device's class within a fleet (see [`Fleet::class_of`]).
    pub fn class(self, fleet: &Fleet) -> Option<&DeviceClass> {
        fleet.class_of(self)
    }
}

impl std::fmt::Display for Device {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Device::Acc(i) => write!(f, "acc{i}"),
            Device::Cpu(j) => write!(f, "cpu{j}"),
        }
    }
}

impl Device {
    /// Parse the [`Device`] `Display` form (`acc3` / `cpu0`) — the device
    /// vocabulary of the simulator's event-script grammar
    /// (`crate::simx::event::EventScript`).
    pub fn parse(s: &str) -> Result<Device, String> {
        let (ctor, digits): (fn(usize) -> Device, &str) = if let Some(d) = s.strip_prefix("acc")
        {
            (Device::Acc, d)
        } else if let Some(d) = s.strip_prefix("cpu") {
            (Device::Cpu, d)
        } else {
            return Err(format!("bad device '{s}' (expected accN or cpuN)"));
        };
        if digits.is_empty() || !digits.chars().all(|c| c.is_ascii_digit()) {
            return Err(format!("bad device index in '{s}'"));
        }
        digits
            .parse::<usize>()
            .map(ctor)
            .map_err(|e| format!("bad device index in '{s}': {e}"))
    }
}

/// How communication overlaps computation when computing a device's load
/// (Appendix C.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum CommModel {
    /// §3 default: transfers serialize with compute → load = comm + compute.
    #[default]
    Sequential,
    /// C.1: transfers overlap compute (one channel) → load = max(comm, compute).
    Overlap,
    /// C.1 full-duplex: separate in/out channels → max(in, compute, out).
    FullDuplex,
}

impl CommModel {
    /// Combine a device's computation and communication loads — the one
    /// implementation behind [`Scenario::combine`] and
    /// [`PlanRequest::combine`].
    pub fn combine(self, compute: f64, comm_in: f64, comm_out: f64) -> f64 {
        match self {
            CommModel::Sequential => compute + comm_in + comm_out,
            CommModel::Overlap => compute.max(comm_in + comm_out),
            CommModel::FullDuplex => compute.max(comm_in).max(comm_out),
        }
    }
}

/// Pipelined-training schedule flavor (§5.3, Fig. 7). Affects the training
/// objective: PipeDream (1F1B) uses `max_i (FW_i + BW_i)`; GPipe uses
/// `max_i FW_i + max_i BW_i`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TrainSchedule {
    #[default]
    PipeDream,
    GPipe,
}

/// Device-class kind: pipeline accelerator (pays boundary comm, memory-
/// capped) or CPU-pool device (compute only, RAM "free" per §3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceKind {
    Accelerator,
    Cpu,
}

impl DeviceKind {
    /// The kind a class *name* implies when no explicit kind is given —
    /// the one rule shared by [`Fleet::parse`], the fleet `Display`
    /// round-trip, and the JSON schema: names starting with `cpu`
    /// (case-insensitive) are CPU classes, everything else accelerators.
    pub fn infer(name: &str) -> DeviceKind {
        if name.to_ascii_lowercase().starts_with("cpu") {
            DeviceKind::Cpu
        } else {
            DeviceKind::Accelerator
        }
    }
}

/// One class of interchangeable devices in a heterogeneous fleet:
/// `count` devices named `name`, each with `mem_cap` memory and relative
/// compute `speed` (node processing times divide by `speed`; 1.0 = the
/// cost model's reference device). Within a class devices are symmetric —
/// across classes they are not.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceClass {
    pub name: String,
    pub count: usize,
    pub mem_cap: f64,
    pub speed: f64,
    pub kind: DeviceKind,
}

impl DeviceClass {
    /// Accelerator class with speed 1.0.
    pub fn acc(name: impl Into<String>, count: usize, mem_cap: f64) -> DeviceClass {
        DeviceClass { name: name.into(), count, mem_cap, speed: 1.0, kind: DeviceKind::Accelerator }
    }

    /// CPU class (uncapped memory, speed 1.0).
    pub fn cpu(name: impl Into<String>, count: usize) -> DeviceClass {
        DeviceClass {
            name: name.into(),
            count,
            mem_cap: f64::INFINITY,
            speed: 1.0,
            kind: DeviceKind::Cpu,
        }
    }

    pub fn speed(mut self, s: f64) -> DeviceClass {
        self.speed = s;
        self
    }
}

/// One dense device's class-derived properties (see [`Fleet::dense_view`]).
/// CPU devices report an unbounded `mem_cap` (§3: RAM is not modeled).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DenseDevice {
    pub mem_cap: f64,
    pub speed: f64,
    /// Index in dense-class order — equal `class` ⇔ interchangeable.
    pub class: usize,
    pub kind: DeviceKind,
}

/// A typed device fleet: ordered [`DeviceClass`]es plus the interconnect
/// bandwidth. Dense device indexing follows [`Device::index`]: accelerator
/// devices come first (`0..k`, walking the accelerator classes in
/// declaration order), then CPU devices (`k..k+ℓ`). A legacy
/// [`Scenario`] is exactly a one-accelerator-class, one-CPU-class fleet
/// ([`Fleet::uniform`] / [`Scenario::to_request`]).
#[derive(Clone, Debug, PartialEq)]
pub struct Fleet {
    pub classes: Vec<DeviceClass>,
    /// Interconnect bandwidth (App.-C.2 AllReduce term; size/time units).
    pub bandwidth: f64,
    /// Optional per-device-pair interconnect topology (DESIGN.md §9).
    /// `None` is the legacy scalar path: every pair-cost accessor below
    /// degenerates to the identity, bitwise-identical to pre-topology
    /// behavior. When present, its slot count equals
    /// [`Fleet::num_devices`] in dense order.
    pub topology: Option<crate::topo::Topology>,
}

impl Fleet {
    pub fn new(classes: Vec<DeviceClass>) -> Fleet {
        Fleet { classes, bandwidth: 1.0, topology: None }
    }

    pub fn bandwidth(mut self, b: f64) -> Fleet {
        self.bandwidth = b;
        self
    }

    /// Attach an interconnect topology (builder style). Callers are
    /// responsible for matching its slot count to the fleet's.
    pub fn topology(mut self, t: crate::topo::Topology) -> Fleet {
        self.topology = Some(t);
        self
    }

    /// The uniform fleet equivalent to `Scenario::new(k, l, mem_cap)`:
    /// one accelerator class `acc` (speed 1.0) and one CPU class `cpu`.
    pub fn uniform(k: usize, l: usize, mem_cap: f64) -> Fleet {
        Fleet::new(vec![DeviceClass::acc("acc", k, mem_cap), DeviceClass::cpu("cpu", l)])
    }

    fn classes_of(&self, kind: DeviceKind) -> impl Iterator<Item = &DeviceClass> {
        self.classes.iter().filter(move |c| c.kind == kind)
    }

    /// Total accelerator count (`k`).
    pub fn k(&self) -> usize {
        self.classes_of(DeviceKind::Accelerator).map(|c| c.count).sum()
    }

    /// Total CPU-device count (`ℓ`).
    pub fn l(&self) -> usize {
        self.classes_of(DeviceKind::Cpu).map(|c| c.count).sum()
    }

    pub fn num_devices(&self) -> usize {
        self.k() + self.l()
    }

    /// The class holding dense accelerator `i`, or the last accelerator
    /// class when `i` is out of range (callers validate ranges separately).
    fn acc_class(&self, i: usize) -> Option<&DeviceClass> {
        let mut seen = 0usize;
        let mut last = None;
        for c in self.classes_of(DeviceKind::Accelerator) {
            seen += c.count;
            last = Some(c);
            if i < seen {
                return Some(c);
            }
        }
        last
    }

    fn cpu_class(&self, j: usize) -> Option<&DeviceClass> {
        let mut seen = 0usize;
        let mut last = None;
        for c in self.classes_of(DeviceKind::Cpu) {
            seen += c.count;
            last = Some(c);
            if j < seen {
                return Some(c);
            }
        }
        last
    }

    /// The class of a device (`None` only for fleets with no class of the
    /// device's kind at all).
    pub fn class_of(&self, d: Device) -> Option<&DeviceClass> {
        match d {
            Device::Acc(i) => self.acc_class(i),
            Device::Cpu(j) => self.cpu_class(j),
        }
    }

    /// Per-dense-device expansion of the fleet, in [`Device::index`]
    /// order: accelerator devices first (walking accelerator classes in
    /// declaration order), then CPU devices. `class` is the device's
    /// index in dense-class order (accelerator classes, then CPU classes
    /// — count-0 classes included), the shared basis for within-class
    /// symmetry breaking. This is THE one definition of the fleet→device
    /// mapping the searches build their per-device tables from; it agrees
    /// with [`Fleet::class_of`] / [`Fleet::acc_mem_cap`] /
    /// [`Fleet::acc_speed`] by construction (and by test).
    pub fn dense_view(&self) -> Vec<DenseDevice> {
        let nd = self.num_devices();
        let mut out = Vec::with_capacity(nd);
        let mut class = 0usize;
        for kind in [DeviceKind::Accelerator, DeviceKind::Cpu] {
            for c in self.classes_of(kind) {
                for _ in 0..c.count {
                    out.push(DenseDevice {
                        mem_cap: if kind == DeviceKind::Accelerator {
                            c.mem_cap
                        } else {
                            f64::INFINITY
                        },
                        speed: c.speed,
                        class,
                        kind,
                    });
                }
                class += 1;
            }
        }
        out
    }

    /// Memory cap of dense accelerator `i`.
    pub fn acc_mem_cap(&self, i: usize) -> f64 {
        self.acc_class(i).map_or(f64::INFINITY, |c| c.mem_cap)
    }

    /// Relative speed of dense accelerator `i`.
    pub fn acc_speed(&self, i: usize) -> f64 {
        self.acc_class(i).map_or(1.0, |c| c.speed)
    }

    /// Relative speed of dense CPU device `j`.
    pub fn cpu_speed(&self, j: usize) -> f64 {
        self.cpu_class(j).map_or(1.0, |c| c.speed)
    }

    /// Fastest accelerator-class speed (`None` when the fleet declares no
    /// accelerator class) — the sound divisor for compute lower bounds.
    /// Deliberately includes count-0 classes: a declared class is part of
    /// the *bound* vocabulary (and the uniform legacy path relies on the
    /// CPU class existing even at `ℓ = 0`); a faster-than-present speed
    /// only weakens the bound, never breaks it.
    pub fn best_acc_speed(&self) -> Option<f64> {
        self.classes_of(DeviceKind::Accelerator).map(|c| c.speed).reduce(f64::max)
    }

    pub fn best_cpu_speed(&self) -> Option<f64> {
        self.classes_of(DeviceKind::Cpu).map(|c| c.speed).reduce(f64::max)
    }

    /// Smallest *populated* accelerator-class memory cap (conservative
    /// single-cap view used by the Appendix-C DPs and
    /// [`PlanRequest::legacy_scenario`]). Classes drained to count 0
    /// (e.g. by [`Fleet::decrement`] device loss) no longer constrain
    /// anything and are skipped.
    pub fn min_acc_mem_cap(&self) -> f64 {
        self.classes_of(DeviceKind::Accelerator)
            .filter(|c| c.count > 0)
            .map(|c| c.mem_cap)
            .fold(f64::INFINITY, f64::min)
    }

    /// Slowest *populated* accelerator-class speed (conservative; 1.0
    /// when no accelerator device remains).
    pub fn min_acc_speed(&self) -> f64 {
        let m = self
            .classes_of(DeviceKind::Accelerator)
            .filter(|c| c.count > 0)
            .map(|c| c.speed)
            .fold(f64::INFINITY, f64::min);
        if m.is_finite() {
            m
        } else {
            1.0
        }
    }

    /// Dense slot index one past class `name`'s current device block
    /// (accelerator classes stack from 0, CPU classes from `k`).
    fn class_block_end(&self, name: &str) -> Option<usize> {
        let target = self.classes.iter().find(|c| c.name == name)?;
        let mut end = if target.kind == DeviceKind::Cpu { self.k() } else { 0 };
        for c in self.classes_of(target.kind) {
            end += c.count;
            if std::ptr::eq(c, target) {
                return Some(end);
            }
        }
        None
    }

    /// Decrement `name`'s device count (serving-time device loss). Returns
    /// `false` when the class is unknown or already empty. An attached
    /// topology drops the lost device's slot (uniform topologies stay
    /// uniform; structured ones degrade to an explicit matrix — see
    /// [`crate::topo::Topology::without_slot`]); if the slot cannot be
    /// removed the topology falls back to the scalar path (`None`).
    pub fn decrement(&mut self, name: &str) -> bool {
        let slot = self.class_block_end(name).map(|e| e.saturating_sub(1));
        match self.classes.iter_mut().find(|c| c.name == name) {
            Some(c) if c.count > 0 => {
                c.count -= 1;
                if let (Some(t), Some(slot)) = (self.topology.take(), slot) {
                    self.topology = t.without_slot(slot).ok();
                }
                true
            }
            _ => false,
        }
    }

    /// Re-increment `name`'s device count (serving-time device recovery) —
    /// the inverse of [`Fleet::decrement`], used by the re-planning
    /// controller when a declared-dead device answers a re-admission
    /// probe. Returns `false` when the class is unknown. An attached
    /// topology gains a slot cloned from the class's surviving twin (or
    /// its dense neighbor when the class was fully drained — see
    /// [`crate::topo::Topology::with_cloned_slot`]).
    pub fn increment(&mut self, name: &str) -> bool {
        let end = self.class_block_end(name);
        match self.classes.iter_mut().find(|c| c.name == name) {
            Some(c) => {
                c.count += 1;
                if let (Some(t), Some(end)) = (self.topology.take(), end) {
                    self.topology = t.with_cloned_slot(end.saturating_sub(1)).ok();
                }
                true
            }
            None => false,
        }
    }

    /// Mutable access to a class by name (serving-time cap/speed updates).
    pub fn class_named_mut(&mut self, name: &str) -> Option<&mut DeviceClass> {
        self.classes.iter_mut().find(|c| c.name == name)
    }

    /// All caps lifted — the scoring mode of the memory-oblivious
    /// baselines (Scotch, expert). Carries the topology unchanged.
    pub fn with_unbounded_memory(&self) -> Fleet {
        let mut f = self.clone();
        for c in &mut f.classes {
            c.mem_cap = f64::INFINITY;
        }
        f
    }

    // ---- per-pair comm pricing (DESIGN.md §9) -------------------------
    //
    // Dense slots follow `Device::index`: accelerators 0..k, CPUs k..k+ℓ.
    // Without a topology every accessor is the exact identity, which keeps
    // the scalar path bitwise-unchanged.

    /// Normalized slowdown of the `a → b` link (`1.0` without a topology,
    /// on the diagonal, and on every fastest-tier pair).
    #[inline]
    pub fn pair_slowdown(&self, a: usize, b: usize) -> f64 {
        match &self.topology {
            Some(t) => t.slowdown(a, b),
            None => 1.0,
        }
    }

    /// Latency of the `a → b` link (`0.0` without a topology).
    #[inline]
    pub fn pair_latency(&self, a: usize, b: usize) -> f64 {
        match &self.topology {
            Some(t) => t.latency(a, b),
            None => 0.0,
        }
    }

    /// Cost of moving `s` reference-seconds of data from dense slot `a`
    /// to dense slot `b`: `s * pair_slowdown + pair_latency`, exactly `s`
    /// on the diagonal — THE comm-pricing accessor every solver and
    /// evaluator routes cut-edge costs through (no site multiplies raw
    /// `fleet.bandwidth`; the only scalar-bandwidth consumers left are
    /// the App.-C.2 AllReduce term and simx's base link rate).
    #[inline]
    pub fn transfer_cost(&self, a: usize, b: usize, s: f64) -> f64 {
        match &self.topology {
            Some(t) => t.transfer_cost(a, b, s),
            None => s,
        }
    }

    /// [`Fleet::transfer_cost`], but free on the same device — the
    /// canonical `pair_cost(src, dst, bytes)` form.
    #[inline]
    pub fn pair_cost(&self, a: usize, b: usize, s: f64) -> f64 {
        match &self.topology {
            Some(t) => t.pair_cost(a, b, s),
            None => {
                if a == b {
                    0.0
                } else {
                    s
                }
            }
        }
    }

    /// Largest pair slowdown (`1.0` without a topology) — the numerator
    /// of the DP family's conservative worst-pair comm bound.
    pub fn max_comm_slowdown(&self) -> f64 {
        self.topology.as_ref().map_or(1.0, |t| t.max_slowdown())
    }

    /// Largest pair latency (`0.0` without a topology).
    pub fn max_comm_latency(&self) -> f64 {
        self.topology.as_ref().map_or(0.0, |t| t.max_latency())
    }

    /// Smallest off-diagonal pair latency (`0.0` without a topology) —
    /// the optimistic half of the MILPs' pair-free relaxation (the
    /// smallest off-diagonal *slowdown* is `1.0` by normalization).
    pub fn min_comm_latency(&self) -> f64 {
        self.topology.as_ref().map_or(0.0, |t| t.min_offdiag_latency())
    }

    /// Conservative worst-pair cost `s * max_slowdown + max_latency`;
    /// bitwise `s` without a topology (`s * 1.0 + 0.0`).
    #[inline]
    pub fn worst_pair_cost(&self, s: f64) -> f64 {
        s * self.max_comm_slowdown() + self.max_comm_latency()
    }

    /// Parse a CLI fleet spec: comma-separated
    /// `COUNTxNAME[@SPEED][:MEM][+acc|+cpu]` entries plus optional
    /// `bw=BANDWIDTH` and `topo=SPEC` entries, e.g.
    /// `"2xfast@2.0:16,4xslow:8,1xcpu,bw=2"` or
    /// `"8xacc:32768,1xcpu,topo=islands:2x4@900/64"` (topology grammar in
    /// [`crate::topo::TopoSpec`]; island/tier shapes cover the
    /// accelerators, CPU slots ride the slowest tier).
    /// Without an explicit `+acc`/`+cpu` suffix the kind is inferred from
    /// the name (a name starting with `cpu` declares a CPU class);
    /// `COUNTx` defaults to 1, `@SPEED` to 1.0, `:MEM` to unlimited.
    pub fn parse(spec: &str) -> Result<Fleet, String> {
        let mut classes = Vec::new();
        let mut bandwidth = 1.0;
        let mut topo_spec = None;
        for raw in spec.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            if let Some(b) = entry.strip_prefix("bw=") {
                bandwidth =
                    b.parse::<f64>().map_err(|_| format!("bad bandwidth in '{entry}'"))?;
                if !(bandwidth.is_finite() && bandwidth > 0.0) {
                    return Err(format!("bandwidth must be positive in '{entry}'"));
                }
                continue;
            }
            if let Some(t) = entry.strip_prefix("topo=") {
                topo_spec = Some(crate::topo::TopoSpec::parse(t)?);
                continue;
            }
            if let Some((key, _)) = entry.split_once('=') {
                return Err(format!(
                    "unknown fleet clause '{key}=' in '{entry}' (expected bw= or topo=)"
                ));
            }
            let (entry_body, explicit_kind) = match entry.rsplit_once('+') {
                Some((body, "acc")) => (body, Some(DeviceKind::Accelerator)),
                Some((body, "cpu")) => (body, Some(DeviceKind::Cpu)),
                Some((_, other)) => {
                    return Err(format!("unknown device kind '+{other}' in '{entry}'"))
                }
                None => (entry, None),
            };
            let (count, rest) = match entry_body.split_once('x') {
                Some((c, rest)) if c.chars().all(|ch| ch.is_ascii_digit()) && !c.is_empty() => {
                    (c.parse::<usize>().map_err(|e| format!("bad count in '{entry}': {e}"))?, rest)
                }
                _ => (1, entry_body),
            };
            let (rest, mem_cap) = match rest.rsplit_once(':') {
                Some((r, m)) => {
                    (r, m.parse::<f64>().map_err(|_| format!("bad memory cap in '{entry}'"))?)
                }
                None => (rest, f64::INFINITY),
            };
            let (name, speed) = match rest.split_once('@') {
                Some((n, s)) => {
                    (n, s.parse::<f64>().map_err(|_| format!("bad speed in '{entry}'"))?)
                }
                None => (rest, 1.0),
            };
            if name.is_empty() {
                return Err(format!("empty class name in '{entry}'"));
            }
            if count > crate::topo::MAX_SLOTS {
                // fat-fingered or fuzzed counts: reject before anything
                // downstream sizes per-device state off them
                return Err(format!(
                    "device count {count} in '{entry}' exceeds the {}-slot sanity bound",
                    crate::topo::MAX_SLOTS
                ));
            }
            if !(speed.is_finite() && speed > 0.0) {
                return Err(format!("speed must be positive in '{entry}'"));
            }
            let kind = explicit_kind.unwrap_or_else(|| DeviceKind::infer(name));
            classes.push(DeviceClass { name: name.to_string(), count, mem_cap, speed, kind });
        }
        if classes.is_empty() {
            return Err("empty fleet spec".into());
        }
        let mut fleet = Fleet::new(classes).bandwidth(bandwidth);
        if let Some(spec) = topo_spec {
            // Materialize once the device counts are known; island/tier
            // shapes must cover exactly the fleet's accelerators.
            let t = crate::topo::Topology::from_spec(&spec, fleet.k(), fleet.l())?;
            fleet.topology = Some(t);
        }
        Ok(fleet)
    }
}

impl std::fmt::Display for Fleet {
    /// Emits the [`Fleet::parse`] grammar; `Display → parse` round-trips
    /// exactly, including classes whose kind the name alone would
    /// mis-infer (an explicit `+acc`/`+cpu` suffix is appended for those)
    /// and a non-default bandwidth.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut first = true;
        for c in &self.classes {
            if !first {
                write!(f, ",")?;
            }
            first = false;
            write!(f, "{}x{}", c.count, c.name)?;
            if c.speed != 1.0 {
                write!(f, "@{}", c.speed)?;
            }
            if c.mem_cap.is_finite() {
                write!(f, ":{}", c.mem_cap)?;
            }
            if c.kind != DeviceKind::infer(&c.name) {
                write!(f, "{}", match c.kind {
                    DeviceKind::Accelerator => "+acc",
                    DeviceKind::Cpu => "+cpu",
                })?;
            }
        }
        if self.bandwidth != 1.0 {
            write!(f, ",bw={}", self.bandwidth)?;
        }
        if let Some(t) = &self.topology {
            write!(f, ",topo={}", t.spec())?;
        }
        Ok(())
    }
}

/// What a [`PlanRequest`] optimizes (§4 vs §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Objective {
    #[default]
    Throughput,
    Latency,
}

/// Algorithm selection on a [`PlanRequest`]: a fixed registry entry or
/// `Auto` (objective- and contiguity-driven: throughput → exact DP with
/// DPL fallback when the lattice blows its cap, or the §5.2
/// non-contiguous IP when the request relaxes contiguity; latency → the
/// latency IP with the request's contiguity toggle).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AlgoChoice {
    #[default]
    Auto,
    Fixed(crate::coordinator::planner::Algorithm),
}

/// The unified planning request: the typed fleet plus every non-graph
/// input of the problem. This is the one entry point the planner, the
/// [`crate::coordinator::service::PlannerService`], the CLI `--fleet`
/// path, the JSON schema and the serving loop all speak; [`Scenario`] is
/// the deprecated scalar adapter ([`Scenario::to_request`] ⇒ uniform
/// fleet).
#[derive(Clone, Debug)]
pub struct PlanRequest {
    pub fleet: Fleet,
    pub objective: Objective,
    pub comm_model: CommModel,
    pub train_schedule: TrainSchedule,
    /// Enforce Def.-3.1 contiguity. Honored by validation
    /// ([`Placement::validate_req`]) and by
    /// [`crate::coordinator::planner::solve_request`]'s dispatch: `Auto`
    /// picks the §5.2 non-contiguous IP for throughput (the DP/DPL only
    /// search contiguous splits) and threads the toggle into the latency
    /// IP; a `Fixed` throughput IP declares its own regime by name.
    pub contiguous: bool,
    pub algorithm: AlgoChoice,
}

impl PlanRequest {
    /// Request over `fleet` with the builder defaults: throughput
    /// objective, sequential comm, PipeDream schedule, contiguous, `Auto`
    /// algorithm.
    pub fn new(fleet: Fleet) -> PlanRequest {
        PlanRequest {
            fleet,
            objective: Objective::Throughput,
            comm_model: CommModel::default(),
            train_schedule: TrainSchedule::default(),
            contiguous: true,
            algorithm: AlgoChoice::Auto,
        }
    }

    pub fn objective(mut self, o: Objective) -> PlanRequest {
        self.objective = o;
        self
    }

    pub fn comm_model(mut self, m: CommModel) -> PlanRequest {
        self.comm_model = m;
        self
    }

    pub fn train_schedule(mut self, t: TrainSchedule) -> PlanRequest {
        self.train_schedule = t;
        self
    }

    pub fn contiguous(mut self, c: bool) -> PlanRequest {
        self.contiguous = c;
        self
    }

    pub fn algorithm(mut self, a: AlgoChoice) -> PlanRequest {
        self.algorithm = a;
        self
    }

    /// Total accelerator count (`k`).
    pub fn k(&self) -> usize {
        self.fleet.k()
    }

    /// Total CPU-device count (`ℓ`).
    pub fn l(&self) -> usize {
        self.fleet.l()
    }

    pub fn num_devices(&self) -> usize {
        self.fleet.num_devices()
    }

    /// Combine compute and communication loads per the request's comm
    /// model (see [`CommModel::combine`]).
    pub fn combine(&self, compute: f64, comm_in: f64, comm_out: f64) -> f64 {
        self.comm_model.combine(compute, comm_in, comm_out)
    }

    /// The scalar view of this request: `(k, ℓ)` counts, the *smallest*
    /// accelerator cap, and the shared cost-model fields. Exact for
    /// uniform fleets (round-trips [`Scenario::to_request`]); a
    /// conservative approximation otherwise. Only legacy consumers that
    /// have not been made fleet-aware should read this.
    pub fn legacy_scenario(&self) -> Scenario {
        Scenario {
            k: self.fleet.k(),
            l: self.fleet.l(),
            mem_cap: self.fleet.min_acc_mem_cap(),
            comm_model: self.comm_model,
            train_schedule: self.train_schedule,
            bandwidth: self.fleet.bandwidth,
        }
    }
}

/// A deployment scenario: the non-graph half of the paper's input.
///
/// Deprecated adapter: `k` interchangeable accelerators sharing one
/// `mem_cap` and implicit speed 1.0. New code should build a
/// [`PlanRequest`] over a [`Fleet`]; every scenario converts losslessly
/// via [`Scenario::to_request`] (a one-class uniform fleet), and all
/// solvers now run on the fleet path internally.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Number of accelerators (`k`).
    pub k: usize,
    /// Number of CPUs (`ℓ`). Throughput algorithms treat these as separate
    /// pipeline devices; the latency IP pools them.
    pub l: usize,
    /// Accelerator memory capacity `M` (same unit as node `mem`).
    pub mem_cap: f64,
    pub comm_model: CommModel,
    pub train_schedule: TrainSchedule,
    /// Interconnect bandwidth used by the App.-C.2 replication DP's
    /// AllReduce weight-sync term (size units per time unit).
    pub bandwidth: f64,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            k: 6,
            l: 1,
            mem_cap: f64::INFINITY,
            comm_model: CommModel::Sequential,
            train_schedule: TrainSchedule::PipeDream,
            bandwidth: 1.0,
        }
    }
}

impl Scenario {
    pub fn new(k: usize, l: usize, mem_cap: f64) -> Self {
        Scenario { k, l, mem_cap, ..Default::default() }
    }

    pub fn num_devices(&self) -> usize {
        self.k + self.l
    }

    /// Combine a device's computation and communication loads per the
    /// scenario's comm model (see [`CommModel::combine`]).
    pub fn combine(&self, compute: f64, comm_in: f64, comm_out: f64) -> f64 {
        self.comm_model.combine(compute, comm_in, comm_out)
    }

    /// The [`PlanRequest`] equivalent of this scenario: a one-class
    /// uniform fleet (speed 1.0, shared cap), same comm model, schedule
    /// and bandwidth. Every solver is bitwise-identical on the two forms
    /// (see the uniform-fleet equivalence tests).
    pub fn to_request(&self) -> PlanRequest {
        PlanRequest {
            fleet: Fleet::uniform(self.k, self.l, self.mem_cap).bandwidth(self.bandwidth),
            objective: Objective::Throughput,
            comm_model: self.comm_model,
            train_schedule: self.train_schedule,
            contiguous: true,
            algorithm: AlgoChoice::Auto,
        }
    }
}

/// A device placement: every node assigned to exactly one device.
#[derive(Clone, Debug)]
pub struct Placement {
    pub assignment: Vec<Device>,
    /// Objective value claimed by the producing algorithm (TPS for
    /// throughput = max-load; end-to-end latency for the latency IP).
    pub objective: f64,
    /// Human-readable producer tag ("DP", "IP (non-contiguous)", …).
    pub algorithm: String,
}

impl Placement {
    pub fn new(assignment: Vec<Device>, objective: f64, algorithm: impl Into<String>) -> Self {
        Placement { assignment, objective, algorithm: algorithm.into() }
    }

    /// Node set on a given device.
    pub fn set_of(&self, device: Device, n: usize) -> BitSet {
        BitSet::from_iter(
            n,
            self.assignment
                .iter()
                .enumerate()
                .filter(|(_, &d)| d == device)
                .map(|(v, _)| v),
        )
    }

    /// All nodes on accelerators.
    pub fn acc_nodes(&self) -> BitSet {
        BitSet::from_iter(
            self.assignment.len(),
            self.assignment
                .iter()
                .enumerate()
                .filter(|(_, d)| d.is_acc())
                .map(|(v, _)| v),
        )
    }

    /// Dense device indices (`0..k` accs, `k..k+ℓ` CPUs) for rendering.
    pub fn dense(&self, k: usize) -> Vec<usize> {
        self.assignment.iter().map(|d| d.index(k)).collect()
    }

    /// Memory-feasibility check (constraint (3)): accelerator memory only.
    pub fn check_memory(&self, g: &OpGraph, sc: &Scenario) -> Result<(), String> {
        self.check_memory_req(g, &sc.to_request())
    }

    /// [`Placement::check_memory`] against a fleet: every accelerator is
    /// checked against its *own class's* cap.
    pub fn check_memory_req(&self, g: &OpGraph, req: &PlanRequest) -> Result<(), String> {
        for i in 0..req.fleet.k() {
            let set = self.set_of(Device::Acc(i), g.n());
            let used = g.mem_of(&set);
            let cap = req.fleet.acc_mem_cap(i);
            if used > cap * (1.0 + 1e-9) {
                let class =
                    req.fleet.class_of(Device::Acc(i)).map_or("?", |c| c.name.as_str());
                return Err(format!(
                    "accelerator {i} ({class}) over capacity: {used:.3} > {cap:.3}"
                ));
            }
        }
        Ok(())
    }

    /// Colocation check (App. B): same color class ⇒ same device.
    pub fn check_colocation(&self, g: &OpGraph) -> Result<(), String> {
        use std::collections::BTreeMap;
        let mut seen: BTreeMap<u32, Device> = BTreeMap::new();
        for (v, node) in g.nodes.iter().enumerate() {
            if let Some(c) = node.color_class {
                match seen.get(&c) {
                    None => {
                        seen.insert(c, self.assignment[v]);
                    }
                    Some(&d) if d != self.assignment[v] => {
                        return Err(format!(
                            "color class {c} split across {d} and {}",
                            self.assignment[v]
                        ));
                    }
                    _ => {}
                }
            }
        }
        Ok(())
    }

    /// Contiguity check (Def. 3.1) per accelerator; for training graphs the
    /// forward and backward parts are checked separately (§5.3). CPUs are
    /// never contiguity-constrained (§4 treats the CPU pool specially, and
    /// §5 pipelines may assign CPUs arbitrary sets).
    pub fn check_contiguity(&self, g: &OpGraph, sc: &Scenario) -> Result<(), String> {
        self.check_contiguity_k(g, sc.k)
    }

    /// [`Placement::check_contiguity`] over the first `k` accelerators
    /// (the fleet form: `k = fleet.k()`; contiguity is class-agnostic).
    pub fn check_contiguity_k(&self, g: &OpGraph, k: usize) -> Result<(), String> {
        let has_bw = g.nodes.iter().any(|n| n.kind == NodeKind::Backward);
        for i in 0..k {
            let set = self.set_of(Device::Acc(i), g.n());
            if !has_bw {
                if !crate::graph::contiguity::is_contiguous(g, &set) {
                    return Err(format!("accelerator {i} holds a non-contiguous set"));
                }
            } else {
                for kind in [NodeKind::Forward, NodeKind::Backward] {
                    let part = BitSet::from_iter(
                        g.n(),
                        set.iter().filter(|&v| g.nodes[v].kind == kind),
                    );
                    if !crate::graph::contiguity::is_contiguous(g, &part) {
                        return Err(format!(
                            "accelerator {i} holds a non-contiguous {kind:?} set"
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Validate everything an optimizer output must satisfy; `contiguous`
    /// toggles the Def.-3.1 check (non-contiguous optimizers skip it).
    pub fn validate(&self, g: &OpGraph, sc: &Scenario, contiguous: bool) -> Result<(), String> {
        let mut req = sc.to_request();
        req.contiguous = contiguous;
        self.validate_req(g, &req)
    }

    /// [`Placement::validate`] against a [`PlanRequest`]: per-class
    /// memory caps, device ranges from the fleet, and the Def.-3.1 check
    /// when `req.contiguous` is set.
    pub fn validate_req(&self, g: &OpGraph, req: &PlanRequest) -> Result<(), String> {
        if self.assignment.len() != g.n() {
            return Err("assignment length mismatch".into());
        }
        let (k, l) = (req.fleet.k(), req.fleet.l());
        for &d in &self.assignment {
            match d {
                Device::Acc(i) if i >= k => return Err(format!("device {d} out of range")),
                Device::Cpu(j) if j >= l.max(1) => {
                    return Err(format!("device {d} out of range"))
                }
                _ => {}
            }
        }
        self.check_memory_req(g, req)?;
        self.check_colocation(g)?;
        if req.contiguous {
            self.check_contiguity_k(g, k)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;

    fn g4() -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..4 {
            g.add_node(Node::new(format!("n{i}")).mem(1.0));
        }
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g.add_edge(2, 3);
        g
    }

    #[test]
    fn device_index_roundtrip() {
        let k = 3;
        for idx in 0..6 {
            assert_eq!(Device::from_index(idx, k).index(k), idx);
        }
        assert_eq!(Device::Acc(2).index(3), 2);
        assert_eq!(Device::Cpu(0).index(3), 3);
    }

    #[test]
    fn device_parse_roundtrips_display() {
        for d in [Device::Acc(0), Device::Acc(17), Device::Cpu(0), Device::Cpu(3)] {
            assert_eq!(Device::parse(&d.to_string()), Ok(d));
        }
        assert!(Device::parse("gpu0").is_err());
        assert!(Device::parse("acc").is_err());
        assert!(Device::parse("acc-1").is_err());
        assert!(Device::parse("cpu1x").is_err());
    }

    #[test]
    fn memory_validation() {
        let g = g4();
        let sc = Scenario::new(2, 1, 1.5);
        let p = Placement::new(
            vec![Device::Acc(0), Device::Acc(0), Device::Acc(1), Device::Cpu(0)],
            0.0,
            "t",
        );
        assert!(p.check_memory(&g, &sc).is_err()); // acc0 holds 2 > 1.5
        let sc_ok = Scenario::new(2, 1, 2.0);
        assert!(p.check_memory(&g, &sc_ok).is_ok());
    }

    #[test]
    fn contiguity_validation() {
        let g = g4();
        let sc = Scenario::new(1, 1, f64::INFINITY);
        let bad = Placement::new(
            vec![Device::Acc(0), Device::Cpu(0), Device::Acc(0), Device::Cpu(0)],
            0.0,
            "t",
        );
        assert!(bad.check_contiguity(&g, &sc).is_err());
        assert!(bad.validate(&g, &sc, false).is_ok()); // ok if non-contiguous allowed
        let good = Placement::new(
            vec![Device::Acc(0), Device::Acc(0), Device::Cpu(0), Device::Cpu(0)],
            0.0,
            "t",
        );
        assert!(good.validate(&g, &sc, true).is_ok());
    }

    #[test]
    fn colocation_validation() {
        let mut g = g4();
        g.nodes[0].color_class = Some(1);
        g.nodes[3].color_class = Some(1);
        let split = Placement::new(
            vec![Device::Acc(0), Device::Acc(0), Device::Acc(0), Device::Cpu(0)],
            0.0,
            "t",
        );
        assert!(split.check_colocation(&g).is_err());
        let together = Placement::new(vec![Device::Acc(0); 4], 0.0, "t");
        assert!(together.check_colocation(&g).is_ok());
    }

    #[test]
    fn comm_models_combine() {
        let sc = |m| Scenario { comm_model: m, ..Default::default() };
        assert_eq!(sc(CommModel::Sequential).combine(5.0, 2.0, 1.0), 8.0);
        assert_eq!(sc(CommModel::Overlap).combine(5.0, 2.0, 1.0), 5.0);
        assert_eq!(sc(CommModel::Overlap).combine(2.0, 4.0, 1.0), 5.0);
        assert_eq!(sc(CommModel::FullDuplex).combine(2.0, 4.0, 1.0), 4.0);
    }

    #[test]
    fn fleet_dense_indexing_and_class_lookup() {
        let fleet = Fleet::new(vec![
            DeviceClass::acc("a100", 2, 40.0).speed(4.0),
            DeviceClass::acc("t4", 3, 16.0),
            DeviceClass::cpu("cpu", 1),
        ]);
        assert_eq!(fleet.k(), 5);
        assert_eq!(fleet.l(), 1);
        assert_eq!(fleet.num_devices(), 6);
        for i in 0..2 {
            assert_eq!(fleet.class_of(Device::Acc(i)).unwrap().name, "a100");
            assert_eq!(fleet.acc_mem_cap(i), 40.0);
            assert_eq!(fleet.acc_speed(i), 4.0);
        }
        for i in 2..5 {
            assert_eq!(fleet.class_of(Device::Acc(i)).unwrap().name, "t4");
            assert_eq!(fleet.acc_mem_cap(i), 16.0);
            assert_eq!(fleet.acc_speed(i), 1.0);
        }
        assert_eq!(fleet.class_of(Device::Cpu(0)).unwrap().name, "cpu");
        assert_eq!(fleet.best_acc_speed(), Some(4.0));
        assert_eq!(fleet.min_acc_mem_cap(), 16.0);
        assert_eq!(fleet.min_acc_speed(), 1.0);
    }

    #[test]
    fn dense_view_agrees_with_per_index_accessors() {
        let fleet = Fleet::new(vec![
            DeviceClass::acc("a100", 2, 40.0).speed(4.0),
            DeviceClass::cpu("cpu", 2),
            DeviceClass::acc("t4", 0, 16.0), // count-0 class still owns an index
            DeviceClass::acc("l4", 3, 24.0).speed(2.0),
        ]);
        let dense = fleet.dense_view();
        assert_eq!(dense.len(), fleet.num_devices());
        let k = fleet.k();
        for (i, d) in dense.iter().enumerate() {
            let dev = Device::from_index(i, k);
            assert_eq!(d.kind == DeviceKind::Accelerator, dev.is_acc(), "device {i}");
            match dev {
                Device::Acc(a) => {
                    assert_eq!(d.mem_cap, fleet.acc_mem_cap(a), "cap of acc{a}");
                    assert_eq!(d.speed, fleet.acc_speed(a), "speed of acc{a}");
                }
                Device::Cpu(j) => {
                    assert!(d.mem_cap.is_infinite());
                    assert_eq!(d.speed, fleet.cpu_speed(j));
                }
            }
            // same dense class ⇔ same DeviceClass by identity
            for (i2, d2) in dense.iter().enumerate() {
                let same_class = fleet.class_of(dev).map(|c| c as *const DeviceClass)
                    == fleet
                        .class_of(Device::from_index(i2, k))
                        .map(|c| c as *const DeviceClass);
                assert_eq!(d.class == d2.class, same_class, "devices {i}/{i2}");
            }
        }
    }

    #[test]
    fn fleet_parse_grammar() {
        let fleet = Fleet::parse("2xfast:16,4xslow:8").unwrap();
        assert_eq!(fleet.classes.len(), 2);
        assert_eq!(fleet.classes[0].name, "fast");
        assert_eq!(fleet.classes[0].count, 2);
        assert_eq!(fleet.classes[0].mem_cap, 16.0);
        assert_eq!(fleet.classes[1].count, 4);
        assert_eq!(fleet.k(), 6);
        assert_eq!(fleet.l(), 0);

        let full = Fleet::parse("2xa100@4.0:40,4xt4:16,1xcpu").unwrap();
        assert_eq!(full.k(), 6);
        assert_eq!(full.l(), 1);
        assert_eq!(full.classes[0].speed, 4.0);
        assert_eq!(full.classes[2].kind, DeviceKind::Cpu);
        assert!(full.classes[2].mem_cap.is_infinite());

        // bare name, default count 1
        let one = Fleet::parse("gpu").unwrap();
        assert_eq!(one.classes[0].count, 1);
        assert_eq!(one.classes[0].kind, DeviceKind::Accelerator);

        assert!(Fleet::parse("").is_err());
        assert!(Fleet::parse("2xfast:oops").is_err());
        assert!(Fleet::parse("2xfast@-1").is_err());
    }

    #[test]
    fn fleet_display_reparses() {
        let fleet = Fleet::parse("2xa100@4:40,4xt4:16,1xcpu").unwrap();
        let round = Fleet::parse(&fleet.to_string()).unwrap();
        assert_eq!(fleet, round);
        // kind the name alone would mis-infer, plus explicit bandwidth
        let tricky = Fleet::new(vec![
            DeviceClass::cpu("pool", 2),                 // cpu named without "cpu"
            DeviceClass::acc("cpu_sim_accel", 1, 8.0),   // acc named WITH "cpu"
        ])
        .bandwidth(2.5);
        let round = Fleet::parse(&tricky.to_string()).unwrap();
        assert_eq!(tricky, round, "display was: {tricky}");
        assert_eq!(round.l(), 2);
        assert_eq!(round.k(), 1);
        // and the explicit-kind / bw grammar parses directly
        let explicit = Fleet::parse("2xpool+cpu,1xgpu:8,bw=2.5").unwrap();
        assert_eq!(explicit.classes[0].kind, DeviceKind::Cpu);
        assert_eq!(explicit.bandwidth, 2.5);
        assert!(Fleet::parse("2xpool+tpu").is_err());
        assert!(Fleet::parse("bw=-1,1xgpu").is_err());
    }

    #[test]
    fn fleet_topo_clause_parses_and_reparses() {
        let fleet = Fleet::parse("4xfast:16,1xcpu,topo=islands:2x2@800/100").unwrap();
        let t = fleet.topology.as_ref().expect("topology attached");
        assert_eq!(t.n(), 5);
        assert_eq!(fleet.pair_slowdown(0, 1), 1.0);
        assert_eq!(fleet.pair_slowdown(0, 2), 8.0);
        assert_eq!(fleet.transfer_cost(0, 2, 2.0), 16.0);
        assert_eq!(fleet.pair_cost(0, 0, 2.0), 0.0);
        assert_eq!(fleet.max_comm_slowdown(), 8.0);
        assert_eq!(fleet.worst_pair_cost(2.0), 16.0);
        let round = Fleet::parse(&fleet.to_string()).unwrap();
        assert_eq!(fleet, round, "display was: {fleet}");
        // shape/fleet mismatch and bad clauses stay loud
        assert!(Fleet::parse("2xfast,1xcpu,topo=islands:2x2@800/100").is_err());
        assert!(Fleet::parse("2xfast,1xcpu,topo=ring:4@10").is_err());
        assert!(Fleet::parse("2xfast,1xcpu,topology=uniform:1").is_err());
    }

    #[test]
    fn topologyless_fleet_accessors_are_identity() {
        let fleet = Fleet::parse("2xfast:16,1xcpu").unwrap();
        for (a, b) in [(0, 0), (0, 1), (2, 0)] {
            assert_eq!(fleet.pair_slowdown(a, b).to_bits(), 1.0_f64.to_bits());
            assert_eq!(fleet.pair_latency(a, b).to_bits(), 0.0_f64.to_bits());
            assert_eq!(fleet.transfer_cost(a, b, 3.25).to_bits(), 3.25_f64.to_bits());
        }
        assert_eq!(fleet.pair_cost(1, 1, 3.25), 0.0);
        assert_eq!(fleet.pair_cost(0, 1, 3.25), 3.25);
        assert_eq!(fleet.worst_pair_cost(3.25).to_bits(), 3.25_f64.to_bits());
    }

    #[test]
    fn decrement_and_increment_maintain_topology_slots() {
        // interleaved islands {0,2} / {1,3}: losing the class's last slot
        // (3) leaves island {0,2} intact
        let mut fleet = Fleet::parse("4xfast:16,1xcpu,topo=islands:0.2|1.3@800/100").unwrap();
        assert!(fleet.decrement("fast"));
        let t = fleet.topology.as_ref().expect("topology survives decrement");
        assert_eq!(t.n(), fleet.num_devices());
        assert_eq!(fleet.pair_slowdown(0, 2), 1.0);
        assert_eq!(fleet.pair_slowdown(0, 1), 8.0);
        assert!(fleet.increment("fast"));
        let t = fleet.topology.as_ref().expect("topology survives increment");
        assert_eq!(t.n(), fleet.num_devices());
        // the revived slot is cloned from its twin (slot 2) and joins its
        // island over the twin's fastest link
        assert_eq!(fleet.pair_slowdown(2, 3), 1.0);
        assert_eq!(fleet.pair_slowdown(1, 3), 8.0);
    }

    #[test]
    fn scenario_to_request_roundtrips_through_legacy_view() {
        let sc = Scenario::new(4, 2, 32.0);
        let req = sc.to_request();
        assert_eq!(req.k(), 4);
        assert_eq!(req.l(), 2);
        let back = req.legacy_scenario();
        assert_eq!(back.k, sc.k);
        assert_eq!(back.l, sc.l);
        assert_eq!(back.mem_cap, sc.mem_cap);
        assert_eq!(back.comm_model, sc.comm_model);
        assert_eq!(back.bandwidth, sc.bandwidth);
    }

    #[test]
    fn per_class_memory_validation() {
        let g = g4();
        // acc0 belongs to a tight class (cap 1.5), acc1 to a roomy one
        let req = PlanRequest::new(Fleet::new(vec![
            DeviceClass::acc("tight", 1, 1.5),
            DeviceClass::acc("roomy", 1, 10.0),
            DeviceClass::cpu("cpu", 1),
        ]));
        let heavy_on_tight = Placement::new(
            vec![Device::Acc(0), Device::Acc(0), Device::Acc(1), Device::Cpu(0)],
            0.0,
            "t",
        );
        assert!(heavy_on_tight.check_memory_req(&g, &req).is_err());
        let heavy_on_roomy = Placement::new(
            vec![Device::Acc(1), Device::Acc(1), Device::Acc(0), Device::Cpu(0)],
            0.0,
            "t",
        );
        assert!(heavy_on_roomy.check_memory_req(&g, &req).is_ok());
        assert!(heavy_on_roomy.validate_req(&g, &req).is_ok());
    }

    #[test]
    fn fleet_decrement_models_device_loss() {
        let mut fleet = Fleet::parse("2xfast:16,1xcpu").unwrap();
        assert!(fleet.decrement("fast"));
        assert_eq!(fleet.k(), 1);
        assert!(fleet.decrement("fast"));
        assert!(!fleet.decrement("fast"), "empty class cannot lose a device");
        assert!(!fleet.decrement("nope"));
        fleet.class_named_mut("cpu").unwrap().count = 3;
        assert_eq!(fleet.l(), 3);
    }

    #[test]
    fn fleet_increment_models_device_recovery() {
        let mut fleet = Fleet::parse("2xfast:16,1xcpu").unwrap();
        assert!(fleet.decrement("fast"));
        assert!(fleet.increment("fast"), "recovery restores the lost slot");
        assert_eq!(fleet.k(), 2);
        assert!(!fleet.increment("nope"));
        // increment ∘ decrement is the identity on the parse/Display form
        let spec = fleet.to_string();
        assert!(fleet.decrement("fast") && fleet.increment("fast"));
        assert_eq!(fleet.to_string(), spec);
        // a fully drained class can be revived (count 0 → 1)
        assert!(fleet.decrement("fast") && fleet.decrement("fast"));
        assert_eq!(fleet.k(), 0);
        assert!(fleet.increment("fast"));
        assert_eq!(fleet.k(), 1);
    }

    #[test]
    fn drained_classes_stop_constraining_conservative_views() {
        let mut fleet = Fleet::new(vec![
            DeviceClass::acc("big", 1, 40.0).speed(4.0),
            DeviceClass::acc("small", 1, 8.0),
        ]);
        assert_eq!(fleet.min_acc_mem_cap(), 8.0);
        assert_eq!(fleet.min_acc_speed(), 1.0);
        // losing the last small device must lift its cap/speed bounds
        assert!(fleet.decrement("small"));
        assert_eq!(fleet.min_acc_mem_cap(), 40.0);
        assert_eq!(fleet.min_acc_speed(), 4.0);
        // the compute lower-bound divisor keeps declared classes (sound:
        // a faster absent class only weakens the bound)
        assert_eq!(fleet.best_acc_speed(), Some(4.0));
    }

    #[test]
    fn set_of_and_dense() {
        let p = Placement::new(
            vec![Device::Acc(1), Device::Cpu(0), Device::Acc(1), Device::Acc(0)],
            0.0,
            "t",
        );
        let s = p.set_of(Device::Acc(1), 4);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(p.dense(2), vec![1, 2, 1, 0]);
    }
}

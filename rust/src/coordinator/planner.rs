//! One-call planning façade over every algorithm and baseline.
//!
//! [`Algorithm`] is the CLI surface; each variant resolves to a boxed
//! [`Solver`] (the registry), so planning is uniformly
//! `alg.solver().solve(&ctx, &opts)` — the old hand-written 10-arm match
//! with per-arm error plumbing is gone. [`plan`] remains as the historical
//! one-shot entry point (it builds a throwaway [`ProblemCtx`]); callers
//! that re-plan should go through
//! [`crate::coordinator::service::PlannerService`] to reuse the analysis.

use crate::algos::hierarchy::Hierarchy;
use crate::algos::{
    dp, hierarchy, ip_latency, ip_throughput, objective, replication, PlaceError,
};
use crate::baselines::{expert, greedy, local_search, pipedream, scotch_like};
use crate::coordinator::context::{
    PlanQuality, PlanRung, ProblemCtx, SolveOpts, Solver, WarmSeed,
};
use crate::coordinator::placement::{Objective, Placement, PlanRequest, Scenario};
use crate::graph::ideals::IdealLattice;
use crate::graph::OpGraph;
use crate::workloads::Workload;
use std::time::{Duration, Instant};

// The fleet-level algorithm selector lives with the request type; re-export
// it here so `planner::AlgoChoice` reads naturally next to `Algorithm`.
pub use crate::coordinator::placement::AlgoChoice;

// `PlanResult` moved to `context` with the `Solver` trait; re-exported here
// so `planner::PlanResult` keeps resolving for existing callers.
pub use crate::coordinator::context::PlanResult;

/// Algorithm selector (CLI surface).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Dp,
    Dpl,
    IpContiguous,
    IpNonContiguous,
    Expert,
    LocalSearch,
    PipeDream,
    Scotch,
    Greedy,
    IpLatency,
    Replication,
    Hierarchy,
}

impl Algorithm {
    /// Every registered algorithm and baseline.
    pub const ALL: [Algorithm; 12] = [
        Algorithm::Dp,
        Algorithm::Dpl,
        Algorithm::IpContiguous,
        Algorithm::IpNonContiguous,
        Algorithm::Expert,
        Algorithm::LocalSearch,
        Algorithm::PipeDream,
        Algorithm::Scotch,
        Algorithm::Greedy,
        Algorithm::IpLatency,
        Algorithm::Replication,
        Algorithm::Hierarchy,
    ];

    pub const ALL_THROUGHPUT: [Algorithm; 8] = [
        Algorithm::Dp,
        Algorithm::IpContiguous,
        Algorithm::IpNonContiguous,
        Algorithm::Dpl,
        Algorithm::Expert,
        Algorithm::LocalSearch,
        Algorithm::PipeDream,
        Algorithm::Scotch,
    ];

    /// Canonical registry name (round-trips through [`Algorithm::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Dp => "dp",
            Algorithm::Dpl => "dpl",
            Algorithm::IpContiguous => "ip-contiguous",
            Algorithm::IpNonContiguous => "ip-noncontiguous",
            Algorithm::Expert => "expert",
            Algorithm::LocalSearch => "local-search",
            Algorithm::PipeDream => "pipedream",
            Algorithm::Scotch => "scotch",
            Algorithm::Greedy => "greedy",
            Algorithm::IpLatency => "ip-latency",
            Algorithm::Replication => "replication",
            Algorithm::Hierarchy => "hierarchy",
        }
    }

    pub fn parse(s: &str) -> Option<Algorithm> {
        let s = s.to_ascii_lowercase();
        // aliases first, then canonical names
        Some(match s.as_str() {
            "ip" => Algorithm::IpContiguous,
            "ipnc" => Algorithm::IpNonContiguous,
            "ls" => Algorithm::LocalSearch,
            "rep" => Algorithm::Replication,
            "hier" => Algorithm::Hierarchy,
            _ => return Algorithm::ALL.into_iter().find(|a| a.name() == s),
        })
    }

    /// The registry: resolve this selector to its [`Solver`].
    pub fn solver(self) -> Box<dyn Solver> {
        match self {
            Algorithm::Dp => Box::new(DpSolver),
            Algorithm::Dpl => Box::new(DplSolver),
            Algorithm::IpContiguous => Box::new(IpThroughputSolver { contiguous: true }),
            Algorithm::IpNonContiguous => Box::new(IpThroughputSolver { contiguous: false }),
            Algorithm::Expert => Box::new(ExpertSolver),
            Algorithm::LocalSearch => Box::new(LocalSearchSolver),
            Algorithm::PipeDream => Box::new(PipeDreamSolver),
            Algorithm::Scotch => Box::new(ScotchSolver),
            Algorithm::Greedy => Box::new(GreedySolver),
            Algorithm::IpLatency => Box::new(IpLatencySolver { contiguous: true }),
            Algorithm::Replication => Box::new(ReplicationSolver),
            Algorithm::Hierarchy => Box::new(HierarchySolver),
        }
    }
}

/// All registered solvers, in [`Algorithm::ALL`] order (name → solver).
pub fn registry() -> Vec<Box<dyn Solver>> {
    Algorithm::ALL.iter().map(|a| a.solver()).collect()
}

/// Plan a split of `w` with `alg`. IP time budget via `ip_budget`. One-shot:
/// builds a fresh [`ProblemCtx`]; use a
/// [`crate::coordinator::service::PlannerService`] to amortize analysis
/// across plans. Fleet-aware: a workload carrying a heterogeneous
/// [`crate::coordinator::placement::Fleet`] plans against it; scalar
/// workloads plan against their scenario's uniform fleet, bit-for-bit as
/// before.
pub fn plan(
    w: &Workload,
    alg: Algorithm,
    ip_budget: Duration,
) -> Result<PlanResult, PlaceError> {
    let opts = SolveOpts { ip_budget, expert: w.expert, ..SolveOpts::default() };
    let ctx = ProblemCtx::from_request(w.graph.clone(), w.request());
    run_traced(&*alg.solver(), &ctx, &opts)
}

/// [`plan`] with caller-supplied [`SolveOpts`] — the deadline-aware
/// one-shot entry point (`partition --deadline-ms`). Routes through
/// [`solve_request`], so a budget deadline engages the degradation ladder:
/// a too-tight deadline degrades to a lower rung (result tagged
/// [`PlanQuality::Anytime`]) instead of erroring. Without a deadline this
/// is the plain registry dispatch, bitwise.
pub fn plan_opts(
    w: &Workload,
    alg: Algorithm,
    opts: &SolveOpts,
) -> Result<PlanResult, PlaceError> {
    let req = w.request().algorithm(AlgoChoice::Fixed(alg));
    let ctx = ProblemCtx::from_request(w.graph.clone(), req.clone());
    solve_request(&ctx, &req, opts)
}

/// Run a solver under an obs span named after it (`solve.dp`,
/// `solve.ip-contiguous`, …) so solver phases nest inside whatever span
/// the caller has open (a `--profile` run, a serving re-plan). Inert when
/// recording is off; never changes the call itself.
///
/// This is also the panic-isolation boundary: a solver bug that unwinds is
/// caught here and surfaced as [`PlaceError::SolverPanicked`], so one
/// buggy solve fails one request instead of tearing down its thread (and,
/// through a poisoned shard mutex, every tenant behind it). The
/// `AssertUnwindSafe` is sound for observers: the shared [`ProblemCtx`]
/// memoizes through `OnceLock`, whose `get_or_init` leaves the cell
/// untouched when its initializer unwinds.
fn run_traced(
    s: &dyn Solver,
    ctx: &ProblemCtx,
    opts: &SolveOpts,
) -> Result<PlanResult, PlaceError> {
    let _span = crate::obs::span_cat(&format!("solve.{}", s.name()), "solver");
    let name = s.name();
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| s.solve(ctx, opts))) {
        Ok(r) => r,
        Err(payload) => {
            crate::obs::counter("plan_solver_panics_total").inc();
            Err(PlaceError::SolverPanicked(format!("{name}: {}", panic_message(&payload))))
        }
    }
}

/// Best-effort text of a caught panic payload (`&str` and `String` cover
/// every `panic!` in this crate).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// One-shot planning of a [`PlanRequest`] (fleet + objective + algorithm
/// selection, `Auto` included). Builds a throwaway [`ProblemCtx`]; use
/// [`crate::coordinator::service::PlannerService::plan_request`] to reuse
/// analysis across re-plans.
pub fn plan_request(
    g: &OpGraph,
    req: &PlanRequest,
    opts: &SolveOpts,
) -> Result<PlanResult, PlaceError> {
    let ctx = ProblemCtx::from_request(g.clone(), req.clone());
    solve_request(&ctx, req, opts)
}

/// Dispatch a request's algorithm selection against an existing context.
/// `Auto` resolves by objective AND the request's contiguity toggle:
/// latency → the latency IP (contiguous per the request); throughput →
/// the exact DP with a DPL fallback when the lattice blows its cap (the
/// paper's own §5.1.2 recommendation), or the §5.2 non-contiguous IP when
/// `contiguous` is off (the DP/DPL search contiguous splits by
/// construction). A `Fixed` algorithm declares its own contiguity regime
/// (`ip-contiguous` vs `ip-noncontiguous`; latency honors the toggle) and
/// is run as named. The context must share the request's
/// fingerprint-relevant fields (fleet/comm/schedule) — solver selectors
/// may differ.
pub fn solve_request(
    ctx: &ProblemCtx,
    req: &PlanRequest,
    opts: &SolveOpts,
) -> Result<PlanResult, PlaceError> {
    // No deadline ⇒ the historical dispatch, bitwise (a bare node limit
    // still reaches the IP engines through `opts.budget`, but triggers no
    // ladder — truncation surfaces as an `Anytime` result or an error).
    if opts.budget.deadline.is_none() {
        return dispatch_request(ctx, req, opts);
    }
    // Deadline set: degrade instead of erroring or overrunning.
    if opts.budget.expired() {
        crate::obs::counter("plan_deadline_hits_total").inc();
        return fallback_ladder(ctx, req, opts, true);
    }
    match dispatch_request(ctx, req, opts) {
        Ok(r) => {
            if matches!(r.quality, PlanQuality::Anytime(_)) {
                crate::obs::counter("plan_deadline_hits_total").inc();
            }
            Ok(r)
        }
        // Problem/config errors no amount of degrading fixes: a proven
        // infeasibility, a cyclic graph, a missing expert rule.
        Err(
            e @ (PlaceError::Infeasible | PlaceError::NotADag | PlaceError::MissingExpertRule),
        ) => Err(e),
        // Budget-shaped failures (no incumbent, blown lattice cap, …):
        // walk down the ladder.
        Err(_) => {
            crate::obs::counter("plan_deadline_hits_total").inc();
            fallback_ladder(ctx, req, opts, false)
        }
    }
}

/// The pre-ladder dispatch (see [`solve_request`] docs). Under a deadline,
/// `Auto` throughput requests go to the budget-aware IP first — the only
/// engine with per-node cooperative cancellation — instead of the DP,
/// whose lattice enumeration checks its budget only at the coarse
/// ideal-count granularity.
fn dispatch_request(
    ctx: &ProblemCtx,
    req: &PlanRequest,
    opts: &SolveOpts,
) -> Result<PlanResult, PlaceError> {
    match req.algorithm {
        AlgoChoice::Fixed(Algorithm::IpLatency) => {
            run_traced(&IpLatencySolver { contiguous: req.contiguous }, ctx, opts)
        }
        AlgoChoice::Fixed(alg) => run_traced(&*alg.solver(), ctx, opts),
        AlgoChoice::Auto => match req.objective {
            Objective::Latency => {
                run_traced(&IpLatencySolver { contiguous: req.contiguous }, ctx, opts)
            }
            Objective::Throughput if !req.contiguous => {
                run_traced(&*Algorithm::IpNonContiguous.solver(), ctx, opts)
            }
            Objective::Throughput if opts.budget.deadline.is_some() => {
                run_traced(&*Algorithm::IpContiguous.solver(), ctx, opts)
            }
            Objective::Throughput => match run_traced(&*Algorithm::Dp.solver(), ctx, opts) {
                Err(PlaceError::TooManyIdeals(_)) => {
                    run_traced(&*Algorithm::Dpl.solver(), ctx, opts)
                }
                r => r,
            },
        },
    }
}

/// Ideal-count bound for the ladder's DP rung: the lattice solvers' coarse
/// node-count budget check. A lattice that enumerates within this bound is
/// complete (the rung's DP is exact); one that exceeds it aborts the rung
/// instead of hanging the deadline on a full-cap enumeration.
const LADDER_IDEAL_CAP: usize = 20_000;

/// The deadline degradation ladder below the primary solver: exact DP
/// (bounded enumeration) → DPL → greedy for throughput, straight to greedy
/// for latency (the DP family doesn't speak that objective) or when the
/// deadline has `expired` before any rung could search. Each rung bumps
/// `plan_fallback_total{rung=…}`; the greedy floor always answers, so a
/// deadline-budgeted request never errors for budget-shaped reasons.
fn fallback_ladder(
    ctx: &ProblemCtx,
    req: &PlanRequest,
    opts: &SolveOpts,
    expired: bool,
) -> Result<PlanResult, PlaceError> {
    if req.objective == Objective::Throughput && !expired && !opts.budget.expired() {
        if let Ok(r) = dp_rung(ctx) {
            return Ok(r);
        }
    }
    greedy_rung(ctx, req)
}

/// The ladder's DP rung. Exact DP from the context cache when that is
/// free (lattice already built) or affordable (cap within
/// [`LADDER_IDEAL_CAP`]); otherwise a LOCAL enumeration bounded by the
/// same cap — never the context's full-cap enumeration on a deadline's
/// clock. A bound-respecting enumeration is complete, so the rung's plan
/// is the exact DP optimum; blowing the bound falls through to DPL.
fn dp_rung(ctx: &ProblemCtx) -> Result<PlanResult, PlaceError> {
    let start = Instant::now();
    let prepared = ctx.prepared()?;
    let solved = if ctx.lattice_if_built().is_some() || ctx.ideal_cap() <= LADDER_IDEAL_CAP {
        ctx.dp_solution().map(Clone::clone)
    } else {
        IdealLattice::enumerate(&prepared.dp_graph, LADDER_IDEAL_CAP)
            .map_err(PlaceError::TooManyIdeals)
            .and_then(|lat| {
                dp::solve_on_lattice_req(
                    &prepared.dp_graph,
                    ctx.request(),
                    &lat,
                    &prepared.bw_comm,
                )
            })
    };
    match solved {
        Ok((obj, dense)) => {
            let placement = prepared.expand_req(ctx.graph(), ctx.request(), obj, &dense);
            crate::obs::counter("plan_fallback_total{rung=\"dp\"}").inc();
            let mut r = PlanResult::basic(placement, start.elapsed());
            r.note = "deadline fallback: dp".into();
            r.quality = PlanQuality::Anytime(PlanRung::Dp);
            Ok(r)
        }
        Err(_) => {
            let (obj, dense) = ctx.dpl_solution()?.clone();
            let mut placement =
                ctx.prepared()?.expand_req(ctx.graph(), ctx.request(), obj, &dense);
            placement.algorithm = "DPL".into();
            crate::obs::counter("plan_fallback_total{rung=\"dpl\"}").inc();
            let mut r = PlanResult::basic(placement, start.elapsed());
            r.note = "deadline fallback: dpl".into();
            r.quality = PlanQuality::Anytime(PlanRung::Dpl);
            Ok(r)
        }
    }
}

/// The ladder's floor: the greedy baseline, re-scored under the request's
/// objective. Always answers (greedy never fails), so the ladder cannot
/// bottom out in an error.
fn greedy_rung(ctx: &ProblemCtx, req: &PlanRequest) -> Result<PlanResult, PlaceError> {
    let start = Instant::now();
    let mut p = greedy::solve_req(ctx.graph(), ctx.request());
    if req.objective == Objective::Latency {
        p.objective = objective::latency_req(ctx.graph(), ctx.request(), &p);
    }
    crate::obs::counter("plan_fallback_total{rung=\"greedy\"}").inc();
    let mut r = PlanResult::basic(p, start.elapsed());
    r.note = "deadline fallback: greedy".into();
    r.quality = PlanQuality::Anytime(PlanRung::Greedy);
    Ok(r)
}


/// The warm-seed cache key of the IP engine [`solve_request`] will run for
/// this request, or `None` when the request resolves to a deterministic or
/// heuristic solver (those gain nothing from incumbent seeding — their
/// outputs are already cached whole in the [`ProblemCtx`]). The key
/// encodes the engine *and* its contiguity regime, so a non-contiguous
/// incumbent can never seed a contiguous search (it might violate
/// constraint (16)) and a latency incumbent can never seed a throughput
/// one (different space and objective). Used by
/// [`crate::coordinator::concurrent::ConcurrentService`] as the second
/// half of its `(fingerprint, key)` incumbent-cache key.
pub fn warm_seed_key(req: &PlanRequest) -> Option<u8> {
    match req.algorithm {
        AlgoChoice::Fixed(Algorithm::IpContiguous) => Some(0),
        AlgoChoice::Fixed(Algorithm::IpNonContiguous) => Some(1),
        AlgoChoice::Fixed(Algorithm::IpLatency) => Some(if req.contiguous { 2 } else { 3 }),
        AlgoChoice::Fixed(_) => None,
        AlgoChoice::Auto => match req.objective {
            Objective::Latency => Some(if req.contiguous { 2 } else { 3 }),
            Objective::Throughput if !req.contiguous => Some(1),
            // Auto throughput runs the DP/DPL — deterministic, no seed
            Objective::Throughput => None,
        },
    }
}

/// Latency of any placement under the §4 schedule (for Table-4 baselines).
pub fn latency_of(g: &OpGraph, sc: &Scenario, p: &Placement) -> f64 {
    objective::latency(g, sc, p)
}

/// [`latency_of`] against a fleet request.
pub fn latency_of_req(g: &OpGraph, req: &PlanRequest, p: &Placement) -> f64 {
    objective::latency_req(g, req, p)
}

// ---------------------------------------------------------------------------
// Solver implementations (the registry entries)
// ---------------------------------------------------------------------------

/// Exact throughput DP (§5.1.1). Its deterministic solution is cached in
/// the context, so repeated plans cost one table expansion.
pub struct DpSolver;

impl Solver for DpSolver {
    fn name(&self) -> &'static str {
        "dp"
    }

    fn solve(&self, ctx: &ProblemCtx, opts: &SolveOpts) -> Result<PlanResult, PlaceError> {
        let start = Instant::now();
        // Coarse cooperative cancellation: the DP's unit of work is a
        // whole memoized artifact (preprocessing, lattice, table), so the
        // budget is checked between artifacts — never inside them, which
        // would memoize a budget-dependent value into the shared context.
        // `NoIncumbent` hands the deadline ladder the floor.
        if opts.budget.expired() {
            return Err(PlaceError::NoIncumbent);
        }
        let prepared = ctx.prepared()?;
        if opts.budget.expired() {
            return Err(PlaceError::NoIncumbent);
        }
        let lattice = ctx.lattice()?;
        if let Some(limit) = opts.budget.node_limit {
            // the lattice's ideals are this solver's "search nodes"
            if lattice.len() as u64 > limit {
                return Err(PlaceError::NoIncumbent);
            }
        }
        if opts.budget.expired() {
            return Err(PlaceError::NoIncumbent);
        }
        let (obj, dense) = ctx.dp_solution()?.clone();
        let placement = prepared.expand_req(ctx.graph(), ctx.request(), obj, &dense);
        Ok(PlanResult::basic(placement, start.elapsed()))
    }
}

/// Linearization heuristic (§5.1.2).
pub struct DplSolver;

impl Solver for DplSolver {
    fn name(&self) -> &'static str {
        "dpl"
    }

    fn solve(&self, ctx: &ProblemCtx, opts: &SolveOpts) -> Result<PlanResult, PlaceError> {
        let start = Instant::now();
        // same coarse between-artifact budget checks as the DP (the DPL's
        // prefix lattice is |V|+1 ideals — building it is never the cost)
        if opts.budget.expired() {
            return Err(PlaceError::NoIncumbent);
        }
        let (obj, dense) = ctx.dpl_solution()?.clone();
        let mut placement =
            ctx.prepared()?.expand_req(ctx.graph(), ctx.request(), obj, &dense);
        placement.algorithm = "DPL".into();
        Ok(PlanResult::basic(placement, start.elapsed()))
    }
}

/// Fig.-6 throughput IP (contiguous or §5.2 non-contiguous).
pub struct IpThroughputSolver {
    pub contiguous: bool,
}

impl Solver for IpThroughputSolver {
    fn name(&self) -> &'static str {
        if self.contiguous {
            "ip-contiguous"
        } else {
            "ip-noncontiguous"
        }
    }

    fn solve(&self, ctx: &ProblemCtx, opts: &SolveOpts) -> Result<PlanResult, PlaceError> {
        let ip_opts = ip_throughput::IpOptions {
            contiguous: self.contiguous,
            time_limit: opts.ip_budget,
            gap_target: opts.gap_target,
            // a latency seed is a different space/objective — regime
            // matching is the incumbent cache's job (warm_seed_key), this
            // is only the type-level filter
            warm_seed: match &opts.warm_seed {
                Some(WarmSeed::Throughput { objective, dense }) => {
                    Some((*objective, dense.clone()))
                }
                _ => None,
            },
            budget: opts.budget,
            ..Default::default()
        };
        let r = ip_throughput::solve_ctx(ctx, &ip_opts)?;
        let (obj, dense) = r.incumbent;
        Ok(PlanResult {
            placement: r.placement,
            runtime: r.elapsed,
            incumbent_at: Some(r.incumbent_at),
            gap: Some(r.gap),
            note: format!("{:?}", r.status),
            warm_seed: Some(WarmSeed::Throughput { objective: obj, dense }),
            quality: if r.truncated {
                PlanQuality::Anytime(PlanRung::Ip)
            } else {
                PlanQuality::Exact
            },
        })
    }
}

/// Figs.-3/4 latency IP (§4), warm-started from the greedy baseline.
/// `contiguous` toggles the one-subgraph-per-accelerator constraint
/// (Fig. 3) vs the Fig.-4 serialized-pieces relaxation; the registry
/// entry is contiguous, [`solve_request`] threads the request's toggle.
pub struct IpLatencySolver {
    pub contiguous: bool,
}

impl Solver for IpLatencySolver {
    fn name(&self) -> &'static str {
        "ip-latency"
    }

    fn solve(&self, ctx: &ProblemCtx, opts: &SolveOpts) -> Result<PlanResult, PlaceError> {
        let mut warm = vec![greedy::solve_req(ctx.graph(), ctx.request())];
        // resume seed: a prior run's final placement of this exact problem
        // + regime, re-validated by the engine like any other warm start
        if let Some(WarmSeed::Latency(p)) = &opts.warm_seed {
            warm.push(p.clone());
        }
        let lat_opts = ip_latency::LatencyIpOptions {
            time_limit: opts.ip_budget,
            gap_target: opts.gap_target,
            warm_starts: warm,
            contiguous: self.contiguous,
            budget: opts.budget,
            ..Default::default()
        };
        let r = ip_latency::solve_ctx(ctx, &lat_opts)?;
        let seed = WarmSeed::Latency(r.placement.clone());
        Ok(PlanResult {
            placement: r.placement,
            runtime: r.elapsed,
            incumbent_at: Some(r.incumbent_at),
            gap: Some(r.gap),
            note: format!("{:?}", r.status),
            warm_seed: Some(seed),
            quality: if r.truncated {
                PlanQuality::Anytime(PlanRung::Ip)
            } else {
                PlanQuality::Exact
            },
        })
    }
}

/// App.-C.2 hybrid model/data-parallel DP.
pub struct ReplicationSolver;

impl Solver for ReplicationSolver {
    fn name(&self) -> &'static str {
        "replication"
    }

    fn solve(&self, ctx: &ProblemCtx, _opts: &SolveOpts) -> Result<PlanResult, PlaceError> {
        let start = Instant::now();
        let rep = replication::solve_ctx(ctx)?;
        let replicated = rep.stage_devices.iter().filter(|d| d.len() > 1).count();
        let note = format!("{} stages, {replicated} replicated", rep.stage_devices.len());
        let mut result = PlanResult::basic(rep.primary_placement(), start.elapsed());
        result.note = note;
        Ok(result)
    }
}

/// App.-C.3 two-level accelerator hierarchies. Topology from
/// [`SolveOpts::hierarchy`], defaulting to an even two-cluster split of
/// the scenario's accelerators (odd `k` leaves the last accelerator idle).
pub struct HierarchySolver;

impl HierarchySolver {
    fn default_hierarchy(req: &PlanRequest) -> Hierarchy {
        let k = req.fleet.k();
        let num_clusters = if k >= 2 { 2 } else { 1 };
        Hierarchy {
            num_clusters,
            accs_per_cluster: (k / num_clusters).max(1),
            inter_factor: 4.0,
            // clusters are modeled uniformly: the smallest class cap is
            // the only bound every slot can honor
            mem_cap: req.fleet.min_acc_mem_cap(),
        }
    }
}

impl Solver for HierarchySolver {
    fn name(&self) -> &'static str {
        "hierarchy"
    }

    fn solve(&self, ctx: &ProblemCtx, opts: &SolveOpts) -> Result<PlanResult, PlaceError> {
        let start = Instant::now();
        let hier = opts
            .hierarchy
            .clone()
            .unwrap_or_else(|| Self::default_hierarchy(ctx.request()));
        let h = hierarchy::solve_ctx(ctx, &hier)?;
        let note = format!(
            "{}x{} clusters, inter-factor {}",
            hier.num_clusters, hier.accs_per_cluster, hier.inter_factor
        );
        let mut result = PlanResult::basic(h.placement, start.elapsed());
        result.note = note;
        Ok(result)
    }
}

/// Human-expert placement rules (§6, layer graphs only).
pub struct ExpertSolver;

impl Solver for ExpertSolver {
    fn name(&self) -> &'static str {
        "expert"
    }

    fn solve(&self, ctx: &ProblemCtx, opts: &SolveOpts) -> Result<PlanResult, PlaceError> {
        let style = opts.expert.ok_or(PlaceError::MissingExpertRule)?;
        let start = Instant::now();
        let p = expert::solve_req(ctx.graph(), ctx.request(), style);
        Ok(PlanResult::basic(p, start.elapsed()))
    }
}

/// Random-restart local search baseline [MKA07].
pub struct LocalSearchSolver;

impl Solver for LocalSearchSolver {
    fn name(&self) -> &'static str {
        "local-search"
    }

    fn solve(&self, ctx: &ProblemCtx, opts: &SolveOpts) -> Result<PlanResult, PlaceError> {
        let start = Instant::now();
        let p =
            local_search::solve_req(ctx.graph(), ctx.request(), opts.ls_restarts, opts.ls_seed);
        Ok(PlanResult::basic(p, start.elapsed()))
    }
}

/// PipeDream's linear-chain DP baseline [NHP+19].
pub struct PipeDreamSolver;

impl Solver for PipeDreamSolver {
    fn name(&self) -> &'static str {
        "pipedream"
    }

    fn solve(&self, ctx: &ProblemCtx, _opts: &SolveOpts) -> Result<PlanResult, PlaceError> {
        let start = Instant::now();
        let p = pipedream::solve_req(ctx.graph(), ctx.request());
        Ok(PlanResult::basic(p, start.elapsed()))
    }
}

/// Scotch-style multilevel partitioner baseline.
pub struct ScotchSolver;

impl Solver for ScotchSolver {
    fn name(&self) -> &'static str {
        "scotch"
    }

    fn solve(&self, ctx: &ProblemCtx, opts: &SolveOpts) -> Result<PlanResult, PlaceError> {
        let start = Instant::now();
        let p = scotch_like::solve_req(ctx.graph(), ctx.request(), opts.scotch_seed);
        Ok(PlanResult::basic(p, start.elapsed()))
    }
}

/// Greedy topological bin-filling baseline (§7).
pub struct GreedySolver;

impl Solver for GreedySolver {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn solve(&self, ctx: &ProblemCtx, _opts: &SolveOpts) -> Result<PlanResult, PlaceError> {
        let start = Instant::now();
        let p = greedy::solve_req(ctx.graph(), ctx.request());
        Ok(PlanResult::basic(p, start.elapsed()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::expert::ExpertStyle;
    use crate::coordinator::service::PlannerService;
    use crate::graph::Node;
    use crate::util::counters;
    use crate::workloads::table1_workloads;

    #[test]
    fn algorithm_parse_roundtrip_covers_every_variant() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.name()), Some(a), "roundtrip of {a:?}");
            assert_eq!(a.solver().name(), a.name(), "registry name of {a:?}");
        }
        // aliases and case-insensitivity
        for (s, a) in [
            ("DPL", Algorithm::Dpl),
            ("ip", Algorithm::IpContiguous),
            ("ipnc", Algorithm::IpNonContiguous),
            ("ls", Algorithm::LocalSearch),
            ("rep", Algorithm::Replication),
            ("hier", Algorithm::Hierarchy),
            ("IP-LATENCY", Algorithm::IpLatency),
        ] {
            assert_eq!(Algorithm::parse(s), Some(a));
        }
        assert_eq!(Algorithm::parse("nope"), None);
        assert_eq!(registry().len(), Algorithm::ALL.len());
    }

    #[test]
    fn plan_small_workload_all_algorithms() {
        // BERT-24 layer inference: small enough to run everything quickly
        let w = table1_workloads().into_iter().find(|w| w.name == "BERT-24").unwrap();
        let budget = Duration::from_secs(2);
        let dp = plan(&w, Algorithm::Dp, budget).unwrap();
        for alg in [
            Algorithm::Dpl,
            Algorithm::Expert,
            Algorithm::LocalSearch,
            Algorithm::PipeDream,
            Algorithm::Scotch,
        ] {
            let r = plan(&w, alg, budget).unwrap();
            assert!(
                r.placement.objective >= dp.placement.objective - 1e-9,
                "{alg:?} beat the DP: {} < {}",
                r.placement.objective,
                dp.placement.objective
            );
        }
    }

    /// A small two-branch graph that exercises every throughput algorithm
    /// fast (the IPs close it in milliseconds).
    fn two_branch_graph() -> crate::graph::OpGraph {
        let mut g = crate::graph::OpGraph::new();
        let s = g.add_node(Node::new("src_0").cpu(1.0).acc(0.2).mem(0.5).comm(0.05));
        let (mut la, mut lb) = (s, s);
        for i in 0..5 {
            let a = g.add_node(Node::new(format!("a_{i}")).cpu(8.0).acc(1.0).mem(1.0).comm(0.1));
            g.add_edge(la, a);
            la = a;
            let b = g.add_node(Node::new(format!("b_{i}")).cpu(8.0).acc(1.0).mem(1.0).comm(0.1));
            g.add_edge(lb, b);
            lb = b;
        }
        let t = g.add_node(Node::new("sink_0").cpu(1.0).acc(0.2).mem(0.5).comm(0.05));
        g.add_edge(la, t);
        g.add_edge(lb, t);
        g
    }

    #[test]
    fn shared_analysis_built_at_most_once_across_all_throughput_algorithms() {
        // The ISSUE-2 acceptance criterion: planning ALL of the throughput
        // algorithms through a PlannerService invokes
        // IdealLattice::enumerate and topo::{reachability,
        // co_reachability}_matrix at most once each per (graph, scenario);
        // a second pass over the cached context builds nothing at all.
        let g = two_branch_graph();
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let opts = SolveOpts {
            ip_budget: Duration::from_secs(5),
            expert: Some(ExpertStyle::EqualStripes),
            ..SolveOpts::default()
        };
        let mut svc = PlannerService::new(4);

        let e0 = counters::enumerate_calls();
        let r0 = counters::reachability_calls();
        let c0 = counters::co_reachability_calls();
        for alg in Algorithm::ALL_THROUGHPUT {
            svc.plan(&g, &sc, alg, &opts).unwrap_or_else(|e| panic!("{alg:?}: {e}"));
        }
        let e1 = counters::enumerate_calls();
        let r1 = counters::reachability_calls();
        let c1 = counters::co_reachability_calls();
        assert!(e1 - e0 <= 1, "IdealLattice::enumerate ran {} times", e1 - e0);
        assert!(r1 - r0 <= 1, "reachability_matrix ran {} times", r1 - r0);
        assert!(c1 - c0 <= 1, "co_reachability_matrix ran {} times", c1 - c0);

        // second pass: pure cache hits, zero new analysis
        for alg in Algorithm::ALL_THROUGHPUT {
            svc.plan(&g, &sc, alg, &opts).unwrap();
        }
        assert_eq!(counters::enumerate_calls(), e1, "cache hit re-enumerated the lattice");
        assert_eq!(counters::reachability_calls(), r1, "cache hit rebuilt reachability");
        assert_eq!(counters::co_reachability_calls(), c1, "cache hit rebuilt co-reachability");
        assert!(svc.hits() >= Algorithm::ALL_THROUGHPUT.len());
    }
}

//! One-call planning façade over every algorithm and baseline, returning
//! uniformly shaped results for tables and the CLI.

use crate::algos::{dp, dpl, ip_latency, ip_throughput, objective};
use crate::baselines::{expert, greedy, local_search, pipedream, scotch_like};
use crate::coordinator::placement::{Placement, Scenario};
use crate::graph::OpGraph;
use crate::workloads::Workload;
use std::time::{Duration, Instant};

/// Algorithm selector (CLI surface).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Dp,
    Dpl,
    IpContiguous,
    IpNonContiguous,
    Expert,
    LocalSearch,
    PipeDream,
    Scotch,
    Greedy,
    IpLatency,
}

impl Algorithm {
    pub fn parse(s: &str) -> Option<Algorithm> {
        Some(match s.to_ascii_lowercase().as_str() {
            "dp" => Algorithm::Dp,
            "dpl" => Algorithm::Dpl,
            "ip" | "ip-contiguous" => Algorithm::IpContiguous,
            "ip-noncontiguous" | "ipnc" => Algorithm::IpNonContiguous,
            "expert" => Algorithm::Expert,
            "local-search" | "ls" => Algorithm::LocalSearch,
            "pipedream" => Algorithm::PipeDream,
            "scotch" => Algorithm::Scotch,
            "greedy" => Algorithm::Greedy,
            "ip-latency" => Algorithm::IpLatency,
            _ => return None,
        })
    }

    pub const ALL_THROUGHPUT: [Algorithm; 8] = [
        Algorithm::Dp,
        Algorithm::IpContiguous,
        Algorithm::IpNonContiguous,
        Algorithm::Dpl,
        Algorithm::Expert,
        Algorithm::LocalSearch,
        Algorithm::PipeDream,
        Algorithm::Scotch,
    ];
}

/// Planner outcome: a placement + run metadata for the tables.
pub struct PlanResult {
    pub placement: Placement,
    pub runtime: Duration,
    /// solver-found-incumbent time (IP engines)
    pub incumbent_at: Option<Duration>,
    pub gap: Option<f64>,
    pub note: String,
}

/// Plan a throughput (pipelined) split. IP time budget via `ip_budget`.
pub fn plan(
    w: &Workload,
    alg: Algorithm,
    ip_budget: Duration,
) -> Result<PlanResult, String> {
    let g = &w.graph;
    let sc = &w.scenario;
    let start = Instant::now();
    let (placement, incumbent_at, gap, note) = match alg {
        Algorithm::Dp => {
            let p = dp::solve(g, sc).map_err(|e| e.to_string())?;
            (p, None, None, String::new())
        }
        Algorithm::Dpl => {
            let p = dpl::solve(g, sc).map_err(|e| e.to_string())?;
            (p, None, None, String::new())
        }
        Algorithm::IpContiguous | Algorithm::IpNonContiguous => {
            let opts = ip_throughput::IpOptions {
                contiguous: alg == Algorithm::IpContiguous,
                time_limit: ip_budget,
                ..Default::default()
            };
            let r = ip_throughput::solve(g, sc, &opts).map_err(|e| e.to_string())?;
            (r.placement, Some(r.incumbent_at), Some(r.gap), format!("{:?}", r.status))
        }
        Algorithm::Expert => {
            let style = w.expert.ok_or("no expert rule for this workload")?;
            (expert::solve(g, sc, style), None, None, String::new())
        }
        Algorithm::LocalSearch => (local_search::solve(g, sc, 10, 0xC0FFEE), None, None, String::new()),
        Algorithm::PipeDream => (pipedream::solve(g, sc), None, None, String::new()),
        Algorithm::Scotch => (scotch_like::solve(g, sc, 0x5C07C4), None, None, String::new()),
        Algorithm::Greedy => (greedy::solve(g, sc), None, None, String::new()),
        Algorithm::IpLatency => {
            let warm = vec![greedy::solve(g, sc)];
            let opts = ip_latency::LatencyIpOptions {
                time_limit: ip_budget,
                warm_starts: warm,
                ..Default::default()
            };
            let r = ip_latency::solve(g, sc, &opts)?;
            (r.placement, Some(r.incumbent_at), Some(r.gap), format!("{:?}", r.status))
        }
    };
    Ok(PlanResult { placement, runtime: start.elapsed(), incumbent_at, gap, note })
}

/// Latency of any placement under the §4 schedule (for Table-4 baselines).
pub fn latency_of(g: &OpGraph, sc: &Scenario, p: &Placement) -> f64 {
    objective::latency(g, sc, p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::table1_workloads;

    #[test]
    fn algorithm_parse_roundtrip() {
        for (s, a) in [
            ("dp", Algorithm::Dp),
            ("DPL", Algorithm::Dpl),
            ("ip", Algorithm::IpContiguous),
            ("ipnc", Algorithm::IpNonContiguous),
            ("scotch", Algorithm::Scotch),
        ] {
            assert_eq!(Algorithm::parse(s), Some(a));
        }
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn plan_small_workload_all_algorithms() {
        // BERT-24 layer inference: small enough to run everything quickly
        let w = table1_workloads().into_iter().find(|w| w.name == "BERT-24").unwrap();
        let budget = Duration::from_secs(2);
        let dp = plan(&w, Algorithm::Dp, budget).unwrap();
        for alg in [
            Algorithm::Dpl,
            Algorithm::Expert,
            Algorithm::LocalSearch,
            Algorithm::PipeDream,
            Algorithm::Scotch,
        ] {
            let r = plan(&w, alg, budget).unwrap();
            assert!(
                r.placement.objective >= dp.placement.objective - 1e-9,
                "{alg:?} beat the DP: {} < {}",
                r.placement.objective,
                dp.placement.objective
            );
        }
    }
}

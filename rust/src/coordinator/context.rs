//! Shared per-`(graph, scenario)` analysis cache and the [`Solver`] trait.
//!
//! Every algorithm in this crate consumes the same structural artifacts —
//! the App.-B preprocessed DP graph, its topological order, the
//! reachability/co-reachability [`BitMatrix`] rows, and the ideal lattice —
//! yet before this module each `solve()` recomputed them from scratch. A
//! [`ProblemCtx`] owns one `(graph, scenario)` pair and lazily computes and
//! memoizes each artifact on first use (thread-safe via [`OnceLock`]), so
//! planning all of [`crate::coordinator::planner::Algorithm::ALL_THROUGHPUT`]
//! builds each artifact exactly once, and re-planning against a cached
//! context (see [`crate::coordinator::service::PlannerService`]) pays only
//! the solver cost — for the deterministic DP/DPL solvers, not even that
//! (their solutions are cached too).
//!
//! Errors are memoized alongside values: a lattice that blows the ideal cap
//! is not re-enumerated on the next call.
//!
//! [`Solver`] is the uniform planning interface: every algorithm and
//! baseline is a `Solver` over `(&ProblemCtx, &SolveOpts)`, which turns the
//! old 10-arm planner match into a registry of boxed solvers.

use crate::algos::dp::{self, Prepared};
use crate::algos::hierarchy::Hierarchy;
use crate::algos::PlaceError;
use crate::baselines::expert::ExpertStyle;
use crate::coordinator::placement::{
    CommModel, DeviceKind, Placement, PlanRequest, Scenario, TrainSchedule,
};
use crate::graph::ideals::{IdealLattice, DEFAULT_IDEAL_CAP};
use crate::graph::{topo, NodeId, OpGraph};
use crate::util::arena::BitMatrix;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Cooperative cancellation budget for one solve. Checked *periodically*
/// (every N search nodes) inside the branch-and-bound engines and the
/// lattice enumerators, so an unbudgeted solve pays only an integer modulo
/// per node and stays bitwise identical to the pre-budget behavior. On
/// expiry a search stops and returns its best incumbent so far (tagged
/// [`PlanQuality::Anytime`]) instead of erroring.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SolveBudget {
    /// Absolute wall-clock cutoff; `None` = no deadline (the engines still
    /// honor their own [`SolveOpts::ip_budget`] time limit).
    pub deadline: Option<Instant>,
    /// Cap on search nodes explored (branch-and-bound nodes for the IPs,
    /// enumerated ideals for the lattice solvers); `None` = unlimited.
    /// Deterministic, unlike the wall-clock deadline — tests pin anytime
    /// behavior with this.
    pub node_limit: Option<u64>,
}

impl SolveBudget {
    /// No deadline, no node limit — the historical behavior.
    pub const UNLIMITED: SolveBudget = SolveBudget { deadline: None, node_limit: None };

    /// A budget whose deadline is `d` from now (node limit unset).
    pub fn deadline_in(d: Duration) -> SolveBudget {
        SolveBudget { deadline: Some(Instant::now() + d), node_limit: None }
    }

    /// True when neither constraint is set.
    pub fn is_unlimited(&self) -> bool {
        self.deadline.is_none() && self.node_limit.is_none()
    }

    /// True when the wall-clock deadline has already passed.
    pub fn expired(&self) -> bool {
        matches!(self.deadline, Some(d) if Instant::now() >= d)
    }

    /// The effective search cutoff: the earlier of the engine's own time
    /// limit (`start + limit`) and this budget's deadline.
    pub fn clamp_deadline(&self, start: Instant, limit: Duration) -> Instant {
        let own = start + limit;
        match self.deadline {
            Some(d) if d < own => d,
            _ => own,
        }
    }
}

/// Which rung of the degradation ladder produced a plan. Also the label
/// vocabulary of the `plan_fallback_total{rung=...}` obs counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanRung {
    /// The branch-and-bound IP engines.
    Ip,
    /// The exact DP over the ideal lattice.
    Dp,
    /// The DPL linearization heuristic.
    Dpl,
    /// The communication-oblivious greedy (always answers).
    Greedy,
}

impl PlanRung {
    pub fn name(&self) -> &'static str {
        match self {
            PlanRung::Ip => "ip",
            PlanRung::Dp => "dp",
            PlanRung::Dpl => "dpl",
            PlanRung::Greedy => "greedy",
        }
    }
}

/// Whether a plan came from a solver that ran to natural completion or
/// from a budget-truncated (anytime) search. `Exact` means *untruncated*
/// — a heuristic that finished normally is `Exact` quality even though
/// its answer carries no optimality proof.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanQuality {
    /// The solver ran to completion (proof closed, gap target met, or the
    /// deterministic/heuristic algorithm simply finished).
    Exact,
    /// Best incumbent at a [`SolveBudget`] cutoff, from the named rung.
    Anytime(PlanRung),
}

impl std::fmt::Display for PlanQuality {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanQuality::Exact => write!(f, "exact"),
            PlanQuality::Anytime(rung) => write!(f, "anytime({})", rung.name()),
        }
    }
}

/// Planner outcome: a placement + run metadata for the tables.
pub struct PlanResult {
    pub placement: Placement,
    pub runtime: Duration,
    /// solver-found-incumbent time (IP engines)
    pub incumbent_at: Option<Duration>,
    pub gap: Option<f64>,
    pub note: String,
    /// The solver's final incumbent in resumable form (IP engines only) —
    /// what the [`crate::coordinator::concurrent::ConcurrentService`]
    /// incumbent cache stores so a later solve of the same problem resumes
    /// instead of restarting.
    pub warm_seed: Option<WarmSeed>,
    /// `Exact` unless a [`SolveBudget`] truncated the search and this is
    /// the best incumbent at the cutoff.
    pub quality: PlanQuality,
}

impl PlanResult {
    /// Result of a solver with no proof state (everything but the IPs).
    pub fn basic(placement: Placement, runtime: Duration) -> PlanResult {
        PlanResult {
            placement,
            runtime,
            incumbent_at: None,
            gap: None,
            note: String::new(),
            warm_seed: None,
            quality: PlanQuality::Exact,
        }
    }
}

/// A solver-produced incumbent that can seed a later solve of the *same*
/// planning problem (equal [`fingerprint_req`]) under the *same* search
/// regime (same engine + contiguity toggle — see
/// `planner::warm_seed_key`). Throughput seeds live in the dense
/// `dp_graph` space the throughput branch-and-bound assigns over; latency
/// seeds are original-graph placements, re-validated by the latency IP
/// like any caller-supplied warm start. Injection is monotone by
/// construction: a seed only ever *replaces* an engine's initial incumbent
/// when strictly better, and the searches only improve incumbents — a
/// warm-started solve can never return a worse objective than a cold one.
#[derive(Clone, Debug)]
pub enum WarmSeed {
    /// `(objective, dense dp_graph assignment)` — the throughput search's
    /// native incumbent form.
    Throughput { objective: f64, dense: Vec<usize> },
    /// Original-graph placement — the latency IP's warm-start form.
    Latency(Placement),
}

impl WarmSeed {
    /// The seed's objective in its own search space (dp-proxy max-load for
    /// throughput, end-to-end latency for latency) — the comparison basis
    /// of the incumbent cache's keep-the-best rule.
    pub fn objective(&self) -> f64 {
        match self {
            WarmSeed::Throughput { objective, .. } => *objective,
            WarmSeed::Latency(p) => p.objective,
        }
    }
}

/// Per-call knobs shared by every [`Solver`]. Defaults reproduce the
/// planner façade's historical behavior bit-for-bit (same baseline seeds,
/// same IP budget shape).
#[derive(Clone, Debug)]
pub struct SolveOpts {
    /// Time budget for the IP branch-and-bound engines.
    pub ip_budget: Duration,
    /// Stop the IPs once the proven gap is below this (paper uses 1%).
    pub gap_target: f64,
    /// Expert rule for the expert baseline (from the workload; layer
    /// graphs only).
    pub expert: Option<ExpertStyle>,
    /// Cluster topology for the hierarchy solver; `None` = an even
    /// two-cluster split of the scenario's accelerators.
    pub hierarchy: Option<Hierarchy>,
    /// Local-search restarts.
    pub ls_restarts: usize,
    /// Local-search seed.
    pub ls_seed: u64,
    /// Scotch-like partitioner seed.
    pub scotch_seed: u64,
    /// Prior incumbent to resume an IP solve from (injected by the
    /// [`crate::coordinator::concurrent::ConcurrentService`] incumbent
    /// cache; `None` = cold solve, the historical behavior). Ignored by
    /// the non-IP solvers.
    pub warm_seed: Option<WarmSeed>,
    /// Cooperative cancellation budget (deadline and/or node limit). The
    /// default is [`SolveBudget::UNLIMITED`], which is bitwise-invisible:
    /// every solver behaves exactly as it did before budgets existed.
    pub budget: SolveBudget,
}

impl Default for SolveOpts {
    fn default() -> Self {
        SolveOpts {
            ip_budget: Duration::from_secs(20),
            gap_target: 0.01,
            expert: None,
            hierarchy: None,
            ls_restarts: 10,
            ls_seed: 0xC0FFEE,
            scotch_seed: 0x5C07C4,
            warm_seed: None,
            budget: SolveBudget::UNLIMITED,
        }
    }
}

/// The uniform planning interface implemented by all seven algorithms and
/// all five baselines. Implementations read shared artifacts from the
/// context instead of recomputing them.
pub trait Solver: Send + Sync {
    /// Canonical registry/CLI name ("dp", "ip-contiguous", …).
    fn name(&self) -> &'static str;

    fn solve(&self, ctx: &ProblemCtx, opts: &SolveOpts) -> Result<PlanResult, PlaceError>;
}

type Cached<T> = OnceLock<Result<T, PlaceError>>;

/// Lazily computed, memoized analysis artifacts of one `(graph, scenario)`
/// pair. Cheap to create (two clones); every artifact is built on first
/// use and shared by reference afterwards. `Send + Sync`: contexts can be
/// shared across planning threads.
pub struct ProblemCtx {
    graph: OpGraph,
    request: PlanRequest,
    /// Scalar view of `request` kept for legacy callers of
    /// [`ProblemCtx::scenario`] (exact for uniform fleets, conservative
    /// otherwise).
    legacy_scenario: Scenario,
    ideal_cap: usize,
    fingerprint: u64,
    /// App.-B preprocessing (subdivide, fw/bw merge, colocation contraction).
    prepared: Cached<Prepared>,
    /// `dp_graph` with the gradient comm folded into node `comm` — the
    /// PipeDream-style proxy cost model the IPs and Appendix-C DPs search.
    proxy: Cached<OpGraph>,
    /// Ideal lattice of `dp_graph`, capped at `ideal_cap`.
    lattice: Cached<IdealLattice>,
    /// The DPL prefix lattice (`|V|+1` ideals along a DFS linearization of
    /// `dp_graph`) — built directly from the order, no enumeration.
    lin_lattice: Cached<IdealLattice>,
    /// Topological order of `dp_graph`.
    dp_order: Cached<Vec<NodeId>>,
    /// Reachability rows of `dp_graph` (valid for `proxy` too — same edges).
    dp_reach: Cached<BitMatrix>,
    dp_co_reach: Cached<BitMatrix>,
    /// Original-graph artifacts (the latency IP searches the raw graph).
    orig_order: Cached<Vec<NodeId>>,
    orig_reach: Cached<BitMatrix>,
    orig_co_reach: Cached<BitMatrix>,
    /// Cached deterministic solutions on `dp_graph` (objective, dense
    /// assignment): reused as the solvers' outputs and as IP warm starts.
    dp_solution: Cached<(f64, Vec<usize>)>,
    dpl_solution: Cached<(f64, Vec<usize>)>,
    /// Cheap throughput warm start for the IPs (see
    /// [`ProblemCtx::warm_solution`]).
    warm_solution: Cached<(f64, Vec<usize>)>,
}

impl ProblemCtx {
    /// Context with the default ideal cap ([`DEFAULT_IDEAL_CAP`]).
    pub fn new(graph: OpGraph, scenario: Scenario) -> ProblemCtx {
        Self::with_cap(graph, scenario, DEFAULT_IDEAL_CAP)
    }

    /// Context with an explicit lattice enumeration cap.
    pub fn with_cap(graph: OpGraph, scenario: Scenario, ideal_cap: usize) -> ProblemCtx {
        Self::from_request_with_cap(graph, scenario.to_request(), ideal_cap)
    }

    /// Context over a heterogeneous [`PlanRequest`] with the default cap.
    pub fn from_request(graph: OpGraph, request: PlanRequest) -> ProblemCtx {
        Self::from_request_with_cap(graph, request, DEFAULT_IDEAL_CAP)
    }

    /// [`ProblemCtx::from_request`] with an explicit lattice cap.
    pub fn from_request_with_cap(
        graph: OpGraph,
        request: PlanRequest,
        ideal_cap: usize,
    ) -> ProblemCtx {
        crate::util::counters::bump_ctx_build();
        let fingerprint = fingerprint_req(&graph, &request);
        let legacy_scenario = request.legacy_scenario();
        ProblemCtx {
            graph,
            request,
            legacy_scenario,
            ideal_cap,
            fingerprint,
            prepared: OnceLock::new(),
            proxy: OnceLock::new(),
            lattice: OnceLock::new(),
            lin_lattice: OnceLock::new(),
            dp_order: OnceLock::new(),
            dp_reach: OnceLock::new(),
            dp_co_reach: OnceLock::new(),
            orig_order: OnceLock::new(),
            orig_reach: OnceLock::new(),
            orig_co_reach: OnceLock::new(),
            dp_solution: OnceLock::new(),
            dpl_solution: OnceLock::new(),
            warm_solution: OnceLock::new(),
        }
    }

    pub fn graph(&self) -> &OpGraph {
        &self.graph
    }

    /// The full planning request (fleet, comm model, schedule, …) this
    /// context's artifacts and cached solutions are computed against.
    pub fn request(&self) -> &PlanRequest {
        &self.request
    }

    /// Deprecated scalar view of [`ProblemCtx::request`]: exact for
    /// uniform fleets, conservative (smallest accelerator cap) otherwise.
    /// Fleet-aware code should read `request()` instead.
    pub fn scenario(&self) -> &Scenario {
        &self.legacy_scenario
    }

    pub fn ideal_cap(&self) -> usize {
        self.ideal_cap
    }

    /// Content hash of `(graph, scenario)` — the cache key under which
    /// [`crate::coordinator::service::PlannerService`] stores this context.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    fn cached<'a, T>(
        cell: &'a Cached<T>,
        init: impl FnOnce() -> Result<T, PlaceError>,
    ) -> Result<&'a T, PlaceError> {
        cell.get_or_init(init).as_ref().map_err(Clone::clone)
    }

    /// App.-B preprocessed problem (see [`Prepared`]).
    pub fn prepared(&self) -> Result<&Prepared, PlaceError> {
        Self::cached(&self.prepared, || {
            let _span = crate::obs::span_cat("ctx.prepared", "ctx");
            Prepared::build(&self.graph)
        })
    }

    /// `dp_graph` with gradient comm folded into node comm (zero fold for
    /// inference graphs) — the search cost model of the IPs and the
    /// Appendix-C DPs.
    pub fn proxy(&self) -> Result<&OpGraph, PlaceError> {
        Self::cached(&self.proxy, || {
            let prepared = self.prepared()?;
            let mut proxy = prepared.dp_graph.clone();
            for (v, node) in proxy.nodes.iter_mut().enumerate() {
                node.comm += prepared.bw_comm[v];
            }
            Ok(proxy)
        })
    }

    /// The lattice only if an earlier call already built (or failed to
    /// build) it — never triggers enumeration itself. Used by the IP warm
    /// start to piggyback on a DP plan without paying full-cap enumeration
    /// on its own.
    pub fn lattice_if_built(&self) -> Option<Result<&IdealLattice, PlaceError>> {
        self.lattice.get().map(|r| r.as_ref().map_err(Clone::clone))
    }

    /// The ideal lattice of `dp_graph`, enumerated once per context.
    pub fn lattice(&self) -> Result<&IdealLattice, PlaceError> {
        Self::cached(&self.lattice, || {
            let prepared = self.prepared()?;
            let _span = crate::obs::span_cat("ctx.lattice", "ctx");
            IdealLattice::enumerate(&prepared.dp_graph, self.ideal_cap)
                .map_err(PlaceError::TooManyIdeals)
        })
    }

    /// The DPL prefix lattice over a DFS linearization of `dp_graph`.
    pub fn lin_lattice(&self) -> Result<&IdealLattice, PlaceError> {
        Self::cached(&self.lin_lattice, || {
            let prepared = self.prepared()?;
            let _span = crate::obs::span_cat("ctx.lin_lattice", "ctx");
            let order = topo::dfs_linearization(&prepared.dp_graph);
            Ok(IdealLattice::from_prefixes(prepared.dp_graph.n(), &order))
        })
    }

    /// Topological order of `dp_graph`.
    pub fn dp_order(&self) -> Result<&[NodeId], PlaceError> {
        Self::cached(&self.dp_order, || {
            let prepared = self.prepared()?;
            topo::toposort(&prepared.dp_graph).ok_or(PlaceError::NotADag)
        })
        .map(Vec::as_slice)
    }

    /// Reachability rows of `dp_graph` (descendants per row).
    pub fn dp_reach(&self) -> Result<&BitMatrix, PlaceError> {
        Self::cached(&self.dp_reach, || {
            self.dp_order()?; // DAG guard
            let _span = crate::obs::span_cat("ctx.dp_reach", "ctx");
            Ok(topo::reachability_matrix(&self.prepared()?.dp_graph))
        })
    }

    /// Co-reachability rows of `dp_graph` (ancestors per row).
    pub fn dp_co_reach(&self) -> Result<&BitMatrix, PlaceError> {
        Self::cached(&self.dp_co_reach, || {
            self.dp_order()?;
            let _span = crate::obs::span_cat("ctx.dp_co_reach", "ctx");
            Ok(topo::co_reachability_matrix(&self.prepared()?.dp_graph))
        })
    }

    /// Topological order of the *original* graph.
    pub fn orig_order(&self) -> Result<&[NodeId], PlaceError> {
        Self::cached(&self.orig_order, || {
            topo::toposort(&self.graph).ok_or(PlaceError::NotADag)
        })
        .map(Vec::as_slice)
    }

    /// Reachability rows of the original graph.
    pub fn orig_reach(&self) -> Result<&BitMatrix, PlaceError> {
        Self::cached(&self.orig_reach, || {
            self.orig_order()?;
            let _span = crate::obs::span_cat("ctx.orig_reach", "ctx");
            Ok(topo::reachability_matrix(&self.graph))
        })
    }

    /// Co-reachability rows of the original graph.
    pub fn orig_co_reach(&self) -> Result<&BitMatrix, PlaceError> {
        Self::cached(&self.orig_co_reach, || {
            self.orig_order()?;
            let _span = crate::obs::span_cat("ctx.orig_co_reach", "ctx");
            Ok(topo::co_reachability_matrix(&self.graph))
        })
    }

    /// The exact throughput DP's `(objective, dense assignment)` on
    /// `dp_graph` — deterministic for a given context (bitwise, any thread
    /// count), so it is computed once and shared (DP solver output, IP
    /// warm start, serving re-plans).
    pub fn dp_solution(&self) -> Result<&(f64, Vec<usize>), PlaceError> {
        Self::cached(&self.dp_solution, || {
            let prepared = self.prepared()?;
            let lattice = self.lattice()?;
            let _span = crate::obs::span_cat("ctx.dp_solve", "ctx");
            dp::solve_on_lattice_req(
                &prepared.dp_graph,
                &self.request,
                lattice,
                &prepared.bw_comm,
            )
        })
    }

    /// A cheap throughput warm start for the IP engines: the cached DP
    /// solution when that is affordable (the context's lattice is already
    /// built, or its cap is at most the historical 20k warm-start bound),
    /// otherwise a LOCAL 20k-capped DP with DPL fallback — never the
    /// context's full-cap enumeration just to warm up a time-budgeted
    /// search. Memoized, so IP-only replanning pays it once per context.
    pub fn warm_solution(&self) -> Result<&(f64, Vec<usize>), PlaceError> {
        const WARM_IDEAL_CAP: usize = 20_000;
        Self::cached(&self.warm_solution, || {
            if self.ideal_cap <= WARM_IDEAL_CAP || self.lattice.get().is_some() {
                return self
                    .dp_solution()
                    .or_else(|_| self.dpl_solution())
                    .map(Clone::clone);
            }
            let prepared = self.prepared()?;
            if let Ok(lat) = IdealLattice::enumerate(&prepared.dp_graph, WARM_IDEAL_CAP) {
                if let Ok(sol) = dp::solve_on_lattice_req(
                    &prepared.dp_graph,
                    &self.request,
                    &lat,
                    &prepared.bw_comm,
                ) {
                    return Ok(sol);
                }
            }
            self.dpl_solution().map(Clone::clone)
        })
    }

    /// The DPL heuristic's `(objective, dense assignment)` on `dp_graph`.
    pub fn dpl_solution(&self) -> Result<&(f64, Vec<usize>), PlaceError> {
        Self::cached(&self.dpl_solution, || {
            let prepared = self.prepared()?;
            let lattice = self.lin_lattice()?;
            let _span = crate::obs::span_cat("ctx.dpl_solve", "ctx");
            dp::solve_on_lattice_req(
                &prepared.dp_graph,
                &self.request,
                lattice,
                &prepared.bw_comm,
            )
        })
    }
}

/// Legacy scalar form of [`fingerprint_req`]: a scenario fingerprints as
/// its uniform-fleet request, so scenario-path and fleet-path callers of
/// [`crate::coordinator::service::PlannerService`] share cache entries
/// for the same problem.
pub fn fingerprint(g: &OpGraph, sc: &Scenario) -> u64 {
    fingerprint_req(g, &sc.to_request())
}

/// 64-bit FNV-1a content fingerprint of a `(graph, request)` pair: node
/// names, all four cost fields, colocation classes, kinds, fw partners,
/// edges, per-edge costs, every fleet class (name, count, cap, speed,
/// kind), bandwidth, comm model and train schedule. Deliberately
/// EXCLUDED: `objective`, `contiguous` and `algorithm` — they are
/// per-call solver selectors that invalidate none of the cached analysis
/// artifacts or deterministic solutions (DESIGN.md §5). Two pairs with
/// equal fingerprints are treated as the same planning problem by
/// [`crate::coordinator::service::PlannerService`].
pub fn fingerprint_req(g: &OpGraph, req: &PlanRequest) -> u64 {
    let mut h = Fnv::new();
    h.u64(g.n() as u64);
    for node in &g.nodes {
        h.bytes(node.name.as_bytes());
        h.f64(node.p_cpu);
        h.f64(node.p_acc);
        h.f64(node.mem);
        h.f64(node.comm);
        h.u64(node.color_class.map_or(0, |c| c as u64 + 1));
        h.u64(match node.kind {
            crate::graph::NodeKind::Forward => 0,
            crate::graph::NodeKind::Backward => 1,
        });
        h.u64(node.fw_partner.map_or(0, |p| p as u64 + 1));
    }
    for (u, v) in g.edges() {
        h.u64(u as u64);
        h.u64(v as u64);
    }
    for (&(u, v), &c) in &g.edge_costs {
        h.u64(u as u64);
        h.u64(v as u64);
        h.f64(c);
    }
    h.u64(req.fleet.classes.len() as u64);
    for class in &req.fleet.classes {
        h.bytes(class.name.as_bytes());
        h.u64(class.count as u64);
        h.f64(class.mem_cap);
        h.f64(class.speed);
        h.u64(match class.kind {
            DeviceKind::Accelerator => 0,
            DeviceKind::Cpu => 1,
        });
    }
    h.u64(match req.comm_model {
        CommModel::Sequential => 0,
        CommModel::Overlap => 1,
        CommModel::FullDuplex => 2,
    });
    h.u64(match req.train_schedule {
        TrainSchedule::PipeDream => 0,
        TrainSchedule::GPipe => 1,
    });
    h.f64(req.fleet.bandwidth);
    // Interconnect topology: per-pair slowdowns/latencies are part of the
    // cost model, so two requests differing only in `topo=` must not share
    // cached analysis or deterministic solutions. Hash the derived cost
    // matrices (what every solver actually reads), not the spec string.
    match &req.fleet.topology {
        None => h.u64(0),
        Some(t) => {
            h.u64(1);
            let n = t.n();
            h.u64(n as u64);
            for a in 0..n {
                for b in 0..n {
                    h.f64(t.slowdown(a, b));
                    h.f64(t.latency(a, b));
                }
            }
        }
    }
    h.0
}

struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn u64(&mut self, x: u64) {
        self.0 = (self.0 ^ x).wrapping_mul(0x1000_0000_01b3);
    }

    fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }

    fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.u64(x as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Node;
    use crate::util::counters;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(9.0).acc(1.0).mem(1.0).comm(0.2));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn artifacts_are_built_once_and_shared() {
        let ctx = ProblemCtx::new(chain(6), Scenario::new(2, 1, f64::INFINITY));
        let e0 = counters::enumerate_calls();
        let r0 = counters::reachability_calls();
        let lat1 = ctx.lattice().unwrap() as *const IdealLattice;
        let lat2 = ctx.lattice().unwrap() as *const IdealLattice;
        assert_eq!(lat1, lat2, "lattice must be memoized, not rebuilt");
        assert_eq!(counters::enumerate_calls() - e0, 1);
        ctx.dp_reach().unwrap();
        ctx.dp_reach().unwrap();
        assert_eq!(counters::reachability_calls() - r0, 1);
        // lin lattice comes from prefixes — no further enumerate calls
        ctx.lin_lattice().unwrap();
        assert_eq!(counters::enumerate_calls() - e0, 1);
    }

    #[test]
    fn errors_are_memoized() {
        // a 10-node antichain has 1024 ideals; cap 10 must fail, once
        let mut g = OpGraph::new();
        for i in 0..10 {
            g.add_node(Node::new(format!("a{i}")));
        }
        let ctx = ProblemCtx::with_cap(g, Scenario::new(2, 1, f64::INFINITY), 10);
        let e0 = counters::enumerate_calls();
        assert!(matches!(ctx.lattice(), Err(PlaceError::TooManyIdeals(_))));
        assert!(matches!(ctx.lattice(), Err(PlaceError::TooManyIdeals(_))));
        assert_eq!(counters::enumerate_calls() - e0, 1, "failed enumerate must be cached");
    }

    #[test]
    fn dp_solution_matches_free_function() {
        let g = chain(6);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let ctx = ProblemCtx::new(g.clone(), sc.clone());
        let (obj, _) = ctx.dp_solution().unwrap();
        let free = dp::solve(&g, &sc).unwrap();
        assert!((obj - free.objective).abs() < 1e-9, "ctx {obj} vs free {}", free.objective);
    }

    #[test]
    fn fingerprint_sensitivity() {
        let g = chain(5);
        let sc = Scenario::new(2, 1, 16.0);
        let base = fingerprint(&g, &sc);
        assert_eq!(base, fingerprint(&g.clone(), &sc.clone()), "deterministic");
        // scenario changes
        assert_ne!(base, fingerprint(&g, &Scenario::new(3, 1, 16.0)));
        assert_ne!(base, fingerprint(&g, &Scenario::new(2, 1, 8.0)));
        // cost change
        let mut g2 = g.clone();
        g2.nodes[3].p_acc += 0.5;
        assert_ne!(base, fingerprint(&g2, &sc));
        // edge change
        let mut g3 = g.clone();
        g3.add_edge(0, 4);
        assert_ne!(base, fingerprint(&g3, &sc));
        // name change (expert rules key on names)
        let mut g4 = g.clone();
        g4.nodes[0].name = "other".into();
        assert_ne!(base, fingerprint(&g4, &sc));
    }

    #[test]
    fn fingerprint_hashes_the_fleet() {
        use crate::coordinator::placement::{
            AlgoChoice, DeviceClass, Fleet, Objective, PlanRequest,
        };
        let g = chain(5);
        let base_req = PlanRequest::new(Fleet::new(vec![
            DeviceClass::acc("a100", 2, 40.0).speed(4.0),
            DeviceClass::acc("t4", 4, 16.0),
            DeviceClass::cpu("cpu", 1),
        ]));
        let base = fingerprint_req(&g, &base_req);
        assert_eq!(base, fingerprint_req(&g, &base_req.clone()), "deterministic");
        // class count change (device loss)
        let mut lost = base_req.clone();
        assert!(lost.fleet.decrement("t4"));
        assert_ne!(base, fingerprint_req(&g, &lost));
        // per-class cap and speed changes
        let mut squeezed = base_req.clone();
        squeezed.fleet.class_named_mut("a100").unwrap().mem_cap = 20.0;
        assert_ne!(base, fingerprint_req(&g, &squeezed));
        let mut slowed = base_req.clone();
        slowed.fleet.class_named_mut("a100").unwrap().speed = 2.0;
        assert_ne!(base, fingerprint_req(&g, &slowed));
        // solver selectors do NOT invalidate the analysis cache
        let relabeled = base_req
            .clone()
            .objective(Objective::Latency)
            .contiguous(false)
            .algorithm(AlgoChoice::Fixed(crate::coordinator::planner::Algorithm::Dpl));
        assert_eq!(base, fingerprint_req(&g, &relabeled));
        // a scenario and its uniform fleet share a fingerprint
        let sc = Scenario::new(2, 1, 16.0);
        assert_eq!(fingerprint(&g, &sc), fingerprint_req(&g, &sc.to_request()));
    }
}
